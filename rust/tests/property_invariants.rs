//! Property-based tests (hand-rolled generators — the offline build has
//! no proptest crate): randomized inputs over many seeds asserting the
//! framework's algebraic invariants:
//!
//! * the aggregator exchange law (paper App. B.2), for dense statistics
//!   and for every sparse/dense shape mix of the `StatValue` path,
//! * scheduler coverage / determinism / LPT dominance,
//! * clip idempotence and norm bounds,
//! * accountant monotonicity (σ, T, q) and RDP ≥ PLD orderings,
//! * replay-model roofline bounds,
//! * metrics merge commutativity.

use pfl::fl::aggregator::{Aggregator, CollectAggregator, SumAggregator};
use pfl::fl::model::{ClipKernel, RustClip};
use pfl::fl::scheduler::{median, schedule, SchedulerKind};
use pfl::fl::stats::{StatValue, Statistics};
use pfl::fl::Metrics;
use pfl::privacy::{Accountant, AccountantParams, PldAccountant, RdpAccountant};
use pfl::simsys::{replay_cluster, replay_round, UserCost};
use pfl::tensor::{ArenaConfig, StatsArena};
use pfl::util::rng::Rng;

const TRIALS: u64 = 25;

fn rand_stats(rng: &mut Rng, dim: usize) -> Statistics {
    let v: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
    let mut s = Statistics::new_update(v, 1.0 + rng.below(5) as f64);
    if rng.f64() < 0.5 {
        s.insert("extra", (0..dim).map(|_| rng.normal() as f32).collect());
    }
    s
}

/// A random sparse value of logical length `dim` (possibly empty).
fn rand_sparse(rng: &mut Rng, dim: usize) -> StatValue {
    let mut idx = Vec::new();
    let mut val = Vec::new();
    for i in 0..dim {
        if rng.f64() < 0.3 {
            idx.push(i as u32);
            val.push(rng.normal() as f32);
        }
    }
    StatValue::sparse(dim as u32, idx, val)
}

/// A statistics record whose update is randomly dense or sparse.
fn rand_mixed_stats(rng: &mut Rng, dim: usize) -> Statistics {
    let value = if rng.f64() < 0.5 {
        StatValue::Dense((0..dim).map(|_| rng.normal() as f32).collect())
    } else {
        rand_sparse(rng, dim)
    };
    Statistics::new_update_value(value, 1.0 + rng.below(5) as f64)
}

/// Canonical dense view of a statistic value, padded to `dim`.
fn dense_of(s: &Statistics, key: &str, dim: usize) -> Vec<f32> {
    let mut v = s.value(key).map(|x| x.to_dense_vec()).unwrap_or_default();
    v.resize(dim, 0.0);
    v
}

fn assert_close(a: &[f32], b: &[f32], msg: &str) {
    assert_eq!(a.len(), b.len(), "{msg}: length {} vs {}", a.len(), b.len());
    for (x, y) in a.iter().zip(b) {
        assert!((x - y).abs() < 1e-4, "{msg}: {x} vs {y}");
    }
}

/// g({f(Sa, Δ), Sb}) = g({f(Sb, Δ), Sa}) = f(g({Sa, Sb}), Δ)
#[test]
fn sum_aggregator_exchange_law_randomized() {
    for seed in 0..TRIALS {
        let mut rng = Rng::seed_from_u64(seed);
        let dim = 1 + rng.below(32);
        let sa = rand_stats(&mut rng, dim);
        let sb = rand_stats(&mut rng, dim);
        let delta = rand_stats(&mut rng, dim);
        let agg = SumAggregator;

        let left = {
            let mut acc = Some(sa.clone());
            agg.accumulate(&mut acc, delta.clone());
            agg.worker_reduce(vec![acc.unwrap(), sb.clone()]).unwrap()
        };
        let middle = {
            let mut acc = Some(sb.clone());
            agg.accumulate(&mut acc, delta.clone());
            agg.worker_reduce(vec![acc.unwrap(), sa.clone()]).unwrap()
        };
        let right = {
            let mut acc = agg.worker_reduce(vec![sa.clone(), sb.clone()]);
            agg.accumulate(&mut acc, delta.clone());
            acc.unwrap()
        };
        for pair in [(&left, &middle), (&left, &right)] {
            assert_eq!(pair.0.weight, pair.1.weight, "seed {seed}");
            assert_eq!(
                pair.0.vecs.keys().collect::<Vec<_>>(),
                pair.1.vecs.keys().collect::<Vec<_>>()
            );
            for k in pair.0.vecs.keys() {
                assert_close(
                    &dense_of(pair.0, k, dim),
                    &dense_of(pair.1, k, dim),
                    &format!("seed {seed} key {k}"),
                );
            }
        }
    }
}

/// The exchange law over every sparse/dense mix of (Sa, Sb, Δ):
/// g({f(Sa, Δ), Sb}) = g({f(Sb, Δ), Sa}) = f(g({Sa, Sb}), Δ).
#[test]
fn sum_aggregator_sparse_exchange_law_randomized() {
    for seed in 0..TRIALS * 4 {
        let mut rng = Rng::seed_from_u64(seed ^ 0x5AB5);
        let dim = 1 + rng.below(48);
        let sa = rand_mixed_stats(&mut rng, dim);
        let sb = rand_mixed_stats(&mut rng, dim);
        let delta = rand_mixed_stats(&mut rng, dim);
        let agg = SumAggregator;

        let left = {
            let mut acc = Some(sa.clone());
            agg.accumulate(&mut acc, delta.clone());
            agg.worker_reduce(vec![acc.unwrap(), sb.clone()]).unwrap()
        };
        let middle = {
            let mut acc = Some(sb.clone());
            agg.accumulate(&mut acc, delta.clone());
            agg.worker_reduce(vec![acc.unwrap(), sa.clone()]).unwrap()
        };
        let right = {
            let mut acc = agg.worker_reduce(vec![sa.clone(), sb.clone()]);
            agg.accumulate(&mut acc, delta.clone());
            acc.unwrap()
        };

        // reference: densify everything and sum coordinatewise
        let mut expect = vec![0.0f32; dim];
        for s in [&sa, &sb, &delta] {
            for (e, x) in expect.iter_mut().zip(dense_of(s, "update", dim)) {
                *e += x;
            }
        }
        let w = sa.weight + sb.weight + delta.weight;
        for (name, got) in [("left", &left), ("middle", &middle), ("right", &right)] {
            assert_eq!(got.weight, w, "seed {seed} {name}");
            assert_close(
                &dense_of(got, "update", dim),
                &expect,
                &format!("seed {seed} {name}"),
            );
        }
    }
}

/// The worker's arena fold must agree with the move-based accumulate on
/// any sparse/dense user mix, including all-sparse rounds.
#[test]
fn arena_fold_matches_accumulate_on_mixes() {
    for seed in 0..TRIALS * 2 {
        let mut rng = Rng::seed_from_u64(seed ^ 0xA4E);
        let dim = 1 + rng.below(64);
        let users: Vec<Statistics> =
            (0..1 + rng.below(12)).map(|_| rand_mixed_stats(&mut rng, dim)).collect();

        let mut arena = StatsArena::new();
        for u in &users {
            arena.fold(u);
        }
        let a = arena.take_partial().unwrap();

        let agg = SumAggregator;
        let mut acc = None;
        for u in users.clone() {
            agg.accumulate(&mut acc, u);
        }
        let b = acc.unwrap();

        assert_eq!(a.weight, b.weight, "seed {seed}");
        assert_close(
            &dense_of(&a, "update", dim),
            &dense_of(&b, "update", dim),
            &format!("seed {seed}"),
        );
    }
}

/// Exchange law of the sparse-aware arena with the spill threshold
/// crossed mid-round: random mixed dense/sparse cohorts split across two
/// arenas (simulating two workers) must reduce to the same statistics as
/// the single-accumulator fold, regardless of which slots spilled where.
#[test]
fn sparse_arena_exchange_law_across_spill_threshold() {
    for seed in 0..TRIALS * 2 {
        let mut rng = Rng::seed_from_u64(seed ^ 0x59A5);
        let dim = 8 + rng.below(56);
        // a low threshold so some rounds cross it mid-round (each sparse
        // user carries ~30% nnz) while all-sparse small unions stay under
        let config = ArenaConfig { sparse_spill_frac: 0.4 };
        let users: Vec<Statistics> =
            (0..2 + rng.below(10)).map(|_| rand_mixed_stats(&mut rng, dim)).collect();

        // one arena folds everything
        let mut arena = StatsArena::with_config(config);
        for u in &users {
            arena.fold(u);
        }
        let single = arena.take_partial().unwrap();

        // two "workers" fold an interleaved split; reduce the partials
        let mut a0 = StatsArena::with_config(config);
        let mut a1 = StatsArena::with_config(config);
        for (i, u) in users.iter().enumerate() {
            if i % 2 == 0 {
                a0.fold(u);
            } else {
                a1.fold(u);
            }
        }
        let partials: Vec<Statistics> =
            [a0.take_partial(), a1.take_partial()].into_iter().flatten().collect();
        let reduced = SumAggregator.worker_reduce(partials).unwrap();

        // reference: the move-based accumulate
        let mut acc = None;
        for u in users.clone() {
            SumAggregator.accumulate(&mut acc, u.clone());
        }
        let reference = acc.unwrap();

        for (name, got) in [("single-arena", &single), ("split-reduce", &reduced)] {
            assert_eq!(got.weight, reference.weight, "seed {seed} {name}");
            assert_close(
                &dense_of(got, "update", dim),
                &dense_of(&reference, "update", dim),
                &format!("seed {seed} {name}"),
            );
        }
    }
}

/// All-sparse regime: the arena must stay in sparse mode (no spills, a
/// sparse partial every round) and reach the zero-allocation steady
/// state after the first round of a repeating cohort shape.
#[test]
fn all_sparse_arena_zero_growth_steady_state() {
    let mut arena = StatsArena::new(); // default spill frac 0.25
    let dim = 4096u32;
    // GBDT-style tiny users: 8 nnz each, union 32 nnz « 0.25·dim
    let users: Vec<Statistics> = (0u32..4)
        .map(|u| {
            let idx: Vec<u32> = (0u32..8).map(|i| u * 512 + i * 9).collect();
            let val: Vec<f32> = (0u32..8).map(|i| (u * 8 + i) as f32 * 0.5 - 2.0).collect();
            Statistics::new_update_value(StatValue::sparse(dim, idx, val), 1.0)
        })
        .collect();

    for u in &users {
        arena.fold(u);
    }
    arena.drain_grown_bytes(); // first round sizes the ping-pong buffers
    let first = arena.take_partial().unwrap();
    assert!(matches!(first.update_value(), Some(StatValue::Sparse { .. })));

    for round in 0..5 {
        for u in &users {
            arena.fold(u);
        }
        assert_eq!(arena.drain_grown_bytes(), 0, "round {round}: steady state must not grow");
        let p = arena.take_partial().unwrap();
        let v = p.update_value().unwrap();
        assert!(matches!(v, StatValue::Sparse { .. }), "round {round} densified");
        assert_eq!(v.element_count(), 32);
        assert_eq!(p.weight, 4.0);
    }
    assert_eq!(arena.drain_spill_count(), 0, "all-sparse cohort must never spill");
    assert_eq!(arena.drain_sparse_rounds(), 6);
}

/// Dropout × spill threshold: an all-sparse cohort whose *full* union
/// would cross `sparse_spill_frac` (and so densify) stays entirely
/// sparse when the scenario layer's mid-round dropout shrinks the round
/// below the threshold — the arena only ever sees the survivors, so the
/// partial cohort must end with a spill count of zero.
#[test]
fn dropout_shrunk_sparse_cohort_never_spills() {
    use pfl::fl::device::ScenarioSpec;

    let dim = 1024u32;
    let config = ArenaConfig { sparse_spill_frac: 0.25 }; // union > 256 nnz spills
    // 16 users × 32 disjoint nnz: the full cohort's union is 512 nnz
    let users: Vec<Statistics> = (0u32..16)
        .map(|u| {
            let idx: Vec<u32> = (0u32..32).map(|i| u * 64 + i * 2).collect();
            let val: Vec<f32> = (0u32..32).map(|i| (u + i) as f32 * 0.25 - 1.0).collect();
            Statistics::new_update_value(StatValue::sparse(dim, idx, val), 1.0)
        })
        .collect();

    // counterfactual: with nobody dropping out the union crosses the
    // threshold and the arena densifies
    let mut full = StatsArena::with_config(config);
    for u in &users {
        full.fold(u);
    }
    assert!(full.drain_spill_count() > 0, "full 16-user cohort should cross 256 nnz");

    // with the dropout hazard active, fold only the survivors of some
    // round whose surviving union stays strictly below the threshold
    // (≤ 7 users × 32 nnz < 256) — the draws are deterministic in
    // (seed, uid, round), so scan the simulated day for such a round
    let spec = ScenarioSpec { dropout_hazard: 0.5, ..ScenarioSpec::disabled() };
    let seed = 77u64;
    let round = (0..pfl::fl::device::ROUNDS_PER_DAY)
        .find(|&r| (0..16usize).filter(|&u| !spec.drops_out(seed, u, r)).count() <= 7)
        .expect("no round with enough dropouts in a simulated day");

    let mut arena = StatsArena::with_config(config);
    let mut survivors = 0usize;
    for (uid, u) in users.iter().enumerate() {
        if spec.drops_out(seed, uid, round) {
            continue; // the worker loop abandons this user pre-fold
        }
        arena.fold(u);
        survivors += 1;
    }
    assert!(survivors > 0 && survivors <= 7, "round {round}: {survivors} survivors");
    let p = arena.take_partial().unwrap();
    assert!(
        matches!(p.update_value(), Some(StatValue::Sparse { .. })),
        "dropout-shrunk round densified anyway"
    );
    assert_eq!(p.weight, survivors as f64);
    assert_eq!(
        arena.drain_spill_count(),
        0,
        "partial cohort below the spill threshold must not spill"
    );
}

/// The sparse-aware scaled fold (async staleness discount) must equal
/// scaling the contribution first and folding it plainly, over every
/// shape mix.
#[test]
fn accumulate_scaled_matches_scaled_accumulate_randomized() {
    for seed in 0..TRIALS * 2 {
        let mut rng = Rng::seed_from_u64(seed ^ 0x5CA1);
        let dim = 1 + rng.below(48);
        let users: Vec<(Statistics, f32)> = (0..2 + rng.below(8))
            .map(|_| {
                let s = rand_mixed_stats(&mut rng, dim);
                let scale = 1.0 / (1.0 + rng.below(4) as f32); // staleness weights
                (s, scale)
            })
            .collect();
        let agg = SumAggregator;

        let mut fast = None;
        for (u, sc) in &users {
            agg.accumulate_scaled(&mut fast, u.clone(), *sc);
        }
        let fast = fast.unwrap();

        let mut reference = None;
        for (u, sc) in &users {
            let mut scaled = u.clone();
            for v in scaled.vecs.values_mut() {
                v.scale(*sc);
            }
            scaled.weight *= *sc as f64;
            agg.accumulate(&mut reference, scaled);
        }
        let reference = reference.unwrap();

        assert!(
            (fast.weight - reference.weight).abs() < 1e-9,
            "seed {seed}: weight {} vs {}",
            fast.weight,
            reference.weight
        );
        assert_close(
            &dense_of(&fast, "update", dim),
            &dense_of(&reference, "update", dim),
            &format!("seed {seed}"),
        );
    }
}

/// Quantize∘dequantize round-trip error is bounded per coordinate:
/// int8-with-scale by half a code step (max|x|/254), binary16 by half an
/// ULP (~4.9e-4 relative, with an absolute floor for subnormals) — over
/// random dense and sparse shapes of both widths.
#[test]
fn quantized_round_trip_error_bounded_randomized() {
    for seed in 0..TRIALS * 2 {
        let mut rng = Rng::seed_from_u64(seed ^ 0x9A17);
        let dim = 1 + rng.below(64);
        let value = if rng.f64() < 0.5 {
            StatValue::Dense((0..dim).map(|_| rng.normal() as f32).collect())
        } else {
            rand_sparse(&mut rng, dim)
        };
        let orig = value.to_dense_vec();
        let max = orig.iter().fold(0f32, |m, &x| m.max(x.abs()));
        for bits in [8u8, 16] {
            let q = value.quantize(bits);
            assert!(
                matches!(q, StatValue::Quantized { .. }),
                "seed {seed}: quantize({bits}) left {q:?}"
            );
            let back = q.dequantize().to_dense_vec();
            assert_eq!(back.len(), orig.len(), "seed {seed} bits {bits}");
            for (x, y) in orig.iter().zip(&back) {
                let tol = if bits == 8 {
                    max / 254.0 + 1e-6
                } else {
                    (x.abs() * 4.9e-4).max(1e-7)
                };
                assert!(
                    (x - y).abs() <= tol,
                    "seed {seed} bits {bits}: {x} vs {y} (tol {tol})"
                );
            }
        }
    }
}

/// Folding the same quantized contributions in any order decodes to the
/// same sum (exchange law over the quantized wire): forward, permuted
/// and the dense reference of the decoded images all agree.
#[test]
fn quantized_accumulate_commutes_within_tolerance() {
    for seed in 0..TRIALS * 2 {
        let mut rng = Rng::seed_from_u64(seed ^ 0x9C0B);
        let dim = 1 + rng.below(48);
        let users: Vec<Statistics> = (0..3 + rng.below(6))
            .map(|_| {
                let mut s = rand_mixed_stats(&mut rng, dim);
                if rng.f64() < 0.6 {
                    let bits = if rng.f64() < 0.5 { 8 } else { 16 };
                    let v = s.vecs.get_mut("update").unwrap();
                    *v = v.quantize(bits);
                }
                s
            })
            .collect();
        let agg = SumAggregator;

        let mut fwd = None;
        for u in users.clone() {
            agg.accumulate(&mut fwd, u);
        }
        let fwd = fwd.unwrap();

        let mut perm = users.clone();
        let mut r2 = Rng::seed_from_u64(seed);
        for i in (1..perm.len()).rev() {
            perm.swap(i, r2.below(i + 1));
        }
        let mut bwd = None;
        for u in perm {
            agg.accumulate(&mut bwd, u);
        }
        let bwd = bwd.unwrap();

        // reference: the decoded dense image of every contribution,
        // summed coordinatewise — quantization error cancels exactly
        // because both orders fold the *same* codes
        let mut expect = vec![0.0f32; dim];
        let mut w = 0.0f64;
        for u in &users {
            w += u.weight;
            for (e, x) in expect.iter_mut().zip(dense_of(u, "update", dim)) {
                *e += x;
            }
        }
        for (name, got) in [("forward", &fwd), ("permuted", &bwd)] {
            assert_eq!(got.weight, w, "seed {seed} {name}");
            assert_close(
                &dense_of(got, "update", dim),
                &expect,
                &format!("seed {seed} {name}"),
            );
        }
    }
}

/// Error feedback drives the mean round-trip bias to ~0: quantizing the
/// same update for N rounds with carried residuals, the decoded mean
/// converges to the true value at rate step/N — far below the one-round
/// quantization error a feedback-free wire would repeat every round.
#[test]
fn wire_quantizer_error_feedback_unbiased_over_rounds() {
    use pfl::fl::postprocess::{Postprocessor, PpEnv, WireQuantizer};
    use pfl::fl::{CentralContext, LocalParams};
    let ctx = CentralContext::train(0, 4, LocalParams::default(), 1);
    for bits in [8u8, 16] {
        let mut rng = Rng::seed_from_u64(bits as u64 ^ 0xEF);
        let dim = 32;
        let truth: Vec<f32> = (0..dim).map(|_| rng.normal() as f32 * 0.01).collect();
        let max = truth.iter().fold(0f32, |m, &x| m.max(x.abs()));
        let pp = WireQuantizer::new(bits, true);
        let n = 200u32;
        let mut sum = vec![0f64; dim];
        for _ in 0..n {
            let mut s = Statistics::new_update(truth.clone(), 1.0);
            let mut env = PpEnv {
                clip: &RustClip,
                rng: &mut rng,
                user_len: 1,
                uid: 7,
                noise_key: 0,
                noise_threads: 0,
                noise_nanos: 0,
            };
            pp.postprocess_one_user(&mut s, &ctx, &mut env).unwrap();
            let dec = s.update_value().unwrap().to_dense_vec();
            for (a, v) in sum.iter_mut().zip(&dec) {
                *a += *v as f64;
            }
        }
        // the carried residual bounds the *sum* of per-round errors by
        // one quantization step, so the mean bias shrinks as step/N
        let step = if bits == 8 { max * 1.05 / 127.0 } else { max * 1.1e-3 };
        for (j, t) in truth.iter().enumerate() {
            let bias = (sum[j] / n as f64 - *t as f64).abs();
            assert!(
                bias <= step as f64 * 2.0 / n as f64 + 1e-9,
                "bits {bits} coord {j}: mean bias {bias:e} not driven to ~0"
            );
        }
    }
}

/// The parallel binary tree fold reduces random mixed partials to the
/// serial left fold's result (weights exact, values to f32-association
/// tolerance), reports depth ceil(log2 n), and repeats bit-identically.
#[test]
fn tree_reduce_matches_serial_within_tolerance_randomized() {
    use pfl::fl::tree_reduce;
    for seed in 0..TRIALS {
        let mut rng = Rng::seed_from_u64(seed ^ 0x73EE);
        let dim = 1 + rng.below(48);
        let n = 1 + rng.below(9);
        let partials: Vec<Statistics> =
            (0..n).map(|_| rand_mixed_stats(&mut rng, dim)).collect();

        let serial = SumAggregator.worker_reduce(partials.clone()).unwrap();
        let (tree, depth) = tree_reduce(&SumAggregator, partials.clone());
        let tree = tree.unwrap();
        assert_eq!(
            depth,
            partials.len().next_power_of_two().trailing_zeros(),
            "seed {seed}: depth for {n} partials"
        );
        assert_eq!(tree.weight, serial.weight, "seed {seed}");
        assert_close(
            &dense_of(&tree, "update", dim),
            &dense_of(&serial, "update", dim),
            &format!("seed {seed}"),
        );

        // fixed adjacent pairing: repeating the tree fold is bit-identical
        let (tree2, depth2) = tree_reduce(&SumAggregator, partials);
        let tree2 = tree2.unwrap();
        assert_eq!(depth, depth2);
        let bits_of = |s: &Statistics| -> Vec<u32> {
            dense_of(s, "update", dim).iter().map(|x| x.to_bits()).collect()
        };
        assert_eq!(bits_of(&tree), bits_of(&tree2), "seed {seed}: tree fold not deterministic");
    }
}

/// CollectAggregator must preserve sparse contributions individually
/// (shape and values) across accumulate + worker_reduce.
#[test]
fn collect_aggregator_preserves_sparse_contributions() {
    for seed in 0..TRIALS {
        let mut rng = Rng::seed_from_u64(seed ^ 0xC011);
        let dim = 4 + rng.below(32);
        let agg = CollectAggregator;
        let mut partials = Vec::new();
        let mut expected: Vec<Vec<f32>> = Vec::new();
        let mut sparse_count = 0usize;
        for _ in 0..1 + rng.below(3) {
            let mut acc = None;
            for _ in 0..1 + rng.below(4) {
                let s = rand_mixed_stats(&mut rng, dim);
                if matches!(s.update_value(), Some(StatValue::Sparse { .. })) {
                    sparse_count += 1;
                }
                expected.push(dense_of(&s, "update", dim));
                agg.accumulate(&mut acc, s);
            }
            partials.push(acc.unwrap());
        }
        let reduced = agg.worker_reduce(partials).unwrap();
        assert_eq!(reduced.vecs.len(), expected.len(), "seed {seed}");
        // every contribution's dense image must appear among the
        // collected entries exactly as shipped
        let mut collected: Vec<Vec<f32>> = reduced
            .vecs
            .values()
            .map(|v| {
                let mut d = v.to_dense_vec();
                d.resize(dim, 0.0);
                d
            })
            .collect();
        for e in &expected {
            let pos = collected
                .iter()
                .position(|c| c.iter().zip(e).all(|(a, b)| (a - b).abs() < 1e-6));
            let pos = pos.unwrap_or_else(|| panic!("seed {seed}: contribution lost"));
            collected.swap_remove(pos);
        }
        // sparse inputs stay sparse through collection (no silent densify)
        let reduced_sparse = reduced
            .vecs
            .values()
            .filter(|v| matches!(v, StatValue::Sparse { .. }))
            .count();
        assert_eq!(reduced_sparse, sparse_count, "seed {seed}");
    }
}

#[test]
fn collect_aggregator_preserves_every_contribution() {
    for seed in 0..TRIALS {
        let mut rng = Rng::seed_from_u64(seed ^ 0xC0);
        let agg = CollectAggregator;
        let n_workers = 1 + rng.below(4);
        let mut partials = Vec::new();
        let mut total_users = 0usize;
        for _ in 0..n_workers {
            let mut acc = None;
            let users = 1 + rng.below(5);
            total_users += users;
            for _ in 0..users {
                agg.accumulate(&mut acc, Statistics::new_update(vec![rng.normal() as f32], 1.0));
            }
            partials.push(acc.unwrap());
        }
        let reduced = agg.worker_reduce(partials).unwrap();
        assert_eq!(reduced.vecs.len(), total_users, "seed {seed}");
        assert_eq!(reduced.weight, total_users as f64);
    }
}

#[test]
fn scheduler_covers_partitions_and_dominates_uniform() {
    for seed in 0..TRIALS {
        let mut rng = Rng::seed_from_u64(seed ^ 0x5C);
        let n = 5 + rng.below(200);
        let workers = 1 + rng.below(9);
        let weights: Vec<f64> =
            (0..n).map(|_| rng.lognormal(2.0, 1.3).ceil().max(1.0)).collect();

        let uni = schedule(SchedulerKind::Uniform, &weights, workers);
        let greedy = schedule(SchedulerKind::Greedy, &weights, workers);
        let base = schedule(SchedulerKind::GreedyMedianBase, &weights, workers);

        for s in [&uni, &greedy, &base] {
            // exact partition
            let mut seen = vec![false; n];
            for a in &s.assignments {
                for &i in a {
                    assert!(!seen[i]);
                    seen[i] = true;
                }
            }
            assert!(seen.iter().all(|&x| x), "seed {seed}: unassigned user");
        }
        // LPT makespan never exceeds round-robin's
        let makespan = |s: &pfl::fl::Schedule, kindless: bool| -> f64 {
            // recompute raw (base-free) makespan from weights
            let _ = kindless;
            s.assignments
                .iter()
                .map(|a| a.iter().map(|&i| weights[i]).sum::<f64>())
                .fold(0.0f64, f64::max)
        };
        assert!(
            makespan(&greedy, true) <= makespan(&uni, true) + 1e-9,
            "seed {seed}: greedy worse than uniform"
        );
        // determinism
        let again = schedule(SchedulerKind::Greedy, &weights, workers);
        assert_eq!(greedy.assignments, again.assignments);
    }
}

#[test]
fn clip_is_idempotent_and_norm_bounded() {
    for seed in 0..TRIALS {
        let mut rng = Rng::seed_from_u64(seed ^ 0xC11F);
        let dim = 1 + rng.below(4096);
        let mut v: Vec<f32> = (0..dim).map(|_| (rng.normal() * 3.0) as f32).collect();
        let bound = (0.1 + rng.f64() * 5.0) as f32;
        let pre = pfl::util::l2_norm(&v);
        let reported = RustClip.clip(&mut v, bound).unwrap();
        assert!((reported - pre).abs() < 1e-3 * pre.max(1.0));
        let post = pfl::util::l2_norm(&v);
        assert!(post <= bound as f64 * (1.0 + 1e-5), "seed {seed}: {post} > {bound}");
        // idempotence
        let once = v.clone();
        RustClip.clip(&mut v, bound).unwrap();
        for (a, b) in v.iter().zip(&once) {
            assert!((a - b).abs() <= 1e-6 * b.abs().max(1.0));
        }
    }
}

#[test]
fn accountant_monotonicity_randomized() {
    let acc = RdpAccountant;
    for seed in 0..10 {
        let mut rng = Rng::seed_from_u64(seed ^ 0xACC);
        let q = 10f64.powf(-(1.0 + rng.f64() * 3.0)); // 1e-4 .. 1e-1
        let steps = 10 + rng.below(3000) as u64;
        let sigma = 0.5 + rng.f64() * 3.0;
        let p = AccountantParams { sampling_rate: q, delta: 1e-6, steps };

        let e = acc.epsilon(sigma, &p);
        assert!(e.is_finite() && e > 0.0);
        // more noise -> less epsilon
        assert!(acc.epsilon(sigma * 1.5, &p) <= e + 1e-12);
        // more steps -> more epsilon
        let p2 = AccountantParams { steps: steps * 2, ..p };
        assert!(acc.epsilon(sigma, &p2) >= e - 1e-12);
        // more sampling -> more epsilon
        let p3 = AccountantParams { sampling_rate: (q * 2.0).min(1.0), ..p };
        assert!(acc.epsilon(sigma, &p3) >= e - 1e-9);
    }
}

#[test]
fn pld_never_much_looser_than_rdp() {
    // PLD is the tighter accountant; allow 5% slack for discretization.
    let pld = PldAccountant { grid: 5e-4, half_width: 20.0 };
    let rdp = RdpAccountant;
    for (q, steps, sigma) in [(1e-3, 100u64, 1.0), (5e-3, 300, 1.2), (1e-2, 50, 0.8)] {
        let p = AccountantParams { sampling_rate: q, delta: 1e-6, steps };
        let e_pld = pld.epsilon(sigma, &p);
        let e_rdp = rdp.epsilon(sigma, &p);
        assert!(
            e_pld <= e_rdp * 1.05,
            "pld {e_pld} vs rdp {e_rdp} at q={q} T={steps} sigma={sigma}"
        );
    }
}

#[test]
fn replay_respects_rooflines_randomized() {
    for seed in 0..TRIALS {
        let mut rng = Rng::seed_from_u64(seed ^ 0x4EA1);
        let n = 1 + rng.below(100);
        let costs: Vec<UserCost> = (0..n)
            .map(|_| {
                let total = 1000 + rng.below(1_000_000) as u64;
                UserCost {
                    datapoints: 1 + rng.below(100),
                    nanos: total,
                    device_nanos: (total as f64 * rng.f64()) as u64,
                }
            })
            .collect();
        let workers = 1 + rng.below(8);
        let weights: Vec<f64> = costs.iter().map(|c| c.datapoints as f64).collect();
        let sched = schedule(SchedulerKind::Greedy, &weights, workers);
        let (round, busy) = replay_round(&costs, &sched.assignments, 0);
        // round is the max worker
        assert_eq!(round, busy.iter().copied().max().unwrap_or(0));
        // total busy conserved
        let total: u64 = costs.iter().map(|c| c.nanos).sum();
        assert_eq!(busy.iter().sum::<u64>(), total);

        // cluster replay floors: >= device serial time per device and
        // >= the largest single worker queue
        let queues: Vec<Vec<UserCost>> = sched
            .assignments
            .iter()
            .map(|a| a.iter().map(|&i| costs[i]).collect())
            .collect();
        let (cround, dev_busy) = replay_cluster(&queues, 1, workers, 0);
        let device_total: u64 = costs.iter().map(|c| c.device_nanos).sum();
        assert_eq!(dev_busy[0], device_total);
        assert!(cround >= device_total);
        // sharing a device can't be faster than the device-serial floor,
        // and can't be slower than fully serial execution
        assert!(cround <= total);
    }
}

#[test]
fn metrics_merge_commutes_randomized() {
    for seed in 0..TRIALS {
        let mut rng = Rng::seed_from_u64(seed ^ 0x3E7);
        let parts: Vec<Metrics> = (0..4 + rng.below(6))
            .map(|_| {
                let mut m = Metrics::new();
                m.add_central("a", rng.normal(), rng.f64() + 0.1);
                m.add_per_user("b", rng.normal());
                m
            })
            .collect();
        let mut fwd = Metrics::new();
        for p in &parts {
            fwd.merge(p);
        }
        let mut perm = parts.clone();
        // deterministic shuffle
        let mut r2 = Rng::seed_from_u64(seed);
        for i in (1..perm.len()).rev() {
            perm.swap(i, r2.below(i + 1));
        }
        let mut bwd = Metrics::new();
        for p in &perm {
            bwd.merge(p);
        }
        for k in ["a", "b"] {
            assert!(
                (fwd.get(k).unwrap() - bwd.get(k).unwrap()).abs() < 1e-10,
                "seed {seed} metric {k}"
            );
        }
    }
}

#[test]
fn median_base_never_hurts_straggler_gap_much() {
    // Table 5's qualitative ordering on random heavy-tailed cohorts:
    // greedy(+median) beats uniform on the predicted straggler gap in
    // aggregate.
    let mut uni_total = 0.0;
    let mut base_total = 0.0;
    for seed in 0..TRIALS {
        let mut rng = Rng::seed_from_u64(seed ^ 0x7AB);
        let n = 50 + rng.below(150);
        let weights: Vec<f64> =
            (0..n).map(|_| rng.lognormal(2.5, 1.2).ceil().max(1.0)).collect();
        let workers = 2 + rng.below(7);
        uni_total += schedule(SchedulerKind::Uniform, &weights, workers).predicted_straggler_gap();
        base_total += schedule(
            SchedulerKind::GreedyBase { base: median(&weights) },
            &weights,
            workers,
        )
        .predicted_straggler_gap();
    }
    assert!(
        base_total < uni_total * 0.6,
        "greedy+median {base_total} vs uniform {uni_total}"
    );
}

// ----------------------------------------------------------------------
// Out-of-core store: materialize-then-read is bit-identical (ISSUE 5)
// ----------------------------------------------------------------------

/// Bit-level fingerprint of a `UserData` record (f32 payloads compared
/// through `to_bits`, so "close" is not enough — identical or fail).
fn data_bits(d: &pfl::data::UserData) -> Vec<u64> {
    d.bit_fingerprint()
}

#[test]
fn store_roundtrip_bit_identical_across_partition_schemes() {
    // Acceptance property of the out-of-core store: for every partition
    // scheme the generators implement (IID fixed-size, Dirichlet
    // label-skew, natural heavy-tailed keys, covariate-shifted tabular,
    // per-user mixtures), materializing to disk and reading back through
    // `ShardedStore` reproduces the generator's output *bit for bit* —
    // users, scheduling lengths, and central-eval shards alike — for
    // every cell of the {none, shuffle-lz} × {mmap, pread} matrix.
    use pfl::data::{
        materialize_with, Compression, FederatedDataset, OpenOptions, ShardedStore, SynthCifar,
        SynthFlair, SynthGmmPoints, SynthTabular, SynthText,
    };
    let root = std::env::temp_dir()
        .join(format!("pfl_prop_store_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    let datasets: Vec<(&str, Box<dyn FederatedDataset>)> = vec![
        ("cifar-iid", Box::new(SynthCifar::new(9, 6, None, 11))),
        ("cifar-dirichlet", Box::new(SynthCifar::new(9, 6, Some(0.1), 12))),
        ("flair-natural", Box::new(SynthFlair::new(9, Some(0.3), 13))),
        ("text-natural", Box::new(SynthText::new(9, 14))),
        ("tabular-shifted", Box::new(SynthTabular::new(9, 10, 4, 15))),
        ("gmm-mixture", Box::new(SynthGmmPoints::new(9, 12, 3, 2, 16))),
    ];
    for (tag, gen) in &datasets {
        for comp in [Compression::None, Compression::ShuffleLz] {
            let cell = format!("{tag}/{comp}");
            let dir = root.join(&cell);
            // users_per_shard 4 forces the multi-shard path for 9 users
            let stats = materialize_with(gen.as_ref(), &dir, 4, 32, comp)
                .unwrap_or_else(|e| panic!("{cell}: {e:#}"));
            assert_eq!(stats.compression, comp, "{cell}");
            for mmap in [true, false] {
                let cell = format!("{cell}/mmap={mmap}");
                let store = ShardedStore::open_with(&dir, OpenOptions { mmap })
                    .unwrap_or_else(|e| panic!("{cell}: {e:#}"));
                assert_eq!(store.num_users(), gen.num_users(), "{cell}");
                assert_eq!(store.name(), gen.name(), "{cell}");
                assert_eq!(store.compression(), comp, "{cell}");
                for uid in 0..gen.num_users() {
                    let (a, b) = (gen.user_data(uid), store.user_data(uid));
                    assert_eq!(
                        data_bits(&a),
                        data_bits(&b),
                        "{cell}: user {uid} not bit-identical"
                    );
                    assert_eq!(
                        store.user_len(uid),
                        a.len(),
                        "{cell}: user {uid} indexed length"
                    );
                }
                let (ea, eb) = (gen.central_eval(32), store.central_eval(32));
                assert_eq!(ea.len(), eb.len(), "{cell}: eval shard count");
                for (i, (a, b)) in ea.iter().zip(&eb).enumerate() {
                    assert_eq!(
                        data_bits(a),
                        data_bits(b),
                        "{cell}: eval shard {i} not bit-identical"
                    );
                }
            }
        }
    }
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn store_v1_fixture_reads_bit_identically() {
    // Back-compat: a checked-in V1 store (raw blobs, absolute offsets,
    // no compression fields in the index — written by the previous
    // release's format) opens and reads the exact bytes it was packed
    // with, through both the mmap and pread paths.
    use pfl::data::{FederatedDataset, OpenOptions, ShardedStore, UserData};
    let dir = std::path::Path::new(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/rust/tests/fixtures/store_v1"
    ));
    for mmap in [true, false] {
        let store = ShardedStore::open_with(dir, OpenOptions { mmap })
            .unwrap_or_else(|e| panic!("mmap={mmap}: {e:#}"));
        assert_eq!(store.version(), 1, "mmap={mmap}");
        assert_eq!(store.compression(), pfl::data::Compression::None);
        assert_eq!(store.name(), "fixture-v1");
        assert_eq!(store.num_users(), 3);
        for uid in 0..3 {
            // the fixture packs user u as Points{dim: 2, x: [u*10 + j
            // + 0.25; j in 0..4]} — exactly representable f32s, so
            // equality is bit-exact
            let want: Vec<f32> = (0..4).map(|j| (uid * 10 + j) as f32 + 0.25).collect();
            match store.user_data(uid) {
                UserData::Points { x, dim } => {
                    assert_eq!(dim, 2, "mmap={mmap} user {uid}");
                    assert_eq!(x, want, "mmap={mmap} user {uid}");
                }
                other => panic!("mmap={mmap} user {uid}: wrong variant {other:?}"),
            }
            assert_eq!(store.user_len(uid), 2, "mmap={mmap} user {uid}");
        }
        let eval = store.central_eval(32);
        assert_eq!(eval.len(), 1, "mmap={mmap}");
        match &eval[0] {
            UserData::Points { x, dim } => {
                assert_eq!(*dim, 2);
                assert_eq!(x, &[100.25f32, 101.25, 102.25, 103.25]);
            }
            other => panic!("mmap={mmap} eval: wrong variant {other:?}"),
        }
    }
}
