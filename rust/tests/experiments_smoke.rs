//! Smoke tests for the experiment harness: every table/figure path runs
//! end-to-end at miniature scale against the real PJRT artifacts.
//! (Skipped when `make artifacts` has not run.)

use pfl::baselines::EngineVariant;
use pfl::experiments::{self, EvalMode};

fn artifacts_available() -> bool {
    let dir = std::env::var("PFL_ARTIFACTS")
        .unwrap_or_else(|_| format!("{}/artifacts", env!("CARGO_MANIFEST_DIR")));
    let ok = std::path::Path::new(&dir).join("manifest.json").exists();
    if !ok {
        eprintln!("skipping: artifacts not built");
    }
    // make sure child code finds them regardless of cwd
    std::env::set_var("PFL_ARTIFACTS", dir);
    ok
}

fn tiny_cifar() -> pfl::config::Config {
    let mut cfg = experiments::speed_cifar_config(1.0);
    cfg.iterations = 3;
    cfg.cohort_size = 3;
    cfg.dataset.num_users = 20;
    cfg
}

#[test]
fn speed_engines_pfl_vs_flower_shape() {
    if !artifacts_available() {
        return;
    }
    let cfg = tiny_cifar();
    let pfl_row = experiments::speed::run_engine(&cfg, EngineVariant::PflStyle, 2).unwrap();
    let flower_row = experiments::speed::run_engine(&cfg, EngineVariant::FlowerLike, 2).unwrap();
    // Table 1's shape on the A100-normalized column (deterministic): the
    // baseline engine pays its paper-calibrated overheads
    assert!(
        flower_row.a100_p1_secs > pfl_row.a100_p1_secs * 4.0,
        "flower norm {:.2}s should far exceed pfl norm {:.2}s",
        flower_row.a100_p1_secs,
        pfl_row.a100_p1_secs
    );
    // and pays them in real time too (spin taxes: >= 9 users * 61 ms)
    assert!(
        flower_row.p1_wall_secs > pfl_row.p1_wall_secs * 0.5,
        "flower {:.2}s vs pfl {:.2}s",
        flower_row.p1_wall_secs,
        pfl_row.p1_wall_secs
    );
    // consistency check: both learn (accuracy defined and close)
    let (a, b) = (pfl_row.accuracy.unwrap(), flower_row.accuracy.unwrap());
    assert!((a - b).abs() < 0.25, "accuracy diverged: {a} vs {b}");
    // per-user costs were recorded for the replay paths
    assert!(!pfl_row.summary.outcome.user_costs.is_empty());
}

#[test]
fn virtual_cluster_replay_is_monotone_on_real_costs() {
    if !artifacts_available() {
        return;
    }
    let cfg = tiny_cifar();
    let summary =
        experiments::run_benchmark(&cfg, EngineVariant::PflStyle.profile(), EvalMode::None, 0)
            .unwrap();
    let costs = &summary.outcome.user_costs;
    assert!(costs.len() >= 9, "{} costs", costs.len());
    // single round replay across p
    let rounds = vec![costs.clone()];
    let (p1, _) = experiments::scaling::replay(&rounds, 1, 1);
    let (p4, _) = experiments::scaling::replay(&rounds, 1, 4);
    assert!(p4 <= p1 + 1e-9, "replay not monotone: {p4} vs {p1}");
    // device floor respected
    let dev: u64 = costs.iter().map(|c| c.device_nanos).sum();
    assert!(p4 >= dev as f64 / 1e9 * 0.99);
}

#[test]
fn quality_cell_runs_and_reports_headline() {
    if !artifacts_available() {
        return;
    }
    // one tiny table-3 cell: cifar10-iid + fedavg
    let (mean, std) = experiments::quality::run_cell("cifar10-iid", "fedavg", None, 0.004, 1, 1)
        .unwrap();
    assert!(mean.is_finite() && mean >= 0.0 && mean <= 1.0, "accuracy {mean}");
    assert!(std >= 0.0);
}

#[test]
fn dp_cell_applies_noise_and_learns_something() {
    if !artifacts_available() {
        return;
    }
    let (mean, _) =
        experiments::quality::run_cell("cifar10-iid", "fedavg", Some("gaussian"), 0.004, 1, 1)
            .unwrap();
    assert!(mean.is_finite());
}

#[test]
fn nonnn_models_converge() {
    // pure Rust; no artifacts needed
    experiments::quality::nonnn(0.4).unwrap();
}

#[test]
fn cost_model_correlation_is_strong_on_flair() {
    if !artifacts_available() {
        return;
    }
    let mut cfg = experiments::speed_flair_config(1.0);
    cfg.iterations = 3;
    cfg.cohort_size = 8;
    let summary =
        experiments::run_benchmark(&cfg, EngineVariant::PflStyle.profile(), EvalMode::None, 0)
            .unwrap();
    let corr = experiments::cost_correlation(&summary.outcome.user_costs);
    // Fig. 4a: dataset size predicts wall-clock
    assert!(corr > 0.5, "correlation too weak: {corr}");
}
