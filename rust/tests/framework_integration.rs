//! Integration tests over the full simulation framework (no PJRT): the
//! generalized loop (Alg. 1), every algorithm on a shared linear task,
//! DP postprocessor composition, engine-variant numeric consistency,
//! checkpoint/resume fault tolerance, and callback control flow.

use std::sync::Arc;

use pfl::baselines::{EngineVariant, OverheadProfile};
use pfl::config::{preset, Config};
use pfl::data::{FederatedDataset, SynthTabular};
use pfl::fl::algorithm::RunSpec;
use pfl::fl::backend::{BackendBuilder, RunParams};
use pfl::fl::callbacks::{load_checkpoint, Callback, CheckpointCallback, EarlyStopping};
use pfl::fl::central_opt::{Adam, Sgd};
use pfl::fl::context::LocalParams;
use pfl::fl::postprocess::{NormClip, WeightByDatapoints};
use pfl::fl::{
    AdaFedProx, FedAvg, FedProx, FederatedAlgorithm, LinearModel, Model, Scaffold,
    SchedulerKind,
};
use pfl::privacy::{BandedMatrixFactorization, GaussianMechanism};

const DIM: usize = 4;

fn dataset(users: usize, seed: u64) -> Arc<dyn FederatedDataset> {
    Arc::new(SynthTabular::new(users, 32, DIM, seed))
}

fn spec(iters: u64, users: usize) -> RunSpec {
    RunSpec {
        iterations: iters,
        cohort_size: 8,
        val_cohort_size: 4,
        eval_every: 3,
        local: LocalParams { epochs: 2, batch_size: 8, lr: 0.05, mu: 0.0, max_steps: 0 },
        central_lr: 1.0,
        central_lr_warmup: 0,
        population: users,
        seed: 3,
        ..Default::default()
    }
}

fn backend_for(
    alg: Arc<dyn FederatedAlgorithm>,
    users: usize,
    workers: usize,
    profile: OverheadProfile,
    scheduler: SchedulerKind,
    pps: Vec<Box<dyn pfl::fl::postprocess::Postprocessor>>,
) -> pfl::fl::SimulatedBackend {
    let mut b = BackendBuilder::new(
        dataset(users, 42),
        alg,
        Arc::new(|_| Ok(Box::new(LinearModel::new(DIM)) as Box<dyn Model>)),
    )
    .params(RunParams { num_workers: workers, scheduler, profile, seed: 7, ..Default::default() });
    for pp in pps {
        b = b.postprocessor(pp);
    }
    b.build().unwrap()
}

fn final_loss(out: &pfl::fl::RunOutcome) -> f64 {
    out.series("train/loss").last().unwrap().1
}

#[test]
fn every_algorithm_learns_the_linear_task() {
    let users = 32;
    for (name, alg) in [
        (
            "fedavg",
            Arc::new(FedAvg::new(spec(25, users), Box::new(Sgd))) as Arc<dyn FederatedAlgorithm>,
        ),
        ("fedprox", Arc::new(FedProx::new(spec(25, users), 0.1, Box::new(Sgd)))),
        ("adafedprox", Arc::new(AdaFedProx::new(spec(25, users), Box::new(Sgd)))),
        ("scaffold", Arc::new(Scaffold::new(spec(25, users), Box::new(Sgd)))),
    ] {
        let mut backend =
            backend_for(alg, users, 2, OverheadProfile::default(), SchedulerKind::Greedy, vec![]);
        let out = backend
            .run(vec![0.0; LinearModel::param_len(DIM)], &mut [])
            .unwrap();
        let series = out.series("train/loss");
        let (first, last) = (series[0].1, series.last().unwrap().1);
        assert!(
            last < first * 0.5,
            "{name}: loss {first:.4} -> {last:.4} did not halve"
        );
        // federated eval ran too
        assert!(out.final_metric("val/loss").is_some(), "{name}: no val metrics");
    }
}

#[test]
fn fedadam_also_converges() {
    let users = 32;
    let alg = Arc::new(FedAvg::new(
        RunSpec { central_lr: 0.05, ..spec(30, users) },
        Box::new(Adam::paper(0.1)),
    ));
    let mut backend =
        backend_for(alg, users, 1, OverheadProfile::default(), SchedulerKind::Greedy, vec![]);
    let out = backend.run(vec![0.0; DIM + 1], &mut []).unwrap();
    assert!(final_loss(&out) < out.series("train/loss")[0].1 * 0.6);
}

#[test]
fn engine_variants_agree_on_the_learned_model() {
    // Same seeds, same cohorts: every overhead profile must produce the
    // same final model (overheads shift time, never statistics) — the
    // accuracy-consistency column of paper Table 1.
    let users = 24;
    let run = |variant: EngineVariant| {
        let alg = Arc::new(FedAvg::new(spec(6, users), Box::new(Sgd)));
        let mut backend = backend_for(
            alg,
            users,
            2,
            variant.profile(),
            variant.scheduler(),
            vec![],
        );
        backend.run(vec![0.0; DIM + 1], &mut []).unwrap().central
    };
    let reference = run(EngineVariant::PflStyle);
    for v in [EngineVariant::FlowerLike, EngineVariant::TffLike, EngineVariant::FedScaleLike] {
        let other = run(v);
        for (a, b) in reference.iter().zip(&other) {
            assert!((a - b).abs() < 1e-4, "{v:?} diverged: {a} vs {b}");
        }
    }
}

#[test]
fn dp_pipeline_composes_with_weighting_and_clipping() {
    let users = 24;
    let alg = Arc::new(FedAvg::new(spec(10, users), Box::new(Sgd)));
    let pps: Vec<Box<dyn pfl::fl::postprocess::Postprocessor>> = vec![
        Box::new(WeightByDatapoints { cap: 64.0 }),
        Box::new(NormClip { bound: 5.0 }),
        Box::new(GaussianMechanism::new(1.0, 0.05, 1.0)),
    ];
    let mut backend =
        backend_for(alg, users, 2, OverheadProfile::default(), SchedulerKind::Greedy, pps);
    let out = backend.run(vec![0.0; DIM + 1], &mut []).unwrap();
    // clip + noise metrics must have been reported
    assert!(out.final_metric("dp/pre-clip-norm").is_some());
    assert!(out.final_metric("dp/snr").is_some());
    assert!(out.final_metric("clip/pre-norm").is_some());
    // learning still happens under mild noise
    let series = out.series("train/loss");
    assert!(series.last().unwrap().1 < series[0].1);
}

#[test]
fn bmf_min_separation_is_enforced_by_the_backend() {
    let users = 6; // tiny population so the filter bites
    let alg = Arc::new(FedAvg::new(
        RunSpec { cohort_size: 4, val_cohort_size: 0, ..spec(8, users) },
        Box::new(Sgd),
    ));
    let mut bmf = BandedMatrixFactorization::new(1.0, 0.0, 1.0, 4);
    bmf.min_sep = 3;
    let mut backend = backend_for(
        alg,
        users,
        1,
        OverheadProfile::default(),
        SchedulerKind::Greedy,
        vec![Box::new(bmf)],
    );
    let out = backend.run(vec![0.0; DIM + 1], &mut []).unwrap();
    // after round 0 trains ~4 of 6 users, rounds 1-2 can only draw from
    // the remaining pool -> cohorts shrink below the nominal size
    let cohorts = out.series("sys/cohort");
    assert!(cohorts.iter().skip(1).take(2).any(|(_, c)| *c < 4.0), "{cohorts:?}");
}

#[test]
fn checkpoint_resume_reproduces_uninterrupted_run() {
    let users = 16;
    let path = std::env::temp_dir().join(format!("pfl_it_ckpt_{}", std::process::id()));

    // uninterrupted run: 10 rounds
    let alg = Arc::new(FedAvg::new(spec(10, users), Box::new(Sgd)));
    let mut backend =
        backend_for(alg, users, 1, OverheadProfile::default(), SchedulerKind::Greedy, vec![]);
    let full = backend.run(vec![0.0; DIM + 1], &mut []).unwrap();

    // interrupted at 5 rounds (checkpointing every round)...
    let alg = Arc::new(FedAvg::new(spec(5, users), Box::new(Sgd)));
    let mut backend =
        backend_for(alg, users, 1, OverheadProfile::default(), SchedulerKind::Greedy, vec![]);
    let mut cbs: Vec<Box<dyn Callback>> = vec![Box::new(CheckpointCallback::new(&path, 1))];
    backend.run(vec![0.0; DIM + 1], &mut cbs).unwrap();

    // ...resumed from the checkpoint for the remaining rounds.
    let (params, next_t) = load_checkpoint(&path).unwrap();
    assert_eq!(next_t, 5);
    let alg = Arc::new(ResumeAt {
        inner: FedAvg::new(spec(10, users), Box::new(Sgd)),
        from: next_t,
    });
    let mut backend =
        backend_for(alg, users, 1, OverheadProfile::default(), SchedulerKind::Greedy, vec![]);
    let resumed = backend.run(params, &mut []).unwrap();

    for (a, b) in full.central.iter().zip(&resumed.central) {
        assert!((a - b).abs() < 1e-5, "resume diverged: {a} vs {b}");
    }
    std::fs::remove_file(&path).ok();
}

/// Wraps an algorithm to start its iteration counter at `from` (resume).
struct ResumeAt {
    inner: FedAvg,
    from: u64,
}

impl FederatedAlgorithm for ResumeAt {
    fn name(&self) -> &'static str {
        "resume"
    }
    fn next_contexts(&self, t: u64) -> Vec<pfl::fl::CentralContext> {
        self.inner.next_contexts(t + self.from)
    }
    fn simulate_one_user(
        &self,
        model: &mut dyn Model,
        uid: usize,
        data: &pfl::data::UserData,
        ctx: &pfl::fl::CentralContext,
    ) -> anyhow::Result<(Option<pfl::fl::Statistics>, pfl::fl::Metrics)> {
        self.inner.simulate_one_user(model, uid, data, ctx)
    }
    fn process_aggregated(
        &self,
        central: &mut [f32],
        ctx: &pfl::fl::CentralContext,
        aggregate: pfl::fl::Statistics,
        metrics: &mut pfl::fl::Metrics,
    ) -> anyhow::Result<()> {
        self.inner.process_aggregated(central, ctx, aggregate, metrics)
    }
}

#[test]
fn early_stopping_halts_training() {
    let users = 16;
    let alg = Arc::new(FedAvg::new(spec(50, users), Box::new(Sgd)));
    let mut backend =
        backend_for(alg, users, 1, OverheadProfile::default(), SchedulerKind::Greedy, vec![]);
    let mut cbs: Vec<Box<dyn Callback>> =
        vec![Box::new(EarlyStopping::new("train/loss", false, 2))];
    let out = backend.run(vec![0.0; DIM + 1], &mut cbs).unwrap();
    assert!(out.rounds < 50, "early stopping never fired ({} rounds)", out.rounds);
}

#[test]
fn config_json_file_roundtrip_through_launcher_types() {
    // `pfl run --config file.json` path: serialize a preset, parse it back.
    let cfg = preset("stackoverflow-dp").unwrap();
    let path = std::env::temp_dir().join(format!("pfl_cfg_{}.json", std::process::id()));
    std::fs::write(&path, cfg.to_json()).unwrap();
    let parsed = Config::from_json(&std::fs::read_to_string(&path).unwrap()).unwrap();
    assert_eq!(cfg, parsed);
    std::fs::remove_file(&path).ok();
}

#[test]
fn weighted_vs_equal_aggregation_differ() {
    let users = 16;
    let run = |weighted: bool| {
        let alg = Arc::new(FedAvg::new(spec(5, users), Box::new(Sgd)));
        let pps: Vec<Box<dyn pfl::fl::postprocess::Postprocessor>> = if weighted {
            vec![Box::new(WeightByDatapoints { cap: 0.0 })]
        } else {
            vec![]
        };
        let mut backend =
            backend_for(alg, users, 1, OverheadProfile::default(), SchedulerKind::Greedy, pps);
        backend.run(vec![0.0; DIM + 1], &mut []).unwrap().central
    };
    let eq = run(false);
    let wt = run(true);
    // SynthTabular has varying user sizes, so the two weightings differ
    assert!(
        eq.iter().zip(&wt).any(|(a, b)| (a - b).abs() > 1e-7),
        "weighting had no effect"
    );
}
