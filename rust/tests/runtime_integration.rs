//! Integration: load real AOT artifacts through PJRT and check numerics.
//!
//! Requires `make artifacts` to have run (skipped otherwise, like the
//! python-side artifact tests).

use pfl::runtime::{Arg, Manifest, Runtime};
use pfl::util::rng::Rng;

fn runtime_or_skip() -> Option<Runtime> {
    let dir = std::env::var("PFL_ARTIFACTS").unwrap_or_else(|_| {
        format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"))
    });
    if !std::path::Path::new(&dir).join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(Runtime::new(Manifest::load(dir).unwrap()).unwrap())
}

#[test]
fn clip_artifact_matches_rust_norm() {
    let Some(rt) = runtime_or_skip() else { return };
    let model = rt.manifest.model("mlp_flair").unwrap().clone();
    let clip_key = model.artifacts.get("clip").unwrap().clone();
    let clip = rt.get(&clip_key).unwrap();

    let mut rng = Rng::seed_from_u64(0);
    let v: Vec<f32> = (0..model.param_count)
        .map(|_| rng.normal_scaled(0.0, 0.01) as f32)
        .collect();
    let expected_norm = pfl::util::l2_norm(&v);

    // bound below the norm -> scaled down to the bound
    let bound = (expected_norm / 2.0) as f32;
    let out = clip
        .execute(&[Arg::F32(&v), Arg::ScalarF32(bound)])
        .unwrap();
    let clipped = out[0].as_f32();
    let norm = out[1].scalar_f32() as f64;
    assert!(
        (norm - expected_norm).abs() / expected_norm < 1e-4,
        "pallas norm {norm} vs rust {expected_norm}"
    );
    let clipped_norm = pfl::util::l2_norm(clipped);
    assert!(
        (clipped_norm - bound as f64).abs() / (bound as f64) < 1e-4,
        "clipped to {clipped_norm}, wanted {bound}"
    );

    // bound above the norm -> unchanged
    let out = clip
        .execute(&[Arg::F32(&v), Arg::ScalarF32((expected_norm * 2.0) as f32)])
        .unwrap();
    let same = out[0].as_f32();
    let max_diff = v
        .iter()
        .zip(same)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff < 1e-6, "max diff {max_diff}");
}

#[test]
fn train_step_reduces_loss_and_eval_agrees() {
    let Some(rt) = runtime_or_skip() else { return };
    let model = rt.manifest.model("mlp_flair").unwrap().clone();
    let train = rt.get(model.artifacts.get("train").unwrap()).unwrap();
    let eval = rt.get(model.artifacts.get("eval").unwrap()).unwrap();

    let mut params = model.init_params(3);
    let zeros = vec![0f32; model.param_count];
    let mut rng = Rng::seed_from_u64(1);

    // synthetic batch: features + sparse multi-hot labels correlated with x
    let b = model.train_batch;
    let feat = 192;
    let labels = 17;
    let x: Vec<f32> = (0..b * feat).map(|_| rng.normal() as f32).collect();
    let mut y = vec![0f32; b * labels];
    for i in 0..b {
        for l in 0..labels {
            if x[i * feat + l] > 0.5 {
                y[i * labels + l] = 1.0;
            }
        }
    }
    let w = vec![1f32; b];

    let mut losses = Vec::new();
    for _ in 0..30 {
        let out = train
            .execute(&[
                Arg::F32(&params),
                Arg::F32(&zeros),
                Arg::F32(&zeros),
                Arg::F32(&x),
                Arg::F32(&y),
                Arg::F32(&w),
                Arg::ScalarF32(0.5),
                Arg::ScalarF32(0.0),
            ])
            .unwrap();
        let loss_sum = out[1].scalar_f32();
        let wsum = out[3].scalar_f32();
        losses.push(loss_sum / wsum);
        params = out[0].clone().into_f32();
    }
    assert!(
        losses.last().unwrap() < &(losses[0] * 0.9),
        "loss did not decrease: {losses:?}"
    );

    // eval on a batch built from the same generator runs and returns
    // finite loss + scores with the right shape
    let eb = model.eval_batch;
    let ex: Vec<f32> = (0..eb * feat).map(|_| rng.normal() as f32).collect();
    let ey = vec![0f32; eb * labels];
    let ew = vec![1f32; eb];
    let out = eval
        .execute(&[Arg::F32(&params), Arg::F32(&ex), Arg::F32(&ey), Arg::F32(&ew)])
        .unwrap();
    assert!(out[0].scalar_f32().is_finite());
    assert_eq!(out[3].as_f32().len(), eb * labels);
}

#[test]
fn exec_stats_accumulate() {
    let Some(rt) = runtime_or_skip() else { return };
    let model = rt.manifest.model("mlp_flair").unwrap().clone();
    let clip = rt.get(model.artifacts.get("clip").unwrap()).unwrap();
    let v = vec![0.5f32; model.param_count];
    clip.execute(&[Arg::F32(&v), Arg::ScalarF32(1.0)]).unwrap();
    clip.execute(&[Arg::F32(&v), Arg::ScalarF32(1.0)]).unwrap();
    let s = clip.stats();
    assert_eq!(s.calls, 2);
    assert!(s.exec_nanos > 0);
    assert!(s.bytes_in > 0);
    let total = rt.total_stats();
    assert!(total.calls >= 2);
}

#[test]
fn shape_and_dtype_mismatches_are_errors() {
    let Some(rt) = runtime_or_skip() else { return };
    let model = rt.manifest.model("mlp_flair").unwrap().clone();
    let clip = rt.get(model.artifacts.get("clip").unwrap()).unwrap();
    let v = vec![0.5f32; 3]; // wrong length
    assert!(clip.execute(&[Arg::F32(&v), Arg::ScalarF32(1.0)]).is_err());
    let ok = vec![0.5f32; model.param_count];
    let bad_ints = vec![1i32; model.param_count];
    assert!(clip
        .execute(&[Arg::I32(&bad_ints), Arg::ScalarF32(1.0)])
        .is_err());
    // wrong arity
    assert!(clip.execute(&[Arg::F32(&ok)]).is_err());
}

// ---------------------------------------------------------------------
// HloModel-level tests: the Model adapter over the artifacts.
// ---------------------------------------------------------------------

use pfl::data::{FederatedDataset, UserData};
use pfl::fl::context::LocalParams;
use pfl::fl::model::{ClipKernel, HloModel, RustClip, ScoreSink};
use pfl::fl::Model;

fn hlo_model(name: &str) -> Option<HloModel> {
    let rt = runtime_or_skip()?;
    Some(HloModel::new(&rt, name, 5).unwrap())
}

fn dataset_for(model: &str) -> Box<dyn FederatedDataset> {
    match model {
        "cnn_c10" => Box::new(pfl::data::SynthCifar::new(10, 30, None, 3)),
        "mlp_flair" => Box::new(pfl::data::SynthFlair::new(10, None, 3)),
        "lm_so" => Box::new(pfl::data::SynthText::new(10, 3)),
        "lora_llm" => Box::new(pfl::data::SynthInstruct::new(
            pfl::data::InstructFlavor::Alpaca,
            300,
            3,
        )),
        other => panic!("unknown model {other}"),
    }
}

#[test]
fn hlo_models_train_locally_and_apply() {
    for name in ["cnn_c10", "mlp_flair", "lm_so", "lora_llm"] {
        let Some(mut model) = hlo_model(name) else { return };
        let data = dataset_for(name).user_data(0);
        let p = LocalParams { epochs: 2, batch_size: 8, lr: 0.1, mu: 0.0, max_steps: 0 };
        let central0 = model.central().to_vec();
        let before = model.evaluate(&data, None).unwrap().get("loss").unwrap();
        let out = model.train_local(&data, &p, None, 1).unwrap();
        assert_eq!(out.update.len(), model.param_count(), "{name}");
        assert!(out.steps > 0 && out.wsum > 0.0, "{name}");
        assert!(pfl::util::l2_norm(&out.update) > 0.0, "{name}: zero update");
        // central untouched by local training
        assert_eq!(model.central(), &central0[..], "{name}: central mutated");
        // apply the delta (FedAvg, central lr 1) and re-evaluate
        let new: Vec<f32> = central0.iter().zip(&out.update).map(|(c, d)| c - d).collect();
        model.set_central(&new);
        let after = model.evaluate(&data, None).unwrap().get("loss").unwrap();
        assert!(
            after < before,
            "{name}: local training did not improve local loss ({before} -> {after})"
        );
    }
}

#[test]
fn hlo_clip_kernel_matches_rust_oracle() {
    let Some(model) = hlo_model("cnn_c10") else { return };
    let kernel = model.clip_kernel().unwrap();
    let mut rng = Rng::seed_from_u64(2);
    let mut v: Vec<f32> = (0..model.param_count()).map(|_| rng.normal() as f32 * 0.01).collect();
    let mut v2 = v.clone();
    let n1 = kernel.clip(&mut v, 0.5).unwrap();
    let n2 = RustClip.clip(&mut v2, 0.5).unwrap();
    assert!((n1 - n2).abs() / n2 < 1e-4, "norms {n1} vs {n2}");
    let max_diff = v.iter().zip(&v2).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
    assert!(max_diff < 1e-5, "clipped vectors diverge by {max_diff}");
}

#[test]
fn flair_eval_collects_scores_for_map() {
    let Some(mut model) = hlo_model("mlp_flair") else { return };
    let ds = dataset_for("mlp_flair");
    let shards = ds.central_eval(128);
    let mut sink = ScoreSink::default();
    let mut total = 0usize;
    for shard in shards.iter().take(2) {
        model.evaluate(shard, Some(&mut sink)).unwrap();
        total += shard.len();
    }
    assert_eq!(sink.labels, 17);
    assert_eq!(sink.scores.len(), total * 17);
    assert_eq!(sink.targets.len(), total * 17);
    let map = pfl::fl::metrics::mean_average_precision(&sink.scores, &sink.targets, 17);
    assert!(map > 0.0 && map <= 1.0, "mAP {map}");
}

#[test]
fn lora_trains_adapters_only() {
    let Some(mut model) = hlo_model("lora_llm") else { return };
    // adapter vector is tiny relative to the frozen base
    assert!(model.param_count() < 20_000, "{}", model.param_count());
    let data = dataset_for("lora_llm").user_data(1);
    let p = LocalParams { epochs: 1, batch_size: 4, lr: 0.1, mu: 0.0, max_steps: 2 };
    let out = model.train_local(&data, &p, None, 0).unwrap();
    assert_eq!(out.update.len(), model.param_count());
    assert_eq!(out.steps, 2);
}

#[test]
fn empty_user_data_is_a_noop() {
    let Some(mut model) = hlo_model("cnn_c10") else { return };
    let empty = UserData::Image { x: vec![], y: vec![], hwc: 3072 };
    let out = model
        .train_local(&empty, &LocalParams::default(), None, 0)
        .unwrap();
    assert!(out.update.is_empty());
    assert_eq!(out.steps, 0);
}
