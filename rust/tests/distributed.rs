//! End-to-end tests for the multi-process distributed backend: a server
//! driving real `pfl worker` child processes over loopback TCP must
//! produce the bit-identical central model to the in-process threaded
//! engine at the same seed (ROADMAP acceptance: N ∈ {1, 2, 4}), and a
//! `kill -9` mid-round must be survived by requeuing the dead worker's
//! in-flight users onto the remaining connections.
//!
//! These tests are PJRT-free: they pair the `"linear"` model with the
//! `"tabular"` synthetic dataset so worker processes rebuild the full
//! stack from the config JSON shipped in the handshake without needing
//! HLO artifacts (see `pfl::config::build::LINEAR_DIM`).

use std::process::{Child, Command};
use std::time::Duration;

use pfl::baselines::EngineVariant;
use pfl::comms::{SetupSpec, SocketServer};
use pfl::config::build::{build_backend, init_params};
use pfl::config::{preset, Config};
use pfl::fl::RunOutcome;

/// Small PJRT-free run: linear model on synthetic tabular users, async
/// replay semantics (bounded reorder window) so the socket run has an
/// in-process twin to be compared against bit-for-bit.
fn base_cfg(iterations: u64) -> Config {
    let mut cfg = preset("cifar10-iid").unwrap();
    cfg.name = "distributed-e2e".into();
    cfg.model = "linear".into();
    cfg.dataset.kind = "tabular".into();
    cfg.dataset.num_users = 48;
    cfg.dataset.per_user = 8;
    cfg.iterations = iterations;
    cfg.cohort_size = 8;
    cfg.val_cohort_size = 4;
    cfg.eval_every = 3;
    cfg.local_epochs = 1;
    cfg.local_batch = 8;
    cfg.local_max_steps = 0;
    cfg.max_staleness = 2;
    cfg.buffer_frac = 0.5;
    cfg.reorder_window = 4;
    cfg.seed = 11;
    cfg
}

fn spawn_worker(addr: &str) -> Child {
    Command::new(env!("CARGO_BIN_EXE_pfl"))
        .args(["worker", "--connect", addr])
        .spawn()
        .expect("spawning pfl worker child process")
}

/// Run the config distributed: bind a loopback server, spawn `workers`
/// child processes, and drive `run_distributed`. `kill_first` kills the
/// first worker with SIGKILL shortly after the run starts and spawns a
/// replacement process into the freed slot.
fn socket_run(cfg: &Config, workers: usize, heartbeat_ms: u64, kill_first: bool) -> RunOutcome {
    let mut cfg = cfg.clone();
    cfg.dispatcher = "socket".into();
    cfg.num_workers = workers;
    let server = SocketServer::bind("127.0.0.1:0").unwrap();
    let addr = server.local_addr().to_string();
    let mut children: Vec<Child> = (0..workers).map(|_| spawn_worker(&addr)).collect();
    let pool = server
        .into_pool(
            workers,
            SetupSpec { use_hlo_clip: false, heartbeat_ms, config_json: cfg.to_json() },
        )
        .unwrap();
    // kill only once every worker has handshaked (the pool exists), so
    // the victim is mid-round rather than mid-connect
    let killer = kill_first.then(|| {
        let mut victim = children.remove(0);
        let addr = addr.clone();
        std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            let _ = victim.kill();
            let _ = victim.wait();
            spawn_worker(&addr)
        })
    });
    let mut backend = build_backend(&cfg, EngineVariant::PflStyle.profile()).unwrap();
    let init = init_params(&cfg).unwrap();
    let outcome = backend.run_distributed(init, &mut [], pool).unwrap();
    if let Some(k) = killer {
        children.push(k.join().unwrap());
    }
    for mut c in children {
        // shutdown already sent Stop; reap (and kill stragglers) anyway
        let _ = c.kill();
        let _ = c.wait();
    }
    outcome
}

#[test]
fn socket_run_bit_identical_to_threaded_run() {
    let cfg = base_cfg(8);

    // in-process reference: same config on the threaded async-replay
    // engine (worker count is immaterial — PR 4's replay fold is
    // bit-identical across worker counts, so one thread is the baseline)
    let mut reference = cfg.clone();
    reference.dispatcher = "async".into();
    reference.num_workers = 1;
    let mut backend = build_backend(&reference, EngineVariant::PflStyle.profile()).unwrap();
    let expect = backend.run(init_params(&reference).unwrap(), &mut []).unwrap();
    assert_eq!(expect.rounds, cfg.iterations);

    for workers in [1usize, 2, 4] {
        let got = socket_run(&cfg, workers, 500, false);
        assert_eq!(got.rounds, expect.rounds, "{workers} workers: rounds diverged");
        assert_eq!(got.central, expect.central, "{workers} workers: central model diverged");
        assert_eq!(
            got.series("train/loss"),
            expect.series("train/loss"),
            "{workers} workers: train/loss series diverged"
        );
        // val metrics merge across the *local* eval pool, whose worker
        // count differs between the runs — float-sum association may
        // differ in the last ulp, so compare approximately
        let (gv, ev) = (got.series("val/loss"), expect.series("val/loss"));
        assert_eq!(gv.len(), ev.len(), "{workers} workers: val cadence diverged");
        for ((gt, g), (et, e)) in gv.iter().zip(&ev) {
            assert_eq!(gt, et);
            assert!((g - e).abs() <= 1e-9 * e.abs().max(1.0), "val/loss diverged: {g} vs {e}");
        }
        assert!(got.counters.wire_bytes_out > 0, "no wire traffic recorded");
        assert!(got.counters.wire_bytes_in > 0, "no wire traffic recorded");
        assert_eq!(got.counters.requeued_users, 0, "healthy run requeued users");
    }
}

#[test]
fn socket_with_dropout_bit_identical_to_threaded() {
    // tentpole property across the process boundary: device availability
    // and mid-round dropout are pure functions of (seed, uid, round), so
    // a scenario-afflicted socket run must match the threaded async-
    // replay engine bit-for-bit — same central model, same dropout
    // accounting — for any worker count.
    let mut cfg = base_cfg(8);
    cfg.scenario = Some(pfl::fl::device::ScenarioSpec {
        churn: 0.2,
        diurnal: 0.5,
        dropout_hazard: 0.3,
        speed_tiers: 3,
    });

    let mut reference = cfg.clone();
    reference.dispatcher = "async".into();
    reference.num_workers = 1;
    let mut backend = build_backend(&reference, EngineVariant::PflStyle.profile()).unwrap();
    let expect = backend.run(init_params(&reference).unwrap(), &mut []).unwrap();
    assert_eq!(expect.rounds, cfg.iterations);
    assert!(expect.counters.dropout_users > 0, "hazard 0.3 never fired in the reference");

    for workers in [1usize, 2] {
        let got = socket_run(&cfg, workers, 500, false);
        assert_eq!(got.rounds, expect.rounds, "{workers} workers: rounds diverged");
        assert_eq!(got.central, expect.central, "{workers} workers: central model diverged");
        assert_eq!(
            got.counters.dropout_users, expect.counters.dropout_users,
            "{workers} workers: dropout accounting diverged across the transport"
        );
        assert_eq!(
            got.counters.unavailable_skipped, expect.counters.unavailable_skipped,
            "{workers} workers: availability accounting diverged"
        );
        for name in ["sys/dropout-frac", "sys/completion-rate", "sys/unavailable-skipped"] {
            assert_eq!(
                got.series(name),
                expect.series(name),
                "{workers} workers: {name} series diverged"
            );
        }
    }
}

#[test]
fn kill_nine_with_dropout_accounts_every_user() {
    // combined failure: a kill -9'd worker (transport death -> requeue,
    // same seqs) in the same run as hazard-dropped users (scenario death
    // -> partial abandoned). The requeue preserves dispatch order and the
    // reorder buffer accepts one result per seq (first wins), so no uid
    // is double-folded: the run stays bit-identical to a healthy
    // threaded run, while both failure counters fire.
    let mut cfg = base_cfg(300);
    cfg.dataset.per_user = 32;
    cfg.scenario = Some(pfl::fl::device::ScenarioSpec {
        churn: 0.0,
        diurnal: 0.0,
        dropout_hazard: 0.2,
        speed_tiers: 1,
    });

    let mut reference = cfg.clone();
    reference.dispatcher = "async".into();
    reference.num_workers = 1;
    let mut backend = build_backend(&reference, EngineVariant::PflStyle.profile()).unwrap();
    let expect = backend.run(init_params(&reference).unwrap(), &mut []).unwrap();

    let out = socket_run(&cfg, 2, 20, true);
    assert_eq!(out.rounds, cfg.iterations, "run did not complete after kill -9");
    assert!(
        out.counters.requeued_users > 0,
        "kill -9 mid-round should have requeued in-flight users"
    );
    assert!(out.counters.dropout_users > 0, "hazard 0.2 never fired in 300 rounds");
    // no uid double-folded, none lost: the transport failure is invisible
    // to the model and to the scenario ledger
    assert_eq!(out.central, expect.central, "kill -9 + dropout changed the model");
    assert_eq!(
        out.counters.dropout_users, expect.counters.dropout_users,
        "requeue double-counted (or lost) hazard-dropped users"
    );
    assert_eq!(
        out.series("sys/dropout-frac"),
        expect.series("sys/dropout-frac"),
        "per-round dropout ledger diverged under kill -9"
    );
    // the per-round requeue metric accounts for exactly the counter total
    let requeued_metric: f64 =
        out.series("sys/requeued-users").iter().map(|(_, v)| v).sum();
    assert_eq!(requeued_metric as u64, out.counters.requeued_users);
    let series = out.series("train/loss");
    assert!(series.last().unwrap().1 < series.first().unwrap().1);
}

#[test]
fn kill_nine_mid_round_requeues_and_completes() {
    // long enough that the kill at ~30ms lands mid-run and the
    // replacement has time to handshake before the final round
    let mut cfg = base_cfg(300);
    cfg.dataset.per_user = 32;
    let out = socket_run(&cfg, 2, 20, true);
    assert_eq!(out.rounds, cfg.iterations, "run did not complete after kill -9");
    assert!(
        out.counters.requeued_users > 0,
        "kill -9 mid-round should have requeued in-flight users"
    );
    assert!(
        out.counters.worker_reconnects >= 1,
        "replacement worker never joined the pool"
    );
    // the run still learns through the failure
    let series = out.series("train/loss");
    assert!(series.last().unwrap().1 < series.first().unwrap().1);
}
