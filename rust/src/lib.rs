//! # pfl-rs
//!
//! A Rust + JAX + Pallas reproduction of **pfl-research** (Granqvist et
//! al., NeurIPS 2024): a fast, modular simulation framework for federated
//! learning (FL) and private federated learning (PFL).
//!
//! Architecture (DESIGN.md §1):
//! * **L3 (this crate)** — the simulation framework: the generalized PFL
//!   loop (paper Alg. 1), algorithms, aggregation, DP mechanisms +
//!   accountants, worker replicas with greedy load balancing, synthetic
//!   federated datasets plus the out-of-core sharded store (DESIGN.md
//!   §6), metrics, callbacks, baseline-architecture emulations and the
//!   benchmark CLI.
//! * **L2 (python/compile)** — JAX benchmark models, AOT-lowered once to
//!   HLO text artifacts.
//! * **L1 (python/compile/kernels)** — Pallas kernels (DP clipping, fused
//!   linear) lowered into the same artifacts.
//!
//! Python never runs on the simulation path: the `runtime` module loads
//! `artifacts/*.hlo.txt` through the PJRT CPU client and the whole
//! simulation is a self-contained Rust binary.

pub mod baselines;
pub mod comms;
pub mod config;
pub mod data;
pub mod experiments;
pub mod fl;
pub mod privacy;
pub mod runtime;
pub mod simsys;
pub mod tensor;
pub mod util;

pub use anyhow::Result;
