//! [`StatValue`] — the payload of one named statistic: a dense vector or
//! a sorted-index sparse vector with an explicit logical dimension.
//!
//! Sparse values are how LoRA-style and GBDT-style scenarios ship
//! compact updates end-to-end: `element_count` (the communication cost)
//! is the number of stored nonzeros, and aggregation sums any mix of
//! shapes without an intermediate densify (sparse+sparse merges sorted
//! indices; sparse+dense scatter-adds into the dense operand). The shape
//! of a sum depends only on the *set* of operands, never their order, so
//! the aggregator exchange law holds across mixes.

use super::ops;

#[derive(Debug, Clone, PartialEq)]
pub enum StatValue {
    /// A plain vector; index i is coordinate i.
    Dense(Vec<f32>),
    /// Coordinates `idx` (sorted, unique, all `< dim`) with values `val`.
    /// `dim` is the logical dense length, so densification and
    /// mixed-shape sums are well-defined even when every contribution is
    /// sparse.
    Sparse { dim: u32, idx: Vec<u32>, val: Vec<f32> },
    /// Quantized wire representation (the `--quantize` path). `bits` is
    /// 8 (symmetric int8 fixed-point in `scale`, 1 byte/code) or 16
    /// (IEEE binary16, little-endian, 2 bytes/code, `scale` = 1.0);
    /// `data` holds the packed codes. `idx: Some(indices)` is the
    /// quantized form of a sparse value — code j encodes coordinate
    /// `idx[j]` (sorted unique, all `< dim`); `None` means dense, with
    /// the codes covering all `dim` coordinates. Quantized values decode
    /// on arrival at the accumulator (see [`Self::axpy_value`]), so they
    /// exist only on the user → aggregator wire hop.
    Quantized { dim: u32, scale: f32, bits: u8, idx: Option<Vec<u32>>, data: Vec<u8> },
}

impl Default for StatValue {
    fn default() -> Self {
        StatValue::Dense(Vec::new())
    }
}

impl StatValue {
    /// Sparse constructor; debug-asserts the index invariants.
    pub fn sparse(dim: u32, idx: Vec<u32>, val: Vec<f32>) -> StatValue {
        debug_assert_eq!(idx.len(), val.len());
        debug_assert!(idx.windows(2).all(|w| w[0] < w[1]), "indices must be sorted unique");
        debug_assert!(idx.last().map(|&i| i < dim).unwrap_or(true), "index out of bounds");
        StatValue::Sparse { dim, idx, val }
    }

    /// Build a sparse value from the nonzeros of a dense slice.
    pub fn from_dense_nonzeros(v: &[f32]) -> StatValue {
        let mut idx = Vec::new();
        let mut val = Vec::new();
        for (i, &x) in v.iter().enumerate() {
            if x != 0.0 {
                idx.push(i as u32);
                val.push(x);
            }
        }
        StatValue::Sparse { dim: v.len() as u32, idx, val }
    }

    /// Compact the stored representation: a mostly-zero dense value
    /// converts to sparse when the sparse encoding (idx + val per
    /// nonzero) is smaller, and a sparse value drops explicitly-stored
    /// zeros (e.g. introduced by top-k masking).
    pub fn compact(self) -> StatValue {
        match self {
            StatValue::Dense(v) => {
                let nnz = v.iter().filter(|x| **x != 0.0).count();
                if nnz * 2 < v.len() {
                    StatValue::from_dense_nonzeros(&v)
                } else {
                    StatValue::Dense(v)
                }
            }
            StatValue::Sparse { dim, mut idx, mut val } => {
                if val.iter().any(|x| *x == 0.0) {
                    let mut ni = Vec::with_capacity(val.len());
                    let mut nv = Vec::with_capacity(val.len());
                    for (i, v) in idx.iter().zip(val.iter()) {
                        if *v != 0.0 {
                            ni.push(*i);
                            nv.push(*v);
                        }
                    }
                    idx = ni;
                    val = nv;
                }
                StatValue::Sparse { dim, idx, val }
            }
            // already the most compact wire form
            q @ StatValue::Quantized { .. } => q,
        }
    }

    /// Quantize to the given wire precision (8 or 16 bits). Sparse
    /// input keeps its index set; already-quantized input is returned
    /// unchanged. The inverse (up to rounding) is [`Self::dequantize`].
    pub fn quantize(&self, bits: u8) -> StatValue {
        debug_assert!(bits == 8 || bits == 16, "wire precision must be 8 or 16");
        let encode = |v: &[f32]| {
            let mut data = Vec::new();
            let scale = if bits == 8 {
                ops::quantize_i8(v, &mut data)
            } else {
                ops::quantize_f16(v, &mut data);
                1.0
            };
            (scale, data)
        };
        match self {
            StatValue::Dense(v) => {
                let (scale, data) = encode(v);
                StatValue::Quantized { dim: v.len() as u32, scale, bits, idx: None, data }
            }
            StatValue::Sparse { dim, idx, val } => {
                let (scale, data) = encode(val);
                StatValue::Quantized { dim: *dim, scale, bits, idx: Some(idx.clone()), data }
            }
            q @ StatValue::Quantized { .. } => q.clone(),
        }
    }

    /// Decode back to the unquantized shape: dense, or sparse when the
    /// quantized value carries an index set. Clones non-quantized input.
    pub fn dequantize(&self) -> StatValue {
        match self {
            StatValue::Quantized { dim, scale, bits, idx, data } => {
                let mut vals = Vec::new();
                if *bits == 8 {
                    ops::dequantize_i8(data, *scale, &mut vals);
                } else {
                    ops::dequantize_f16(data, &mut vals);
                }
                match idx {
                    Some(i) => StatValue::Sparse { dim: *dim, idx: i.clone(), val: vals },
                    None => StatValue::Dense(vals),
                }
            }
            other => other.clone(),
        }
    }

    /// Logical dense length.
    pub fn len(&self) -> usize {
        match self {
            StatValue::Dense(v) => v.len(),
            StatValue::Sparse { dim, .. } | StatValue::Quantized { dim, .. } => *dim as usize,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Stored coordinate count — the communication cost of this value in
    /// coordinates (nonzeros for sparse/indexed-quantized, full length
    /// for dense shapes).
    pub fn element_count(&self) -> usize {
        match self {
            StatValue::Dense(v) => v.len(),
            StatValue::Sparse { val, .. } => val.len(),
            StatValue::Quantized { bits, data, .. } => data.len() / (*bits as usize / 8),
        }
    }

    /// Wire cost in coordinate-slots: dense ships one slot per
    /// coordinate; sparse (and indexed-quantized) ships an index slot
    /// plus a value slot per nonzero. This is the width-independent
    /// volume metric (`sys/user-update-elems`); [`Self::wire_bytes`] is
    /// the width-aware one. Near the compact threshold a "sparse" update
    /// costs the same as dense, and `compact()` only converts when this
    /// number shrinks.
    pub fn wire_elements(&self) -> usize {
        match self {
            StatValue::Dense(v) => v.len(),
            StatValue::Sparse { val, .. } => 2 * val.len(),
            StatValue::Quantized { idx, .. } => {
                let n = self.element_count();
                n + if idx.is_some() { n } else { 0 }
            }
        }
    }

    /// Wire cost in bytes, accounting for the stored width: dense = 4
    /// bytes per coordinate, sparse = 8 per nonzero (u32 index + f32
    /// value), quantized = the packed code bytes plus 4 per index (when
    /// indexed) plus a 4-byte scale header. Feeds
    /// `sys/user-update-bytes`.
    pub fn wire_bytes(&self) -> usize {
        match self {
            StatValue::Dense(v) => 4 * v.len(),
            StatValue::Sparse { val, .. } => 8 * val.len(),
            StatValue::Quantized { idx, data, .. } => {
                4 + data.len() + 4 * idx.as_ref().map_or(0, |i| i.len())
            }
        }
    }

    /// The backing f32 values: all coordinates for dense, the nonzeros
    /// for sparse. Norms and uniform scaling over this slice are exact
    /// for both shapes (absent coordinates are zero). Quantized values
    /// have no f32 backing and return the empty slice — use
    /// [`Self::l2_norm`] / [`Self::scale`] (decode-aware) or
    /// [`Self::dequantize`] instead.
    pub fn values(&self) -> &[f32] {
        match self {
            StatValue::Dense(v) => v,
            StatValue::Sparse { val, .. } => val,
            StatValue::Quantized { .. } => &[],
        }
    }

    /// Mutable backing values (see [`Self::values`]); a full `Vec` so
    /// clip kernels with a `&mut Vec<f32>` interface apply directly.
    /// A quantized value densifies first (in-place mutation of packed
    /// codes is not representable).
    pub fn values_mut(&mut self) -> &mut Vec<f32> {
        if matches!(self, StatValue::Quantized { .. }) {
            return self.densify();
        }
        match self {
            StatValue::Dense(v) => v,
            StatValue::Sparse { val, .. } => val,
            StatValue::Quantized { .. } => unreachable!("densified above"),
        }
    }

    /// Dense view, `None` when sparse or quantized.
    pub fn as_dense(&self) -> Option<&[f32]> {
        match self {
            StatValue::Dense(v) => Some(v),
            StatValue::Sparse { .. } | StatValue::Quantized { .. } => None,
        }
    }

    /// Materialize the dense form (clones for dense input; decodes
    /// quantized input).
    pub fn to_dense_vec(&self) -> Vec<f32> {
        match self {
            StatValue::Dense(v) => v.clone(),
            StatValue::Sparse { dim, idx, val } => {
                let mut out = vec![0.0f32; *dim as usize];
                ops::scatter_add(&mut out, idx, val);
                out
            }
            StatValue::Quantized { dim, .. } => {
                let mut out = vec![0.0f32; *dim as usize];
                dequant_axpy_into(&mut out, 1.0, self);
                out
            }
        }
    }

    /// Convert to dense in place and return the buffer. No-op for
    /// dense; decodes quantized values.
    pub fn densify(&mut self) -> &mut Vec<f32> {
        match self {
            StatValue::Dense(_) => {}
            _ => *self = StatValue::Dense(self.to_dense_vec()),
        }
        match self {
            StatValue::Dense(v) => v,
            _ => unreachable!("densified above"),
        }
    }

    /// self += other, for any mix of shapes. The result is sparse only
    /// when both operands are sparse; any dense operand densifies.
    /// Exactly [`Self::axpy_value`] at s = 1.0 (bit-identical: IEEE
    /// multiplication by 1.0 is the identity).
    pub fn add_value(&mut self, other: &StatValue) {
        self.axpy_value(1.0, other);
    }

    /// self += s · other, for any mix of shapes, without materializing a
    /// scaled copy of `other` — the staleness-discounted fold of async
    /// buffered aggregation. Shape result matches [`Self::add_value`]:
    /// sparse only when both operands are sparse.
    pub fn axpy_value(&mut self, s: f32, other: &StatValue) {
        if matches!(self, StatValue::Quantized { .. }) {
            // a quantized accumulator decodes before accepting adds
            self.densify();
        }
        match other {
            StatValue::Dense(x) => {
                let dst = self.densify();
                if dst.len() < x.len() {
                    dst.resize(x.len(), 0.0);
                }
                ops::axpy(&mut dst[..x.len()], s, x);
            }
            StatValue::Sparse { dim, idx, val } => match self {
                StatValue::Dense(dst) => {
                    if dst.len() < *dim as usize {
                        dst.resize(*dim as usize, 0.0);
                    }
                    ops::scatter_axpy(dst, s, idx, val);
                }
                StatValue::Sparse { dim: d0, idx: i0, val: v0 } => {
                    *d0 = (*d0).max(*dim);
                    if i0.as_slice() == idx.as_slice() {
                        ops::axpy(v0, s, val);
                    } else {
                        let mut mi = Vec::new();
                        let mut mv = Vec::new();
                        merge_sparse_scaled_into(i0, v0, idx, val, s, &mut mi, &mut mv);
                        *i0 = mi;
                        *v0 = mv;
                    }
                }
                StatValue::Quantized { .. } => unreachable!("densified above"),
            },
            q @ StatValue::Quantized { dim, .. } => {
                // quantized arrivals decode into a dense accumulator —
                // the aggregation-side decode of the wire representation
                let dst = self.densify();
                if dst.len() < *dim as usize {
                    dst.resize(*dim as usize, 0.0);
                }
                dequant_axpy_into(dst, s, q);
            }
        }
    }

    /// Uniform scale (exact for dense/sparse; int8 rescales the shared
    /// fixed-point scale exactly, f16 re-encodes each code in place).
    pub fn scale(&mut self, s: f32) {
        match self {
            StatValue::Quantized { scale, bits: 8, .. } => *scale *= s,
            StatValue::Quantized { data, .. } => {
                for c in data.chunks_exact_mut(2) {
                    let x = ops::f16_decode(u16::from_le_bytes([c[0], c[1]])) * s;
                    c.copy_from_slice(&ops::f16_encode(x).to_le_bytes());
                }
            }
            _ => ops::scale(self.values_mut(), s),
        }
    }

    /// L2 norm (exact for dense/sparse; decodes quantized codes on the
    /// fly without materializing an f32 buffer).
    pub fn l2_norm(&self) -> f64 {
        match self {
            StatValue::Quantized { scale, bits, data, .. } => {
                if *bits == 8 {
                    ops::l2_norm_i8(data, *scale)
                } else {
                    ops::l2_norm_f16(data)
                }
            }
            _ => ops::l2_norm(self.values()),
        }
    }
}

/// dst += s · decode(q) without materializing an f32 copy of `q`'s
/// payload; `dst` must already cover `q.len()`. No-op for non-quantized
/// input (callers dispatch those through [`StatValue::axpy_value`]).
pub(crate) fn dequant_axpy_into(dst: &mut [f32], s: f32, q: &StatValue) {
    if let StatValue::Quantized { scale, bits, idx, data, .. } = q {
        match (idx, *bits) {
            (Some(i), 8) => ops::dequant_scatter_axpy_i8(dst, s, i, data, *scale),
            (Some(i), _) => ops::dequant_scatter_axpy_f16(dst, s, i, data),
            (None, 8) => ops::dequant_axpy_i8(dst, s, data, *scale),
            (None, _) => ops::dequant_axpy_f16(dst, s, data),
        }
    }
}

/// Merge two sorted sparse streams into caller-owned output buffers,
/// scaling the `b` side by `s`: out = a + s·b. The outputs are cleared
/// but keep their capacity, so a caller that ping-pongs the same pair of
/// buffers (the sparse [`crate::tensor::StatsArena`] slot) allocates
/// nothing once the buffers have grown to the working-set size.
pub(crate) fn merge_sparse_scaled_into(
    ia: &[u32],
    va: &[f32],
    ib: &[u32],
    vb: &[f32],
    s: f32,
    out_idx: &mut Vec<u32>,
    out_val: &mut Vec<f32>,
) {
    debug_assert_eq!(ia.len(), va.len());
    debug_assert_eq!(ib.len(), vb.len());
    out_idx.clear();
    out_val.clear();
    out_idx.reserve(ia.len() + ib.len());
    out_val.reserve(ia.len() + ib.len());
    let (mut i, mut j) = (0usize, 0usize);
    while i < ia.len() && j < ib.len() {
        if ia[i] == ib[j] {
            out_idx.push(ia[i]);
            out_val.push(va[i] + s * vb[j]);
            i += 1;
            j += 1;
        } else if ia[i] < ib[j] {
            out_idx.push(ia[i]);
            out_val.push(va[i]);
            i += 1;
        } else {
            out_idx.push(ib[j]);
            out_val.push(s * vb[j]);
            j += 1;
        }
    }
    while i < ia.len() {
        out_idx.push(ia[i]);
        out_val.push(va[i]);
        i += 1;
    }
    while j < ib.len() {
        out_idx.push(ib[j]);
        out_val.push(s * vb[j]);
        j += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sp(dim: u32, pairs: &[(u32, f32)]) -> StatValue {
        StatValue::sparse(
            dim,
            pairs.iter().map(|p| p.0).collect(),
            pairs.iter().map(|p| p.1).collect(),
        )
    }

    #[test]
    fn densify_and_roundtrip() {
        let mut v = sp(5, &[(1, 2.0), (4, -1.0)]);
        assert_eq!(v.len(), 5);
        assert_eq!(v.element_count(), 2);
        assert_eq!(v.to_dense_vec(), vec![0.0, 2.0, 0.0, 0.0, -1.0]);
        let d = v.densify();
        assert_eq!(d, &vec![0.0, 2.0, 0.0, 0.0, -1.0]);
        assert!(v.as_dense().is_some());
    }

    #[test]
    fn compact_only_when_beneficial() {
        let mostly_zero = StatValue::Dense(vec![0.0, 0.0, 0.0, 0.0, 0.0, 7.0]);
        match mostly_zero.compact() {
            StatValue::Sparse { dim, idx, val } => {
                assert_eq!(dim, 6);
                assert_eq!(idx, vec![5]);
                assert_eq!(val, vec![7.0]);
            }
            other => panic!("expected sparse, got {other:?}"),
        }
        let dense = StatValue::Dense(vec![1.0, 2.0, 3.0]);
        assert!(matches!(dense.compact(), StatValue::Dense(_)));

        // sparse input drops stored zeros (top-k masking aftermath)
        let masked = StatValue::sparse(8, vec![1, 3, 5], vec![2.0, 0.0, -1.0]);
        let c = masked.compact();
        assert_eq!(c.element_count(), 2);
        assert_eq!(c.to_dense_vec(), vec![0.0, 2.0, 0.0, 0.0, 0.0, -1.0, 0.0, 0.0]);
    }

    #[test]
    fn add_value_all_shape_mixes() {
        // dense += dense
        let mut a = StatValue::Dense(vec![1.0, 2.0]);
        a.add_value(&StatValue::Dense(vec![3.0, 4.0]));
        assert_eq!(a.to_dense_vec(), vec![4.0, 6.0]);

        // dense += sparse
        let mut a = StatValue::Dense(vec![1.0, 1.0, 1.0]);
        a.add_value(&sp(3, &[(2, 5.0)]));
        assert_eq!(a.to_dense_vec(), vec![1.0, 1.0, 6.0]);

        // sparse += dense (densifies)
        let mut a = sp(3, &[(0, 1.0)]);
        a.add_value(&StatValue::Dense(vec![1.0, 1.0, 1.0]));
        assert!(a.as_dense().is_some());
        assert_eq!(a.to_dense_vec(), vec![2.0, 1.0, 1.0]);

        // sparse += sparse, disjoint + shared indices (stays sparse)
        let mut a = sp(6, &[(1, 1.0), (3, 1.0)]);
        a.add_value(&sp(6, &[(3, 2.0), (5, 4.0)]));
        assert!(matches!(a, StatValue::Sparse { .. }));
        assert_eq!(a.to_dense_vec(), vec![0.0, 1.0, 0.0, 3.0, 0.0, 4.0]);

        // identical pattern fast path
        let mut a = sp(4, &[(0, 1.0), (2, 2.0)]);
        a.add_value(&sp(4, &[(0, 10.0), (2, 20.0)]));
        assert_eq!(a.element_count(), 2);
        assert_eq!(a.to_dense_vec(), vec![11.0, 0.0, 22.0, 0.0]);
    }

    #[test]
    fn axpy_value_matches_scaled_add_all_mixes() {
        let cases: Vec<(StatValue, StatValue)> = vec![
            (StatValue::Dense(vec![1.0, 2.0, 3.0]), StatValue::Dense(vec![4.0, 5.0, 6.0])),
            (StatValue::Dense(vec![1.0, 1.0, 1.0]), sp(3, &[(0, 2.0), (2, -4.0)])),
            (sp(3, &[(1, 1.0)]), StatValue::Dense(vec![2.0, 2.0, 2.0])),
            (sp(5, &[(0, 1.0), (3, 1.0)]), sp(5, &[(3, 2.0), (4, 8.0)])),
            (sp(4, &[(1, 1.0), (2, 2.0)]), sp(4, &[(1, 10.0), (2, 20.0)])),
        ];
        for (a0, b) in cases {
            let s = 0.5f32;
            let mut a = a0.clone();
            a.axpy_value(s, &b);
            let mut reference = a0.clone();
            let mut scaled = b.clone();
            scaled.scale(s);
            reference.add_value(&scaled);
            assert_eq!(a.to_dense_vec(), reference.to_dense_vec(), "{a0:?} += {s}*{b:?}");
            // shape law matches add_value: sparse only when both sparse
            assert_eq!(
                matches!(a, StatValue::Sparse { .. }),
                matches!(a0, StatValue::Sparse { .. }) && matches!(b, StatValue::Sparse { .. })
            );
        }
    }

    #[test]
    fn scale_and_norm_exact_for_sparse() {
        let mut v = sp(100, &[(10, 3.0), (90, 4.0)]);
        assert!((v.l2_norm() - 5.0).abs() < 1e-9);
        v.scale(0.5);
        assert_eq!(v.to_dense_vec()[10], 1.5);
        assert_eq!(v.to_dense_vec()[90], 2.0);
    }

    #[test]
    fn quantize_round_trips_both_shapes_and_widths() {
        let dense = StatValue::Dense(vec![1.0, -2.0, 0.5, 0.25]);
        let sparse = sp(10, &[(1, 2.0), (7, -4.0)]);
        for bits in [8u8, 16] {
            let qd = dense.quantize(bits);
            assert_eq!(qd.len(), 4);
            assert_eq!(qd.element_count(), 4);
            let back = qd.dequantize();
            assert!(matches!(back, StatValue::Dense(_)));
            for (a, b) in back.to_dense_vec().iter().zip(dense.to_dense_vec()) {
                assert!((a - b).abs() <= 2.0 / 127.0, "{a} vs {b}");
            }

            let qs = sparse.quantize(bits);
            assert_eq!(qs.len(), 10);
            assert_eq!(qs.element_count(), 2);
            let back = qs.dequantize();
            assert!(matches!(back, StatValue::Sparse { .. }));
            for (a, b) in back.to_dense_vec().iter().zip(sparse.to_dense_vec()) {
                assert!((a - b).abs() <= 4.0 / 127.0, "{a} vs {b}");
            }
        }
        // quantizing a quantized value is the identity
        let q = dense.quantize(8);
        assert_eq!(q.quantize(8), q);
        // f16 of exactly representable values is lossless
        assert_eq!(dense.quantize(16).to_dense_vec(), dense.to_dense_vec());
    }

    #[test]
    fn wire_bytes_accounts_for_width() {
        let d = 1000usize;
        let dense = StatValue::Dense((0..d).map(|i| (i as f32).cos()).collect());
        assert_eq!(dense.wire_bytes(), 4 * d);
        let q8 = dense.quantize(8);
        assert_eq!(q8.wire_bytes(), 4 + d);
        let q16 = dense.quantize(16);
        assert_eq!(q16.wire_bytes(), 4 + 2 * d);
        // the satellite claim: int8 ships ≈4× fewer bytes than f32
        assert!(dense.wire_bytes() as f64 / q8.wire_bytes() as f64 >= 3.5);
        // elems metric stays width-independent
        assert_eq!(q8.wire_elements(), d);
        assert_eq!(q16.wire_elements(), d);

        let s = sp(1000, &[(3, 1.0), (500, -2.0), (999, 4.0)]);
        assert_eq!(s.wire_bytes(), 8 * 3);
        let sq = s.quantize(8);
        assert_eq!(sq.wire_bytes(), 4 + 3 + 4 * 3);
        assert_eq!(sq.wire_elements(), 6);
    }

    #[test]
    fn axpy_value_decodes_quantized_operands() {
        // dense accumulator += quantized dense
        let mut a = StatValue::Dense(vec![1.0, 1.0, 1.0, 1.0]);
        let q = StatValue::Dense(vec![2.0, -4.0, 0.0, 8.0]).quantize(8);
        a.axpy_value(0.5, &q);
        let want = [2.0f32, -1.0, 1.0, 5.0];
        for (got, w) in a.to_dense_vec().iter().zip(want) {
            assert!((got - w).abs() <= 0.5 * 8.0 / 127.0 + 1e-6, "{got} vs {w}");
        }
        assert!(a.as_dense().is_some());

        // sparse accumulator += quantized sparse: densifies (quantized
        // arrivals decode into a dense accumulator)
        let mut a = sp(6, &[(0, 1.0)]);
        a.add_value(&sp(6, &[(2, 2.0)]).quantize(16));
        assert!(a.as_dense().is_some());
        assert_eq!(a.to_dense_vec(), vec![1.0, 0.0, 2.0, 0.0, 0.0, 0.0]);

        // quantized accumulator decodes before accepting adds
        let mut a = StatValue::Dense(vec![1.0, 2.0]).quantize(16);
        a.add_value(&StatValue::Dense(vec![1.0, 1.0]));
        assert_eq!(a.to_dense_vec(), vec![2.0, 3.0]);
    }

    #[test]
    fn quantized_scale_and_norm() {
        let v = StatValue::Dense(vec![3.0, 4.0]);
        let mut q8 = v.quantize(8);
        assert!((q8.l2_norm() - 5.0).abs() < 0.1);
        q8.scale(2.0);
        assert!((q8.l2_norm() - 10.0).abs() < 0.2);
        let mut q16 = v.quantize(16);
        assert!((q16.l2_norm() - 5.0).abs() < 1e-6);
        q16.scale(0.5);
        assert_eq!(q16.to_dense_vec(), vec![1.5, 2.0]);
        // values_mut densifies packed codes
        let mut q = v.quantize(16);
        assert!(q.values().is_empty());
        q.values_mut().push(9.0);
        assert_eq!(q.to_dense_vec(), vec![3.0, 4.0, 9.0]);
    }
}
