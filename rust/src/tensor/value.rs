//! [`StatValue`] — the payload of one named statistic: a dense vector or
//! a sorted-index sparse vector with an explicit logical dimension.
//!
//! Sparse values are how LoRA-style and GBDT-style scenarios ship
//! compact updates end-to-end: `element_count` (the communication cost)
//! is the number of stored nonzeros, and aggregation sums any mix of
//! shapes without an intermediate densify (sparse+sparse merges sorted
//! indices; sparse+dense scatter-adds into the dense operand). The shape
//! of a sum depends only on the *set* of operands, never their order, so
//! the aggregator exchange law holds across mixes.

use super::ops;

#[derive(Debug, Clone, PartialEq)]
pub enum StatValue {
    /// A plain vector; index i is coordinate i.
    Dense(Vec<f32>),
    /// Coordinates `idx` (sorted, unique, all `< dim`) with values `val`.
    /// `dim` is the logical dense length, so densification and
    /// mixed-shape sums are well-defined even when every contribution is
    /// sparse.
    Sparse { dim: u32, idx: Vec<u32>, val: Vec<f32> },
}

impl Default for StatValue {
    fn default() -> Self {
        StatValue::Dense(Vec::new())
    }
}

impl StatValue {
    /// Sparse constructor; debug-asserts the index invariants.
    pub fn sparse(dim: u32, idx: Vec<u32>, val: Vec<f32>) -> StatValue {
        debug_assert_eq!(idx.len(), val.len());
        debug_assert!(idx.windows(2).all(|w| w[0] < w[1]), "indices must be sorted unique");
        debug_assert!(idx.last().map(|&i| i < dim).unwrap_or(true), "index out of bounds");
        StatValue::Sparse { dim, idx, val }
    }

    /// Build a sparse value from the nonzeros of a dense slice.
    pub fn from_dense_nonzeros(v: &[f32]) -> StatValue {
        let mut idx = Vec::new();
        let mut val = Vec::new();
        for (i, &x) in v.iter().enumerate() {
            if x != 0.0 {
                idx.push(i as u32);
                val.push(x);
            }
        }
        StatValue::Sparse { dim: v.len() as u32, idx, val }
    }

    /// Compact the stored representation: a mostly-zero dense value
    /// converts to sparse when the sparse encoding (idx + val per
    /// nonzero) is smaller, and a sparse value drops explicitly-stored
    /// zeros (e.g. introduced by top-k masking).
    pub fn compact(self) -> StatValue {
        match self {
            StatValue::Dense(v) => {
                let nnz = v.iter().filter(|x| **x != 0.0).count();
                if nnz * 2 < v.len() {
                    StatValue::from_dense_nonzeros(&v)
                } else {
                    StatValue::Dense(v)
                }
            }
            StatValue::Sparse { dim, mut idx, mut val } => {
                if val.iter().any(|x| *x == 0.0) {
                    let mut ni = Vec::with_capacity(val.len());
                    let mut nv = Vec::with_capacity(val.len());
                    for (i, v) in idx.iter().zip(val.iter()) {
                        if *v != 0.0 {
                            ni.push(*i);
                            nv.push(*v);
                        }
                    }
                    idx = ni;
                    val = nv;
                }
                StatValue::Sparse { dim, idx, val }
            }
        }
    }

    /// Logical dense length.
    pub fn len(&self) -> usize {
        match self {
            StatValue::Dense(v) => v.len(),
            StatValue::Sparse { dim, .. } => *dim as usize,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Stored f32 count — the communication cost of this value (nonzeros
    /// for sparse, full length for dense).
    pub fn element_count(&self) -> usize {
        match self {
            StatValue::Dense(v) => v.len(),
            StatValue::Sparse { val, .. } => val.len(),
        }
    }

    /// Wire cost in f32-equivalents: dense ships one f32 per
    /// coordinate; sparse ships a u32 index plus an f32 value per
    /// nonzero (2 f32-equivalents). This is the honest basis for
    /// communication metrics — near the compact threshold a "sparse"
    /// update costs the same as dense, and `compact()` only converts
    /// when this number shrinks.
    pub fn wire_elements(&self) -> usize {
        match self {
            StatValue::Dense(v) => v.len(),
            StatValue::Sparse { val, .. } => 2 * val.len(),
        }
    }

    /// The backing values: all coordinates for dense, the nonzeros for
    /// sparse. Norms and uniform scaling over this slice are exact for
    /// both shapes (absent coordinates are zero).
    pub fn values(&self) -> &[f32] {
        match self {
            StatValue::Dense(v) => v,
            StatValue::Sparse { val, .. } => val,
        }
    }

    /// Mutable backing values (see [`Self::values`]); a full `Vec` so
    /// clip kernels with a `&mut Vec<f32>` interface apply directly.
    pub fn values_mut(&mut self) -> &mut Vec<f32> {
        match self {
            StatValue::Dense(v) => v,
            StatValue::Sparse { val, .. } => val,
        }
    }

    /// Dense view, `None` when sparse.
    pub fn as_dense(&self) -> Option<&[f32]> {
        match self {
            StatValue::Dense(v) => Some(v),
            StatValue::Sparse { .. } => None,
        }
    }

    /// Materialize the dense form (clones for dense input).
    pub fn to_dense_vec(&self) -> Vec<f32> {
        match self {
            StatValue::Dense(v) => v.clone(),
            StatValue::Sparse { dim, idx, val } => {
                let mut out = vec![0.0f32; *dim as usize];
                ops::scatter_add(&mut out, idx, val);
                out
            }
        }
    }

    /// Convert to dense in place and return the buffer. No-op for dense.
    pub fn densify(&mut self) -> &mut Vec<f32> {
        if let StatValue::Sparse { dim, idx, val } = self {
            let mut out = vec![0.0f32; *dim as usize];
            ops::scatter_add(&mut out, idx, val);
            *self = StatValue::Dense(out);
        }
        match self {
            StatValue::Dense(v) => v,
            StatValue::Sparse { .. } => unreachable!("densified above"),
        }
    }

    /// self += other, for any mix of shapes. The result is sparse only
    /// when both operands are sparse; any dense operand densifies.
    /// Exactly [`Self::axpy_value`] at s = 1.0 (bit-identical: IEEE
    /// multiplication by 1.0 is the identity).
    pub fn add_value(&mut self, other: &StatValue) {
        self.axpy_value(1.0, other);
    }

    /// self += s · other, for any mix of shapes, without materializing a
    /// scaled copy of `other` — the staleness-discounted fold of async
    /// buffered aggregation. Shape result matches [`Self::add_value`]:
    /// sparse only when both operands are sparse.
    pub fn axpy_value(&mut self, s: f32, other: &StatValue) {
        match other {
            StatValue::Dense(x) => {
                let dst = self.densify();
                if dst.len() < x.len() {
                    dst.resize(x.len(), 0.0);
                }
                ops::axpy(&mut dst[..x.len()], s, x);
            }
            StatValue::Sparse { dim, idx, val } => match self {
                StatValue::Dense(dst) => {
                    if dst.len() < *dim as usize {
                        dst.resize(*dim as usize, 0.0);
                    }
                    ops::scatter_axpy(dst, s, idx, val);
                }
                StatValue::Sparse { dim: d0, idx: i0, val: v0 } => {
                    *d0 = (*d0).max(*dim);
                    if i0.as_slice() == idx.as_slice() {
                        ops::axpy(v0, s, val);
                    } else {
                        let mut mi = Vec::new();
                        let mut mv = Vec::new();
                        merge_sparse_scaled_into(i0, v0, idx, val, s, &mut mi, &mut mv);
                        *i0 = mi;
                        *v0 = mv;
                    }
                }
            },
        }
    }

    /// Uniform scale (exact for both shapes).
    pub fn scale(&mut self, s: f32) {
        ops::scale(self.values_mut(), s);
    }

    /// L2 norm (exact for both shapes).
    pub fn l2_norm(&self) -> f64 {
        ops::l2_norm(self.values())
    }
}

/// Merge two sorted sparse streams into caller-owned output buffers,
/// scaling the `b` side by `s`: out = a + s·b. The outputs are cleared
/// but keep their capacity, so a caller that ping-pongs the same pair of
/// buffers (the sparse [`crate::tensor::StatsArena`] slot) allocates
/// nothing once the buffers have grown to the working-set size.
pub(crate) fn merge_sparse_scaled_into(
    ia: &[u32],
    va: &[f32],
    ib: &[u32],
    vb: &[f32],
    s: f32,
    out_idx: &mut Vec<u32>,
    out_val: &mut Vec<f32>,
) {
    debug_assert_eq!(ia.len(), va.len());
    debug_assert_eq!(ib.len(), vb.len());
    out_idx.clear();
    out_val.clear();
    out_idx.reserve(ia.len() + ib.len());
    out_val.reserve(ia.len() + ib.len());
    let (mut i, mut j) = (0usize, 0usize);
    while i < ia.len() && j < ib.len() {
        if ia[i] == ib[j] {
            out_idx.push(ia[i]);
            out_val.push(va[i] + s * vb[j]);
            i += 1;
            j += 1;
        } else if ia[i] < ib[j] {
            out_idx.push(ia[i]);
            out_val.push(va[i]);
            i += 1;
        } else {
            out_idx.push(ib[j]);
            out_val.push(s * vb[j]);
            j += 1;
        }
    }
    while i < ia.len() {
        out_idx.push(ia[i]);
        out_val.push(va[i]);
        i += 1;
    }
    while j < ib.len() {
        out_idx.push(ib[j]);
        out_val.push(s * vb[j]);
        j += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sp(dim: u32, pairs: &[(u32, f32)]) -> StatValue {
        StatValue::sparse(
            dim,
            pairs.iter().map(|p| p.0).collect(),
            pairs.iter().map(|p| p.1).collect(),
        )
    }

    #[test]
    fn densify_and_roundtrip() {
        let mut v = sp(5, &[(1, 2.0), (4, -1.0)]);
        assert_eq!(v.len(), 5);
        assert_eq!(v.element_count(), 2);
        assert_eq!(v.to_dense_vec(), vec![0.0, 2.0, 0.0, 0.0, -1.0]);
        let d = v.densify();
        assert_eq!(d, &vec![0.0, 2.0, 0.0, 0.0, -1.0]);
        assert!(v.as_dense().is_some());
    }

    #[test]
    fn compact_only_when_beneficial() {
        let mostly_zero = StatValue::Dense(vec![0.0, 0.0, 0.0, 0.0, 0.0, 7.0]);
        match mostly_zero.compact() {
            StatValue::Sparse { dim, idx, val } => {
                assert_eq!(dim, 6);
                assert_eq!(idx, vec![5]);
                assert_eq!(val, vec![7.0]);
            }
            other => panic!("expected sparse, got {other:?}"),
        }
        let dense = StatValue::Dense(vec![1.0, 2.0, 3.0]);
        assert!(matches!(dense.compact(), StatValue::Dense(_)));

        // sparse input drops stored zeros (top-k masking aftermath)
        let masked = StatValue::sparse(8, vec![1, 3, 5], vec![2.0, 0.0, -1.0]);
        let c = masked.compact();
        assert_eq!(c.element_count(), 2);
        assert_eq!(c.to_dense_vec(), vec![0.0, 2.0, 0.0, 0.0, 0.0, -1.0, 0.0, 0.0]);
    }

    #[test]
    fn add_value_all_shape_mixes() {
        // dense += dense
        let mut a = StatValue::Dense(vec![1.0, 2.0]);
        a.add_value(&StatValue::Dense(vec![3.0, 4.0]));
        assert_eq!(a.to_dense_vec(), vec![4.0, 6.0]);

        // dense += sparse
        let mut a = StatValue::Dense(vec![1.0, 1.0, 1.0]);
        a.add_value(&sp(3, &[(2, 5.0)]));
        assert_eq!(a.to_dense_vec(), vec![1.0, 1.0, 6.0]);

        // sparse += dense (densifies)
        let mut a = sp(3, &[(0, 1.0)]);
        a.add_value(&StatValue::Dense(vec![1.0, 1.0, 1.0]));
        assert!(a.as_dense().is_some());
        assert_eq!(a.to_dense_vec(), vec![2.0, 1.0, 1.0]);

        // sparse += sparse, disjoint + shared indices (stays sparse)
        let mut a = sp(6, &[(1, 1.0), (3, 1.0)]);
        a.add_value(&sp(6, &[(3, 2.0), (5, 4.0)]));
        assert!(matches!(a, StatValue::Sparse { .. }));
        assert_eq!(a.to_dense_vec(), vec![0.0, 1.0, 0.0, 3.0, 0.0, 4.0]);

        // identical pattern fast path
        let mut a = sp(4, &[(0, 1.0), (2, 2.0)]);
        a.add_value(&sp(4, &[(0, 10.0), (2, 20.0)]));
        assert_eq!(a.element_count(), 2);
        assert_eq!(a.to_dense_vec(), vec![11.0, 0.0, 22.0, 0.0]);
    }

    #[test]
    fn axpy_value_matches_scaled_add_all_mixes() {
        let cases: Vec<(StatValue, StatValue)> = vec![
            (StatValue::Dense(vec![1.0, 2.0, 3.0]), StatValue::Dense(vec![4.0, 5.0, 6.0])),
            (StatValue::Dense(vec![1.0, 1.0, 1.0]), sp(3, &[(0, 2.0), (2, -4.0)])),
            (sp(3, &[(1, 1.0)]), StatValue::Dense(vec![2.0, 2.0, 2.0])),
            (sp(5, &[(0, 1.0), (3, 1.0)]), sp(5, &[(3, 2.0), (4, 8.0)])),
            (sp(4, &[(1, 1.0), (2, 2.0)]), sp(4, &[(1, 10.0), (2, 20.0)])),
        ];
        for (a0, b) in cases {
            let s = 0.5f32;
            let mut a = a0.clone();
            a.axpy_value(s, &b);
            let mut reference = a0.clone();
            let mut scaled = b.clone();
            scaled.scale(s);
            reference.add_value(&scaled);
            assert_eq!(a.to_dense_vec(), reference.to_dense_vec(), "{a0:?} += {s}*{b:?}");
            // shape law matches add_value: sparse only when both sparse
            assert_eq!(
                matches!(a, StatValue::Sparse { .. }),
                matches!(a0, StatValue::Sparse { .. }) && matches!(b, StatValue::Sparse { .. })
            );
        }
    }

    #[test]
    fn scale_and_norm_exact_for_sparse() {
        let mut v = sp(100, &[(10, 3.0), (90, 4.0)]);
        assert!((v.l2_norm() - 5.0).abs() < 1e-9);
        v.scale(0.5);
        assert_eq!(v.to_dense_vec()[10], 1.5);
        assert_eq!(v.to_dense_vec()[90], 2.0);
    }
}
