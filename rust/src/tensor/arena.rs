//! [`StatsArena`] — the worker-local accumulation arena.
//!
//! One arena lives in each worker thread for the whole simulation. Per
//! round, the worker folds every user's statistics into the arena's
//! resident dense buffers by reference; at round end `take_partial`
//! emits one `Statistics` (the per-worker partial handed to
//! `worker_reduce`) and re-arms the buffers for the next round without
//! dropping their capacity.
//!
//! Steady-state guarantee: after the first round sizes the slots, `fold`
//! performs **zero heap allocation** — dense contributions are a chunked
//! `add_assign` (or a `copy_from_slice` for the round's first
//! contribution), sparse contributions a `scatter_add`. Growth bytes are
//! tracked and drained into `Counters::arena_grow_bytes`, so the
//! `loop_alloc_bytes == 0` invariant is observable, not aspirational.

use std::collections::BTreeMap;

use super::ops;
use super::value::StatValue;
use crate::fl::stats::Statistics;

#[derive(Debug, Default)]
struct Slot {
    buf: Vec<f32>,
    /// Whether this round has already written into the slot (the first
    /// contribution overwrites; later ones add).
    live: bool,
}

#[derive(Debug, Default)]
pub struct StatsArena {
    weight: f64,
    /// True once any user was folded this round (so an all-empty round
    /// yields `None`, matching `Aggregator::accumulate` semantics).
    active: bool,
    slots: BTreeMap<String, Slot>,
    /// Bytes allocated growing slot buffers since the last drain.
    grown_bytes: u64,
}

impl StatsArena {
    pub fn new() -> Self {
        StatsArena::default()
    }

    /// Accumulated weight this round.
    pub fn weight(&self) -> f64 {
        self.weight
    }

    pub fn is_active(&self) -> bool {
        self.active
    }

    /// Fold one user's statistics into the resident buffers (summation
    /// semantics — the `SumAggregator` hot path). Borrows the user's
    /// statistics; nothing is moved or inserted per user.
    pub fn fold(&mut self, user: &Statistics) {
        self.active = true;
        self.weight += user.weight;
        for (key, value) in &user.vecs {
            self.fold_value(key, value);
        }
    }

    fn fold_value(&mut self, key: &str, value: &StatValue) {
        if !self.slots.contains_key(key) {
            // key names are bounded by the statistic schema (a handful),
            // so this path runs O(keys) times per run, not per user
            self.slots.insert(key.to_string(), Slot::default());
        }
        let slot = self.slots.get_mut(key).expect("just inserted");
        let need = value.len();
        if slot.buf.len() < need {
            self.grown_bytes += ((need - slot.buf.len()) * std::mem::size_of::<f32>()) as u64;
            slot.buf.resize(need, 0.0);
        }
        if slot.live {
            match value {
                StatValue::Dense(v) => ops::add_assign(&mut slot.buf[..v.len()], v),
                StatValue::Sparse { idx, val, .. } => ops::scatter_add(&mut slot.buf, idx, val),
            }
        } else {
            match value {
                StatValue::Dense(v) => {
                    slot.buf[..v.len()].copy_from_slice(v);
                    slot.buf[v.len()..].fill(0.0);
                }
                StatValue::Sparse { idx, val, .. } => {
                    slot.buf.fill(0.0);
                    ops::scatter_add(&mut slot.buf, idx, val);
                }
            }
            slot.live = true;
        }
    }

    /// Emit this round's partial (one dense vector clone per live slot —
    /// the per-round hand-off to `worker_reduce`, not a per-user cost)
    /// and re-arm the buffers, keeping their capacity.
    pub fn take_partial(&mut self) -> Option<Statistics> {
        if !self.active {
            return None;
        }
        let mut stats = Statistics { weight: self.weight, vecs: BTreeMap::new() };
        for (key, slot) in &mut self.slots {
            if slot.live {
                stats.vecs.insert(key.clone(), StatValue::Dense(slot.buf.clone()));
            }
        }
        self.reset();
        Some(stats)
    }

    /// Re-arm for the next round without dropping buffer capacity.
    /// Also clears undrained growth bookkeeping so an error-aborted
    /// round cannot misattribute its allocations to the next round
    /// (the worker drains *before* `take_partial` in normal flow).
    pub fn reset(&mut self) {
        self.weight = 0.0;
        self.active = false;
        self.grown_bytes = 0;
        for slot in self.slots.values_mut() {
            slot.live = false;
        }
    }

    /// Bytes allocated growing the arena since the last call (0 in
    /// steady state). The worker drains this into
    /// `Counters::arena_grow_bytes` every round.
    pub fn drain_grown_bytes(&mut self) -> u64 {
        std::mem::take(&mut self.grown_bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fold_matches_sum_aggregator() {
        use crate::fl::aggregator::{Aggregator, SumAggregator};
        let users: Vec<Statistics> = (0..5)
            .map(|u| {
                let mut s = Statistics::new_update(vec![u as f32, 1.0, -2.0], 1.0 + u as f64);
                if u % 2 == 0 {
                    s.insert("extra", vec![u as f32; 2]);
                }
                s
            })
            .collect();

        let mut arena = StatsArena::new();
        for u in &users {
            arena.fold(u);
        }
        let a = arena.take_partial().unwrap();

        let agg = SumAggregator;
        let mut acc = None;
        for u in users {
            agg.accumulate(&mut acc, u);
        }
        let b = acc.unwrap();

        assert_eq!(a.weight, b.weight);
        assert_eq!(a.update(), b.update());
        assert_eq!(a.get("extra"), b.get("extra"));
    }

    #[test]
    fn sparse_and_dense_fold_together() {
        let mut arena = StatsArena::new();
        arena.fold(&Statistics::new_update(vec![1.0; 4], 1.0));
        arena.fold(&Statistics::new_update_value(
            StatValue::sparse(4, vec![0, 3], vec![2.0, -1.0]),
            1.0,
        ));
        let p = arena.take_partial().unwrap();
        assert_eq!(p.update(), &[3.0, 1.0, 1.0, 0.0]);
        assert_eq!(p.weight, 2.0);
    }

    #[test]
    fn all_sparse_round_densifies_to_dim() {
        let mut arena = StatsArena::new();
        arena.fold(&Statistics::new_update_value(
            StatValue::sparse(6, vec![5], vec![1.0]),
            1.0,
        ));
        let p = arena.take_partial().unwrap();
        assert_eq!(p.update().len(), 6);
        assert_eq!(p.update()[5], 1.0);
    }

    #[test]
    fn steady_state_needs_no_growth() {
        let mut arena = StatsArena::new();
        let user = Statistics::new_update(vec![1.0; 128], 1.0);
        arena.fold(&user);
        assert!(arena.drain_grown_bytes() > 0); // first round sizes slots
        arena.take_partial().unwrap();
        for _ in 0..3 {
            arena.fold(&user);
            arena.fold(&user);
            assert_eq!(arena.drain_grown_bytes(), 0, "steady-state fold must not grow");
            let p = arena.take_partial().unwrap();
            assert_eq!(p.update(), &[2.0f32; 128][..]);
        }
    }

    #[test]
    fn empty_round_yields_none() {
        let mut arena = StatsArena::new();
        assert!(arena.take_partial().is_none());
        arena.fold(&Statistics::new_update(vec![1.0], 1.0));
        assert!(arena.take_partial().is_some());
        // arena re-armed: next empty round is again None
        assert!(arena.take_partial().is_none());
    }
}
