//! [`StatsArena`] — the worker-local accumulation arena.
//!
//! One arena lives in each worker thread for the whole simulation. Per
//! round, the worker folds every user's statistics into the arena's
//! resident buffers by reference; at round end `take_partial` emits one
//! `Statistics` (the per-worker partial handed to `worker_reduce`) and
//! re-arms the buffers for the next round without dropping their
//! capacity.
//!
//! # Sparse slot lifecycle
//!
//! A slot starts every round **sparse**: contributions with
//! `StatValue::Sparse` payloads accumulate in a sorted-index (idx, val)
//! pair via ping-pong merge buffers, so very-sparse regimes (GBDT
//! histograms, `--topk` LoRA adapters) never touch a model-sized dense
//! buffer. The slot **spills** to its resident dense buffer when either
//!
//! * a dense contribution arrives (a dense operand makes the sum dense
//!   anyway), or
//! * the union nnz crosses `sparse_spill_frac · dim`
//!   ([`ArenaConfig::sparse_spill_frac`]) — past that point the sorted
//!   merge costs more than a dense scatter and the sparse encoding stops
//!   paying for itself.
//!
//! Spills are counted ([`Counters::arena_spill_count`]) and rounds whose
//! every live slot stayed sparse are counted too
//! ([`Counters::arena_sparse_rounds`]), so "the arena stayed sparse" is
//! an observable claim, not an aspiration.
//!
//! Steady-state guarantee: after the first rounds size the slots (dense
//! buffers and sparse ping-pong buffers both keep their capacity across
//! rounds), `fold` performs **zero heap allocation** — dense
//! contributions are a chunked `add_assign`, sparse contributions a
//! sorted merge into retained scratch (or a `scatter_add` once spilled).
//! Growth bytes are tracked and drained into
//! `Counters::arena_grow_bytes`, so the `loop_alloc_bytes == 0`
//! invariant is observable in both regimes.
//!
//! [`Counters::arena_spill_count`]: crate::simsys::Counters::arena_spill_count
//! [`Counters::arena_sparse_rounds`]: crate::simsys::Counters::arena_sparse_rounds

use std::collections::BTreeMap;

use super::ops;
use super::value::{dequant_axpy_into, merge_sparse_scaled_into, StatValue};
use crate::fl::stats::Statistics;

/// Tuning knobs of the worker accumulation arena (config
/// `engine.sparse_spill_frac`, CLI `--sparse-spill-frac`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ArenaConfig {
    /// A slot's sparse accumulator spills to the dense buffer once its
    /// union nnz exceeds this fraction of the logical dimension. `0.0`
    /// densifies on the first sparse contribution (the pre-sparse-arena
    /// behavior); values `>= 1.0` never spill on nnz growth (only a
    /// dense contribution forces the dense buffer).
    pub sparse_spill_frac: f64,
}

impl Default for ArenaConfig {
    fn default() -> Self {
        // past ~1/4 occupancy the sparse encoding (u32 idx + f32 val per
        // nonzero) stops winning on wire size and the sorted merge stops
        // winning on fold cost
        ArenaConfig { sparse_spill_frac: 0.25 }
    }
}

/// Per-round accumulation state of one slot.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
enum SlotMode {
    /// No contribution yet this round.
    #[default]
    Idle,
    /// Accumulating in the sorted sparse (idx, val) pair.
    Sparse,
    /// Accumulating in the resident dense buffer.
    Dense,
}

#[derive(Debug, Default)]
struct Slot {
    /// Resident dense buffer (allocated on first spill / dense
    /// contribution; capacity kept across rounds).
    buf: Vec<f32>,
    /// Sparse accumulator: sorted unique indices + values.
    idx: Vec<u32>,
    val: Vec<f32>,
    /// Ping-pong merge scratch (swapped with idx/val each sparse merge).
    scratch_idx: Vec<u32>,
    scratch_val: Vec<f32>,
    /// Retained decode buffer for indexed-quantized contributions
    /// (`--quantize` + sparse updates), so decoding allocates nothing
    /// once sized.
    dequant_val: Vec<f32>,
    /// Logical dimension of the sparse accumulator this round.
    dim: usize,
    mode: SlotMode,
}

impl Slot {
    /// Grow the dense buffer to at least `need` coordinates, tracking
    /// growth bytes.
    fn ensure_dense_len(&mut self, need: usize, grown: &mut u64) {
        if self.buf.len() < need {
            *grown += ((need - self.buf.len()) * std::mem::size_of::<f32>()) as u64;
            self.buf.resize(need, 0.0);
        }
    }

    /// Total f32/u32 slots allocated across the sparse accumulator and
    /// its merge scratch (growth accounting).
    fn sparse_capacity(&self) -> usize {
        self.idx.capacity()
            + self.val.capacity()
            + self.scratch_idx.capacity()
            + self.scratch_val.capacity()
    }

    /// Move the sparse accumulator into the dense buffer (zeroed first —
    /// the buffer may hold a previous round's partial).
    fn spill(&mut self, grown: &mut u64) {
        self.ensure_dense_len(self.dim, grown);
        self.buf.fill(0.0);
        ops::scatter_add(&mut self.buf, &self.idx, &self.val);
        self.idx.clear();
        self.val.clear();
        self.mode = SlotMode::Dense;
    }

    /// Spill to dense once the union nnz crosses `frac · dim` (runs
    /// inline on the already-borrowed slot — the per-user hot loop pays
    /// no extra map lookup).
    fn maybe_spill(&mut self, frac: f64, grown: &mut u64, spills: &mut u64) {
        if self.mode == SlotMode::Sparse
            && self.dim > 0
            && self.idx.len() as f64 > frac * self.dim as f64
        {
            self.spill(grown);
            *spills += 1;
        }
    }
}

#[derive(Debug, Default)]
pub struct StatsArena {
    config: ArenaConfig,
    weight: f64,
    /// True once any user was folded this round (so an all-empty round
    /// yields `None`, matching `Aggregator::accumulate` semantics).
    active: bool,
    slots: BTreeMap<String, Slot>,
    /// Bytes allocated growing slot buffers since the last drain.
    grown_bytes: u64,
    /// Sparse→dense slot spills since the last drain.
    spill_count: u64,
    /// Rounds whose every live slot was emitted sparse, since the last
    /// drain.
    sparse_rounds: u64,
}

impl StatsArena {
    pub fn new() -> Self {
        StatsArena::with_config(ArenaConfig::default())
    }

    pub fn with_config(config: ArenaConfig) -> Self {
        StatsArena { config, ..Default::default() }
    }

    pub fn config(&self) -> ArenaConfig {
        self.config
    }

    /// Accumulated weight this round.
    pub fn weight(&self) -> f64 {
        self.weight
    }

    pub fn is_active(&self) -> bool {
        self.active
    }

    /// Fold one user's statistics into the resident buffers (summation
    /// semantics — the `SumAggregator` hot path). Borrows the user's
    /// statistics; nothing is moved or inserted per user.
    pub fn fold(&mut self, user: &Statistics) {
        self.active = true;
        self.weight += user.weight;
        for (key, value) in &user.vecs {
            self.fold_value(key, value);
        }
    }

    fn fold_value(&mut self, key: &str, value: &StatValue) {
        if !self.slots.contains_key(key) {
            // key names are bounded by the statistic schema (a handful),
            // so this path runs O(keys) times per run, not per user
            self.slots.insert(key.to_string(), Slot::default());
        }
        let frac = self.config.sparse_spill_frac;
        let slot = self.slots.get_mut(key).expect("just inserted");
        match value {
            StatValue::Dense(v) => {
                slot.ensure_dense_len(v.len().max(slot.dim), &mut self.grown_bytes);
                match slot.mode {
                    SlotMode::Dense => ops::add_assign(&mut slot.buf[..v.len()], v),
                    SlotMode::Sparse => {
                        // a dense operand makes the sum dense: spill the
                        // sparse accumulator, then add
                        slot.spill(&mut self.grown_bytes);
                        self.spill_count += 1;
                        ops::add_assign(&mut slot.buf[..v.len()], v);
                    }
                    SlotMode::Idle => {
                        slot.buf[..v.len()].copy_from_slice(v);
                        slot.buf[v.len()..].fill(0.0);
                        slot.mode = SlotMode::Dense;
                    }
                }
            }
            StatValue::Sparse { dim, idx, val } => {
                Self::fold_sparse_into_slot(
                    slot,
                    frac,
                    *dim as usize,
                    idx,
                    val,
                    &mut self.grown_bytes,
                    &mut self.spill_count,
                );
            }
            StatValue::Quantized { dim, idx, .. } => {
                let dim = *dim as usize;
                match idx {
                    None => {
                        // a dense-quantized contribution makes the sum
                        // dense, exactly like a dense one; the decode is
                        // fused into the accumulate
                        slot.ensure_dense_len(dim.max(slot.dim), &mut self.grown_bytes);
                        if slot.mode == SlotMode::Sparse {
                            slot.spill(&mut self.grown_bytes);
                            self.spill_count += 1;
                        }
                        if slot.mode == SlotMode::Idle {
                            slot.buf.fill(0.0);
                            slot.mode = SlotMode::Dense;
                        }
                        dequant_axpy_into(&mut slot.buf, 1.0, value);
                    }
                    Some(qidx) => {
                        // indexed-quantized: decode the codes into the
                        // slot's retained scratch, then run the normal
                        // sparse lifecycle — sparsity survives the wire
                        // quantization end to end
                        let mut dec = std::mem::take(&mut slot.dequant_val);
                        let cap_before = dec.capacity();
                        if let StatValue::Quantized { scale, bits, data, .. } = value {
                            if *bits == 8 {
                                ops::dequantize_i8(data, *scale, &mut dec);
                            } else {
                                ops::dequantize_f16(data, &mut dec);
                            }
                        }
                        self.grown_bytes +=
                            (dec.capacity().saturating_sub(cap_before) * 4) as u64;
                        Self::fold_sparse_into_slot(
                            slot,
                            frac,
                            dim,
                            qidx,
                            &dec,
                            &mut self.grown_bytes,
                            &mut self.spill_count,
                        );
                        slot.dequant_val = dec;
                    }
                }
            }
        }
    }

    /// The sparse-contribution slot lifecycle (shared by plain sparse
    /// and decoded indexed-quantized contributions).
    #[allow(clippy::too_many_arguments)]
    fn fold_sparse_into_slot(
        slot: &mut Slot,
        frac: f64,
        dim: usize,
        idx: &[u32],
        val: &[f32],
        grown: &mut u64,
        spills: &mut u64,
    ) {
        match slot.mode {
            SlotMode::Dense => {
                slot.ensure_dense_len(dim, grown);
                ops::scatter_add(&mut slot.buf, idx, val);
            }
            SlotMode::Idle => {
                slot.dim = dim;
                Self::copy_sparse_into(idx, val, &mut slot.idx, &mut slot.val, grown);
                slot.mode = SlotMode::Sparse;
                slot.maybe_spill(frac, grown, spills);
            }
            SlotMode::Sparse => {
                slot.dim = slot.dim.max(dim);
                if slot.idx.as_slice() == idx {
                    // identical sparsity pattern (users sharing a
                    // top-k mask / histogram layout): plain add
                    ops::add_assign(&mut slot.val, val);
                } else {
                    let cap_before = slot.sparse_capacity();
                    merge_sparse_scaled_into(
                        &slot.idx,
                        &slot.val,
                        idx,
                        val,
                        1.0,
                        &mut slot.scratch_idx,
                        &mut slot.scratch_val,
                    );
                    std::mem::swap(&mut slot.idx, &mut slot.scratch_idx);
                    std::mem::swap(&mut slot.val, &mut slot.scratch_val);
                    // keep the ping-pong pair symmetric so the
                    // all-sparse steady state settles after one
                    // round of a repeating cohort shape
                    slot.scratch_idx.clear();
                    slot.scratch_val.clear();
                    let need = slot.idx.len();
                    if slot.scratch_idx.capacity() < need {
                        slot.scratch_idx.reserve(need);
                        slot.scratch_val.reserve(need);
                    }
                    let cap_after = slot.sparse_capacity();
                    *grown += (cap_after.saturating_sub(cap_before) * 4) as u64;
                }
                slot.maybe_spill(frac, grown, spills);
            }
        }
    }

    /// Copy a sparse contribution into retained accumulator buffers,
    /// tracking capacity growth (zero once the buffers reached the
    /// working-set size).
    fn copy_sparse_into(
        idx: &[u32],
        val: &[f32],
        dst_idx: &mut Vec<u32>,
        dst_val: &mut Vec<f32>,
        grown: &mut u64,
    ) {
        let cap_before = dst_idx.capacity() + dst_val.capacity();
        dst_idx.clear();
        dst_val.clear();
        dst_idx.extend_from_slice(idx);
        dst_val.extend_from_slice(val);
        let cap_after = dst_idx.capacity() + dst_val.capacity();
        *grown += (cap_after.saturating_sub(cap_before) * 4) as u64;
    }

    /// Emit this round's partial (one vector clone per live slot — the
    /// per-round hand-off to `worker_reduce`, not a per-user cost) and
    /// re-arm the buffers, keeping their capacity. Slots still in sparse
    /// mode emit `StatValue::Sparse`, so sparsity survives into the
    /// cross-worker reduce and the async fold.
    pub fn take_partial(&mut self) -> Option<Statistics> {
        if !self.active {
            return None;
        }
        let mut stats = Statistics { weight: self.weight, vecs: BTreeMap::new() };
        let mut all_sparse = true;
        for (key, slot) in &mut self.slots {
            match slot.mode {
                SlotMode::Idle => {}
                SlotMode::Dense => {
                    all_sparse = false;
                    stats.vecs.insert(key.clone(), StatValue::Dense(slot.buf.clone()));
                }
                SlotMode::Sparse => {
                    stats.vecs.insert(
                        key.clone(),
                        StatValue::sparse(
                            slot.dim as u32,
                            slot.idx.clone(),
                            slot.val.clone(),
                        ),
                    );
                }
            }
        }
        if all_sparse && !stats.vecs.is_empty() {
            self.sparse_rounds += 1;
        }
        self.reset();
        Some(stats)
    }

    /// Re-arm for the next round without dropping buffer capacity.
    /// Also clears undrained growth bookkeeping so an error-aborted
    /// round cannot misattribute its allocations to the next round
    /// (the worker drains *before* `take_partial` in normal flow).
    pub fn reset(&mut self) {
        self.weight = 0.0;
        self.active = false;
        self.grown_bytes = 0;
        for slot in self.slots.values_mut() {
            slot.mode = SlotMode::Idle;
            slot.idx.clear();
            slot.val.clear();
            slot.dim = 0;
        }
    }

    /// Bytes allocated growing the arena since the last call (0 in
    /// steady state). The worker drains this into
    /// `Counters::arena_grow_bytes` every round.
    pub fn drain_grown_bytes(&mut self) -> u64 {
        std::mem::take(&mut self.grown_bytes)
    }

    /// Sparse→dense slot spills since the last call (dense contribution
    /// or nnz crossing the threshold). Drained into
    /// `Counters::arena_spill_count`.
    pub fn drain_spill_count(&mut self) -> u64 {
        std::mem::take(&mut self.spill_count)
    }

    /// Rounds whose every live slot stayed sparse, since the last call
    /// (drain after `take_partial` — the round is classified when the
    /// partial is emitted). Drained into
    /// `Counters::arena_sparse_rounds`.
    pub fn drain_sparse_rounds(&mut self) -> u64 {
        std::mem::take(&mut self.sparse_rounds)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sparse_user(dim: u32, pairs: &[(u32, f32)], weight: f64) -> Statistics {
        Statistics::new_update_value(
            StatValue::sparse(
                dim,
                pairs.iter().map(|p| p.0).collect(),
                pairs.iter().map(|p| p.1).collect(),
            ),
            weight,
        )
    }

    #[test]
    fn fold_matches_sum_aggregator() {
        use crate::fl::aggregator::{Aggregator, SumAggregator};
        let users: Vec<Statistics> = (0..5)
            .map(|u| {
                let mut s = Statistics::new_update(vec![u as f32, 1.0, -2.0], 1.0 + u as f64);
                if u % 2 == 0 {
                    s.insert("extra", vec![u as f32; 2]);
                }
                s
            })
            .collect();

        let mut arena = StatsArena::new();
        for u in &users {
            arena.fold(u);
        }
        let a = arena.take_partial().unwrap();

        let agg = SumAggregator;
        let mut acc = None;
        for u in users {
            agg.accumulate(&mut acc, u);
        }
        let b = acc.unwrap();

        assert_eq!(a.weight, b.weight);
        assert_eq!(a.update(), b.update());
        assert_eq!(a.get("extra"), b.get("extra"));
    }

    #[test]
    fn sparse_and_dense_fold_together() {
        let mut arena = StatsArena::new();
        arena.fold(&Statistics::new_update(vec![1.0; 4], 1.0));
        arena.fold(&sparse_user(4, &[(0, 2.0), (3, -1.0)], 1.0));
        let p = arena.take_partial().unwrap();
        assert_eq!(p.update(), &[3.0, 1.0, 1.0, 0.0]);
        assert_eq!(p.weight, 2.0);
        // a dense contribution is a spill
        assert_eq!(arena.drain_spill_count(), 0, "dense-first round never spills");
        assert_eq!(arena.drain_sparse_rounds(), 0);
    }

    #[test]
    fn all_sparse_round_stays_sparse_below_threshold() {
        // 1 nnz of 16 is far below the default 0.25 threshold: the round
        // must emit a sparse partial and never touch a dense buffer
        let mut arena = StatsArena::new();
        arena.fold(&sparse_user(16, &[(5, 1.0)], 1.0));
        arena.fold(&sparse_user(16, &[(9, 2.0)], 1.0));
        let p = arena.take_partial().unwrap();
        let v = p.update_value().unwrap();
        assert!(matches!(v, StatValue::Sparse { .. }), "partial densified: {v:?}");
        assert_eq!(v.element_count(), 2);
        assert_eq!(v.to_dense_vec()[5], 1.0);
        assert_eq!(v.to_dense_vec()[9], 2.0);
        assert_eq!(arena.drain_spill_count(), 0);
        assert_eq!(arena.drain_sparse_rounds(), 1);
    }

    #[test]
    fn union_nnz_crossing_threshold_spills_mid_round() {
        let mut arena = StatsArena::with_config(ArenaConfig { sparse_spill_frac: 0.5 });
        // dim 8, threshold = 4 nnz: two disjoint 3-nnz users cross it
        arena.fold(&sparse_user(8, &[(0, 1.0), (1, 1.0), (2, 1.0)], 1.0));
        arena.fold(&sparse_user(8, &[(5, 1.0), (6, 1.0), (7, 1.0)], 1.0));
        // the slot is dense now; more sparse folds scatter in place
        arena.fold(&sparse_user(8, &[(0, 1.0)], 1.0));
        let p = arena.take_partial().unwrap();
        assert!(p.update_value().unwrap().as_dense().is_some());
        assert_eq!(p.update(), &[2.0, 1.0, 1.0, 0.0, 0.0, 1.0, 1.0, 1.0]);
        assert_eq!(p.weight, 3.0);
        assert_eq!(arena.drain_spill_count(), 1);
        assert_eq!(arena.drain_sparse_rounds(), 0);
    }

    #[test]
    fn spill_frac_zero_recovers_dense_behavior() {
        let mut arena = StatsArena::with_config(ArenaConfig { sparse_spill_frac: 0.0 });
        arena.fold(&sparse_user(6, &[(5, 1.0)], 1.0));
        let p = arena.take_partial().unwrap();
        assert_eq!(p.update().len(), 6);
        assert_eq!(p.update()[5], 1.0);
        assert_eq!(arena.drain_spill_count(), 1);
    }

    #[test]
    fn steady_state_needs_no_growth() {
        let mut arena = StatsArena::new();
        let user = Statistics::new_update(vec![1.0; 128], 1.0);
        arena.fold(&user);
        assert!(arena.drain_grown_bytes() > 0); // first round sizes slots
        arena.take_partial().unwrap();
        for _ in 0..3 {
            arena.fold(&user);
            arena.fold(&user);
            assert_eq!(arena.drain_grown_bytes(), 0, "steady-state fold must not grow");
            let p = arena.take_partial().unwrap();
            assert_eq!(p.update(), &[2.0f32; 128][..]);
        }
    }

    #[test]
    fn sparse_steady_state_needs_no_growth() {
        // all-sparse regime: after the ping-pong buffers size themselves,
        // repeated rounds of the same cohort shape allocate nothing
        let mut arena = StatsArena::new();
        let users: Vec<Statistics> = (0..4)
            .map(|u| sparse_user(1024, &[(u * 7, 1.0), (u * 7 + 3, -1.0)], 1.0))
            .collect();
        for u in &users {
            arena.fold(u);
        }
        arena.drain_grown_bytes();
        arena.take_partial().unwrap();
        for round in 0..3 {
            for u in &users {
                arena.fold(u);
            }
            assert_eq!(
                arena.drain_grown_bytes(),
                0,
                "round {round}: sparse steady-state fold must not grow"
            );
            let p = arena.take_partial().unwrap();
            let v = p.update_value().unwrap();
            assert!(matches!(v, StatValue::Sparse { .. }));
            assert_eq!(v.element_count(), 8);
        }
        assert_eq!(arena.drain_spill_count(), 0);
        assert_eq!(arena.drain_sparse_rounds(), 4);
    }

    #[test]
    fn spilled_slot_rearms_sparse_next_round() {
        // the sparse-first lifecycle restarts every round, so one dense
        // round does not condemn later all-sparse rounds to dense
        let mut arena = StatsArena::new();
        arena.fold(&Statistics::new_update(vec![1.0; 8], 1.0));
        arena.take_partial().unwrap();
        arena.fold(&sparse_user(8, &[(2, 4.0)], 1.0));
        let p = arena.take_partial().unwrap();
        assert!(matches!(p.update_value().unwrap(), StatValue::Sparse { .. }));
        assert_eq!(p.update_value().unwrap().to_dense_vec()[2], 4.0);
    }

    #[test]
    fn quantized_dense_contribution_decodes_into_dense_slot() {
        let mut arena = StatsArena::new();
        let raw = vec![1.0f32, -2.0, 0.5, 4.0];
        let q = StatValue::Dense(raw.clone()).quantize(16); // f16 exact here
        arena.fold(&Statistics::new_update_value(q.clone(), 1.0));
        arena.fold(&Statistics::new_update_value(q, 1.0));
        let p = arena.take_partial().unwrap();
        assert!(p.update_value().unwrap().as_dense().is_some());
        assert_eq!(p.update(), &[2.0, -4.0, 1.0, 8.0]);
        assert_eq!(p.weight, 2.0);
    }

    #[test]
    fn quantized_sparse_contribution_stays_sparse_and_allocs_nothing_in_steady_state() {
        let mut arena = StatsArena::new();
        let users: Vec<Statistics> = (0..4)
            .map(|u| {
                let s = StatValue::sparse(1024, vec![u * 7, u * 7 + 3], vec![1.0, -1.0]);
                Statistics::new_update_value(s.quantize(16), 1.0)
            })
            .collect();
        for u in &users {
            arena.fold(u);
        }
        arena.drain_grown_bytes();
        let p = arena.take_partial().unwrap();
        let v = p.update_value().unwrap();
        assert!(matches!(v, StatValue::Sparse { .. }), "quantized-sparse densified: {v:?}");
        assert_eq!(v.element_count(), 8);
        assert_eq!(v.to_dense_vec()[0], 1.0);
        for round in 0..3 {
            for u in &users {
                arena.fold(u);
            }
            assert_eq!(arena.drain_grown_bytes(), 0, "round {round}: decode scratch grew");
            arena.take_partial().unwrap();
        }
        assert_eq!(arena.drain_spill_count(), 0);
    }

    #[test]
    fn quantized_fold_matches_direct_sum() {
        use crate::fl::aggregator::{Aggregator, SumAggregator};
        let users: Vec<Statistics> = (0..5)
            .map(|u| {
                let v: Vec<f32> = (0..16).map(|i| ((u * 16 + i) as f32).sin()).collect();
                Statistics::new_update_value(StatValue::Dense(v).quantize(8), 1.0)
            })
            .collect();
        let mut arena = StatsArena::new();
        for u in &users {
            arena.fold(u);
        }
        let a = arena.take_partial().unwrap();
        let agg = SumAggregator;
        let mut acc = None;
        for u in users {
            agg.accumulate(&mut acc, u);
        }
        let b = acc.unwrap();
        assert_eq!(a.weight, b.weight);
        assert_eq!(a.update(), b.update(), "arena decode must match accumulate decode");
    }

    #[test]
    fn empty_round_yields_none() {
        let mut arena = StatsArena::new();
        assert!(arena.take_partial().is_none());
        arena.fold(&Statistics::new_update(vec![1.0], 1.0));
        assert!(arena.take_partial().is_some());
        // arena re-armed: next empty round is again None
        assert!(arena.take_partial().is_none());
    }
}
