//! The unified tensor/statistics layer.
//!
//! Every model-sized vector in the simulator — user updates, worker
//! partials, control variates, DP noise buffers — flows through this
//! module. It exists so the hot loop stays free of model-sized
//! allocations and so new statistic shapes (sparse LoRA adapters, GBDT
//! histograms) drop into aggregation, privacy and the worker path
//! without touching the runtime (paper §3.1, App. B.2).
//!
//! # Architecture
//!
//! Three pieces, stacked bottom-up:
//!
//! * [`ops`] — the scalar/SIMD kernel layer. Chunked, auto-vectorizable
//!   implementations of the vector math every other layer uses:
//!   [`ops::add_assign`], [`ops::axpy`], [`ops::scale`],
//!   [`ops::sub_into`], [`ops::l2_norm`], [`ops::l1_norm`],
//!   [`ops::l2_clip`], [`ops::l1_clip`], [`ops::scatter_add`],
//!   [`ops::scatter_axpy`], [`ops::add_gaussian_noise`],
//!   [`ops::add_laplace_noise`]. This is the **only** place in the crate
//!   that writes raw `f32` arithmetic loops; `crate::util` re-exports
//!   the common names for backwards compatibility, and `fl/` +
//!   `privacy/` call them via either path.
//!
//! * [`value`] — [`StatValue`], the statistic payload: `Dense(Vec<f32>)`
//!   or `Sparse { dim, idx, val }` (sorted unique `idx`). Sums of any
//!   mix of shapes are well-defined and order-independent (sparse+sparse
//!   stays sparse via a sorted merge; any dense operand densifies the
//!   result), which preserves the aggregator exchange law — see the
//!   randomized property tests in `rust/tests/property_invariants.rs`.
//!   `axpy_value` is the scaled variant backing the staleness-discounted
//!   async fold without materializing scaled copies.
//!
//! * [`arena`] — [`StatsArena`], the worker-local accumulation arena.
//!   Per-key slots that persist across rounds; `fold` adds a user's
//!   statistics **by reference** instead of moving/inserting per-user
//!   `Vec`s into a fresh accumulator. Each slot starts a round as a
//!   **sorted-merge sparse accumulator** and spills to its resident
//!   dense buffer only when a dense contribution arrives or the union
//!   nnz crosses [`ArenaConfig::sparse_spill_frac`] · dim — so an
//!   all-sparse cohort (GBDT histograms, top-k LoRA) finishes the round
//!   without ever allocating a model-sized buffer, and its partial
//!   leaves the worker sparse. Spills and all-sparse rounds are counted
//!   (`Counters::{arena_spill_count, arena_sparse_rounds}`). This is
//!   what makes the `Counters::loop_alloc_bytes == 0` steady-state
//!   invariant hold under aggregation: after the first round sizes the
//!   slots (dense buffers and sparse ping-pong merge buffers alike), the
//!   per-user loop performs zero heap allocation (arena growth is
//!   reported separately via `Counters::arena_grow_bytes`).
//!
//! # Who uses what
//!
//! * `fl::stats::Statistics` stores `BTreeMap<String, StatValue>`.
//! * `fl::worker` folds each user's statistics into its `StatsArena`
//!   whenever the aggregator is arena-compatible (plain summation), and
//!   hands one partial per round — sparse when every slot stayed sparse
//!   — to `worker_reduce`.
//! * `fl::aggregator::SumAggregator` uses `StatValue::add_value` for the
//!   reduce and `StatValue::axpy_value` for the staleness-weighted async
//!   fold, so dense and sparse partials mix freely without densifying.
//! * `fl::backend::run_async` optionally replays arrivals through a
//!   bounded reorder buffer (`DispatchSpec::reorder_window`) that
//!   releases results in dispatch (round, uid) order, making async runs
//!   bit-identical across worker counts.
//! * `privacy::mechanisms` and `fl::postprocess` clip/scale/noise
//!   through `ops`, densifying sparse aggregates only where a mechanism
//!   mathematically requires full coverage (additive noise).

pub mod arena;
pub mod ops;
pub mod value;

pub use arena::{ArenaConfig, StatsArena};
pub use value::StatValue;
