//! SIMD-friendly chunked vector kernels — the single home of raw `f32`
//! arithmetic in the crate.
//!
//! Every function processes fixed-width lanes (`LANES` elements) through
//! plain indexed loops over `chunks_exact`, the shape LLVM reliably
//! auto-vectorizes on stable Rust without `unsafe` or intrinsics, plus a
//! short scalar tail. Norm reductions accumulate in `f64` across
//! independent partial sums so vectorization is not serialized by a
//! single dependency chain.
//!
//! Callers: `fl::aggregator` / `tensor::arena` (accumulate),
//! `fl::postprocess` + `privacy::mechanisms` (clip / noise / quantize),
//! `fl::algorithm` (SCAFFOLD control variates), `fl::central_opt`
//! (central step), and `crate::util`, which re-exports the common names.

use crate::util::rng::Rng;

/// Lane width the kernels are written for (f32x8 — one AVX2 register).
pub const LANES: usize = 8;

/// y += x (the aggregation hot path).
#[inline]
pub fn add_assign(y: &mut [f32], x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    let n = y.len().min(x.len());
    let split = n - n % LANES;
    let (yh, yt) = y[..n].split_at_mut(split);
    let (xh, xt) = x[..n].split_at(split);
    for (ys, xs) in yh.chunks_exact_mut(LANES).zip(xh.chunks_exact(LANES)) {
        for i in 0..LANES {
            ys[i] += xs[i];
        }
    }
    for (a, b) in yt.iter_mut().zip(xt) {
        *a += *b;
    }
}

/// y += s * x
#[inline]
pub fn axpy(y: &mut [f32], s: f32, x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    let n = y.len().min(x.len());
    let split = n - n % LANES;
    let (yh, yt) = y[..n].split_at_mut(split);
    let (xh, xt) = x[..n].split_at(split);
    for (ys, xs) in yh.chunks_exact_mut(LANES).zip(xh.chunks_exact(LANES)) {
        for i in 0..LANES {
            ys[i] += s * xs[i];
        }
    }
    for (a, b) in yt.iter_mut().zip(xt) {
        *a += s * *b;
    }
}

/// y *= s
#[inline]
pub fn scale(y: &mut [f32], s: f32) {
    for chunk in y.chunks_exact_mut(LANES) {
        for v in chunk {
            *v *= s;
        }
    }
    let tail = y.len() - y.len() % LANES;
    for v in &mut y[tail..] {
        *v *= s;
    }
}

/// out = a - b
#[inline]
pub fn sub_into(out: &mut [f32], a: &[f32], b: &[f32]) {
    debug_assert_eq!(out.len(), a.len());
    debug_assert_eq!(out.len(), b.len());
    let n = out.len().min(a.len()).min(b.len());
    for i in 0..n {
        out[i] = a[i] - b[i];
    }
}

/// y -= x
#[inline]
pub fn sub_assign(y: &mut [f32], x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    let n = y.len().min(x.len());
    for i in 0..n {
        y[i] -= x[i];
    }
}

/// y = a - y (in-place reversed subtraction; the Δ = θ − θ′ shape that
/// reuses the trained buffer as the update).
#[inline]
pub fn sub_rev_assign(y: &mut [f32], a: &[f32]) {
    debug_assert_eq!(y.len(), a.len());
    let n = y.len().min(a.len());
    for i in 0..n {
        y[i] = a[i] - y[i];
    }
}

/// One fused (Fed)Adam step over flat buffers (Reddi et al.; τ plays
/// epsilon's role as the adaptivity degree):
/// m ← β₁m + (1−β₁)g, v ← β₂v + (1−β₂)g², θ −= step·m̂/(√v̂ + τ)
/// with m̂ = m/bc₁, v̂ = v/bc₂.
#[allow(clippy::too_many_arguments)]
pub fn adam_step(
    params: &mut [f32],
    delta: &[f32],
    m: &mut [f32],
    v: &mut [f32],
    b1: f32,
    b2: f32,
    bc1: f32,
    bc2: f32,
    tau: f32,
    step: f32,
) {
    debug_assert_eq!(params.len(), delta.len());
    debug_assert_eq!(params.len(), m.len());
    debug_assert_eq!(params.len(), v.len());
    let n = params.len().min(delta.len()).min(m.len()).min(v.len());
    for i in 0..n {
        let g = delta[i];
        m[i] = b1 * m[i] + (1.0 - b1) * g;
        v[i] = b2 * v[i] + (1.0 - b2) * g * g;
        let mhat = m[i] / bc1;
        let vhat = v[i] / bc2;
        params[i] -= step * mhat / (vhat.sqrt() + tau);
    }
}

/// Σ v², accumulated in f64 across `LANES` independent partial sums.
#[inline]
pub fn l2_norm_sq(v: &[f32]) -> f64 {
    let mut acc = [0f64; LANES];
    let chunks = v.chunks_exact(LANES);
    let tail = chunks.remainder();
    for chunk in chunks {
        for i in 0..LANES {
            let x = chunk[i] as f64;
            acc[i] += x * x;
        }
    }
    let mut s: f64 = acc.iter().sum();
    for &x in tail {
        s += (x as f64) * (x as f64);
    }
    s
}

/// L2 norm (f64 accumulation).
#[inline]
pub fn l2_norm(v: &[f32]) -> f64 {
    l2_norm_sq(v).sqrt()
}

/// L1 norm (f64 accumulation).
#[inline]
pub fn l1_norm(v: &[f32]) -> f64 {
    let mut acc = [0f64; LANES];
    let chunks = v.chunks_exact(LANES);
    let tail = chunks.remainder();
    for chunk in chunks {
        for i in 0..LANES {
            acc[i] += chunk[i].abs() as f64;
        }
    }
    let mut s: f64 = acc.iter().sum();
    for &x in tail {
        s += x.abs() as f64;
    }
    s
}

/// Clip `v` to L2 norm `bound` in place; returns the pre-clip norm.
/// Semantics match the L1 Pallas `clip_scale` kernel (`RustClip` is the
/// oracle in `runtime_integration.rs`).
#[inline]
pub fn l2_clip(v: &mut [f32], bound: f32) -> f64 {
    let norm = l2_norm(v);
    if norm > bound as f64 && norm > 0.0 {
        scale(v, (bound as f64 / norm) as f32);
    }
    norm
}

/// Clip `v` to L1 norm `bound` in place; returns the pre-clip L1 norm.
#[inline]
pub fn l1_clip(v: &mut [f32], bound: f32) -> f64 {
    let norm = l1_norm(v);
    if norm > bound as f64 && norm > 0.0 {
        scale(v, (bound as f64 / norm) as f32);
    }
    norm
}

/// y[idx[j]] += val[j] — the sparse-statistic fold. Indices must be in
/// bounds; `StatValue` guarantees `idx < dim` and callers size `y` to
/// the sparse value's `dim`.
#[inline]
pub fn scatter_add(y: &mut [f32], idx: &[u32], val: &[f32]) {
    debug_assert_eq!(idx.len(), val.len());
    for (i, v) in idx.iter().zip(val) {
        y[*i as usize] += *v;
    }
}

/// y[idx[j]] += s * val[j] — the scaled sparse fold (async buffered
/// aggregation discounts stale sparse arrivals by the staleness weight
/// without materializing a scaled copy). Index contract as
/// [`scatter_add`].
#[inline]
pub fn scatter_axpy(y: &mut [f32], s: f32, idx: &[u32], val: &[f32]) {
    debug_assert_eq!(idx.len(), val.len());
    for (i, v) in idx.iter().zip(val) {
        y[*i as usize] += s * *v;
    }
}

/// Add iid N(0, std²) noise to `v` in place; returns the noise L2 norm
/// (for SNR diagnostics, paper Fig. 6).
pub fn add_gaussian_noise(v: &mut [f32], std: f64, rng: &mut Rng) -> f64 {
    if std <= 0.0 {
        return 0.0;
    }
    let mut sq = 0f64;
    for x in v.iter_mut() {
        let n = rng.normal() * std;
        sq += n * n;
        *x += n as f32;
    }
    sq.sqrt()
}

/// Add iid Laplace(0, scale) noise to `v` in place; returns the noise L2
/// norm.
pub fn add_laplace_noise(v: &mut [f32], scale: f64, rng: &mut Rng) -> f64 {
    if scale <= 0.0 {
        return 0.0;
    }
    let mut sq = 0f64;
    for x in v.iter_mut() {
        let n = rng.laplace(scale);
        sq += n * n;
        *x += n as f32;
    }
    sq.sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunked_ops_match_scalar_reference() {
        // lengths straddling the lane width, including 0 and tails
        for n in [0usize, 1, 7, 8, 9, 16, 31, 100] {
            let a: Vec<f32> = (0..n).map(|i| i as f32 * 0.5 - 3.0).collect();
            let b: Vec<f32> = (0..n).map(|i| (i as f32).sin()).collect();

            let mut y = a.clone();
            add_assign(&mut y, &b);
            for i in 0..n {
                assert_eq!(y[i], a[i] + b[i]);
            }

            let mut y = a.clone();
            axpy(&mut y, 2.5, &b);
            for i in 0..n {
                assert!((y[i] - (a[i] + 2.5 * b[i])).abs() < 1e-6);
            }

            let mut y = a.clone();
            scale(&mut y, -0.25);
            for i in 0..n {
                assert_eq!(y[i], a[i] * -0.25);
            }

            let mut out = vec![0.0; n];
            sub_into(&mut out, &a, &b);
            for i in 0..n {
                assert_eq!(out[i], a[i] - b[i]);
            }

            let mut y = a.clone();
            sub_assign(&mut y, &b);
            for i in 0..n {
                assert_eq!(y[i], a[i] - b[i]);
            }

            let mut y = b.clone();
            sub_rev_assign(&mut y, &a);
            for i in 0..n {
                assert_eq!(y[i], a[i] - b[i]);
            }

            let ref_l2: f64 = a.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>().sqrt();
            assert!((l2_norm(&a) - ref_l2).abs() < 1e-9 * ref_l2.max(1.0));
            let ref_l1: f64 = a.iter().map(|x| x.abs() as f64).sum();
            assert!((l1_norm(&a) - ref_l1).abs() < 1e-9 * ref_l1.max(1.0));
        }
    }

    #[test]
    fn clips_bound_norms() {
        let mut v = vec![3.0f32, 4.0];
        let pre = l2_clip(&mut v, 1.0);
        assert!((pre - 5.0).abs() < 1e-6);
        assert!((l2_norm(&v) - 1.0).abs() < 1e-6);

        let mut u = vec![1.0f32, -1.0, 2.0];
        let pre = l1_clip(&mut u, 1.0);
        assert!((pre - 4.0).abs() < 1e-6);
        assert!((l1_norm(&u) - 1.0).abs() < 1e-6);

        // below the bound: untouched
        let mut w = vec![0.3f32, 0.4];
        l2_clip(&mut w, 1.0);
        assert_eq!(w, vec![0.3, 0.4]);
    }

    #[test]
    fn adam_step_matches_reference() {
        let (b1, b2, tau, step) = (0.9f32, 0.99, 0.1, 0.1);
        let delta = [1.0f32, -2.0, 0.5];
        let mut params = [0.0f32; 3];
        let mut m = [0.0f32; 3];
        let mut v = [0.0f32; 3];
        let (bc1, bc2) = (1.0 - b1, 1.0 - b2); // t = 1
        adam_step(&mut params, &delta, &mut m, &mut v, b1, b2, bc1, bc2, tau, step);
        for i in 0..3 {
            let g = delta[i];
            let mhat = ((1.0 - b1) * g) / bc1; // = g at t=1
            let vhat = ((1.0 - b2) * g * g) / bc2; // = g² at t=1
            let expect = -step * mhat / (vhat.sqrt() + tau);
            assert!((params[i] - expect).abs() < 1e-6, "{} vs {}", params[i], expect);
        }
    }

    #[test]
    fn scatter_add_hits_indices() {
        let mut y = vec![0.0f32; 6];
        scatter_add(&mut y, &[1, 3, 5], &[1.0, 2.0, 3.0]);
        assert_eq!(y, vec![0.0, 1.0, 0.0, 2.0, 0.0, 3.0]);
        scatter_add(&mut y, &[1], &[0.5]);
        assert_eq!(y[1], 1.5);
    }

    #[test]
    fn scatter_axpy_scales_contributions() {
        let mut y = vec![1.0f32; 4];
        scatter_axpy(&mut y, 0.5, &[0, 2], &[2.0, -4.0]);
        assert_eq!(y, vec![2.0, 1.0, -1.0, 1.0]);
        // scale 1 degenerates to scatter_add
        let mut z = vec![0.0f32; 3];
        scatter_axpy(&mut z, 1.0, &[1], &[3.0]);
        assert_eq!(z, vec![0.0, 3.0, 0.0]);
    }

    #[test]
    fn noise_magnitudes() {
        let mut rng = Rng::seed_from_u64(3);
        let mut v = vec![0.0f32; 20_000];
        let norm = add_gaussian_noise(&mut v, 2.0, &mut rng);
        let expect = (20_000f64).sqrt() * 2.0; // E‖noise‖ = √d·σ
        assert!((norm / expect - 1.0).abs() < 0.05, "{norm} vs {expect}");
        // zero std is a no-op
        let mut w = vec![1.0f32; 4];
        assert_eq!(add_gaussian_noise(&mut w, 0.0, &mut rng), 0.0);
        assert_eq!(w, vec![1.0; 4]);
        assert_eq!(add_laplace_noise(&mut w, 0.0, &mut rng), 0.0);
        // laplace noise perturbs
        let mut u = vec![0.0f32; 1000];
        let n = add_laplace_noise(&mut u, 1.0, &mut rng);
        assert!(n > 0.0);
        assert!(u.iter().any(|x| *x != 0.0));
    }
}
