//! SIMD-friendly chunked vector kernels — the single home of raw `f32`
//! arithmetic in the crate.
//!
//! Every function processes fixed-width lanes (`LANES` elements) through
//! plain indexed loops over `chunks_exact`, the shape LLVM reliably
//! auto-vectorizes on stable Rust without `unsafe` or intrinsics, plus a
//! short scalar tail. Norm reductions accumulate in `f64` across
//! independent partial sums so vectorization is not serialized by a
//! single dependency chain.
//!
//! Callers: `fl::aggregator` / `tensor::arena` (accumulate),
//! `fl::postprocess` + `privacy::mechanisms` (clip / noise / quantize),
//! `fl::algorithm` (SCAFFOLD control variates), `fl::central_opt`
//! (central step), and `crate::util`, which re-exports the common names.

use crate::util::rng::{CtrRng, Rng, CTR_BLOCK};

/// Lane width the kernels are written for (f32x8 — one AVX2 register).
pub const LANES: usize = 8;

/// y += x (the aggregation hot path).
#[inline]
pub fn add_assign(y: &mut [f32], x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    let n = y.len().min(x.len());
    let split = n - n % LANES;
    let (yh, yt) = y[..n].split_at_mut(split);
    let (xh, xt) = x[..n].split_at(split);
    for (ys, xs) in yh.chunks_exact_mut(LANES).zip(xh.chunks_exact(LANES)) {
        for i in 0..LANES {
            ys[i] += xs[i];
        }
    }
    for (a, b) in yt.iter_mut().zip(xt) {
        *a += *b;
    }
}

/// y += s * x
#[inline]
pub fn axpy(y: &mut [f32], s: f32, x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    let n = y.len().min(x.len());
    let split = n - n % LANES;
    let (yh, yt) = y[..n].split_at_mut(split);
    let (xh, xt) = x[..n].split_at(split);
    for (ys, xs) in yh.chunks_exact_mut(LANES).zip(xh.chunks_exact(LANES)) {
        for i in 0..LANES {
            ys[i] += s * xs[i];
        }
    }
    for (a, b) in yt.iter_mut().zip(xt) {
        *a += s * *b;
    }
}

/// y *= s
#[inline]
pub fn scale(y: &mut [f32], s: f32) {
    for chunk in y.chunks_exact_mut(LANES) {
        for v in chunk {
            *v *= s;
        }
    }
    let tail = y.len() - y.len() % LANES;
    for v in &mut y[tail..] {
        *v *= s;
    }
}

/// out = a - b
#[inline]
pub fn sub_into(out: &mut [f32], a: &[f32], b: &[f32]) {
    debug_assert_eq!(out.len(), a.len());
    debug_assert_eq!(out.len(), b.len());
    let n = out.len().min(a.len()).min(b.len());
    for i in 0..n {
        out[i] = a[i] - b[i];
    }
}

/// y -= x
#[inline]
pub fn sub_assign(y: &mut [f32], x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    let n = y.len().min(x.len());
    for i in 0..n {
        y[i] -= x[i];
    }
}

/// y = a - y (in-place reversed subtraction; the Δ = θ − θ′ shape that
/// reuses the trained buffer as the update).
#[inline]
pub fn sub_rev_assign(y: &mut [f32], a: &[f32]) {
    debug_assert_eq!(y.len(), a.len());
    let n = y.len().min(a.len());
    for i in 0..n {
        y[i] = a[i] - y[i];
    }
}

/// One fused (Fed)Adam step over flat buffers (Reddi et al.; τ plays
/// epsilon's role as the adaptivity degree):
/// m ← β₁m + (1−β₁)g, v ← β₂v + (1−β₂)g², θ −= step·m̂/(√v̂ + τ)
/// with m̂ = m/bc₁, v̂ = v/bc₂.
#[allow(clippy::too_many_arguments)]
pub fn adam_step(
    params: &mut [f32],
    delta: &[f32],
    m: &mut [f32],
    v: &mut [f32],
    b1: f32,
    b2: f32,
    bc1: f32,
    bc2: f32,
    tau: f32,
    step: f32,
) {
    debug_assert_eq!(params.len(), delta.len());
    debug_assert_eq!(params.len(), m.len());
    debug_assert_eq!(params.len(), v.len());
    let n = params.len().min(delta.len()).min(m.len()).min(v.len());
    for i in 0..n {
        let g = delta[i];
        m[i] = b1 * m[i] + (1.0 - b1) * g;
        v[i] = b2 * v[i] + (1.0 - b2) * g * g;
        let mhat = m[i] / bc1;
        let vhat = v[i] / bc2;
        params[i] -= step * mhat / (vhat.sqrt() + tau);
    }
}

/// Σ v², accumulated in f64 across `LANES` independent partial sums.
#[inline]
pub fn l2_norm_sq(v: &[f32]) -> f64 {
    let mut acc = [0f64; LANES];
    let chunks = v.chunks_exact(LANES);
    let tail = chunks.remainder();
    for chunk in chunks {
        for i in 0..LANES {
            let x = chunk[i] as f64;
            acc[i] += x * x;
        }
    }
    let mut s: f64 = acc.iter().sum();
    for &x in tail {
        s += (x as f64) * (x as f64);
    }
    s
}

/// L2 norm (f64 accumulation).
#[inline]
pub fn l2_norm(v: &[f32]) -> f64 {
    l2_norm_sq(v).sqrt()
}

/// L1 norm (f64 accumulation).
#[inline]
pub fn l1_norm(v: &[f32]) -> f64 {
    let mut acc = [0f64; LANES];
    let chunks = v.chunks_exact(LANES);
    let tail = chunks.remainder();
    for chunk in chunks {
        for i in 0..LANES {
            acc[i] += chunk[i].abs() as f64;
        }
    }
    let mut s: f64 = acc.iter().sum();
    for &x in tail {
        s += x.abs() as f64;
    }
    s
}

/// Clip `v` to L2 norm `bound` in place; returns the pre-clip norm.
/// Semantics match the L1 Pallas `clip_scale` kernel (`RustClip` is the
/// oracle in `runtime_integration.rs`).
#[inline]
pub fn l2_clip(v: &mut [f32], bound: f32) -> f64 {
    let norm = l2_norm(v);
    if norm > bound as f64 && norm > 0.0 {
        scale(v, (bound as f64 / norm) as f32);
    }
    norm
}

/// Clip `v` to L1 norm `bound` in place; returns the pre-clip L1 norm.
#[inline]
pub fn l1_clip(v: &mut [f32], bound: f32) -> f64 {
    let norm = l1_norm(v);
    if norm > bound as f64 && norm > 0.0 {
        scale(v, (bound as f64 / norm) as f32);
    }
    norm
}

/// y[idx[j]] += val[j] — the sparse-statistic fold. Indices must be in
/// bounds; `StatValue` guarantees `idx < dim` and callers size `y` to
/// the sparse value's `dim`.
#[inline]
pub fn scatter_add(y: &mut [f32], idx: &[u32], val: &[f32]) {
    debug_assert_eq!(idx.len(), val.len());
    for (i, v) in idx.iter().zip(val) {
        y[*i as usize] += *v;
    }
}

/// y[idx[j]] += s * val[j] — the scaled sparse fold (async buffered
/// aggregation discounts stale sparse arrivals by the staleness weight
/// without materializing a scaled copy). Index contract as
/// [`scatter_add`].
#[inline]
pub fn scatter_axpy(y: &mut [f32], s: f32, idx: &[u32], val: &[f32]) {
    debug_assert_eq!(idx.len(), val.len());
    for (i, v) in idx.iter().zip(val) {
        y[*i as usize] += s * *v;
    }
}

// ----------------------------------------------------------------------
// Wire quantization kernels (f16 / int8-with-scale)
// ----------------------------------------------------------------------

/// max |v| over the buffer (chunked; NaN-free inputs assumed, matching
/// the rest of the kernel layer).
#[inline]
pub fn max_abs(v: &[f32]) -> f32 {
    let mut acc = [0f32; LANES];
    let chunks = v.chunks_exact(LANES);
    let tail = chunks.remainder();
    for chunk in chunks {
        for i in 0..LANES {
            acc[i] = acc[i].max(chunk[i].abs());
        }
    }
    let mut m = 0f32;
    for a in acc {
        m = m.max(a);
    }
    for &x in tail {
        m = m.max(x.abs());
    }
    m
}

/// Encode one f32 as IEEE 754 binary16 bits (round-to-nearest-even,
/// overflow to ±inf, subnormal and NaN preserved). No `half` crate in
/// the offline build — this is the crate's single f16 codec.
#[inline]
pub fn f16_encode(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let mant = bits & 0x007f_ffff;
    if exp == 0xff {
        // inf / NaN (keep NaN signaling-agnostic via a quiet mantissa bit)
        return sign | 0x7c00 | if mant != 0 { 0x0200 } else { 0 };
    }
    let e = exp - 127 + 15;
    if e >= 0x1f {
        return sign | 0x7c00; // overflow -> inf
    }
    if e <= 0 {
        if e < -10 {
            return sign; // underflow -> signed zero
        }
        // subnormal: shift the (implicit-bit) mantissa into 10 bits
        let m = mant | 0x0080_0000;
        let shift = (14 - e) as u32;
        let half = 1u32 << (shift - 1);
        let rem = m & ((1u32 << shift) - 1);
        let mut v = m >> shift;
        if rem > half || (rem == half && v & 1 == 1) {
            v += 1;
        }
        return sign | v as u16;
    }
    // normal: round 23-bit mantissa to 10 bits, nearest-even; a mantissa
    // carry rolls into the exponent (and saturates to inf) by encoding
    let mut v = ((e as u32) << 10) | (mant >> 13);
    let rem = mant & 0x1fff;
    if rem > 0x1000 || (rem == 0x1000 && v & 1 == 1) {
        v += 1;
    }
    sign | v as u16
}

/// Decode IEEE 754 binary16 bits to f32 (exact — every f16 is an f32).
#[inline]
pub fn f16_decode(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let mant = (h & 0x03ff) as u32;
    if exp == 0 {
        // zero / subnormal: mant · 2⁻²⁴ (exact in f32)
        let v = mant as f32 * (1.0 / 16_777_216.0);
        return if sign != 0 { -v } else { v };
    }
    let bits = if exp == 0x1f {
        sign | 0x7f80_0000 | (mant << 13)
    } else {
        sign | ((exp + 127 - 15) << 23) | (mant << 13)
    };
    f32::from_bits(bits)
}

/// Quantize `src` to f16 wire bytes (little-endian u16 per element,
/// 2 bytes/elem), replacing `out`'s contents.
pub fn quantize_f16(src: &[f32], out: &mut Vec<u8>) {
    out.clear();
    out.reserve(src.len() * 2);
    let chunks = src.chunks_exact(LANES);
    let tail = chunks.remainder();
    for chunk in chunks {
        let mut enc = [0u16; LANES];
        for i in 0..LANES {
            enc[i] = f16_encode(chunk[i]);
        }
        for h in enc {
            out.extend_from_slice(&h.to_le_bytes());
        }
    }
    for &x in tail {
        out.extend_from_slice(&f16_encode(x).to_le_bytes());
    }
}

/// Decode f16 wire bytes back to f32, replacing `out`'s contents.
pub fn dequantize_f16(data: &[u8], out: &mut Vec<f32>) {
    out.clear();
    out.reserve(data.len() / 2);
    for c in data.chunks_exact(2) {
        out.push(f16_decode(u16::from_le_bytes([c[0], c[1]])));
    }
}

/// y[i] += s · decode(data[i]) — fused f16 dequantize-accumulate, no
/// intermediate f32 buffer.
pub fn dequant_axpy_f16(y: &mut [f32], s: f32, data: &[u8]) {
    let n = y.len().min(data.len() / 2);
    let split = n - n % LANES;
    let (yh, yt) = y[..n].split_at_mut(split);
    let (dh, dt) = data[..n * 2].split_at(split * 2);
    for (ys, ds) in yh.chunks_exact_mut(LANES).zip(dh.chunks_exact(2 * LANES)) {
        for i in 0..LANES {
            ys[i] += s * f16_decode(u16::from_le_bytes([ds[2 * i], ds[2 * i + 1]]));
        }
    }
    for (yv, c) in yt.iter_mut().zip(dt.chunks_exact(2)) {
        *yv += s * f16_decode(u16::from_le_bytes([c[0], c[1]]));
    }
}

/// y[idx[j]] += s · decode(data[j]) — sparse fused f16 accumulate.
/// Index contract as [`scatter_add`].
pub fn dequant_scatter_axpy_f16(y: &mut [f32], s: f32, idx: &[u32], data: &[u8]) {
    debug_assert_eq!(idx.len() * 2, data.len());
    for (i, c) in idx.iter().zip(data.chunks_exact(2)) {
        y[*i as usize] += s * f16_decode(u16::from_le_bytes([c[0], c[1]]));
    }
}

/// Symmetric int8 quantization: scale = max|x|/127 (0 for an all-zero
/// buffer), byte j = round(x[j]/scale) clamped to [−127, 127] stored
/// two's-complement (1 byte/elem). Replaces `out`'s contents and
/// returns the scale.
pub fn quantize_i8(src: &[f32], out: &mut Vec<u8>) -> f32 {
    out.clear();
    out.reserve(src.len());
    let m = max_abs(src);
    if m == 0.0 {
        out.resize(src.len(), 0);
        return 0.0;
    }
    let scale = m / 127.0;
    let inv = 127.0 / m;
    let chunks = src.chunks_exact(LANES);
    let tail = chunks.remainder();
    for chunk in chunks {
        let mut enc = [0u8; LANES];
        for i in 0..LANES {
            enc[i] = (chunk[i] * inv).round().clamp(-127.0, 127.0) as i8 as u8;
        }
        out.extend_from_slice(&enc);
    }
    for &x in tail {
        out.push((x * inv).round().clamp(-127.0, 127.0) as i8 as u8);
    }
    scale
}

/// Decode int8 wire bytes back to f32 (· scale), replacing `out`'s
/// contents.
pub fn dequantize_i8(data: &[u8], scale: f32, out: &mut Vec<f32>) {
    out.clear();
    out.reserve(data.len());
    for &b in data {
        out.push(b as i8 as f32 * scale);
    }
}

/// y[i] += s · scale · data[i] — fused int8 dequantize-accumulate.
pub fn dequant_axpy_i8(y: &mut [f32], s: f32, data: &[u8], scale: f32) {
    let eff = s * scale;
    let n = y.len().min(data.len());
    let split = n - n % LANES;
    let (yh, yt) = y[..n].split_at_mut(split);
    let (dh, dt) = data[..n].split_at(split);
    for (ys, ds) in yh.chunks_exact_mut(LANES).zip(dh.chunks_exact(LANES)) {
        for i in 0..LANES {
            ys[i] += eff * (ds[i] as i8 as f32);
        }
    }
    for (yv, &b) in yt.iter_mut().zip(dt) {
        *yv += eff * (b as i8 as f32);
    }
}

/// y[idx[j]] += s · scale · data[j] — sparse fused int8 accumulate.
/// Index contract as [`scatter_add`].
pub fn dequant_scatter_axpy_i8(y: &mut [f32], s: f32, idx: &[u32], data: &[u8], scale: f32) {
    debug_assert_eq!(idx.len(), data.len());
    let eff = s * scale;
    for (i, &b) in idx.iter().zip(data) {
        y[*i as usize] += eff * (b as i8 as f32);
    }
}

/// L2 norm of int8 codes · scale: scale · √Σq² (integer-exact sum in
/// f64, no decoded buffer).
pub fn l2_norm_i8(data: &[u8], scale: f32) -> f64 {
    let mut sq = 0f64;
    for &b in data {
        let q = b as i8 as f64;
        sq += q * q;
    }
    scale as f64 * sq.sqrt()
}

/// L2 norm of packed f16 codes (f64 accumulation, no decoded buffer).
pub fn l2_norm_f16(data: &[u8]) -> f64 {
    let mut sq = 0f64;
    for c in data.chunks_exact(2) {
        let x = f16_decode(u16::from_le_bytes([c[0], c[1]])) as f64;
        sq += x * x;
    }
    sq.sqrt()
}

/// Add iid N(0, std²) noise to `v` in place; returns the noise L2 norm
/// (for SNR diagnostics, paper Fig. 6).
pub fn add_gaussian_noise(v: &mut [f32], std: f64, rng: &mut Rng) -> f64 {
    if std <= 0.0 {
        return 0.0;
    }
    let mut sq = 0f64;
    for x in v.iter_mut() {
        let n = rng.normal() * std;
        sq += n * n;
        *x += n as f32;
    }
    sq.sqrt()
}

/// Add iid Laplace(0, scale) noise to `v` in place; returns the noise L2
/// norm.
pub fn add_laplace_noise(v: &mut [f32], scale: f64, rng: &mut Rng) -> f64 {
    if scale <= 0.0 {
        return 0.0;
    }
    let mut sq = 0f64;
    for x in v.iter_mut() {
        let n = rng.laplace(scale);
        sq += n * n;
        *x += n as f32;
    }
    sq.sqrt()
}

// ----------------------------------------------------------------------
// Counter-based parallel noise kernels (DP mechanisms' hot path)
// ----------------------------------------------------------------------

/// Work unit of the parallel noise kernels, in samples. Chunk boundaries
/// are fixed at multiples of this (a [`CTR_BLOCK`] multiple), so the
/// generated vector — and the per-chunk partial norm sums — are
/// bit-identical for *any* thread count: threads only change which
/// worker owns a chunk, never where chunks fall.
pub const NOISE_CHUNK: usize = 1 << 16;

/// Run `f(chunk, global_offset) -> partial_sq` over fixed
/// [`NOISE_CHUNK`]-sized chunks of `v`, on `threads` scoped workers
/// (≤ 1 runs inline). Partial squared-norm sums land in a per-chunk
/// table and are reduced in chunk order, so the returned L2 norm is as
/// thread-count-invariant as the vector contents.
fn noise_par_chunks<F>(v: &mut [f32], threads: usize, f: F) -> f64
where
    F: Fn(&mut [f32], usize) -> f64 + Sync,
{
    if v.is_empty() {
        return 0.0;
    }
    let nchunks = v.len().div_ceil(NOISE_CHUNK);
    let mut partial = vec![0f64; nchunks];
    let threads = threads.max(1).min(nchunks);
    if threads == 1 {
        for (ci, chunk) in v.chunks_mut(NOISE_CHUNK).enumerate() {
            partial[ci] = f(chunk, ci * NOISE_CHUNK);
        }
    } else {
        // contiguous spans of whole chunks per worker (like tree_reduce,
        // scoped threads — no shared mutable state, no locks)
        let per = nchunks.div_ceil(threads);
        let mut spans: Vec<(usize, &mut [f32], &mut [f64])> = Vec::with_capacity(threads);
        let mut rv: &mut [f32] = v;
        let mut rp: &mut [f64] = &mut partial;
        let mut start = 0usize;
        while !rv.is_empty() {
            let take = (per * NOISE_CHUNK).min(rv.len());
            let (vh, vt) = rv.split_at_mut(take);
            let (ph, pt) = rp.split_at_mut(vh.len().div_ceil(NOISE_CHUNK));
            spans.push((start, vh, ph));
            start += take;
            rv = vt;
            rp = pt;
        }
        std::thread::scope(|s| {
            let fr = &f;
            let handles: Vec<_> = spans
                .into_iter()
                .map(|(base, vh, ph)| {
                    s.spawn(move || {
                        for (ci, chunk) in vh.chunks_mut(NOISE_CHUNK).enumerate() {
                            ph[ci] = fr(chunk, base + ci * NOISE_CHUNK);
                        }
                    })
                })
                .collect();
            for h in handles {
                h.join().expect("noise worker panicked");
            }
        });
    }
    partial.iter().sum::<f64>().sqrt()
}

/// One chunk of `fill`/`add`: regenerate N(0, std²) samples positioned at
/// `offset..offset+chunk.len()` of the stream and either overwrite
/// (`add = false`, [`Rng::fill_normal_f32`] semantics) or add in place
/// (`add = true`, [`add_gaussian_noise`] semantics). Returns the chunk's
/// squared noise norm (f64-accumulated, exactly like the legacy loop).
fn normal_chunk_ctr(chunk: &mut [f32], offset: usize, std: f64, rng: &CtrRng, add: bool) -> f64 {
    debug_assert_eq!(offset % CTR_BLOCK, 0);
    let mut sq = 0f64;
    let mut i = 0usize;
    while i < chunk.len() {
        let z = rng.normal_block(((offset + i) / CTR_BLOCK) as u64);
        let take = (chunk.len() - i).min(CTR_BLOCK);
        for (j, &zj) in z.iter().take(take).enumerate() {
            let n = zj * std;
            sq += n * n;
            if add {
                chunk[i + j] += n as f32;
            } else {
                chunk[i + j] = n as f32;
            }
        }
        i += take;
    }
    sq
}

/// One chunk of the fused axpy: `chunk[i] += a · n32[i]` where
/// `n32[i] = (z[offset+i]·std) as f32` is the f32 sample a retained ring
/// buffer would have stored — the cast happens *before* the f32
/// multiply-add, so regeneration is bit-identical to
/// [`CtrRng`]-filled-ring-then-[`axpy`].
fn axpy_normal_chunk_ctr(chunk: &mut [f32], offset: usize, a: f32, std: f64, rng: &CtrRng) {
    debug_assert_eq!(offset % CTR_BLOCK, 0);
    let mut i = 0usize;
    while i < chunk.len() {
        let z = rng.normal_block(((offset + i) / CTR_BLOCK) as u64);
        let take = (chunk.len() - i).min(CTR_BLOCK);
        for (j, &zj) in z.iter().take(take).enumerate() {
            chunk[i + j] += a * ((zj * std) as f32);
        }
        i += take;
    }
}

/// Counter-based parallel variant of [`Rng::fill_normal_f32`]:
/// `dst[i] = (z_i·std) as f32` with `z_i` sample `i` of `rng`'s stream.
/// Bit-identical for any `threads` ≥ 0 (0/1 run inline).
pub fn fill_normal_f32_ctr(dst: &mut [f32], std: f64, rng: &CtrRng, threads: usize) {
    noise_par_chunks(dst, threads, |chunk, offset| {
        normal_chunk_ctr(chunk, offset, std, rng, false)
    });
}

/// Counter-based parallel variant of [`add_gaussian_noise`]: adds iid
/// N(0, std²) to `v` in place and returns the noise L2 norm. Both the
/// vector and the returned norm are bit-identical for any thread count
/// (per-chunk partial sums reduce in fixed chunk order).
pub fn add_gaussian_noise_par(v: &mut [f32], std: f64, rng: &CtrRng, threads: usize) -> f64 {
    if std <= 0.0 {
        return 0.0;
    }
    noise_par_chunks(v, threads, |chunk, offset| {
        normal_chunk_ctr(chunk, offset, std, rng, true)
    })
}

/// Fused `y += a · noise(rng, std)` without materializing the noise
/// vector: the single-stream view of [`axpy_normal_mix_ctr`].
pub fn axpy_normal_ctr(y: &mut [f32], a: f32, std: f64, rng: &CtrRng, threads: usize) {
    axpy_normal_mix_ctr(y, &[(a, *rng)], std, threads);
}

/// The banded-MF fused mix: `y[i] += Σ_j a_j · n_j[i]` with `n_j` the f32
/// noise of the j-th counter stream — every band's z_{t−k} regenerates
/// chunk by chunk inside ONE parallel pass (O(chunk) scratch per worker)
/// instead of being read from a retained `band × dim` ring. Per element
/// the terms accumulate in slice order, matching a ring mixed by
/// repeated [`axpy`] calls in the same order bit for bit.
pub fn axpy_normal_mix_ctr(y: &mut [f32], terms: &[(f32, CtrRng)], std: f64, threads: usize) {
    noise_par_chunks(y, threads, |chunk, offset| {
        for &(a, ref rng) in terms {
            axpy_normal_chunk_ctr(chunk, offset, a, std, rng);
        }
        0.0
    });
}

/// Counter-based parallel variant of [`add_laplace_noise`]: adds iid
/// Laplace(0, scale) to `v` in place (sample `i` consumes counter `i`)
/// and returns the noise L2 norm; bit-identical for any thread count.
pub fn add_laplace_noise_ctr(v: &mut [f32], scale: f64, rng: &CtrRng, threads: usize) -> f64 {
    if scale <= 0.0 {
        return 0.0;
    }
    noise_par_chunks(v, threads, |chunk, offset| {
        let mut sq = 0f64;
        for (i, x) in chunk.iter_mut().enumerate() {
            let n = rng.laplace_at((offset + i) as u64, scale);
            sq += n * n;
            *x += n as f32;
        }
        sq
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunked_ops_match_scalar_reference() {
        // lengths straddling the lane width, including 0 and tails
        for n in [0usize, 1, 7, 8, 9, 16, 31, 100] {
            let a: Vec<f32> = (0..n).map(|i| i as f32 * 0.5 - 3.0).collect();
            let b: Vec<f32> = (0..n).map(|i| (i as f32).sin()).collect();

            let mut y = a.clone();
            add_assign(&mut y, &b);
            for i in 0..n {
                assert_eq!(y[i], a[i] + b[i]);
            }

            let mut y = a.clone();
            axpy(&mut y, 2.5, &b);
            for i in 0..n {
                assert!((y[i] - (a[i] + 2.5 * b[i])).abs() < 1e-6);
            }

            let mut y = a.clone();
            scale(&mut y, -0.25);
            for i in 0..n {
                assert_eq!(y[i], a[i] * -0.25);
            }

            let mut out = vec![0.0; n];
            sub_into(&mut out, &a, &b);
            for i in 0..n {
                assert_eq!(out[i], a[i] - b[i]);
            }

            let mut y = a.clone();
            sub_assign(&mut y, &b);
            for i in 0..n {
                assert_eq!(y[i], a[i] - b[i]);
            }

            let mut y = b.clone();
            sub_rev_assign(&mut y, &a);
            for i in 0..n {
                assert_eq!(y[i], a[i] - b[i]);
            }

            let ref_l2: f64 = a.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>().sqrt();
            assert!((l2_norm(&a) - ref_l2).abs() < 1e-9 * ref_l2.max(1.0));
            let ref_l1: f64 = a.iter().map(|x| x.abs() as f64).sum();
            assert!((l1_norm(&a) - ref_l1).abs() < 1e-9 * ref_l1.max(1.0));
        }
    }

    #[test]
    fn clips_bound_norms() {
        let mut v = vec![3.0f32, 4.0];
        let pre = l2_clip(&mut v, 1.0);
        assert!((pre - 5.0).abs() < 1e-6);
        assert!((l2_norm(&v) - 1.0).abs() < 1e-6);

        let mut u = vec![1.0f32, -1.0, 2.0];
        let pre = l1_clip(&mut u, 1.0);
        assert!((pre - 4.0).abs() < 1e-6);
        assert!((l1_norm(&u) - 1.0).abs() < 1e-6);

        // below the bound: untouched
        let mut w = vec![0.3f32, 0.4];
        l2_clip(&mut w, 1.0);
        assert_eq!(w, vec![0.3, 0.4]);
    }

    #[test]
    fn adam_step_matches_reference() {
        let (b1, b2, tau, step) = (0.9f32, 0.99, 0.1, 0.1);
        let delta = [1.0f32, -2.0, 0.5];
        let mut params = [0.0f32; 3];
        let mut m = [0.0f32; 3];
        let mut v = [0.0f32; 3];
        let (bc1, bc2) = (1.0 - b1, 1.0 - b2); // t = 1
        adam_step(&mut params, &delta, &mut m, &mut v, b1, b2, bc1, bc2, tau, step);
        for i in 0..3 {
            let g = delta[i];
            let mhat = ((1.0 - b1) * g) / bc1; // = g at t=1
            let vhat = ((1.0 - b2) * g * g) / bc2; // = g² at t=1
            let expect = -step * mhat / (vhat.sqrt() + tau);
            assert!((params[i] - expect).abs() < 1e-6, "{} vs {}", params[i], expect);
        }
    }

    #[test]
    fn scatter_add_hits_indices() {
        let mut y = vec![0.0f32; 6];
        scatter_add(&mut y, &[1, 3, 5], &[1.0, 2.0, 3.0]);
        assert_eq!(y, vec![0.0, 1.0, 0.0, 2.0, 0.0, 3.0]);
        scatter_add(&mut y, &[1], &[0.5]);
        assert_eq!(y[1], 1.5);
    }

    #[test]
    fn scatter_axpy_scales_contributions() {
        let mut y = vec![1.0f32; 4];
        scatter_axpy(&mut y, 0.5, &[0, 2], &[2.0, -4.0]);
        assert_eq!(y, vec![2.0, 1.0, -1.0, 1.0]);
        // scale 1 degenerates to scatter_add
        let mut z = vec![0.0f32; 3];
        scatter_axpy(&mut z, 1.0, &[1], &[3.0]);
        assert_eq!(z, vec![0.0, 3.0, 0.0]);
    }

    #[test]
    fn f16_codec_round_trips_special_and_normal_values() {
        // exactly representable values survive the round trip bit-perfectly
        for x in [0.0f32, -0.0, 1.0, -1.0, 0.5, 2.0, 65504.0, -65504.0, 1.5, 0.099975586] {
            let y = f16_decode(f16_encode(x));
            assert_eq!(y, x, "{x} -> {y}");
        }
        // signed zero keeps its sign bit
        assert_eq!(f16_encode(-0.0).to_be_bytes()[0] & 0x80, 0x80);
        // overflow saturates to inf, inf/nan pass through
        assert_eq!(f16_decode(f16_encode(1e6)), f32::INFINITY);
        assert_eq!(f16_decode(f16_encode(f32::NEG_INFINITY)), f32::NEG_INFINITY);
        assert!(f16_decode(f16_encode(f32::NAN)).is_nan());
        // tiny values underflow through the subnormal range to zero
        assert_eq!(f16_decode(f16_encode(1e-10)), 0.0);
        // subnormal f16s decode exactly (mant · 2⁻²⁴)
        assert_eq!(f16_decode(1), 1.0 / 16_777_216.0);
        // general values: relative error ≤ 2⁻¹¹ in the normal range
        for i in 0..200 {
            let x = (i as f32 - 100.0) * 0.37 + 0.013 * i as f32;
            let y = f16_decode(f16_encode(x));
            let tol = x.abs().max(6.1e-5) * 4.9e-4;
            assert!((y - x).abs() <= tol, "{x} -> {y}");
        }
    }

    #[test]
    fn quantize_kernels_bound_round_trip_error() {
        for n in [0usize, 1, 7, 8, 9, 16, 31, 100] {
            let src: Vec<f32> = (0..n).map(|i| ((i as f32) * 0.73 - 7.0).sin() * 3.0).collect();

            // f16: per-element relative error ≤ 2⁻¹¹
            let mut bytes = Vec::new();
            quantize_f16(&src, &mut bytes);
            assert_eq!(bytes.len(), 2 * n);
            let mut back = Vec::new();
            dequantize_f16(&bytes, &mut back);
            assert_eq!(back.len(), n);
            for i in 0..n {
                let tol = src[i].abs().max(6.1e-5) * 4.9e-4;
                assert!((back[i] - src[i]).abs() <= tol);
            }

            // int8: per-element absolute error ≤ scale/2 = max|x|/254
            let mut b8 = Vec::new();
            let scale = quantize_i8(&src, &mut b8);
            assert_eq!(b8.len(), n);
            let mut back8 = Vec::new();
            dequantize_i8(&b8, scale, &mut back8);
            let m = max_abs(&src);
            for i in 0..n {
                assert!((back8[i] - src[i]).abs() <= m / 254.0 + 1e-7);
            }

            // fused accumulate matches dequantize-then-axpy
            let base: Vec<f32> = (0..n).map(|i| i as f32 * 0.1).collect();
            let mut y = base.clone();
            dequant_axpy_f16(&mut y, 2.0, &bytes);
            for i in 0..n {
                assert_eq!(y[i], base[i] + 2.0 * back[i]);
            }
            let mut y8 = base.clone();
            dequant_axpy_i8(&mut y8, 2.0, &b8, scale);
            for i in 0..n {
                let expect = base[i] + 2.0 * scale * (b8[i] as i8 as f32);
                assert!((y8[i] - expect).abs() <= expect.abs().max(1.0) * 1e-6);
            }
        }
        // all-zero input quantizes to scale 0 and zero bytes
        let mut b = Vec::new();
        assert_eq!(quantize_i8(&[0.0; 9], &mut b), 0.0);
        assert!(b.iter().all(|&x| x == 0));
    }

    #[test]
    fn dequant_scatter_hits_indices() {
        let src = [1.0f32, -2.0, 0.5];
        let mut f16b = Vec::new();
        quantize_f16(&src, &mut f16b);
        let mut y = vec![0.0f32; 6];
        dequant_scatter_axpy_f16(&mut y, 2.0, &[1, 3, 5], &f16b);
        assert_eq!(y, vec![0.0, 2.0, 0.0, -4.0, 0.0, 1.0]);

        let mut i8b = Vec::new();
        let scale = quantize_i8(&src, &mut i8b);
        let mut z = vec![0.0f32; 6];
        dequant_scatter_axpy_i8(&mut z, 1.0, &[0, 2, 4], &i8b, scale);
        for (got, want) in z.iter().step_by(2).zip(src) {
            assert!((got - want).abs() <= 2.0 / 254.0 + 1e-6);
        }
    }

    #[test]
    fn max_abs_matches_reference() {
        for n in [0usize, 1, 7, 8, 9, 16, 31, 100] {
            let v: Vec<f32> = (0..n).map(|i| (i as f32 - 4.5) * -0.7).collect();
            let want = v.iter().fold(0f32, |a, x| a.max(x.abs()));
            assert_eq!(max_abs(&v), want);
        }
    }

    #[test]
    fn ctr_noise_bit_identical_across_thread_counts() {
        // lengths straddling chunk and block boundaries: empty, sub-block,
        // sub-chunk, exact chunk, chunk+tail, several chunks + ragged tail
        for n in [0usize, 5, 1000, NOISE_CHUNK, NOISE_CHUNK + 3, 3 * NOISE_CHUNK + 17] {
            let rng = CtrRng::new(0xBEEF, 1);
            let mut fills: Vec<Vec<f32>> = Vec::new();
            let mut adds: Vec<(Vec<f32>, f64)> = Vec::new();
            let mut axpys: Vec<Vec<f32>> = Vec::new();
            let mut laps: Vec<(Vec<f32>, f64)> = Vec::new();
            for threads in [1usize, 2, 4] {
                let mut f = vec![0.0f32; n];
                fill_normal_f32_ctr(&mut f, 1.5, &rng, threads);
                fills.push(f);
                let mut a = vec![0.25f32; n];
                let norm = add_gaussian_noise_par(&mut a, 1.5, &rng, threads);
                adds.push((a, norm));
                let mut y = vec![0.5f32; n];
                axpy_normal_ctr(&mut y, 0.375, 1.5, &rng, threads);
                axpys.push(y);
                let mut l = vec![0.0f32; n];
                let lnorm = add_laplace_noise_ctr(&mut l, 2.0, &rng, threads);
                laps.push((l, lnorm));
            }
            for t in 1..3 {
                assert_eq!(fills[0], fills[t], "fill n={n} threads differ");
                assert_eq!(adds[0].0, adds[t].0, "add n={n} threads differ");
                assert_eq!(
                    adds[0].1.to_bits(),
                    adds[t].1.to_bits(),
                    "add norm n={n} threads differ"
                );
                assert_eq!(axpys[0], axpys[t], "axpy n={n} threads differ");
                assert_eq!(laps[0].0, laps[t].0, "laplace n={n} threads differ");
                assert_eq!(laps[0].1.to_bits(), laps[t].1.to_bits());
            }
        }
    }

    #[test]
    fn ctr_kernels_are_consistent_views_of_one_stream() {
        let rng = CtrRng::new(7, 3);
        let n = NOISE_CHUNK + 123;
        // add over zeros == fill (same samples, same positions)
        let mut filled = vec![0.0f32; n];
        fill_normal_f32_ctr(&mut filled, 2.0, &rng, 2);
        let mut added = vec![0.0f32; n];
        let norm = add_gaussian_noise_par(&mut added, 2.0, &rng, 2);
        assert_eq!(filled, added);
        assert!(norm > 0.0);
        // fused axpy == fill-then-axpy against a materialized buffer
        let base: Vec<f32> = (0..n).map(|i| (i as f32 * 0.001).sin()).collect();
        let mut fused = base.clone();
        axpy_normal_ctr(&mut fused, 0.5, 2.0, &rng, 4);
        let mut reference = base;
        axpy(&mut reference, 0.5, &filled);
        assert_eq!(fused, reference);
        // mix of two streams == two sequential single-stream axpys
        let rng2 = CtrRng::new(7, 4);
        let mut mixed = vec![1.0f32; n];
        axpy_normal_mix_ctr(&mut mixed, &[(0.5, rng), (0.25, rng2)], 2.0, 3);
        let mut seq = vec![1.0f32; n];
        axpy_normal_ctr(&mut seq, 0.5, 2.0, &rng, 1);
        axpy_normal_ctr(&mut seq, 0.25, 2.0, &rng2, 1);
        assert_eq!(mixed, seq);
    }

    #[test]
    fn ctr_noise_magnitudes_and_zero_guards() {
        let rng = CtrRng::new(11, 0);
        let mut v = vec![0.0f32; 20_000];
        let norm = add_gaussian_noise_par(&mut v, 2.0, &rng, 2);
        let expect = (20_000f64).sqrt() * 2.0; // E‖noise‖ = √d·σ
        assert!((norm / expect - 1.0).abs() < 0.05, "{norm} vs {expect}");
        // the returned norm is the norm of what was added
        let direct = l2_norm(&v);
        assert!((direct / norm - 1.0).abs() < 1e-4, "{direct} vs {norm}");
        // zero std/scale are no-ops
        let mut w = vec![1.0f32; 4];
        assert_eq!(add_gaussian_noise_par(&mut w, 0.0, &rng, 2), 0.0);
        assert_eq!(w, vec![1.0; 4]);
        assert_eq!(add_laplace_noise_ctr(&mut w, 0.0, &rng, 2), 0.0);
        assert_eq!(w, vec![1.0; 4]);
        // laplace variance: Var = 2·scale²
        let mut u = vec![0.0f32; 200_000];
        add_laplace_noise_ctr(&mut u, 2.0, &rng, 4);
        let var = u.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>() / u.len() as f64;
        assert!((var - 8.0).abs() < 0.3, "laplace var {var}");
        // chunk granularity must stay block-aligned or chunk stitching
        // would shear Box–Muller pairs
        assert_eq!(NOISE_CHUNK % CTR_BLOCK, 0);
    }

    #[test]
    fn noise_magnitudes() {
        let mut rng = Rng::seed_from_u64(3);
        let mut v = vec![0.0f32; 20_000];
        let norm = add_gaussian_noise(&mut v, 2.0, &mut rng);
        let expect = (20_000f64).sqrt() * 2.0; // E‖noise‖ = √d·σ
        assert!((norm / expect - 1.0).abs() < 0.05, "{norm} vs {expect}");
        // zero std is a no-op
        let mut w = vec![1.0f32; 4];
        assert_eq!(add_gaussian_noise(&mut w, 0.0, &mut rng), 0.0);
        assert_eq!(w, vec![1.0; 4]);
        assert_eq!(add_laplace_noise(&mut w, 0.0, &mut rng), 0.0);
        // laplace noise perturbs
        let mut u = vec![0.0f32; 1000];
        let n = add_laplace_noise(&mut u, 1.0, &mut rng);
        assert!(n > 0.0);
        assert!(u.iter().any(|x| *x != 0.0));
    }
}
