//! Length-framed byte protocol (DESIGN.md §7).
//!
//! A connection opens with a 5-byte preamble — magic `b"PFLC"` + a
//! version byte — written by *both* sides before either reads, so a
//! version mismatch fails fast in one round trip. After the preamble
//! the stream is a sequence of frames:
//!
//! ```text
//! +-----+----------------+=================+
//! | tag |  len (varint)  |  payload (len)  |
//! | u8  |  LEB128 u64    |  codec bytes    |
//! +-----+----------------+=================+
//! ```
//!
//! Varints are unsigned LEB128 (7 bits per byte, LSB first, high bit =
//! continue). Scalars inside payloads are little-endian. There is no
//! per-frame checksum: the transports below this layer (Unix-domain and
//! TCP sockets) are reliable byte streams.

use super::CommError;
use std::io::{Read, Write};

/// Connection preamble magic.
pub const MAGIC: [u8; 4] = *b"PFLC";
/// Wire protocol version; bump on any frame-layout change.
pub const VERSION: u8 = 1;
/// Upper bound on a single frame payload (1 GiB) — a corrupt length
/// field must not turn into an attempted allocation.
pub const MAX_FRAME_LEN: usize = 1 << 30;

// ---------------------------------------------------------------- encode

pub fn put_varint(buf: &mut Vec<u8>, mut v: u64) {
    loop {
        let byte = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            buf.push(byte);
            return;
        }
        buf.push(byte | 0x80);
    }
}

pub fn put_u8(buf: &mut Vec<u8>, v: u8) {
    buf.push(v);
}

pub fn put_bool(buf: &mut Vec<u8>, v: bool) {
    buf.push(v as u8);
}

pub fn put_u32_le(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

pub fn put_u64_le(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

pub fn put_f32_le(buf: &mut Vec<u8>, v: f32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

pub fn put_f64_le(buf: &mut Vec<u8>, v: f64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

/// Varint byte length + UTF-8 bytes.
pub fn put_str(buf: &mut Vec<u8>, s: &str) {
    put_varint(buf, s.len() as u64);
    buf.extend_from_slice(s.as_bytes());
}

// ---------------------------------------------------------------- decode

/// Bounds-checked reader over a received payload.
pub struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Cursor { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn take(&mut self, n: usize) -> Result<&'a [u8], CommError> {
        if self.remaining() < n {
            return Err(CommError::Truncated { need: n, have: self.remaining() });
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8, CommError> {
        Ok(self.take(1)?[0])
    }

    pub fn bool(&mut self) -> Result<bool, CommError> {
        Ok(self.u8()? != 0)
    }

    pub fn u32_le(&mut self) -> Result<u32, CommError> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn u64_le(&mut self) -> Result<u64, CommError> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    pub fn f32_le(&mut self) -> Result<f32, CommError> {
        Ok(f32::from_bits(self.u32_le()?))
    }

    pub fn f64_le(&mut self) -> Result<f64, CommError> {
        Ok(f64::from_bits(self.u64_le()?))
    }

    pub fn varint(&mut self) -> Result<u64, CommError> {
        let mut v = 0u64;
        for shift in 0..10 {
            let byte = self.u8()?;
            if shift == 9 && byte > 1 {
                return Err(CommError::Malformed("varint overflows u64"));
            }
            v |= u64::from(byte & 0x7f) << (7 * shift);
            if byte & 0x80 == 0 {
                return Ok(v);
            }
        }
        Err(CommError::Malformed("varint longer than 10 bytes"))
    }

    /// Varint that must fit a sane in-memory length.
    pub fn len(&mut self) -> Result<usize, CommError> {
        let v = self.varint()?;
        if v > MAX_FRAME_LEN as u64 {
            return Err(CommError::FrameTooLarge { len: v });
        }
        Ok(v as usize)
    }

    pub fn string(&mut self) -> Result<String, CommError> {
        let n = self.len()?;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec()).map_err(|_| CommError::Malformed("invalid utf-8"))
    }

    /// Assert the payload was fully consumed.
    pub fn done(&self) -> Result<(), CommError> {
        if self.remaining() == 0 {
            Ok(())
        } else {
            Err(CommError::Malformed("trailing bytes after payload"))
        }
    }
}

// ---------------------------------------------------------------- framing

/// Write one frame; returns total bytes written (header + payload).
pub fn write_frame<W: Write>(w: &mut W, tag: u8, payload: &[u8]) -> Result<u64, CommError> {
    if payload.len() > MAX_FRAME_LEN {
        return Err(CommError::FrameTooLarge { len: payload.len() as u64 });
    }
    let mut head = Vec::with_capacity(11);
    head.push(tag);
    put_varint(&mut head, payload.len() as u64);
    w.write_all(&head)?;
    w.write_all(payload)?;
    w.flush()?;
    Ok((head.len() + payload.len()) as u64)
}

/// Read one frame; returns (tag, payload, total bytes read). A clean
/// EOF *at a frame boundary* is [`CommError::Closed`]; EOF anywhere
/// else is an I/O error (the peer died mid-frame).
pub fn read_frame<R: Read>(r: &mut R) -> Result<(u8, Vec<u8>, u64), CommError> {
    let mut tag = [0u8; 1];
    loop {
        match r.read(&mut tag) {
            Ok(0) => return Err(CommError::Closed),
            Ok(_) => break,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(CommError::Io(e)),
        }
    }
    let (len, len_bytes) = read_varint(r)?;
    if len > MAX_FRAME_LEN as u64 {
        return Err(CommError::FrameTooLarge { len });
    }
    let mut payload = vec![0u8; len as usize];
    r.read_exact(&mut payload)?;
    Ok((tag[0], payload, 1 + len_bytes + len))
}

fn read_varint<R: Read>(r: &mut R) -> Result<(u64, u64), CommError> {
    let mut v = 0u64;
    let mut byte = [0u8; 1];
    for shift in 0..10u64 {
        r.read_exact(&mut byte)?;
        if shift == 9 && byte[0] > 1 {
            return Err(CommError::Malformed("varint overflows u64"));
        }
        v |= u64::from(byte[0] & 0x7f) << (7 * shift);
        if byte[0] & 0x80 == 0 {
            return Ok((v, shift + 1));
        }
    }
    Err(CommError::Malformed("varint longer than 10 bytes"))
}

/// Both sides write their preamble before reading the peer's.
pub fn write_preamble<W: Write>(w: &mut W) -> Result<(), CommError> {
    w.write_all(&MAGIC)?;
    w.write_all(&[VERSION])?;
    w.flush()?;
    Ok(())
}

pub fn read_preamble<R: Read>(r: &mut R) -> Result<(), CommError> {
    let mut m = [0u8; 5];
    r.read_exact(&mut m).map_err(|e| {
        if e.kind() == std::io::ErrorKind::UnexpectedEof {
            CommError::Closed
        } else {
            CommError::Io(e)
        }
    })?;
    if m[..4] != MAGIC {
        return Err(CommError::BadMagic([m[0], m[1], m[2], m[3]]));
    }
    if m[4] != VERSION {
        return Err(CommError::BadVersion { got: m[4], want: VERSION });
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn varint_roundtrips_across_widths() {
        let cases = [0u64, 1, 127, 128, 300, 16_383, 16_384, u32::MAX as u64, u64::MAX];
        for &v in &cases {
            let mut buf = Vec::new();
            put_varint(&mut buf, v);
            let mut cur = Cursor::new(&buf);
            assert_eq!(cur.varint().unwrap(), v, "value {v}");
            cur.done().unwrap();
        }
    }

    #[test]
    fn varint_known_encodings() {
        let mut buf = Vec::new();
        put_varint(&mut buf, 300);
        assert_eq!(buf, [0xAC, 0x02]);
        buf.clear();
        put_varint(&mut buf, 127);
        assert_eq!(buf, [0x7F]);
        buf.clear();
        put_varint(&mut buf, 128);
        assert_eq!(buf, [0x80, 0x01]);
    }

    #[test]
    fn overlong_varint_rejected() {
        let buf = [0xFFu8; 11];
        let mut cur = Cursor::new(&buf);
        assert!(matches!(cur.varint(), Err(CommError::Malformed(_))));
    }

    #[test]
    fn frame_layout_is_pinned() {
        // tag 4, payload [1,2,3] → exactly [4, 3, 1, 2, 3] on the wire.
        let mut wire = Vec::new();
        let n = write_frame(&mut wire, 4, &[1, 2, 3]).unwrap();
        assert_eq!(wire, [4, 3, 1, 2, 3]);
        assert_eq!(n, 5);
        let (tag, payload, read) = read_frame(&mut wire.as_slice()).unwrap();
        assert_eq!((tag, payload.as_slice(), read), (4, &[1u8, 2, 3][..], 5));
    }

    #[test]
    fn preamble_bytes_are_pinned() {
        let mut wire = Vec::new();
        write_preamble(&mut wire).unwrap();
        assert_eq!(wire, [0x50, 0x46, 0x4C, 0x43, 0x01]); // "PFLC" + v1
        read_preamble(&mut wire.as_slice()).unwrap();
    }

    #[test]
    fn preamble_rejects_bad_magic_and_version() {
        let bad = [0x50, 0x46, 0x4C, 0x58, 0x01];
        assert!(matches!(read_preamble(&mut bad.as_slice()), Err(CommError::BadMagic(_))));
        let vers = [0x50, 0x46, 0x4C, 0x43, 0x09];
        assert!(matches!(
            read_preamble(&mut vers.as_slice()),
            Err(CommError::BadVersion { got: 9, want: 1 })
        ));
    }

    #[test]
    fn eof_at_frame_boundary_is_closed() {
        let empty: &[u8] = &[];
        assert!(matches!(read_frame(&mut &empty[..]), Err(CommError::Closed)));
        // EOF mid-frame is an I/O error, not Closed.
        let partial: &[u8] = &[4, 10, 1, 2];
        assert!(matches!(read_frame(&mut &partial[..]), Err(CommError::Io(_))));
    }

    #[test]
    fn cursor_reports_truncation() {
        let mut cur = Cursor::new(&[1, 2]);
        assert!(matches!(cur.u32_le(), Err(CommError::Truncated { need: 4, have: 2 })));
    }

    #[test]
    fn scalars_roundtrip() {
        let mut buf = Vec::new();
        put_u8(&mut buf, 7);
        put_bool(&mut buf, true);
        put_u32_le(&mut buf, 0xDEAD_BEEF);
        put_u64_le(&mut buf, u64::MAX - 1);
        put_f32_le(&mut buf, -1.5);
        put_f64_le(&mut buf, std::f64::consts::PI);
        put_str(&mut buf, "héllo");
        let mut cur = Cursor::new(&buf);
        assert_eq!(cur.u8().unwrap(), 7);
        assert!(cur.bool().unwrap());
        assert_eq!(cur.u32_le().unwrap(), 0xDEAD_BEEF);
        assert_eq!(cur.u64_le().unwrap(), u64::MAX - 1);
        assert_eq!(cur.f32_le().unwrap(), -1.5);
        assert_eq!(cur.f64_le().unwrap(), std::f64::consts::PI);
        assert_eq!(cur.string().unwrap(), "héllo");
        cur.done().unwrap();
    }
}
