//! Explicit wire encodings for every type that crosses the
//! dispatcher↔worker seam (DESIGN.md §7).
//!
//! One rule: a codec function is the *only* place a given type's byte
//! layout exists. The in-process coordinator tax (`fl/worker.rs`) and
//! the socket transport both call in here, so there is exactly one wire
//! path to version. The layout is pinned by fixture tests below —
//! change a byte, bump [`super::wire::VERSION`].
//!
//! All sizes/counts are LEB128 varints, all scalars little-endian,
//! except `CentralContext::seed` which is a fixed 8-byte LE u64 (seeds
//! are uniformly distributed, so a varint would usually cost 10 bytes).

use super::wire::{self, Cursor};
use super::CommError;
use crate::fl::context::{CentralContext, DispatchMode, DispatchSpec, LocalParams, Population};
use crate::fl::metrics::{MetricValue, Metrics};
use crate::fl::stats::Statistics;
use crate::fl::worker::{Cmd, RoundResult};
use crate::simsys::{Counters, UserCost};
use crate::tensor::StatValue;
use std::sync::Arc;

// ------------------------------------------------------------ frame tags

/// worker → server, first frame after the preamble: identify yourself.
pub const FRAME_HELLO: u8 = 1;
/// server → worker, handshake reply: slot assignment + run config.
pub const FRAME_SETUP: u8 = 2;
/// server → worker: execute one seq-stamped unit of round work.
pub const FRAME_ROUND: u8 = 3;
/// worker → server: the [`RoundResult`] for one `FRAME_ROUND`.
pub const FRAME_RESULT: u8 = 4;
/// worker → server: liveness beacon (empty payload).
pub const FRAME_HEARTBEAT: u8 = 5;
/// server → worker: orderly shutdown (empty payload).
pub const FRAME_STOP: u8 = 6;

// ------------------------------------------------------------- handshake

/// Worker's self-introduction (payload of [`FRAME_HELLO`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Hello {
    pub pid: u32,
}

/// Server's handshake reply (payload of [`FRAME_SETUP`]): which worker
/// slot this connection fills, and everything needed to reconstruct the
/// training environment (the full run config as JSON — datasets here
/// are config-derived, so shipping the config ships the data).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Setup {
    pub worker: usize,
    pub use_hlo_clip: bool,
    /// Interval at which the worker must beacon; the server declares a
    /// worker dead after 3× this without any frame.
    pub heartbeat_ms: u64,
    pub config_json: String,
}

pub fn encode_hello(buf: &mut Vec<u8>, h: &Hello) {
    wire::put_varint(buf, u64::from(h.pid));
}

pub fn decode_hello(cur: &mut Cursor) -> Result<Hello, CommError> {
    Ok(Hello { pid: cur.varint()? as u32 })
}

pub fn encode_setup(buf: &mut Vec<u8>, s: &Setup) {
    wire::put_varint(buf, s.worker as u64);
    wire::put_bool(buf, s.use_hlo_clip);
    wire::put_varint(buf, s.heartbeat_ms);
    wire::put_str(buf, &s.config_json);
}

pub fn decode_setup(cur: &mut Cursor) -> Result<Setup, CommError> {
    Ok(Setup {
        worker: cur.varint()? as usize,
        use_hlo_clip: cur.bool()?,
        heartbeat_ms: cur.varint()?,
        config_json: cur.string()?,
    })
}

// ------------------------------------------------------------ stat values

const SV_DENSE: u8 = 0;
const SV_SPARSE: u8 = 1;
const SV_QUANTIZED: u8 = 2;

pub fn encode_stat_value(buf: &mut Vec<u8>, v: &StatValue) {
    match v {
        StatValue::Dense(vals) => {
            wire::put_u8(buf, SV_DENSE);
            wire::put_varint(buf, vals.len() as u64);
            for &x in vals {
                wire::put_f32_le(buf, x);
            }
        }
        StatValue::Sparse { dim, idx, val } => {
            wire::put_u8(buf, SV_SPARSE);
            wire::put_varint(buf, u64::from(*dim));
            wire::put_varint(buf, idx.len() as u64);
            for &i in idx {
                wire::put_u32_le(buf, i);
            }
            for &x in val {
                wire::put_f32_le(buf, x);
            }
        }
        StatValue::Quantized { dim, scale, bits, idx, data } => {
            wire::put_u8(buf, SV_QUANTIZED);
            wire::put_varint(buf, u64::from(*dim));
            wire::put_f32_le(buf, *scale);
            wire::put_u8(buf, *bits);
            wire::put_bool(buf, idx.is_some());
            if let Some(idx) = idx {
                wire::put_varint(buf, idx.len() as u64);
                for &i in idx {
                    wire::put_u32_le(buf, i);
                }
            }
            wire::put_varint(buf, data.len() as u64);
            buf.extend_from_slice(data);
        }
    }
}

pub fn decode_stat_value(cur: &mut Cursor) -> Result<StatValue, CommError> {
    match cur.u8()? {
        SV_DENSE => {
            let n = cur.len()?;
            let mut vals = Vec::with_capacity(n.min(cur.remaining() / 4 + 1));
            for _ in 0..n {
                vals.push(cur.f32_le()?);
            }
            Ok(StatValue::Dense(vals))
        }
        SV_SPARSE => {
            let dim = cur.varint()? as u32;
            let nnz = cur.len()?;
            let mut idx = Vec::with_capacity(nnz.min(cur.remaining() / 4 + 1));
            for _ in 0..nnz {
                idx.push(cur.u32_le()?);
            }
            let mut val = Vec::with_capacity(nnz);
            for _ in 0..nnz {
                val.push(cur.f32_le()?);
            }
            Ok(StatValue::Sparse { dim, idx, val })
        }
        SV_QUANTIZED => {
            let dim = cur.varint()? as u32;
            let scale = cur.f32_le()?;
            let bits = cur.u8()?;
            let idx = if cur.bool()? {
                let nnz = cur.len()?;
                let mut idx = Vec::with_capacity(nnz.min(cur.remaining() / 4 + 1));
                for _ in 0..nnz {
                    idx.push(cur.u32_le()?);
                }
                Some(idx)
            } else {
                None
            };
            let n = cur.len()?;
            let data = cur.take(n)?.to_vec();
            Ok(StatValue::Quantized { dim, scale, bits, idx, data })
        }
        tag => Err(CommError::BadTag { what: "stat value", tag }),
    }
}

pub fn encode_statistics(buf: &mut Vec<u8>, s: &Statistics) {
    wire::put_f64_le(buf, s.weight);
    wire::put_varint(buf, s.vecs.len() as u64);
    for (k, v) in &s.vecs {
        wire::put_str(buf, k);
        encode_stat_value(buf, v);
    }
}

pub fn decode_statistics(cur: &mut Cursor) -> Result<Statistics, CommError> {
    let weight = cur.f64_le()?;
    let n = cur.len()?;
    let mut stats = Statistics { weight, ..Default::default() };
    for _ in 0..n {
        let key = cur.string()?;
        let value = decode_stat_value(cur)?;
        stats.vecs.insert(key, value);
    }
    Ok(stats)
}

// --------------------------------------------------------------- metrics

const MV_CENTRAL: u8 = 0;
const MV_PER_USER: u8 = 1;

fn encode_metric_value(buf: &mut Vec<u8>, v: &MetricValue) {
    match v {
        MetricValue::Central { sum, weight } => {
            wire::put_u8(buf, MV_CENTRAL);
            wire::put_f64_le(buf, *sum);
            wire::put_f64_le(buf, *weight);
        }
        MetricValue::PerUser { sum, count } => {
            wire::put_u8(buf, MV_PER_USER);
            wire::put_f64_le(buf, *sum);
            wire::put_varint(buf, *count);
        }
    }
}

fn decode_metric_value(cur: &mut Cursor) -> Result<MetricValue, CommError> {
    match cur.u8()? {
        MV_CENTRAL => Ok(MetricValue::Central { sum: cur.f64_le()?, weight: cur.f64_le()? }),
        MV_PER_USER => Ok(MetricValue::PerUser { sum: cur.f64_le()?, count: cur.varint()? }),
        tag => Err(CommError::BadTag { what: "metric value", tag }),
    }
}

pub fn encode_metrics(buf: &mut Vec<u8>, m: &Metrics) {
    wire::put_varint(buf, m.0.len() as u64);
    for (name, v) in &m.0 {
        wire::put_str(buf, name);
        encode_metric_value(buf, v);
    }
}

pub fn decode_metrics(cur: &mut Cursor) -> Result<Metrics, CommError> {
    let n = cur.len()?;
    let mut m = Metrics::new();
    for _ in 0..n {
        let name = cur.string()?;
        let value = decode_metric_value(cur)?;
        m.0.insert(name, value);
    }
    Ok(m)
}

// -------------------------------------------------------------- counters

/// Counters ride as varints in declared-field order; new fields are
/// only ever appended (and the wire version bumped).
pub fn encode_counters(buf: &mut Vec<u8>, c: &Counters) {
    for v in counter_fields(c) {
        wire::put_varint(buf, v);
    }
}

pub fn decode_counters(cur: &mut Cursor) -> Result<Counters, CommError> {
    // Struct-literal fields evaluate in written order, which must match
    // `counter_fields` — `counters_roundtrip_every_field` pins this.
    Ok(Counters {
        loop_alloc_bytes: cur.varint()?,
        arena_grow_bytes: cur.varint()?,
        arena_sparse_rounds: cur.varint()?,
        arena_spill_count: cur.varint()?,
        copy_bytes: cur.varint()?,
        wire_bytes: cur.varint()?,
        coordinator_msgs: cur.varint()?,
        stat_elements: cur.varint()?,
        stat_bytes: cur.varint()?,
        busy_nanos: cur.varint()?,
        users_trained: cur.varint()?,
        steps: cur.varint()?,
        steal_count: cur.varint()?,
        stale_updates: cur.varint()?,
        dropped_updates: cur.varint()?,
        cache_hits: cur.varint()?,
        cache_misses: cur.varint()?,
        prefetch_stall_nanos: cur.varint()?,
        store_bytes_read: cur.varint()?,
        decode_nanos: cur.varint()?,
        mmap_stall_nanos: cur.varint()?,
        pread_stall_nanos: cur.varint()?,
        noise_nanos: cur.varint()?,
        requeued_users: cur.varint()?,
        worker_reconnects: cur.varint()?,
        wire_bytes_in: cur.varint()?,
        wire_bytes_out: cur.varint()?,
    })
}

fn counter_fields(c: &Counters) -> [u64; 27] {
    [
        c.loop_alloc_bytes,
        c.arena_grow_bytes,
        c.arena_sparse_rounds,
        c.arena_spill_count,
        c.copy_bytes,
        c.wire_bytes,
        c.coordinator_msgs,
        c.stat_elements,
        c.stat_bytes,
        c.busy_nanos,
        c.users_trained,
        c.steps,
        c.steal_count,
        c.stale_updates,
        c.dropped_updates,
        c.cache_hits,
        c.cache_misses,
        c.prefetch_stall_nanos,
        c.store_bytes_read,
        c.decode_nanos,
        c.mmap_stall_nanos,
        c.pread_stall_nanos,
        c.noise_nanos,
        c.requeued_users,
        c.worker_reconnects,
        c.wire_bytes_in,
        c.wire_bytes_out,
    ]
}

// ----------------------------------------------------------- round state

fn encode_user_cost(buf: &mut Vec<u8>, c: &UserCost) {
    wire::put_varint(buf, c.datapoints as u64);
    wire::put_varint(buf, c.nanos);
    wire::put_varint(buf, c.device_nanos);
}

fn decode_user_cost(cur: &mut Cursor) -> Result<UserCost, CommError> {
    Ok(UserCost {
        datapoints: cur.varint()? as usize,
        nanos: cur.varint()?,
        device_nanos: cur.varint()?,
    })
}

fn encode_local_params(buf: &mut Vec<u8>, p: &LocalParams) {
    wire::put_varint(buf, p.epochs as u64);
    wire::put_varint(buf, p.batch_size as u64);
    wire::put_f32_le(buf, p.lr);
    wire::put_f32_le(buf, p.mu);
    wire::put_varint(buf, p.max_steps as u64);
}

fn decode_local_params(cur: &mut Cursor) -> Result<LocalParams, CommError> {
    Ok(LocalParams {
        epochs: cur.varint()? as usize,
        batch_size: cur.varint()? as usize,
        lr: cur.f32_le()?,
        mu: cur.f32_le()?,
        max_steps: cur.varint()? as usize,
    })
}

fn encode_dispatch_spec(buf: &mut Vec<u8>, d: &DispatchSpec) {
    let mode = match d.mode {
        DispatchMode::Static => 0u8,
        DispatchMode::WorkStealing => 1,
        DispatchMode::Async => 2,
        DispatchMode::Socket => 3,
    };
    wire::put_u8(buf, mode);
    wire::put_varint(buf, d.max_staleness);
    wire::put_f64_le(buf, d.buffer_frac);
    wire::put_varint(buf, d.reorder_window as u64);
}

fn decode_dispatch_spec(cur: &mut Cursor) -> Result<DispatchSpec, CommError> {
    let mode = match cur.u8()? {
        0 => DispatchMode::Static,
        1 => DispatchMode::WorkStealing,
        2 => DispatchMode::Async,
        3 => DispatchMode::Socket,
        tag => return Err(CommError::BadTag { what: "dispatch mode", tag }),
    };
    Ok(DispatchSpec {
        mode,
        max_staleness: cur.varint()?,
        buffer_frac: cur.f64_le()?,
        reorder_window: cur.varint()? as usize,
    })
}

/// Algorithm tags are `&'static str` in [`CentralContext`]; decoding
/// interns against the known set (leaking only for tags this build has
/// never seen, which a matching peer never sends).
fn intern_algorithm(s: &str) -> &'static str {
    const KNOWN: [&str; 9] =
        ["", "fedavg", "fedprox", "adafedprox", "scaffold", "gbdt", "fed-gbdt", "gmm", "fed-gmm"];
    for k in KNOWN {
        if k == s {
            return k;
        }
    }
    Box::leak(s.to_string().into_boxed_str())
}

pub fn encode_context(buf: &mut Vec<u8>, ctx: &CentralContext) {
    wire::put_varint(buf, ctx.iteration);
    let pop = match ctx.population {
        Population::Train => 0u8,
        Population::Val => 1,
    };
    wire::put_u8(buf, pop);
    wire::put_varint(buf, ctx.cohort_size as u64);
    encode_local_params(buf, &ctx.local);
    wire::put_u64_le(buf, ctx.seed);
    encode_dispatch_spec(buf, &ctx.dispatch);
    wire::put_str(buf, ctx.algorithm);
}

pub fn decode_context(cur: &mut Cursor) -> Result<CentralContext, CommError> {
    let iteration = cur.varint()?;
    let population = match cur.u8()? {
        0 => Population::Train,
        1 => Population::Val,
        tag => return Err(CommError::BadTag { what: "population", tag }),
    };
    let cohort_size = cur.varint()? as usize;
    let local = decode_local_params(cur)?;
    let seed = cur.u64_le()?;
    let dispatch = decode_dispatch_spec(cur)?;
    let algorithm = intern_algorithm(&cur.string()?);
    Ok(CentralContext { iteration, population, cohort_size, local, seed, dispatch, algorithm })
}

/// Payload of a [`FRAME_ROUND`]: one seq-stamped unit of work — the
/// context, the central model it trains against, and the uids to train.
#[derive(Debug, Clone)]
pub struct RoundMsg {
    pub seq: u64,
    pub ctx: CentralContext,
    pub central: Vec<f32>,
    pub uids: Vec<usize>,
}

pub fn encode_round(
    buf: &mut Vec<u8>,
    seq: u64,
    ctx: &CentralContext,
    central: &[f32],
    uids: &[usize],
) {
    wire::put_varint(buf, seq);
    encode_context(buf, ctx);
    wire::put_varint(buf, central.len() as u64);
    for &x in central {
        wire::put_f32_le(buf, x);
    }
    wire::put_varint(buf, uids.len() as u64);
    for &u in uids {
        wire::put_varint(buf, u as u64);
    }
}

pub fn decode_round(cur: &mut Cursor) -> Result<RoundMsg, CommError> {
    let seq = cur.varint()?;
    let ctx = decode_context(cur)?;
    let n = cur.len()?;
    let mut central = Vec::with_capacity(n.min(cur.remaining() / 4 + 1));
    for _ in 0..n {
        central.push(cur.f32_le()?);
    }
    let k = cur.len()?;
    let mut uids = Vec::with_capacity(k.min(cur.remaining() + 1));
    for _ in 0..k {
        uids.push(cur.varint()? as usize);
    }
    Ok(RoundMsg { seq, ctx, central, uids })
}

pub fn encode_round_result(buf: &mut Vec<u8>, r: &RoundResult) {
    wire::put_varint(buf, r.worker as u64);
    wire::put_varint(buf, r.round);
    wire::put_varint(buf, r.seq);
    wire::put_bool(buf, r.partial.is_some());
    if let Some(p) = &r.partial {
        encode_statistics(buf, p);
    }
    encode_metrics(buf, &r.metrics);
    encode_counters(buf, &r.counters);
    wire::put_varint(buf, r.costs.len() as u64);
    for c in &r.costs {
        encode_user_cost(buf, c);
    }
    wire::put_bool(buf, r.error.is_some());
    if let Some(e) = &r.error {
        wire::put_str(buf, e);
    }
}

pub fn decode_round_result(cur: &mut Cursor) -> Result<RoundResult, CommError> {
    let worker = cur.varint()? as usize;
    let round = cur.varint()?;
    let seq = cur.varint()?;
    let partial = if cur.bool()? { Some(decode_statistics(cur)?) } else { None };
    let metrics = decode_metrics(cur)?;
    let counters = decode_counters(cur)?;
    let n = cur.len()?;
    let mut costs = Vec::with_capacity(n.min(cur.remaining() / 3 + 1));
    for _ in 0..n {
        costs.push(decode_user_cost(cur)?);
    }
    let error = if cur.bool()? { Some(cur.string()?) } else { None };
    Ok(RoundResult { worker, round, seq, partial, metrics, counters, costs, error })
}

// ------------------------------------------------------------------ Cmd

/// Encode a worker command as (frame tag, payload). A
/// [`crate::fl::WorkSource::Shared`] queue is a pointer into server
/// memory and cannot cross a process boundary — callers of the socket
/// path materialize uid lists first.
pub fn encode_cmd(cmd: &Cmd) -> Result<(u8, Vec<u8>), CommError> {
    match cmd {
        Cmd::Round { ctx, central, work, seq } => {
            let uids = match work {
                crate::fl::WorkSource::Owned(uids) => uids,
                crate::fl::WorkSource::Shared(_) => {
                    return Err(CommError::Unencodable(
                        "shared in-process work queue cannot cross a socket",
                    ))
                }
            };
            let mut buf = Vec::new();
            encode_round(&mut buf, *seq, ctx, central, uids);
            Ok((FRAME_ROUND, buf))
        }
        Cmd::Stop => Ok((FRAME_STOP, Vec::new())),
    }
}

/// Decode a server→worker frame back into a [`Cmd`].
pub fn decode_cmd(tag: u8, payload: &[u8]) -> Result<Cmd, CommError> {
    match tag {
        FRAME_ROUND => {
            let mut cur = Cursor::new(payload);
            let msg = decode_round(&mut cur)?;
            cur.done()?;
            Ok(Cmd::Round {
                ctx: msg.ctx,
                central: Arc::new(msg.central),
                work: crate::fl::WorkSource::Owned(msg.uids),
                seq: msg.seq,
            })
        }
        FRAME_STOP => Ok(Cmd::Stop),
        tag => Err(CommError::BadTag { what: "command frame", tag }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fl::WorkSource;

    fn roundtrip_stat(v: &StatValue) -> StatValue {
        let mut buf = Vec::new();
        encode_stat_value(&mut buf, v);
        let mut cur = Cursor::new(&buf);
        let back = decode_stat_value(&mut cur).unwrap();
        cur.done().unwrap();
        back
    }

    // Satellite: fixture tests pinning the frame layout byte-for-byte,
    // so a codec edit that would break cross-version workers fails here
    // instead of in production.
    #[test]
    fn dense_layout_is_pinned() {
        let mut buf = Vec::new();
        encode_stat_value(&mut buf, &StatValue::Dense(vec![1.0, -2.0]));
        assert_eq!(buf, [0, 2, 0x00, 0x00, 0x80, 0x3F, 0x00, 0x00, 0x00, 0xC0]);
    }

    #[test]
    fn empty_sparse_layout_is_pinned() {
        let mut buf = Vec::new();
        encode_stat_value(&mut buf, &StatValue::Sparse { dim: 7, idx: vec![], val: vec![] });
        assert_eq!(buf, [1, 7, 0]);
    }

    #[test]
    fn sparse_layout_is_pinned() {
        let mut buf = Vec::new();
        encode_stat_value(&mut buf, &StatValue::Sparse { dim: 300, idx: vec![5], val: vec![0.5] });
        assert_eq!(buf, [1, 0xAC, 0x02, 1, 5, 0, 0, 0, 0x00, 0x00, 0x00, 0x3F]);
    }

    #[test]
    fn quantized_layout_is_pinned() {
        let q = StatValue::Quantized {
            dim: 2,
            scale: 1.5,
            bits: 8,
            idx: None,
            data: vec![0x7F, 0x81],
        };
        let mut buf = Vec::new();
        encode_stat_value(&mut buf, &q);
        assert_eq!(buf, [2, 2, 0x00, 0x00, 0xC0, 0x3F, 8, 0, 2, 0x7F, 0x81]);
    }

    #[test]
    fn stat_values_roundtrip_all_variants() {
        let cases = vec![
            StatValue::Dense(vec![]),
            StatValue::Dense(vec![0.0, -0.0, f32::MIN_POSITIVE, 3.25e7]),
            StatValue::Sparse { dim: 7, idx: vec![], val: vec![] },
            StatValue::Sparse { dim: 4096, idx: vec![0, 9, 4000], val: vec![1.0, -1.0, 0.25] },
            StatValue::Quantized { dim: 4, scale: 0.125, bits: 8, idx: None, data: vec![0, 255] },
            StatValue::Quantized {
                dim: 1000,
                scale: 2.0,
                bits: 8,
                idx: Some(vec![3, 999]),
                data: vec![1, 2],
            },
            StatValue::Quantized { dim: 16, scale: 1.0, bits: 16, idx: None, data: vec![0; 32] },
        ];
        for v in &cases {
            assert_eq!(&roundtrip_stat(v), v, "variant {v:?}");
        }
    }

    #[test]
    fn nan_payloads_roundtrip_bitwise() {
        // PartialEq fails on NaN, so compare re-encoded bytes instead.
        let v = StatValue::Dense(vec![f32::NAN, f32::INFINITY, f32::NEG_INFINITY]);
        let mut a = Vec::new();
        encode_stat_value(&mut a, &v);
        let back = roundtrip_stat(&v);
        let mut b = Vec::new();
        encode_stat_value(&mut b, &back);
        assert_eq!(a, b);
    }

    #[test]
    fn statistics_roundtrip() {
        let mut s = Statistics { weight: 3.5, ..Default::default() };
        s.vecs.insert("update".into(), StatValue::Dense(vec![1.0, 2.0, 3.0]));
        s.vecs.insert("c-delta".into(), StatValue::Sparse { dim: 10, idx: vec![4], val: vec![-2.0] });
        let mut buf = Vec::new();
        encode_statistics(&mut buf, &s);
        let mut cur = Cursor::new(&buf);
        let back = decode_statistics(&mut cur).unwrap();
        cur.done().unwrap();
        assert_eq!(back, s);
    }

    #[test]
    fn metrics_roundtrip() {
        let mut m = Metrics::new();
        m.add_central("loss", 12.5, 4.0);
        m.0.insert("train/steps".into(), MetricValue::PerUser { sum: 18.0, count: 6 });
        let mut buf = Vec::new();
        encode_metrics(&mut buf, &m);
        let mut cur = Cursor::new(&buf);
        let back = decode_metrics(&mut cur).unwrap();
        cur.done().unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn counters_roundtrip_every_field() {
        // Distinct value per field: a swapped pair in encode vs decode
        // order cannot cancel out.
        let fields = counter_fields(&Counters::default()).len() as u64;
        let mut buf = Vec::new();
        for i in 1..=fields {
            wire::put_varint(&mut buf, i * 1000 + i);
        }
        let mut cur = Cursor::new(&buf);
        let c = decode_counters(&mut cur).unwrap();
        cur.done().unwrap();
        assert_eq!(c.loop_alloc_bytes, 1001);
        assert_eq!(c.noise_nanos, 23_023);
        assert_eq!(c.requeued_users, 24_024);
        assert_eq!(c.worker_reconnects, 25_025);
        assert_eq!(c.wire_bytes_in, 26_026);
        assert_eq!(c.wire_bytes_out, 27_027);
        let mut again = Vec::new();
        encode_counters(&mut again, &c);
        assert_eq!(again, buf);
    }

    #[test]
    fn context_roundtrip_interns_algorithm() {
        let local = LocalParams { epochs: 3, batch_size: 16, lr: 0.5, mu: 0.1, max_steps: 7 };
        let mut ctx = CentralContext::train(9, 40, local, 0xDEAD_BEEF_CAFE_F00D);
        ctx.dispatch = DispatchSpec {
            mode: DispatchMode::Socket,
            max_staleness: 5,
            buffer_frac: 0.75,
            reorder_window: 8,
        };
        ctx.algorithm = "scaffold";
        let mut buf = Vec::new();
        encode_context(&mut buf, &ctx);
        let mut cur = Cursor::new(&buf);
        let back = decode_context(&mut cur).unwrap();
        cur.done().unwrap();
        assert_eq!(back.iteration, 9);
        assert_eq!(back.population, Population::Train);
        assert_eq!(back.cohort_size, 40);
        assert_eq!(back.local.epochs, 3);
        assert_eq!(back.local.batch_size, 16);
        assert_eq!(back.local.lr, 0.5);
        assert_eq!(back.local.mu, 0.1);
        assert_eq!(back.local.max_steps, 7);
        assert_eq!(back.seed, 0xDEAD_BEEF_CAFE_F00D);
        assert_eq!(back.dispatch, ctx.dispatch);
        assert_eq!(back.algorithm, "scaffold");
    }

    #[test]
    fn round_result_roundtrips_via_reencode() {
        let mut stats = Statistics { weight: 2.0, ..Default::default() };
        stats.vecs.insert("update".into(), StatValue::Dense(vec![0.5; 5]));
        let mut metrics = Metrics::new();
        metrics.add_central("loss", 1.0, 1.0);
        let r = RoundResult {
            worker: 3,
            round: 17,
            seq: 255,
            partial: Some(stats),
            metrics,
            counters: Counters { users_trained: 4, steps: 12, ..Default::default() },
            costs: vec![UserCost { datapoints: 10, nanos: 5000, device_nanos: 3000 }],
            error: Some("worker 3 failed: oom".into()),
        };
        let mut a = Vec::new();
        encode_round_result(&mut a, &r);
        let mut cur = Cursor::new(&a);
        let back = decode_round_result(&mut cur).unwrap();
        cur.done().unwrap();
        // RoundResult/Counters don't derive PartialEq: compare re-encode.
        let mut b = Vec::new();
        encode_round_result(&mut b, &back);
        assert_eq!(a, b);
        assert_eq!(back.worker, 3);
        assert_eq!(back.seq, 255);
        assert_eq!(back.error.as_deref(), Some("worker 3 failed: oom"));
        assert_eq!(back.counters.users_trained, 4);
    }

    #[test]
    fn cmd_round_and_stop_roundtrip() {
        let ctx = CentralContext::train(1, 4, LocalParams::default(), 42);
        let cmd = Cmd::Round {
            ctx,
            central: Arc::new(vec![1.0, -2.5, 0.0]),
            work: WorkSource::Owned(vec![7, 0, 300]),
            seq: 11,
        };
        let (tag, payload) = encode_cmd(&cmd).unwrap();
        assert_eq!(tag, FRAME_ROUND);
        let back = decode_cmd(tag, &payload).unwrap();
        let (tag2, payload2) = encode_cmd(&back).unwrap();
        assert_eq!((tag, &payload), (tag2, &payload2));
        match back {
            Cmd::Round { central, work, seq, .. } => {
                assert_eq!(*central, vec![1.0, -2.5, 0.0]);
                assert_eq!(seq, 11);
                match work {
                    WorkSource::Owned(uids) => assert_eq!(uids, vec![7, 0, 300]),
                    _ => panic!("expected owned work"),
                }
            }
            Cmd::Stop => panic!("expected round"),
        }
        let (tag, payload) = encode_cmd(&Cmd::Stop).unwrap();
        assert_eq!((tag, payload.len()), (FRAME_STOP, 0));
        assert!(matches!(decode_cmd(FRAME_STOP, &[]).unwrap(), Cmd::Stop));
    }

    #[test]
    fn shared_work_is_unencodable() {
        let queue = Arc::new(crate::fl::CohortQueue::new(vec![1, 2, 3]));
        let cmd = Cmd::Round {
            ctx: CentralContext::train(0, 3, LocalParams::default(), 0),
            central: Arc::new(vec![]),
            work: WorkSource::Shared(queue),
            seq: 0,
        };
        assert!(matches!(encode_cmd(&cmd), Err(CommError::Unencodable(_))));
    }

    #[test]
    fn handshake_roundtrip() {
        let h = Hello { pid: 12345 };
        let mut buf = Vec::new();
        encode_hello(&mut buf, &h);
        let mut cur = Cursor::new(&buf);
        assert_eq!(decode_hello(&mut cur).unwrap(), h);
        cur.done().unwrap();

        let s = Setup {
            worker: 2,
            use_hlo_clip: true,
            heartbeat_ms: 250,
            config_json: "{\"name\":\"x\"}".into(),
        };
        let mut buf = Vec::new();
        encode_setup(&mut buf, &s);
        let mut cur = Cursor::new(&buf);
        assert_eq!(decode_setup(&mut cur).unwrap(), s);
        cur.done().unwrap();
    }

    #[test]
    fn unknown_tags_are_typed_errors() {
        let mut cur = Cursor::new(&[9]);
        assert!(matches!(
            decode_stat_value(&mut cur),
            Err(CommError::BadTag { what: "stat value", tag: 9 })
        ));
        assert!(matches!(
            decode_cmd(99, &[]),
            Err(CommError::BadTag { what: "command frame", tag: 99 })
        ));
    }
}
