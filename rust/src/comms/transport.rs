//! Socket transport: the process-boundary drivers behind the seam
//! (DESIGN.md §7).
//!
//! Topology: one server ([`SocketServer`] → [`SocketPool`]) and N
//! worker processes ([`WorkerConn`], the `pfl worker --connect ADDR`
//! entry point), over Unix-domain or TCP sockets. Address syntax:
//! anything containing `/` (or prefixed `unix:`) is a Unix socket
//! path; everything else is a TCP `host:port`.
//!
//! Failure model (one-strike): workers beacon a heartbeat frame every
//! `heartbeat_ms`; the server reads each connection with a 3× heartbeat
//! timeout, so a worker that is killed (`kill -9`), wedged, or
//! partitioned surfaces as a [`PoolEvent::Dead`] within one timeout.
//! The engine — not this layer — decides what to do with the dead
//! worker's in-flight uids (requeue to a live peer, preserving seq
//! order). A background accept loop keeps admitting replacement
//! workers into dead slots for the lifetime of the run
//! ([`PoolEvent::Joined`]).

use super::codec::{
    self, Hello, RoundMsg, Setup, FRAME_HEARTBEAT, FRAME_HELLO, FRAME_RESULT, FRAME_ROUND,
    FRAME_SETUP, FRAME_STOP,
};
use super::wire;
use super::CommError;
use crate::fl::context::CentralContext;
use crate::fl::worker::RoundResult;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
#[cfg(unix)]
use std::os::unix::net::{UnixListener, UnixStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// `unix:…` prefix or any path-looking string selects a Unix socket.
fn unix_path(addr: &str) -> Option<&str> {
    if let Some(p) = addr.strip_prefix("unix:") {
        Some(p)
    } else if addr.contains('/') {
        Some(addr)
    } else {
        None
    }
}

/// A connected byte stream over either socket family.
pub enum SocketStream {
    Tcp(TcpStream),
    #[cfg(unix)]
    Unix(UnixStream),
}

impl SocketStream {
    pub fn connect(addr: &str) -> Result<Self, CommError> {
        if let Some(path) = unix_path(addr) {
            #[cfg(unix)]
            {
                return Ok(SocketStream::Unix(UnixStream::connect(path)?));
            }
            #[cfg(not(unix))]
            {
                let _ = path;
                return Err(CommError::Unencodable("unix sockets unsupported on this platform"));
            }
        }
        let s = TcpStream::connect(addr)?;
        let _ = s.set_nodelay(true);
        Ok(SocketStream::Tcp(s))
    }

    fn try_clone(&self) -> Result<Self, CommError> {
        Ok(match self {
            SocketStream::Tcp(s) => SocketStream::Tcp(s.try_clone()?),
            #[cfg(unix)]
            SocketStream::Unix(s) => SocketStream::Unix(s.try_clone()?),
        })
    }

    fn set_read_timeout(&self, d: Option<Duration>) -> Result<(), CommError> {
        match self {
            SocketStream::Tcp(s) => s.set_read_timeout(d)?,
            #[cfg(unix)]
            SocketStream::Unix(s) => s.set_read_timeout(d)?,
        }
        Ok(())
    }
}

impl Read for SocketStream {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            SocketStream::Tcp(s) => s.read(buf),
            #[cfg(unix)]
            SocketStream::Unix(s) => s.read(buf),
        }
    }
}

impl Write for SocketStream {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            SocketStream::Tcp(s) => s.write(buf),
            #[cfg(unix)]
            SocketStream::Unix(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            SocketStream::Tcp(s) => s.flush(),
            #[cfg(unix)]
            SocketStream::Unix(s) => s.flush(),
        }
    }
}

enum Listener {
    Tcp(TcpListener),
    #[cfg(unix)]
    Unix(UnixListener),
}

impl Listener {
    fn accept(&self) -> std::io::Result<SocketStream> {
        match self {
            Listener::Tcp(l) => {
                let (s, _) = l.accept()?;
                let _ = s.set_nodelay(true);
                // Some platforms propagate the listener's non-blocking
                // flag to accepted sockets; the frame reader needs a
                // blocking stream.
                let _ = s.set_nonblocking(false);
                Ok(SocketStream::Tcp(s))
            }
            #[cfg(unix)]
            Listener::Unix(l) => {
                let (s, _) = l.accept()?;
                let _ = s.set_nonblocking(false);
                Ok(SocketStream::Unix(s))
            }
        }
    }

    fn set_nonblocking(&self, nb: bool) -> std::io::Result<()> {
        match self {
            Listener::Tcp(l) => l.set_nonblocking(nb),
            #[cfg(unix)]
            Listener::Unix(l) => l.set_nonblocking(nb),
        }
    }
}

// ================================================================= worker

/// Client side of the seam: one connection from a worker process back
/// to the server, plus the background heartbeat beacon.
pub struct WorkerConn {
    reader: SocketStream,
    writer: Arc<Mutex<SocketStream>>,
    /// The server's handshake reply: slot, heartbeat interval, config.
    pub setup: Setup,
    hb_stop: Arc<AtomicBool>,
    hb: Option<JoinHandle<()>>,
}

impl WorkerConn {
    /// Dial the server, introduce ourselves, and receive the [`Setup`]
    /// (worker slot + run config). Starts the heartbeat thread.
    pub fn connect(addr: &str) -> Result<Self, CommError> {
        let mut stream = SocketStream::connect(addr)?;
        wire::write_preamble(&mut stream)?;
        let mut buf = Vec::new();
        codec::encode_hello(&mut buf, &Hello { pid: std::process::id() });
        wire::write_frame(&mut stream, FRAME_HELLO, &buf)?;
        // Bound the handshake read; cleared afterwards — a worker waiting
        // for round work blocks indefinitely (server death is an EOF).
        stream.set_read_timeout(Some(Duration::from_secs(30)))?;
        wire::read_preamble(&mut stream)?;
        let (tag, payload, _) = wire::read_frame(&mut stream)?;
        if tag != FRAME_SETUP {
            return Err(CommError::BadTag { what: "setup frame", tag });
        }
        let mut cur = wire::Cursor::new(&payload);
        let setup = codec::decode_setup(&mut cur)?;
        cur.done()?;
        stream.set_read_timeout(None)?;

        let writer = Arc::new(Mutex::new(stream.try_clone()?));
        let hb_stop = Arc::new(AtomicBool::new(false));
        let hb = {
            let writer = Arc::clone(&writer);
            let stop = Arc::clone(&hb_stop);
            let interval = Duration::from_millis(setup.heartbeat_ms.max(1));
            std::thread::Builder::new()
                .name("comms-heartbeat".into())
                .spawn(move || heartbeat_loop(writer, stop, interval))
                .map_err(std::io::Error::from)?
        };
        Ok(WorkerConn { reader: stream, writer, setup, hb_stop, hb: Some(hb) })
    }

    /// Block for the next unit of work. `Ok(None)` is an orderly stop
    /// (explicit [`FRAME_STOP`] or server EOF at a frame boundary).
    pub fn recv(&mut self) -> Result<Option<RoundMsg>, CommError> {
        match wire::read_frame(&mut self.reader) {
            Ok((FRAME_ROUND, payload, _)) => {
                let mut cur = wire::Cursor::new(&payload);
                let msg = codec::decode_round(&mut cur)?;
                cur.done()?;
                Ok(Some(msg))
            }
            Ok((FRAME_STOP, _, _)) => Ok(None),
            Ok((tag, _, _)) => Err(CommError::BadTag { what: "server frame", tag }),
            Err(CommError::Closed) => Ok(None),
            Err(e) => Err(e),
        }
    }

    pub fn send_result(&self, r: &RoundResult) -> Result<(), CommError> {
        let mut buf = Vec::new();
        codec::encode_round_result(&mut buf, r);
        let mut w = self.writer.lock().unwrap();
        wire::write_frame(&mut *w, FRAME_RESULT, &buf)?;
        Ok(())
    }
}

impl Drop for WorkerConn {
    fn drop(&mut self) {
        self.hb_stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.hb.take() {
            let _ = h.join();
        }
    }
}

fn heartbeat_loop(writer: Arc<Mutex<SocketStream>>, stop: Arc<AtomicBool>, interval: Duration) {
    loop {
        // Chunked sleep so Drop never waits a full interval to join.
        let deadline = Instant::now() + interval;
        while Instant::now() < deadline {
            if stop.load(Ordering::SeqCst) {
                return;
            }
            std::thread::sleep(Duration::from_millis(20).min(interval));
        }
        let mut w = writer.lock().unwrap();
        if wire::write_frame(&mut *w, FRAME_HEARTBEAT, &[]).is_err() {
            return;
        }
    }
}

// ================================================================= server

/// Everything a worker needs beyond its slot number; `worker` is filled
/// in per accepted connection.
#[derive(Debug, Clone)]
pub struct SetupSpec {
    pub use_hlo_clip: bool,
    /// Worker heartbeat interval; server read timeout is 3× this.
    pub heartbeat_ms: u64,
    /// Full run config as JSON — workers rebuild dataset + algorithm
    /// from it (datasets here are config-derived).
    pub config_json: String,
}

/// A bound listener, not yet serving. Split from [`SocketPool`] so the
/// caller can learn the resolved address (`--listen 127.0.0.1:0`) and
/// launch worker processes *before* blocking in the accept loop.
pub struct SocketServer {
    listener: Listener,
    local: String,
}

impl SocketServer {
    pub fn bind(addr: &str) -> Result<Self, CommError> {
        if let Some(path) = unix_path(addr) {
            #[cfg(unix)]
            {
                let _ = std::fs::remove_file(path);
                let l = UnixListener::bind(path)?;
                return Ok(SocketServer {
                    listener: Listener::Unix(l),
                    local: format!("unix:{path}"),
                });
            }
            #[cfg(not(unix))]
            {
                let _ = path;
                return Err(CommError::Unencodable("unix sockets unsupported on this platform"));
            }
        }
        let l = TcpListener::bind(addr)?;
        let local = l.local_addr()?.to_string();
        Ok(SocketServer { listener: Listener::Tcp(l), local })
    }

    /// The resolved address workers should `--connect` to (port 0 is
    /// resolved to the actual port).
    pub fn local_addr(&self) -> &str {
        &self.local
    }

    /// Accept `num_workers` handshakes, then hand the listener to a
    /// background accept loop that admits replacements into dead slots.
    pub fn into_pool(self, num_workers: usize, spec: SetupSpec) -> Result<SocketPool, CommError> {
        assert!(num_workers > 0, "socket pool needs at least one worker");
        let shared = Arc::new(PoolShared {
            writers: (0..num_workers).map(|_| Mutex::new(None)).collect(),
            alive: (0..num_workers).map(|_| AtomicBool::new(false)).collect(),
            bytes_in: AtomicU64::new(0),
            bytes_out: AtomicU64::new(0),
        });
        let (events_tx, events_rx) = channel();
        for slot in 0..num_workers {
            loop {
                let stream = self.listener.accept()?;
                match handshake(stream, slot, &spec) {
                    Ok(stream) => {
                        spawn_reader(&shared, slot, stream, &events_tx)?;
                        break;
                    }
                    // A worker that died before completing the handshake
                    // is not fatal — wait for the next connection.
                    Err(_) => continue,
                }
            }
        }
        let accept_stop = Arc::new(AtomicBool::new(false));
        let accept_handle = {
            let shared = Arc::clone(&shared);
            let stop = Arc::clone(&accept_stop);
            let events = events_tx.clone();
            let listener = self.listener;
            std::thread::Builder::new()
                .name("comms-accept".into())
                .spawn(move || accept_loop(shared, listener, stop, spec, events))
                .map_err(std::io::Error::from)?
        };
        Ok(SocketPool {
            shared,
            events_rx,
            events_tx,
            accept_stop,
            accept_handle: Some(accept_handle),
            num_workers,
        })
    }
}

struct PoolShared {
    writers: Vec<Mutex<Option<SocketStream>>>,
    alive: Vec<AtomicBool>,
    bytes_in: AtomicU64,
    bytes_out: AtomicU64,
}

impl PoolShared {
    /// First marker wins: exactly one `Dead` event per death, however
    /// many paths (reader error, write failure) detect it.
    fn mark_dead(&self, worker: usize, reason: String, events: &Sender<PoolEvent>) {
        if self.alive[worker].swap(false, Ordering::SeqCst) {
            if let Ok(mut g) = self.writers[worker].lock() {
                *g = None;
            }
            let _ = events.send(PoolEvent::Dead { worker, reason });
        }
    }
}

/// What the server-side engine drains from [`SocketPool::recv_event`].
pub enum PoolEvent {
    /// A worker finished a unit of round work.
    Result(Box<RoundResult>),
    /// A worker's connection died (EOF, I/O error, or 3× heartbeat
    /// timeout). Its in-flight uids are the engine's to requeue.
    Dead { worker: usize, reason: String },
    /// A replacement worker completed the handshake into a dead slot.
    Joined { worker: usize },
}

/// Server side of the seam: per-worker connections drained by reader
/// threads into one event queue, plus liveness + wire accounting.
pub struct SocketPool {
    shared: Arc<PoolShared>,
    events_rx: Receiver<PoolEvent>,
    events_tx: Sender<PoolEvent>,
    accept_stop: Arc<AtomicBool>,
    accept_handle: Option<JoinHandle<()>>,
    num_workers: usize,
}

impl SocketPool {
    pub fn num_workers(&self) -> usize {
        self.num_workers
    }

    pub fn alive(&self, worker: usize) -> bool {
        self.shared.alive[worker].load(Ordering::SeqCst)
    }

    pub fn alive_count(&self) -> usize {
        self.shared.alive.iter().filter(|a| a.load(Ordering::SeqCst)).count()
    }

    /// Cumulative (bytes received, bytes sent) over all connections.
    pub fn wire_bytes(&self) -> (u64, u64) {
        (self.shared.bytes_in.load(Ordering::Relaxed), self.shared.bytes_out.load(Ordering::Relaxed))
    }

    /// Ship one seq-stamped unit of work to `worker`. A write failure
    /// (or an already-dead worker) is not an error here: the death is
    /// published as a [`PoolEvent::Dead`] and the engine requeues the
    /// in-flight uids when it drains the event.
    pub fn send_round(
        &self,
        worker: usize,
        ctx: &CentralContext,
        central: &[f32],
        uids: &[usize],
        seq: u64,
    ) -> Result<(), CommError> {
        let mut payload = Vec::with_capacity(central.len() * 4 + 64);
        codec::encode_round(&mut payload, seq, ctx, central, uids);
        let mut guard = self.shared.writers[worker].lock().unwrap();
        let Some(stream) = guard.as_mut() else {
            return Ok(()); // already dead; Dead event already queued
        };
        match wire::write_frame(stream, FRAME_ROUND, &payload) {
            Ok(n) => {
                self.shared.bytes_out.fetch_add(n, Ordering::Relaxed);
                Ok(())
            }
            Err(e) => {
                *guard = None;
                drop(guard);
                if self.shared.alive[worker].swap(false, Ordering::SeqCst) {
                    let _ = self
                        .events_tx
                        .send(PoolEvent::Dead { worker, reason: format!("send failed: {e}") });
                }
                Ok(())
            }
        }
    }

    /// Block for the next pool event.
    pub fn recv_event(&self) -> Result<PoolEvent, CommError> {
        self.events_rx.recv().map_err(|_| CommError::Closed)
    }

    /// Stop accepting replacements and send an orderly stop to every
    /// live worker.
    pub fn shutdown(&mut self) {
        self.accept_stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
        for w in &self.shared.writers {
            if let Ok(mut g) = w.lock() {
                if let Some(stream) = g.as_mut() {
                    let _ = wire::write_frame(stream, FRAME_STOP, &[]);
                }
                *g = None;
            }
        }
    }
}

impl Drop for SocketPool {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Complete the preamble/Hello/Setup exchange on a fresh connection and
/// arm the steady-state read timeout (3× heartbeat, one strike).
fn handshake(mut stream: SocketStream, slot: usize, spec: &SetupSpec) -> Result<SocketStream, CommError> {
    stream.set_read_timeout(Some(Duration::from_secs(10)))?;
    wire::write_preamble(&mut stream)?;
    wire::read_preamble(&mut stream)?;
    let (tag, payload, _) = wire::read_frame(&mut stream)?;
    if tag != FRAME_HELLO {
        return Err(CommError::BadTag { what: "handshake frame", tag });
    }
    let mut cur = wire::Cursor::new(&payload);
    let _hello = codec::decode_hello(&mut cur)?;
    cur.done()?;
    let setup = Setup {
        worker: slot,
        use_hlo_clip: spec.use_hlo_clip,
        heartbeat_ms: spec.heartbeat_ms,
        config_json: spec.config_json.clone(),
    };
    let mut buf = Vec::new();
    codec::encode_setup(&mut buf, &setup);
    wire::write_frame(&mut stream, FRAME_SETUP, &buf)?;
    stream.set_read_timeout(Some(Duration::from_millis(spec.heartbeat_ms.saturating_mul(3).max(1))))?;
    Ok(stream)
}

/// Install a handshaken connection into `slot` and start its reader.
fn spawn_reader(
    shared: &Arc<PoolShared>,
    slot: usize,
    stream: SocketStream,
    events: &Sender<PoolEvent>,
) -> Result<(), CommError> {
    let reader = stream.try_clone()?;
    *shared.writers[slot].lock().unwrap() = Some(stream);
    shared.alive[slot].store(true, Ordering::SeqCst);
    let shared = Arc::clone(shared);
    let events = events.clone();
    std::thread::Builder::new()
        .name(format!("comms-reader-{slot}"))
        .spawn(move || reader_loop(shared, slot, reader, events))
        .map_err(std::io::Error::from)?;
    Ok(())
}

fn reader_loop(shared: Arc<PoolShared>, worker: usize, mut stream: SocketStream, events: Sender<PoolEvent>) {
    loop {
        match wire::read_frame(&mut stream) {
            Ok((FRAME_RESULT, payload, n)) => {
                shared.bytes_in.fetch_add(n, Ordering::Relaxed);
                let mut cur = wire::Cursor::new(&payload);
                let decoded = codec::decode_round_result(&mut cur).and_then(|r| {
                    cur.done()?;
                    Ok(r)
                });
                match decoded {
                    Ok(r) => {
                        if events.send(PoolEvent::Result(Box::new(r))).is_err() {
                            return; // pool dropped
                        }
                    }
                    Err(e) => {
                        shared.mark_dead(worker, format!("undecodable result: {e}"), &events);
                        return;
                    }
                }
            }
            Ok((FRAME_HEARTBEAT, _, n)) => {
                shared.bytes_in.fetch_add(n, Ordering::Relaxed);
            }
            Ok((tag, _, _)) => {
                shared.mark_dead(worker, format!("unexpected frame tag {tag}"), &events);
                return;
            }
            Err(e) => {
                shared.mark_dead(worker, e.to_string(), &events);
                return;
            }
        }
    }
}

fn accept_loop(
    shared: Arc<PoolShared>,
    listener: Listener,
    stop: Arc<AtomicBool>,
    spec: SetupSpec,
    events: Sender<PoolEvent>,
) {
    if listener.set_nonblocking(true).is_err() {
        return;
    }
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok(stream) => {
                let slot =
                    (0..shared.alive.len()).find(|&w| !shared.alive[w].load(Ordering::SeqCst));
                let Some(slot) = slot else {
                    continue; // all slots live: refuse the extra worker
                };
                if let Ok(stream) = handshake(stream, slot, &spec) {
                    if spawn_reader(&shared, slot, stream, &events).is_ok() {
                        let _ = events.send(PoolEvent::Joined { worker: slot });
                    }
                }
            }
            Err(_) => std::thread::sleep(Duration::from_millis(20)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fl::context::LocalParams;
    use crate::fl::metrics::Metrics;
    use crate::fl::stats::Statistics;
    use crate::simsys::Counters;
    use crate::tensor::StatValue;

    fn spec(config: &str) -> SetupSpec {
        SetupSpec { use_hlo_clip: false, heartbeat_ms: 100, config_json: config.into() }
    }

    // Satellite: loopback property tests — every Cmd/RoundResult/
    // StatValue variant round-trips bit-identically through
    // encode → socketpair → decode.
    #[cfg(unix)]
    #[test]
    fn frames_roundtrip_through_unix_socketpair() {
        use crate::fl::worker::Cmd;
        use crate::fl::WorkSource;
        use std::sync::Arc as StdArc;

        let (mut a, mut b) = UnixStream::pair().unwrap();
        let stat_cases = vec![
            StatValue::Dense(vec![1.0, -2.0, 0.5]),
            StatValue::Sparse { dim: 9, idx: vec![], val: vec![] },
            StatValue::Sparse { dim: 300, idx: vec![5, 7], val: vec![0.5, -0.25] },
            StatValue::Quantized { dim: 2, scale: 1.5, bits: 8, idx: None, data: vec![0x7F, 0x81] },
            StatValue::Quantized { dim: 64, scale: 0.5, bits: 8, idx: Some(vec![1, 63]), data: vec![9, 200] },
        ];
        for v in &stat_cases {
            let mut buf = Vec::new();
            codec::encode_stat_value(&mut buf, v);
            wire::write_frame(&mut a, 42, &buf).unwrap();
            let (tag, payload, _) = wire::read_frame(&mut b).unwrap();
            assert_eq!(tag, 42);
            assert_eq!(payload, buf, "bytes must survive the socket unchanged");
            let mut cur = wire::Cursor::new(&payload);
            let back = codec::decode_stat_value(&mut cur).unwrap();
            cur.done().unwrap();
            assert_eq!(&back, v);
        }

        // Every Cmd variant (Shared is Unencodable by contract, tested in
        // the codec module).
        let ctx = CentralContext::train(4, 8, LocalParams::default(), 99);
        let cmds = vec![
            Cmd::Round {
                ctx,
                central: StdArc::new(vec![0.25; 6]),
                work: WorkSource::Owned(vec![1, 2, 3]),
                seq: 17,
            },
            Cmd::Stop,
        ];
        for cmd in &cmds {
            let (tag, payload) = codec::encode_cmd(cmd).unwrap();
            wire::write_frame(&mut a, tag, &payload).unwrap();
            let (rtag, rpayload, _) = wire::read_frame(&mut b).unwrap();
            assert_eq!((rtag, &rpayload), (tag, &payload));
            let back = codec::decode_cmd(rtag, &rpayload).unwrap();
            let (tag2, payload2) = codec::encode_cmd(&back).unwrap();
            assert_eq!((tag2, payload2), (tag, payload));
        }

        // RoundResult with an int8-quantized partial and an empty-sparse
        // entry — the codec edge cases — across the pair, both ways.
        let mut stats = Statistics { weight: 4.0, ..Default::default() };
        stats.vecs.insert("update".into(), stat_cases[3].clone());
        stats.vecs.insert("mask".into(), stat_cases[1].clone());
        let mut metrics = Metrics::new();
        metrics.add_central("loss", 2.0, 1.0);
        let r = RoundResult {
            worker: 1,
            round: 4,
            seq: 17,
            partial: Some(stats),
            metrics,
            counters: Counters { users_trained: 3, stat_bytes: 11, ..Default::default() },
            costs: vec![],
            error: None,
        };
        let mut buf = Vec::new();
        codec::encode_round_result(&mut buf, &r);
        wire::write_frame(&mut b, FRAME_RESULT, &buf).unwrap();
        let (tag, payload, _) = wire::read_frame(&mut a).unwrap();
        assert_eq!(tag, FRAME_RESULT);
        let mut cur = wire::Cursor::new(&payload);
        let back = codec::decode_round_result(&mut cur).unwrap();
        cur.done().unwrap();
        let mut again = Vec::new();
        codec::encode_round_result(&mut again, &back);
        assert_eq!(again, buf);
        assert_eq!(back.partial, r.partial);
    }

    #[test]
    fn frames_roundtrip_through_tcp_loopback() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let t = std::thread::spawn(move || {
            let mut client = TcpStream::connect(addr).unwrap();
            let (tag, payload, _) = wire::read_frame(&mut client).unwrap();
            wire::write_frame(&mut client, tag, &payload).unwrap(); // echo
        });
        let (mut server, _) = listener.accept().unwrap();
        let v = StatValue::Sparse { dim: 1000, idx: vec![0, 999], val: vec![1.0, -1.0] };
        let mut buf = Vec::new();
        codec::encode_stat_value(&mut buf, &v);
        wire::write_frame(&mut server, 7, &buf).unwrap();
        let (tag, echoed, _) = wire::read_frame(&mut server).unwrap();
        assert_eq!((tag, &echoed), (7, &buf));
        let mut cur = wire::Cursor::new(&echoed);
        assert_eq!(codec::decode_stat_value(&mut cur).unwrap(), v);
        t.join().unwrap();
    }

    #[test]
    fn pool_handshake_roundtrip_and_result_event() {
        let server = SocketServer::bind("127.0.0.1:0").unwrap();
        let addr = server.local_addr().to_string();
        let client = std::thread::spawn(move || {
            let mut conn = WorkerConn::connect(&addr).unwrap();
            assert_eq!(conn.setup.worker, 0);
            assert_eq!(conn.setup.config_json, "{\"cfg\":1}");
            let msg = conn.recv().unwrap().expect("expected round work");
            assert_eq!(msg.seq, 5);
            assert_eq!(msg.uids, vec![3]);
            assert_eq!(msg.central, vec![1.5, -0.5]);
            let r = RoundResult {
                worker: conn.setup.worker,
                round: msg.ctx.iteration,
                seq: msg.seq,
                partial: None,
                metrics: Metrics::new(),
                counters: Counters::default(),
                costs: vec![],
                error: None,
            };
            conn.send_result(&r).unwrap();
            assert!(conn.recv().unwrap().is_none(), "expected stop");
        });
        let mut pool = server.into_pool(1, spec("{\"cfg\":1}")).unwrap();
        assert_eq!(pool.alive_count(), 1);
        let ctx = CentralContext::train(2, 1, LocalParams::default(), 0);
        pool.send_round(0, &ctx, &[1.5, -0.5], &[3], 5).unwrap();
        match pool.recv_event().unwrap() {
            PoolEvent::Result(r) => {
                assert_eq!(r.seq, 5);
                assert_eq!(r.worker, 0);
            }
            PoolEvent::Dead { reason, .. } => panic!("worker died: {reason}"),
            PoolEvent::Joined { .. } => panic!("unexpected join"),
        }
        let (bin, bout) = pool.wire_bytes();
        assert!(bin > 0 && bout > 0, "wire accounting must tick ({bin}/{bout})");
        pool.shutdown();
        client.join().unwrap();
    }

    #[test]
    fn dead_worker_surfaces_and_replacement_joins() {
        let server = SocketServer::bind("127.0.0.1:0").unwrap();
        let addr = server.local_addr().to_string();
        let first = std::thread::spawn({
            let addr = addr.clone();
            move || {
                let conn = WorkerConn::connect(&addr).unwrap();
                drop(conn); // abrupt exit: EOF on the server side
            }
        });
        let pool = server.into_pool(1, spec("{}")).unwrap();
        first.join().unwrap();
        match pool.recv_event().unwrap() {
            PoolEvent::Dead { worker: 0, .. } => {}
            _ => panic!("expected Dead for worker 0"),
        }
        assert_eq!(pool.alive_count(), 0);
        // A replacement connects into the dead slot.
        let second = std::thread::spawn(move || {
            let mut conn = WorkerConn::connect(&addr).unwrap();
            assert_eq!(conn.setup.worker, 0);
            assert!(conn.recv().unwrap().is_none()); // stop
        });
        match pool.recv_event().unwrap() {
            PoolEvent::Joined { worker: 0 } => {}
            PoolEvent::Dead { reason, .. } => panic!("unexpected death: {reason}"),
            PoolEvent::Result(_) => panic!("unexpected result"),
        }
        assert_eq!(pool.alive_count(), 1);
        drop(pool); // shutdown sends Stop to the replacement
        second.join().unwrap();
    }

    #[cfg(unix)]
    #[test]
    fn unix_socket_server_roundtrip() {
        let dir = std::env::temp_dir().join(format!("pfl-comms-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("srv.sock");
        let addr = format!("unix:{}", path.display());
        let server = SocketServer::bind(&addr).unwrap();
        let local = server.local_addr().to_string();
        assert_eq!(local, addr);
        let client = std::thread::spawn(move || {
            let mut conn = WorkerConn::connect(&local).unwrap();
            assert!(conn.recv().unwrap().is_none());
        });
        let mut pool = server.into_pool(1, spec("{}")).unwrap();
        assert!(pool.alive(0));
        pool.shutdown();
        client.join().unwrap();
        let _ = std::fs::remove_file(&path);
    }
}
