//! Transport seam between dispatcher and workers (DESIGN.md §7).
//!
//! Everything that crosses the dispatcher↔worker boundary goes through
//! this module, whatever carries it:
//!
//! * [`wire`] — length-framed byte protocol: a 5-byte connection
//!   preamble (magic `PFLC` + version) followed by `tag · varint-length
//!   · payload` frames. LEB128 varints, little-endian scalars, no
//!   external dependencies.
//! * [`codec`] — explicit encode/decode for every domain type that
//!   crosses the seam: `Cmd`, `RoundResult`, model deltas
//!   (`Statistics`), all `StatValue` variants, `Metrics`, `Counters`,
//!   `CentralContext`. The in-process coordinator tax and the socket
//!   transport share this single byte path.
//! * [`transport`] — Unix-domain/TCP socket drivers: [`transport::WorkerConn`]
//!   (the `pfl worker --connect ADDR` client) and
//!   [`transport::SocketServer`] → [`transport::SocketPool`] (the
//!   server-side event loop feeding `--dispatch socket` runs), with
//!   heartbeat + read-timeout dead-worker detection.
//!
//! Failure is typed: every fallible operation returns [`CommError`], so
//! the engine can distinguish a dead peer ([`CommError::Closed`], I/O
//! timeouts) from a protocol bug (bad magic/tag/length) and requeue or
//! abort accordingly.

pub mod codec;
pub mod transport;
pub mod wire;

pub use transport::{PoolEvent, SetupSpec, SocketPool, SocketServer, WorkerConn};

/// Typed communication failure — everything the wire layer can report.
#[derive(Debug)]
pub enum CommError {
    /// Underlying socket/pipe error (includes read timeouts).
    Io(std::io::Error),
    /// Connection preamble did not start with `PFLC`.
    BadMagic([u8; 4]),
    /// Peer speaks a different wire version.
    BadVersion { got: u8, want: u8 },
    /// A payload ended before a field was fully read.
    Truncated { need: usize, have: usize },
    /// Unknown discriminant for `what` (frame, stat value, metric, …).
    BadTag { what: &'static str, tag: u8 },
    /// Declared frame length exceeds [`wire::MAX_FRAME_LEN`].
    FrameTooLarge { len: u64 },
    /// Structurally invalid payload (overlong varint, bad UTF-8, …).
    Malformed(&'static str),
    /// The value cannot be represented on the wire (e.g. a shared
    /// in-process work queue).
    Unencodable(&'static str),
    /// Orderly EOF at a frame boundary — the peer went away.
    Closed,
}

impl std::fmt::Display for CommError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CommError::Io(e) => write!(f, "i/o: {e}"),
            CommError::BadMagic(m) => write!(f, "bad magic {m:02x?} (expected \"PFLC\")"),
            CommError::BadVersion { got, want } => {
                write!(f, "peer speaks wire version {got}, this build speaks {want}")
            }
            CommError::Truncated { need, have } => {
                write!(f, "truncated payload: need {need} more bytes, have {have}")
            }
            CommError::BadTag { what, tag } => write!(f, "unknown {what} tag {tag}"),
            CommError::FrameTooLarge { len } => write!(f, "frame length {len} exceeds limit"),
            CommError::Malformed(m) => write!(f, "malformed payload: {m}"),
            CommError::Unencodable(m) => write!(f, "cannot encode: {m}"),
            CommError::Closed => write!(f, "connection closed by peer"),
        }
    }
}

impl std::error::Error for CommError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            CommError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for CommError {
    fn from(e: std::io::Error) -> Self {
        CommError::Io(e)
    }
}
