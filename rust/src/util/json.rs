//! Minimal JSON parser/writer (the build environment is offline and
//! serde/serde_json are not in the cargo cache — see Cargo.toml).
//!
//! Supports the full JSON grammar needed by `artifacts/manifest.json`,
//! run configs and experiment reports: objects, arrays, strings (with
//! escapes), numbers, booleans, null.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn parse(text: &str) -> Result<Value> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn req(&self, key: &str) -> Result<&Value> {
        self.get(key).ok_or_else(|| anyhow!("missing key {key:?}"))
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Num(x) => Ok(*x),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    pub fn as_u64(&self) -> Result<u64> {
        Ok(self.as_f64()? as u64)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Value]> {
        match self {
            Value::Arr(v) => Ok(v),
            _ => bail!("expected array, got {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Ok(m),
            _ => bail!("expected object, got {self:?}"),
        }
    }

    pub fn usize_arr(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    // ---- writer ----------------------------------------------------------

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, true);
        s
    }

    pub fn to_json(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, false);
        s
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push(' ');
                }
            }
        };
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Value::Str(s) => write_escaped(out, s),
            Value::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    item.write(out, indent + 1, pretty);
                }
                if !v.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Value::Obj(m) => {
                out.push('{');
                for (i, (k, item)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    item.write(out, indent + 1, pretty);
                }
                if !m.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

// Convenience constructors for building reports.
pub fn num(x: f64) -> Value {
    Value::Num(x)
}
pub fn s(v: impl Into<String>) -> Value {
    Value::Str(v.into())
}
pub fn arr(v: Vec<Value>) -> Value {
    Value::Arr(v)
}
pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at byte {}, got {:?}", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Value> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Value::Str(self.string()?)),
            b't' => self.lit("true", Value::Bool(true)),
            b'f' => self.lit("false", Value::Bool(false)),
            b'n' => self.lit("null", Value::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Value::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Value::Obj(m));
                }
                c => bail!("expected ',' or '}}' at byte {}, got {:?}", self.i, c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Value::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Value::Arr(v));
                }
                c => bail!("expected ',' or ']' at byte {}, got {:?}", self.i, c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape at byte {}", self.i),
                    }
                }
                c => {
                    // copy raw utf-8 bytes through
                    let start = self.i - 1;
                    let mut end = self.i;
                    if c >= 0x80 {
                        while end < self.b.len() && self.b[end] >= 0x80 {
                            end += 1;
                        }
                        self.i = end;
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Value::Num(text.parse::<f64>().map_err(|e| anyhow!("bad number {text:?}: {e}"))?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_manifest_like() {
        let text = r#"{"format": "hlo-text", "models": {"a": {"n": 3, "xs": [1, 2.5, -3e2], "ok": true, "none": null}}, "s": "he\"llo\nworld"}"#;
        let v = Value::parse(text).unwrap();
        assert_eq!(v.req("format").unwrap().as_str().unwrap(), "hlo-text");
        let a = v.req("models").unwrap().req("a").unwrap();
        assert_eq!(a.req("n").unwrap().as_usize().unwrap(), 3);
        assert_eq!(a.req("xs").unwrap().as_arr().unwrap()[2].as_f64().unwrap(), -300.0);
        assert!(a.req("ok").unwrap().as_bool().unwrap());
        assert_eq!(v.req("s").unwrap().as_str().unwrap(), "he\"llo\nworld");
        // reparse what we write
        let v2 = Value::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, v2);
        let v3 = Value::parse(&v.to_json()).unwrap();
        assert_eq!(v, v3);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Value::parse("{").is_err());
        assert!(Value::parse("[1,]").is_err());
        assert!(Value::parse("{\"a\" 1}").is_err());
        assert!(Value::parse("12 34").is_err());
        assert!(Value::parse("").is_err());
    }

    #[test]
    fn unicode_escapes() {
        let v = Value::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "Aé");
    }

    #[test]
    fn nested_empty() {
        let v = Value::parse(r#"{"a": [], "b": {}}"#).unwrap();
        assert!(v.req("a").unwrap().as_arr().unwrap().is_empty());
        assert!(v.req("b").unwrap().as_obj().unwrap().is_empty());
    }

    #[test]
    fn builders() {
        let v = obj(vec![("x", num(1.0)), ("y", arr(vec![s("a")]))]);
        assert_eq!(Value::parse(&v.to_json()).unwrap(), v);
    }
}
