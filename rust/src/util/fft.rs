//! Iterative radix-2 FFT (f64) — offline build, so no external FFT crate.
//! Used by the PLD privacy accountant for T-fold self-convolution of the
//! privacy-loss pmf.

use std::f64::consts::PI;

/// In-place iterative Cooley–Tukey FFT over interleaved (re, im) pairs.
/// `n` must be a power of two. `inverse` applies the conjugate transform
/// (unnormalized — caller divides by n).
fn fft_in_place(re: &mut [f64], im: &mut [f64], inverse: bool) {
    let n = re.len();
    debug_assert!(n.is_power_of_two());
    // bit reversal permutation
    let mut j = 0usize;
    for i in 1..n {
        let mut bit = n >> 1;
        while j & bit != 0 {
            j ^= bit;
            bit >>= 1;
        }
        j |= bit;
        if i < j {
            re.swap(i, j);
            im.swap(i, j);
        }
    }
    let sign = if inverse { 1.0 } else { -1.0 };
    let mut len = 2;
    while len <= n {
        let ang = sign * 2.0 * PI / len as f64;
        let (wr, wi) = (ang.cos(), ang.sin());
        let mut i = 0;
        while i < n {
            let (mut cur_r, mut cur_i) = (1.0f64, 0.0f64);
            for k in 0..len / 2 {
                let (ur, ui) = (re[i + k], im[i + k]);
                let (vr0, vi0) = (re[i + k + len / 2], im[i + k + len / 2]);
                let vr = vr0 * cur_r - vi0 * cur_i;
                let vi = vr0 * cur_i + vi0 * cur_r;
                re[i + k] = ur + vr;
                im[i + k] = ui + vi;
                re[i + k + len / 2] = ur - vr;
                im[i + k + len / 2] = ui - vi;
                let nr = cur_r * wr - cur_i * wi;
                cur_i = cur_r * wi + cur_i * wr;
                cur_r = nr;
            }
            i += len;
        }
        len <<= 1;
    }
}

/// Linear convolution of two non-negative pmfs via FFT. Output length is
/// `a.len() + b.len() - 1`. Tiny negative round-off values are clamped.
pub fn convolve(a: &[f64], b: &[f64]) -> Vec<f64> {
    if a.is_empty() || b.is_empty() {
        return Vec::new();
    }
    let out_len = a.len() + b.len() - 1;
    // below this size plain O(n*m) is faster than three FFTs
    if a.len().min(b.len()) <= 64 || out_len <= 1024 {
        let mut out = vec![0.0; out_len];
        for (i, &x) in a.iter().enumerate() {
            if x == 0.0 {
                continue;
            }
            for (j, &y) in b.iter().enumerate() {
                out[i + j] += x * y;
            }
        }
        return out;
    }
    let n = out_len.next_power_of_two();
    let mut ar = vec![0.0; n];
    let mut ai = vec![0.0; n];
    let mut br = vec![0.0; n];
    let mut bi = vec![0.0; n];
    ar[..a.len()].copy_from_slice(a);
    br[..b.len()].copy_from_slice(b);
    fft_in_place(&mut ar, &mut ai, false);
    fft_in_place(&mut br, &mut bi, false);
    for i in 0..n {
        let r = ar[i] * br[i] - ai[i] * bi[i];
        let im = ar[i] * bi[i] + ai[i] * br[i];
        ar[i] = r;
        ai[i] = im;
    }
    fft_in_place(&mut ar, &mut ai, true);
    let inv = 1.0 / n as f64;
    ar.truncate(out_len);
    for v in ar.iter_mut() {
        *v = (*v * inv).max(0.0);
    }
    ar
}

#[cfg(test)]
mod tests {
    use super::*;

    fn direct(a: &[f64], b: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; a.len() + b.len() - 1];
        for (i, &x) in a.iter().enumerate() {
            for (j, &y) in b.iter().enumerate() {
                out[i + j] += x * y;
            }
        }
        out
    }

    #[test]
    fn small_convolution_exact() {
        let a = [1.0, 2.0, 3.0];
        let b = [0.5, 0.5];
        assert_eq!(convolve(&a, &b), direct(&a, &b));
    }

    #[test]
    fn fft_path_matches_direct() {
        // sizes large enough to take the FFT path
        let a: Vec<f64> = (0..700).map(|i| ((i * 37) % 11) as f64 / 11.0).collect();
        let b: Vec<f64> = (0..900).map(|i| ((i * 17) % 7) as f64 / 7.0).collect();
        let fast = convolve(&a, &b);
        let slow = direct(&a, &b);
        assert_eq!(fast.len(), slow.len());
        let max: f64 = slow.iter().fold(0.0, |m, &x| m.max(x.abs()));
        for (x, y) in fast.iter().zip(&slow) {
            assert!((x - y).abs() < 1e-9 * max.max(1.0), "{x} vs {y}");
        }
    }

    #[test]
    fn pmf_mass_is_preserved() {
        let a: Vec<f64> = (0..2048).map(|i| if i % 3 == 0 { 1.0 } else { 0.25 }).collect();
        let sa: f64 = a.iter().sum();
        let c = convolve(&a, &a);
        let sc: f64 = c.iter().sum();
        assert!((sc - sa * sa).abs() / (sa * sa) < 1e-12);
    }

    #[test]
    fn empty_inputs() {
        assert!(convolve(&[], &[1.0]).is_empty());
    }
}
