//! Deterministic RNG + the distributions the simulator needs.
//!
//! (Offline build: the `rand`/`rand_distr` crates are not in the cargo
//! cache, and DP noise generation wants explicit, auditable sampling
//! anyway.) Core generator is splitmix64-seeded xoshiro256++ — fast,
//! high-quality, and trivially reproducible across platforms.
//!
//! Distributions: uniform, normal (Box–Muller with caching), laplace
//! (inverse CDF), poisson (Knuth for small mean, PTRS-style normal
//! approximation fallback), gamma (Marsaglia–Tsang), dirichlet (via
//! gamma), lognormal, zipf (rejection-inversion-free CDF table for the
//! vocab sizes we use), and permutation/choose-k helpers for cohort
//! sampling.

#![allow(clippy::many_single_char_names)]

/// xoshiro256++ with splitmix64 seeding.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    cached_normal: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, cached_normal: None }
    }

    /// Derive an independent stream (e.g. per worker / per user).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::seed_from_u64(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in (0, 1] — safe for log().
    #[inline]
    pub fn f64_open(&mut self) -> f64 {
        ((self.next_u64() >> 11) + 1) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift with rejection for exactness.
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let l = m as u64;
            if l >= n.wrapping_neg() % n {
                return (m >> 64) as usize;
            }
        }
    }

    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box–Muller (pair-cached).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.cached_normal.take() {
            return z;
        }
        let u1 = self.f64_open();
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
        self.cached_normal = Some(r * s);
        r * c
    }

    pub fn normal_scaled(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Laplace(0, scale) via inverse CDF.
    pub fn laplace(&mut self, scale: f64) -> f64 {
        let u = self.f64() - 0.5;
        -scale * u.signum() * (1.0 - 2.0 * u.abs()).ln()
    }

    /// Poisson(lambda).
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        if lambda <= 0.0 {
            return 0;
        }
        if lambda < 30.0 {
            // Knuth
            let l = (-lambda).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.f64_open();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        }
        // normal approximation with continuity correction (fine for the
        // user-partitioning use cases where lambda >= 30)
        let x = self.normal_scaled(lambda, lambda.sqrt());
        x.round().max(0.0) as u64
    }

    /// Gamma(shape k, scale 1) via Marsaglia–Tsang; k can be < 1.
    pub fn gamma(&mut self, k: f64) -> f64 {
        if k < 1.0 {
            let u = self.f64_open();
            return self.gamma(k + 1.0) * u.powf(1.0 / k);
        }
        let d = k - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.f64_open();
            if u.ln() < 0.5 * x * x + d - d * v + d * v.ln() {
                return d * v;
            }
        }
    }

    /// Symmetric Dirichlet(alpha) over n categories.
    pub fn dirichlet(&mut self, alpha: f64, n: usize) -> Vec<f64> {
        let mut g: Vec<f64> = (0..n).map(|_| self.gamma(alpha).max(1e-300)).collect();
        let sum: f64 = g.iter().sum();
        for x in &mut g {
            *x /= sum;
        }
        g
    }

    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.normal_scaled(mu, sigma).exp()
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            v.swap(i, self.below(i + 1));
        }
    }

    /// Sample k distinct indices from [0, n) (Floyd's algorithm order-
    /// randomized). Used for cohort sampling without replacement.
    pub fn choose_k(&mut self, n: usize, k: usize) -> Vec<usize> {
        let k = k.min(n);
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.below(j + 1);
            let pick = if chosen.contains(&t) { j } else { t };
            chosen.insert(pick);
            out.push(pick);
        }
        self.shuffle(&mut out);
        out
    }

    /// Bernoulli(p) per element over [0, n): Poisson sampling of cohorts.
    pub fn poisson_subsample(&mut self, n: usize, p: f64) -> Vec<usize> {
        (0..n).filter(|_| self.f64() < p).collect()
    }

    /// Fill a slice with iid N(0, std) f32 noise (DP mechanisms' hot path).
    pub fn fill_normal_f32(&mut self, dst: &mut [f32], std: f64) {
        for v in dst {
            *v = self.normal_scaled(0.0, std) as f32;
        }
    }
}

// ----------------------------------------------------------------------
// Counter-based (stateless) RNG — the parallel DP noise engine's core
// ----------------------------------------------------------------------

/// Samples per counter block of [`CtrRng::normal_block`]: four Box–Muller
/// pairs. Chunk-parallel noise kernels partition vectors at block-aligned
/// boundaries, so every chunk regenerates exactly the samples the serial
/// traversal would have produced at the same positions.
pub const CTR_BLOCK: usize = 8;

/// Counter-based stateless RNG: every output is a pure function of
/// `(key, stream, counter)` through two splitmix64 finalizer rounds, so
/// any chunk of a sample sequence can be generated independently, in any
/// order, on any thread — bit-identical regardless of thread count or
/// traversal order. This is the engine behind the chunk-parallel DP
/// noise kernels in [`crate::tensor::ops`]; the stateful [`Rng`] remains
/// the legacy sequential path (`--noise-threads 0`).
#[derive(Debug, Clone, Copy)]
pub struct CtrRng {
    k0: u64,
    k1: u64,
}

/// Domain-separated per-round noise key: a pure function of the run-level
/// `base` key (the run seed) and the central round, so any *past* round's
/// noise streams can be re-derived later — the banded-MF mechanism
/// regenerates z_{t−k} from these instead of retaining a `band × dim`
/// ring buffer.
pub fn round_key(base: u64, round: u64) -> u64 {
    let mut s = base ^ 0x4E01_5EC0_DE00_0001; // noise-domain tag
    let a = splitmix64(&mut s);
    let mut t = a ^ round.wrapping_mul(0x9E3779B97F4A7C15);
    splitmix64(&mut t)
}

impl CtrRng {
    /// An independent stream under `key` (typically a [`round_key`]);
    /// distinct `stream` values decorrelate mechanisms sharing a round.
    pub fn new(key: u64, stream: u64) -> Self {
        let mut s = key ^ 0xA076_1D64_78BD_642F;
        let k0 = splitmix64(&mut s);
        let mut t = stream ^ k0.rotate_left(29);
        let k1 = splitmix64(&mut t);
        CtrRng { k0, k1 }
    }

    /// The raw 64-bit output at `counter` — splitmix64's counter-indexed
    /// form (state_i = k0 + i·γ, finalized), then a second finalizer
    /// round keyed by the stream, so adjacent counters decohere fully.
    #[inline]
    pub fn u64_at(&self, counter: u64) -> u64 {
        let mut z = self.k0.wrapping_add(counter.wrapping_mul(0x9E3779B97F4A7C15));
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^= self.k1;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1) at `counter` (same 53-bit mapping as [`Rng::f64`]).
    #[inline]
    pub fn f64_at(&self, counter: u64) -> f64 {
        (self.u64_at(counter) >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in (0, 1] at `counter` — safe for log().
    #[inline]
    pub fn f64_open_at(&self, counter: u64) -> f64 {
        ((self.u64_at(counter) >> 11) + 1) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Box–Muller pair `j`, consuming counters (2j, 2j+1). Sample indices
    /// 2j and 2j+1 of the stream's normal sequence.
    #[inline]
    fn normal_pair(&self, j: u64) -> (f64, f64) {
        let u1 = self.f64_open_at(2 * j);
        let u2 = self.f64_at(2 * j + 1);
        let r = (-2.0 * u1.ln()).sqrt();
        let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
        (r * c, r * s)
    }

    /// Standard-normal samples `block·CTR_BLOCK .. (block+1)·CTR_BLOCK`
    /// of this stream, generated as a fixed lane block so block-aligned
    /// chunks reproduce the identical sequence in any traversal order.
    #[inline]
    pub fn normal_block(&self, block: u64) -> [f64; CTR_BLOCK] {
        let mut out = [0.0; CTR_BLOCK];
        let base = block * (CTR_BLOCK as u64 / 2);
        for p in 0..CTR_BLOCK / 2 {
            let (a, b) = self.normal_pair(base + p as u64);
            out[2 * p] = a;
            out[2 * p + 1] = b;
        }
        out
    }

    /// Standard-normal sample `i` of this stream — the scalar view of
    /// [`Self::normal_block`] (bit-identical to the block's element), for
    /// single draws like the adaptive-clip count noise.
    pub fn normal_at(&self, i: u64) -> f64 {
        let (a, b) = self.normal_pair(i / 2);
        if i % 2 == 0 {
            a
        } else {
            b
        }
    }

    /// Laplace(0, scale) sample `i` via inverse CDF (same mapping as
    /// [`Rng::laplace`], one counter per sample).
    #[inline]
    pub fn laplace_at(&self, i: u64, scale: f64) -> f64 {
        let u = self.f64_at(i) - 0.5;
        -scale * u.signum() * (1.0 - 2.0 * u.abs()).ln()
    }
}

/// Zipf sampler over {0, .., n-1} with exponent `s`, using a precomputed
/// CDF (n is at most vocab-size ~1e4 in our datasets, so the table is
/// cheap and sampling is a binary search).
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, s: f64) -> Self {
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for i in 1..=n {
            acc += 1.0 / (i as f64).powf(s);
            cdf.push(acc);
        }
        let total = acc;
        for c in &mut cdf {
            *c /= total;
        }
        Zipf { cdf }
    }

    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64();
        match self.cdf.binary_search_by(|c| c.partial_cmp(&u).unwrap()) {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_distinct_streams() {
        let mut a = Rng::seed_from_u64(42);
        let mut b = Rng::seed_from_u64(42);
        assert_eq!(a.next_u64(), b.next_u64());
        let mut c = Rng::seed_from_u64(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut r = Rng::seed_from_u64(1);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from_u64(2);
        let n = 200_000;
        let (mut m, mut v) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            m += x;
            v += x * x;
        }
        m /= n as f64;
        v = v / n as f64 - m * m;
        assert!(m.abs() < 0.02, "mean {m}");
        assert!((v - 1.0).abs() < 0.03, "var {v}");
    }

    #[test]
    fn laplace_variance() {
        let mut r = Rng::seed_from_u64(3);
        let scale = 2.0;
        let n = 200_000;
        let mut v = 0.0;
        for _ in 0..n {
            let x = r.laplace(scale);
            v += x * x;
        }
        v /= n as f64;
        // Var = 2 scale^2 = 8
        assert!((v - 8.0).abs() < 0.3, "var {v}");
    }

    #[test]
    fn poisson_mean_small_and_large() {
        let mut r = Rng::seed_from_u64(4);
        for lambda in [0.5, 4.0, 16.0, 64.0] {
            let n = 50_000;
            let mut sum = 0.0;
            for _ in 0..n {
                sum += r.poisson(lambda) as f64;
            }
            let mean = sum / n as f64;
            assert!(
                (mean - lambda).abs() < 0.1 * lambda.max(1.0),
                "lambda {lambda} mean {mean}"
            );
        }
    }

    #[test]
    fn gamma_mean() {
        let mut r = Rng::seed_from_u64(5);
        for k in [0.3, 1.0, 2.5, 10.0] {
            let n = 100_000;
            let mut sum = 0.0;
            for _ in 0..n {
                sum += r.gamma(k);
            }
            let mean = sum / n as f64;
            assert!((mean - k).abs() < 0.05 * k.max(1.0), "k {k} mean {mean}");
        }
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = Rng::seed_from_u64(6);
        for alpha in [0.1, 1.0, 10.0] {
            let p = r.dirichlet(alpha, 10);
            let sum: f64 = p.iter().sum();
            assert!((sum - 1.0).abs() < 1e-9);
            assert!(p.iter().all(|x| *x >= 0.0));
        }
    }

    #[test]
    fn choose_k_distinct_and_complete() {
        let mut r = Rng::seed_from_u64(7);
        let picks = r.choose_k(100, 30);
        assert_eq!(picks.len(), 30);
        let set: std::collections::HashSet<_> = picks.iter().collect();
        assert_eq!(set.len(), 30);
        assert!(picks.iter().all(|&i| i < 100));
        // k >= n returns a permutation
        let all = r.choose_k(10, 10);
        let set: std::collections::HashSet<_> = all.iter().collect();
        assert_eq!(set.len(), 10);
    }

    #[test]
    fn poisson_subsample_rate() {
        let mut r = Rng::seed_from_u64(8);
        let mut total = 0;
        for _ in 0..100 {
            total += r.poisson_subsample(1000, 0.05).len();
        }
        let rate = total as f64 / 100_000.0;
        assert!((rate - 0.05).abs() < 0.005, "rate {rate}");
    }

    #[test]
    fn zipf_is_monotone_decreasing() {
        let z = Zipf::new(1000, 1.1);
        let mut r = Rng::seed_from_u64(9);
        let mut counts = vec![0u32; 1000];
        for _ in 0..100_000 {
            counts[z.sample(&mut r)] += 1;
        }
        assert!(counts[0] > counts[10]);
        assert!(counts[10] > counts[200]);
    }

    #[test]
    fn below_is_exact_bounds() {
        let mut r = Rng::seed_from_u64(10);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
            assert_eq!(r.below(1), 0);
        }
    }

    #[test]
    fn ctr_is_deterministic_and_order_invariant() {
        let r = CtrRng::new(42, 7);
        let s = CtrRng::new(42, 7);
        // same (key, stream, counter) -> same output, in any query order
        let forward: Vec<u64> = (0..64).map(|i| r.u64_at(i)).collect();
        let backward: Vec<u64> = (0..64).rev().map(|i| s.u64_at(i)).collect();
        assert_eq!(forward, backward.into_iter().rev().collect::<Vec<_>>());
        // distinct keys/streams give distinct sequences
        assert_ne!(CtrRng::new(43, 7).u64_at(0), r.u64_at(0));
        assert_ne!(CtrRng::new(42, 8).u64_at(0), r.u64_at(0));
        // round keys are distinct per (base, round) and reproducible
        assert_eq!(round_key(1, 5), round_key(1, 5));
        assert_ne!(round_key(1, 5), round_key(1, 6));
        assert_ne!(round_key(1, 5), round_key(2, 5));
    }

    #[test]
    fn ctr_normal_block_matches_scalar_view() {
        let r = CtrRng::new(9, 3);
        for b in 0..16u64 {
            let block = r.normal_block(b);
            for (j, &z) in block.iter().enumerate() {
                let i = b * CTR_BLOCK as u64 + j as u64;
                assert_eq!(z.to_bits(), r.normal_at(i).to_bits(), "sample {i}");
            }
        }
    }

    #[test]
    fn ctr_normal_moments_guard() {
        // Statistical guard on the counter-normal sampler (a kernel bug
        // here silently biases DP noise): mean, variance and excess
        // kurtosis at n = 1e6 must sit within a few standard errors
        // (se_mean = 1e-3, se_var ≈ 1.4e-3, se_kurt ≈ 4.9e-3).
        let r = CtrRng::new(0xD00D, 1);
        let n = 1_000_000usize;
        let (mut m1, mut m2, mut m4) = (0.0f64, 0.0f64, 0.0f64);
        for b in 0..(n / CTR_BLOCK) as u64 {
            for z in r.normal_block(b) {
                m1 += z;
                m2 += z * z;
                m4 += z * z * z * z;
            }
        }
        let nf = n as f64;
        let mean = m1 / nf;
        let var = m2 / nf - mean * mean;
        let kurt = (m4 / nf) / (var * var) - 3.0;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.01, "var {var}");
        assert!(kurt.abs() < 0.05, "excess kurtosis {kurt}");
    }

    #[test]
    fn ctr_no_correlation_across_chunk_boundaries() {
        // Chunk-parallel fills stitch block-aligned chunks together; a
        // correlation between the last sample of one block and the first
        // of the next would show up as banded structure in the noise.
        let r = CtrRng::new(0xF00F, 2);
        let blocks = 125_000u64;
        let (mut dot, mut n_sq, mut f_sq) = (0.0f64, 0.0f64, 0.0f64);
        let mut prev_last = r.normal_block(0)[CTR_BLOCK - 1];
        for b in 1..blocks {
            let blk = r.normal_block(b);
            dot += prev_last * blk[0];
            n_sq += prev_last * prev_last;
            f_sq += blk[0] * blk[0];
            prev_last = blk[CTR_BLOCK - 1];
        }
        let corr = dot / (n_sq.sqrt() * f_sq.sqrt());
        // se ≈ 1/√pairs ≈ 2.8e-3
        assert!(corr.abs() < 0.02, "boundary correlation {corr}");
    }

    #[test]
    fn ctr_laplace_variance() {
        let r = CtrRng::new(5, 4);
        let scale = 2.0;
        let n = 200_000u64;
        let mut v = 0.0;
        for i in 0..n {
            let x = r.laplace_at(i, scale);
            v += x * x;
        }
        v /= n as f64;
        // Var = 2·scale² = 8
        assert!((v - 8.0).abs() < 0.3, "var {v}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from_u64(11);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
