//! Tiny CLI argument parser (offline build: clap is unavailable).
//!
//! Supports `pfl <subcommand> [--key value]... [--flag]...` which is all
//! the experiment harness needs.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Result<Self> {
        let mut args = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    args.options.insert(key.to_string(), it.next().unwrap());
                } else {
                    args.flags.push(key.to_string());
                }
            } else if args.subcommand.is_none() {
                args.subcommand = Some(a);
            } else {
                args.positional.push(a);
            }
        }
        Ok(args)
    }

    pub fn from_env() -> Result<Self> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow!("--{key} {v:?}: {e}")),
        }
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow!("--{key} {v:?}: {e}")),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow!("--{key} {v:?}: {e}")),
        }
    }

    pub fn get_str<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn require(&self, key: &str) -> Result<&str> {
        self.get(key).ok_or_else(|| anyhow!("missing required --{key}"))
    }

    pub fn require_subcommand(&self) -> Result<&str> {
        match &self.subcommand {
            Some(s) => Ok(s),
            None => bail!("missing subcommand"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string())).unwrap()
    }

    #[test]
    fn parses_subcommand_options_flags() {
        // NB: a bare `--x` followed by a non-dashed token is an option
        // (`--x value`), so flags must be written last or as `--x=`.
        let a = p("table1 extra --scale 0.1 --workers=4 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("table1"));
        assert_eq!(a.get("scale"), Some("0.1"));
        assert_eq!(a.get_usize("workers", 1).unwrap(), 4);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn defaults_and_errors() {
        let a = p("run --lr 0.5");
        assert_eq!(a.get_f64("lr", 1.0).unwrap(), 0.5);
        assert_eq!(a.get_f64("mu", 0.25).unwrap(), 0.25);
        assert!(a.require("missing").is_err());
        let bad = p("run --n abc");
        assert!(bad.get_usize("n", 1).is_err());
    }

    #[test]
    fn trailing_flag() {
        let a = p("run --check");
        assert!(a.flag("check"));
        assert_eq!(a.get("check"), None);
    }
}
