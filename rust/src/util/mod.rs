//! In-tree infrastructure (offline build — see Cargo.toml): JSON, RNG +
//! distributions, CLI parsing, bench harness, and small vector math
//! helpers shared by the aggregation / privacy hot paths.

pub mod bench;
pub mod cli;
pub mod fft;
pub mod json;
pub mod rng;

/// y += x (the aggregation hot path; kept in one place so the perf pass
/// can vectorize/tune a single site).
#[inline]
pub fn add_assign(y: &mut [f32], x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    for (a, b) in y.iter_mut().zip(x) {
        *a += *b;
    }
}

/// y += s * x
#[inline]
pub fn axpy(y: &mut [f32], s: f32, x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    for (a, b) in y.iter_mut().zip(x) {
        *a += s * *b;
    }
}

/// y *= s
#[inline]
pub fn scale(y: &mut [f32], s: f32) {
    for a in y {
        *a *= s;
    }
}

/// out = a - b
#[inline]
pub fn sub_into(out: &mut [f32], a: &[f32], b: &[f32]) {
    debug_assert_eq!(out.len(), a.len());
    debug_assert_eq!(out.len(), b.len());
    for ((o, x), y) in out.iter_mut().zip(a).zip(b) {
        *o = *x - *y;
    }
}

/// L2 norm (f64 accumulation).
#[inline]
pub fn l2_norm(v: &[f32]) -> f64 {
    v.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>().sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vector_ops() {
        let mut y = vec![1.0f32, 2.0, 3.0];
        add_assign(&mut y, &[1.0, 1.0, 1.0]);
        assert_eq!(y, vec![2.0, 3.0, 4.0]);
        axpy(&mut y, 2.0, &[1.0, 0.0, -1.0]);
        assert_eq!(y, vec![4.0, 3.0, 2.0]);
        scale(&mut y, 0.5);
        assert_eq!(y, vec![2.0, 1.5, 1.0]);
        let mut out = vec![0.0f32; 3];
        sub_into(&mut out, &[3.0, 3.0, 3.0], &y);
        assert_eq!(out, vec![1.0, 1.5, 2.0]);
        assert!((l2_norm(&[3.0, 4.0]) - 5.0).abs() < 1e-12);
    }
}
