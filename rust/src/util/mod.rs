//! In-tree infrastructure (offline build — see Cargo.toml): JSON, RNG +
//! distributions, CLI parsing, bench harness.
//!
//! The small-vector math helpers formerly defined here moved to
//! [`crate::tensor::ops`] (the unified SIMD-chunked kernel layer); the
//! common names are re-exported so existing call sites keep compiling.

pub mod bench;
pub mod cli;
pub mod fft;
pub mod json;
pub mod mman;
pub mod rng;

pub use crate::tensor::ops::{add_assign, axpy, l2_norm, scale, sub_assign, sub_into};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vector_ops_reexports() {
        let mut y = vec![1.0f32, 2.0, 3.0];
        add_assign(&mut y, &[1.0, 1.0, 1.0]);
        assert_eq!(y, vec![2.0, 3.0, 4.0]);
        axpy(&mut y, 2.0, &[1.0, 0.0, -1.0]);
        assert_eq!(y, vec![4.0, 3.0, 2.0]);
        scale(&mut y, 0.5);
        assert_eq!(y, vec![2.0, 1.5, 1.0]);
        let mut out = vec![0.0f32; 3];
        sub_into(&mut out, &[3.0, 3.0, 3.0], &y);
        assert_eq!(out, vec![1.0, 1.5, 2.0]);
        assert!((l2_norm(&[3.0, 4.0]) - 5.0).abs() < 1e-12);
    }
}
