//! Minimal `mmap`/`munmap`/`madvise` FFI shim (offline build: the
//! `memmap2`/`libc` crates are unavailable, so the three syscall
//! wrappers the data store needs are declared directly).
//!
//! [`Mmap`] maps a file read-only for the store's zero-copy shard read
//! path (`crate::data::store`): the OS page cache becomes the L2 cache
//! behind the user-level LRU, a warm read is a slice into the mapping
//! (no heap allocation, no copy), and a cold read stalls on a page
//! fault instead of an explicit `pread` (reported separately as
//! `sys/page-fault-stalls`).
//!
//! Platform gate: the FFI is only compiled on 64-bit unix (the declared
//! `off_t = i64` ABI). Elsewhere [`Mmap::map_readonly`] returns an
//! error and callers fall back to the portable positioned-read path —
//! the store works everywhere, it is just zero-copy where mmap exists.

use std::fs::File;
use std::io;

/// A read-only memory mapping of a file's first `len` bytes. `Send +
/// Sync`: the mapping is immutable for its whole lifetime (`PROT_READ`,
/// private), so concurrent reads from worker and prefetch threads are
/// safe.
pub struct Mmap {
    ptr: *const u8,
    len: usize,
}

// SAFETY: the mapping is PROT_READ-only and never remapped; sharing
// immutable bytes across threads is safe.
unsafe impl Send for Mmap {}
unsafe impl Sync for Mmap {}

#[cfg(all(unix, target_pointer_width = "64"))]
mod sys {
    use std::ffi::c_void;

    pub const PROT_READ: i32 = 1;
    pub const MAP_PRIVATE: i32 = 2;
    /// `MADV_SEQUENTIAL` (linux + macOS share the value).
    pub const MADV_SEQUENTIAL: i32 = 2;
    /// `MADV_WILLNEED` (linux + macOS share the value).
    pub const MADV_WILLNEED: i32 = 3;

    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: i32,
            flags: i32,
            fd: i32,
            offset: i64,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> i32;
        pub fn madvise(addr: *mut c_void, len: usize, advice: i32) -> i32;
    }
}

/// Access-pattern hint forwarded to `madvise` (advisory: failures are
/// ignored, the kernel is free to ignore the hint too).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Advice {
    Sequential,
    WillNeed,
}

impl Mmap {
    /// Map the first `len` bytes of `file` read-only. `len` must not
    /// exceed the file's length (reading a mapped page past EOF is a
    /// SIGBUS — callers validate against `fs::metadata` first).
    #[cfg(all(unix, target_pointer_width = "64"))]
    pub fn map_readonly(file: &File, len: usize) -> io::Result<Mmap> {
        use std::os::unix::io::AsRawFd;
        if len == 0 {
            // mmap(len = 0) is EINVAL; an empty file maps to an empty
            // slice without a syscall
            return Ok(Mmap { ptr: std::ptr::NonNull::<u8>::dangling().as_ptr(), len: 0 });
        }
        // SAFETY: a fresh PROT_READ | MAP_PRIVATE mapping of a file fd
        // at offset 0; address chosen by the kernel. The result is
        // checked against MAP_FAILED before use.
        let p = unsafe {
            sys::mmap(
                std::ptr::null_mut(),
                len,
                sys::PROT_READ,
                sys::MAP_PRIVATE,
                file.as_raw_fd(),
                0,
            )
        };
        if p == usize::MAX as *mut _ || p.is_null() {
            return Err(io::Error::last_os_error());
        }
        Ok(Mmap { ptr: p as *const u8, len })
    }

    /// Unsupported-platform fallback: always errors, so the store keeps
    /// using the portable positioned-read path.
    #[cfg(not(all(unix, target_pointer_width = "64")))]
    pub fn map_readonly(_file: &File, _len: usize) -> io::Result<Mmap> {
        Err(io::Error::new(
            io::ErrorKind::Unsupported,
            "mmap is unavailable on this platform; using positioned reads",
        ))
    }

    /// Advise the kernel about the expected access pattern (no-op on
    /// error or on platforms without the shim).
    pub fn advise(&self, advice: Advice) {
        #[cfg(all(unix, target_pointer_width = "64"))]
        if self.len > 0 {
            let a = match advice {
                Advice::Sequential => sys::MADV_SEQUENTIAL,
                Advice::WillNeed => sys::MADV_WILLNEED,
            };
            // SAFETY: (ptr, len) is exactly the live mapping; madvise is
            // advisory and cannot invalidate it.
            unsafe {
                sys::madvise(self.ptr as *mut _, self.len, a);
            }
        }
        #[cfg(not(all(unix, target_pointer_width = "64")))]
        let _ = advice;
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// The mapped bytes. Reading a page for the first time may stall on
    /// a page fault — that stall is the mmap analogue of a `pread`.
    pub fn as_slice(&self) -> &[u8] {
        if self.len == 0 {
            return &[];
        }
        // SAFETY: (ptr, len) is a live PROT_READ mapping for the whole
        // lifetime of self; the file length was validated ≥ len at map
        // time, so every byte is backed.
        unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
    }
}

impl Drop for Mmap {
    fn drop(&mut self) {
        #[cfg(all(unix, target_pointer_width = "64"))]
        if self.len > 0 {
            // SAFETY: (ptr, len) came from a successful mmap and is
            // unmapped exactly once.
            unsafe {
                sys::munmap(self.ptr as *mut _, self.len);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    #[test]
    fn maps_file_contents_readonly() {
        let path = std::env::temp_dir()
            .join(format!("pfl_mman_test_{}", std::process::id()));
        let payload: Vec<u8> = (0..=255u8).cycle().take(10_000).collect();
        {
            let mut f = File::create(&path).unwrap();
            f.write_all(&payload).unwrap();
        }
        let f = File::open(&path).unwrap();
        let len = f.metadata().unwrap().len() as usize;
        match Mmap::map_readonly(&f, len) {
            Ok(m) => {
                assert_eq!(m.len(), payload.len());
                assert!(!m.is_empty());
                assert_eq!(m.as_slice(), &payload[..]);
                m.advise(Advice::Sequential);
                m.advise(Advice::WillNeed);
                // a partial-length map exposes a prefix
                let short = Mmap::map_readonly(&f, 4096).unwrap();
                assert_eq!(short.as_slice(), &payload[..4096]);
            }
            // non-unix targets: the fallback errors and the store uses
            // positioned reads — nothing further to assert
            Err(e) => assert_eq!(e.kind(), io::ErrorKind::Unsupported),
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn empty_map_is_empty_slice() {
        let path = std::env::temp_dir()
            .join(format!("pfl_mman_empty_{}", std::process::id()));
        File::create(&path).unwrap();
        let f = File::open(&path).unwrap();
        if let Ok(m) = Mmap::map_readonly(&f, 0) {
            assert!(m.is_empty());
            assert_eq!(m.as_slice(), &[] as &[u8]);
            m.advise(Advice::WillNeed); // no-op, must not crash
        }
        let _ = std::fs::remove_file(&path);
    }
}
