//! Micro-benchmark harness (offline build: criterion is unavailable).
//!
//! Measures wall-clock over warmup + N timed iterations and reports
//! median / mean / stddev / min, criterion-style. Used by every target in
//! `benches/`.
//!
//! Bench binaries additionally install [`CountingAlloc`] as their global
//! allocator and emit machine-readable `BENCH_*.json` files (ns/op +
//! alloc bytes/op) via [`write_bench_json`], so the perf trajectory is
//! tracked across PRs.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub median: Duration,
    pub mean: Duration,
    pub stddev: Duration,
    pub min: Duration,
}

impl BenchResult {
    pub fn print(&self) {
        println!(
            "{:<44} iters={:<4} median={:>12?} mean={:>12?} sd={:>10?} min={:>12?}",
            self.name, self.iters, self.median, self.mean, self.stddev, self.min
        );
    }

    pub fn median_secs(&self) -> f64 {
        self.median.as_secs_f64()
    }
}

/// Time `f` with `warmup` discarded runs and `iters` measured runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed());
    }
    summarize(name, samples)
}

/// Time a batch-style closure that runs `n` inner operations per call;
/// reported durations are per-op.
pub fn bench_per_op<F: FnMut()>(
    name: &str,
    warmup: usize,
    iters: usize,
    ops_per_iter: usize,
    f: F,
) -> BenchResult {
    bench_per_op_alloc(name, warmup, iters, ops_per_iter, f).0
}

fn summarize(name: &str, mut samples: Vec<Duration>) -> BenchResult {
    samples.sort();
    let iters = samples.len();
    let median = samples[iters / 2];
    let mean_nanos: f64 =
        samples.iter().map(|d| d.as_nanos() as f64).sum::<f64>() / iters as f64;
    let var: f64 = samples
        .iter()
        .map(|d| (d.as_nanos() as f64 - mean_nanos).powi(2))
        .sum::<f64>()
        / iters as f64;
    let r = BenchResult {
        name: name.to_string(),
        iters,
        median,
        mean: Duration::from_nanos(mean_nanos as u64),
        stddev: Duration::from_nanos(var.sqrt() as u64),
        min: samples[0],
    };
    r.print();
    r
}

/// Prevent the optimizer from eliding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

// ----------------------------------------------------------------------
// Allocation accounting + machine-readable output
// ----------------------------------------------------------------------

static ALLOC_BYTES: AtomicU64 = AtomicU64::new(0);

/// Byte-counting global allocator for bench binaries. Install with
///
/// ```ignore
/// #[global_allocator]
/// static ALLOC: pfl::util::bench::CountingAlloc = pfl::util::bench::CountingAlloc;
/// ```
///
/// Only allocation (and realloc growth) is counted — the interesting
/// signal for the "no alloc in the hot loop" invariant; frees are not
/// subtracted.
pub struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_BYTES
            .fetch_add(new_size.saturating_sub(layout.size()) as u64, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

/// Bytes allocated so far through [`CountingAlloc`] (0 when the binary
/// did not install it).
pub fn alloc_bytes_now() -> u64 {
    ALLOC_BYTES.load(Ordering::Relaxed)
}

/// Like [`bench_per_op`] but also reports heap bytes allocated per op
/// during the timed iterations (requires [`CountingAlloc`]).
pub fn bench_per_op_alloc<F: FnMut()>(
    name: &str,
    warmup: usize,
    iters: usize,
    ops_per_iter: usize,
    mut f: F,
) -> (BenchResult, f64) {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    let a0 = alloc_bytes_now();
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed() / ops_per_iter.max(1) as u32);
    }
    let alloc_per_op =
        (alloc_bytes_now() - a0) as f64 / (iters.max(1) * ops_per_iter.max(1)) as f64;
    (summarize(name, samples), alloc_per_op)
}

/// One machine-readable bench record.
pub struct BenchRecord {
    pub name: String,
    pub ns_per_op: f64,
    pub alloc_bytes_per_op: f64,
}

impl BenchRecord {
    pub fn new(r: &BenchResult, alloc_bytes_per_op: f64) -> Self {
        BenchRecord {
            name: r.name.clone(),
            ns_per_op: r.median.as_nanos() as f64,
            alloc_bytes_per_op,
        }
    }
}

/// Write `BENCH_*.json`: `{"schema": "pfl-bench-v1", "benches": [...]}`.
pub fn write_bench_json(path: &str, records: &[BenchRecord]) -> std::io::Result<()> {
    use crate::util::json::{arr, num, obj, s};
    let benches: Vec<_> = records
        .iter()
        .map(|r| {
            obj(vec![
                ("name", s(r.name.clone())),
                ("ns_per_op", num(r.ns_per_op)),
                ("alloc_bytes_per_op", num(r.alloc_bytes_per_op)),
            ])
        })
        .collect();
    let doc = obj(vec![("schema", s("pfl-bench-v1")), ("benches", arr(benches))]);
    std::fs::write(path, doc.to_string_pretty())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_stats() {
        let r = bench("noop-ish", 2, 9, || {
            black_box((0..1000).sum::<u64>());
        });
        assert_eq!(r.iters, 9);
        assert!(r.min <= r.median);
        assert!(r.median <= r.mean * 4);
    }

    #[test]
    fn per_op_divides() {
        let r = bench_per_op("per-op", 1, 5, 100, || {
            black_box((0..10_000).sum::<u64>());
        });
        assert!(r.median.as_nanos() < 1_000_000);
    }
}
