//! Micro-benchmark harness (offline build: criterion is unavailable).
//!
//! Measures wall-clock over warmup + N timed iterations and reports
//! median / mean / stddev / min, criterion-style. Used by every target in
//! `benches/`.

use std::time::{Duration, Instant};

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub median: Duration,
    pub mean: Duration,
    pub stddev: Duration,
    pub min: Duration,
}

impl BenchResult {
    pub fn print(&self) {
        println!(
            "{:<44} iters={:<4} median={:>12?} mean={:>12?} sd={:>10?} min={:>12?}",
            self.name, self.iters, self.median, self.mean, self.stddev, self.min
        );
    }

    pub fn median_secs(&self) -> f64 {
        self.median.as_secs_f64()
    }
}

/// Time `f` with `warmup` discarded runs and `iters` measured runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed());
    }
    summarize(name, samples)
}

/// Time a batch-style closure that runs `n` inner operations per call;
/// reported durations are per-op.
pub fn bench_per_op<F: FnMut()>(
    name: &str,
    warmup: usize,
    iters: usize,
    ops_per_iter: usize,
    mut f: F,
) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed() / ops_per_iter.max(1) as u32);
    }
    summarize(name, samples)
}

fn summarize(name: &str, mut samples: Vec<Duration>) -> BenchResult {
    samples.sort();
    let iters = samples.len();
    let median = samples[iters / 2];
    let mean_nanos: f64 =
        samples.iter().map(|d| d.as_nanos() as f64).sum::<f64>() / iters as f64;
    let var: f64 = samples
        .iter()
        .map(|d| (d.as_nanos() as f64 - mean_nanos).powi(2))
        .sum::<f64>()
        / iters as f64;
    let r = BenchResult {
        name: name.to_string(),
        iters,
        median,
        mean: Duration::from_nanos(mean_nanos as u64),
        stddev: Duration::from_nanos(var.sqrt() as u64),
        min: samples[0],
    };
    r.print();
    r
}

/// Prevent the optimizer from eliding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_stats() {
        let r = bench("noop-ish", 2, 9, || {
            black_box((0..1000).sum::<u64>());
        });
        assert_eq!(r.iters, 9);
        assert!(r.min <= r.median);
        assert!(r.median <= r.mean * 4);
    }

    #[test]
    fn per_op_divides() {
        let r = bench_per_op("per-op", 1, 5, 100, || {
            black_box((0..10_000).sum::<u64>());
        });
        assert!(r.median.as_nanos() < 1_000_000);
    }
}
