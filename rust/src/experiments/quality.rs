//! Tables 3–4 (and 12–13): the algorithm benchmark suite.
//!
//! The benchmark matrix of paper §4.3: datasets × {IID, non-IID} ×
//! {no DP, central DP} × algorithms, each run `seeds` times and averaged.
//! Headline metrics: accuracy (CIFAR10), perplexity (StackOverflow, LLM),
//! mAP (FLAIR).

use anyhow::Result;

use super::{run_benchmark, EvalMode, TablePrinter};
use crate::baselines::EngineVariant;
use crate::config::{preset, Config};

pub const ALGOS: [&str; 4] = ["fedavg", "fedprox", "adafedprox", "scaffold"];

/// Benchmarks of Table 3/4 columns (subset selectable via CLI).
pub const BENCHMARKS: [&str; 8] = [
    "cifar10-iid",
    "cifar10-noniid",
    "stackoverflow",
    "flair-iid",
    "flair",
    "llm-sa",
    "llm-aya",
    "llm-oa",
];

fn mean_std(xs: &[f64]) -> (f64, f64) {
    let n = xs.len() as f64;
    let mean = xs.iter().sum::<f64>() / n;
    let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n.max(1.0);
    (mean, var.sqrt())
}

/// Run one (benchmark, algorithm, mechanism) cell for `seeds` seeds.
pub fn run_cell(
    bench: &str,
    algo: &str,
    mechanism: Option<&str>,
    scale: f64,
    seeds: u64,
    workers: usize,
) -> Result<(f64, f64)> {
    let mut vals = Vec::new();
    for seed in 0..seeds.max(1) {
        let mut cfg: Config = preset(&format!(
            "{bench}{}",
            if mechanism.is_some() { "-dp" } else { "" }
        ))
        .or_else(|_| preset(bench))?
        .scaled(scale);
        cfg.algorithm.kind = algo.into();
        if algo == "fedprox" {
            cfg.algorithm.mu = 0.1; // [52]'s recommended starting µ
        }
        if let Some(mech) = mechanism {
            cfg.privacy.mechanism = mech.into();
        }
        cfg.seed = seed;
        cfg.num_workers = workers;
        // periodic central eval at the paper's cadence
        cfg.eval_every = (cfg.iterations / 4).max(1);
        let summary = run_benchmark(&cfg, EngineVariant::PflStyle.profile(), EvalMode::Periodic, 0)?;
        let v = summary.headline.map(|(_, v)| v).unwrap_or(f64::NAN);
        eprintln!("  [{bench}/{algo}{}] seed {seed}: {v:.4}", mechanism.map(|m| format!("+{m}")).unwrap_or_default());
        vals.push(v);
    }
    Ok(mean_std(&vals))
}

/// Table 3: algorithms without DP.
pub fn table3(benchmarks: &[String], scale: f64, seeds: u64, workers: usize) -> Result<()> {
    let mut headers = vec!["algorithm".to_string()];
    headers.extend(benchmarks.iter().cloned());
    let hdr_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut t = TablePrinter::new(&hdr_refs);
    for algo in ALGOS {
        eprintln!("[table3] {algo} ...");
        let mut row = vec![algo.to_string()];
        for bench in benchmarks {
            let (mean, std) = run_cell(bench, algo, None, scale, seeds, workers)?;
            row.push(format!("{mean:.4}±{std:.4}"));
        }
        t.row(row);
    }
    t.print("Table 3: FL algorithms without DP");
    println!("# paper shape: SCAFFOLD never beats FedAvg; FedProx ≈ FedAvg (slightly better non-IID)");
    Ok(())
}

/// Table 4: algorithms with central DP (Gaussian for all, banded-MF for
/// FedAvg as the second row).
pub fn table4(benchmarks: &[String], scale: f64, seeds: u64, workers: usize) -> Result<()> {
    let mut headers = vec!["algorithm".to_string(), "DP".to_string()];
    headers.extend(benchmarks.iter().cloned());
    let hdr_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut t = TablePrinter::new(&hdr_refs);

    let cells: Vec<(&str, &str)> = vec![
        ("fedavg", "gaussian"),
        ("fedavg", "banded-mf"),
        ("fedprox", "gaussian"),
        ("adafedprox", "gaussian"),
        ("scaffold", "gaussian"),
    ];
    for (algo, mech) in cells {
        eprintln!("[table4] {algo} + {mech} ...");
        let mut row = vec![algo.to_string(), if mech == "gaussian" { "G".into() } else { "BMF".into() }];
        for bench in benchmarks {
            let (mean, std) = run_cell(bench, algo, Some(mech), scale, seeds, workers)?;
            row.push(format!("{mean:.4}±{std:.4}"));
        }
        t.row(row);
    }
    t.print("Table 4: FL algorithms with central DP (eps=2, delta=1e-6)");
    println!("# paper shape: BMF > Gaussian (esp. StackOverflow, ~10% rel. perplexity); SCAFFOLD degrades most under DP");
    Ok(())
}

/// The GBDT/GMM sanity benchmark (paper §1's non-NN models; no paper
/// table — reported as convergence curves).
pub fn nonnn(scale: f64) -> Result<()> {
    use crate::fl::backend::{BackendBuilder, RunParams};
    use crate::fl::gbdt::{initial_state as gbdt_init, FedGbdt, GbdtModel, GbdtParams};
    use crate::fl::gmm::{initial_state as gmm_init, FedGmm, GmmModel, GmmParams};
    use std::sync::Arc;

    let users = ((64.0 * scale.max(0.1)) as usize).max(8);

    // ---- GBDT ----
    let gp = GbdtParams { num_features: 6, max_depth: 3, max_trees: 12, ..Default::default() };
    let dataset: Arc<dyn crate::data::FederatedDataset> =
        Arc::new(crate::data::SynthTabular::new(users, 64, 6, 7));
    let spec = crate::fl::algorithm::RunSpec {
        iterations: 12,
        cohort_size: (users / 2).max(2),
        val_cohort_size: 2,
        eval_every: 3,
        population: users,
        ..Default::default()
    };
    let gp2 = gp.clone();
    let mut backend = BackendBuilder::new(
        dataset,
        Arc::new(FedGbdt::new(spec, gp.clone())),
        Arc::new(move |_| Ok(Box::new(GbdtModel::new(gp2.clone())) as Box<dyn crate::fl::Model>)),
    )
    .params(RunParams { num_workers: 2, ..Default::default() })
    .build()?;
    let out = backend.run(gbdt_init(&gp), &mut [])?;
    let series = out.series("train/loss");
    println!("\n=== Federated GBDT (synthetic tabular) ===");
    println!("round\ttrain_mse");
    for (t, v) in &series {
        println!("{t}\t{v:.5}");
    }
    anyhow::ensure!(
        series.last().unwrap().1 < series[0].1,
        "GBDT loss did not decrease"
    );

    // ---- GMM ----
    let p = GmmParams { components: 3, dim: 2, var_floor: 1e-3 };
    let dataset: Arc<dyn crate::data::FederatedDataset> =
        Arc::new(crate::data::SynthGmmPoints::new(users, 40, 2, 3, 11));
    let spec = crate::fl::algorithm::RunSpec {
        iterations: 15,
        cohort_size: (users / 2).max(2),
        val_cohort_size: 2,
        eval_every: 3,
        population: users,
        ..Default::default()
    };
    let mut backend = BackendBuilder::new(
        dataset,
        Arc::new(FedGmm::new(spec, p)),
        Arc::new(move |w| Ok(Box::new(GmmModel::new(p, w as u64)) as Box<dyn crate::fl::Model>)),
    )
    .params(RunParams { num_workers: 2, ..Default::default() })
    .build()?;
    let out = backend.run(gmm_init(&p, 5), &mut [])?;
    let series = out.series("train/nll");
    println!("\n=== Federated GMM (federated EM) ===");
    println!("round\ttrain_nll");
    for (t, v) in &series {
        println!("{t}\t{v:.5}");
    }
    anyhow::ensure!(series.last().unwrap().1 < series[0].1, "GMM NLL did not decrease");
    Ok(())
}
