//! The experiment harness: one function per paper table/figure
//! (DESIGN.md §4's index maps each to its CLI subcommand).
//!
//! Every harness prints the same rows/series the paper reports. Absolute
//! numbers differ — this testbed is a single-core CPU PJRT device, not
//! 4×A100 — but the *shape* (who wins, by what factor, where crossovers
//! fall) is the reproduction target, and EXPERIMENTS.md records
//! paper-vs-measured for each.
//!
//! Wall-clock rows marked `sim` come from the virtual-cluster replay
//! (measured per-user costs re-scheduled onto v virtual workers; see
//! `simsys::replay_cluster`) — the documented substitution for multi-GPU
//! scaling on this testbed.

pub mod dispatch;
pub mod privacy_fig;
pub mod quality;
pub mod scaling;
pub mod scenario;
pub mod sched;
pub mod speed;

use anyhow::Result;

use crate::baselines::OverheadProfile;
use crate::config::build::{build_backend, build_eval_callback, headline_metric};
use crate::config::Config;
use crate::fl::backend::RunOutcome;
use crate::fl::callbacks::Callback;
use crate::simsys::UserCost;

/// Result of one benchmark run, with the headline metric resolved.
pub struct RunSummary {
    pub name: String,
    pub wall_secs: f64,
    /// ("accuracy" | "perplexity" | "map", value) from the final central
    /// evaluation, when evaluation was enabled.
    pub headline: Option<(String, f64)>,
    pub outcome: RunOutcome,
}

/// Build + run one config end to end. `final_eval_only` replaces the
/// periodic central evaluation with a single final one (speed harnesses
/// use this as the paper's "accuracy as a consistency check").
pub fn run_benchmark(
    cfg: &Config,
    profile: OverheadProfile,
    eval: EvalMode,
    log_every: u64,
) -> Result<RunSummary> {
    let mut backend = build_backend(cfg, profile)?;
    // the backend's dataset: the generator, or the one opened store
    // for `engine.data_store` configs (no second open)
    let dataset = backend.dataset();
    let init = crate::config::build::init_params(cfg)?;

    let mut callbacks: Vec<Box<dyn Callback>> = Vec::new();
    let mut eval_cb = match eval {
        EvalMode::None => None,
        EvalMode::Final => Some(build_eval_callback(cfg, &dataset)?),
        EvalMode::Periodic => {
            callbacks.push(Box::new(build_eval_callback(cfg, &dataset)?));
            None
        }
    };
    if log_every > 0 {
        // the backend prints via its own params; re-build with logging
        // (cheaper: just rely on our own printing below)
    }
    let _ = log_every;
    let mut outcome = backend.run(init, &mut callbacks)?;

    let metric_name = headline_metric(&cfg.model);
    let headline = match eval {
        EvalMode::None => None,
        EvalMode::Final => {
            let m = eval_cb.as_mut().unwrap().evaluate(&outcome.central)?;
            m.get(&format!("centraleval/{metric_name}"))
                .map(|v| (metric_name.to_string(), v))
        }
        EvalMode::Periodic => outcome
            .final_metric(&format!("centraleval/{metric_name}"))
            .map(|v| (metric_name.to_string(), v)),
    };
    outcome.wall_secs = outcome.wall_secs.max(1e-9);
    Ok(RunSummary { name: cfg.name.clone(), wall_secs: outcome.wall_secs, headline, outcome })
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EvalMode {
    None,
    /// One central evaluation after training (consistency check).
    Final,
    /// The benchmark's periodic central evaluation.
    Periodic,
}

/// Least-squares fit of cost ≈ a + b·datapoints over measured user costs
/// (the Fig. 4a correlation made quantitative; also the generator for the
/// 50k-cohort replay of Fig. 3 right).
pub fn fit_cost_model(costs: &[UserCost]) -> (f64, f64) {
    if costs.is_empty() {
        return (0.0, 0.0);
    }
    let n = costs.len() as f64;
    let mx = costs.iter().map(|c| c.datapoints as f64).sum::<f64>() / n;
    let my = costs.iter().map(|c| c.nanos as f64).sum::<f64>() / n;
    let mut sxx = 0.0;
    let mut sxy = 0.0;
    for c in costs {
        let dx = c.datapoints as f64 - mx;
        sxx += dx * dx;
        sxy += dx * (c.nanos as f64 - my);
    }
    let b = if sxx > 0.0 { sxy / sxx } else { 0.0 };
    (my - b * mx, b)
}

/// Pearson correlation between datapoints and cost (Fig. 4a's headline
/// number: "strong correlation").
pub fn cost_correlation(costs: &[UserCost]) -> f64 {
    if costs.len() < 2 {
        return 0.0;
    }
    let n = costs.len() as f64;
    let mx = costs.iter().map(|c| c.datapoints as f64).sum::<f64>() / n;
    let my = costs.iter().map(|c| c.nanos as f64).sum::<f64>() / n;
    let (mut sxx, mut syy, mut sxy) = (0.0, 0.0, 0.0);
    for c in costs {
        let dx = c.datapoints as f64 - mx;
        let dy = c.nanos as f64 - my;
        sxx += dx * dx;
        syy += dy * dy;
        sxy += dx * dy;
    }
    if sxx <= 0.0 || syy <= 0.0 {
        0.0
    } else {
        sxy / (sxx * syy).sqrt()
    }
}

/// A fixed-width table printer for the experiment outputs.
pub struct TablePrinter {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl TablePrinter {
    pub fn new(headers: &[&str]) -> Self {
        TablePrinter {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        self.rows.push(cells);
    }

    pub fn print(&self, title: &str) {
        println!("\n=== {title} ===");
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                if i < widths.len() {
                    widths[i] = widths[i].max(c.len());
                }
            }
        }
        let fmt_row = |cells: &[String]| {
            let mut line = String::new();
            for (i, c) in cells.iter().enumerate() {
                let w = widths.get(i).copied().unwrap_or(c.len());
                line.push_str(&format!("{c:<w$}  "));
            }
            println!("{}", line.trim_end());
        };
        fmt_row(&self.headers);
        println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        for row in &self.rows {
            fmt_row(row);
        }
    }
}

/// Shared small-scale defaults for the speed experiments: the structural
/// hyperparameters of the paper's setups with a compute budget that fits
/// a single CPU core. Scaled up with `--scale`.
pub fn speed_cifar_config(scale: f64) -> Config {
    let mut cfg = crate::config::preset("cifar10-iid").unwrap();
    cfg.iterations = 10;
    cfg.cohort_size = 10;
    cfg.dataset.num_users = 200;
    cfg.eval_every = 10_000; // no periodic eval inside the timed region
    cfg.val_cohort_size = 0;
    if (scale - 1.0).abs() > 1e-12 {
        cfg.iterations = ((cfg.iterations as f64 * scale).round() as u64).max(2);
        cfg.cohort_size = ((cfg.cohort_size as f64 * scale).round() as usize).max(2);
    }
    cfg
}

pub fn speed_flair_config(scale: f64) -> Config {
    let mut cfg = crate::config::preset("flair").unwrap();
    cfg.iterations = 8;
    cfg.cohort_size = 12;
    cfg.dataset.num_users = 300;
    cfg.eval_every = 10_000;
    cfg.val_cohort_size = 0;
    if (scale - 1.0).abs() > 1e-12 {
        cfg.iterations = ((cfg.iterations as f64 * scale).round() as u64).max(2);
        cfg.cohort_size = ((cfg.cohort_size as f64 * scale).round() as usize).max(2);
    }
    cfg
}

pub fn speed_so_config(scale: f64) -> Config {
    let mut cfg = crate::config::preset("stackoverflow").unwrap();
    cfg.iterations = 6;
    cfg.cohort_size = 12;
    cfg.dataset.num_users = 400;
    cfg.eval_every = 10_000;
    cfg.val_cohort_size = 0;
    if (scale - 1.0).abs() > 1e-12 {
        cfg.iterations = ((cfg.iterations as f64 * scale).round() as u64).max(2);
        cfg.cohort_size = ((cfg.cohort_size as f64 * scale).round() as usize).max(2);
    }
    cfg
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cost_model_fits_linear_data() {
        let costs: Vec<UserCost> = (1..50)
            .map(|d| UserCost {
                datapoints: d,
                nanos: 1000 + 250 * d as u64,
                device_nanos: 200 * d as u64,
            })
            .collect();
        let (a, b) = fit_cost_model(&costs);
        assert!((a - 1000.0).abs() < 1.0, "a={a}");
        assert!((b - 250.0).abs() < 0.1, "b={b}");
        assert!(cost_correlation(&costs) > 0.999);
    }

    #[test]
    fn cost_model_degenerate_inputs() {
        assert_eq!(fit_cost_model(&[]), (0.0, 0.0));
        let one = [UserCost { datapoints: 5, nanos: 100, device_nanos: 0 }];
        let (a, b) = fit_cost_model(&one);
        assert_eq!(b, 0.0);
        assert_eq!(a, 100.0);
        assert_eq!(cost_correlation(&one), 0.0);
    }

    #[test]
    fn speed_configs_are_small() {
        assert!(speed_cifar_config(1.0).iterations <= 10);
        assert!(speed_flair_config(0.5).iterations >= 2);
        assert!(speed_so_config(2.0).cohort_size >= 20);
    }
}
