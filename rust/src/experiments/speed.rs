//! Tables 1–2 + Figs. 7–8: framework speed comparison.
//!
//! Each engine variant runs the *same* benchmark (same PJRT executables,
//! same hyperparameters, same cohorts) under its overhead profile; the
//! p = 1 rows are real wall-clock, the p > 1 rows are virtual-cluster
//! replays of the measured per-user costs (marked `sim`) because this
//! testbed has a single core. Accuracy is reported as the consistency
//! check of paper Table 1.

use anyhow::Result;

use super::{run_benchmark, EvalMode, RunSummary, TablePrinter};
use crate::baselines::EngineVariant;
use crate::config::Config;
use crate::fl::scheduler::{schedule, SchedulerKind};
use crate::simsys::replay_cluster;

/// One engine's measured + simulated timings.
pub struct EngineRow {
    pub engine: EngineVariant,
    pub p1_wall_secs: f64,
    /// (p, simulated wall secs)
    pub multi: Option<(usize, f64)>,
    /// A100-normalized wall-clock at p = 1 and at the multi-p setting:
    /// the same cohorts replayed with the paper testbed's device time
    /// (8.1 ms/user, Table 1) plus this engine's paper-calibrated
    /// overhead — the column whose *ratios* reproduce Table 1's shape.
    pub a100_p1_secs: f64,
    pub a100_multi_secs: Option<f64>,
    pub accuracy: Option<f64>,
    pub summary: RunSummary,
}

/// Replay the run's cohorts in A100-normalized time: device time scales
/// with user datapoints around the 8.1 ms/user mean; host time is the
/// engine's paper-calibrated per-user overhead. Co-located workers
/// serialize device time, overlap host time (why p > 1 pays off).
fn a100_normalized(summary: &RunSummary, engine: EngineVariant, p: usize) -> f64 {
    let costs = &summary.outcome.user_costs;
    if costs.is_empty() {
        return 0.0;
    }
    let mean_dp: f64 =
        costs.iter().map(|c| c.datapoints as f64).sum::<f64>() / costs.len() as f64;
    let tax = engine.paper_user_overhead_ns();
    let mut total = 0u64;
    let mut idx = 0usize;
    for (_, m) in &summary.outcome.history {
        let cohort = m.get("sys/cohort").unwrap_or(0.0) as usize;
        if cohort == 0 || idx >= costs.len() {
            continue;
        }
        let hi = (idx + cohort).min(costs.len());
        let synthetic: Vec<crate::simsys::UserCost> = costs[idx..hi]
            .iter()
            .map(|c| {
                let scale = c.datapoints as f64 / mean_dp.max(1.0);
                let dev = (EngineVariant::A100_PFL_DEVICE_NS as f64 * scale) as u64;
                let host = (EngineVariant::A100_PFL_HOST_NS as f64 * scale) as u64;
                crate::simsys::UserCost {
                    datapoints: c.datapoints,
                    nanos: dev + host + tax,
                    device_nanos: dev,
                }
            })
            .collect();
        idx = hi;
        let weights: Vec<f64> = synthetic.iter().map(|c| c.datapoints as f64).collect();
        let sched = schedule(engine.scheduler(), &weights, p);
        let queues: Vec<Vec<crate::simsys::UserCost>> = sched
            .assignments
            .iter()
            .map(|a| a.iter().map(|&i| synthetic[i]).collect())
            .collect();
        let (round, _) = replay_cluster(&queues, 1, p, 0);
        total += round;
    }
    total as f64 / 1e9
}

/// Replay the run's cohorts onto 1 device × p workers using the engine's
/// scheduler and its per-user overhead tax.
fn simulate_p(summary: &RunSummary, engine: EngineVariant, p: usize) -> f64 {
    let profile = engine.profile();
    let costs = &summary.outcome.user_costs;
    if costs.is_empty() {
        return summary.wall_secs;
    }
    // Re-schedule each round's measured cohort. Rounds were stored
    // contiguously; recover them via round sizes from history (cohort
    // metric), falling back to one big round.
    let mut total = 0u64;
    let mut idx = 0usize;
    for (_, m) in &summary.outcome.history {
        let cohort = m.get("sys/cohort").unwrap_or(costs.len() as f64) as usize;
        if cohort == 0 || idx >= costs.len() {
            continue;
        }
        let hi = (idx + cohort).min(costs.len());
        let round_costs = &costs[idx..hi];
        idx = hi;
        let weights: Vec<f64> = round_costs.iter().map(|c| c.datapoints as f64).collect();
        let sched = schedule(engine.scheduler(), &weights, p);
        let queues: Vec<Vec<crate::simsys::UserCost>> = sched
            .assignments
            .iter()
            .map(|a| a.iter().map(|&i| round_costs[i]).collect())
            .collect();
        let (round, _) = replay_cluster(&queues, 1, p, profile.per_user_overhead_ns);
        total += round;
    }
    total as f64 / 1e9
}

/// Run one engine on a config; returns measured + simulated rows.
pub fn run_engine(cfg: &Config, engine: EngineVariant, multi_p: usize) -> Result<EngineRow> {
    let mut cfg = cfg.clone();
    cfg.num_workers = 1;
    cfg.scheduler = match engine.scheduler() {
        SchedulerKind::Uniform => "uniform".into(),
        _ => "greedy-median".into(),
    };
    cfg.name = format!("{}:{}", cfg.name, engine.name());
    let summary = run_benchmark(&cfg, engine.profile(), EvalMode::Final, 0)?;
    let multi = if multi_p > 1 && engine.supports_multiprocess() {
        Some((multi_p, simulate_p(&summary, engine, multi_p)))
    } else {
        None
    };
    let a100_p1_secs = a100_normalized(&summary, engine, 1);
    let a100_multi_secs = if multi_p > 1 && engine.supports_multiprocess() {
        Some(a100_normalized(&summary, engine, multi_p))
    } else {
        None
    };
    Ok(EngineRow {
        engine,
        p1_wall_secs: summary.wall_secs,
        multi,
        a100_p1_secs,
        a100_multi_secs,
        accuracy: summary.headline.as_ref().map(|(_, v)| *v),
        summary,
    })
}

fn print_speed_table(title: &str, rows: &[EngineRow], headline: &str) {
    let mut t = TablePrinter::new(&[
        "engine",
        "p",
        "wall-clock (s)",
        "A100-norm (s)",
        headline,
        "pfl is faster (norm)",
    ]);
    // best pfl-style A100-normalized time (the paper compares against
    // pfl's best p setting)
    let pfl_best = rows
        .iter()
        .filter(|r| r.engine == EngineVariant::PflStyle)
        .map(|r| r.a100_multi_secs.unwrap_or(r.a100_p1_secs).min(r.a100_p1_secs))
        .fold(f64::INFINITY, f64::min);
    for r in rows {
        let acc = r
            .accuracy
            .map(|a| format!("{a:.4}"))
            .unwrap_or_else(|| "-".into());
        let speedup = |s: f64| {
            if r.engine == EngineVariant::PflStyle {
                "-".to_string()
            } else {
                format!("{:.1}x", s / pfl_best)
            }
        };
        t.row(vec![
            r.engine.name().into(),
            "1".into(),
            format!("{:.2}", r.p1_wall_secs),
            format!("{:.2}", r.a100_p1_secs),
            acc.clone(),
            speedup(r.a100_p1_secs),
        ]);
        if let (Some((p, s)), Some(ns)) = (r.multi, r.a100_multi_secs) {
            t.row(vec![
                r.engine.name().into(),
                format!("{p} (sim)"),
                format!("{s:.2}"),
                format!("{ns:.2}"),
                acc,
                speedup(ns),
            ]);
        }
    }
    t.print(title);
    println!(
        "# wall-clock: real time on this testbed (CPU device time dominates);\n\
         # A100-norm: same cohorts replayed at the paper testbed's 8.1 ms/user\n\
         #   device time + each engine's paper-calibrated overhead (App. D) —\n\
         #   the ratio column reproduces Table 1's shape."
    );
}

/// Paper Table 1: CIFAR10 speed across engines.
pub fn table1(scale: f64, multi_p: usize) -> Result<Vec<EngineRow>> {
    let cfg = super::speed_cifar_config(scale);
    let mut rows = Vec::new();
    for engine in EngineVariant::all() {
        eprintln!("[table1] running {} ...", engine.name());
        rows.push(run_engine(&cfg, engine, multi_p)?);
    }
    print_speed_table("Table 1: CIFAR10 simulation speed", &rows, "accuracy");
    Ok(rows)
}

/// Paper Table 2: FLAIR speed (pfl 0.1 = greedy, 0.2 = greedy+median,
/// +central DP row, vs TFF-like and Flower-like).
pub fn table2(scale: f64, multi_p: usize) -> Result<()> {
    let base = super::speed_flair_config(scale);

    let mut t = TablePrinter::new(&["framework", "p", "wall-clock (s)", "mAP", "pfl is faster"]);
    // pfl 0.1.0: plain greedy scheduling
    let mut v010 = base.clone();
    v010.scheduler = "greedy".into();
    v010.name = "pfl-0.1.0".into();
    eprintln!("[table2] pfl-0.1.0 (greedy) ...");
    let r010 = run_benchmark(&v010, EngineVariant::PflStyle.profile(), EvalMode::Final, 0)?;

    // pfl 0.2.0: greedy + median base (App. B.6)
    let mut v020 = base.clone();
    v020.scheduler = "greedy-median".into();
    v020.name = "pfl-0.2.0".into();
    eprintln!("[table2] pfl-0.2.0 (greedy+median) ...");
    let r020 = run_benchmark(&v020, EngineVariant::PflStyle.profile(), EvalMode::Final, 0)?;

    // pfl 0.2.0 + central DP (the "+9%" row)
    let mut vdp = v020.clone();
    vdp.name = "pfl-0.2.0+dp".into();
    vdp.privacy = crate::config::preset("flair-dp").unwrap().privacy;
    vdp.privacy.noise_cohort = (vdp.cohort_size as f64) * 25.0;
    eprintln!("[table2] pfl-0.2.0 + central DP ...");
    let rdp = run_benchmark(&vdp, EngineVariant::PflStyle.profile(), EvalMode::Final, 0)?;

    // baselines
    eprintln!("[table2] tff-like ...");
    let rtff = run_engine(&base, EngineVariant::TffLike, multi_p)?;
    eprintln!("[table2] flower-like ...");
    let rflower = run_engine(&base, EngineVariant::FlowerLike, multi_p)?;

    let pfl = r020.wall_secs;
    let map = |s: &RunSummary| {
        s.headline
            .as_ref()
            .map(|(_, v)| format!("{v:.4}"))
            .unwrap_or_else(|| "-".into())
    };
    t.row(vec!["pfl-0.1.0".into(), "1".into(), format!("{:.2}", r010.wall_secs), map(&r010), "-".into()]);
    t.row(vec!["pfl-0.2.0".into(), "1".into(), format!("{:.2}", r020.wall_secs), map(&r020), "-".into()]);
    t.row(vec![
        "pfl-0.2.0 +DP".into(),
        "1".into(),
        format!("{:.2} (+{:.0}%)", rdp.wall_secs, 100.0 * (rdp.wall_secs / pfl - 1.0)),
        "-".into(),
        "-".into(),
    ]);
    for r in [&rtff, &rflower] {
        let (p, s) = r.multi.unwrap_or((1, r.p1_wall_secs));
        t.row(vec![
            r.engine.name().into(),
            format!("{p}{}", if p > 1 { " (sim)" } else { "" }),
            format!("{s:.2}"),
            r.accuracy.map(|a| format!("{a:.4}")).unwrap_or_else(|| "-".into()),
            format!("{:.1}x", s / pfl),
        ]);
    }
    t.print("Table 2: FLAIR simulation speed");
    Ok(())
}

/// Figs. 7–8: system metric timelines per engine (TSV).
pub fn fig7_fig8(scale: f64) -> Result<()> {
    let cfg = super::speed_cifar_config(scale);
    for engine in EngineVariant::all() {
        eprintln!("[fig7] running {} ...", engine.name());
        let row = run_engine(&cfg, engine, 1)?;
        let o = &row.summary.outcome;
        println!("\n# engine={} (p=1)", engine.name());
        println!("round\twall_s\trss_mb\talloc_mb\tcopy_mb\twire_mb\tdevice_busy_frac");
        let total_busy: u64 = o.worker_busy_nanos.iter().sum();
        let busy_frac = if o.wall_secs > 0.0 {
            (total_busy as f64 / 1e9) / o.wall_secs
        } else {
            0.0
        };
        for r in &o.timeline.rows {
            println!(
                "{}\t{:.2}\t{:.1}\t{:.1}\t{:.1}\t{:.1}\t{:.3}",
                r.round,
                r.wall_secs,
                r.rss_bytes as f64 / 1e6,
                r.loop_alloc_bytes as f64 / 1e6,
                r.copy_bytes as f64 / 1e6,
                o.counters.wire_bytes as f64 / 1e6,
                busy_frac,
            );
        }
        println!(
            "# totals: users={} steps={} loop_alloc={:.1}MB copies={:.1}MB wire={:.1}MB coord_msgs={}",
            o.counters.users_trained,
            o.counters.steps,
            o.counters.loop_alloc_bytes as f64 / 1e6,
            o.counters.copy_bytes as f64 / 1e6,
            o.counters.wire_bytes as f64 / 1e6,
            o.counters.coordinator_msgs,
        );
    }
    Ok(())
}
