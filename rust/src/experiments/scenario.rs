//! Device-realism study (DESIGN.md §8): completion rate vs cohort size
//! under deterministic churn, diurnal availability windows and a
//! mid-round dropout hazard.
//!
//! Production FL deployments over-provision cohorts because devices go
//! offline mid-round; this table reproduces that sizing curve on the
//! simulator. Every profile and every per-round draw is a pure function
//! of `(seed, uid)` through counter-based RNG streams, so the same curve
//! comes out for any worker count or dispatch mode.

use std::sync::Arc;

use anyhow::Result;

use super::TablePrinter;
use crate::data::{FederatedDataset, SynthTabular};
use crate::fl::algorithm::RunSpec;
use crate::fl::backend::{BackendBuilder, RunParams};
use crate::fl::central_opt::Sgd;
use crate::fl::context::{DispatchSpec, LocalParams};
use crate::fl::device::ScenarioSpec;
use crate::fl::{FedAvg, LinearModel, Model, SchedulerKind};

const DIM: usize = 8;

fn mean(series: &[(u64, f64)]) -> f64 {
    if series.is_empty() {
        return 0.0;
    }
    series.iter().map(|(_, v)| v).sum::<f64>() / series.len() as f64
}

/// One row per (scenario severity × cohort size); the completion-rate
/// column is the sizing curve.
pub fn completion_curves(scale: f64, workers: usize) -> Result<()> {
    let users = ((240.0 * scale) as usize).max(48);
    let iterations = ((16.0 * scale) as u64).max(6);
    let mut t = TablePrinter::new(&[
        "scenario",
        "cohort",
        "completion",
        "dropout-frac",
        "unavail/round",
        "dropped users",
        "final loss",
    ]);

    let scenarios: [(&str, ScenarioSpec); 3] = [
        ("off", ScenarioSpec::disabled()),
        (
            "mild (churn=.1 diurnal=.25 drop=.05)",
            ScenarioSpec { churn: 0.1, diurnal: 0.25, dropout_hazard: 0.05, speed_tiers: 3 },
        ),
        (
            "harsh (churn=.3 diurnal=.5 drop=.2)",
            ScenarioSpec { churn: 0.3, diurnal: 0.5, dropout_hazard: 0.2, speed_tiers: 4 },
        ),
    ];
    let cohorts = [users / 8, users / 4, users / 2];

    for (label, spec) in scenarios {
        for &cohort in &cohorts {
            let cohort = cohort.max(4);
            let dataset: Arc<dyn FederatedDataset> =
                Arc::new(SynthTabular::new(users, 64, DIM, 42));
            let rspec = RunSpec {
                iterations,
                cohort_size: cohort,
                val_cohort_size: 0,
                eval_every: 0,
                local: LocalParams { epochs: 1, batch_size: 8, lr: 0.05, mu: 0.0, max_steps: 0 },
                central_lr: 1.0,
                central_lr_warmup: 0,
                population: users,
                seed: 3,
                dispatch: DispatchSpec::default(),
            };
            let alg = Arc::new(FedAvg::new(rspec, Box::new(Sgd)));
            let mut backend = BackendBuilder::new(
                dataset,
                alg,
                Arc::new(|_| Ok(Box::new(LinearModel::new(DIM)) as Box<dyn Model>)),
            )
            .params(RunParams {
                num_workers: workers,
                scheduler: SchedulerKind::GreedyMedianBase,
                seed: 7,
                scenario: spec,
                ..Default::default()
            })
            .build()?;
            let out = backend.run(vec![0.0; LinearModel::param_len(DIM)], &mut [])?;

            let completion = out.series("sys/completion-rate");
            let dropfrac = out.series("sys/dropout-frac");
            let unavail = out.series("sys/unavailable-skipped");
            t.row(vec![
                label.into(),
                format!("{cohort}"),
                if completion.is_empty() {
                    "1.000 (off)".into()
                } else {
                    format!("{:.3}", mean(&completion))
                },
                format!("{:.3}", mean(&dropfrac)),
                format!("{:.1}", mean(&unavail)),
                format!("{}", out.counters.dropout_users),
                out.series("train/loss")
                    .last()
                    .map(|(_, v)| format!("{v:.4}"))
                    .unwrap_or_else(|| "n/a".into()),
            ]);
        }
    }
    t.print("Device realism: completion rate vs cohort size under churn + dropout");
    println!("# completion = folded / intended cohort; diurnal windows shrink the available");
    println!("# population per round, the dropout hazard discards partials mid-round.");
    println!("# Profiles are counter-keyed by (seed, uid): the curve is identical for any");
    println!("# worker count and for threaded vs socket transports (see rust/tests).");
    Ok(())
}
