//! Figs. 2–3: distributed-simulation scaling via the virtual cluster.
//!
//! A single measured run per benchmark yields per-user (host, device)
//! costs; the replay re-schedules those cohorts onto g devices × p
//! workers (device time serializes per device, host time overlaps —
//! `simsys::replay_cluster`). Wall-clock and GPU-hours are then exact
//! functions of the schedule, which is what the paper's scaling figures
//! measure (scheduling quality, utilization, stragglers).

use anyhow::Result;

use super::{fit_cost_model, run_benchmark, EvalMode, RunSummary, TablePrinter};
use crate::baselines::EngineVariant;
use crate::fl::scheduler::{schedule, SchedulerKind};
use crate::simsys::{replay_cluster, UserCost};
use crate::util::rng::Rng;

/// Group a run's user costs back into per-round cohorts.
fn rounds_of(summary: &RunSummary) -> Vec<Vec<UserCost>> {
    let costs = &summary.outcome.user_costs;
    let mut rounds = Vec::new();
    let mut idx = 0;
    for (_, m) in &summary.outcome.history {
        let cohort = m.get("sys/cohort").unwrap_or(0.0) as usize;
        if cohort == 0 || idx >= costs.len() {
            continue;
        }
        let hi = (idx + cohort).min(costs.len());
        rounds.push(costs[idx..hi].to_vec());
        idx = hi;
    }
    rounds
}

/// Re-split each measured cost into the paper testbed's device/host
/// proportions (A100: ~41% serialized device work, ~59% overlappable
/// host work — derived from paper Table 1's p=1 vs p=5 pfl rows). On
/// this CPU the device fraction is ~95%, which is not representative of
/// the GPU overlap the paper's Figs. 2–3 demonstrate; the A100-split
/// column is the reproduction target, the raw column the honest local
/// measurement.
pub fn a100_split(rounds: &[Vec<UserCost>]) -> Vec<Vec<UserCost>> {
    rounds
        .iter()
        .map(|r| {
            r.iter()
                .map(|c| UserCost {
                    datapoints: c.datapoints,
                    nanos: c.nanos,
                    device_nanos: (c.nanos as f64 * 0.41) as u64,
                })
                .collect()
        })
        .collect()
}

/// Replay a run onto (gpus × per_gpu) workers; returns (total_secs,
/// gpu_hours).
pub fn replay(rounds: &[Vec<UserCost>], gpus: usize, per_gpu: usize) -> (f64, f64) {
    let workers = gpus * per_gpu;
    let mut total = 0u64;
    for round in rounds {
        let weights: Vec<f64> = round.iter().map(|c| c.datapoints as f64).collect();
        let sched = schedule(SchedulerKind::GreedyMedianBase, &weights, workers);
        let queues: Vec<Vec<UserCost>> = sched
            .assignments
            .iter()
            .map(|a| a.iter().map(|&i| round[i]).collect())
            .collect();
        let (r, _) = replay_cluster(&queues, gpus, per_gpu, 0);
        total += r;
    }
    let secs = total as f64 / 1e9;
    (secs, secs * gpus as f64 / 3600.0)
}

fn measure(cfg: &crate::config::Config) -> Result<RunSummary> {
    run_benchmark(cfg, EngineVariant::PflStyle.profile(), EvalMode::None, 0)
}

/// Fig. 2 / Fig. 3 left: wall-clock vs processes per GPU, hardware
/// pinned (1 virtual GPU).
pub fn fig2(scale: f64, max_p: usize) -> Result<()> {
    let mut t = TablePrinter::new(&["benchmark", "p", "wall-clock (s, sim)", "rel. to p=1"]);
    for (name, cfg) in [
        ("cifar10", super::speed_cifar_config(scale)),
        ("stackoverflow", super::speed_so_config(scale)),
        ("flair", super::speed_flair_config(scale)),
    ] {
        eprintln!("[fig2] measuring {name} ...");
        let summary = measure(&cfg)?;
        let rounds = rounds_of(&summary);
        let norm = a100_split(&rounds);
        let (base, _) = replay(&rounds, 1, 1);
        let (nbase, _) = replay(&norm, 1, 1);
        for p in 1..=max_p {
            let (secs, _) = replay(&rounds, 1, p);
            let (nsecs, _) = replay(&norm, 1, p);
            t.row(vec![
                name.into(),
                p.to_string(),
                format!("{secs:.2}"),
                format!("{:.2} / {:.2} (A100-split)", secs / base, nsecs / nbase),
            ]);
        }
    }
    t.print("Fig 2: speedup from processes per GPU (virtual cluster)");
    println!(
        "# expectation: monotone decrease with p until device saturation; \
         FLAIR saturates earliest (largest model => device-bound)."
    );
    Ok(())
}

/// Synthetic cohort costs from the fitted linear cost model (Fig. 3
/// right panel's 50k cohort).
fn synthetic_rounds(
    summary: &RunSummary,
    cohort: usize,
    rounds: usize,
    seed: u64,
) -> Vec<Vec<UserCost>> {
    let (a, b) = fit_cost_model(&summary.outcome.user_costs);
    let dev_frac = {
        let costs = &summary.outcome.user_costs;
        let dev: u64 = costs.iter().map(|c| c.device_nanos).sum();
        let tot: u64 = costs.iter().map(|c| c.nanos).sum();
        if tot == 0 {
            0.5
        } else {
            dev as f64 / tot as f64
        }
    };
    let mut rng = Rng::seed_from_u64(seed);
    (0..rounds)
        .map(|_| {
            (0..cohort)
                .map(|_| {
                    let d = (rng.lognormal(2.5, 1.0).ceil() as usize).clamp(1, 512);
                    let nanos = (a + b * d as f64).max(1.0) as u64;
                    UserCost {
                        datapoints: d,
                        nanos,
                        device_nanos: (nanos as f64 * dev_frac) as u64,
                    }
                })
                .collect()
        })
        .collect()
}

/// Fig. 3: wall-clock + GPU-hours vs #GPUs (left: measured cohort;
/// right: synthetic 50k cohort from the fitted cost model).
pub fn fig3(scale: f64, big_cohort: usize) -> Result<()> {
    let cfg = super::speed_so_config(scale);
    eprintln!("[fig3] measuring stackoverflow ...");
    let summary = measure(&cfg)?;
    let rounds = a100_split(&rounds_of(&summary));

    let mut t = TablePrinter::new(&["panel", "gpus", "p", "wall-clock (s, sim)", "gpu-hours (sim)"]);
    for &gpus in &[1usize, 2, 4, 8, 16, 32] {
        for &p in &[1usize, 3, 5] {
            let (secs, gpu_h) = replay(&rounds, gpus, p);
            t.row(vec![
                "left".into(),
                gpus.to_string(),
                p.to_string(),
                format!("{secs:.2}"),
                format!("{gpu_h:.4}"),
            ]);
        }
    }

    let big = a100_split(&synthetic_rounds(&summary, big_cohort, rounds.len().max(1), 42));
    for &gpus in &[8usize, 16, 32, 64] {
        for &p in &[1usize, 5] {
            let (secs, gpu_h) = replay(&big, gpus, p);
            t.row(vec![
                format!("right (cohort {big_cohort})"),
                gpus.to_string(),
                p.to_string(),
                format!("{secs:.2}"),
                format!("{gpu_h:.4}"),
            ]);
        }
    }
    t.print("Fig 3: scaling number of GPUs (virtual cluster)");
    println!(
        "# expectation: wall-clock falls with gpus; gpu-hours rise as load \
         balancing loses slack (left), but stay nearly flat with a 50k \
         cohort (right; paper: +3.6% from 16->32 GPUs)."
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_rounds() -> Vec<Vec<UserCost>> {
        (0..4)
            .map(|r| {
                (0..40)
                    .map(|i| {
                        let d = 1 + (i * 7 + r * 3) % 50;
                        UserCost {
                            datapoints: d,
                            nanos: (1000 + 100 * d) as u64,
                            device_nanos: (70 * d) as u64,
                        }
                    })
                    .collect()
            })
            .collect()
    }

    #[test]
    fn replay_monotone_in_p_until_saturation() {
        let rounds = fake_rounds();
        let (p1, _) = replay(&rounds, 1, 1);
        let (p2, _) = replay(&rounds, 1, 2);
        let (p5, _) = replay(&rounds, 1, 5);
        assert!(p2 < p1, "{p2} !< {p1}");
        assert!(p5 <= p2 + 1e-9);
        // device-time floor: can never beat sum of device time on 1 gpu
        let dev_floor: u64 = rounds
            .iter()
            .map(|r| r.iter().map(|c| c.device_nanos).sum::<u64>())
            .sum();
        assert!(p5 >= dev_floor as f64 / 1e9 - 1e-9);
    }

    #[test]
    fn replay_scales_with_gpus() {
        let rounds = fake_rounds();
        let (g1, h1) = replay(&rounds, 1, 2);
        let (g4, h4) = replay(&rounds, 4, 2);
        assert!(g4 < g1);
        // gpu-hours grow (or stay equal) when splitting across devices
        assert!(h4 >= h1 * 0.99, "h4 {h4} vs h1 {h1}");
    }
}
