//! Table 5 + Figs. 4–5: worker-scheduling studies (paper App. B.6).
//!
//! One measured FLAIR-style run provides per-user costs (Fig. 4a's
//! correlation); the three schedulers are then compared on *measured*
//! straggler gaps via the replay, exactly the quantity Table 5 reports.

use anyhow::Result;

use super::{cost_correlation, run_benchmark, EvalMode, RunSummary, TablePrinter};
use crate::baselines::EngineVariant;
use crate::fl::scheduler::{median, schedule, SchedulerKind};
use crate::simsys::{replay_round, straggler_gap_nanos, UserCost};

fn measure_flair(scale: f64) -> Result<RunSummary> {
    let cfg = super::speed_flair_config(scale);
    run_benchmark(&cfg, EngineVariant::PflStyle.profile(), EvalMode::None, 0)
}

fn rounds_of(summary: &RunSummary) -> Vec<Vec<UserCost>> {
    let costs = &summary.outcome.user_costs;
    let mut rounds = Vec::new();
    let mut idx = 0;
    for (_, m) in &summary.outcome.history {
        let cohort = m.get("sys/cohort").unwrap_or(0.0) as usize;
        if cohort == 0 || idx >= costs.len() {
            continue;
        }
        let hi = (idx + cohort).min(costs.len());
        rounds.push(costs[idx..hi].to_vec());
        idx = hi;
    }
    rounds
}

/// Mean straggler gap over rounds for one scheduler (ms).
fn mean_gap_ms(rounds: &[Vec<UserCost>], kind: SchedulerKind, workers: usize) -> f64 {
    let mut total = 0u64;
    for round in rounds {
        let weights: Vec<f64> = round.iter().map(|c| c.datapoints as f64).collect();
        let sched = schedule(kind, &weights, workers);
        let (_, busy) = replay_round(round, &sched.assignments, 0);
        total += straggler_gap_nanos(&busy);
    }
    total as f64 / rounds.len().max(1) as f64 / 1e6
}

/// Table 5: maximum straggler time per scheduling policy.
pub fn table5(scale: f64, workers: usize) -> Result<()> {
    eprintln!("[table5] measuring FLAIR-style run ...");
    let summary = measure_flair(scale)?;
    let rounds = rounds_of(&summary);

    let mut t = TablePrinter::new(&["setup", "mean straggler time (ms)"]);
    let uniform = mean_gap_ms(&rounds, SchedulerKind::Uniform, workers);
    let greedy = mean_gap_ms(&rounds, SchedulerKind::Greedy, workers);
    let greedy_median = mean_gap_ms(&rounds, SchedulerKind::GreedyMedianBase, workers);
    t.row(vec!["No scheduling (uniform user split)".into(), format!("{uniform:.1}")]);
    t.row(vec!["Greedy scheduling".into(), format!("{greedy:.1}")]);
    t.row(vec!["Greedy scheduling +median".into(), format!("{greedy_median:.1}")]);
    t.print("Table 5: maximum straggler time, averaged over iterations");
    println!("# paper: 1294 / 484 / 178 ms — expect uniform >> greedy >= greedy+median");
    Ok(())
}

/// Fig. 4a: per-user dataset size vs wall-clock scatter (TSV) + the
/// correlation that justifies weight-by-size scheduling.
pub fn fig4a(scale: f64) -> Result<()> {
    eprintln!("[fig4a] measuring FLAIR-style run ...");
    let summary = measure_flair(scale)?;
    let costs = &summary.outcome.user_costs;
    println!("datapoints\twall_ms\tdevice_ms");
    for c in costs.iter().take(2000) {
        println!(
            "{}\t{:.3}\t{:.3}",
            c.datapoints,
            c.nanos as f64 / 1e6,
            c.device_nanos as f64 / 1e6
        );
    }
    println!("# correlation(datapoints, wall) = {:.4}", cost_correlation(costs));
    Ok(())
}

/// Fig. 4b: wall-clock change as a base value is added to user weights.
pub fn fig4b(scale: f64, workers: usize) -> Result<()> {
    eprintln!("[fig4b] measuring FLAIR-style run ...");
    let summary = measure_flair(scale)?;
    let rounds = rounds_of(&summary);
    let all_weights: Vec<f64> = rounds
        .iter()
        .flat_map(|r| r.iter().map(|c| c.datapoints as f64))
        .collect();
    let med = median(&all_weights);

    let mut t = TablePrinter::new(&["base value", "total wall-clock (s, sim)", "rel. to base=0"]);
    let mut base0 = 0.0;
    for mult in [0.0, 0.25, 0.5, 1.0, 2.0, 4.0] {
        let base = med * mult;
        let mut total = 0u64;
        for round in &rounds {
            let weights: Vec<f64> = round.iter().map(|c| c.datapoints as f64).collect();
            let sched = schedule(SchedulerKind::GreedyBase { base }, &weights, workers);
            let (r, _) = replay_round(round, &sched.assignments, 50_000);
            total += r;
        }
        let secs = total as f64 / 1e9;
        if mult == 0.0 {
            base0 = secs;
        }
        t.row(vec![
            format!("{:.1} ({}x median)", base, mult),
            format!("{secs:.3}"),
            format!("{:.4}", secs / base0),
        ]);
    }
    t.print("Fig 4b: effect of scheduling base value");
    println!("# paper: base ≈ median is optimal (~3% over greedy, 19% over none)");
    Ok(())
}

/// Fig. 5: per-worker weight totals for one cohort under each scheduler.
pub fn fig5(scale: f64, workers: usize) -> Result<()> {
    eprintln!("[fig5] measuring FLAIR-style run ...");
    let summary = measure_flair(scale)?;
    let rounds = rounds_of(&summary);
    let Some(round) = rounds.iter().max_by_key(|r| r.len()) else {
        anyhow::bail!("no rounds recorded");
    };
    let weights: Vec<f64> = round.iter().map(|c| c.datapoints as f64).collect();

    for (label, kind) in [
        ("a) uniform", SchedulerKind::Uniform),
        ("b) greedy", SchedulerKind::Greedy),
        ("c) greedy+median", SchedulerKind::GreedyMedianBase),
    ] {
        let sched = schedule(kind, &weights, workers);
        let (_, busy) = replay_round(round, &sched.assignments, 0);
        println!("\n# {label}");
        println!("worker\tusers\ttotal_weight\twall_ms");
        for (w, a) in sched.assignments.iter().enumerate() {
            println!(
                "{w}\t{}\t{:.0}\t{:.3}",
                a.len(),
                sched.totals[w],
                busy[w] as f64 / 1e6
            );
        }
        println!("# straggler gap: {:.3} ms", straggler_gap_nanos(&busy) as f64 / 1e6);
    }
    Ok(())
}
