//! Fig. 6 + the accountant comparison: SNR and accuracy when trading
//! cohort size C against the noise-rescaling factor r (paper App. C.4).
//!
//! Left panel: SNR (Eq. 1) for sweeps of C (black) and r (red). Right
//! panel: accuracy for the same sweeps. The paper's claim: the two sweeps
//! correlate ≈ 1, so small-C + rescaled-noise simulations predict the
//! large-C̃ deployment.

use anyhow::Result;

use super::{run_benchmark, EvalMode, TablePrinter};
use crate::baselines::EngineVariant;
use crate::privacy::{accountant_by_name, AccountantParams};

/// Fig. 6: sweep C with full noise vs sweep r at fixed C.
pub fn fig6(scale: f64, seeds: u64) -> Result<()> {
    let base = {
        let mut c = crate::config::preset("cifar10-iid-dp").unwrap();
        c.iterations = ((40.0 * scale).round() as u64).max(10);
        c.dataset.num_users = 400;
        c.eval_every = c.iterations; // final eval only
        c.val_cohort_size = 0;
        c
    };

    let mut t = TablePrinter::new(&["sweep", "C", "r", "SNR (mean)", "accuracy"]);
    // Sweep 1 (black): increase the real cohort size C, noise for C̃ = C
    // (no rescaling: r = 1 by setting noise_cohort = C).
    for &c in &[5usize, 10, 20, 40] {
        let mut cfg = base.clone();
        cfg.cohort_size = c;
        cfg.privacy.noise_cohort = c as f64;
        cfg.name = format!("fig6-C{c}");
        let (snr, acc) = run_point(&cfg, seeds)?;
        t.row(vec![
            "cohort C".into(),
            c.to_string(),
            "1.0".into(),
            format!("{snr:.3}"),
            format!("{acc:.4}"),
        ]);
    }
    // Sweep 2 (red): fix C small, reduce the noise via r = C/C̃.
    let c_fixed = 5usize;
    for &ctilde in &[5.0f64, 10.0, 20.0, 40.0] {
        let mut cfg = base.clone();
        cfg.cohort_size = c_fixed;
        cfg.privacy.noise_cohort = ctilde;
        cfg.name = format!("fig6-r{}", c_fixed as f64 / ctilde);
        let (snr, acc) = run_point(&cfg, seeds)?;
        t.row(vec![
            "noise scale r".into(),
            c_fixed.to_string(),
            format!("{:.3}", c_fixed as f64 / ctilde),
            format!("{snr:.3}"),
            format!("{acc:.4}"),
        ]);
    }
    t.print("Fig 6: SNR and accuracy, cohort size C vs noise scale r");
    println!("# paper: the two sweeps trace the same curve (correlation ~1)");
    Ok(())
}

fn run_point(cfg: &crate::config::Config, seeds: u64) -> Result<(f64, f64)> {
    let mut snrs = Vec::new();
    let mut accs = Vec::new();
    for seed in 0..seeds.max(1) {
        let mut c = cfg.clone();
        c.seed = seed;
        let s = run_benchmark(&c, EngineVariant::PflStyle.profile(), EvalMode::Final, 0)?;
        // mean SNR over the last half of training
        let series = s.outcome.series("dp/snr");
        let half = &series[series.len() / 2..];
        let snr = half.iter().map(|(_, v)| v).sum::<f64>() / half.len().max(1) as f64;
        snrs.push(snr);
        accs.push(s.headline.map(|(_, v)| v).unwrap_or(f64::NAN));
    }
    Ok((
        snrs.iter().sum::<f64>() / snrs.len() as f64,
        accs.iter().sum::<f64>() / accs.len() as f64,
    ))
}

/// The `calibrate` command: σ for each accountant on each DP benchmark
/// (the workflow of paper Table 7 / App. C.4).
pub fn calibrate() -> Result<()> {
    let mut t = TablePrinter::new(&[
        "benchmark",
        "q = C~/M",
        "T",
        "sigma (rdp)",
        "sigma (pld)",
        "sigma (prv)",
    ]);
    for name in ["cifar10-iid-dp", "stackoverflow-dp", "flair-dp", "llm-sa-dp"] {
        let cfg = crate::config::preset(name)?;
        let p = AccountantParams {
            sampling_rate: cfg.privacy.noise_cohort / cfg.privacy.population_m,
            delta: cfg.privacy.delta,
            steps: cfg.iterations,
        };
        let mut sigmas = Vec::new();
        for acc_name in ["rdp", "pld", "prv"] {
            let acc = accountant_by_name(acc_name)?;
            let sigma = acc.calibrate_sigma(cfg.privacy.epsilon, &p)?;
            sigmas.push(sigma);
        }
        t.row(vec![
            name.into(),
            format!("{:.1e}", p.sampling_rate),
            p.steps.to_string(),
            format!("{:.4}", sigmas[0]),
            format!("{:.4}", sigmas[1]),
            format!("{:.4}", sigmas[2]),
        ]);
    }
    t.print("Noise calibration: sigma for (eps=2, delta=1e-6, M=1e6)");
    println!("# tighter accountants need smaller sigma: expect pld <= rdp, prv ~ pld");
    Ok(())
}
