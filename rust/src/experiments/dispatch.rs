//! Dispatch-mode study (extends App. B.6 / Table 5): measured straggler
//! gap, steal and staleness accounting for the three dispatch engines
//! (`fl::dispatch`) on a pure-Rust heavy-tailed task — runs without the
//! PJRT artifacts, so it works in `--no-default-features` builds too.
//!
//! The paper's Table 5 shows static greedy scheduling shrinking the
//! straggler gap; this table shows the pull-based queue shrinking it
//! further (the gap is bounded by one user's tail) and the async engine
//! removing the barrier entirely.

use std::sync::Arc;

use anyhow::Result;

use super::TablePrinter;
use crate::data::{FederatedDataset, SynthTabular};
use crate::fl::algorithm::RunSpec;
use crate::fl::backend::{BackendBuilder, RunParams};
use crate::fl::central_opt::Sgd;
use crate::fl::context::{DispatchSpec, LocalParams};
use crate::fl::{FedAvg, LinearModel, Model, SchedulerKind};

const DIM: usize = 8;

/// One row per dispatch mode on the same cohort stream.
pub fn compare(scale: f64, workers: usize) -> Result<()> {
    let users = ((160.0 * scale) as usize).max(32);
    let iterations = ((12.0 * scale) as u64).max(4);
    let mut t = TablePrinter::new(&[
        "mode",
        "rounds",
        "wall (s)",
        "straggler (ms, mean)",
        "steals",
        "stale",
        "dropped",
        "final loss",
    ]);

    for (label, spec) in [
        ("static (paper App. B.6)", DispatchSpec::default()),
        ("work-stealing", DispatchSpec::work_stealing()),
        ("async K=50% s<=2", DispatchSpec::async_mode(2, 0.5)),
        ("async replay w=8", DispatchSpec::async_replay(2, 0.5, 8)),
    ] {
        let dataset: Arc<dyn FederatedDataset> = Arc::new(SynthTabular::new(users, 64, DIM, 42));
        let rspec = RunSpec {
            iterations,
            cohort_size: (users / 4).max(8),
            val_cohort_size: 0,
            eval_every: 0,
            local: LocalParams { epochs: 2, batch_size: 8, lr: 0.05, mu: 0.0, max_steps: 0 },
            central_lr: 1.0,
            central_lr_warmup: 0,
            population: users,
            seed: 3,
            dispatch: spec,
        };
        let alg = Arc::new(FedAvg::new(rspec, Box::new(Sgd)));
        let mut backend = BackendBuilder::new(
            dataset,
            alg,
            Arc::new(|_| Ok(Box::new(LinearModel::new(DIM)) as Box<dyn Model>)),
        )
        .params(RunParams {
            num_workers: workers,
            scheduler: SchedulerKind::GreedyMedianBase,
            dispatch: spec,
            seed: 7,
            ..Default::default()
        })
        .build()?;
        let out = backend.run(vec![0.0; LinearModel::param_len(DIM)], &mut [])?;

        let mean_gap_ms = if out.straggler_nanos.is_empty() {
            0.0
        } else {
            out.straggler_nanos.iter().sum::<u64>() as f64
                / out.straggler_nanos.len() as f64
                / 1e6
        };
        t.row(vec![
            label.into(),
            format!("{}", out.rounds),
            format!("{:.3}", out.wall_secs),
            format!("{mean_gap_ms:.3}"),
            format!("{}", out.counters.steal_count),
            format!("{}", out.counters.stale_updates),
            format!("{}", out.counters.dropped_updates),
            out.series("train/loss")
                .last()
                .map(|(_, v)| format!("{v:.4}"))
                .unwrap_or_else(|| "n/a".into()),
        ]);
    }
    t.print("Dispatch modes: straggler gap under static vs pull-based dispatch");
    println!("# static pays the LPT residual gap; work-stealing bounds it by one user's tail;");
    println!("# async pays no barrier at all (its gap column is 0 by construction);");
    println!("# async replay folds in dispatch order — bit-identical across worker counts.");
    Ok(())
}
