//! `pfl` — the simulation launcher + experiment harness CLI.
//!
//! ```text
//! pfl run --preset cifar10-iid [--scale 0.05] [--workers 2] ...
//! pfl run --config path.json
//! pfl worker --connect ADDR                   # socket-fed worker process
//! pfl materialize --preset X --out DIR        # write an on-disk store
//! pfl import --in corpus.jsonl --out DIR      # import a real corpus
//! pfl store stat DIR                          # summarize a store
//! pfl table1|table2|table3|table4|table5      # paper tables
//! pfl fig2|fig3|fig4a|fig4b|fig5|fig6|fig7    # paper figures
//! pfl calibrate                               # DP noise calibration
//! pfl nonnn                                   # federated GBDT/GMM demo
//! pfl presets [--dump]                        # hyperparameter tables
//! ```
//!
//! Every experiment accepts `--scale f` (compute budget relative to the
//! built-in CPU-sized default) and prints the rows/series of the
//! corresponding paper table/figure.

use anyhow::{bail, Context, Result};

use pfl::baselines::EngineVariant;
use pfl::experiments;
use pfl::fl::callbacks::{Callback, CsvReporter, JsonlReporter};
use pfl::util::cli::Args;

fn main() {
    if let Err(e) = real_main() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

const HELP: &str = "\
pfl — Rust+JAX+Pallas reproduction of pfl-research (NeurIPS 2024)

USAGE: pfl <command> [--key value]...

COMMANDS
  run        run one benchmark      --preset NAME | --config FILE
                                    [--scale F] [--workers N]
                                    [--algorithm A] [--mechanism M]
                                    [--dispatch static|work-stealing|async|socket]
                                    [--max-staleness N] [--buffer-frac F]
                                    [--reorder-window N] [--sparse-spill-frac F]
                                    [--listen ADDR] [--spawn-workers]
                                    [--heartbeat-ms N]
                                    [--data-store DIR] [--cache-users N]
                                    [--prefetch-depth N] [--store-mmap on|off]
                                    [--quantize none|f16|int8] [--fold-tree]
                                    [--noise-threads N]
                                    [--scenario churn=F,diurnal=F,dropout=F,tiers=N | off]
                                    [--iterations N] [--cohort N] [--seed S]
                                    [--csv PATH] [--jsonl PATH] [--log K]
  worker     socket-fed worker process --connect ADDR
             (connects to a `pfl run --dispatch socket` server, receives
             the config over the wire, then trains users it is sent)
  materialize  write a preset/config dataset to an on-disk sharded store
                                    --preset NAME | --config FILE
                                    --out DIR [--scale F]
                                    [--users-per-shard N] [--eval-shard N]
                                    [--compression none|shuffle-lz]
  import     import a JSONL/CSV tabular corpus into a sharded store
                                    --in FILE --out DIR [--name NAME]
                                    [--format jsonl|csv] [--users-per-shard N]
                                    [--compression none|shuffle-lz]
  store      `store stat DIR` — summarize a store from headers + index
             (population, shards, raw vs on-disk bytes, ratio, version)
  table1     CIFAR10 speed vs baseline engines   [--scale F] [--p N]
  table2     FLAIR speed (+DP overhead row)      [--scale F] [--p N]
  table3     algorithm suite, no DP    [--benchmarks a,b] [--scale F] [--seeds N]
  table4     algorithm suite, central DP (same options)
  table5     straggler time per scheduler        [--scale F] [--workers N]
  fig2       wall-clock vs processes/GPU         [--scale F] [--max-p N]
  fig3       scaling #GPUs (+50k-cohort panel)   [--scale F] [--big-cohort N]
  fig4a      user size vs wall-clock scatter     [--scale F]
  fig4b      scheduling base-value sweep         [--scale F] [--workers N]
  fig5       per-worker load histograms          [--scale F] [--workers N]
  fig6       SNR/accuracy: cohort C vs noise r   [--scale F] [--seeds N]
  fig7       system-metric timelines per engine  [--scale F]
  dispatch   straggler gap + round time per dispatch mode
                                    [--scale F] [--workers N]
  scenario   device realism: completion rate vs cohort size under
             churn / diurnal windows / dropout  [--scale F] [--workers N]
  calibrate  DP noise calibration per accountant
  nonnn      federated GBDT + GMM convergence
  presets    list benchmark presets  [--dump]
  engines    list baseline engine emulations
";

fn real_main() -> Result<()> {
    let args = Args::from_env()?;
    let Some(cmd) = args.subcommand.clone() else {
        print!("{HELP}");
        return Ok(());
    };
    let scale = args.get_f64("scale", 1.0)?;
    match cmd.as_str() {
        "help" | "--help" => print!("{HELP}"),
        "run" => cmd_run(&args)?,
        "worker" => cmd_worker(&args)?,
        "materialize" => cmd_materialize(&args)?,
        "import" => cmd_import(&args)?,
        "store" => cmd_store(&args)?,
        "table1" => {
            experiments::speed::table1(scale, args.get_usize("p", 5)?)?;
        }
        "table2" => experiments::speed::table2(scale, args.get_usize("p", 5)?)?,
        "table3" | "table4" => {
            let benchmarks: Vec<String> = args
                .get_str("benchmarks", "cifar10-iid,cifar10-noniid")
                .split(',')
                .map(|s| s.trim().to_string())
                .collect();
            let seeds = args.get_u64("seeds", 1)?;
            let workers = args.get_usize("workers", 1)?;
            let scale = args.get_f64("scale", 0.02)?;
            if cmd == "table3" {
                experiments::quality::table3(&benchmarks, scale, seeds, workers)?;
            } else {
                experiments::quality::table4(&benchmarks, scale, seeds, workers)?;
            }
        }
        "table5" => experiments::sched::table5(scale, args.get_usize("workers", 5)?)?,
        "fig2" => experiments::scaling::fig2(scale, args.get_usize("max-p", 6)?)?,
        "fig3" => experiments::scaling::fig3(scale, args.get_usize("big-cohort", 50_000)?)?,
        "fig4a" => experiments::sched::fig4a(scale)?,
        "fig4b" => experiments::sched::fig4b(scale, args.get_usize("workers", 5)?)?,
        "fig5" => experiments::sched::fig5(scale, args.get_usize("workers", 5)?)?,
        "dispatch" => {
            experiments::dispatch::compare(scale, args.get_usize("workers", 4)?)?;
        }
        "scenario" => {
            experiments::scenario::completion_curves(scale, args.get_usize("workers", 4)?)?;
        }
        "fig6" => experiments::privacy_fig::fig6(scale, args.get_u64("seeds", 1)?)?,
        "fig7" | "fig8" => experiments::speed::fig7_fig8(scale)?,
        "calibrate" => experiments::privacy_fig::calibrate()?,
        "nonnn" => experiments::quality::nonnn(scale)?,
        "presets" => {
            if args.flag("dump") {
                println!("{}", pfl::config::dump_presets());
            } else {
                for name in pfl::config::preset_names() {
                    let c = pfl::config::preset(name)?;
                    println!(
                        "{name:<22} model={:<10} T={:<5} C={:<4} dp={}",
                        c.model,
                        c.iterations,
                        c.cohort_size,
                        if c.privacy.is_none() { "no" } else { "central" }
                    );
                }
            }
        }
        "engines" => {
            for e in EngineVariant::all() {
                let p = e.profile();
                println!(
                    "{:<14} realloc={:<5} roundtrip={:<5} coordinator={:<5} user_tax={}us step_tax={}us",
                    e.name(),
                    p.realloc_per_user,
                    p.cpu_roundtrip,
                    p.coordinator,
                    p.per_user_overhead_ns / 1000,
                    p.per_step_overhead_ns / 1000,
                );
            }
        }
        other => bail!("unknown command {other:?}; run `pfl help`"),
    }
    Ok(())
}

/// Resolve `--preset NAME | --config FILE` (+ `--scale`) into a config.
fn cmd_config(args: &Args, what: &str) -> Result<pfl::config::Config> {
    let cfg = if let Some(path) = args.get("config") {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        pfl::config::Config::from_json(&text)?
    } else {
        let name = args
            .get("preset")
            .with_context(|| format!("{what} needs --preset NAME or --config FILE"))?;
        pfl::config::preset(name)?
    };
    Ok(cfg.scaled(args.get_f64("scale", 1.0)?))
}

/// `pfl materialize` — write a dataset to an on-disk sharded store that
/// `pfl run --data-store DIR` reads back out-of-core (bit-identical to
/// the generator; see `rust/src/data/store.rs`).
fn cmd_materialize(args: &Args) -> Result<()> {
    let cfg = cmd_config(args, "materialize")?;
    let out = args.require("out")?;
    let users_per_shard = args.get_usize("users-per-shard", 1024)?;
    let eval_shard = args.get_usize("eval-shard", 256)?;
    // --compression overrides the config's engine.store_compression
    let compression: pfl::data::Compression = match args.get("compression") {
        Some(s) => s.parse()?,
        None => cfg.store_compression()?,
    };
    let dataset = pfl::config::build::build_dataset(&cfg.dataset)?;
    eprintln!(
        "materializing {} ({} users, compression={compression}) -> {out}",
        dataset.name(),
        dataset.num_users()
    );
    let t0 = std::time::Instant::now();
    let stats = pfl::data::materialize_with(
        &*dataset,
        std::path::Path::new(out),
        users_per_shard,
        eval_shard,
        compression,
    )?;
    println!(
        "wrote {} users in {} shards ({:.1} MB raw, {:.1} MB on disk, ratio {:.2}x, \
         {} eval shards) in {:.1}s",
        stats.num_users,
        stats.num_shards,
        stats.data_bytes as f64 / 1e6,
        stats.disk_bytes as f64 / 1e6,
        stats.compression_ratio(),
        stats.eval_shards,
        t0.elapsed().as_secs_f64(),
    );
    // the run must use the same dataset config AND scale the store was
    // materialized from (build_backend validates and rejects mismatches)
    let scale = args.get_f64("scale", 1.0)?;
    let scale_arg = if (scale - 1.0).abs() > 1e-12 {
        format!(" --scale {scale}")
    } else {
        String::new()
    };
    match args.get("preset") {
        Some(p) => println!("run it with: pfl run --preset {p}{scale_arg} --data-store {out}"),
        None => println!("run it with: pfl run --config FILE{scale_arg} --data-store {out}"),
    }
    Ok(())
}

/// `pfl import` — write-through import of a real tabular corpus
/// (JSONL or CSV, rows grouped by user) into a sharded store, streamed
/// through the same [`pfl::data::ShardWriter`] path `materialize` uses.
fn cmd_import(args: &Args) -> Result<()> {
    let input = args.require("in")?;
    let out = args.require("out")?;
    let mut opts = pfl::data::ImportOptions {
        users_per_shard: args.get_usize("users-per-shard", 256)?,
        name: args.get_str("name", "imported").to_string(),
        ..Default::default()
    };
    if let Some(c) = args.get("compression") {
        opts.compression = c.parse()?;
    }
    if let Some(f) = args.get("format") {
        opts.format = Some(f.parse()?);
    }
    let t0 = std::time::Instant::now();
    let stats = pfl::data::import_corpus(
        std::path::Path::new(input),
        std::path::Path::new(out),
        &opts,
    )?;
    println!(
        "imported {} users in {} shards ({:.1} MB raw, {:.1} MB on disk, ratio {:.2}x) \
         in {:.1}s",
        stats.num_users,
        stats.num_shards,
        stats.data_bytes as f64 / 1e6,
        stats.disk_bytes as f64 / 1e6,
        stats.compression_ratio(),
        t0.elapsed().as_secs_f64(),
    );
    println!("run it with: pfl run --config FILE --data-store {out}");
    Ok(())
}

/// `pfl store stat DIR` — summarize a store by reading only the shard
/// headers and `index.bin` (no user payloads are scanned).
fn cmd_store(args: &Args) -> Result<()> {
    let (action, dir) = match args.positional.as_slice() {
        [a, d] => (a.as_str(), d.as_str()),
        _ => bail!("usage: pfl store stat DIR"),
    };
    if action != "stat" {
        bail!("unknown store action {action:?}; usage: pfl store stat DIR");
    }
    let st = pfl::data::stat(std::path::Path::new(dir))?;
    println!("store:        {dir}");
    println!("dataset:      {}", st.name);
    println!("version:      {}", st.version);
    println!("compression:  {}", st.compression);
    if st.block_size > 0 {
        println!("block size:   {} KiB", st.block_size / 1024);
    }
    println!("users:        {}", st.num_users);
    println!("shards:       {}", st.num_shards);
    println!("eval shards:  {}", st.eval_shards);
    println!("raw bytes:    {:.1} MB", st.raw_bytes as f64 / 1e6);
    println!("disk bytes:   {:.1} MB", st.disk_bytes as f64 / 1e6);
    println!("ratio:        {:.2}x", st.compression_ratio());
    Ok(())
}

/// `pfl run` — the config-driven launcher.
fn cmd_run(args: &Args) -> Result<()> {
    let mut cfg = cmd_config(args, "run")?;
    if let Some(w) = args.get("workers") {
        cfg.num_workers = w.parse()?;
    }
    if let Some(a) = args.get("algorithm") {
        cfg.algorithm.kind = a.into();
    }
    if let Some(m) = args.get("mechanism") {
        if cfg.privacy.is_none() {
            cfg.privacy = pfl::config::PrivacyConfig {
                mechanism: m.into(),
                accountant: "pld".into(),
                clip_bound: 0.4,
                epsilon: 2.0,
                delta: 1e-6,
                population_m: 1e6,
                noise_cohort: cfg.cohort_size as f64 * 20.0,
                sparse_top_k: 0,
            };
        } else {
            cfg.privacy.mechanism = m.into();
        }
    }
    if let Some(d) = args.get("dispatch") {
        cfg.dispatcher = d.into();
    }
    cfg.max_staleness = args.get_u64("max-staleness", cfg.max_staleness)?;
    cfg.buffer_frac = args.get_f64("buffer-frac", cfg.buffer_frac)?;
    cfg.reorder_window = args.get_usize("reorder-window", cfg.reorder_window)?;
    cfg.sparse_spill_frac = args.get_f64("sparse-spill-frac", cfg.sparse_spill_frac)?;
    if let Some(d) = args.get("data-store") {
        cfg.data_store = d.into();
    }
    cfg.cache_users = args.get_usize("cache-users", cfg.cache_users)?;
    cfg.prefetch_depth = args.get_usize("prefetch-depth", cfg.prefetch_depth)?;
    if let Some(m) = args.get("store-mmap") {
        cfg.store_mmap = match m {
            "on" | "true" | "1" => true,
            "off" | "false" | "0" => false,
            other => bail!("--store-mmap {other:?}: expected on|off"),
        };
    }
    if let Some(q) = args.get("quantize") {
        cfg.wire_quantization = q.into();
        cfg.wire_quantization_bits()?; // fail fast on unknown widths
    }
    if args.flag("fold-tree") {
        cfg.fold_tree = true;
    }
    cfg.noise_threads = args.get_usize("noise-threads", cfg.noise_threads)?;
    if let Some(sv) = args.get("scenario") {
        cfg.scenario = if sv == "off" {
            None
        } else {
            let spec = pfl::fl::device::ScenarioSpec::parse(sv)
                .map_err(|e| anyhow::anyhow!("--scenario {sv:?}: {e}"))?;
            if spec.enabled() {
                Some(spec)
            } else {
                None
            }
        };
    }
    if let Some(it) = args.get("iterations") {
        cfg.iterations = it.parse()?;
    }
    if let Some(c) = args.get("cohort") {
        cfg.cohort_size = c.parse()?;
    }
    cfg.seed = args.get_u64("seed", cfg.seed)?;
    let log_every = args.get_u64("log", 1)?;

    eprintln!(
        "running {} (T={} C={} workers={})",
        cfg.name, cfg.iterations, cfg.cohort_size, cfg.num_workers
    );

    let mut backend =
        pfl::config::build::build_backend(&cfg, EngineVariant::PflStyle.profile())?;
    // reuse the backend's dataset (for --data-store runs this shares
    // the one opened store instead of parsing the index twice)
    let dataset = backend.dataset();
    let init = pfl::config::build::init_params(&cfg)?;
    let mut callbacks: Vec<Box<dyn Callback>> = Vec::new();
    // the linear model has no HLO graph: its eval runs on-worker through
    // the federation's Val contexts, so there is no central-eval callback
    if cfg.model != "linear" {
        callbacks.push(Box::new(pfl::config::build::build_eval_callback(&cfg, &dataset)?));
    }
    if let Some(path) = args.get("csv") {
        callbacks.push(Box::new(CsvReporter::new(path)));
    }
    if let Some(path) = args.get("jsonl") {
        callbacks.push(Box::new(JsonlReporter::new(path)?));
    }
    let t0 = std::time::Instant::now();
    let outcome = if cfg.dispatch_spec()?.mode == pfl::fl::DispatchMode::Socket {
        run_socket(args, &cfg, &mut backend, init, &mut callbacks)?
    } else {
        backend.run(init, &mut callbacks)?
    };
    let metric = pfl::config::build::headline_metric(&cfg.model);
    if log_every > 0 {
        for (t, m) in &outcome.history {
            if t % log_every == 0 {
                println!("[round {t}] {m}");
            }
        }
    }
    if !cfg.data_store.is_empty() {
        let c = &outcome.counters;
        let total = c.cache_hits + c.cache_misses;
        if total > 0 {
            eprintln!(
                "data store: {:.1}% cache hits over {} fetches, {:.1} ms stalled on reads",
                100.0 * c.cache_hits as f64 / total as f64,
                total,
                c.prefetch_stall_nanos as f64 / 1e6,
            );
            eprintln!(
                "            {:.1} MB read, {:.1} ms decoding on workers, \
                 stalls {:.1} ms mmap / {:.1} ms pread",
                c.store_bytes_read as f64 / 1e6,
                c.decode_nanos as f64 / 1e6,
                c.mmap_stall_nanos as f64 / 1e6,
                c.pread_stall_nanos as f64 / 1e6,
            );
        }
    }
    println!(
        "done: {} rounds in {:.1}s; final {metric} = {}",
        outcome.rounds,
        t0.elapsed().as_secs_f64(),
        outcome
            .final_metric(&format!("centraleval/{metric}"))
            .map(|v| format!("{v:.4}"))
            .unwrap_or_else(|| "n/a".into()),
    );
    Ok(())
}

/// Socket-dispatch arm of `pfl run`: bind the listener, optionally spawn
/// `cfg.num_workers` local `pfl worker` child processes, admit them into a
/// [`pfl::comms::SocketPool`], and drive the distributed round loop.
fn run_socket(
    args: &Args,
    cfg: &pfl::config::Config,
    backend: &mut pfl::fl::SimulatedBackend,
    init: Vec<f32>,
    callbacks: &mut [Box<dyn Callback>],
) -> Result<pfl::fl::RunOutcome> {
    let listen = args.get_str("listen", "127.0.0.1:0");
    let server = pfl::comms::SocketServer::bind(listen)?;
    let addr = server.local_addr().to_string();
    eprintln!(
        "listening on {addr}; waiting for {} worker(s) — start each with \
         `pfl worker --connect {addr}`",
        cfg.num_workers
    );
    let mut children = Vec::new();
    if args.flag("spawn-workers") {
        let exe = std::env::current_exe().context("locating the pfl binary")?;
        for _ in 0..cfg.num_workers {
            children.push(
                std::process::Command::new(&exe)
                    .args(["worker", "--connect", &addr])
                    .spawn()
                    .context("spawning `pfl worker`")?,
            );
        }
    }
    let spec = pfl::comms::SetupSpec {
        use_hlo_clip: false, // build_backend leaves ClipBackend at Rust
        heartbeat_ms: args.get_u64("heartbeat-ms", 500)?,
        config_json: cfg.to_json(),
    };
    let pool = server.into_pool(cfg.num_workers, spec)?;
    let outcome = backend.run_distributed(init, callbacks, pool);
    for mut c in children {
        let _ = c.wait();
    }
    outcome
}

/// `pfl worker --connect ADDR` — process entry point for a socket-fed
/// worker. The handshake delivers the run's full config JSON, so the
/// worker rebuilds the identical dataset/algorithm/model stack locally and
/// then trains whichever users the server streams to it.
fn cmd_worker(args: &Args) -> Result<()> {
    let addr = args.require("connect")?;
    let conn = pfl::comms::WorkerConn::connect(addr)
        .with_context(|| format!("connecting to pfl server at {addr}"))?;
    let cfg = pfl::config::Config::from_json(&conn.setup.config_json)?;
    let shared = pfl::config::build::build_worker_shared(&cfg, conn.setup.use_hlo_clip)?;
    pfl::fl::run_socket_worker(conn, std::sync::Arc::new(shared))
}
