//! Privacy accountants (paper App. A + B.5): compute the total privacy
//! loss (ε, δ) of T compositions of the Poisson-subsampled Gaussian
//! mechanism, and calibrate the noise multiplier σ for a target budget.
//!
//! Three accountants, as in pfl-research:
//! * [`RdpAccountant`] — Rényi DP of the subsampled Gaussian (Mironov
//!   [64], Zhu–Wang [94]) with the standard RDP→(ε,δ) conversion. Simple
//!   and robust, but a looser bound.
//! * [`PldAccountant`] — discretized privacy-loss distribution ([22, 62]):
//!   the PLD of one step is convolved T times (exponentiation by
//!   squaring), and δ(ε) is read off the composed distribution. Tighter
//!   than RDP.
//! * [`PrvAccountant`] — the PRV accountant of [32] shares the PLD's
//!   convolution machinery; here it is the same pipeline on a 4× finer
//!   grid with wider support, which is how it tightens the ε estimate in
//!   practice. (Documented substitution: we do not reimplement [32]'s
//!   error analysis.)
//!
//! All accountants assume Poisson sampling with rate q = C̃/M and
//! add/remove adjacency (paper App. A).

use anyhow::{bail, Result};

/// Common accounting parameters (paper Table 7: M = 1e6, ε = 2, δ = 1/M).
#[derive(Debug, Clone, Copy)]
pub struct AccountantParams {
    /// Poisson sampling rate q = cohort_size / population.
    pub sampling_rate: f64,
    /// Target δ.
    pub delta: f64,
    /// Number of composition steps (central iterations).
    pub steps: u64,
}

/// A privacy accountant for the subsampled Gaussian mechanism.
pub trait Accountant: Send + Sync {
    /// ε spent after `p.steps` steps with noise multiplier σ (noise std =
    /// σ × clip bound on the *sum*), at δ = p.delta.
    fn epsilon(&self, sigma: f64, p: &AccountantParams) -> f64;

    fn name(&self) -> &'static str;

    /// Smallest σ achieving ε ≤ `target_epsilon` (bisection; the paper's
    /// workflow: fix (ε, δ, T, q), derive σ).
    fn calibrate_sigma(&self, target_epsilon: f64, p: &AccountantParams) -> Result<f64> {
        if target_epsilon <= 0.0 {
            bail!("target epsilon must be positive");
        }
        let mut lo = 0.05;
        let mut hi = 1.0;
        // grow hi until it satisfies the budget
        while self.epsilon(hi, p) > target_epsilon {
            hi *= 2.0;
            if hi > 1e4 {
                bail!("calibration diverged: eps({hi}) still above target");
            }
        }
        while self.epsilon(lo, p) < target_epsilon && lo > 1e-6 {
            lo /= 2.0;
        }
        for _ in 0..60 {
            let mid = 0.5 * (lo + hi);
            if self.epsilon(mid, p) > target_epsilon {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        Ok(hi)
    }
}

// ---------------------------------------------------------------------
// RDP
// ---------------------------------------------------------------------

/// Rényi-DP accountant for the Poisson-subsampled Gaussian.
#[derive(Debug, Default)]
pub struct RdpAccountant;

/// log(a + b) given log a, log b.
fn log_add(la: f64, lb: f64) -> f64 {
    let (hi, lo) = if la > lb { (la, lb) } else { (lb, la) };
    if hi == f64::NEG_INFINITY {
        return f64::NEG_INFINITY;
    }
    hi + (lo - hi).exp().ln_1p()
}

/// log C(n, k)
fn log_binom(n: u64, k: u64) -> f64 {
    lgamma((n + 1) as f64) - lgamma((k + 1) as f64) - lgamma((n - k + 1) as f64)
}

/// Lanczos log-gamma (no libm lgamma in core; matches to ~1e-13).
fn lgamma(x: f64) -> f64 {
    // Lanczos approximation, g = 7, n = 9
    const G: f64 = 7.0;
    const C: [f64; 9] = [
        0.99999999999980993,
        676.5203681218851,
        -1259.1392167224028,
        771.32342877765313,
        -176.61502916214059,
        12.507343278686905,
        -0.13857109526572012,
        9.9843695780195716e-6,
        1.5056327351493116e-7,
    ];
    if x < 0.5 {
        // reflection
        std::f64::consts::PI.ln() - (std::f64::consts::PI * x).sin().abs().ln() - lgamma(1.0 - x)
    } else {
        let x = x - 1.0;
        let mut a = C[0];
        let t = x + G + 0.5;
        for (i, &c) in C.iter().enumerate().skip(1) {
            a += c / (x + i as f64);
        }
        0.5 * (2.0 * std::f64::consts::PI).ln() + (x + 0.5) * t.ln() - t + a.ln()
    }
}

impl RdpAccountant {
    /// RDP of one subsampled-Gaussian step at integer order α
    /// (Mironov et al.'s binomial expansion, the standard upper bound):
    ///
    /// ε(α) = 1/(α−1) · log Σ_{k=0}^{α} C(α,k)(1−q)^{α−k} q^k e^{k(k−1)/(2σ²)}
    pub fn rdp_step(q: f64, sigma: f64, alpha: u64) -> f64 {
        if q == 0.0 {
            return 0.0;
        }
        if q >= 1.0 {
            // plain Gaussian: ε(α) = α / (2σ²)
            return alpha as f64 / (2.0 * sigma * sigma);
        }
        let mut log_sum = f64::NEG_INFINITY;
        for k in 0..=alpha {
            let term = log_binom(alpha, k)
                + (alpha - k) as f64 * (1.0 - q).ln()
                + k as f64 * q.ln()
                + (k * (k.saturating_sub(1))) as f64 / (2.0 * sigma * sigma);
            log_sum = log_add(log_sum, term);
        }
        (log_sum / (alpha as f64 - 1.0)).max(0.0)
    }
}

/// Orders scanned for the RDP→DP conversion.
const ORDERS: &[u64] = &[
    2, 3, 4, 5, 6, 8, 10, 12, 16, 20, 24, 32, 48, 64, 96, 128, 192, 256, 512,
];

impl Accountant for RdpAccountant {
    fn epsilon(&self, sigma: f64, p: &AccountantParams) -> f64 {
        let mut best = f64::INFINITY;
        for &alpha in ORDERS {
            let rdp = Self::rdp_step(p.sampling_rate, sigma, alpha) * p.steps as f64;
            // standard conversion (Mironov Prop. 3)
            let eps = rdp + (1.0 / p.delta).ln() / (alpha as f64 - 1.0);
            best = best.min(eps);
        }
        best
    }

    fn name(&self) -> &'static str {
        "rdp"
    }
}

// ---------------------------------------------------------------------
// PLD
// ---------------------------------------------------------------------

/// Discretized privacy-loss-distribution accountant.
///
/// Losses are binned to the *nearest* grid point (T-fold composition on
/// a pessimistic ceil grid would inflate ε by T·grid, which dominates at
/// the benchmark's thousands of rounds); with grid h the residual
/// discretization error is O(h·√T) ≈ 0.02 at h = 2e-4, T = 5000 —
/// recorded as a known approximation in DESIGN.md §3. Self-composition uses
/// exponentiation by squaring with FFT convolutions (`util::fft`).
pub struct PldAccountant {
    /// Discretization step of the privacy-loss grid.
    pub grid: f64,
    /// Support half-width of the single-step PLD in privacy-loss units.
    pub half_width: f64,
}

impl Default for PldAccountant {
    fn default() -> Self {
        PldAccountant { grid: 2e-4, half_width: 30.0 }
    }
}

/// A discretized PLD: pmf over losses `min + i*grid`, plus the mass that
/// escapes to +infinity (treated as a pure δ contribution).
#[derive(Debug, Clone)]
struct Pld {
    min: f64,
    grid: f64,
    pmf: Vec<f64>,
    inf_mass: f64,
}

impl Pld {
    /// PLD of one Poisson-subsampled Gaussian step (add/remove adjacency,
    /// "remove" direction which dominates):
    ///   P = (1−q)·N(0,σ²) + q·N(1,σ²),  Q = N(0,σ²)
    ///   ℓ(t) = log(1−q + q·e^{(2t−1)/(2σ²)}),  t ~ P.
    fn subsampled_gaussian(q: f64, sigma: f64, grid: f64, half_width: f64) -> Pld {
        // integrate t over a wide grid; map mass into loss bins with
        // pessimistic (round-up) placement for a valid upper bound.
        let t_lo = -12.0 * sigma;
        let t_hi = 12.0 * sigma + 1.0;
        let steps = 60_000usize;
        let dt = (t_hi - t_lo) / steps as f64;

        let loss_min = -half_width;
        let n_bins = (2.0 * half_width / grid).ceil() as usize + 1;
        let mut pmf = vec![0.0; n_bins];
        let mut inf_mass = 0.0;

        let inv2s2 = 1.0 / (2.0 * sigma * sigma);
        let norm = 1.0 / (sigma * (2.0 * std::f64::consts::PI).sqrt());
        for i in 0..steps {
            let t = t_lo + (i as f64 + 0.5) * dt;
            // density of P at t
            let p0 = norm * (-t * t * inv2s2).exp();
            let p1 = norm * (-(t - 1.0) * (t - 1.0) * inv2s2).exp();
            let p = (1.0 - q) * p0 + q * p1;
            if p <= 0.0 {
                continue;
            }
            let ratio = 1.0 - q + q * ((2.0 * t - 1.0) * inv2s2).exp();
            let loss = ratio.ln();
            let mass = p * dt;
            if loss >= half_width {
                inf_mass += mass;
            } else {
                // nearest rounding (see type docs for the error budget)
                let bin = ((loss - loss_min) / grid).round().clamp(0.0, (n_bins - 1) as f64);
                pmf[bin as usize] += mass;
            }
        }
        // normalize tiny integration error into the top bin (pessimistic)
        let total: f64 = pmf.iter().sum::<f64>() + inf_mass;
        if total < 1.0 {
            inf_mass += 1.0 - total;
        }
        let mut out = Pld { min: loss_min, grid, pmf, inf_mass };
        out.trim_tails(1e-15);
        out
    }

    /// Convolution (independent composition: losses add), FFT-backed.
    fn compose(&self, other: &Pld) -> Pld {
        let pmf = crate::util::fft::convolve(&self.pmf, &other.pmf);
        let inf_mass = self.inf_mass + other.inf_mass
            - self.inf_mass * other.inf_mass;
        let mut out = Pld {
            min: self.min + other.min,
            grid: self.grid,
            pmf,
            inf_mass,
        };
        out.trim_tails(1e-14);
        out
    }

    /// Bound the support by trimming negligible tail mass, pessimistically:
    /// low-tail mass folds into the lowest kept bin (raising its loss),
    /// high-tail mass moves to `inf_mass` (counted fully in δ). With a
    /// per-trim budget of 1e-14 and ≲64 trims the added δ is ≪ 1e-6.
    fn trim_tails(&mut self, tail_mass: f64) {
        // high end -> inf_mass
        let mut cum = 0.0;
        let mut hi_cut = self.pmf.len();
        while hi_cut > 1 && cum + self.pmf[hi_cut - 1] <= tail_mass {
            cum += self.pmf[hi_cut - 1];
            hi_cut -= 1;
        }
        self.inf_mass += cum;
        self.pmf.truncate(hi_cut);
        // low end -> fold into lowest kept bin
        let mut cum = 0.0;
        let mut lo_cut = 0usize;
        while lo_cut + 1 < self.pmf.len() && cum + self.pmf[lo_cut] <= tail_mass {
            cum += self.pmf[lo_cut];
            lo_cut += 1;
        }
        if lo_cut > 0 {
            self.pmf.drain(0..lo_cut);
            self.pmf[0] += cum;
            self.min += lo_cut as f64 * self.grid;
        }
    }

    /// T-fold self-composition by exponentiation by squaring.
    fn self_compose(&self, t: u64) -> Pld {
        assert!(t >= 1);
        let mut result: Option<Pld> = None;
        let mut base = self.clone();
        let mut k = t;
        loop {
            if k & 1 == 1 {
                result = Some(match result {
                    None => base.clone(),
                    Some(r) => r.compose(&base),
                });
            }
            k >>= 1;
            if k == 0 {
                break;
            }
            base = base.compose(&base);
        }
        result.unwrap()
    }

    /// δ(ε) = inf_mass + Σ_{ℓ > ε} p(ℓ)·(1 − e^{ε−ℓ})
    fn delta_at(&self, eps: f64) -> f64 {
        let mut delta = self.inf_mass;
        for (i, &p) in self.pmf.iter().enumerate() {
            let loss = self.min + i as f64 * self.grid;
            if loss > eps {
                delta += p * (1.0 - (eps - loss).exp());
            }
        }
        delta.clamp(0.0, 1.0)
    }

    /// Smallest ε with δ(ε) ≤ target (bisection; δ(ε) is decreasing).
    fn epsilon_at(&self, target_delta: f64) -> f64 {
        let mut lo = 0.0;
        let mut hi = 400.0;
        if self.delta_at(lo) <= target_delta {
            return 0.0;
        }
        if self.delta_at(hi) > target_delta {
            return f64::INFINITY;
        }
        for _ in 0..80 {
            let mid = 0.5 * (lo + hi);
            if self.delta_at(mid) > target_delta {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        hi
    }
}

impl Accountant for PldAccountant {
    fn epsilon(&self, sigma: f64, p: &AccountantParams) -> f64 {
        let step = Pld::subsampled_gaussian(p.sampling_rate, sigma, self.grid, self.half_width);
        let composed = step.self_compose(p.steps.max(1));
        composed.epsilon_at(p.delta)
    }

    fn name(&self) -> &'static str {
        "pld"
    }
}

// ---------------------------------------------------------------------
// PRV (PLD-backed, finer grid)
// ---------------------------------------------------------------------

/// PRV-style accountant: the PLD pipeline on a finer grid with wider
/// support (see module docs for the substitution note).
pub struct PrvAccountant {
    inner: PldAccountant,
}

impl Default for PrvAccountant {
    fn default() -> Self {
        PrvAccountant { inner: PldAccountant { grid: 1e-4, half_width: 40.0 } }
    }
}

impl Accountant for PrvAccountant {
    fn epsilon(&self, sigma: f64, p: &AccountantParams) -> f64 {
        self.inner.epsilon(sigma, p)
    }

    fn name(&self) -> &'static str {
        "prv"
    }
}

/// Look up an accountant by config name.
pub fn accountant_by_name(name: &str) -> Result<Box<dyn Accountant>> {
    Ok(match name {
        "rdp" => Box::new(RdpAccountant),
        "pld" => Box::new(PldAccountant::default()),
        "prv" => Box::new(PrvAccountant::default()),
        other => bail!("unknown accountant {other:?} (rdp|pld|prv)"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params(q: f64, steps: u64) -> AccountantParams {
        AccountantParams { sampling_rate: q, delta: 1e-6, steps }
    }

    #[test]
    fn lgamma_matches_factorials() {
        for n in 1..10u64 {
            let f: f64 = (1..=n).product::<u64>() as f64;
            assert!((lgamma((n + 1) as f64) - f.ln()).abs() < 1e-9, "n={n}");
        }
    }

    #[test]
    fn log_binom_small_cases() {
        assert!((log_binom(5, 2) - 10f64.ln()).abs() < 1e-9);
        assert!((log_binom(10, 0) - 0.0).abs() < 1e-9);
    }

    #[test]
    fn rdp_unsubsampled_gaussian_matches_closed_form() {
        // q = 1: ε(α) = α/(2σ²)
        let e = RdpAccountant::rdp_step(1.0, 2.0, 8);
        assert!((e - 1.0).abs() < 1e-12);
    }

    #[test]
    fn rdp_epsilon_monotone_in_sigma_and_steps() {
        let acc = RdpAccountant;
        let p = params(0.01, 100);
        assert!(acc.epsilon(1.0, &p) > acc.epsilon(2.0, &p));
        let p2 = params(0.01, 1000);
        assert!(acc.epsilon(1.0, &p2) > acc.epsilon(1.0, &p));
    }

    #[test]
    fn subsampling_amplifies() {
        // smaller q -> smaller epsilon at equal sigma
        let acc = RdpAccountant;
        assert!(acc.epsilon(1.0, &params(0.001, 100)) < acc.epsilon(1.0, &params(0.1, 100)));
    }

    #[test]
    fn pld_single_step_plain_gaussian_sane() {
        // q=1, σ=1, δ=1e-5: analytic Gaussian DP gives ε ≈ 4.2–4.9
        let acc = PldAccountant::default();
        let p = AccountantParams { sampling_rate: 1.0, delta: 1e-5, steps: 1 };
        let e = acc.epsilon(1.0, &p);
        assert!(e > 3.5 && e < 5.5, "eps = {e}");
    }

    #[test]
    fn pld_tighter_than_rdp() {
        let p = params(0.005, 200);
        let rdp = RdpAccountant.epsilon(1.0, &p);
        let pld = PldAccountant::default().epsilon(1.0, &p);
        assert!(
            pld <= rdp * 1.05,
            "pld {pld} should not be much looser than rdp {rdp}"
        );
    }

    #[test]
    fn calibration_hits_target() {
        let acc = RdpAccountant;
        let p = params(0.0005, 1500); // the CIFAR10 DP benchmark shape
        let sigma = acc.calibrate_sigma(2.0, &p).unwrap();
        let eps = acc.epsilon(sigma, &p);
        assert!(eps <= 2.0 && eps > 1.8, "eps({sigma}) = {eps}");
    }

    #[test]
    fn pld_composition_grows_epsilon() {
        let acc = PldAccountant { grid: 5e-4, half_width: 20.0 };
        let e1 = acc.epsilon(1.0, &params(0.01, 10));
        let e2 = acc.epsilon(1.0, &params(0.01, 100));
        assert!(e2 > e1);
    }

    #[test]
    fn accountant_lookup() {
        assert!(accountant_by_name("rdp").is_ok());
        assert!(accountant_by_name("pld").is_ok());
        assert!(accountant_by_name("prv").is_ok());
        assert!(accountant_by_name("nope").is_err());
    }
}
