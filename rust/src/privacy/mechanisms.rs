//! DP mechanisms (paper App. B.5). All implement
//! [`Postprocessor`](crate::fl::postprocess::Postprocessor); per-user
//! clipping runs through the side's [`ClipKernel`] (the L1 Pallas
//! artifact on workers) and noise is added to the aggregate in place,
//! once per central iteration.
//!
//! All vector math routes through [`crate::tensor::ops`] — no mechanism
//! carries its own scalar loops — and sparse updates clip exactly on
//! their nonzeros, densifying only where additive noise requires full
//! coordinate coverage.
//!
//! Shared mechanism state (adaptive bounds, noise rings, participation
//! maps) is locked poison-tolerantly (`unwrap_or_else
//! (PoisonError::into_inner)`): the state is plain data, so a worker
//! that panics mid-round must not wedge the mechanism for the rest of
//! the simulation.

use std::sync::{Mutex, PoisonError};
use std::time::Instant;

use anyhow::Result;

use crate::fl::context::CentralContext;
use crate::fl::metrics::Metrics;
use crate::fl::postprocess::{clip_value, Postprocessor, PpEnv};
use crate::fl::stats::{Statistics, UPDATE};
use crate::tensor::ops;
use crate::util::rng::CtrRng;

// Per-mechanism stream ids for the counter noise engine: mechanisms
// sharing one round key draw decorrelated streams, so stacking (e.g.
// adaptive clip's count noise next to its update noise) can never reuse
// samples.
const STREAM_GAUSS: u64 = 1;
const STREAM_LAPLACE: u64 = 2;
const STREAM_ADAPT_UPDATE: u64 = 3;
const STREAM_ADAPT_COUNT: u64 = 4;
const STREAM_BMF: u64 = 5;
const STREAM_CLT: u64 = 6;
const STREAM_LOCAL: u64 = 7;

/// Add N(0, std²) per coordinate through the engine selected by
/// `env.noise_threads`: 0 routes through the legacy sequential `env.rng`
/// stream (byte-identical to pre-engine runs), N ≥ 1 through the
/// counter kernels keyed by `(noise_key, round, stream)` — bit-identical
/// output for every N. Returns the noise L2 norm and accrues the wall
/// time into `env.noise_nanos` (drained to `sys/noise-nanos`).
fn gaussian_noise(
    env: &mut PpEnv,
    update: &mut [f32],
    std: f64,
    stream: u64,
    round: u64,
) -> f64 {
    let t0 = Instant::now();
    let norm = if env.noise_threads == 0 {
        ops::add_gaussian_noise(update, std, env.rng)
    } else {
        let rng = env.ctr(stream, round);
        ops::add_gaussian_noise_par(update, std, &rng, env.noise_threads)
    };
    env.noise_nanos += t0.elapsed().as_nanos() as u64;
    norm
}

/// Laplace(0, scale) counterpart of [`gaussian_noise`].
fn laplace_noise(
    env: &mut PpEnv,
    update: &mut [f32],
    scale: f64,
    stream: u64,
    round: u64,
) -> f64 {
    let t0 = Instant::now();
    let norm = if env.noise_threads == 0 {
        ops::add_laplace_noise(update, scale, env.rng)
    } else {
        let rng = env.ctr(stream, round);
        ops::add_laplace_noise_ctr(update, scale, &rng, env.noise_threads)
    };
    env.noise_nanos += t0.elapsed().as_nanos() as u64;
    norm
}

/// No-op mechanism (the "no DP" arm of every benchmark).
pub struct NoPrivacy;

impl Postprocessor for NoPrivacy {
    fn name(&self) -> &'static str {
        "no-dp"
    }
}

/// Shared noise bookkeeping: noise std on the *sum* of clipped updates is
/// `noise_multiplier × clip_bound × r`, with r = C/C̃ the noise-cohort
/// rescaling factor (paper App. C.4; r = 1 means no rescaling).
#[derive(Debug, Clone, Copy)]
pub struct NoiseParams {
    pub clip_bound: f32,
    pub noise_multiplier: f64,
    /// r = C/C̃ (simulated cohort / noise cohort).
    pub rescale_r: f64,
}

impl NoiseParams {
    pub fn noise_std(&self) -> f64 {
        self.noise_multiplier * self.clip_bound as f64 * self.rescale_r
    }
}

/// Central Gaussian mechanism [24]: clip each user's update to
/// `clip_bound`, add N(0, σ²) per coordinate to the aggregate.
pub struct GaussianMechanism {
    pub p: NoiseParams,
}

impl GaussianMechanism {
    pub fn new(clip_bound: f32, noise_multiplier: f64, rescale_r: f64) -> Self {
        GaussianMechanism {
            p: NoiseParams { clip_bound, noise_multiplier, rescale_r },
        }
    }
}

/// Signal-to-noise ratio as defined in paper Eq. (1):
/// SNR = ‖Δ‖₂ / sqrt(d·σ²).
pub fn snr(update_norm: f64, dim: usize, noise_std: f64) -> f64 {
    if noise_std <= 0.0 || dim == 0 {
        return f64::INFINITY;
    }
    update_norm / ((dim as f64).sqrt() * noise_std)
}

impl Postprocessor for GaussianMechanism {
    fn name(&self) -> &'static str {
        "gaussian"
    }

    fn postprocess_one_user(
        &self,
        stats: &mut Statistics,
        _ctx: &CentralContext,
        env: &mut PpEnv,
    ) -> Result<Metrics> {
        let mut m = Metrics::new();
        if let Some(update) = stats.vecs.get_mut(UPDATE) {
            let norm = clip_value(env, update, self.p.clip_bound)?;
            m.add_central("dp/pre-clip-norm", norm, 1.0);
            m.add_central(
                "dp/clipped-frac",
                (norm > self.p.clip_bound as f64) as u8 as f64,
                1.0,
            );
        }
        Ok(m)
    }

    fn postprocess_server(
        &self,
        stats: &mut Statistics,
        ctx: &CentralContext,
        env: &mut PpEnv,
    ) -> Result<Metrics> {
        let mut m = Metrics::new();
        // additive noise must cover every coordinate, so a sparse
        // aggregate densifies here (the DP release is dense by design)
        if let Some(update) = stats.dense_mut(UPDATE) {
            let signal = ops::l2_norm(update);
            let std = self.p.noise_std();
            gaussian_noise(env, update, std, STREAM_GAUSS, ctx.iteration);
            m.add_central("dp/noise-std", std, 1.0);
            m.add_central("dp/snr", snr(signal, update.len(), std), 1.0);
        }
        Ok(m)
    }
}

/// Central Laplace mechanism [24]: L1 clipping + Laplace(b) noise, with
/// b = clip_bound × noise_multiplier × r (ε-DP per step with
/// ε = 1/noise_multiplier under L1 sensitivity clip_bound).
pub struct LaplaceMechanism {
    pub p: NoiseParams,
}

impl LaplaceMechanism {
    pub fn new(clip_bound: f32, noise_multiplier: f64, rescale_r: f64) -> Self {
        LaplaceMechanism {
            p: NoiseParams { clip_bound, noise_multiplier, rescale_r },
        }
    }
}

impl Postprocessor for LaplaceMechanism {
    fn name(&self) -> &'static str {
        "laplace"
    }

    fn postprocess_one_user(
        &self,
        stats: &mut Statistics,
        _ctx: &CentralContext,
        _env: &mut PpEnv,
    ) -> Result<Metrics> {
        let mut m = Metrics::new();
        if let Some(update) = stats.vecs.get_mut(UPDATE) {
            // exact for sparse too: absent coordinates contribute 0 to L1
            let norm = ops::l1_clip(update.values_mut(), self.p.clip_bound);
            m.add_central("dp/pre-clip-l1", norm, 1.0);
        }
        Ok(m)
    }

    fn postprocess_server(
        &self,
        stats: &mut Statistics,
        ctx: &CentralContext,
        env: &mut PpEnv,
    ) -> Result<Metrics> {
        let mut m = Metrics::new();
        if let Some(update) = stats.dense_mut(UPDATE) {
            let b = self.p.noise_std();
            laplace_noise(env, update, b, STREAM_LAPLACE, ctx.iteration);
            m.add_central("dp/laplace-scale", b, 1.0);
        }
        Ok(m)
    }
}

/// Gaussian mechanism with adaptive clipping (Andrew et al. [5]): the
/// clip bound tracks the γ-quantile of user update norms by geometric
/// updates on the privately-estimated clipped fraction.
pub struct AdaptiveClipGaussian {
    pub noise_multiplier: f64,
    pub rescale_r: f64,
    /// Target quantile γ (0.5 in [5]).
    pub quantile: f64,
    /// Learning rate of the geometric bound update.
    pub eta: f64,
    /// Noise std for the clipped-count estimate (σ_b in [5]).
    pub count_noise_std: f64,
    state: Mutex<AdaptiveState>,
}

#[derive(Debug)]
struct AdaptiveState {
    bound: f64,
}

/// Key under which the per-user "was clipped" indicator travels.
pub const CLIP_INDICATOR: &str = "clip_indicator";

impl AdaptiveClipGaussian {
    pub fn new(initial_bound: f64, noise_multiplier: f64, rescale_r: f64) -> Self {
        AdaptiveClipGaussian {
            noise_multiplier,
            rescale_r,
            quantile: 0.5,
            eta: 0.2,
            count_noise_std: 1.0,
            state: Mutex::new(AdaptiveState { bound: initial_bound }),
        }
    }

    pub fn current_bound(&self) -> f64 {
        self.state.lock().unwrap_or_else(PoisonError::into_inner).bound
    }
}

impl Postprocessor for AdaptiveClipGaussian {
    fn name(&self) -> &'static str {
        "adaptive-clip-gaussian"
    }

    fn postprocess_one_user(
        &self,
        stats: &mut Statistics,
        _ctx: &CentralContext,
        env: &mut PpEnv,
    ) -> Result<Metrics> {
        let mut m = Metrics::new();
        let bound = self.current_bound() as f32;
        if let Some(update) = stats.vecs.get_mut(UPDATE) {
            let norm = clip_value(env, update, bound)?;
            let clipped = (norm > bound as f64) as u8 as f64;
            // the indicator is itself aggregated (and noised server-side)
            stats.insert(CLIP_INDICATOR, vec![clipped as f32]);
            m.add_central("dp/pre-clip-norm", norm, 1.0);
        }
        Ok(m)
    }

    fn postprocess_server(
        &self,
        stats: &mut Statistics,
        ctx: &CentralContext,
        env: &mut PpEnv,
    ) -> Result<Metrics> {
        let mut m = Metrics::new();
        let cohort = stats.weight.max(1.0);
        let mut st = self.state.lock().unwrap_or_else(PoisonError::into_inner);
        // privately estimate the clipped fraction and adapt the bound:
        // C ← C · exp(−η (b̂ − γ))
        if let Some(ind) = stats.vecs.get_mut(CLIP_INDICATOR) {
            // the scalar count draw goes through the same engine switch
            // as the vector noise (counter 0 of its own stream), so a
            // counter run never consumes the legacy sequential stream
            let count_noise = if env.noise_threads == 0 {
                env.rng.normal()
            } else {
                env.ctr(STREAM_ADAPT_COUNT, ctx.iteration).normal_at(0)
            };
            let noisy = ind.values()[0] as f64 + count_noise * self.count_noise_std;
            let frac = (noisy / cohort).clamp(0.0, 1.0);
            st.bound *= (-self.eta * (frac - self.quantile)).exp();
            m.add_central("dp/clipped-frac-est", frac, 1.0);
            // the indicator is bookkeeping, not part of the model update
            stats.vecs.remove(CLIP_INDICATOR);
        }
        if let Some(update) = stats.dense_mut(UPDATE) {
            let std = self.noise_multiplier * st.bound * self.rescale_r;
            let signal = ops::l2_norm(update);
            gaussian_noise(env, update, std, STREAM_ADAPT_UPDATE, ctx.iteration);
            m.add_central("dp/noise-std", std, 1.0);
            m.add_central("dp/snr", snr(signal, update.len(), std), 1.0);
        }
        m.add_central("dp/clip-bound", st.bound, 1.0);
        Ok(m)
    }
}

/// Banded matrix-factorization mechanism (Choquette-Choo et al. [20];
/// DP-FTRL when applied to FL). Noise added at step t is the correlated
/// combination Σ_{k<b} c_k·z_{t−k} with iid Gaussian buffers z and the
/// first b coefficients of (1−x)^{−1/2} — the optimal Toeplitz factor for
/// prefix-sum release, truncated to band b. Sensitivity under
/// min-separation ≥ b participation is the column norm ‖c‖₂, by which the
/// noise is normalized so the *privacy* noise multiplier matches the
/// Gaussian mechanism's while the *error* on learning trajectories is
/// lower (the Table 4 StackOverflow effect).
pub struct BandedMatrixFactorization {
    pub p: NoiseParams,
    pub band: usize,
    /// Minimum central iterations between two participations of one user
    /// (paper App. C.4 sets 48). Enforced via a participation filter.
    pub min_sep: u64,
    coeffs: Vec<f64>,
    state: Mutex<BmfState>,
}

#[derive(Default)]
struct BmfState {
    /// Ring buffer of the last `band` noise vectors z_{t−k}. Only the
    /// legacy sequential path (`noise_threads == 0`) retains it; the
    /// counter engine regenerates every z from `(noise_key, round)` and
    /// keeps this empty.
    ring: Vec<Vec<f32>>,
    next: usize,
    /// Last participation iteration per user (min-separation filter).
    last_seen: std::collections::HashMap<usize, u64>,
}

impl BandedMatrixFactorization {
    pub fn new(clip_bound: f32, noise_multiplier: f64, rescale_r: f64, band: usize) -> Self {
        // coefficients of (1−x)^{−1/2}: c_0 = 1, c_k = c_{k−1}·(2k−1)/(2k)
        let mut coeffs = vec![1.0f64];
        for k in 1..band.max(1) {
            let prev = coeffs[k - 1];
            coeffs.push(prev * (2.0 * k as f64 - 1.0) / (2.0 * k as f64));
        }
        BandedMatrixFactorization {
            p: NoiseParams { clip_bound, noise_multiplier, rescale_r },
            band: band.max(1),
            min_sep: 48,
            coeffs,
            state: Mutex::new(BmfState::default()),
        }
    }

    /// Column norm of the banded factor (the per-user sensitivity).
    pub fn column_norm(&self) -> f64 {
        self.coeffs.iter().map(|c| c * c).sum::<f64>().sqrt()
    }
}

impl Postprocessor for BandedMatrixFactorization {
    fn name(&self) -> &'static str {
        "banded-mf"
    }

    fn postprocess_one_user(
        &self,
        stats: &mut Statistics,
        _ctx: &CentralContext,
        env: &mut PpEnv,
    ) -> Result<Metrics> {
        let mut m = Metrics::new();
        if let Some(update) = stats.vecs.get_mut(UPDATE) {
            let norm = clip_value(env, update, self.p.clip_bound)?;
            m.add_central("dp/pre-clip-norm", norm, 1.0);
        }
        Ok(m)
    }

    fn postprocess_server(
        &self,
        stats: &mut Statistics,
        ctx: &CentralContext,
        env: &mut PpEnv,
    ) -> Result<Metrics> {
        let mut m = Metrics::new();
        if let Some(update) = stats.dense_mut(UPDATE) {
            let n = update.len();
            let std = self.p.noise_std() / self.column_norm();
            let signal = ops::l2_norm(update);
            let t0 = Instant::now();
            if env.noise_threads == 0 {
                // legacy retained-ring path (byte-identical to pre-engine
                // runs): store the last `band` z vectors, mix by axpy
                let mut st = self.state.lock().unwrap_or_else(PoisonError::into_inner);
                if st.ring.len() != self.band || st.ring.first().map(|v| v.len()) != Some(n) {
                    st.ring = (0..self.band).map(|_| vec![0.0f32; n]).collect();
                    st.next = 0;
                }
                // fresh z_t
                {
                    let next = st.next;
                    let z = &mut st.ring[next];
                    env.rng.fill_normal_f32(z, std);
                }
                // noise_t = Σ_k c_k z_{t−k}
                let t = st.next;
                for (k, &c) in self.coeffs.iter().enumerate() {
                    let idx = (t + self.band - k) % self.band;
                    // only mix buffers that are "old enough" to exist
                    if ctx.iteration >= k as u64 {
                        ops::axpy(update, c as f32, &st.ring[idx]);
                    }
                }
                st.next = (st.next + 1) % self.band;
            } else {
                // counter regeneration: z_{t−k} is a pure function of
                // (noise_key, round t−k), so nothing is retained — the
                // band × dim f32 ring collapses to O(chunk) scratch per
                // worker and the whole Σ_k c_k z_{t−k} mix fuses into
                // one parallel pass over the update
                let t = ctx.iteration;
                let terms: Vec<(f32, CtrRng)> = self
                    .coeffs
                    .iter()
                    .enumerate()
                    .filter(|(k, _)| t >= *k as u64)
                    .map(|(k, &c)| (c as f32, env.ctr(STREAM_BMF, t - k as u64)))
                    .collect();
                ops::axpy_normal_mix_ctr(update, &terms, std, env.noise_threads);
            }
            env.noise_nanos += t0.elapsed().as_nanos() as u64;
            m.add_central("dp/noise-std", std, 1.0);
            m.add_central("dp/snr", snr(signal, n, std * self.column_norm()), 1.0);
        }
        Ok(m)
    }

    fn may_participate(&self, uid: usize, iteration: u64) -> bool {
        self.may_participate_inner(uid, iteration)
    }

    fn record_participation(&self, uid: usize, iteration: u64) {
        self.record_participation_inner(uid, iteration)
    }
}

impl BandedMatrixFactorization {
    /// Min-separation participation filter (paper App. C.4): true if the
    /// user may participate at iteration t. The backend consults this for
    /// BMF runs before scheduling a user (via the `Postprocessor` hook).
    pub fn may_participate_inner(&self, uid: usize, t: u64) -> bool {
        let st = self.state.lock().unwrap_or_else(PoisonError::into_inner);
        match st.last_seen.get(&uid) {
            Some(&last) => t.saturating_sub(last) >= self.min_sep,
            None => true,
        }
    }

    pub fn record_participation_inner(&self, uid: usize, t: u64) {
        self.state.lock().unwrap_or_else(PoisonError::into_inner).last_seen.insert(uid, t);
    }
}

/// Local Gaussian mechanism: noise each user's (clipped) update on the
/// worker. Slow in simulation (one noise draw per user) — exactly why
/// the paper ships [`CltApproxLocal`].
pub struct LocalGaussianMechanism {
    pub p: NoiseParams,
}

impl LocalGaussianMechanism {
    pub fn new(clip_bound: f32, noise_multiplier: f64) -> Self {
        LocalGaussianMechanism {
            p: NoiseParams { clip_bound, noise_multiplier, rescale_r: 1.0 },
        }
    }
}

impl Postprocessor for LocalGaussianMechanism {
    fn name(&self) -> &'static str {
        "local-gaussian"
    }

    fn postprocess_one_user(
        &self,
        stats: &mut Statistics,
        ctx: &CentralContext,
        env: &mut PpEnv,
    ) -> Result<Metrics> {
        let mut m = Metrics::new();
        // local noise covers every coordinate, so a sparse update
        // densifies before the worker-side clip + noise
        if let Some(update) = stats.dense_mut(UPDATE) {
            let norm = env.clip.clip(update, self.p.clip_bound)?;
            // worker side: the stream is salted by uid so every user
            // draws independent noise from the shared round key
            let stream =
                STREAM_LOCAL ^ (env.uid as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
            gaussian_noise(env, update, self.p.noise_std(), stream, ctx.iteration);
            m.add_central("dp/pre-clip-norm", norm, 1.0);
        }
        Ok(m)
    }
}

/// Central-limit-theorem approximation of a local mechanism (paper App.
/// B.5, `GaussianApproximatedPrivacyMechanism`): the sum of C local
/// N(0, σ_l²) noises is N(0, C·σ_l²), so one central draw with std
/// σ_l·√C reproduces the local mechanism's effect at a fraction of the
/// cost. Simulation-only — a real deployment must noise locally.
pub struct CltApproxLocal {
    pub clip_bound: f32,
    pub local_noise_std: f64,
}

impl Postprocessor for CltApproxLocal {
    fn name(&self) -> &'static str {
        "clt-approx-local"
    }

    fn postprocess_one_user(
        &self,
        stats: &mut Statistics,
        _ctx: &CentralContext,
        env: &mut PpEnv,
    ) -> Result<Metrics> {
        let mut m = Metrics::new();
        if let Some(update) = stats.vecs.get_mut(UPDATE) {
            let norm = clip_value(env, update, self.clip_bound)?;
            m.add_central("dp/pre-clip-norm", norm, 1.0);
        }
        Ok(m)
    }

    fn postprocess_server(
        &self,
        stats: &mut Statistics,
        ctx: &CentralContext,
        env: &mut PpEnv,
    ) -> Result<Metrics> {
        let mut m = Metrics::new();
        let cohort = stats.weight.max(1.0);
        if let Some(update) = stats.dense_mut(UPDATE) {
            let std = self.local_noise_std * cohort.sqrt();
            gaussian_noise(env, update, std, STREAM_CLT, ctx.iteration);
            m.add_central("dp/noise-std", std, 1.0);
        }
        Ok(m)
    }
}

/// Look up a mechanism by config name with explicit parameters.
pub fn mechanism_by_name(
    name: &str,
    clip_bound: f32,
    noise_multiplier: f64,
    rescale_r: f64,
) -> Result<Box<dyn Postprocessor>> {
    Ok(match name {
        "none" => Box::new(NoPrivacy),
        "gaussian" => Box::new(GaussianMechanism::new(clip_bound, noise_multiplier, rescale_r)),
        "laplace" => Box::new(LaplaceMechanism::new(clip_bound, noise_multiplier, rescale_r)),
        "adaptive-gaussian" => Box::new(AdaptiveClipGaussian::new(
            clip_bound as f64,
            noise_multiplier,
            rescale_r,
        )),
        "banded-mf" => Box::new(BandedMatrixFactorization::new(
            clip_bound,
            noise_multiplier,
            rescale_r,
            8,
        )),
        "local-gaussian" => Box::new(LocalGaussianMechanism::new(clip_bound, noise_multiplier)),
        "clt-local" => Box::new(CltApproxLocal {
            clip_bound,
            local_noise_std: noise_multiplier * clip_bound as f64,
        }),
        other => anyhow::bail!("unknown mechanism {other:?}"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fl::context::LocalParams;
    use crate::fl::model::RustClip;
    use crate::util::rng::Rng;

    fn ctx(t: u64) -> CentralContext {
        CentralContext::train(t, 10, LocalParams::default(), 1)
    }

    /// Legacy-path env (noise_threads = 0): routes through `rng`.
    fn env_of(rng: &mut Rng, user_len: usize) -> PpEnv<'_> {
        PpEnv {
            clip: &RustClip,
            rng,
            user_len,
            uid: 0,
            noise_key: 0,
            noise_threads: 0,
            noise_nanos: 0,
        }
    }

    /// Counter-engine env keyed by `key` with N noise threads.
    fn env_ctr(rng: &mut Rng, key: u64, threads: usize) -> PpEnv<'_> {
        PpEnv {
            clip: &RustClip,
            rng,
            user_len: 0,
            uid: 0,
            noise_key: key,
            noise_threads: threads,
            noise_nanos: 0,
        }
    }

    fn run_user(pp: &dyn Postprocessor, v: Vec<f32>) -> Statistics {
        let mut rng = Rng::seed_from_u64(7);
        let mut env = env_of(&mut rng, 1);
        let mut s = Statistics::new_update(v, 1.0);
        pp.postprocess_one_user(&mut s, &ctx(0), &mut env).unwrap();
        s
    }

    #[test]
    fn poisoned_state_does_not_wedge_the_mechanism() {
        // regression (ISSUE 4 satellite): shared mechanism state was
        // locked with `.lock().unwrap()`, so one panicking worker
        // poisoned the mutex and every later round panicked too. The
        // state is plain data (a bound, a ring buffer, a seen-map) — the
        // run must recover the lock and continue.
        use std::sync::Arc;
        let mech = Arc::new(AdaptiveClipGaussian::new(1.5, 1.0, 1.0));
        let m2 = mech.clone();
        let _ = std::thread::spawn(move || {
            let _guard = m2.state.lock().unwrap();
            panic!("worker dies while holding the mechanism lock");
        })
        .join();
        assert_eq!(mech.current_bound(), 1.5);

        let bmf = Arc::new(BandedMatrixFactorization::new(1.0, 1.0, 1.0, 4));
        let b2 = bmf.clone();
        let _ = std::thread::spawn(move || {
            let _guard = b2.state.lock().unwrap();
            panic!("worker dies while holding the BMF lock");
        })
        .join();
        assert!(bmf.may_participate_inner(0, 0));
        bmf.record_participation_inner(0, 5);
        assert!(!bmf.may_participate_inner(0, 6), "min-sep filter still works after poison");
    }

    #[test]
    fn gaussian_clips_then_noises() {
        let g = GaussianMechanism::new(1.0, 0.5, 1.0);
        let mut s = run_user(&g, vec![3.0, 4.0]);
        assert!((crate::util::l2_norm(s.update()) - 1.0).abs() < 1e-6);
        let before = s.update().to_vec();
        let mut rng = Rng::seed_from_u64(8);
        let mut env = env_of(&mut rng, 0);
        let m = g.postprocess_server(&mut s, &ctx(0), &mut env).unwrap();
        assert_ne!(s.update(), &before[..]);
        assert!((m.get("dp/noise-std").unwrap() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn noise_rescaling_r() {
        // r = C/C̃ scales the noise std (App. C.4)
        let g = GaussianMechanism::new(2.0, 1.0, 0.1);
        assert!((g.p.noise_std() - 0.2).abs() < 1e-12);
    }

    #[test]
    fn gaussian_noise_magnitude_statistics() {
        let mut rng = Rng::seed_from_u64(3);
        let mut v = vec![0.0f32; 20_000];
        let norm = ops::add_gaussian_noise(&mut v, 2.0, &mut rng);
        // E||noise|| = sqrt(d)*std
        let expect = (20_000f64).sqrt() * 2.0;
        assert!((norm / expect - 1.0).abs() < 0.05, "{norm} vs {expect}");
    }

    #[test]
    fn sparse_update_clips_and_noises_dense() {
        use crate::fl::stats::StatValue;
        let g = GaussianMechanism::new(1.0, 0.5, 1.0);
        let mut s = Statistics::new_update_value(
            StatValue::sparse(10, vec![2, 7], vec![3.0, 4.0]),
            1.0,
        );
        let mut rng = Rng::seed_from_u64(7);
        let mut env = env_of(&mut rng, 1);
        g.postprocess_one_user(&mut s, &ctx(0), &mut env).unwrap();
        // clip is exact on the nonzeros and preserves sparsity
        let v = s.update_value().unwrap();
        assert!(matches!(v, StatValue::Sparse { .. }));
        assert!((v.l2_norm() - 1.0).abs() < 1e-6);
        // server noise densifies to the logical dimension
        g.postprocess_server(&mut s, &ctx(0), &mut env).unwrap();
        assert_eq!(s.update().len(), 10);
        assert!(s.update().iter().filter(|x| **x != 0.0).count() > 2);
    }

    #[test]
    fn snr_definition() {
        assert!((snr(10.0, 100, 0.5) - 10.0 / (10.0 * 0.5)).abs() < 1e-12);
        assert_eq!(snr(1.0, 10, 0.0), f64::INFINITY);
    }

    #[test]
    fn laplace_l1_clip() {
        let l = LaplaceMechanism::new(1.0, 0.1, 1.0);
        let s = run_user(&l, vec![1.0, -1.0, 2.0]);
        let l1: f64 = s.update().iter().map(|x| x.abs() as f64).sum();
        assert!((l1 - 1.0).abs() < 1e-6);
    }

    #[test]
    fn adaptive_bound_moves_toward_quantile() {
        let a = AdaptiveClipGaussian::new(1.0, 0.0, 1.0);
        let start = a.current_bound();
        // all users clipped -> fraction 1 > 0.5 -> bound must grow
        for _ in 0..10 {
            let mut s = run_user(&a, vec![30.0, 40.0]);
            let mut rng = Rng::seed_from_u64(9);
            let mut env = env_of(&mut rng, 0);
            a.postprocess_server(&mut s, &ctx(0), &mut env).unwrap();
        }
        assert!(a.current_bound() > start, "{} !> {start}", a.current_bound());
        // indicator must not leak into the update stats
        let mut s = run_user(&a, vec![1.0]);
        let mut rng = Rng::seed_from_u64(9);
        let mut env = env_of(&mut rng, 0);
        a.postprocess_server(&mut s, &ctx(0), &mut env).unwrap();
        assert!(s.get(CLIP_INDICATOR).is_none());
    }

    #[test]
    fn bmf_coefficients_are_sqrt_series() {
        let b = BandedMatrixFactorization::new(1.0, 1.0, 1.0, 4);
        // (1-x)^{-1/2}: 1, 1/2, 3/8, 5/16
        let expect = [1.0, 0.5, 0.375, 0.3125];
        for (c, e) in b.coeffs.iter().zip(expect) {
            assert!((c - e).abs() < 1e-12);
        }
        assert!(b.column_norm() > 1.0);
    }

    #[test]
    fn bmf_noise_is_correlated_across_rounds() {
        let b = BandedMatrixFactorization::new(1.0, 1.0, 1.0, 4);
        let mut rng = Rng::seed_from_u64(5);
        let d = 4096;
        let mut prev: Option<Vec<f32>> = None;
        let mut corr_sum = 0.0;
        for t in 0..6u64 {
            let mut s = Statistics::new_update(vec![0.0; d], 1.0);
            let mut env = env_of(&mut rng, 0);
            b.postprocess_server(&mut s, &ctx(t), &mut env).unwrap();
            let noise = s.update().to_vec();
            if let Some(p) = &prev {
                let dot: f64 = noise.iter().zip(p).map(|(a, b)| (*a as f64) * (*b as f64)).sum();
                let na = crate::util::l2_norm(&noise);
                let nb = crate::util::l2_norm(p);
                corr_sum += dot / (na * nb);
            }
            prev = Some(noise);
        }
        // shared z-buffers make consecutive noise positively correlated
        assert!(corr_sum / 5.0 > 0.3, "avg corr {}", corr_sum / 5.0);
    }

    #[test]
    fn bmf_min_sep_filter() {
        let b = BandedMatrixFactorization::new(1.0, 1.0, 1.0, 4);
        assert!(b.may_participate_inner(7, 0));
        b.record_participation_inner(7, 0);
        assert!(!b.may_participate_inner(7, 10));
        assert!(b.may_participate_inner(7, 48));
        assert!(b.may_participate_inner(8, 10));
    }

    #[test]
    fn clt_approx_scales_with_cohort() {
        let c = CltApproxLocal { clip_bound: 1.0, local_noise_std: 0.1 };
        let mut s = Statistics::new_update(vec![0.0; 10_000], 100.0);
        let mut rng = Rng::seed_from_u64(11);
        let mut env = env_of(&mut rng, 0);
        let m = c.postprocess_server(&mut s, &ctx(0), &mut env).unwrap();
        assert!((m.get("dp/noise-std").unwrap() - 1.0).abs() < 1e-9); // 0.1*sqrt(100)
    }

    #[test]
    fn mechanism_lookup() {
        for name in ["none", "gaussian", "laplace", "adaptive-gaussian", "banded-mf", "local-gaussian", "clt-local"] {
            assert!(mechanism_by_name(name, 1.0, 1.0, 1.0).is_ok(), "{name}");
        }
        assert!(mechanism_by_name("bogus", 1.0, 1.0, 1.0).is_err());
    }

    #[test]
    fn noise_threads_zero_matches_legacy_exactly() {
        // the default engine setting must keep existing runs
        // byte-identical: the mechanism output equals a direct call to
        // the legacy sequential kernel with the same stateful rng
        let g = GaussianMechanism::new(1.0, 0.5, 1.0);
        let base = vec![0.25f32; 512];
        let mut s = Statistics::new_update(base.clone(), 1.0);
        let mut rng = Rng::seed_from_u64(8);
        let mut env = env_of(&mut rng, 0);
        g.postprocess_server(&mut s, &ctx(0), &mut env).unwrap();
        let mut reference = base;
        let mut rng2 = Rng::seed_from_u64(8);
        ops::add_gaussian_noise(&mut reference, 0.5, &mut rng2);
        assert_eq!(s.update(), &reference[..]);
    }

    fn assert_thread_invariant<F: Fn() -> Box<dyn Postprocessor>>(make: F, t: u64, tag: &str) {
        let d = ops::NOISE_CHUNK * 2 + 77; // force real multi-chunk splits
        let base: Vec<f32> = (0..d).map(|i| (i as f32 * 0.001).sin() * 0.01).collect();
        let mut outs: Vec<Vec<f32>> = Vec::new();
        for threads in [1usize, 2, 4] {
            let mech = make(); // fresh state per run (adaptive bound etc.)
            let mut s = Statistics::new_update(base.clone(), 10.0);
            s.insert(CLIP_INDICATOR, vec![1.0]); // exercise the count draw
            let mut rng = Rng::seed_from_u64(1);
            let mut env = env_ctr(&mut rng, 0x5EED, threads);
            mech.postprocess_server(&mut s, &ctx(t), &mut env).unwrap();
            assert!(env.noise_nanos > 0, "{tag}: noise time not accounted");
            outs.push(s.update().to_vec());
        }
        assert_eq!(outs[0], outs[1], "{tag}: 1 vs 2 threads differ");
        assert_eq!(outs[0], outs[2], "{tag}: 1 vs 4 threads differ");
        assert_ne!(outs[0], base, "{tag}: no noise was added");
    }

    #[test]
    fn counter_noise_bit_identical_across_thread_counts() {
        assert_thread_invariant(|| Box::new(GaussianMechanism::new(1.0, 0.5, 1.0)), 3, "gaussian");
        assert_thread_invariant(|| Box::new(LaplaceMechanism::new(1.0, 0.1, 1.0)), 3, "laplace");
        assert_thread_invariant(
            || Box::new(CltApproxLocal { clip_bound: 1.0, local_noise_std: 0.1 }),
            3,
            "clt-local",
        );
        assert_thread_invariant(
            || Box::new(AdaptiveClipGaussian::new(1.0, 0.5, 1.0)),
            3,
            "adaptive-gaussian",
        );
        assert_thread_invariant(
            || Box::new(BandedMatrixFactorization::new(1.0, 1.0, 1.0, 4)),
            9,
            "banded-mf",
        );
    }

    #[test]
    fn bmf_counter_regen_matches_ring_reference_bitwise() {
        // reference implementation: a retained ring filled from the SAME
        // counter streams the engine regenerates from, mixed by repeated
        // axpy exactly like the legacy path. Over 3×band rounds —
        // including the early rounds where the `iteration >= k` guard
        // truncates the mix — the storeless fused regeneration must
        // reproduce it bit for bit.
        use crate::util::rng::round_key;
        let band = 4usize;
        let d = ops::NOISE_CHUNK + 100; // straddle a chunk boundary
        let key = 0xFEEDu64;
        let b = BandedMatrixFactorization::new(1.0, 1.0, 1.0, band);
        let std = b.p.noise_std() / b.column_norm();
        let mut ring: Vec<Vec<f32>> = (0..band).map(|_| vec![0.0f32; d]).collect();
        for t in 0..(3 * band as u64) {
            let zi = (t as usize) % band;
            ops::fill_normal_f32_ctr(
                &mut ring[zi],
                std,
                &CtrRng::new(round_key(key, t), STREAM_BMF),
                1,
            );
            let mut expect = vec![0.0f32; d];
            for (k, &c) in b.coeffs.iter().enumerate() {
                if t >= k as u64 {
                    let idx = (zi + band - k) % band;
                    ops::axpy(&mut expect, c as f32, &ring[idx]);
                }
            }
            let mut s = Statistics::new_update(vec![0.0f32; d], 1.0);
            let mut rng = Rng::seed_from_u64(0);
            let mut env = env_ctr(&mut rng, key, 2);
            b.postprocess_server(&mut s, &ctx(t), &mut env).unwrap();
            assert_eq!(s.update(), &expect[..], "round {t} diverged from ring reference");
        }
        // the whole point: the mechanism retained no band × dim ring
        let st = b.state.lock().unwrap_or_else(PoisonError::into_inner);
        assert!(st.ring.is_empty(), "counter mode must not allocate the ring");
    }
}
