//! Differential privacy for PFL simulation (paper §3 "Privacy
//! integration" + App. B.5).
//!
//! Mechanisms are [`Postprocessor`](crate::fl::postprocess::Postprocessor)s,
//! so they compose with any algorithm and run in the same pipeline as
//! weighting/compression. Each mechanism *owns* its clipping bound and
//! derives its noise scale from it, so bound and noise can never diverge
//! (the paper's "tight integration between the DP mechanisms and FL
//! hyperparameters"). Clipping on the user path goes through the worker's
//! L1 Pallas `clip_scale` kernel; noise is added once per central
//! iteration on the aggregate, in place.
//!
//! The *noise cohort size* rescaling of App. C.4 is built in: simulate
//! with cohort C but noise as if the cohort were C̃ by scaling the noise
//! standard deviation by r = C/C̃.

pub mod accountant;
pub mod mechanisms;

pub use accountant::{
    accountant_by_name, Accountant, AccountantParams, PldAccountant, PrvAccountant,
    RdpAccountant,
};
pub use mechanisms::{
    AdaptiveClipGaussian, BandedMatrixFactorization, CltApproxLocal, GaussianMechanism,
    LaplaceMechanism, LocalGaussianMechanism, NoPrivacy,
};
