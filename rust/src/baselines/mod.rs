//! Baseline FL-simulator architecture emulations (paper §4.1, App. D).
//!
//! We cannot ship TFF, Flower, FedML, FedScale and FLUTE; instead each
//! baseline is an [`OverheadProfile`] that re-introduces, on top of the
//! *same* local compute (the same PJRT executables), exactly the design
//! costs the paper attributes the speed gap to (§3 items 1–6 and App.
//! D.4.2):
//!
//! * **per-user model re-allocation** instead of one resident model
//!   updated in place (Flower / FedML / FedScale);
//! * **host round-trips** of every update through a NumPy-style staging
//!   buffer (Flower's outer loop);
//! * **explicit topology**: every per-user update serialized through a
//!   dedicated coordinator process (TFF-style execution stacks);
//! * **full-participation bookkeeping**: per-round work proportional to
//!   the population, not the cohort (FedScale's sampler);
//! * **per-round checkpointing** hard-coded in the framework (FedScale);
//! * **interpreter/dispatch tax** per local step (FLUTE's client loop;
//!   calibrated, see `benchmarks` in the CLI).
//!
//! The profiles change *where time goes*, never the statistics: every
//! variant converges to the same model up to scheduling-order floating
//! point noise (asserted in `framework_integration.rs`), which mirrors the
//! accuracy-consistency column of paper Table 1.

use anyhow::{bail, Result};

/// Overhead knobs a worker round pays per user / per step / per round.
#[derive(Debug, Clone, Default)]
pub struct OverheadProfile {
    /// Re-materialize model-sized tensors for every client.
    pub realloc_per_user: bool,
    /// Bounce every update device→host→device.
    pub cpu_roundtrip: bool,
    /// Route every per-user update through a dedicated coordinator thread
    /// (serialized + deserialized), simulating FL topology.
    pub coordinator: bool,
    /// Fixed per-user framework overhead (client construction, context
    /// switches), busy-wait emulated.
    pub per_user_overhead_ns: u64,
    /// Per-local-step dispatch tax (interpreter-driven client loops).
    pub per_step_overhead_ns: u64,
    /// Per-round bookkeeping proportional to the *population* (FedScale
    /// samples all users each round): O(population) work units per round.
    pub full_participation_bookkeeping: bool,
    /// Serialize the model to disk every round (hard-coded checkpointing).
    pub checkpoint_every_round: bool,
}

/// The engines compared in paper Tables 1–2.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineVariant {
    /// This framework's design: resident model, in-place updates, replica
    /// workers, on-device DP, greedy load balancing.
    PflStyle,
    /// Flower-like: per-client model instantiation + NumPy outer loop.
    FlowerLike,
    /// FedML-like: per-client realloc + slow one-off partitioning
    /// (represented by per-user overhead; App. D.4.2 notes its 20-minute
    /// init).
    FedMlLike,
    /// TFF-like: explicit topology through a coordinator + host copies.
    TffLike,
    /// FedScale-like: realloc + full-participation bookkeeping +
    /// per-round checkpointing.
    FedScaleLike,
    /// FLUTE-like: coordinator topology + heavy per-step dispatch tax
    /// (single process per GPU only — see Table 1, p=1 row).
    FluteLike,
}

impl EngineVariant {
    pub fn all() -> [EngineVariant; 6] {
        [
            EngineVariant::PflStyle,
            EngineVariant::FlowerLike,
            EngineVariant::FedMlLike,
            EngineVariant::TffLike,
            EngineVariant::FedScaleLike,
            EngineVariant::FluteLike,
        ]
    }

    pub fn name(&self) -> &'static str {
        match self {
            EngineVariant::PflStyle => "pfl-style",
            EngineVariant::FlowerLike => "flower-like",
            EngineVariant::FedMlLike => "fedml-like",
            EngineVariant::TffLike => "tff-like",
            EngineVariant::FedScaleLike => "fedscale-like",
            EngineVariant::FluteLike => "flute-like",
        }
    }

    pub fn from_name(name: &str) -> Result<Self> {
        for v in Self::all() {
            if v.name() == name {
                return Ok(v);
            }
        }
        bail!("unknown engine {name:?} (one of: pfl-style, flower-like, fedml-like, tff-like, fedscale-like, flute-like)")
    }

    /// Per-user framework overhead on the paper's A100 testbed,
    /// **derived from paper Table 1** (p = 1 rows): total wall-clock
    /// minus pfl-research's, divided by the 1500 × 50 user-trainings of
    /// the CIFAR10 benchmark. E.g. Flower: (86.88 − 10.13) min / 75 000 ≈
    /// 61 ms per user. These calibrate the emulations; the structural
    /// flags (realloc/roundtrip/coordinator) are what *generates* such
    /// overheads mechanically, and the counters in Figs. 7–8 show them.
    pub fn paper_user_overhead_ns(&self) -> u64 {
        match self {
            EngineVariant::PflStyle => 0,
            EngineVariant::FlowerLike => 61_400_000,   // 86.88 min
            EngineVariant::FedMlLike => 64_700_000,    // 90.95 min
            EngineVariant::TffLike => 82_700_000,      // 113.52 min
            EngineVariant::FedScaleLike => 332_100_000, // 425.2 min
            EngineVariant::FluteLike => 46_200_000,    // 67.86 min
        }
    }

    /// pfl-research's own per-user wall-clock on the paper testbed:
    /// 10.13 min / 75 000 users ≈ 8.1 ms (Table 1, p = 1), split into a
    /// device part and an overlappable host part. The split follows the
    /// paper's own p-scaling: p = 5 takes 4.20/10.13 ≈ 0.41 of p = 1, so
    /// ~41% of per-user time is serialized device work and ~59% host
    /// work that overlaps when processes share the GPU (§4.2).
    pub const A100_PFL_USER_NS: u64 = 8_100_000;
    pub const A100_PFL_DEVICE_NS: u64 = 3_350_000;
    pub const A100_PFL_HOST_NS: u64 = 4_750_000;

    /// The overhead profile of this engine. The per-user taxes are the
    /// paper-calibrated values above; the structural flags re-create the
    /// *mechanisms* (re-allocation, host round-trips, coordinator
    /// topology, full-participation bookkeeping) so the system counters
    /// of App. D.4.2 (Figs. 7–8) move the way the paper reports.
    pub fn profile(&self) -> OverheadProfile {
        let tax = self.paper_user_overhead_ns();
        match self {
            EngineVariant::PflStyle => OverheadProfile::default(),
            EngineVariant::FlowerLike => OverheadProfile {
                realloc_per_user: true,
                cpu_roundtrip: true,
                per_user_overhead_ns: tax,
                ..Default::default()
            },
            EngineVariant::FedMlLike => OverheadProfile {
                realloc_per_user: true,
                cpu_roundtrip: true,
                per_user_overhead_ns: tax,
                ..Default::default()
            },
            EngineVariant::TffLike => OverheadProfile {
                coordinator: true,
                cpu_roundtrip: true,
                per_user_overhead_ns: tax,
                ..Default::default()
            },
            EngineVariant::FedScaleLike => OverheadProfile {
                realloc_per_user: true,
                cpu_roundtrip: true,
                full_participation_bookkeeping: true,
                checkpoint_every_round: true,
                per_user_overhead_ns: tax,
                ..Default::default()
            },
            EngineVariant::FluteLike => OverheadProfile {
                coordinator: true,
                per_user_overhead_ns: tax,
                ..Default::default()
            },
        }
    }

    /// Whether the engine supports multiple worker processes per device
    /// (FLUTE could not run p > 1 in the paper's Table 1).
    pub fn supports_multiprocess(&self) -> bool {
        !matches!(self, EngineVariant::FluteLike)
    }

    /// The scheduler the engine uses: only pfl-style load balances.
    pub fn scheduler(&self) -> crate::fl::scheduler::SchedulerKind {
        match self {
            EngineVariant::PflStyle => crate::fl::scheduler::SchedulerKind::Greedy,
            _ => crate::fl::scheduler::SchedulerKind::Uniform,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_round_trip() {
        for v in EngineVariant::all() {
            assert_eq!(EngineVariant::from_name(v.name()).unwrap(), v);
        }
        assert!(EngineVariant::from_name("nope").is_err());
    }

    #[test]
    fn pfl_style_pays_no_overhead() {
        let p = EngineVariant::PflStyle.profile();
        assert!(!p.realloc_per_user && !p.cpu_roundtrip && !p.coordinator);
        assert_eq!(p.per_user_overhead_ns, 0);
        assert_eq!(p.per_step_overhead_ns, 0);
    }

    #[test]
    fn baselines_pay_overheads() {
        for v in EngineVariant::all() {
            if v == EngineVariant::PflStyle {
                continue;
            }
            let p = v.profile();
            assert!(
                p.realloc_per_user
                    || p.coordinator
                    || p.per_user_overhead_ns > 0
                    || p.full_participation_bookkeeping,
                "{v:?} has no overhead"
            );
        }
    }

    #[test]
    fn flute_is_single_process() {
        assert!(!EngineVariant::FluteLike.supports_multiprocess());
        assert!(EngineVariant::PflStyle.supports_multiprocess());
    }
}
