//! PJRT execution of AOT-lowered artifacts.
//!
//! Wraps the `xla` crate (xla_extension 0.5.1, CPU PJRT): load HLO *text*
//! (`HloModuleProto::from_text_file` — the text parser reassigns the 64-bit
//! instruction ids jax >= 0.5 emits, which the proto path rejects), compile
//! once per worker, then execute from the simulation hot path.
//!
//! Each worker replica owns its own `Runtime` (client + executables),
//! mirroring pfl-research's "only one model per worker process is
//! initialized and preserved on the GPU at all times": the compiled
//! executables and the flat parameter buffers live for the whole
//! simulation; per-call allocations are bounded by batch size, not model
//! size.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::manifest::{ArtifactSpec, IoSpec, Manifest};

/// An input argument to an executable. Borrowed slices avoid staging
/// copies on the rust side; the single host->device copy happens inside
/// literal construction.
#[derive(Debug, Clone, Copy)]
pub enum Arg<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
    ScalarF32(f32),
}

impl Arg<'_> {
    pub fn element_count(&self) -> usize {
        match self {
            Arg::F32(v) => v.len(),
            Arg::I32(v) => v.len(),
            Arg::ScalarF32(_) => 1,
        }
    }
    pub fn dtype(&self) -> &'static str {
        match self {
            Arg::F32(_) | Arg::ScalarF32(_) => "f32",
            Arg::I32(_) => "i32",
        }
    }
}

/// An output value decoded from the executable's result tuple.
#[derive(Debug, Clone)]
pub enum Out {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Out {
    pub fn as_f32(&self) -> &[f32] {
        match self {
            Out::F32(v) => v,
            Out::I32(_) => panic!("expected f32 output"),
        }
    }
    pub fn scalar_f32(&self) -> f32 {
        let v = self.as_f32();
        assert_eq!(v.len(), 1, "expected scalar output");
        v[0]
    }
    pub fn into_f32(self) -> Vec<f32> {
        match self {
            Out::F32(v) => v,
            Out::I32(_) => panic!("expected f32 output"),
        }
    }
}

/// Execution statistics for the profiler / simulated-device accounting.
#[derive(Debug, Default, Clone)]
pub struct ExecStats {
    pub calls: u64,
    pub exec_nanos: u64,
    pub stage_nanos: u64,
    pub fetch_nanos: u64,
    pub bytes_in: u64,
    pub bytes_out: u64,
}

/// One compiled artifact.
pub struct Compiled {
    pub key: String,
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
    stats: RefCell<ExecStats>,
}

fn mk_literal(arg: &Arg, spec: &IoSpec) -> Result<xla::Literal> {
    if arg.dtype() != spec.dtype {
        bail!("dtype mismatch: arg {} vs spec {}", arg.dtype(), spec.dtype);
    }
    if arg.element_count() != spec.element_count() {
        bail!(
            "shape mismatch: arg has {} elements, spec {:?} wants {}",
            arg.element_count(),
            spec.shape,
            spec.element_count()
        );
    }
    let dims: Vec<usize> = spec.shape.clone();
    let lit = match arg {
        Arg::F32(v) => {
            let mut lit = xla::Literal::create_from_shape(xla::PrimitiveType::F32, &dims);
            lit.copy_raw_from::<f32>(v)?;
            lit
        }
        Arg::ScalarF32(x) => {
            let mut lit = xla::Literal::create_from_shape(xla::PrimitiveType::F32, &dims);
            lit.copy_raw_from::<f32>(&[*x])?;
            lit
        }
        Arg::I32(v) => {
            let mut lit = xla::Literal::create_from_shape(xla::PrimitiveType::S32, &dims);
            lit.copy_raw_from::<i32>(v)?;
            lit
        }
    };
    Ok(lit)
}

impl Compiled {
    /// Execute with shape-checked args; returns the decoded output tuple.
    pub fn execute(&self, args: &[Arg]) -> Result<Vec<Out>> {
        if args.len() != self.spec.inputs.len() {
            bail!(
                "{}: got {} args, artifact wants {}",
                self.key,
                args.len(),
                self.spec.inputs.len()
            );
        }
        let t0 = Instant::now();
        let mut literals = Vec::with_capacity(args.len());
        let mut bytes_in = 0u64;
        for (arg, spec) in args.iter().zip(&self.spec.inputs) {
            bytes_in += (spec.element_count() * 4) as u64;
            literals.push(
                mk_literal(arg, spec).with_context(|| format!("artifact {}", self.key))?,
            );
        }
        let t1 = Instant::now();
        let result = self.exe.execute::<xla::Literal>(&literals)?;
        let root = result[0][0].to_literal_sync()?;
        let t2 = Instant::now();
        let parts = root.to_tuple()?;
        if parts.len() != self.spec.outputs.len() {
            bail!(
                "{}: executable returned {} outputs, manifest says {}",
                self.key,
                parts.len(),
                self.spec.outputs.len()
            );
        }
        let mut outs = Vec::with_capacity(parts.len());
        let mut bytes_out = 0u64;
        for (lit, spec) in parts.iter().zip(&self.spec.outputs) {
            bytes_out += (spec.element_count() * 4) as u64;
            let out = match spec.dtype.as_str() {
                "f32" => Out::F32(lit.to_vec::<f32>()?),
                "i32" => Out::I32(lit.to_vec::<i32>()?),
                other => bail!("unsupported dtype {other}"),
            };
            outs.push(out);
        }
        let t3 = Instant::now();
        let mut s = self.stats.borrow_mut();
        s.calls += 1;
        s.stage_nanos += (t1 - t0).as_nanos() as u64;
        s.exec_nanos += (t2 - t1).as_nanos() as u64;
        s.fetch_nanos += (t3 - t2).as_nanos() as u64;
        s.bytes_in += bytes_in;
        s.bytes_out += bytes_out;
        Ok(outs)
    }

    pub fn stats(&self) -> ExecStats {
        self.stats.borrow().clone()
    }
}

/// Per-worker runtime: one PJRT client + a cache of compiled artifacts.
///
/// Deliberately `!Send`: each worker thread constructs its own `Runtime`,
/// which is exactly the replica model of the paper (Fig. 1a).
pub struct Runtime {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    cache: RefCell<HashMap<String, Rc<Compiled>>>,
    pub compile_nanos: RefCell<u64>,
}

impl Runtime {
    pub fn new(manifest: Manifest) -> Result<Self> {
        let client = xla::PjRtClient::cpu()?;
        Ok(Self {
            manifest,
            client,
            cache: RefCell::new(HashMap::new()),
            compile_nanos: RefCell::new(0),
        })
    }

    pub fn from_dir(dir: impl AsRef<std::path::Path>) -> Result<Self> {
        Self::new(Manifest::load(dir)?)
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an artifact (cached).
    pub fn get(&self, key: &str) -> Result<Rc<Compiled>> {
        if let Some(c) = self.cache.borrow().get(key) {
            return Ok(c.clone());
        }
        let spec = self.manifest.artifact(key)?.clone();
        let path = self.manifest.artifact_path(key)?;
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("loading HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        *self.compile_nanos.borrow_mut() += t0.elapsed().as_nanos() as u64;
        let compiled = Rc::new(Compiled {
            key: key.to_string(),
            spec,
            exe,
            stats: RefCell::new(ExecStats::default()),
        });
        self.cache.borrow_mut().insert(key.to_string(), compiled.clone());
        Ok(compiled)
    }

    /// Aggregate execution stats across all compiled artifacts.
    pub fn total_stats(&self) -> ExecStats {
        let mut total = ExecStats::default();
        for c in self.cache.borrow().values() {
            let s = c.stats();
            total.calls += s.calls;
            total.exec_nanos += s.exec_nanos;
            total.stage_nanos += s.stage_nanos;
            total.fetch_nanos += s.fetch_nanos;
            total.bytes_in += s.bytes_in;
            total.bytes_out += s.bytes_out;
        }
        total
    }
}
