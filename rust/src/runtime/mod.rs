//! Runtime layer: load + execute AOT-compiled HLO artifacts via PJRT.
//!
//! See DESIGN.md §1 (layering) and §5 (hardware adaptation) —
//! python/jax (+Pallas) runs only at `make artifacts` time;
//! this module is the only place the simulator touches XLA. The PJRT
//! executor (and with it the `xla` crate) is behind the optional `hlo`
//! cargo feature; the manifest layer is pure Rust and always available,
//! so configs, presets and the pure-Rust model zoo build everywhere.

#[cfg(feature = "hlo")]
mod executor;
mod manifest;

#[cfg(feature = "hlo")]
pub use executor::{Arg, Compiled, ExecStats, Out, Runtime};
pub use manifest::{
    init_from_layout, ArtifactSpec, IoSpec, Manifest, ModelEntry, TensorEntry,
};
