//! Runtime layer: load + execute AOT-compiled HLO artifacts via PJRT.
//!
//! See DESIGN.md — python/jax (+Pallas) runs only at `make artifacts` time;
//! this module is the only place the simulator touches XLA.

mod executor;
mod manifest;

pub use executor::{Arg, Compiled, ExecStats, Out, Runtime};
pub use manifest::{
    init_from_layout, ArtifactSpec, IoSpec, Manifest, ModelEntry, TensorEntry,
};
