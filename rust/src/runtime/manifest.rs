//! Artifact manifest: the python->rust interchange contract.
//!
//! `python/compile/aot.py` writes `artifacts/manifest.json` describing every
//! AOT-lowered HLO module: input/output shapes, the flat-parameter layout of
//! each model (tensor names, offsets, init specs) and per-step FLOP
//! estimates used by the simulated-device accounting.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::util::json::Value;
use crate::util::rng::Rng;

#[derive(Debug, Clone, PartialEq)]
pub struct IoSpec {
    pub shape: Vec<usize>,
    pub dtype: String, // "f32" | "i32"
}

impl IoSpec {
    pub fn element_count(&self) -> usize {
        self.shape.iter().product::<usize>()
    }

    fn from_json(v: &Value) -> Result<Self> {
        Ok(IoSpec {
            shape: v.req("shape")?.usize_arr()?,
            dtype: v.req("dtype")?.as_str()?.to_string(),
        })
    }
}

#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub file: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
    pub sha256: String,
}

impl ArtifactSpec {
    fn from_json(v: &Value) -> Result<Self> {
        Ok(ArtifactSpec {
            file: v.req("file")?.as_str()?.to_string(),
            inputs: v.req("inputs")?.as_arr()?.iter().map(IoSpec::from_json).collect::<Result<_>>()?,
            outputs: v.req("outputs")?.as_arr()?.iter().map(IoSpec::from_json).collect::<Result<_>>()?,
            sha256: v.get("sha256").and_then(|x| x.as_str().ok()).unwrap_or("").to_string(),
        })
    }
}

/// One tensor inside a model's flat parameter vector.
#[derive(Debug, Clone)]
pub struct TensorEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub size: usize,
    pub init: String, // "zeros" | "ones" | "normal"
    pub std: f64,
}

impl TensorEntry {
    fn from_json(v: &Value) -> Result<Self> {
        Ok(TensorEntry {
            name: v.req("name")?.as_str()?.to_string(),
            shape: v.req("shape")?.usize_arr()?,
            offset: v.req("offset")?.as_usize()?,
            size: v.req("size")?.as_usize()?,
            init: v.req("init")?.as_str()?.to_string(),
            std: v.req("std")?.as_f64()?,
        })
    }
}

#[derive(Debug, Clone)]
pub struct ModelEntry {
    pub param_count: usize,
    pub layout: Vec<TensorEntry>,
    pub train_batch: usize,
    pub eval_batch: usize,
    pub flops_per_train_step: u64,
    pub description: String,
    /// step name -> artifact key ("train", "eval", "clip")
    pub artifacts: BTreeMap<String, String>,
    pub base_param_count: Option<usize>,
    pub base_layout: Option<Vec<TensorEntry>>,
}

impl ModelEntry {
    fn from_json(v: &Value) -> Result<Self> {
        let layout = v
            .req("layout")?
            .as_arr()?
            .iter()
            .map(TensorEntry::from_json)
            .collect::<Result<_>>()?;
        let artifacts = v
            .req("artifacts")?
            .as_obj()?
            .iter()
            .map(|(k, val)| Ok((k.clone(), val.as_str()?.to_string())))
            .collect::<Result<_>>()?;
        let base_layout = match v.get("base_layout") {
            Some(b) => Some(
                b.as_arr()?
                    .iter()
                    .map(TensorEntry::from_json)
                    .collect::<Result<Vec<_>>>()?,
            ),
            None => None,
        };
        Ok(ModelEntry {
            param_count: v.req("param_count")?.as_usize()?,
            layout,
            train_batch: v.req("train_batch")?.as_usize()?,
            eval_batch: v.req("eval_batch")?.as_usize()?,
            flops_per_train_step: v.req("flops_per_train_step")?.as_u64()?,
            description: v
                .get("description")
                .and_then(|x| x.as_str().ok())
                .unwrap_or("")
                .to_string(),
            artifacts,
            base_param_count: match v.get("base_param_count") {
                Some(x) => Some(x.as_usize()?),
                None => None,
            },
            base_layout,
        })
    }

    /// Look up a tensor by name in the flat layout.
    pub fn tensor(&self, name: &str) -> Option<&TensorEntry> {
        self.layout.iter().find(|t| t.name == name)
    }

    /// Deterministically initialize the flat parameter vector from the
    /// manifest init specs (He/normal per tensor, zeros/ones for biases
    /// and norms). Mirrors pfl-research's framework-side model init.
    pub fn init_params(&self, seed: u64) -> Vec<f32> {
        init_from_layout(&self.layout, self.param_count, seed)
    }

    pub fn init_base_params(&self, seed: u64) -> Option<Vec<f32>> {
        let layout = self.base_layout.as_ref()?;
        Some(init_from_layout(layout, self.base_param_count?, seed))
    }
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub format: String,
    pub models: BTreeMap<String, ModelEntry>,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    pub dir: PathBuf,
}

impl Manifest {
    pub fn parse(text: &str, dir: PathBuf) -> Result<Self> {
        let v = Value::parse(text).context("parsing manifest.json")?;
        let models = v
            .req("models")?
            .as_obj()?
            .iter()
            .map(|(k, m)| Ok((k.clone(), ModelEntry::from_json(m).with_context(|| format!("model {k}"))?)))
            .collect::<Result<_>>()?;
        let artifacts = v
            .req("artifacts")?
            .as_obj()?
            .iter()
            .map(|(k, a)| Ok((k.clone(), ArtifactSpec::from_json(a).with_context(|| format!("artifact {k}"))?)))
            .collect::<Result<_>>()?;
        Ok(Manifest {
            format: v.req("format")?.as_str()?.to_string(),
            models,
            artifacts,
            dir,
        })
    }

    /// Load `manifest.json` from an artifacts directory.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} (run `make artifacts`)"))?;
        Self::parse(&text, dir.to_path_buf())
    }

    /// Default artifacts directory: $PFL_ARTIFACTS or ./artifacts.
    pub fn load_default() -> Result<Self> {
        let dir = std::env::var("PFL_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        Self::load(dir)
    }

    pub fn model(&self, name: &str) -> Result<&ModelEntry> {
        self.models.get(name).ok_or_else(|| {
            anyhow!(
                "model {name:?} not in manifest (have: {:?})",
                self.models.keys().collect::<Vec<_>>()
            )
        })
    }

    pub fn artifact(&self, key: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(key)
            .ok_or_else(|| anyhow!("artifact {key:?} not in manifest"))
    }

    pub fn artifact_path(&self, key: &str) -> Result<PathBuf> {
        Ok(self.dir.join(&self.artifact(key)?.file))
    }
}

pub fn init_from_layout(layout: &[TensorEntry], total: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::seed_from_u64(seed);
    let mut out = vec![0f32; total];
    for t in layout {
        let dst = &mut out[t.offset..t.offset + t.size];
        match t.init.as_str() {
            "zeros" => {}
            "ones" => dst.fill(1.0),
            _ => {
                for v in dst.iter_mut() {
                    *v = rng.normal_scaled(0.0, t.std) as f32;
                }
            }
        }
    }
    out
}

#[cfg(test)]
pub(crate) fn toy_manifest_json() -> &'static str {
    r#"{
      "format": "hlo-text",
      "models": {
        "toy": {
          "param_count": 6,
          "layout": [
            {"name": "w", "shape": [2, 2], "offset": 0, "size": 4, "init": "normal", "std": 0.5},
            {"name": "b", "shape": [2], "offset": 4, "size": 2, "init": "zeros", "std": 0.0}
          ],
          "train_batch": 4,
          "eval_batch": 8,
          "flops_per_train_step": 100,
          "artifacts": {"train": "toy_train"}
        }
      },
      "artifacts": {
        "toy_train": {
          "file": "toy_train.hlo.txt",
          "inputs": [{"shape": [6], "dtype": "f32"}],
          "outputs": [{"shape": [6], "dtype": "f32"}]
        }
      }
    }"#
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Manifest {
        Manifest::parse(toy_manifest_json(), PathBuf::from("/tmp")).unwrap()
    }

    #[test]
    fn parse_and_lookup() {
        let m = toy();
        let t = m.models.get("toy").unwrap();
        assert_eq!(t.param_count, 6);
        assert_eq!(t.tensor("b").unwrap().offset, 4);
        assert!(t.tensor("nope").is_none());
        assert_eq!(m.artifacts["toy_train"].inputs[0].element_count(), 6);
        assert_eq!(m.artifact_path("toy_train").unwrap(), PathBuf::from("/tmp/toy_train.hlo.txt"));
    }

    #[test]
    fn init_respects_kinds() {
        let p = toy().models["toy"].init_params(7);
        assert_eq!(p.len(), 6);
        assert!(p[0..4].iter().any(|v| *v != 0.0));
        assert_eq!(&p[4..6], &[0.0, 0.0]);
    }

    #[test]
    fn init_deterministic_per_seed() {
        let m = toy();
        assert_eq!(m.models["toy"].init_params(1), m.models["toy"].init_params(1));
        assert_ne!(m.models["toy"].init_params(1), m.models["toy"].init_params(2));
    }

    #[test]
    fn missing_model_is_error() {
        let m = toy();
        assert!(m.model("missing").is_err());
        assert!(m.model("toy").is_ok());
    }
}
