//! Config system + benchmark presets (paper §4.3 and App. C).
//!
//! A [`Config`] fully describes one simulation: dataset, model, algorithm,
//! run schedule, privacy setup, and engine topology. Configs serialize to
//! JSON (`pfl run --config file.json`), and every benchmark in the paper's
//! suite is a named [`preset`] whose hyperparameters copy Tables 8–11:
//!
//! `{cifar10, stackoverflow, flair, llm-sa, llm-aya, llm-oa}` ×
//! `{iid, noniid}` × `{nodp, dp}`.
//!
//! Because this testbed is a CPU PJRT device (not 4×A100), presets are run
//! through [`Config::scaled`], which shrinks iterations / cohort /
//! population proportionally while preserving every structural ratio
//! (local epochs, batch sizes, clip bounds, ε budget, r = C/C̃). The CLI
//! default is scale 1.0 = paper values; experiments record their scale.

pub mod build;

use anyhow::{bail, Context, Result};

use crate::util::json::{arr, num, obj, s, Value};

#[derive(Debug, Clone, PartialEq)]
pub struct DatasetConfig {
    /// "cifar" | "flair" | "text" | "instruct-sa" | "instruct-aya" |
    /// "instruct-oa" | "tabular" | "points"
    pub kind: String,
    pub num_users: usize,
    /// Datapoints per user for IID fixed-size partitions.
    pub per_user: usize,
    /// Dirichlet α for non-IID label partitions (None = IID / natural).
    pub dirichlet_alpha: Option<f64>,
    pub seed: u64,
}

#[derive(Debug, Clone, PartialEq)]
pub struct AlgorithmConfig {
    /// "fedavg" | "fedprox" | "adafedprox" | "scaffold"
    pub kind: String,
    /// FedProx µ.
    pub mu: f64,
}

#[derive(Debug, Clone, PartialEq)]
pub struct CentralOptConfig {
    /// "sgd" | "adam"
    pub kind: String,
    pub lr: f64,
    pub warmup: u64,
    /// Adam adaptivity degree τ (paper Tables 9–11).
    pub adaptivity: f64,
    pub beta1: f64,
    pub beta2: f64,
}

#[derive(Debug, Clone, PartialEq)]
pub struct PrivacyConfig {
    /// "none" | "gaussian" | "banded-mf" | "adaptive-gaussian" | ...
    pub mechanism: String,
    /// "rdp" | "pld" | "prv"
    pub accountant: String,
    pub clip_bound: f64,
    pub epsilon: f64,
    pub delta: f64,
    /// Accounting population M (paper Table 7: 1e6).
    pub population_m: f64,
    /// Noise cohort size C̃ (paper App. C.4).
    pub noise_cohort: f64,
    /// Top-k sparsification of user updates before the DP clip (0 = keep
    /// dense). Surviving coordinates travel as sparse statistics.
    pub sparse_top_k: usize,
}

impl PrivacyConfig {
    pub fn none() -> Self {
        PrivacyConfig {
            mechanism: "none".into(),
            accountant: "pld".into(),
            clip_bound: 0.0,
            epsilon: 0.0,
            delta: 0.0,
            population_m: 1e6,
            noise_cohort: 0.0,
            sparse_top_k: 0,
        }
    }

    pub fn is_none(&self) -> bool {
        self.mechanism == "none"
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct Config {
    pub name: String,
    /// Manifest model name ("cnn_c10" | "lm_so" | "mlp_flair" | "lora_llm").
    pub model: String,
    pub dataset: DatasetConfig,
    pub algorithm: AlgorithmConfig,
    pub central_opt: CentralOptConfig,
    pub privacy: PrivacyConfig,
    // run schedule (paper Tables 8–11)
    pub iterations: u64,
    pub cohort_size: usize,
    pub val_cohort_size: usize,
    pub eval_every: u64,
    pub local_epochs: usize,
    pub local_batch: usize,
    pub local_lr: f64,
    pub local_max_steps: usize,
    // engine
    pub num_workers: usize,
    /// "uniform" | "greedy" | "greedy-median"
    pub scheduler: String,
    /// "static" | "work-stealing" | "async" (see `fl::dispatch`).
    pub dispatcher: String,
    /// Async dispatch: staleness bound (rounds) before an update drops.
    pub max_staleness: u64,
    /// Async dispatch: fraction of the cohort that closes the buffer.
    pub buffer_frac: f64,
    /// Async dispatch: deterministic-replay window (0 = physical arrival
    /// order; > 0 folds in dispatch order through a bounded
    /// arrival-reorder buffer, bit-identical across worker counts).
    pub reorder_window: usize,
    /// Worker arena: sparse slots spill to dense once union nnz exceeds
    /// this fraction of the dimension (`ArenaConfig::sparse_spill_frac`).
    pub sparse_spill_frac: f64,
    /// Path of a `pfl materialize` store directory. Empty (default) =
    /// generate user data lazily (pre-store behavior, byte-identical);
    /// set = read materialized users out-of-core through the LRU cache
    /// + prefetch pipeline (`crate::data::store`, CLI `--data-store`).
    pub data_store: String,
    /// Store-backed runs: LRU user-cache capacity (CLI `--cache-users`).
    pub cache_users: usize,
    /// Store-backed runs: how many users the prefetch thread may run
    /// ahead of worker consumption; 0 disables the thread (CLI
    /// `--prefetch-depth`).
    pub prefetch_depth: usize,
    /// Store-backed runs: map shards with `mmap` so warm reads are
    /// zero-copy out of the page cache (default true; CLI
    /// `--store-mmap=false` forces the portable `pread` path — also the
    /// automatic fallback on platforms without the mmap shim).
    pub store_mmap: bool,
    /// Compression for `pfl materialize`: "none" (default) or
    /// "shuffle-lz" (byte-shuffle + block LZ, decoded on the prefetch
    /// thread; CLI `--compression`). Reads auto-detect from the store
    /// index, so this only affects writing.
    pub store_compression: String,
    /// Wire representation of user statistics: "none" (exact f32,
    /// default), "f16" or "int8" (CLI `--quantize`). Non-none appends an
    /// error-feedback [`crate::fl::postprocess::WireQuantizer`] as the
    /// last local step, so the narrow codes are what ships to the
    /// aggregator.
    pub wire_quantization: String,
    /// Reduce worker partials with the parallel binary tree fold instead
    /// of the serial left fold (CLI `--fold-tree`).
    pub fold_tree: bool,
    /// Worker threads for the counter-based DP noise engine (CLI
    /// `--noise-threads`). 0 (default) keeps the legacy sequential noise
    /// stream byte-identical to previous releases; N ≥ 1 switches every
    /// mechanism to counter-keyed parallel kernels (bit-identical output
    /// for any N) and lets banded-MF regenerate noise instead of
    /// retaining its `band × dim` ring.
    pub noise_threads: usize,
    /// Device-realism scenario (DESIGN.md §8): speed tiers, diurnal
    /// availability windows and a mid-round dropout hazard, sampled
    /// deterministically per uid (CLI `--scenario`). `None` (default)
    /// disables the layer entirely — runs are byte-identical to
    /// previous releases and the key is omitted from the JSON form.
    pub scenario: Option<crate::fl::device::ScenarioSpec>,
    pub seed: u64,
}

impl Config {
    /// Scale the compute budget while preserving structure: iterations,
    /// cohort sizes and population shrink by `f`; batch sizes, epochs,
    /// clip bounds, ε stay fixed; the DP noise-rescaling r = C/C̃ is
    /// recomputed downstream from the scaled C.
    pub fn scaled(mut self, f: f64) -> Config {
        if (f - 1.0).abs() < 1e-12 {
            return self;
        }
        let sc = |x: usize| ((x as f64 * f).round() as usize).max(1);
        self.iterations = ((self.iterations as f64 * f).round() as u64).max(1);
        self.cohort_size = sc(self.cohort_size).max(2);
        if self.val_cohort_size > 0 {
            self.val_cohort_size = sc(self.val_cohort_size).max(2);
        }
        self.dataset.num_users = sc(self.dataset.num_users).max(self.cohort_size * 2);
        self.eval_every = ((self.eval_every as f64 * f).round() as u64).max(1);
        self.name = format!("{}@{f}", self.name);
        self
    }

    pub fn scheduler_kind(&self) -> Result<crate::fl::SchedulerKind> {
        Ok(match self.scheduler.as_str() {
            "uniform" => crate::fl::SchedulerKind::Uniform,
            "greedy" => crate::fl::SchedulerKind::Greedy,
            "greedy-median" => crate::fl::SchedulerKind::GreedyMedianBase,
            other => bail!("unknown scheduler {other:?}"),
        })
    }

    pub fn arena_config(&self) -> crate::tensor::ArenaConfig {
        crate::tensor::ArenaConfig { sparse_spill_frac: self.sparse_spill_frac }
    }

    pub fn source_config(&self) -> crate::data::SourceConfig {
        crate::data::SourceConfig {
            cache_users: self.cache_users,
            prefetch_depth: self.prefetch_depth,
        }
    }

    pub fn open_options(&self) -> crate::data::OpenOptions {
        crate::data::OpenOptions { mmap: self.store_mmap }
    }

    /// Parsed `engine.store_compression` (write-side only).
    pub fn store_compression(&self) -> Result<crate::data::Compression> {
        if self.store_compression.is_empty() {
            return Ok(crate::data::Compression::None);
        }
        self.store_compression.parse()
    }

    pub fn dispatch_spec(&self) -> Result<crate::fl::DispatchSpec> {
        let mode = match self.dispatcher.as_str() {
            "static" => crate::fl::DispatchMode::Static,
            "work-stealing" | "worksteal" => crate::fl::DispatchMode::WorkStealing,
            "async" => crate::fl::DispatchMode::Async,
            "socket" => crate::fl::DispatchMode::Socket,
            other => {
                bail!("unknown dispatcher {other:?} (static | work-stealing | async | socket)")
            }
        };
        Ok(crate::fl::DispatchSpec {
            mode,
            max_staleness: self.max_staleness,
            buffer_frac: self.buffer_frac,
            // socket dispatch always folds through the reorder buffer (a
            // zero window would deadlock the distributed fold loop)
            reorder_window: if mode == crate::fl::DispatchMode::Socket {
                self.reorder_window.max(1)
            } else {
                self.reorder_window
            },
        })
    }

    /// The runtime scenario spec: the configured one, or the inert
    /// all-off spec when `scenario` is unset.
    pub fn scenario_spec(&self) -> crate::fl::device::ScenarioSpec {
        self.scenario.unwrap_or_default()
    }

    /// Code width of the configured wire quantization: `None` for the
    /// exact f32 wire, `Some(16)` for binary16, `Some(8)` for
    /// int8-with-scale.
    pub fn wire_quantization_bits(&self) -> Result<Option<u8>> {
        Ok(match self.wire_quantization.as_str() {
            "" | "none" => None,
            "f16" => Some(16),
            "int8" => Some(8),
            other => bail!("unknown wire quantization {other:?} (none | f16 | int8)"),
        })
    }

    // ------------------------------------------------------------------
    // JSON round trip
    // ------------------------------------------------------------------

    pub fn to_json(&self) -> String {
        let d = &self.dataset;
        let a = &self.algorithm;
        let c = &self.central_opt;
        let p = &self.privacy;
        let mut top = vec![
            ("name", s(self.name.clone())),
            ("model", s(self.model.clone())),
            (
                "dataset",
                obj(vec![
                    ("kind", s(d.kind.clone())),
                    ("num_users", num(d.num_users as f64)),
                    ("per_user", num(d.per_user as f64)),
                    (
                        "dirichlet_alpha",
                        d.dirichlet_alpha.map(num).unwrap_or(Value::Null),
                    ),
                    ("seed", num(d.seed as f64)),
                ]),
            ),
            (
                "algorithm",
                obj(vec![("kind", s(a.kind.clone())), ("mu", num(a.mu))]),
            ),
            (
                "central_opt",
                obj(vec![
                    ("kind", s(c.kind.clone())),
                    ("lr", num(c.lr)),
                    ("warmup", num(c.warmup as f64)),
                    ("adaptivity", num(c.adaptivity)),
                    ("beta1", num(c.beta1)),
                    ("beta2", num(c.beta2)),
                ]),
            ),
            (
                "privacy",
                obj(vec![
                    ("mechanism", s(p.mechanism.clone())),
                    ("accountant", s(p.accountant.clone())),
                    ("clip_bound", num(p.clip_bound)),
                    ("epsilon", num(p.epsilon)),
                    ("delta", num(p.delta)),
                    ("population_m", num(p.population_m)),
                    ("noise_cohort", num(p.noise_cohort)),
                    ("sparse_top_k", num(p.sparse_top_k as f64)),
                ]),
            ),
            (
                "run",
                obj(vec![
                    ("iterations", num(self.iterations as f64)),
                    ("cohort_size", num(self.cohort_size as f64)),
                    ("val_cohort_size", num(self.val_cohort_size as f64)),
                    ("eval_every", num(self.eval_every as f64)),
                    ("local_epochs", num(self.local_epochs as f64)),
                    ("local_batch", num(self.local_batch as f64)),
                    ("local_lr", num(self.local_lr)),
                    ("local_max_steps", num(self.local_max_steps as f64)),
                ]),
            ),
            (
                "engine",
                obj(vec![
                    ("num_workers", num(self.num_workers as f64)),
                    ("scheduler", s(self.scheduler.clone())),
                    ("dispatcher", s(self.dispatcher.clone())),
                    ("max_staleness", num(self.max_staleness as f64)),
                    ("buffer_frac", num(self.buffer_frac)),
                    ("reorder_window", num(self.reorder_window as f64)),
                    ("sparse_spill_frac", num(self.sparse_spill_frac)),
                    ("data_store", s(self.data_store.clone())),
                    ("cache_users", num(self.cache_users as f64)),
                    ("prefetch_depth", num(self.prefetch_depth as f64)),
                    ("store_mmap", Value::Bool(self.store_mmap)),
                    ("store_compression", s(self.store_compression.clone())),
                    ("wire_quantization", s(self.wire_quantization.clone())),
                    ("fold_tree", Value::Bool(self.fold_tree)),
                    ("noise_threads", num(self.noise_threads as f64)),
                    ("seed", num(self.seed as f64)),
                ]),
            ),
        ];
        // the scenario key is omitted entirely when unset, so configs
        // written before (and runs without) the device-realism layer
        // keep a byte-identical JSON form
        if let Some(sc) = &self.scenario {
            top.push((
                "scenario",
                obj(vec![
                    ("churn", num(sc.churn)),
                    ("diurnal", num(sc.diurnal)),
                    ("dropout_hazard", num(sc.dropout_hazard)),
                    ("speed_tiers", num(sc.speed_tiers as f64)),
                ]),
            ));
        }
        obj(top).to_string_pretty()
    }

    pub fn from_json(text: &str) -> Result<Config> {
        let v = Value::parse(text).context("parsing config JSON")?;
        let d = v.req("dataset")?;
        let a = v.req("algorithm")?;
        let c = v.req("central_opt")?;
        let p = v.req("privacy")?;
        let r = v.req("run")?;
        let e = v.req("engine")?;
        Ok(Config {
            name: v.req("name")?.as_str()?.to_string(),
            model: v.req("model")?.as_str()?.to_string(),
            dataset: DatasetConfig {
                kind: d.req("kind")?.as_str()?.to_string(),
                num_users: d.req("num_users")?.as_usize()?,
                per_user: d.req("per_user")?.as_usize()?,
                dirichlet_alpha: match d.get("dirichlet_alpha") {
                    Some(Value::Null) | None => None,
                    Some(x) => Some(x.as_f64()?),
                },
                seed: d.req("seed")?.as_u64()?,
            },
            algorithm: AlgorithmConfig {
                kind: a.req("kind")?.as_str()?.to_string(),
                mu: a.req("mu")?.as_f64()?,
            },
            central_opt: CentralOptConfig {
                kind: c.req("kind")?.as_str()?.to_string(),
                lr: c.req("lr")?.as_f64()?,
                warmup: c.req("warmup")?.as_u64()?,
                adaptivity: c.req("adaptivity")?.as_f64()?,
                beta1: c.req("beta1")?.as_f64()?,
                beta2: c.req("beta2")?.as_f64()?,
            },
            privacy: PrivacyConfig {
                mechanism: p.req("mechanism")?.as_str()?.to_string(),
                accountant: p.req("accountant")?.as_str()?.to_string(),
                clip_bound: p.req("clip_bound")?.as_f64()?,
                epsilon: p.req("epsilon")?.as_f64()?,
                delta: p.req("delta")?.as_f64()?,
                population_m: p.req("population_m")?.as_f64()?,
                noise_cohort: p.req("noise_cohort")?.as_f64()?,
                // optional for configs written before sparse statistics
                sparse_top_k: match p.get("sparse_top_k") {
                    Some(x) => x.as_usize()?,
                    None => 0,
                },
            },
            iterations: r.req("iterations")?.as_u64()?,
            cohort_size: r.req("cohort_size")?.as_usize()?,
            val_cohort_size: r.req("val_cohort_size")?.as_usize()?,
            eval_every: r.req("eval_every")?.as_u64()?,
            local_epochs: r.req("local_epochs")?.as_usize()?,
            local_batch: r.req("local_batch")?.as_usize()?,
            local_lr: r.req("local_lr")?.as_f64()?,
            local_max_steps: r.req("local_max_steps")?.as_usize()?,
            num_workers: e.req("num_workers")?.as_usize()?,
            scheduler: e.req("scheduler")?.as_str()?.to_string(),
            // optional for configs written before the dispatch engine
            dispatcher: match e.get("dispatcher") {
                Some(x) => x.as_str()?.to_string(),
                None => "static".into(),
            },
            max_staleness: match e.get("max_staleness") {
                Some(x) => x.as_u64()?,
                None => 2,
            },
            buffer_frac: match e.get("buffer_frac") {
                Some(x) => x.as_f64()?,
                None => 0.5,
            },
            // optional for configs written before deterministic replay /
            // the sparse arena
            reorder_window: match e.get("reorder_window") {
                Some(x) => x.as_usize()?,
                None => 0,
            },
            sparse_spill_frac: match e.get("sparse_spill_frac") {
                Some(x) => x.as_f64()?,
                None => crate::tensor::ArenaConfig::default().sparse_spill_frac,
            },
            // optional for configs written before the out-of-core store
            data_store: match e.get("data_store") {
                Some(x) => x.as_str()?.to_string(),
                None => String::new(),
            },
            cache_users: match e.get("cache_users") {
                Some(x) => x.as_usize()?,
                None => crate::data::SourceConfig::default().cache_users,
            },
            prefetch_depth: match e.get("prefetch_depth") {
                Some(x) => x.as_usize()?,
                None => crate::data::SourceConfig::default().prefetch_depth,
            },
            // optional for configs written before mmap/compressed stores
            store_mmap: match e.get("store_mmap") {
                Some(x) => x.as_bool()?,
                None => true,
            },
            store_compression: match e.get("store_compression") {
                Some(x) => x.as_str()?.to_string(),
                None => "none".into(),
            },
            // optional for configs written before wire quantization /
            // the tree fold
            wire_quantization: match e.get("wire_quantization") {
                Some(x) => x.as_str()?.to_string(),
                None => "none".into(),
            },
            fold_tree: match e.get("fold_tree") {
                Some(x) => x.as_bool()?,
                None => false,
            },
            // optional for configs written before the counter noise engine
            noise_threads: match e.get("noise_threads") {
                Some(x) => x.as_usize()?,
                None => 0,
            },
            // optional top-level section: absent for configs written
            // before the device-realism scenario layer (and for every
            // run with the layer off)
            scenario: match v.get("scenario") {
                Some(Value::Null) | None => None,
                Some(sc) => Some(crate::fl::device::ScenarioSpec {
                    churn: match sc.get("churn") {
                        Some(x) => x.as_f64()?,
                        None => 0.0,
                    },
                    diurnal: match sc.get("diurnal") {
                        Some(x) => x.as_f64()?,
                        None => 0.0,
                    },
                    dropout_hazard: match sc.get("dropout_hazard") {
                        Some(x) => x.as_f64()?,
                        None => 0.0,
                    },
                    speed_tiers: match sc.get("speed_tiers") {
                        Some(x) => x.as_u64()? as u32,
                        None => 0,
                    },
                }),
            },
            seed: e.req("seed")?.as_u64()?,
        })
    }
}

// ----------------------------------------------------------------------
// Presets — paper Tables 8–11
// ----------------------------------------------------------------------

fn central_dp(clip: f64, noise_cohort: f64) -> PrivacyConfig {
    // Table 7: ε = 2, δ = 1/M, M = 1e6
    PrivacyConfig {
        mechanism: "gaussian".into(),
        accountant: "pld".into(),
        clip_bound: clip,
        epsilon: 2.0,
        delta: 1e-6,
        population_m: 1e6,
        noise_cohort,
        sparse_top_k: 0,
    }
}

/// CIFAR10 benchmarks (Table 8): 1500 iterations, central SGD lr 1.0,
/// C = 50, 1 local epoch, batch 10, 50 datapoints/user, eval every 10.
fn cifar10(iid: bool, dp: bool) -> Config {
    Config {
        name: format!(
            "cifar10{}{}",
            if iid { "-iid" } else { "-noniid" },
            if dp { "-dp" } else { "" }
        ),
        model: "cnn_c10".into(),
        dataset: DatasetConfig {
            kind: "cifar".into(),
            num_users: 1000, // 50000/50
            per_user: 50,
            dirichlet_alpha: if iid { None } else { Some(0.1) },
            seed: 100,
        },
        algorithm: AlgorithmConfig { kind: "fedavg".into(), mu: 0.0 },
        central_opt: CentralOptConfig {
            kind: "sgd".into(),
            lr: 1.0,
            warmup: 0,
            adaptivity: 0.0,
            beta1: 0.0,
            beta2: 0.0,
        },
        privacy: if dp { central_dp(0.4, 1000.0) } else { PrivacyConfig::none() },
        iterations: 1500,
        cohort_size: 50,
        val_cohort_size: 0,
        eval_every: 10,
        local_epochs: 1,
        local_batch: 10,
        local_lr: 0.1,
        local_max_steps: 0,
        num_workers: 1,
        scheduler: "greedy-median".into(),
        dispatcher: "static".into(),
        max_staleness: 2,
        buffer_frac: 0.5,
        reorder_window: 0,
        sparse_spill_frac: 0.25,
        data_store: String::new(),
        cache_users: 512,
        prefetch_depth: 8,
        store_mmap: true,
        store_compression: "none".into(),
        wire_quantization: "none".into(),
        fold_tree: false,
        noise_threads: 0,
        scenario: None,
        seed: 0,
    }
}

/// StackOverflow benchmarks (Table 9): 2000 iterations, FedAdam (lr 0.1,
/// warmup 50, τ = 0.1), C = 400, local lr 0.3, batch 16, max 64
/// sentences/user.
fn stackoverflow(dp: bool) -> Config {
    Config {
        name: format!("stackoverflow{}", if dp { "-dp" } else { "" }),
        model: "lm_so".into(),
        dataset: DatasetConfig {
            kind: "text".into(),
            num_users: 20_000, // natural user keys; SO has ~342k train users
            per_user: 0,       // natural heavy-tailed sizes, capped at 64
            dirichlet_alpha: None,
            seed: 200,
        },
        algorithm: AlgorithmConfig { kind: "fedavg".into(), mu: 0.0 },
        central_opt: CentralOptConfig {
            kind: "adam".into(),
            lr: 0.1,
            warmup: 50,
            adaptivity: 0.1,
            beta1: 0.9,
            beta2: 0.99,
        },
        privacy: if dp { central_dp(1.0, 5000.0) } else { PrivacyConfig::none() },
        iterations: 2000,
        cohort_size: 400,
        val_cohort_size: 0,
        eval_every: 20,
        local_epochs: 1,
        local_batch: 16,
        local_lr: 0.3,
        local_max_steps: 0,
        num_workers: 1,
        scheduler: "greedy-median".into(),
        dispatcher: "static".into(),
        max_staleness: 2,
        buffer_frac: 0.5,
        reorder_window: 0,
        sparse_spill_frac: 0.25,
        data_store: String::new(),
        cache_users: 512,
        prefetch_depth: 8,
        store_mmap: true,
        store_compression: "none".into(),
        wire_quantization: "none".into(),
        fold_tree: false,
        noise_threads: 0,
        scenario: None,
        seed: 0,
    }
}

/// FLAIR benchmarks (Table 10): 5000 iterations, FedAdam lr 0.1, τ = 0.1,
/// C = 200, 2 local epochs, batch 16, max 512 images/user.
fn flair(iid: bool, dp: bool) -> Config {
    Config {
        name: format!(
            "flair{}{}",
            if iid { "-iid" } else { "" },
            if dp { "-dp" } else { "" }
        ),
        model: "mlp_flair".into(),
        dataset: DatasetConfig {
            kind: "flair".into(),
            num_users: 5_000, // FLAIR: 41k users; heavy-tailed sizes
            per_user: if iid { 50 } else { 0 },
            dirichlet_alpha: if iid { None } else { Some(0.3) },
            seed: 300,
        },
        algorithm: AlgorithmConfig { kind: "fedavg".into(), mu: 0.0 },
        central_opt: CentralOptConfig {
            kind: "adam".into(),
            lr: 0.1,
            warmup: 0,
            adaptivity: 0.1,
            beta1: 0.9,
            beta2: 0.99,
        },
        privacy: if dp { central_dp(0.1, 5000.0) } else { PrivacyConfig::none() },
        iterations: 5000,
        cohort_size: 200,
        val_cohort_size: 0,
        eval_every: 20,
        local_epochs: 2,
        local_batch: 16,
        local_lr: 0.01,
        local_max_steps: 0,
        num_workers: 1,
        scheduler: "greedy-median".into(),
        dispatcher: "static".into(),
        max_staleness: 2,
        buffer_frac: 0.5,
        reorder_window: 0,
        sparse_spill_frac: 0.25,
        data_store: String::new(),
        cache_users: 512,
        prefetch_depth: 8,
        store_mmap: true,
        store_compression: "none".into(),
        wire_quantization: "none".into(),
        fold_tree: false,
        noise_threads: 0,
        scenario: None,
        seed: 0,
    }
}

/// LLM benchmarks (Table 11): 1000 iterations, FedAdam lr 0.01, τ = 1e-4,
/// C = 100, local batch 4, LoRA r=8 adapters only.
fn llm(flavor: &str, dp: bool) -> Config {
    Config {
        name: format!("llm-{flavor}{}", if dp { "-dp" } else { "" }),
        model: "lora_llm".into(),
        dataset: DatasetConfig {
            kind: format!("instruct-{flavor}"),
            num_users: 3000,
            per_user: if flavor == "sa" { 16 } else { 0 }, // SA: Poisson(16)
            dirichlet_alpha: None,
            seed: 400,
        },
        algorithm: AlgorithmConfig { kind: "fedavg".into(), mu: 0.0 },
        central_opt: CentralOptConfig {
            kind: "adam".into(),
            lr: 0.01,
            warmup: 0,
            adaptivity: 1e-4,
            beta1: 0.9,
            beta2: 0.99,
        },
        privacy: if dp { central_dp(0.1, 5000.0) } else { PrivacyConfig::none() },
        iterations: 1000,
        cohort_size: 100,
        val_cohort_size: 0,
        eval_every: 10,
        local_epochs: 1,
        local_batch: 4,
        local_lr: if flavor == "sa" { 0.01 } else { 0.1 },
        local_max_steps: 0,
        num_workers: 1,
        scheduler: "greedy-median".into(),
        dispatcher: "static".into(),
        max_staleness: 2,
        buffer_frac: 0.5,
        reorder_window: 0,
        sparse_spill_frac: 0.25,
        data_store: String::new(),
        cache_users: 512,
        prefetch_depth: 8,
        store_mmap: true,
        store_compression: "none".into(),
        wire_quantization: "none".into(),
        fold_tree: false,
        noise_threads: 0,
        scenario: None,
        seed: 0,
    }
}

/// Every named preset of the benchmark suite.
pub fn preset_names() -> Vec<&'static str> {
    vec![
        "cifar10-iid",
        "cifar10-noniid",
        "cifar10-iid-dp",
        "cifar10-noniid-dp",
        "stackoverflow",
        "stackoverflow-dp",
        "flair-iid",
        "flair",
        "flair-iid-dp",
        "flair-dp",
        "llm-sa",
        "llm-aya",
        "llm-oa",
        "llm-sa-dp",
        "llm-aya-dp",
        "llm-oa-dp",
    ]
}

pub fn preset(name: &str) -> Result<Config> {
    Ok(match name {
        "cifar10-iid" => cifar10(true, false),
        "cifar10-noniid" => cifar10(false, false),
        "cifar10-iid-dp" => cifar10(true, true),
        "cifar10-noniid-dp" => cifar10(false, true),
        "stackoverflow" => stackoverflow(false),
        "stackoverflow-dp" => stackoverflow(true),
        "flair-iid" => flair(true, false),
        "flair" => flair(false, false),
        "flair-iid-dp" => flair(true, true),
        "flair-dp" => flair(false, true),
        "llm-sa" => llm("sa", false),
        "llm-aya" => llm("aya", false),
        "llm-oa" => llm("oa", false),
        "llm-sa-dp" => llm("sa", true),
        "llm-aya-dp" => llm("aya", true),
        "llm-oa-dp" => llm("oa", true),
        other => bail!("unknown preset {other:?} (see `pfl presets`)"),
    })
}

/// Dump all presets as a JSON array (the `pfl presets --dump` command —
/// the analogue of the paper's hyperparameter tables 8–11).
pub fn dump_presets() -> String {
    let items: Vec<Value> = preset_names()
        .iter()
        .map(|n| Value::parse(&preset(n).unwrap().to_json()).unwrap())
        .collect();
    arr(items).to_string_pretty()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_presets_construct_and_roundtrip() {
        for name in preset_names() {
            let c = preset(name).unwrap();
            let json = c.to_json();
            let back = Config::from_json(&json).unwrap();
            assert_eq!(c, back, "{name} did not round-trip");
        }
    }

    #[test]
    fn paper_hyperparameters_table8() {
        let c = preset("cifar10-iid").unwrap();
        assert_eq!(c.iterations, 1500);
        assert_eq!(c.cohort_size, 50);
        assert_eq!(c.local_batch, 10);
        assert_eq!(c.local_lr, 0.1);
        assert_eq!(c.central_opt.lr, 1.0);
        assert_eq!(c.dataset.per_user, 50);
        let dp = preset("cifar10-iid-dp").unwrap();
        assert_eq!(dp.privacy.clip_bound, 0.4);
        assert_eq!(dp.privacy.noise_cohort, 1000.0);
        assert_eq!(dp.privacy.epsilon, 2.0);
    }

    #[test]
    fn paper_hyperparameters_table9_10() {
        let so = preset("stackoverflow").unwrap();
        assert_eq!(so.iterations, 2000);
        assert_eq!(so.cohort_size, 400);
        assert_eq!(so.central_opt.warmup, 50);
        assert_eq!(so.central_opt.adaptivity, 0.1);
        let fl = preset("flair-dp").unwrap();
        assert_eq!(fl.iterations, 5000);
        assert_eq!(fl.local_epochs, 2);
        assert_eq!(fl.privacy.clip_bound, 0.1);
        assert_eq!(fl.privacy.noise_cohort, 5000.0);
    }

    #[test]
    fn noniid_uses_dirichlet() {
        assert_eq!(preset("cifar10-noniid").unwrap().dataset.dirichlet_alpha, Some(0.1));
        assert_eq!(preset("cifar10-iid").unwrap().dataset.dirichlet_alpha, None);
    }

    #[test]
    fn scaling_preserves_structure() {
        let c = preset("cifar10-iid").unwrap().scaled(0.1);
        assert_eq!(c.iterations, 150);
        assert_eq!(c.cohort_size, 5);
        assert_eq!(c.dataset.num_users, 100);
        // structural values unchanged
        assert_eq!(c.local_batch, 10);
        assert_eq!(c.local_epochs, 1);
        assert_eq!(c.privacy.is_none(), true);
        // scale 1.0 is identity
        let d = preset("cifar10-iid").unwrap().scaled(1.0);
        assert_eq!(d.iterations, 1500);
    }

    #[test]
    fn dump_is_valid_json() {
        let v = Value::parse(&dump_presets()).unwrap();
        assert_eq!(v.as_arr().unwrap().len(), preset_names().len());
    }

    #[test]
    fn scheduler_kind_parses() {
        let mut c = preset("cifar10-iid").unwrap();
        assert!(c.scheduler_kind().is_ok());
        c.scheduler = "bogus".into();
        assert!(c.scheduler_kind().is_err());
    }

    #[test]
    fn dispatch_spec_parses_and_defaults() {
        let mut c = preset("cifar10-iid").unwrap();
        assert_eq!(c.dispatch_spec().unwrap().mode, crate::fl::DispatchMode::Static);
        c.dispatcher = "work-stealing".into();
        assert_eq!(c.dispatch_spec().unwrap().mode, crate::fl::DispatchMode::WorkStealing);
        c.dispatcher = "async".into();
        c.max_staleness = 3;
        c.buffer_frac = 0.25;
        let spec = c.dispatch_spec().unwrap();
        assert_eq!(spec.mode, crate::fl::DispatchMode::Async);
        assert_eq!(spec.max_staleness, 3);
        assert_eq!(spec.buffer_frac, 0.25);
        // socket dispatch clamps the replay window to >= 1 (a zero
        // window would deadlock the distributed fold loop)
        c.dispatcher = "socket".into();
        c.reorder_window = 0;
        let spec = c.dispatch_spec().unwrap();
        assert_eq!(spec.mode, crate::fl::DispatchMode::Socket);
        assert_eq!(spec.reorder_window, 1);
        c.reorder_window = 8;
        assert_eq!(c.dispatch_spec().unwrap().reorder_window, 8);
        c.dispatcher = "bogus".into();
        assert!(c.dispatch_spec().is_err());
    }

    #[test]
    fn old_configs_without_dispatch_fields_parse() {
        // engine section written before the dispatch engine / sparse
        // arena / deterministic replay / out-of-core store existed
        let json = preset("cifar10-iid").unwrap().to_json();
        let stripped = json
            .lines()
            .filter(|l| {
                !l.contains("dispatcher")
                    && !l.contains("max_staleness")
                    && !l.contains("buffer_frac")
                    && !l.contains("reorder_window")
                    && !l.contains("sparse_spill_frac")
                    && !l.contains("data_store")
                    && !l.contains("cache_users")
                    && !l.contains("prefetch_depth")
                    && !l.contains("store_mmap")
                    && !l.contains("store_compression")
                    && !l.contains("wire_quantization")
                    && !l.contains("fold_tree")
                    && !l.contains("noise_threads")
            })
            .collect::<Vec<_>>()
            .join("\n");
        let parsed = Config::from_json(&stripped).unwrap();
        assert_eq!(parsed.dispatcher, "static");
        assert_eq!(parsed.max_staleness, 2);
        assert_eq!(parsed.buffer_frac, 0.5);
        assert_eq!(parsed.reorder_window, 0);
        assert_eq!(parsed.sparse_spill_frac, 0.25);
        assert_eq!(parsed.data_store, "");
        assert_eq!(parsed.cache_users, 512);
        assert_eq!(parsed.prefetch_depth, 8);
        assert!(parsed.store_mmap, "pre-mmap configs default to mmap");
        assert_eq!(parsed.store_compression, "none");
        assert_eq!(parsed.wire_quantization, "none");
        assert!(!parsed.fold_tree);
        assert_eq!(parsed.noise_threads, 0, "pre-engine configs keep the legacy noise path");
    }

    #[test]
    fn quantize_and_fold_tree_knobs_roundtrip() {
        let mut c = preset("cifar10-iid").unwrap();
        assert_eq!(c.wire_quantization_bits().unwrap(), None);
        assert_eq!(c.noise_threads, 0, "presets default to the legacy noise path");
        c.wire_quantization = "int8".into();
        c.fold_tree = true;
        c.noise_threads = 4;
        let back = Config::from_json(&c.to_json()).unwrap();
        assert_eq!(back.wire_quantization, "int8");
        assert!(back.fold_tree);
        assert_eq!(back.noise_threads, 4);
        assert_eq!(back.wire_quantization_bits().unwrap(), Some(8));
        c.wire_quantization = "f16".into();
        assert_eq!(c.wire_quantization_bits().unwrap(), Some(16));
        c.wire_quantization = "int4".into();
        assert!(c.wire_quantization_bits().is_err());
    }

    #[test]
    fn data_store_knobs_roundtrip() {
        let mut c = preset("cifar10-iid").unwrap();
        assert!(c.data_store.is_empty(), "presets default to lazy generation");
        c.data_store = "/tmp/cifar-store".into();
        c.cache_users = 64;
        c.prefetch_depth = 3;
        c.store_mmap = false;
        c.store_compression = "shuffle-lz".into();
        let back = Config::from_json(&c.to_json()).unwrap();
        assert_eq!(back.data_store, "/tmp/cifar-store");
        assert_eq!(back.source_config().cache_users, 64);
        assert_eq!(back.source_config().prefetch_depth, 3);
        assert!(!back.open_options().mmap);
        assert_eq!(back.store_compression().unwrap(), crate::data::Compression::ShuffleLz);
        // and the parse helper rejects junk
        c.store_compression = "zstd".into();
        assert!(c.store_compression().is_err());
    }

    #[test]
    fn scenario_roundtrips_and_defaults_to_none() {
        let mut c = preset("cifar10-iid").unwrap();
        assert_eq!(c.scenario, None, "presets ship without device realism");
        assert!(!c.scenario_spec().enabled());
        // None omits the key entirely, so old readers see an unchanged file
        assert!(!c.to_json().contains("scenario"));
        c.scenario = Some(crate::fl::device::ScenarioSpec {
            churn: 0.2,
            diurnal: 0.5,
            dropout_hazard: 0.1,
            speed_tiers: 3,
        });
        let json = c.to_json();
        assert!(json.contains("scenario"));
        let back = Config::from_json(&json).unwrap();
        assert_eq!(back, c, "scenario section did not round-trip");
        assert!(back.scenario_spec().enabled());
        // pre-scenario configs (no key at all) parse to None
        let old = preset("cifar10-iid").unwrap().to_json();
        let parsed = Config::from_json(&old).unwrap();
        assert_eq!(parsed.scenario, None);
    }

    #[test]
    fn replay_and_arena_knobs_roundtrip() {
        let mut c = preset("cifar10-iid").unwrap();
        c.dispatcher = "async".into();
        c.reorder_window = 8;
        c.sparse_spill_frac = 0.1;
        let back = Config::from_json(&c.to_json()).unwrap();
        assert_eq!(back.reorder_window, 8);
        assert_eq!(back.sparse_spill_frac, 0.1);
        assert_eq!(back.dispatch_spec().unwrap().reorder_window, 8);
        assert_eq!(back.arena_config().sparse_spill_frac, 0.1);
    }
}
