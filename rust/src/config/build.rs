//! The launcher glue: turn a [`Config`] into live objects — dataset,
//! algorithm, DP postprocessors (with accountant-calibrated noise),
//! model factory and a ready [`SimulatedBackend`].

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use super::{Config, DatasetConfig};
use crate::baselines::OverheadProfile;
use crate::data::{
    FederatedDataset, GeneratorSource, InstructFlavor, ShardedStore, StoreSource, SynthCifar,
    SynthFlair, SynthInstruct, SynthTabular, SynthText, UserDataSource,
};
use crate::fl::algorithm::RunSpec;
use crate::fl::backend::{BackendBuilder, RunParams, SimulatedBackend};
use crate::fl::callbacks::CentralEvalCallback;
use crate::fl::central_opt::{Adam, CentralOptimizer, Sgd};
use crate::fl::context::LocalParams;
#[cfg(feature = "hlo")]
use crate::fl::model::HloModel;
use crate::fl::postprocess::Postprocessor;
use crate::fl::worker::{ModelFactory, WorkerShared};
use crate::fl::{AdaFedProx, FedAvg, FedProx, FederatedAlgorithm, Scaffold};
use crate::privacy::{accountant_by_name, mechanisms::mechanism_by_name, AccountantParams};
use crate::runtime::Manifest;
#[cfg(feature = "hlo")]
use crate::runtime::Runtime;

/// Feature width of the `tabular` dataset / `linear` model pairing —
/// the PJRT-free configuration the distributed tests and CI smoke runs
/// use (the model carries `LINEAR_DIM + 1` parameters).
pub const LINEAR_DIM: usize = 8;

pub fn build_dataset(cfg: &DatasetConfig) -> Result<Arc<dyn FederatedDataset>> {
    Ok(match cfg.kind.as_str() {
        "tabular" => Arc::new(SynthTabular::new(
            cfg.num_users,
            cfg.per_user.max(1),
            LINEAR_DIM,
            cfg.seed,
        )),
        "cifar" => Arc::new(SynthCifar::new(
            cfg.num_users,
            cfg.per_user.max(1),
            cfg.dirichlet_alpha,
            cfg.seed,
        )),
        "flair" => Arc::new(SynthFlair::new(cfg.num_users, cfg.dirichlet_alpha, cfg.seed)),
        "text" => Arc::new(SynthText::new(cfg.num_users, cfg.seed)),
        "instruct-sa" => Arc::new(SynthInstruct::new(
            InstructFlavor::Alpaca,
            cfg.num_users * 16,
            cfg.seed,
        )),
        "instruct-aya" => Arc::new(SynthInstruct::new(
            InstructFlavor::Aya,
            cfg.num_users * 12,
            cfg.seed,
        )),
        "instruct-oa" => Arc::new(SynthInstruct::new(
            InstructFlavor::OpenAssistant,
            cfg.num_users * 8,
            cfg.seed,
        )),
        other => bail!("unknown dataset kind {other:?}"),
    })
}

fn build_central_opt(cfg: &Config) -> Result<Box<dyn CentralOptimizer>> {
    Ok(match cfg.central_opt.kind.as_str() {
        "sgd" => Box::new(Sgd),
        "adam" => Box::new(Adam::new(
            cfg.central_opt.beta1,
            cfg.central_opt.beta2,
            cfg.central_opt.adaptivity,
        )),
        other => bail!("unknown central optimizer {other:?}"),
    })
}

pub fn run_spec(cfg: &Config, population: usize) -> RunSpec {
    RunSpec {
        iterations: cfg.iterations,
        cohort_size: cfg.cohort_size,
        val_cohort_size: cfg.val_cohort_size,
        eval_every: cfg.eval_every,
        local: LocalParams {
            epochs: cfg.local_epochs,
            batch_size: cfg.local_batch,
            lr: cfg.local_lr as f32,
            mu: 0.0,
            max_steps: cfg.local_max_steps,
        },
        central_lr: cfg.central_opt.lr,
        central_lr_warmup: cfg.central_opt.warmup,
        population,
        seed: cfg.seed,
        // invalid dispatcher strings surface in build_backend; contexts
        // fall back to the engine default here
        dispatch: cfg.dispatch_spec().unwrap_or_default(),
    }
}

pub fn build_algorithm(cfg: &Config, population: usize) -> Result<Arc<dyn FederatedAlgorithm>> {
    let spec = run_spec(cfg, population);
    let opt = build_central_opt(cfg)?;
    Ok(match cfg.algorithm.kind.as_str() {
        "fedavg" => Arc::new(FedAvg::new(spec, opt)),
        "fedprox" => Arc::new(FedProx::new(spec, cfg.algorithm.mu as f32, opt)),
        "adafedprox" => Arc::new(AdaFedProx::new(spec, opt)),
        "scaffold" => Arc::new(Scaffold::new(spec, opt)),
        other => bail!("unknown algorithm {other:?}"),
    })
}

/// Calibrate the noise multiplier for the configured (ε, δ, T) budget
/// with sampling rate q = C̃/M (paper App. C.4), via the configured
/// accountant.
pub fn calibrated_noise_multiplier(cfg: &Config) -> Result<f64> {
    if cfg.privacy.is_none() {
        return Ok(0.0);
    }
    let acc = accountant_by_name(&cfg.privacy.accountant)?;
    let params = AccountantParams {
        sampling_rate: (cfg.privacy.noise_cohort / cfg.privacy.population_m).min(1.0),
        delta: cfg.privacy.delta,
        steps: cfg.iterations,
    };
    acc.calibrate_sigma(cfg.privacy.epsilon, &params)
        .context("noise calibration")
}

/// Build the DP postprocessor chain: the mechanism owns clip bound and
/// noise, with the noise-cohort rescaling r = C/C̃ applied on top of the
/// calibrated multiplier (σ is per-user-sum; the mechanism divides by C̃
/// implicitly through r when the simulation averages over C).
///
/// With `sparse_top_k > 0`, a top-k sparsifier runs *before* the DP clip
/// (so clipping remains the last local step and the sensitivity bound is
/// unaffected) and the surviving coordinates travel as sparse statistics.
///
/// With `wire_quantization != "none"`, an error-feedback
/// [`crate::fl::postprocess::WireQuantizer`] runs *after* the mechanism
/// (= last in local order): the DP-noised update is what gets encoded,
/// so the wire narrows without touching the sensitivity bound.
pub fn build_postprocessors(cfg: &Config) -> Result<Vec<Box<dyn Postprocessor>>> {
    let mut pps: Vec<Box<dyn Postprocessor>> = Vec::new();
    if cfg.privacy.sparse_top_k > 0 {
        pps.push(Box::new(crate::fl::postprocess::TopKSparsifier {
            k: cfg.privacy.sparse_top_k,
            emit_sparse: true,
        }));
    }
    let quant_bits = cfg.wire_quantization_bits()?;
    if !cfg.privacy.is_none() {
        let sigma = calibrated_noise_multiplier(cfg)?;
        let r = if cfg.privacy.noise_cohort > 0.0 {
            cfg.cohort_size as f64 / cfg.privacy.noise_cohort
        } else {
            1.0
        };
        pps.push(mechanism_by_name(
            &cfg.privacy.mechanism,
            cfg.privacy.clip_bound as f32,
            sigma,
            r,
        )?);
    }
    if let Some(bits) = quant_bits {
        pps.push(Box::new(crate::fl::postprocess::WireQuantizer::new(bits, true)));
    }
    Ok(pps)
}

/// Model factory: each worker constructs its own PJRT runtime + model
/// from the artifacts directory (one resident model per worker).
#[cfg(feature = "hlo")]
pub fn hlo_factory(model: String, init_seed: u64) -> ModelFactory {
    Arc::new(move |_worker| {
        let rt = std::rc::Rc::new(Runtime::new(Manifest::load_default()?)?);
        let m = HloModel::new_owned(rt, &model, init_seed)?;
        Ok(Box::new(m) as Box<dyn crate::fl::Model>)
    })
}

/// Without the `hlo` feature the NN-model factory is a stub that errors
/// at model-construction time (the first round), so the launcher and
/// experiment harness stay buildable on runners without the PJRT stack.
#[cfg(not(feature = "hlo"))]
pub fn hlo_factory(model: String, _init_seed: u64) -> ModelFactory {
    Arc::new(move |_worker| {
        anyhow::bail!(
            "model {model:?} needs the PJRT runtime; rebuild with `--features hlo`"
        )
    })
}

/// The model factory for a config: the pure-Rust [`crate::fl::LinearModel`]
/// for `model = "linear"` (no PJRT anywhere — what the distributed tests
/// and CI smoke runs use), the HLO factory for the NN zoo otherwise.
pub fn model_factory(cfg: &Config) -> ModelFactory {
    if cfg.model == "linear" {
        Arc::new(|_worker| {
            Ok(Box::new(crate::fl::LinearModel::new(LINEAR_DIM)) as Box<dyn crate::fl::Model>)
        })
    } else {
        hlo_factory(cfg.model.clone(), cfg.seed ^ 0x1817)
    }
}

/// Initial central parameters for the configured model.
pub fn init_params(cfg: &Config) -> Result<Vec<f32>> {
    if cfg.model == "linear" {
        return Ok(vec![0.0; crate::fl::LinearModel::param_len(LINEAR_DIM)]);
    }
    let manifest = Manifest::load_default()?;
    Ok(manifest.model(&cfg.model)?.init_params(cfg.seed ^ 0x1817))
}

/// The headline metric of each benchmark model (paper Tables 1–4).
pub fn headline_metric(model: &str) -> &'static str {
    match model {
        "cnn_c10" => "accuracy",
        "lm_so" | "lora_llm" => "perplexity",
        "mlp_flair" => "map",
        _ => "accuracy",
    }
}

/// Central-eval callback over the dataset's held-out shards.
#[cfg(feature = "hlo")]
pub fn build_eval_callback(
    cfg: &Config,
    dataset: &Arc<dyn FederatedDataset>,
) -> Result<CentralEvalCallback> {
    let manifest = Manifest::load_default()?;
    let entry = manifest.model(&cfg.model)?;
    let shards = dataset.central_eval(entry.eval_batch);
    let rt = std::rc::Rc::new(Runtime::new(manifest.clone())?);
    let model = HloModel::new_owned(rt, &cfg.model, cfg.seed ^ 0x1817)?;
    Ok(CentralEvalCallback::new(
        Box::new(model),
        shards,
        cfg.eval_every,
        headline_metric(&cfg.model),
    ))
}

/// Without the `hlo` feature central evaluation of NN models is
/// unavailable — error out with the rebuild hint.
#[cfg(not(feature = "hlo"))]
pub fn build_eval_callback(
    cfg: &Config,
    _dataset: &Arc<dyn FederatedDataset>,
) -> Result<CentralEvalCallback> {
    anyhow::bail!(
        "central eval of model {:?} needs the PJRT runtime; rebuild with `--features hlo`",
        cfg.model
    )
}

/// Open + validate the config's data store: the store must hold the
/// same dataset (name) and population the config's generator would
/// produce — a store materialized from a different preset or `--scale`
/// would feed the wrong shapes into the model, so fail loudly instead.
fn open_store(cfg: &Config) -> Result<Arc<ShardedStore>> {
    let store = Arc::new(
        ShardedStore::open_with(std::path::Path::new(&cfg.data_store), cfg.open_options())
            .with_context(|| {
                format!("opening data store {} (run `pfl materialize` first)", cfg.data_store)
            })?,
    );
    let expect = build_dataset(&cfg.dataset)?;
    if store.name() != expect.name() || store.num_users() != expect.num_users() {
        bail!(
            "data store {} holds {:?} with {} users, but the config expects {:?} with {} \
             users — materialize with the same --preset/--config and --scale",
            cfg.data_store,
            store.name(),
            store.num_users(),
            expect.name(),
            expect.num_users(),
        );
    }
    Ok(store)
}

/// The run's training dataset: the lazy generator, or — with
/// `engine.data_store` set — the materialized store opened from disk
/// (its in-memory index serves `user_len` scheduling weights with no
/// I/O; reads are bit-identical to the generator it was materialized
/// from). Prefer [`crate::fl::backend::SimulatedBackend::dataset`]
/// when a backend has already been built — it shares one store open.
pub fn effective_dataset(cfg: &Config) -> Result<Arc<dyn FederatedDataset>> {
    if cfg.data_store.is_empty() {
        build_dataset(&cfg.dataset)
    } else {
        Ok(open_store(cfg)?)
    }
}

/// Assemble the full backend for a config.
pub fn build_backend(cfg: &Config, profile: OverheadProfile) -> Result<SimulatedBackend> {
    let mut source: Option<Arc<dyn UserDataSource>> = None;
    let dataset: Arc<dyn FederatedDataset> = if cfg.data_store.is_empty() {
        build_dataset(&cfg.dataset)?
    } else {
        let store = open_store(cfg)?;
        source = Some(Arc::new(StoreSource::new(store.clone(), cfg.source_config())));
        store
    };
    let algorithm = build_algorithm(cfg, dataset.num_users())?;
    let factory = model_factory(cfg);
    let mut builder = BackendBuilder::new(dataset, algorithm, factory).params(RunParams {
        num_workers: cfg.num_workers,
        scheduler: cfg.scheduler_kind()?,
        dispatch: cfg.dispatch_spec()?,
        profile,
        seed: cfg.seed,
        log_every: 0,
        arena: cfg.arena_config(),
        fold_tree: cfg.fold_tree,
        noise_threads: cfg.noise_threads,
        scenario: cfg.scenario_spec(),
        ..Default::default()
    });
    if let Some(s) = source {
        builder = builder.data_source(s);
    }
    for pp in build_postprocessors(cfg)? {
        builder = builder.postprocessor(pp);
    }
    builder.build()
}

/// Assemble the [`WorkerShared`] a socket-fed worker process needs
/// (`pfl worker --connect`) from the config the server shipped in its
/// handshake — the same pieces [`build_backend`] hands the in-process
/// pool, so a user trains identically on either transport. Only the
/// pfl-style profile is supported over sockets (the coordinator
/// emulation is an in-process baseline diagnostic).
pub fn build_worker_shared(cfg: &Config, use_hlo_clip: bool) -> Result<WorkerShared> {
    let mut source: Option<Arc<dyn UserDataSource>> = None;
    let dataset: Arc<dyn FederatedDataset> = if cfg.data_store.is_empty() {
        build_dataset(&cfg.dataset)?
    } else {
        let store = open_store(cfg)?;
        source = Some(Arc::new(StoreSource::new(store.clone(), cfg.source_config())));
        store
    };
    let algorithm = build_algorithm(cfg, dataset.num_users())?;
    Ok(WorkerShared {
        source: source.unwrap_or_else(|| Arc::new(GeneratorSource::new(dataset))),
        algorithm,
        postprocessors: Arc::new(build_postprocessors(cfg)?),
        aggregator: Arc::new(crate::fl::SumAggregator),
        factory: model_factory(cfg),
        profile: OverheadProfile::default(),
        seed: cfg.seed,
        use_hlo_clip,
        arena: cfg.arena_config(),
        noise_threads: cfg.noise_threads,
        scenario: cfg.scenario_spec(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::preset;

    #[test]
    fn datasets_build_for_all_presets() {
        for name in crate::config::preset_names() {
            let cfg = preset(name).unwrap().scaled(0.02);
            let ds = build_dataset(&cfg.dataset).unwrap();
            assert!(ds.num_users() > 0, "{name}");
            let d = ds.user_data(0);
            assert!(!d.is_empty(), "{name} user 0 empty");
        }
    }

    #[test]
    fn algorithms_build_for_all_kinds() {
        let mut cfg = preset("cifar10-iid").unwrap();
        for kind in ["fedavg", "fedprox", "adafedprox", "scaffold"] {
            cfg.algorithm.kind = kind.into();
            let alg = build_algorithm(&cfg, 100).unwrap();
            assert!(!alg.next_contexts(0).is_empty());
        }
        cfg.algorithm.kind = "bogus".into();
        assert!(build_algorithm(&cfg, 100).is_err());
    }

    #[test]
    fn dp_presets_calibrate_noise() {
        let cfg = preset("cifar10-iid-dp").unwrap().scaled(0.1);
        let sigma = calibrated_noise_multiplier(&cfg).unwrap();
        assert!(sigma > 0.1 && sigma < 50.0, "sigma {sigma}");
        let pps = build_postprocessors(&cfg).unwrap();
        assert_eq!(pps.len(), 1);
        assert_eq!(pps[0].name(), "gaussian");
    }

    #[test]
    fn nodp_presets_have_no_postprocessors() {
        let cfg = preset("cifar10-iid").unwrap();
        assert!(build_postprocessors(&cfg).unwrap().is_empty());
        assert_eq!(calibrated_noise_multiplier(&cfg).unwrap(), 0.0);
    }

    #[test]
    fn wire_quantizer_appends_after_mechanism() {
        // the quantizer must be the last local step, so the DP-noised
        // f32s are what gets encoded for the wire
        let mut cfg = preset("cifar10-iid-dp").unwrap().scaled(0.1);
        cfg.wire_quantization = "int8".into();
        let pps = build_postprocessors(&cfg).unwrap();
        assert_eq!(pps.len(), 2);
        assert_eq!(pps[0].name(), "gaussian");
        assert_eq!(pps[1].name(), "wire-quantize");
        // without DP it is the only postprocessor
        cfg.privacy = crate::config::PrivacyConfig::none();
        let pps = build_postprocessors(&cfg).unwrap();
        assert_eq!(pps.len(), 1);
        assert_eq!(pps[0].name(), "wire-quantize");
        // invalid widths surface at build time
        cfg.wire_quantization = "int4".into();
        assert!(build_postprocessors(&cfg).is_err());
    }

    #[test]
    fn effective_dataset_opens_materialized_store() {
        let mut cfg = preset("cifar10-iid").unwrap().scaled(0.02);
        let dir =
            std::env::temp_dir().join(format!("pfl_build_store_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let gen = build_dataset(&cfg.dataset).unwrap();
        crate::data::materialize(&*gen, &dir, 16, 0).unwrap();
        cfg.data_store = dir.to_string_lossy().into_owned();
        cfg.cache_users = 8;
        cfg.prefetch_depth = 2;
        let ds = effective_dataset(&cfg).unwrap();
        assert_eq!(ds.num_users(), gen.num_users());
        assert_eq!(ds.name(), gen.name());
        assert_eq!(ds.user_len(0), gen.user_len(0));
        // the portable pread fallback opens the same store
        cfg.store_mmap = false;
        let ds = effective_dataset(&cfg).unwrap();
        assert_eq!(ds.num_users(), gen.num_users());
        cfg.store_mmap = true;
        // the full backend assembles over the store (model construction
        // is lazy, so no hlo feature is needed here)
        let backend = build_backend(&cfg, OverheadProfile::default()).unwrap();
        assert_eq!(backend.num_workers(), cfg.num_workers);
        // a store from a different scale (population mismatch) is
        // rejected instead of silently training on the wrong users
        let mut other = preset("cifar10-iid").unwrap().scaled(0.05);
        other.data_store = cfg.data_store.clone();
        let err = effective_dataset(&other).unwrap_err();
        assert!(err.to_string().contains("users"), "unhelpful mismatch error: {err:#}");
        // a bogus path errors with context instead of falling back
        cfg.data_store = "/nonexistent/pfl-store".into();
        assert!(effective_dataset(&cfg).is_err());
        assert!(build_backend(&cfg, OverheadProfile::default()).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn tabular_linear_pairing_is_pjrt_free() {
        let mut cfg = preset("cifar10-iid").unwrap().scaled(0.02);
        cfg.model = "linear".into();
        cfg.dataset.kind = "tabular".into();
        // init + factory never touch the artifact manifest
        let params = init_params(&cfg).unwrap();
        assert_eq!(params.len(), LINEAR_DIM + 1);
        let shared = build_worker_shared(&cfg, false).unwrap();
        let model = (shared.factory)(0).unwrap();
        assert_eq!(model.name(), "linear");
        assert_eq!(model.param_count(), LINEAR_DIM + 1);
        let ds = build_dataset(&cfg.dataset).unwrap();
        assert!(ds.num_users() > 0);
        assert!(matches!(
            ds.user_data(0),
            crate::data::UserData::Tabular { dim: LINEAR_DIM, .. }
        ));
    }

    #[test]
    fn headline_metrics_per_model() {
        assert_eq!(headline_metric("cnn_c10"), "accuracy");
        assert_eq!(headline_metric("lm_so"), "perplexity");
        assert_eq!(headline_metric("mlp_flair"), "map");
        assert_eq!(headline_metric("lora_llm"), "perplexity");
    }
}
