//! System metrics + simulated-device accounting (paper App. D.4.2,
//! Figs. 7–8; and the substrate for the scaling studies Figs. 2–3).
//!
//! Two roles:
//!
//! 1. **Counters** — bytes allocated/copied in the round loop, device
//!    busy/idle time, per-user timings. These are what Figs. 7–8 plot
//!    (CPU/GPU memory + utilization over the run) and what the
//!    "no model-sized alloc in the loop" invariant tests assert.
//!
//! 2. **Virtual cluster** — this testbed has a single CPU core, so
//!    multi-GPU scaling (Figs. 2–3) is *simulated*: every user's local
//!    training cost is **measured** (real wall-clock of its PJRT
//!    executions), then users are replayed onto v virtual workers
//!    according to the scheduler. Simulated round time = max over
//!    workers of Σ assigned costs (+ per-round overheads); GPU-hours =
//!    Σ busy time. This preserves exactly the quantities the paper's
//!    scaling figures measure (scheduling quality, straggler gaps,
//!    utilization) — see DESIGN.md §2 substitutions.

use std::time::Duration;

/// Lightweight event counters, one per worker (merged at round end).
#[derive(Debug, Default, Clone)]
pub struct Counters {
    /// Bytes of model-sized heap allocation in the training loop.
    pub loop_alloc_bytes: u64,
    /// Bytes allocated growing the workers' accumulation arenas (sized
    /// once on first use; 0 in steady-state rounds — the observable form
    /// of the "no model-sized alloc in the loop" invariant).
    pub arena_grow_bytes: u64,
    /// Worker-rounds whose arena emitted an all-sparse partial (every
    /// live slot stayed a sorted sparse accumulator — no model-sized
    /// dense buffer was touched).
    pub arena_sparse_rounds: u64,
    /// Arena slots spilled sparse→dense (union nnz crossed
    /// `ArenaConfig::sparse_spill_frac` · dim, or a dense contribution
    /// arrived). 0 across an all-sparse run is the observable form of
    /// "very-sparse regimes never allocate model-sized buffers".
    pub arena_spill_count: u64,
    /// Bytes memcpy'd between "host" and "device" staging buffers.
    pub copy_bytes: u64,
    /// Bytes serialized for topology-simulating transport (baselines).
    pub wire_bytes: u64,
    /// Count of model-update messages through a coordinator (baselines).
    pub coordinator_msgs: u64,
    /// f32-equivalents shipped by users after local postprocessing
    /// (sparse statistics count u32 idx + f32 val per nonzero) — the
    /// user→server communication volume, which sparsification shrinks
    /// even though the arena-reduced aggregate stays dense.
    pub stat_elements: u64,
    /// Bytes shipped by users after local postprocessing, accounting for
    /// the stored width (f32 = 4/coordinate, sparse = 8/nonzero,
    /// quantized = the packed code bytes + index/scale overhead) — the
    /// width-aware companion of `stat_elements`, which `--quantize`
    /// shrinks even though the element count is unchanged.
    pub stat_bytes: u64,
    /// Device busy time (executable execution).
    pub busy_nanos: u64,
    /// Users trained.
    pub users_trained: u64,
    /// Local optimization steps executed.
    pub steps: u64,
    /// Work-stealing rounds: users pulled beyond the even per-worker
    /// share (load the shared queue migrated off stragglers).
    pub steal_count: u64,
    /// Async rounds: updates folded with staleness ≥ 1 (discounted).
    pub stale_updates: u64,
    /// Async rounds: updates discarded — staler than the bound, or still
    /// in flight when the run (or an eval barrier) drained the engine.
    pub dropped_updates: u64,
    /// Store-backed runs: user fetches served from the LRU cache
    /// (generator-backed sources count neither hits nor misses).
    pub cache_hits: u64,
    /// Store-backed runs: user fetches that had to read the shard file
    /// on the worker thread (the prefetcher lost the race).
    pub cache_misses: u64,
    /// Nanoseconds workers spent blocked on user-data I/O (miss reads).
    /// 0 when every load was prefetched off the critical path — the
    /// observable form of "data loading overlaps local training".
    pub prefetch_stall_nanos: u64,
    /// Store-backed runs: shard-file bytes actually read for user data
    /// (compressed stores count framed on-disk bytes; prefetched reads
    /// are credited when the worker consumes the cache entry).
    pub store_bytes_read: u64,
    /// Nanoseconds spent decompressing blocks *on worker threads* (miss
    /// reads only). Prefetch-thread decode is deliberately excluded: ≈0
    /// here is the observable form of "decompression is off the
    /// critical path".
    pub decode_nanos: u64,
    /// Portion of `prefetch_stall_nanos` from mmap-backed reads — page
    /// faults the kernel resolved while the worker touched the mapping.
    pub mmap_stall_nanos: u64,
    /// Portion of `prefetch_stall_nanos` from the portable pread path.
    pub pread_stall_nanos: u64,
    /// Nanoseconds spent generating DP noise (server mechanisms and
    /// worker-local noise), whichever engine (legacy sequential or
    /// counter-parallel) produced it.
    pub noise_nanos: u64,
    /// Socket runs: in-flight uids re-dispatched to a live worker after
    /// their original worker died mid-round (`sys/requeued-users`).
    pub requeued_users: u64,
    /// Socket runs: replacement worker processes admitted into a dead
    /// slot after the run started (`sys/worker-reconnects`).
    pub worker_reconnects: u64,
    /// Socket runs: framed bytes received from workers — results +
    /// heartbeats (`sys/wire-bytes-in`).
    pub wire_bytes_in: u64,
    /// Socket runs: framed bytes sent to workers — round commands
    /// (`sys/wire-bytes-out`).
    pub wire_bytes_out: u64,
    /// Scenario runs: dispatched users whose device died mid-round
    /// (hazard dropout) — their partials were discarded, never folded
    /// (`sys/dropout-frac`, DESIGN.md §8).
    pub dropout_users: u64,
    /// Scenario runs: sampled users skipped at cohort time because
    /// their device was outside its diurnal window or churned offline
    /// (`sys/unavailable-skipped`).
    pub unavailable_skipped: u64,
}

impl Counters {
    pub fn merge(&mut self, o: &Counters) {
        self.loop_alloc_bytes += o.loop_alloc_bytes;
        self.arena_grow_bytes += o.arena_grow_bytes;
        self.arena_sparse_rounds += o.arena_sparse_rounds;
        self.arena_spill_count += o.arena_spill_count;
        self.copy_bytes += o.copy_bytes;
        self.wire_bytes += o.wire_bytes;
        self.coordinator_msgs += o.coordinator_msgs;
        self.stat_elements += o.stat_elements;
        self.stat_bytes += o.stat_bytes;
        self.busy_nanos += o.busy_nanos;
        self.users_trained += o.users_trained;
        self.steps += o.steps;
        self.steal_count += o.steal_count;
        self.stale_updates += o.stale_updates;
        self.dropped_updates += o.dropped_updates;
        self.cache_hits += o.cache_hits;
        self.cache_misses += o.cache_misses;
        self.prefetch_stall_nanos += o.prefetch_stall_nanos;
        self.store_bytes_read += o.store_bytes_read;
        self.decode_nanos += o.decode_nanos;
        self.mmap_stall_nanos += o.mmap_stall_nanos;
        self.pread_stall_nanos += o.pread_stall_nanos;
        self.noise_nanos += o.noise_nanos;
        self.requeued_users += o.requeued_users;
        self.worker_reconnects += o.worker_reconnects;
        self.wire_bytes_in += o.wire_bytes_in;
        self.wire_bytes_out += o.wire_bytes_out;
        self.dropout_users += o.dropout_users;
        self.unavailable_skipped += o.unavailable_skipped;
    }

    pub fn busy(&self) -> Duration {
        Duration::from_nanos(self.busy_nanos)
    }
}

/// A measured per-user training record (feeds Fig. 4a and the virtual
/// cluster replay).
#[derive(Debug, Clone, Copy)]
pub struct UserCost {
    pub datapoints: usize,
    /// Total wall-clock for the user (host + device).
    pub nanos: u64,
    /// Device-busy portion (executable execution time). The replay model
    /// serializes device time among workers sharing a device and overlaps
    /// the host portion — the mechanism behind the paper's "p > 1
    /// processes per GPU increases utilization" (§4.2).
    pub device_nanos: u64,
}

impl UserCost {
    pub fn host_nanos(&self) -> u64 {
        self.nanos.saturating_sub(self.device_nanos)
    }
}

/// Simulated round time for a cluster of `gpus` devices with `per_gpu`
/// workers each, given per-worker queues of user costs. Device time of
/// co-located workers serializes; host time overlaps. Returns
/// (round_nanos, per_device_busy_nanos).
///
/// Roofline model per device: round_d = max(Σ_w device_w,
/// max_w (host_w + device_w)); the cluster round is max over devices.
pub fn replay_cluster(
    queues: &[Vec<UserCost>],
    gpus: usize,
    per_gpu: usize,
    per_user_overhead_nanos: u64,
) -> (u64, Vec<u64>) {
    assert_eq!(queues.len(), gpus * per_gpu);
    let mut round = 0u64;
    let mut device_busy = Vec::with_capacity(gpus);
    for g in 0..gpus {
        let mut sum_device = 0u64;
        let mut max_worker = 0u64;
        for p in 0..per_gpu {
            let q = &queues[g * per_gpu + p];
            let dev: u64 = q.iter().map(|c| c.device_nanos).sum();
            let host: u64 =
                q.iter().map(|c| c.host_nanos() + per_user_overhead_nanos).sum();
            sum_device += dev;
            max_worker = max_worker.max(dev + host);
        }
        let dev_round = sum_device.max(max_worker);
        device_busy.push(sum_device);
        round = round.max(dev_round);
    }
    (round, device_busy)
}

/// Replay measured user costs onto a virtual cluster using a precomputed
/// schedule; returns (round_nanos, busy_nanos_per_worker).
pub fn replay_round(
    costs: &[UserCost],
    assignments: &[Vec<usize>],
    per_user_overhead_nanos: u64,
) -> (u64, Vec<u64>) {
    let mut busy: Vec<u64> = Vec::with_capacity(assignments.len());
    for a in assignments {
        let mut t = 0u64;
        for &i in a {
            t += costs[i].nanos + per_user_overhead_nanos;
        }
        busy.push(t);
    }
    let round = busy.iter().copied().max().unwrap_or(0);
    (round, busy)
}

/// Utilization of the virtual cluster for one round: Σ busy / (v * round).
pub fn utilization(round_nanos: u64, busy: &[u64]) -> f64 {
    if round_nanos == 0 || busy.is_empty() {
        return 0.0;
    }
    let total: u64 = busy.iter().sum();
    total as f64 / (round_nanos as f64 * busy.len() as f64)
}

/// Straggler gap: difference between last and first worker to finish.
pub fn straggler_gap_nanos(busy: &[u64]) -> u64 {
    let max = busy.iter().copied().max().unwrap_or(0);
    let min = busy.iter().copied().min().unwrap_or(0);
    max - min
}

/// A time series sampled once per round — the Figs. 7/8 output format.
#[derive(Debug, Default, Clone)]
pub struct Timeline {
    pub rows: Vec<TimelineRow>,
}

#[derive(Debug, Clone, Copy)]
pub struct TimelineRow {
    pub round: u64,
    pub wall_secs: f64,
    pub rss_bytes: u64,
    pub busy_frac: f64,
    pub loop_alloc_bytes: u64,
    pub copy_bytes: u64,
}

impl Timeline {
    pub fn push(&mut self, row: TimelineRow) {
        self.rows.push(row);
    }

    pub fn print_tsv(&self) {
        println!("round\twall_s\trss_mb\tbusy_frac\talloc_mb\tcopy_mb");
        for r in &self.rows {
            println!(
                "{}\t{:.2}\t{:.1}\t{:.3}\t{:.1}\t{:.1}",
                r.round,
                r.wall_secs,
                r.rss_bytes as f64 / 1e6,
                r.busy_frac,
                r.loop_alloc_bytes as f64 / 1e6,
                r.copy_bytes as f64 / 1e6
            );
        }
    }
}

/// Current process RSS in bytes (linux; 0 elsewhere).
pub fn current_rss_bytes() -> u64 {
    if let Ok(s) = std::fs::read_to_string("/proc/self/statm") {
        if let Some(pages) = s.split_whitespace().nth(1) {
            if let Ok(p) = pages.parse::<u64>() {
                return p * 4096;
            }
        }
    }
    0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_merge() {
        let mut a = Counters {
            busy_nanos: 5,
            users_trained: 1,
            cache_hits: 2,
            ..Default::default()
        };
        let b = Counters {
            busy_nanos: 7,
            steps: 3,
            copy_bytes: 10,
            cache_hits: 1,
            cache_misses: 4,
            prefetch_stall_nanos: 9,
            stat_elements: 6,
            stat_bytes: 24,
            store_bytes_read: 100,
            decode_nanos: 11,
            mmap_stall_nanos: 5,
            pread_stall_nanos: 4,
            noise_nanos: 13,
            requeued_users: 2,
            worker_reconnects: 1,
            wire_bytes_in: 77,
            wire_bytes_out: 88,
            dropout_users: 5,
            unavailable_skipped: 6,
            ..Default::default()
        };
        a.merge(&b);
        assert_eq!(a.busy_nanos, 12);
        assert_eq!(a.users_trained, 1);
        assert_eq!(a.steps, 3);
        assert_eq!(a.copy_bytes, 10);
        assert_eq!(a.cache_hits, 3);
        assert_eq!(a.cache_misses, 4);
        assert_eq!(a.prefetch_stall_nanos, 9);
        assert_eq!(a.stat_elements, 6);
        assert_eq!(a.stat_bytes, 24);
        assert_eq!(a.store_bytes_read, 100);
        assert_eq!(a.decode_nanos, 11);
        assert_eq!(a.mmap_stall_nanos, 5);
        assert_eq!(a.pread_stall_nanos, 4);
        assert_eq!(a.noise_nanos, 13);
        assert_eq!(a.requeued_users, 2);
        assert_eq!(a.worker_reconnects, 1);
        assert_eq!(a.wire_bytes_in, 77);
        assert_eq!(a.wire_bytes_out, 88);
        assert_eq!(a.dropout_users, 5);
        assert_eq!(a.unavailable_skipped, 6);
    }

    #[test]
    fn replay_matches_hand_computation() {
        let costs = vec![
            UserCost { datapoints: 10, nanos: 100, device_nanos: 60 },
            UserCost { datapoints: 20, nanos: 200, device_nanos: 120 },
            UserCost { datapoints: 30, nanos: 300, device_nanos: 180 },
        ];
        let assignments = vec![vec![0, 1], vec![2]];
        let (round, busy) = replay_round(&costs, &assignments, 10);
        assert_eq!(busy, vec![320, 310]);
        assert_eq!(round, 320);
        assert_eq!(straggler_gap_nanos(&busy), 10);
        let u = utilization(round, &busy);
        assert!((u - (630.0 / 640.0)).abs() < 1e-12);
    }

    #[test]
    fn utilization_edge_cases() {
        assert_eq!(utilization(0, &[1, 2]), 0.0);
        assert_eq!(utilization(10, &[]), 0.0);
    }

    #[test]
    fn rss_is_positive_on_linux() {
        assert!(current_rss_bytes() > 0);
    }
}
