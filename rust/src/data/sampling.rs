//! Cohort sampling (paper App. A: Poisson sampling for DP accounting;
//! `pfl/data/sampling.py` for cross-silo).

use crate::util::rng::Rng;

/// Samples the cohort of user ids for one central iteration.
pub trait CohortSampler: Send + Sync {
    fn sample(&self, population: usize, iteration: u64, seed: u64) -> Vec<usize>;
    fn name(&self) -> &'static str;
}

/// Fixed-size cohort, uniform without replacement — what simulations
/// actually run (the accountant then *assumes* Poisson sampling of the
/// same expected size, App. A).
pub struct MinibatchSampler {
    pub cohort_size: usize,
}

impl CohortSampler for MinibatchSampler {
    fn sample(&self, population: usize, iteration: u64, seed: u64) -> Vec<usize> {
        let mut rng = Rng::seed_from_u64(seed ^ iteration.wrapping_mul(0x9E37_79B9));
        rng.choose_k(population, self.cohort_size)
    }
    fn name(&self) -> &'static str {
        "minibatch"
    }
}

/// True Poisson sampling: each user flips a coin with p = C/M.
pub struct PoissonCohortSampler {
    pub rate: f64,
}

impl CohortSampler for PoissonCohortSampler {
    fn sample(&self, population: usize, iteration: u64, seed: u64) -> Vec<usize> {
        let mut rng = Rng::seed_from_u64(seed ^ iteration.wrapping_mul(0x517C_C1B7));
        rng.poisson_subsample(population, self.rate)
    }
    fn name(&self) -> &'static str {
        "poisson"
    }
}

/// Cross-silo: every silo participates every round (the common cross-silo
/// regime: few, reliable participants).
pub struct CrossSiloSampler;

impl CohortSampler for CrossSiloSampler {
    fn sample(&self, population: usize, _iteration: u64, _seed: u64) -> Vec<usize> {
        (0..population).collect()
    }
    fn name(&self) -> &'static str {
        "cross-silo"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minibatch_size_distinct_deterministic() {
        let s = MinibatchSampler { cohort_size: 50 };
        let a = s.sample(1000, 3, 42);
        assert_eq!(a.len(), 50);
        let set: std::collections::HashSet<_> = a.iter().collect();
        assert_eq!(set.len(), 50);
        assert_eq!(a, s.sample(1000, 3, 42));
        assert_ne!(a, s.sample(1000, 4, 42));
    }

    #[test]
    fn minibatch_caps_at_population() {
        let s = MinibatchSampler { cohort_size: 50 };
        assert_eq!(s.sample(10, 0, 1).len(), 10);
    }

    #[test]
    fn poisson_rate_is_respected() {
        let s = PoissonCohortSampler { rate: 0.05 };
        let mut total = 0usize;
        for it in 0..200 {
            total += s.sample(1000, it, 7).len();
        }
        let mean = total as f64 / 200.0;
        assert!((mean - 50.0).abs() < 5.0, "mean cohort {mean}");
    }

    #[test]
    fn cross_silo_takes_everyone() {
        assert_eq!(CrossSiloSampler.sample(7, 0, 0), (0..7).collect::<Vec<_>>());
    }

    #[test]
    fn poisson_per_user_inclusion_rate() {
        // The DP accounting assumption is *per-user*: every uid is an
        // independent Bernoulli(rate) each round, not just the cohort
        // mean — check the inclusion frequency of individual users.
        let s = PoissonCohortSampler { rate: 0.2 };
        let rounds = 2000u64;
        let population = 40;
        let mut included = vec![0u32; population];
        for it in 0..rounds {
            for uid in s.sample(population, it, 11) {
                included[uid] += 1;
            }
        }
        for (uid, &n) in included.iter().enumerate() {
            let freq = n as f64 / rounds as f64;
            // 5 sigma of Bernoulli(0.2) over 2000 trials ≈ 0.045
            assert!((freq - 0.2).abs() < 0.05, "uid {uid} included at rate {freq}");
        }
    }

    #[test]
    fn poisson_cohorts_are_valid_sorted_and_deterministic() {
        let s = PoissonCohortSampler { rate: 0.3 };
        for it in 0..20 {
            let c = s.sample(100, it, 5);
            assert!(c.iter().all(|&u| u < 100));
            // per-user coin flips over 0..n yield strictly increasing ids
            assert!(c.windows(2).all(|w| w[0] < w[1]), "iteration {it} not sorted-unique");
            assert_eq!(c, s.sample(100, it, 5), "iteration {it} not deterministic");
        }
        // different seeds decorrelate the rounds
        assert_ne!(s.sample(100, 3, 5), s.sample(100, 3, 6));
        // degenerate rates
        assert!(PoissonCohortSampler { rate: 0.0 }.sample(50, 0, 1).is_empty());
        assert_eq!(PoissonCohortSampler { rate: 1.0 }.sample(50, 0, 1).len(), 50);
    }

    #[test]
    fn cross_silo_coverage_invariants() {
        // Every silo participates every round: full coverage, each id
        // exactly once, in stable order, regardless of iteration or
        // seed — the invariant the prefetcher's hint order relies on.
        let s = CrossSiloSampler;
        for population in [0usize, 1, 13, 100] {
            for (it, seed) in [(0u64, 0u64), (7, 3), (1000, 99)] {
                let c = s.sample(population, it, seed);
                assert_eq!(c.len(), population);
                assert_eq!(c, (0..population).collect::<Vec<_>>(), "pop {population}");
            }
        }
        assert_eq!(s.name(), "cross-silo");
    }
}
