//! Cohort sampling (paper App. A: Poisson sampling for DP accounting;
//! `pfl/data/sampling.py` for cross-silo).

use crate::util::rng::Rng;

/// Samples the cohort of user ids for one central iteration.
pub trait CohortSampler: Send + Sync {
    fn sample(&self, population: usize, iteration: u64, seed: u64) -> Vec<usize>;
    fn name(&self) -> &'static str;
}

/// Fixed-size cohort, uniform without replacement — what simulations
/// actually run (the accountant then *assumes* Poisson sampling of the
/// same expected size, App. A).
pub struct MinibatchSampler {
    pub cohort_size: usize,
}

impl CohortSampler for MinibatchSampler {
    fn sample(&self, population: usize, iteration: u64, seed: u64) -> Vec<usize> {
        let mut rng = Rng::seed_from_u64(seed ^ iteration.wrapping_mul(0x9E37_79B9));
        rng.choose_k(population, self.cohort_size)
    }
    fn name(&self) -> &'static str {
        "minibatch"
    }
}

/// True Poisson sampling: each user flips a coin with p = C/M.
pub struct PoissonCohortSampler {
    pub rate: f64,
}

impl CohortSampler for PoissonCohortSampler {
    fn sample(&self, population: usize, iteration: u64, seed: u64) -> Vec<usize> {
        let mut rng = Rng::seed_from_u64(seed ^ iteration.wrapping_mul(0x517C_C1B7));
        rng.poisson_subsample(population, self.rate)
    }
    fn name(&self) -> &'static str {
        "poisson"
    }
}

/// Cross-silo: every silo participates every round (the common cross-silo
/// regime: few, reliable participants).
pub struct CrossSiloSampler;

impl CohortSampler for CrossSiloSampler {
    fn sample(&self, population: usize, _iteration: u64, _seed: u64) -> Vec<usize> {
        (0..population).collect()
    }
    fn name(&self) -> &'static str {
        "cross-silo"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minibatch_size_distinct_deterministic() {
        let s = MinibatchSampler { cohort_size: 50 };
        let a = s.sample(1000, 3, 42);
        assert_eq!(a.len(), 50);
        let set: std::collections::HashSet<_> = a.iter().collect();
        assert_eq!(set.len(), 50);
        assert_eq!(a, s.sample(1000, 3, 42));
        assert_ne!(a, s.sample(1000, 4, 42));
    }

    #[test]
    fn minibatch_caps_at_population() {
        let s = MinibatchSampler { cohort_size: 50 };
        assert_eq!(s.sample(10, 0, 1).len(), 10);
    }

    #[test]
    fn poisson_rate_is_respected() {
        let s = PoissonCohortSampler { rate: 0.05 };
        let mut total = 0usize;
        for it in 0..200 {
            total += s.sample(1000, it, 7).len();
        }
        let mean = total as f64 / 200.0;
        assert!((mean - 50.0).abs() < 5.0, "mean cohort {mean}");
    }

    #[test]
    fn cross_silo_takes_everyone() {
        assert_eq!(CrossSiloSampler.sample(7, 0, 0), (0..7).collect::<Vec<_>>());
    }
}
