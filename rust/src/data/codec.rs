//! Per-block compression codec for the sharded data store.
//!
//! Store payloads are dominated by little-endian `f32`/`i32` lanes
//! (see the blob encoding in [`crate::data::store`]), which raw LZ
//! handles poorly: the low mantissa bytes are near-random while the
//! sign/exponent bytes repeat heavily. The codec therefore runs two
//! passes per fixed-size block:
//!
//! 1. **byte-shuffle** — transpose the 4-byte lanes so byte plane 0 of
//!    every word is contiguous, then plane 1, etc. (the classic
//!    blosc/HDF5 shuffle filter). Repetitive planes become long runs.
//! 2. **LZ** — a greedy LZ4-block-style coder: hash table over 4-byte
//!    words, 64 KiB window, `token = lit-nibble | match-nibble` with
//!    255-extension bytes and a 2-byte little-endian offset. Runs (the
//!    post-shuffle common case) collapse to offset-1 matches, so this
//!    subsumes RLE.
//!
//! Each compressed block is framed with a 1-byte flag; blocks the codec
//! cannot shrink are **stored** verbatim (flag 0), bounding the worst
//! case at one byte of overhead per block. Framing and integrity
//! errors surface as `anyhow` errors that the store maps into its typed
//! corruption errors.
//!
//! Decompression happens on the store's prefetch thread (never on the
//! worker critical path); see `DESIGN.md` §6.

use anyhow::{bail, ensure, Result};

/// Store-level compression scheme, recorded in the index header
/// (format V2; see [`crate::data::store`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Compression {
    /// Raw blobs, byte-compatible with the V1 shard layout.
    None,
    /// Byte-shuffle + block LZ as described at module level.
    ShuffleLz,
}

impl Compression {
    pub fn as_str(&self) -> &'static str {
        match self {
            Compression::None => "none",
            Compression::ShuffleLz => "shuffle-lz",
        }
    }

    pub fn to_u8(self) -> u8 {
        match self {
            Compression::None => 0,
            Compression::ShuffleLz => 1,
        }
    }

    pub fn from_u8(v: u8) -> Result<Compression> {
        match v {
            0 => Ok(Compression::None),
            1 => Ok(Compression::ShuffleLz),
            other => bail!("unknown compression id {other} in store index"),
        }
    }
}

impl std::str::FromStr for Compression {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Compression> {
        match s {
            "none" => Ok(Compression::None),
            "shuffle-lz" => Ok(Compression::ShuffleLz),
            other => bail!("unknown compression {other:?} (expected none|shuffle-lz)"),
        }
    }
}

impl std::fmt::Display for Compression {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Default uncompressed block size written by `ShardWriter` (256 KiB:
/// large enough that the per-block flag/table overhead is noise, small
/// enough that a single-user decode touches one or two blocks).
pub const DEFAULT_BLOCK_SIZE: u32 = 256 * 1024;

/// Block flag: payload is the raw bytes, stored verbatim.
pub const FLAG_STORED: u8 = 0;
/// Block flag: payload is byte-shuffled then LZ-coded.
pub const FLAG_SHUFFLE_LZ: u8 = 1;

const MIN_MATCH: usize = 4;
const HASH_BITS: u32 = 12;
const HASH_SIZE: usize = 1 << HASH_BITS;
const MAX_OFFSET: usize = u16::MAX as usize;

/// Transpose `src` into 4 byte-planes (word stride 4); the non-multiple
/// tail is appended verbatim. `out` is cleared first.
pub fn byte_shuffle(src: &[u8], out: &mut Vec<u8>) {
    out.clear();
    out.reserve(src.len());
    let words = src.len() / 4;
    for plane in 0..4 {
        out.extend(src[..words * 4].iter().skip(plane).step_by(4));
    }
    out.extend_from_slice(&src[words * 4..]);
}

/// Inverse of [`byte_shuffle`]. `out` is cleared first.
pub fn byte_unshuffle(shuffled: &[u8], out: &mut Vec<u8>) {
    out.clear();
    out.resize(shuffled.len(), 0);
    let words = shuffled.len() / 4;
    for plane in 0..4 {
        for (j, &b) in shuffled[plane * words..(plane + 1) * words].iter().enumerate() {
            out[j * 4 + plane] = b;
        }
    }
    out[words * 4..].copy_from_slice(&shuffled[words * 4..]);
}

#[inline]
fn hash4(v: u32) -> usize {
    (v.wrapping_mul(2_654_435_761) >> (32 - HASH_BITS)) as usize
}

#[inline]
fn word_at(b: &[u8], i: usize) -> u32 {
    u32::from_le_bytes([b[i], b[i + 1], b[i + 2], b[i + 3]])
}

fn push_ext(out: &mut Vec<u8>, mut v: usize) {
    while v >= 255 {
        out.push(255);
        v -= 255;
    }
    out.push(v as u8);
}

/// Emit one `literals + match` sequence. `mlen >= MIN_MATCH`.
fn emit_sequence(out: &mut Vec<u8>, literals: &[u8], offset: u16, mlen: usize) {
    let lit = literals.len();
    let m = mlen - MIN_MATCH;
    let token = ((lit.min(15) as u8) << 4) | m.min(15) as u8;
    out.push(token);
    if lit >= 15 {
        push_ext(out, lit - 15);
    }
    out.extend_from_slice(literals);
    out.extend_from_slice(&offset.to_le_bytes());
    if m >= 15 {
        push_ext(out, m - 15);
    }
}

/// Emit the final literals-only sequence (match nibble 0, no offset).
fn emit_last(out: &mut Vec<u8>, literals: &[u8]) {
    let lit = literals.len();
    if lit == 0 {
        return;
    }
    out.push((lit.min(15) as u8) << 4);
    if lit >= 15 {
        push_ext(out, lit - 15);
    }
    out.extend_from_slice(literals);
}

/// Greedy single-pass LZ coder, appending to `out`.
pub fn lz_compress(src: &[u8], out: &mut Vec<u8>) {
    // positions stored as pos+1 so 0 means empty
    let mut table = vec![0usize; HASH_SIZE];
    let mut anchor = 0usize;
    let mut i = 0usize;
    while i + MIN_MATCH <= src.len() {
        let h = hash4(word_at(src, i));
        let cand = table[h];
        table[h] = i + 1;
        if cand > 0 {
            let c = cand - 1;
            if i - c <= MAX_OFFSET && word_at(src, c) == word_at(src, i) {
                let mut mlen = MIN_MATCH;
                while i + mlen < src.len() && src[c + mlen] == src[i + mlen] {
                    mlen += 1;
                }
                emit_sequence(out, &src[anchor..i], (i - c) as u16, mlen);
                i += mlen;
                anchor = i;
                continue;
            }
        }
        i += 1;
    }
    emit_last(out, &src[anchor..]);
}

fn read_ext(comp: &[u8], p: &mut usize) -> Result<usize> {
    let mut v = 0usize;
    loop {
        let b = *comp
            .get(*p)
            .ok_or_else(|| anyhow::anyhow!("lz stream truncated in length extension"))?;
        *p += 1;
        v += b as usize;
        if b != 255 {
            return Ok(v);
        }
    }
}

/// Decode an [`lz_compress`] stream, verifying the output is exactly
/// `raw_len` bytes. Bounds-checked throughout: corrupt input errors,
/// never panics or reads out of range.
pub fn lz_decompress(comp: &[u8], raw_len: usize) -> Result<Vec<u8>> {
    let mut out = Vec::with_capacity(raw_len);
    let mut p = 0usize;
    while p < comp.len() {
        let token = comp[p];
        p += 1;
        let mut lit = (token >> 4) as usize;
        if lit == 15 {
            lit += read_ext(comp, &mut p)?;
        }
        ensure!(
            p + lit <= comp.len(),
            "lz stream truncated: literal run of {lit} at {p} overruns {} bytes",
            comp.len()
        );
        out.extend_from_slice(&comp[p..p + lit]);
        p += lit;
        if p == comp.len() {
            break; // last sequence carries no match
        }
        ensure!(p + 2 <= comp.len(), "lz stream truncated before match offset");
        let off = u16::from_le_bytes([comp[p], comp[p + 1]]) as usize;
        p += 2;
        ensure!(
            off >= 1 && off <= out.len(),
            "lz match offset {off} out of range (decoded {} bytes)",
            out.len()
        );
        let mut m = (token & 0x0f) as usize;
        if m == 15 {
            m += read_ext(comp, &mut p)?;
        }
        let mlen = m + MIN_MATCH;
        ensure!(
            out.len() + mlen <= raw_len,
            "lz match of {mlen} overruns declared raw length {raw_len}"
        );
        // byte-by-byte so overlapping matches (offset < mlen, the RLE
        // case) replicate correctly
        let start = out.len() - off;
        for k in 0..mlen {
            let b = out[start + k];
            out.push(b);
        }
    }
    ensure!(
        out.len() == raw_len,
        "lz stream decoded {} bytes, index declares {raw_len}",
        out.len()
    );
    Ok(out)
}

/// Compress one block: shuffle + LZ framed behind a flag byte, falling
/// back to a stored block when that does not shrink the data.
pub fn compress_block(raw: &[u8]) -> Vec<u8> {
    let mut shuffled = Vec::new();
    byte_shuffle(raw, &mut shuffled);
    let mut out = Vec::with_capacity(raw.len() / 2 + 16);
    out.push(FLAG_SHUFFLE_LZ);
    lz_compress(&shuffled, &mut out);
    if out.len() > raw.len() {
        out.clear();
        out.push(FLAG_STORED);
        out.extend_from_slice(raw);
    }
    out
}

/// Decode one framed block back to exactly `raw_len` raw bytes.
pub fn decompress_block(framed: &[u8], raw_len: usize) -> Result<Vec<u8>> {
    let Some((&flag, payload)) = framed.split_first() else {
        bail!("empty compressed block");
    };
    match flag {
        FLAG_STORED => {
            ensure!(
                payload.len() == raw_len,
                "stored block is {} bytes, index declares {raw_len}",
                payload.len()
            );
            Ok(payload.to_vec())
        }
        FLAG_SHUFFLE_LZ => {
            let shuffled = lz_decompress(payload, raw_len)?;
            let mut raw = Vec::new();
            byte_unshuffle(&shuffled, &mut raw);
            Ok(raw)
        }
        other => bail!("unknown block flag {other}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_bytes(rng: &mut Rng, len: usize) -> Vec<u8> {
        (0..len).map(|_| (rng.next_u64() & 0xff) as u8).collect()
    }

    fn roundtrip(raw: &[u8]) {
        let framed = compress_block(raw);
        let back = decompress_block(&framed, raw.len()).unwrap();
        assert_eq!(back, raw, "roundtrip mismatch for {} bytes", raw.len());
    }

    #[test]
    fn shuffle_is_exact_inverse() {
        let mut rng = Rng::seed_from_u64(7);
        for len in [0usize, 1, 2, 3, 4, 5, 7, 8, 64, 1001] {
            let raw = rand_bytes(&mut rng, len);
            let mut sh = Vec::new();
            byte_shuffle(&raw, &mut sh);
            assert_eq!(sh.len(), raw.len());
            let mut back = Vec::new();
            byte_unshuffle(&sh, &mut back);
            assert_eq!(back, raw, "len {len}");
        }
    }

    #[test]
    fn roundtrips_edge_and_random_blocks() {
        roundtrip(&[]);
        roundtrip(&[42]);
        roundtrip(&[1, 2, 3]);
        roundtrip(&[0; 4096]); // pure run → offset-1 match chain
        let mut rng = Rng::seed_from_u64(11);
        for len in [17usize, 255, 256, 4093, 65_537] {
            roundtrip(&rand_bytes(&mut rng, len));
        }
    }

    #[test]
    fn f32_lanes_compress_after_shuffle() {
        // slowly-varying f32s: shared sign/exponent planes shuffle into
        // long runs the LZ collapses
        let floats: Vec<f32> = (0..16_384).map(|i| 1.0 + (i as f32) * 1e-4).collect();
        let raw: Vec<u8> = floats.iter().flat_map(|f| f.to_le_bytes()).collect();
        let framed = compress_block(&raw);
        assert!(
            framed.len() * 2 < raw.len(),
            "expected ≥2× shrink on lane data, got {} / {}",
            framed.len(),
            raw.len()
        );
        assert_eq!(framed[0], FLAG_SHUFFLE_LZ);
        assert_eq!(decompress_block(&framed, raw.len()).unwrap(), raw);
    }

    #[test]
    fn incompressible_blocks_are_stored_with_one_byte_overhead() {
        let mut rng = Rng::seed_from_u64(5);
        let raw = rand_bytes(&mut rng, 8192);
        let framed = compress_block(&raw);
        assert!(framed.len() <= raw.len() + 1);
        if framed[0] == FLAG_STORED {
            assert_eq!(framed.len(), raw.len() + 1);
        }
        assert_eq!(decompress_block(&framed, raw.len()).unwrap(), raw);
    }

    #[test]
    fn corrupt_streams_error_not_panic() {
        // empty frame
        assert!(decompress_block(&[], 4).is_err());
        // unknown flag
        assert!(decompress_block(&[9, 0, 0], 2).is_err());
        // stored length mismatch
        assert!(decompress_block(&[FLAG_STORED, 1, 2], 3).is_err());
        // wrong declared raw_len for a valid stream
        let framed = compress_block(&[7u8; 1000]);
        assert!(decompress_block(&framed, 999).is_err());
        assert!(decompress_block(&framed, 1001).is_err());
        // truncated / bit-flipped LZ payloads must error cleanly
        let floats: Vec<u8> = (0..4096u32).flat_map(|i| (i as f32).to_le_bytes()).collect();
        let good = compress_block(&floats);
        assert_eq!(good[0], FLAG_SHUFFLE_LZ);
        for cut in [1usize, 2, good.len() / 2, good.len() - 1] {
            let _ = decompress_block(&good[..cut], floats.len()); // may Err; must not panic
        }
        for flip in [1usize, 5, good.len() / 3] {
            let mut bad = good.clone();
            bad[flip] ^= 0xff;
            let _ = decompress_block(&bad, floats.len()); // may Err or decode junk of right length; must not panic
        }
    }

    #[test]
    fn compression_names_and_ids_roundtrip() {
        for c in [Compression::None, Compression::ShuffleLz] {
            assert_eq!(Compression::from_u8(c.to_u8()).unwrap(), c);
            assert_eq!(c.as_str().parse::<Compression>().unwrap(), c);
            assert_eq!(format!("{c}"), c.as_str());
        }
        assert!(Compression::from_u8(7).is_err());
        assert!("zstd".parse::<Compression>().is_err());
    }
}
