//! FLAIR substitute (paper App. C.7): multi-label classification with 17
//! coarse labels over features (stand-in for pretrained-ResNet18
//! embeddings), natural user partition with *heavy-tailed* user sizes —
//! the dispersion that makes the scheduling experiments (App. B.6,
//! Figs. 4-5, Table 5) meaningful.
//!
//! Generative process: each label has a prototype direction in feature
//! space; each user has a label-propensity vector (Dirichlet — strong
//! heterogeneity like real FLAIR user photo collections); an example
//! activates labels by propensity, x = Σ active prototypes + user bias +
//! noise, y = the active multi-hot set.

use super::{partition::lognormal_size_partition, FederatedDataset, UserData};
use crate::util::rng::Rng;

pub const FEAT: usize = 192;
pub const LABELS: usize = 17;

pub struct SynthFlair {
    pub num_users: usize,
    pub max_images: usize,
    /// None => IID (fixed size, global label prior); Some(alpha) =>
    /// natural heterogeneous partition.
    pub dirichlet_alpha: Option<f64>,
    pub iid_per_user: usize,
    pub eval_examples: usize,
    pub noise: f32,
    seed: u64,
    prototypes: Vec<f32>, // LABELS x FEAT
    sizes: Vec<usize>,
}

impl SynthFlair {
    pub fn new(num_users: usize, dirichlet_alpha: Option<f64>, seed: u64) -> Self {
        let mut rng = Rng::seed_from_u64(seed ^ 0xF1A1_0017);
        let mut prototypes = vec![0f32; LABELS * FEAT];
        for v in prototypes.iter_mut() {
            *v = rng.normal() as f32;
        }
        // normalize prototypes to unit norm
        for l in 0..LABELS {
            let row = &mut prototypes[l * FEAT..(l + 1) * FEAT];
            let n = crate::util::l2_norm(row) as f32;
            for v in row.iter_mut() {
                *v /= n.max(1e-6);
            }
        }
        let sizes = if dirichlet_alpha.is_some() {
            // FLAIR-like dispersion: median ~20, tail to max_images
            lognormal_size_partition(num_users, 3.0, 1.2, 512, seed)
        } else {
            vec![50; num_users]
        };
        SynthFlair {
            num_users,
            max_images: 512,
            dirichlet_alpha,
            iid_per_user: 50,
            eval_examples: 2000,
            noise: 0.6,
            seed,
            prototypes,
            sizes,
        }
    }

    pub fn paper_iid(num_users: usize, seed: u64) -> Self {
        Self::new(num_users, None, seed)
    }

    pub fn paper_noniid(num_users: usize, seed: u64) -> Self {
        Self::new(num_users, Some(0.3), seed)
    }

    fn gen(&self, rng: &mut Rng, n: usize, propensity: Option<&[f64]>) -> UserData {
        let mut x = vec![0f32; n * FEAT];
        let mut y = vec![0f32; n * LABELS];
        // user-level bias vector (heterogeneity in feature space)
        let mut bias = vec![0f32; FEAT];
        if propensity.is_some() {
            for v in bias.iter_mut() {
                *v = 0.3 * rng.normal() as f32;
            }
        }
        for i in 0..n {
            let xi = &mut x[i * FEAT..(i + 1) * FEAT];
            xi.copy_from_slice(&bias);
            let mut active = 0;
            for l in 0..LABELS {
                let p = match propensity {
                    Some(pr) => (pr[l] * 4.0).min(0.9),
                    None => 0.15,
                };
                if rng.f64() < p {
                    y[i * LABELS + l] = 1.0;
                    active += 1;
                    let proto = &self.prototypes[l * FEAT..(l + 1) * FEAT];
                    crate::util::add_assign(xi, proto);
                }
            }
            if active == 0 {
                // guarantee at least one label (FLAIR images always have one)
                let l = rng.below(LABELS);
                y[i * LABELS + l] = 1.0;
                let proto = &self.prototypes[l * FEAT..(l + 1) * FEAT];
                crate::util::add_assign(xi, proto);
            }
            for v in xi.iter_mut() {
                *v += self.noise * rng.normal() as f32;
            }
        }
        UserData::Features { x, y, feat: FEAT, labels: LABELS }
    }
}

impl FederatedDataset for SynthFlair {
    fn name(&self) -> &str {
        if self.dirichlet_alpha.is_some() {
            "synth-flair"
        } else {
            "synth-flair-iid"
        }
    }

    fn num_users(&self) -> usize {
        self.num_users
    }

    fn user_data(&self, uid: usize) -> UserData {
        let mut rng = Rng::seed_from_u64(self.seed ^ (uid as u64).wrapping_mul(0x5851_F42D));
        let propensity = self.dirichlet_alpha.map(|alpha| {
            let mut prng = Rng::seed_from_u64(self.seed ^ (uid as u64).wrapping_mul(0x2545_F491) ^ 0x11);
            prng.dirichlet(alpha, LABELS)
        });
        let n = self.user_len(uid);
        self.gen(&mut rng, n, propensity.as_deref())
    }

    fn user_len(&self, uid: usize) -> usize {
        if self.dirichlet_alpha.is_some() {
            self.sizes[uid].min(self.max_images)
        } else {
            self.iid_per_user
        }
    }

    fn central_eval(&self, shard_size: usize) -> Vec<UserData> {
        let mut rng = Rng::seed_from_u64(self.seed ^ 0xEEE2);
        let mut shards = Vec::new();
        let mut remaining = self.eval_examples;
        while remaining > 0 {
            let n = remaining.min(shard_size);
            shards.push(self.gen(&mut rng, n, None));
            remaining -= n;
        }
        shards
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn heterogeneous_sizes_are_heavy_tailed() {
        let d = SynthFlair::paper_noniid(500, 3);
        let sizes: Vec<usize> = (0..500).map(|u| d.user_len(u)).collect();
        let mean = sizes.iter().sum::<usize>() as f64 / 500.0;
        let mut sorted = sizes.clone();
        sorted.sort_unstable();
        let median = sorted[250] as f64;
        assert!(mean > median * 1.2, "mean {mean} median {median}");
        assert!(*sorted.last().unwrap() > 100);
        assert!(sorted[0] >= 1);
    }

    #[test]
    fn iid_sizes_are_fixed() {
        let d = SynthFlair::paper_iid(100, 3);
        assert!((0..100).all(|u| d.user_len(u) == 50));
    }

    #[test]
    fn every_example_has_a_label() {
        let d = SynthFlair::paper_noniid(50, 5);
        let u = d.user_data(7);
        if let UserData::Features { y, labels, .. } = &u {
            for row in y.chunks(*labels) {
                assert!(row.iter().sum::<f32>() >= 1.0);
            }
        } else {
            panic!("wrong variant");
        }
        assert_eq!(u.len(), d.user_len(7));
    }

    #[test]
    fn user_data_matches_len_and_is_deterministic() {
        let d = SynthFlair::paper_noniid(50, 5);
        for uid in [0, 13, 49] {
            assert_eq!(d.user_data(uid).len(), d.user_len(uid));
        }
        match (d.user_data(13), d.user_data(13)) {
            (UserData::Features { x: a, .. }, UserData::Features { x: b, .. }) => {
                assert_eq!(a, b)
            }
            _ => unreachable!(),
        }
    }

    #[test]
    fn eval_total() {
        let d = SynthFlair::paper_iid(10, 0);
        let total: usize = d.central_eval(128).iter().map(|s| s.len()).sum();
        assert_eq!(total, d.eval_examples);
    }
}
