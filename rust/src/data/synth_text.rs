//! StackOverflow substitute (paper App. C.6): next-word prediction over a
//! user-keyed corpus. Zipf-distributed 10k vocab, per-user topic mixture
//! over latent bigram dynamics (natural non-IID), user sizes capped at 64
//! sentences / 1600 tokens like the paper's Table 9.
//!
//! Generative process: K latent "topics", each a deterministic affine
//! bigram map next = (a*cur + b) mod V' perturbed by Zipf unigram noise.
//! A transformer can learn the per-topic dynamics, so perplexity falls
//! well below the unigram baseline — giving the benchmark a real learning
//! signal at zero storage cost.

use super::{FederatedDataset, UserData};
use crate::util::rng::{Rng, Zipf};

pub const VOCAB: usize = 10_000;
pub const SEQ: usize = 20;
pub const PAD: i32 = 0;
pub const TOPICS: usize = 8;

pub struct SynthText {
    pub num_users: usize,
    pub max_sentences: usize,
    pub max_tokens: usize,
    pub eval_examples: usize,
    pub vocab: usize,
    pub seq_len: usize,
    seed: u64,
    zipf: Zipf,
    topic_params: Vec<(u64, u64)>, // (a, b) per topic
    sizes: Vec<usize>,             // sentences per user
}

impl SynthText {
    pub fn new(num_users: usize, seed: u64) -> Self {
        Self::with_shape(num_users, VOCAB, SEQ, seed)
    }

    /// Custom vocab/seq (used by the LLM-benchmark variant).
    pub fn with_shape(num_users: usize, vocab: usize, seq_len: usize, seed: u64) -> Self {
        let mut rng = Rng::seed_from_u64(seed ^ 0x5071_EE7Du64);
        let topic_params = (0..TOPICS)
            .map(|_| {
                (
                    1 + 2 * (rng.next_u64() % (vocab as u64 / 2)), // odd multiplier
                    rng.next_u64() % vocab as u64,
                )
            })
            .collect();
        // sentence counts: heavy-tailed, capped (Table 9: max 64 sentences)
        let sizes = (0..num_users)
            .map(|_| (rng.lognormal(2.0, 1.0).ceil() as usize).clamp(1, 64))
            .collect();
        SynthText {
            num_users,
            max_sentences: 64,
            max_tokens: 1600,
            eval_examples: 1024,
            vocab,
            seq_len,
            seed,
            zipf: Zipf::new(vocab - 1, 1.1),
            topic_params,
            sizes,
        }
    }

    fn gen_sentences(&self, rng: &mut Rng, n: usize, mixture: &[f64]) -> UserData {
        let sl = self.seq_len;
        let mut seqs = vec![PAD; n * sl];
        for s in 0..n {
            // pick topic from the user mixture
            let u = rng.f64();
            let mut topic = TOPICS - 1;
            let mut acc = 0.0;
            for (k, p) in mixture.iter().enumerate() {
                acc += p;
                if u < acc {
                    topic = k;
                    break;
                }
            }
            let (a, b) = self.topic_params[topic];
            let len = 3 + rng.below(sl - 3) + 1; // in [4, sl]
            let mut cur = 1 + self.zipf.sample(rng) as u64; // ids in [1, V)
            for t in 0..len.min(sl) {
                seqs[s * sl + t] = cur as i32;
                // bigram dynamics with unigram noise
                cur = if rng.f64() < 0.8 {
                    1 + (a.wrapping_mul(cur).wrapping_add(b)) % (self.vocab as u64 - 1)
                } else {
                    1 + self.zipf.sample(rng) as u64
                };
            }
        }
        UserData::Tokens { seqs, seq_len: sl }
    }

    fn user_mixture(&self, uid: usize) -> Vec<f64> {
        let mut rng = Rng::seed_from_u64(self.seed ^ (uid as u64).wrapping_mul(0x0DDB_1A5E) ^ 0x22);
        rng.dirichlet(0.3, TOPICS)
    }
}

impl FederatedDataset for SynthText {
    fn name(&self) -> &str {
        "synth-stackoverflow"
    }

    fn num_users(&self) -> usize {
        self.num_users
    }

    fn user_data(&self, uid: usize) -> UserData {
        let mut rng = Rng::seed_from_u64(self.seed ^ (uid as u64).wrapping_mul(0x94D0_49BB));
        let n = self.user_len(uid);
        let mixture = self.user_mixture(uid);
        self.gen_sentences(&mut rng, n, &mixture)
    }

    fn user_len(&self, uid: usize) -> usize {
        // token cap (Table 9: max 1600 tokens per user)
        self.sizes[uid].min(self.max_tokens / self.seq_len)
    }

    fn central_eval(&self, shard_size: usize) -> Vec<UserData> {
        let mut rng = Rng::seed_from_u64(self.seed ^ 0xEEE3);
        let uniform = vec![1.0 / TOPICS as f64; TOPICS];
        let mut shards = Vec::new();
        let mut remaining = self.eval_examples;
        while remaining > 0 {
            let n = remaining.min(shard_size);
            shards.push(self.gen_sentences(&mut rng, n, &uniform));
            remaining -= n;
        }
        shards
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn token_ranges_and_padding() {
        let d = SynthText::new(100, 11);
        let u = d.user_data(5);
        if let UserData::Tokens { seqs, seq_len } = &u {
            assert_eq!(*seq_len, SEQ);
            assert!(seqs.iter().all(|&t| (0..VOCAB as i32).contains(&t)));
            // every sentence starts with a non-pad token
            for row in seqs.chunks(*seq_len) {
                assert_ne!(row[0], PAD);
            }
        } else {
            panic!("wrong variant");
        }
    }

    #[test]
    fn sizes_capped_by_tokens() {
        let d = SynthText::new(1000, 1);
        for uid in 0..1000 {
            assert!(d.user_len(uid) * SEQ <= 1600);
            assert!(d.user_len(uid) >= 1);
        }
    }

    #[test]
    fn bigram_structure_is_learnable() {
        // adjacent-token pairs should repeat far more often than chance
        let d = SynthText::new(50, 3);
        let mut pair_counts = std::collections::HashMap::new();
        let mut total_pairs = 0u32;
        for uid in 0..50 {
            if let UserData::Tokens { seqs, seq_len } = d.user_data(uid) {
                for row in seqs.chunks(seq_len) {
                    for w in row.windows(2) {
                        if w[0] != PAD && w[1] != PAD {
                            *pair_counts.entry((w[0], w[1])).or_insert(0u32) += 1;
                            total_pairs += 1;
                        }
                    }
                }
            }
        }
        let repeated: u32 = pair_counts.values().filter(|&&c| c > 1).sum();
        // with pure uniform-random pairs over 10k^2 the repeat rate would
        // be ~0; the topic bigrams make many pairs recur
        assert!(
            repeated as f64 / total_pairs as f64 > 0.1,
            "repeat rate {}",
            repeated as f64 / total_pairs as f64
        );
    }

    #[test]
    fn users_have_distinct_topic_mixtures() {
        let d = SynthText::new(10, 9);
        let m0 = d.user_mixture(0);
        let m1 = d.user_mixture(1);
        assert_ne!(m0, m1);
        assert!((m0.iter().sum::<f64>() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn eval_deterministic() {
        let d = SynthText::new(10, 4);
        let a = d.central_eval(64);
        let b = d.central_eval(64);
        match (&a[0], &b[0]) {
            (UserData::Tokens { seqs: x, .. }, UserData::Tokens { seqs: y, .. }) => {
                assert_eq!(x, y)
            }
            _ => unreachable!(),
        }
    }
}
