//! CIFAR10 substitute (paper App. C.5): 10-class 32x32x3 images,
//! 1000 users x 50 datapoints, IID or Dirichlet(0.1) label-skew.
//!
//! Generative process: each class has a fixed random template image
//! (drawn from the dataset seed); an example is template[class] + noise.
//! This keeps the learning problem real (a CNN must separate 10 smooth
//! templates under noise, accuracy climbs from 10% chance toward the
//! 60-70% range at the paper's hyper-parameters depending on noise) while
//! costing nothing to store.

use super::{FederatedDataset, UserData};
use crate::util::rng::Rng;

pub const HWC: usize = 32 * 32 * 3;
pub const CLASSES: usize = 10;

pub struct SynthCifar {
    pub num_users: usize,
    pub per_user: usize,
    pub noise: f32,
    /// None => IID; Some(alpha) => per-user Dirichlet(alpha) class skew.
    pub dirichlet_alpha: Option<f64>,
    pub eval_examples: usize,
    seed: u64,
    templates: Vec<f32>, // CLASSES x HWC
}

impl SynthCifar {
    pub fn new(num_users: usize, per_user: usize, dirichlet_alpha: Option<f64>, seed: u64) -> Self {
        let mut rng = Rng::seed_from_u64(seed ^ 0xC1FA_0010);
        let mut templates = vec![0f32; CLASSES * HWC];
        // smooth low-frequency templates: random per-channel sinusoids
        for c in 0..CLASSES {
            let fx = rng.range_f64(0.5, 3.0);
            let fy = rng.range_f64(0.5, 3.0);
            let phase = rng.range_f64(0.0, std::f64::consts::TAU);
            let amp = rng.range_f64(0.5, 1.0);
            for yy in 0..32 {
                for xx in 0..32 {
                    for ch in 0..3 {
                        let v = amp
                            * ((fx * xx as f64 / 32.0 * std::f64::consts::TAU
                                + fy * yy as f64 / 32.0 * std::f64::consts::TAU
                                + phase
                                + ch as f64)
                                .sin());
                        templates[c * HWC + (yy * 32 + xx) * 3 + ch] = v as f32;
                    }
                }
            }
        }
        SynthCifar {
            num_users,
            per_user,
            noise: 0.8,
            dirichlet_alpha,
            eval_examples: 2000,
            seed,
            templates,
        }
    }

    /// The paper's benchmark population: 50000/50 = 1000 users.
    pub fn paper_iid(seed: u64) -> Self {
        Self::new(1000, 50, None, seed)
    }

    pub fn paper_noniid(seed: u64) -> Self {
        Self::new(1000, 50, Some(0.1), seed)
    }

    fn class_probs(&self, uid: usize) -> Option<Vec<f64>> {
        self.dirichlet_alpha.map(|alpha| {
            let mut rng = Rng::seed_from_u64(self.seed ^ (uid as u64).wrapping_mul(0xABCD_1234) ^ 0xD1A1);
            rng.dirichlet(alpha, CLASSES)
        })
    }

    fn sample_class(&self, rng: &mut Rng, probs: &Option<Vec<f64>>) -> usize {
        match probs {
            None => rng.below(CLASSES),
            Some(p) => {
                let u = rng.f64();
                let mut acc = 0.0;
                for (i, pi) in p.iter().enumerate() {
                    acc += pi;
                    if u < acc {
                        return i;
                    }
                }
                CLASSES - 1
            }
        }
    }

    fn gen(&self, rng: &mut Rng, n: usize, probs: &Option<Vec<f64>>) -> UserData {
        let mut x = vec![0f32; n * HWC];
        let mut y = vec![0i32; n];
        for i in 0..n {
            let c = self.sample_class(rng, probs);
            y[i] = c as i32;
            let t = &self.templates[c * HWC..(c + 1) * HWC];
            for (dst, src) in x[i * HWC..(i + 1) * HWC].iter_mut().zip(t) {
                *dst = *src + self.noise * rng.normal() as f32;
            }
        }
        UserData::Image { x, y, hwc: HWC }
    }
}

impl FederatedDataset for SynthCifar {
    fn name(&self) -> &str {
        if self.dirichlet_alpha.is_some() {
            "synth-cifar10"
        } else {
            "synth-cifar10-iid"
        }
    }

    fn num_users(&self) -> usize {
        self.num_users
    }

    fn user_data(&self, uid: usize) -> UserData {
        let mut rng = Rng::seed_from_u64(self.seed ^ (uid as u64).wrapping_mul(0x9E37_79B9));
        let probs = self.class_probs(uid);
        self.gen(&mut rng, self.per_user, &probs)
    }

    fn user_len(&self, _uid: usize) -> usize {
        self.per_user
    }

    fn central_eval(&self, shard_size: usize) -> Vec<UserData> {
        let mut rng = Rng::seed_from_u64(self.seed ^ 0xEEE1);
        let mut shards = Vec::new();
        let mut remaining = self.eval_examples;
        while remaining > 0 {
            let n = remaining.min(shard_size);
            shards.push(self.gen(&mut rng, n, &None));
            remaining -= n;
        }
        shards
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_determinism() {
        let d = SynthCifar::new(10, 50, None, 7);
        let u = d.user_data(3);
        assert_eq!(u.len(), 50);
        if let UserData::Image { x, y, hwc } = &u {
            assert_eq!(*hwc, HWC);
            assert_eq!(x.len(), 50 * HWC);
            assert!(y.iter().all(|&c| (0..10).contains(&c)));
        } else {
            panic!("wrong variant");
        }
        // regeneration is identical
        let u2 = d.user_data(3);
        match (&u, &u2) {
            (UserData::Image { x: a, .. }, UserData::Image { x: b, .. }) => assert_eq!(a, b),
            _ => unreachable!(),
        }
    }

    #[test]
    fn iid_users_cover_classes_noniid_users_skew() {
        let iid = SynthCifar::new(50, 50, None, 1);
        let niid = SynthCifar::new(50, 50, Some(0.1), 1);
        let count_classes = |u: &UserData| -> usize {
            if let UserData::Image { y, .. } = u {
                let set: std::collections::HashSet<_> = y.iter().collect();
                set.len()
            } else {
                0
            }
        };
        let mean_iid: f64 = (0..20).map(|u| count_classes(&iid.user_data(u)) as f64).sum::<f64>() / 20.0;
        let mean_niid: f64 = (0..20).map(|u| count_classes(&niid.user_data(u)) as f64).sum::<f64>() / 20.0;
        assert!(mean_iid > 8.5, "iid class coverage {mean_iid}");
        assert!(mean_niid < mean_iid - 2.0, "non-iid should be skewed: {mean_niid} vs {mean_iid}");
    }

    #[test]
    fn eval_shards_cover_request() {
        let d = SynthCifar::new(10, 50, None, 2);
        let shards = d.central_eval(256);
        let total: usize = shards.iter().map(|s| s.len()).sum();
        assert_eq!(total, d.eval_examples);
        assert!(shards.iter().all(|s| s.len() <= 256));
    }

    #[test]
    fn paper_presets() {
        let d = SynthCifar::paper_iid(0);
        assert_eq!(d.num_users(), 1000);
        assert_eq!(d.user_len(5), 50);
    }
}
