//! Out-of-core sharded dataset store: materialized user data on disk,
//! read back through a bounded LRU cache with dispatcher-driven
//! prefetch. See DESIGN.md §6 for the architecture.
//!
//! The synthetic generators in this module's siblings cost no memory
//! because user data is a pure function of (seed, uid) — but that also
//! means every simulated dataset is formulaic. pfl-research's answer
//! for *realistic* datasets is to keep user-dataset loading off the
//! critical path on a separate thread; this module reproduces that
//! design for populations whose data is materialized and does not fit
//! in RAM:
//!
//! * [`ShardWriter`] / [`materialize`] write any [`FederatedDataset`]
//!   to a directory of binary shards (the `pfl materialize`
//!   subcommand): each shard has a fixed header, and `index.bin` holds
//!   the per-user (shard, offset, length, examples) index, so reading
//!   one user costs a single positioned read.
//! * [`ShardedStore`] opens a store directory and implements
//!   [`FederatedDataset`] over it — bit-identical to the generator it
//!   was materialized from (property-tested in
//!   `rust/tests/property_invariants.rs`), so every downstream layer
//!   is unchanged.
//! * [`StoreSource`] wraps a store in the [`UserDataSource`] interface
//!   the workers consume: a bounded LRU user cache (a hit allocates
//!   nothing — asserted by `benches/data_store.rs`) plus a background
//!   prefetch thread that consumes the *dispatcher's* upcoming-uid
//!   order ([`UserDataSource::hint_round`]: the static LPT schedule,
//!   the work-stealing shared-queue order, and the async streaming
//!   order all feed it) and stays at most `prefetch_depth` users ahead
//!   of worker consumption, so disk I/O overlaps local training
//!   exactly as pfl-research keeps loading off the critical path.
//!
//! Observability: every fetch reports hit/miss and the nanoseconds the
//! worker spent blocked on a miss; workers fold these into
//! [`crate::simsys::Counters`] (`cache_hits`, `cache_misses`,
//! `prefetch_stall_nanos`) and the backend emits the per-round
//! `sys/cache-hit-frac` metric.

use std::collections::{HashMap, VecDeque};
use std::fs::File;
use std::io::{BufWriter, Read, Write};
use std::os::unix::fs::FileExt;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Condvar, Mutex, PoisonError};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use super::{FederatedDataset, UserData};

/// Store format version; any layout change bumps it and readers reject
/// mismatches instead of misparsing.
const VERSION: u32 = 1;
const INDEX_MAGIC: &[u8; 8] = b"PFLSIDX1";
const SHARD_MAGIC: &[u8; 8] = b"PFLSHRD1";
const EVAL_MAGIC: &[u8; 8] = b"PFLSEVL1";
/// Bytes of fixed shard header preceding the first user blob.
const SHARD_HEADER_LEN: u64 = 8 + 4 + 4;

fn shard_file_name(shard: u32) -> String {
    format!("shard_{shard:05}.bin")
}

// ----------------------------------------------------------------------
// Blob encoding: one self-describing record per user (or eval shard)
// ----------------------------------------------------------------------

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_f32s(buf: &mut Vec<u8>, v: &[f32]) {
    for x in v {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

fn put_i32s(buf: &mut Vec<u8>, v: &[i32]) {
    for x in v {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

/// Byte cursor over an encoded blob.
struct Cur<'a> {
    b: &'a [u8],
    p: usize,
}

impl<'a> Cur<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self.p.checked_add(n).ok_or_else(|| anyhow!("blob offset overflow"))?;
        if end > self.b.len() {
            bail!("truncated blob: want {n} bytes at {}, have {}", self.p, self.b.len());
        }
        let s = &self.b[self.p..end];
        self.p = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        let s = self.take(4)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    fn f32s(&mut self, n: usize) -> Result<Vec<f32>> {
        let s = self.take(n.checked_mul(4).ok_or_else(|| anyhow!("blob length overflow"))?)?;
        Ok(s.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
    }

    fn i32s(&mut self, n: usize) -> Result<Vec<i32>> {
        let s = self.take(n.checked_mul(4).ok_or_else(|| anyhow!("blob length overflow"))?)?;
        Ok(s.chunks_exact(4).map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
    }
}

/// Encode one [`UserData`] record. f32/i32 payloads are stored as raw
/// little-endian bits, so a round trip is bit-exact (NaNs included).
fn encode_user_data(d: &UserData, buf: &mut Vec<u8>) {
    match d {
        UserData::Image { x, y, hwc } => {
            buf.push(0);
            put_u32(buf, *hwc as u32);
            put_u32(buf, y.len() as u32);
            put_u32(buf, x.len() as u32);
            put_i32s(buf, y);
            put_f32s(buf, x);
        }
        UserData::Features { x, y, feat, labels } => {
            buf.push(1);
            put_u32(buf, *feat as u32);
            put_u32(buf, *labels as u32);
            put_u32(buf, x.len() as u32);
            put_u32(buf, y.len() as u32);
            put_f32s(buf, x);
            put_f32s(buf, y);
        }
        UserData::Tokens { seqs, seq_len } => {
            buf.push(2);
            put_u32(buf, *seq_len as u32);
            put_u32(buf, seqs.len() as u32);
            put_i32s(buf, seqs);
        }
        UserData::Tabular { x, y, dim } => {
            buf.push(3);
            put_u32(buf, *dim as u32);
            put_u32(buf, x.len() as u32);
            put_u32(buf, y.len() as u32);
            put_f32s(buf, x);
            put_f32s(buf, y);
        }
        UserData::Points { x, dim } => {
            buf.push(4);
            put_u32(buf, *dim as u32);
            put_u32(buf, x.len() as u32);
            put_f32s(buf, x);
        }
    }
}

fn decode_user_data(b: &[u8]) -> Result<UserData> {
    let mut c = Cur { b, p: 0 };
    let d = match c.u8()? {
        0 => {
            let hwc = c.u32()? as usize;
            let ny = c.u32()? as usize;
            let nx = c.u32()? as usize;
            UserData::Image { y: c.i32s(ny)?, x: c.f32s(nx)?, hwc }
        }
        1 => {
            let feat = c.u32()? as usize;
            let labels = c.u32()? as usize;
            let nx = c.u32()? as usize;
            let ny = c.u32()? as usize;
            UserData::Features { x: c.f32s(nx)?, y: c.f32s(ny)?, feat, labels }
        }
        2 => {
            let seq_len = c.u32()? as usize;
            let n = c.u32()? as usize;
            UserData::Tokens { seqs: c.i32s(n)?, seq_len }
        }
        3 => {
            let dim = c.u32()? as usize;
            let nx = c.u32()? as usize;
            let ny = c.u32()? as usize;
            UserData::Tabular { x: c.f32s(nx)?, y: c.f32s(ny)?, dim }
        }
        4 => {
            let dim = c.u32()? as usize;
            let nx = c.u32()? as usize;
            UserData::Points { x: c.f32s(nx)?, dim }
        }
        t => bail!("unknown UserData tag {t}"),
    };
    if c.p != b.len() {
        bail!("trailing bytes in blob: consumed {}, have {}", c.p, b.len());
    }
    Ok(d)
}

// ----------------------------------------------------------------------
// Writer
// ----------------------------------------------------------------------

/// One user's location in the store.
#[derive(Debug, Clone, Copy)]
struct IndexEntry {
    shard: u32,
    offset: u64,
    len: u32,
    examples: u32,
}

/// Materialization summary returned by [`ShardWriter::finish`].
#[derive(Debug, Clone, Copy)]
pub struct StoreStats {
    pub num_users: usize,
    pub num_shards: usize,
    /// Total user-payload bytes across all shard files (headers excluded).
    pub data_bytes: u64,
    /// Central-eval shards materialized alongside the users.
    pub eval_shards: usize,
}

struct CurShard {
    idx: u32,
    w: BufWriter<File>,
    off: u64,
}

/// Sequential store writer: `append_user` in uid order (uid 0, 1, ...),
/// optionally `write_eval`, then `finish` to seal the index. Users land
/// in shard `uid / users_per_shard`, so a shard is one contiguous write
/// and one uid range. Any existing store in `dir` is overwritten.
pub struct ShardWriter {
    dir: PathBuf,
    users_per_shard: usize,
    cur: Option<CurShard>,
    index: Vec<IndexEntry>,
    data_bytes: u64,
    eval_shards: usize,
    buf: Vec<u8>,
}

impl ShardWriter {
    pub fn create(dir: &Path, users_per_shard: usize) -> Result<Self> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating store dir {}", dir.display()))?;
        Ok(ShardWriter {
            dir: dir.to_path_buf(),
            users_per_shard: users_per_shard.max(1),
            cur: None,
            index: Vec::new(),
            data_bytes: 0,
            eval_shards: 0,
            buf: Vec::new(),
        })
    }

    fn close_shard(&mut self) -> Result<()> {
        if let Some(mut c) = self.cur.take() {
            c.w.flush().context("flushing shard")?;
        }
        Ok(())
    }

    /// Append the next user (uid = number of users appended so far).
    pub fn append_user(&mut self, data: &UserData) -> Result<()> {
        let uid = self.index.len();
        let shard = (uid / self.users_per_shard) as u32;
        if self.cur.as_ref().map(|c| c.idx) != Some(shard) {
            self.close_shard()?;
            let path = self.dir.join(shard_file_name(shard));
            let f = File::create(&path)
                .with_context(|| format!("creating shard {}", path.display()))?;
            let mut w = BufWriter::new(f);
            w.write_all(SHARD_MAGIC)?;
            w.write_all(&VERSION.to_le_bytes())?;
            w.write_all(&shard.to_le_bytes())?;
            self.cur = Some(CurShard { idx: shard, w, off: SHARD_HEADER_LEN });
        }
        self.buf.clear();
        encode_user_data(data, &mut self.buf);
        if self.buf.len() > u32::MAX as usize {
            // the index stores blob lengths as u32; a wrapped length
            // would silently corrupt the store
            bail!("user {uid} encodes to {} bytes (> u32::MAX)", self.buf.len());
        }
        let c = self.cur.as_mut().unwrap();
        c.w.write_all(&self.buf).with_context(|| format!("writing user {uid}"))?;
        self.index.push(IndexEntry {
            shard,
            offset: c.off,
            len: self.buf.len() as u32,
            examples: data.len() as u32,
        });
        c.off += self.buf.len() as u64;
        self.data_bytes += self.buf.len() as u64;
        Ok(())
    }

    /// Materialize the central-eval shards (`eval.bin`). The shard size
    /// is fixed at materialization time; [`ShardedStore::central_eval`]
    /// returns these shards as stored.
    pub fn write_eval(&mut self, shards: &[UserData]) -> Result<()> {
        let path = self.dir.join("eval.bin");
        let f = File::create(&path).with_context(|| format!("creating {}", path.display()))?;
        let mut w = BufWriter::new(f);
        w.write_all(EVAL_MAGIC)?;
        w.write_all(&VERSION.to_le_bytes())?;
        w.write_all(&(shards.len() as u32).to_le_bytes())?;
        for (i, s) in shards.iter().enumerate() {
            self.buf.clear();
            encode_user_data(s, &mut self.buf);
            if self.buf.len() > u32::MAX as usize {
                bail!("eval shard {i} encodes to {} bytes (> u32::MAX)", self.buf.len());
            }
            w.write_all(&(self.buf.len() as u32).to_le_bytes())?;
            w.write_all(&self.buf)?;
        }
        w.flush().context("flushing eval.bin")?;
        self.eval_shards = shards.len();
        Ok(())
    }

    /// Seal the store: flush the open shard and write `index.bin`.
    pub fn finish(mut self, name: &str) -> Result<StoreStats> {
        self.close_shard()?;
        let num_shards = self.index.last().map(|e| e.shard as usize + 1).unwrap_or(0);
        let path = self.dir.join("index.bin");
        let f = File::create(&path).with_context(|| format!("creating {}", path.display()))?;
        let mut w = BufWriter::new(f);
        w.write_all(INDEX_MAGIC)?;
        w.write_all(&VERSION.to_le_bytes())?;
        w.write_all(&(num_shards as u32).to_le_bytes())?;
        w.write_all(&(self.users_per_shard as u32).to_le_bytes())?;
        w.write_all(&(name.len() as u32).to_le_bytes())?;
        w.write_all(name.as_bytes())?;
        w.write_all(&(self.index.len() as u64).to_le_bytes())?;
        for e in &self.index {
            w.write_all(&e.shard.to_le_bytes())?;
            w.write_all(&e.offset.to_le_bytes())?;
            w.write_all(&e.len.to_le_bytes())?;
            w.write_all(&e.examples.to_le_bytes())?;
        }
        w.flush().context("flushing index.bin")?;
        Ok(StoreStats {
            num_users: self.index.len(),
            num_shards,
            data_bytes: self.data_bytes,
            eval_shards: self.eval_shards,
        })
    }
}

/// Materialize a [`FederatedDataset`] to `dir`: every user in uid order
/// plus (when `eval_shard_size > 0`) the central-eval shards.
pub fn materialize(
    dataset: &dyn FederatedDataset,
    dir: &Path,
    users_per_shard: usize,
    eval_shard_size: usize,
) -> Result<StoreStats> {
    let mut w = ShardWriter::create(dir, users_per_shard)?;
    for uid in 0..dataset.num_users() {
        w.append_user(&dataset.user_data(uid))
            .with_context(|| format!("materializing user {uid}"))?;
    }
    if eval_shard_size > 0 {
        w.write_eval(&dataset.central_eval(eval_shard_size))?;
    }
    w.finish(dataset.name())
}

// ----------------------------------------------------------------------
// Reader
// ----------------------------------------------------------------------

/// An opened store directory. Thread-safe: shard file handles are opened
/// lazily, kept for the store's lifetime, and read with positioned reads
/// (no shared seek cursor), so workers and the prefetch thread read
/// concurrently.
pub struct ShardedStore {
    dir: PathBuf,
    name: String,
    index: Vec<IndexEntry>,
    files: Mutex<HashMap<u32, Arc<File>>>,
}

impl ShardedStore {
    pub fn open(dir: &Path) -> Result<Self> {
        let path = dir.join("index.bin");
        let mut raw = Vec::new();
        File::open(&path)
            .with_context(|| {
                format!("opening {} (is this a `pfl materialize` dir?)", path.display())
            })?
            .read_to_end(&mut raw)
            .with_context(|| format!("reading {}", path.display()))?;
        let mut c = Cur { b: &raw, p: 0 };
        if c.take(8)? != INDEX_MAGIC {
            bail!("{}: bad index magic", path.display());
        }
        let version = c.u32()?;
        if version != VERSION {
            bail!("{}: store version {version}, reader supports {VERSION}", path.display());
        }
        let _num_shards = c.u32()?;
        let _users_per_shard = c.u32()?;
        let name_len = c.u32()? as usize;
        let name = String::from_utf8(c.take(name_len)?.to_vec()).context("store name")?;
        let n = {
            let s = c.take(8)?;
            u64::from_le_bytes([s[0], s[1], s[2], s[3], s[4], s[5], s[6], s[7]]) as usize
        };
        let mut index = Vec::with_capacity(n);
        for _ in 0..n {
            let shard = c.u32()?;
            let offset = {
                let s = c.take(8)?;
                u64::from_le_bytes([s[0], s[1], s[2], s[3], s[4], s[5], s[6], s[7]])
            };
            let len = c.u32()?;
            let examples = c.u32()?;
            index.push(IndexEntry { shard, offset, len, examples });
        }
        Ok(ShardedStore {
            dir: dir.to_path_buf(),
            name,
            index,
            files: Mutex::new(HashMap::new()),
        })
    }

    fn file(&self, shard: u32) -> Result<Arc<File>> {
        let mut files = self.files.lock().unwrap_or_else(PoisonError::into_inner);
        if let Some(f) = files.get(&shard) {
            return Ok(f.clone());
        }
        let path = self.dir.join(shard_file_name(shard));
        let f = File::open(&path).with_context(|| format!("opening {}", path.display()))?;
        let mut header = [0u8; SHARD_HEADER_LEN as usize];
        f.read_exact_at(&mut header, 0)
            .with_context(|| format!("reading {} header", path.display()))?;
        if &header[..8] != SHARD_MAGIC {
            bail!("{}: bad shard magic", path.display());
        }
        let f = Arc::new(f);
        files.insert(shard, f.clone());
        Ok(f)
    }

    /// Read one user straight from disk (no cache — [`StoreSource`]
    /// layers the cache on top).
    pub fn read_user(&self, uid: usize) -> Result<UserData> {
        let e = self
            .index
            .get(uid)
            .copied()
            .ok_or_else(|| anyhow!("uid {uid} out of range ({} users)", self.index.len()))?;
        let f = self.file(e.shard)?;
        let mut buf = vec![0u8; e.len as usize];
        f.read_exact_at(&mut buf, e.offset)
            .with_context(|| format!("reading user {uid} (shard {}, off {})", e.shard, e.offset))?;
        decode_user_data(&buf).with_context(|| format!("decoding user {uid}"))
    }

    fn read_eval(&self) -> Result<Vec<UserData>> {
        let path = self.dir.join("eval.bin");
        if !path.exists() {
            return Ok(Vec::new());
        }
        let mut raw = Vec::new();
        File::open(&path)?.read_to_end(&mut raw)?;
        let mut c = Cur { b: &raw, p: 0 };
        if c.take(8)? != EVAL_MAGIC {
            bail!("{}: bad eval magic", path.display());
        }
        let version = c.u32()?;
        if version != VERSION {
            bail!("{}: eval version {version}, reader supports {VERSION}", path.display());
        }
        let n = c.u32()? as usize;
        let mut shards = Vec::with_capacity(n);
        for i in 0..n {
            let len = c.u32()? as usize;
            shards.push(
                decode_user_data(c.take(len)?).with_context(|| format!("eval shard {i}"))?,
            );
        }
        Ok(shards)
    }
}

impl FederatedDataset for ShardedStore {
    /// The materialized generator's name, so runs over a store report
    /// the same dataset they would have reported over the generator.
    fn name(&self) -> &str {
        &self.name
    }

    fn num_users(&self) -> usize {
        self.index.len()
    }

    /// The trait is infallible (generators cannot fail), so an I/O or
    /// decode error here panics with the store path — a corrupt store
    /// is unrecoverable mid-simulation anyway.
    fn user_data(&self, uid: usize) -> UserData {
        self.read_user(uid)
            .unwrap_or_else(|e| panic!("store {}: {e:#}", self.dir.display()))
    }

    /// Free: the example count comes from the in-memory index, never
    /// from disk — scheduling weights cost no I/O.
    fn user_len(&self, uid: usize) -> usize {
        self.index.get(uid).map(|e| e.examples as usize).unwrap_or(0)
    }

    /// Eval shards as materialized; the shard size was fixed by
    /// `pfl materialize --eval-shard`, so the requested size is ignored.
    fn central_eval(&self, _shard_size: usize) -> Vec<UserData> {
        self.read_eval()
            .unwrap_or_else(|e| panic!("store {}: {e:#}", self.dir.display()))
    }
}

// ----------------------------------------------------------------------
// UserDataSource: the worker-facing fetch interface
// ----------------------------------------------------------------------

/// One fetched user, with the bookkeeping the worker folds into its
/// round [`crate::simsys::Counters`].
pub struct Fetched {
    pub data: Arc<UserData>,
    /// `Some(hit)` for cache-backed sources; `None` when no cache is in
    /// play (generator-backed), so generator runs report no hit-rate.
    pub cache_hit: Option<bool>,
    /// Nanoseconds this call was blocked on I/O (0 on a hit).
    pub stall_nanos: u64,
}

/// Where workers get user data: the lazy synthetic generators
/// ([`GeneratorSource`], the default — no behavior change) or the
/// out-of-core store ([`StoreSource`]). The backend feeds each round's
/// dispatch order to [`Self::hint_round`] so a prefetching source can
/// overlap loads with local training.
pub trait UserDataSource: Send + Sync {
    fn fetch(&self, uid: usize) -> Fetched;

    /// Whether [`Self::hint_round`] is worth calling (lets the backend
    /// skip building the order vector for generator runs).
    fn wants_hints(&self) -> bool {
        false
    }

    /// Announce one round's upcoming uids in dispatch order. Replaces
    /// any previous (possibly abandoned) round's hints.
    fn hint_round(&self, _uids: &[usize]) {}
}

/// The default source: generate lazily from (seed, uid), exactly the
/// pre-store behavior.
pub struct GeneratorSource {
    dataset: Arc<dyn FederatedDataset>,
}

impl GeneratorSource {
    pub fn new(dataset: Arc<dyn FederatedDataset>) -> Self {
        GeneratorSource { dataset }
    }
}

impl UserDataSource for GeneratorSource {
    fn fetch(&self, uid: usize) -> Fetched {
        Fetched {
            data: Arc::new(self.dataset.user_data(uid)),
            cache_hit: None,
            stall_nanos: 0,
        }
    }
}

/// Tuning for a [`StoreSource`] (config `engine.cache_users` /
/// `engine.prefetch_depth`, CLI `--cache-users` / `--prefetch-depth`).
#[derive(Debug, Clone, Copy)]
pub struct SourceConfig {
    /// LRU user-cache capacity (entries).
    pub cache_users: usize,
    /// How many users the prefetch thread may run ahead of worker
    /// consumption (0 disables the thread; the cache remains).
    pub prefetch_depth: usize,
}

impl Default for SourceConfig {
    fn default() -> Self {
        SourceConfig { cache_users: 512, prefetch_depth: 8 }
    }
}

struct CacheEntry {
    data: Arc<UserData>,
    last_used: u64,
}

/// Bounded LRU over `Arc<UserData>`: a hit bumps a tick in place and
/// clones the `Arc` — no allocation. Eviction scans for the least
/// recently used entry (O(capacity), fine for the few-thousand-entry
/// caches this is built for).
struct LruCache {
    cap: usize,
    tick: u64,
    map: HashMap<usize, CacheEntry>,
}

impl LruCache {
    fn new(cap: usize) -> Self {
        let cap = cap.max(1);
        LruCache { cap, tick: 0, map: HashMap::with_capacity(cap + 1) }
    }

    fn get(&mut self, uid: usize) -> Option<Arc<UserData>> {
        self.tick += 1;
        let tick = self.tick;
        let e = self.map.get_mut(&uid)?;
        e.last_used = tick;
        Some(e.data.clone())
    }

    fn contains(&self, uid: usize) -> bool {
        self.map.contains_key(&uid)
    }

    fn insert(&mut self, uid: usize, data: Arc<UserData>) {
        if self.map.contains_key(&uid) {
            return; // fetch and prefetch raced: keep the resident copy
        }
        if self.map.len() >= self.cap {
            let victim = self.map.iter().min_by_key(|(_, e)| e.last_used).map(|(&k, _)| k);
            if let Some(victim) = victim {
                self.map.remove(&victim);
            }
        }
        self.tick += 1;
        self.map.insert(uid, CacheEntry { data, last_used: self.tick });
    }

    fn len(&self) -> usize {
        self.map.len()
    }
}

/// Round-scoped prefetch cursor. `issued - consumed` is how far the
/// prefetch thread has run ahead of the workers; it stalls at
/// `prefetch_depth` and wakes on every worker fetch. `hint_round`
/// resets the cursor, so hints from an abandoned round (async mode
/// moves on when its buffer fills) can never wedge the thread.
#[derive(Default)]
struct PrefetchState {
    upcoming: VecDeque<usize>,
    issued: u64,
    consumed: u64,
    stop: bool,
}

struct PrefetchShared {
    state: Mutex<PrefetchState>,
    cv: Condvar,
}

struct Prefetcher {
    shared: Arc<PrefetchShared>,
    handle: Option<JoinHandle<()>>,
}

/// The cached, prefetching [`UserDataSource`] over a [`ShardedStore`].
pub struct StoreSource {
    store: Arc<ShardedStore>,
    cache: Arc<Mutex<LruCache>>,
    prefetch: Option<Prefetcher>,
}

impl StoreSource {
    pub fn new(store: Arc<ShardedStore>, cfg: SourceConfig) -> Self {
        let cache = Arc::new(Mutex::new(LruCache::new(cfg.cache_users)));
        // a prefetch window wider than the cache would evict its own
        // loads before any worker consumed them — every fetch would
        // then re-read the shard, doubling I/O; clamp to the capacity
        let depth_cap = cfg.prefetch_depth.min(cfg.cache_users.max(1));
        let prefetch = if depth_cap > 0 {
            let shared = Arc::new(PrefetchShared {
                state: Mutex::new(PrefetchState::default()),
                cv: Condvar::new(),
            });
            let (s2, c2, st2) = (shared.clone(), cache.clone(), store.clone());
            let depth = depth_cap as u64;
            let handle = std::thread::Builder::new()
                .name("data-prefetch".into())
                .spawn(move || prefetch_loop(s2, c2, st2, depth))
                .expect("spawning data-prefetch thread");
            Some(Prefetcher { shared, handle: Some(handle) })
        } else {
            None
        };
        StoreSource { store, cache, prefetch }
    }

    /// Resident cache entries (diagnostics / tests).
    pub fn cached_users(&self) -> usize {
        self.cache.lock().unwrap_or_else(PoisonError::into_inner).len()
    }

    fn note_consumed(&self) {
        if let Some(p) = &self.prefetch {
            let mut st = p.shared.state.lock().unwrap_or_else(PoisonError::into_inner);
            st.consumed += 1;
            drop(st);
            p.shared.cv.notify_all();
        }
    }
}

impl UserDataSource for StoreSource {
    fn fetch(&self, uid: usize) -> Fetched {
        if let Some(data) =
            self.cache.lock().unwrap_or_else(PoisonError::into_inner).get(uid)
        {
            self.note_consumed();
            return Fetched { data, cache_hit: Some(true), stall_nanos: 0 };
        }
        // Miss: the worker eats the read latency; that is exactly the
        // stall the prefetcher exists to hide.
        let t0 = Instant::now();
        let data = Arc::new(
            self.store
                .read_user(uid)
                .unwrap_or_else(|e| panic!("store {}: {e:#}", self.store.dir.display())),
        );
        let stall_nanos = t0.elapsed().as_nanos() as u64;
        self.cache
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .insert(uid, data.clone());
        self.note_consumed();
        Fetched { data, cache_hit: Some(false), stall_nanos }
    }

    fn wants_hints(&self) -> bool {
        self.prefetch.is_some()
    }

    fn hint_round(&self, uids: &[usize]) {
        if let Some(p) = &self.prefetch {
            let mut st = p.shared.state.lock().unwrap_or_else(PoisonError::into_inner);
            st.upcoming.clear();
            st.upcoming.extend(uids.iter().copied());
            st.issued = 0;
            st.consumed = 0;
            drop(st);
            p.shared.cv.notify_all();
        }
    }
}

impl Drop for StoreSource {
    fn drop(&mut self) {
        if let Some(p) = &mut self.prefetch {
            {
                let mut st = p.shared.state.lock().unwrap_or_else(PoisonError::into_inner);
                st.stop = true;
            }
            p.shared.cv.notify_all();
            if let Some(h) = p.handle.take() {
                let _ = h.join();
            }
        }
    }
}

fn prefetch_loop(
    shared: Arc<PrefetchShared>,
    cache: Arc<Mutex<LruCache>>,
    store: Arc<ShardedStore>,
    depth: u64,
) {
    loop {
        let uid = {
            let mut st = shared.state.lock().unwrap_or_else(PoisonError::into_inner);
            loop {
                if st.stop {
                    return;
                }
                if !st.upcoming.is_empty() && st.issued < st.consumed + depth {
                    st.issued += 1;
                    break st.upcoming.pop_front().unwrap();
                }
                st = shared.cv.wait(st).unwrap_or_else(PoisonError::into_inner);
            }
        };
        if cache.lock().unwrap_or_else(PoisonError::into_inner).contains(uid) {
            continue; // already resident: the cursor still advances
        }
        // I/O outside every lock, so workers hitting the cache never
        // wait on the disk. A failed read is not fatal here: the
        // worker's own fetch of this uid will surface the error.
        if let Ok(d) = store.read_user(uid) {
            cache
                .lock()
                .unwrap_or_else(PoisonError::into_inner)
                .insert(uid, Arc::new(d));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{SynthGmmPoints, SynthTabular};

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("pfl_store_test_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn bits(d: &UserData) -> Vec<u64> {
        d.bit_fingerprint()
    }

    #[test]
    fn blob_roundtrip_every_variant() {
        let variants = vec![
            UserData::Image { x: vec![0.5, -1.25, f32::MIN_POSITIVE], y: vec![1, -2, 3], hwc: 1 },
            UserData::Features { x: vec![1.0, 2.0], y: vec![0.0, 1.0], feat: 1, labels: 1 },
            UserData::Tokens { seqs: vec![5, 0, -1, 7], seq_len: 2 },
            UserData::Tabular { x: vec![0.25; 6], y: vec![1.5, 2.5], dim: 3 },
            UserData::Points { x: vec![f32::NAN, 1.0], dim: 2 },
            UserData::Points { x: vec![], dim: 3 }, // empty payload
        ];
        for d in &variants {
            let mut buf = Vec::new();
            encode_user_data(d, &mut buf);
            let back = decode_user_data(&buf).unwrap();
            assert_eq!(bits(d), bits(&back));
        }
        // corrupt tag and truncation are errors, not panics
        assert!(decode_user_data(&[9]).is_err());
        let mut buf = Vec::new();
        encode_user_data(&variants[0], &mut buf);
        assert!(decode_user_data(&buf[..buf.len() - 1]).is_err());
    }

    #[test]
    fn materialize_then_read_matches_generator() {
        let dir = tmp_dir("roundtrip");
        let gen = SynthTabular::new(11, 8, 3, 42);
        // odd users_per_shard exercises the multi-shard path
        let stats = materialize(&gen, &dir, 4, 16).unwrap();
        assert_eq!(stats.num_users, 11);
        assert_eq!(stats.num_shards, 3);
        assert!(stats.eval_shards > 0);
        let store = ShardedStore::open(&dir).unwrap();
        assert_eq!(store.name(), gen.name());
        assert_eq!(store.num_users(), 11);
        for uid in 0..11 {
            let (a, b) = (gen.user_data(uid), store.user_data(uid));
            assert_eq!(bits(&a), bits(&b), "user {uid}");
            // user_len comes from the index, free of I/O, and reflects
            // the materialized data
            assert_eq!(store.user_len(uid), a.len());
        }
        let (ea, eb) = (gen.central_eval(16), store.central_eval(16));
        assert_eq!(ea.len(), eb.len());
        for (a, b) in ea.iter().zip(&eb) {
            assert_eq!(bits(a), bits(b));
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn open_rejects_missing_and_garbage() {
        let dir = tmp_dir("garbage");
        assert!(ShardedStore::open(&dir).is_err()); // no index
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("index.bin"), b"not a store").unwrap();
        assert!(ShardedStore::open(&dir).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn lru_evicts_least_recently_used() {
        let d = Arc::new(UserData::Points { x: vec![1.0], dim: 1 });
        let mut c = LruCache::new(2);
        c.insert(1, d.clone());
        c.insert(2, d.clone());
        assert!(c.get(1).is_some()); // 1 is now most recent
        c.insert(3, d.clone()); // evicts 2
        assert!(c.contains(1));
        assert!(!c.contains(2));
        assert!(c.contains(3));
        assert_eq!(c.len(), 2);
        // double insert keeps one entry
        c.insert(3, d);
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn source_counts_hits_misses_and_stalls() {
        let dir = tmp_dir("hitmiss");
        let gen = SynthGmmPoints::new(6, 5, 2, 2, 1);
        materialize(&gen, &dir, 8, 0).unwrap();
        let store = Arc::new(ShardedStore::open(&dir).unwrap());
        let src = StoreSource::new(store, SourceConfig { cache_users: 8, prefetch_depth: 0 });
        let first = src.fetch(3);
        assert_eq!(first.cache_hit, Some(false));
        let second = src.fetch(3);
        assert_eq!(second.cache_hit, Some(true));
        assert_eq!(second.stall_nanos, 0);
        assert_eq!(bits(&first.data), bits(&second.data));
        assert_eq!(bits(&first.data), bits(&gen.user_data(3)));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn prefetcher_runs_ahead_and_respects_depth() {
        let dir = tmp_dir("prefetch");
        let gen = SynthGmmPoints::new(16, 5, 2, 2, 2);
        materialize(&gen, &dir, 8, 0).unwrap();
        let store = Arc::new(ShardedStore::open(&dir).unwrap());
        let src =
            StoreSource::new(store, SourceConfig { cache_users: 16, prefetch_depth: 4 });
        assert!(src.wants_hints());
        let order: Vec<usize> = (0..16).collect();
        src.hint_round(&order);
        // the prefetcher loads at most `depth` users before any fetch
        let deadline = Instant::now() + std::time::Duration::from_secs(5);
        while src.cached_users() < 4 && Instant::now() < deadline {
            std::thread::yield_now();
        }
        assert_eq!(src.cached_users(), 4, "prefetcher should stop at depth");
        // consuming in dispatch order hits the cache and tops it back up
        let mut hits = 0;
        for &uid in &order {
            if src.fetch(uid).cache_hit == Some(true) {
                hits += 1;
            }
        }
        assert!(hits >= 4, "prefetched users should be hits, got {hits}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn stale_hints_are_replaced_not_wedged() {
        let dir = tmp_dir("stale");
        let gen = SynthGmmPoints::new(8, 5, 2, 2, 3);
        materialize(&gen, &dir, 8, 0).unwrap();
        let store = Arc::new(ShardedStore::open(&dir).unwrap());
        let src =
            StoreSource::new(store, SourceConfig { cache_users: 8, prefetch_depth: 2 });
        // an abandoned round's hints...
        src.hint_round(&[0, 1, 2, 3]);
        // ...are replaced wholesale by the next round's
        src.hint_round(&[4, 5, 6, 7]);
        for uid in [4usize, 5, 6, 7] {
            let f = src.fetch(uid);
            assert!(f.cache_hit.is_some());
        }
        // and the source still serves anything on demand
        assert_eq!(bits(&src.fetch(0).data), bits(&gen.user_data(0)));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn store_backed_run_matches_generator_run() {
        // end-to-end: the same simulation over the generator and over
        // its materialized store produces bit-identical central models
        // (acceptance: with a store, reads are bit-identical, so the
        // whole run is).
        use crate::fl::algorithm::RunSpec;
        use crate::fl::backend::{BackendBuilder, RunParams};
        use crate::fl::central_opt::Sgd;
        use crate::fl::worker::tests::MeanModel;
        use crate::fl::FedAvg;

        let dir = tmp_dir("e2e");
        let gen: Arc<dyn FederatedDataset> = Arc::new(SynthGmmPoints::new(24, 10, 3, 2, 5));
        materialize(&*gen, &dir, 7, 0).unwrap();
        let store = Arc::new(ShardedStore::open(&dir).unwrap());

        let run = |dataset: Arc<dyn FederatedDataset>,
                   source: Option<Arc<dyn UserDataSource>>| {
            let spec = RunSpec {
                iterations: 5,
                cohort_size: 8,
                population: 24,
                ..Default::default()
            };
            let alg = Arc::new(FedAvg::new(spec, Box::new(Sgd)));
            let mut builder = BackendBuilder::new(
                dataset,
                alg,
                Arc::new(|_| Ok(Box::new(MeanModel::new(3)) as Box<dyn crate::fl::Model>)),
            )
            .params(RunParams { num_workers: 2, ..Default::default() });
            if let Some(s) = source {
                builder = builder.data_source(s);
            }
            builder.build().unwrap().run(vec![1.0; 3], &mut []).unwrap()
        };

        let base = run(gen, None);
        let src: Arc<dyn UserDataSource> = Arc::new(StoreSource::new(
            store.clone(),
            SourceConfig { cache_users: 8, prefetch_depth: 2 },
        ));
        let stored = run(store as Arc<dyn FederatedDataset>, Some(src));
        assert_eq!(base.central, stored.central, "store-backed run diverged");
        assert_eq!(base.rounds, stored.rounds);
        // the store run observed its cache
        let (h, m) = (stored.counters.cache_hits, stored.counters.cache_misses);
        assert!(h + m > 0, "cache counters never ticked");
        assert!(stored.final_metric("sys/cache-hit-frac").is_some());
        // the generator run reports no cache metric at all
        assert!(base.final_metric("sys/cache-hit-frac").is_none());
        assert_eq!(base.counters.cache_hits + base.counters.cache_misses, 0);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
