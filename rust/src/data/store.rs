//! Out-of-core sharded dataset store: materialized user data on disk,
//! read back through a bounded LRU cache with dispatcher-driven
//! prefetch. See DESIGN.md §6 for the architecture and the format V2
//! layout diagram.
//!
//! The synthetic generators in this module's siblings cost no memory
//! because user data is a pure function of (seed, uid) — but that also
//! means every simulated dataset is formulaic. pfl-research's answer
//! for *realistic* datasets is to keep user-dataset loading off the
//! critical path on a separate thread; this module reproduces that
//! design for populations whose data is materialized and does not fit
//! in RAM:
//!
//! * [`ShardWriter`] / [`materialize`] write any [`FederatedDataset`]
//!   to a directory of binary shards (the `pfl materialize` and
//!   `pfl import` subcommands): each shard has a fixed header, and
//!   `index.bin` holds the per-user (shard, offset, length, examples)
//!   index, so reading one user costs a single positioned read.
//! * [`ShardedStore`] opens a store directory and implements
//!   [`FederatedDataset`] over it — bit-identical to the generator it
//!   was materialized from (property-tested in
//!   `rust/tests/property_invariants.rs`), so every downstream layer
//!   is unchanged.
//! * [`StoreSource`] wraps a store in the [`UserDataSource`] interface
//!   the workers consume: a bounded LRU user cache (a hit allocates
//!   nothing — asserted by `benches/data_store.rs`) plus a background
//!   prefetch thread that consumes the *dispatcher's* upcoming-uid
//!   order ([`UserDataSource::hint_round`]) and stays at most
//!   `prefetch_depth` users ahead of worker consumption, so disk I/O —
//!   and, for compressed stores, block decompression — overlaps local
//!   training exactly as pfl-research keeps loading off the critical
//!   path.
//!
//! **Format V2 (this version) vs V1:** V2 shards can be mapped with
//! `mmap` ([`crate::util::mman`]) so the OS page cache is the L2 cache
//! behind the user LRU and a warm read decodes straight out of the
//! mapping — zero heap allocation and zero copies beyond the
//! [`UserData`] vectors themselves. V2 also adds optional per-block
//! compression ([`crate::data::codec`]: byte-shuffle + LZ on fixed
//! blocks) recorded in the index header, with a decoded-block LRU
//! alongside the user cache. V1 stores (raw, version 1) still open and
//! read bit-identically; the `pread` path remains as a portable
//! fallback selected at open time ([`OpenOptions`]).
//!
//! Observability: every fetch reports hit/miss, the nanoseconds the
//! worker spent blocked on a miss (split mmap-vs-pread), bytes read
//! from disk, and worker-side decode time; workers fold these into
//! [`crate::simsys::Counters`] and the backend emits per-round
//! `sys/cache-hit-frac`, `sys/store-bytes-read`, `sys/decode-nanos`
//! and `sys/page-fault-stalls` metrics.

use std::collections::{HashMap, VecDeque};
use std::fs::File;
use std::io::{BufWriter, Read, Write};
use std::os::unix::fs::FileExt;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex, PoisonError};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use super::codec::{self, Compression};
use super::{FederatedDataset, UserData};
use crate::util::mman::{Advice, Mmap};

/// Store format version written by [`ShardWriter`]; readers accept
/// this and [`V1`] (raw stores from the previous release).
const VERSION: u32 = 2;
/// First format version: raw blobs, absolute file offsets, no
/// compression fields in the index. Still readable.
const V1: u32 = 1;
const INDEX_MAGIC: &[u8; 8] = b"PFLSIDX1";
const SHARD_MAGIC: &[u8; 8] = b"PFLSHRD1";
const EVAL_MAGIC: &[u8; 8] = b"PFLSEVL1";
/// Bytes of fixed shard header preceding the first user blob (or first
/// compressed block).
const SHARD_HEADER_LEN: u64 = 8 + 4 + 4;
/// Decoded-block LRU budget in bytes (counted in raw block bytes).
const BLOCK_CACHE_BYTES: u64 = 32 * 1024 * 1024;

fn shard_file_name(shard: u32) -> String {
    format!("shard_{shard:05}.bin")
}

// ----------------------------------------------------------------------
// Typed store errors
// ----------------------------------------------------------------------

/// Typed corruption/robustness errors surfaced (through `anyhow`, so
/// callers can `downcast_ref::<StoreError>()`) by [`ShardedStore::open`]
/// and the fetch paths instead of panicking. Regression-tested in this
/// module's tests: truncated shards, wrong magics, index/shard length
/// mismatches and out-of-range offsets all land here.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum StoreError {
    /// A file does not start with the expected magic.
    BadMagic { path: PathBuf, expected: &'static str },
    /// Format version this reader does not understand.
    UnsupportedVersion { path: PathBuf, version: u32 },
    /// A shard file is shorter than the extent the index declares.
    Truncated { path: PathBuf, need: u64, have: u64 },
    /// Shard file header names a different shard than its file name.
    ShardMismatch { path: PathBuf, expected: u32, found: u32 },
    /// Requested uid is not in the store.
    UidOutOfRange { uid: usize, num_users: usize },
    /// An index entry points outside its shard's addressable range.
    OffsetOutOfRange { uid: usize, shard: u32, end: u64, limit: u64 },
    /// Structural damage not covered by a more specific variant.
    Corrupt { path: PathBuf, detail: String },
}

impl std::fmt::Display for StoreError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StoreError::BadMagic { path, expected } => {
                write!(f, "{}: bad magic (expected {expected})", path.display())
            }
            StoreError::UnsupportedVersion { path, version } => write!(
                f,
                "{}: store version {version}, reader supports {V1} and {VERSION}",
                path.display()
            ),
            StoreError::Truncated { path, need, have } => write!(
                f,
                "{}: truncated — index needs {need} bytes, file has {have}",
                path.display()
            ),
            StoreError::ShardMismatch { path, expected, found } => write!(
                f,
                "{}: header names shard {found}, file name says {expected}",
                path.display()
            ),
            StoreError::UidOutOfRange { uid, num_users } => {
                write!(f, "uid {uid} out of range ({num_users} users)")
            }
            StoreError::OffsetOutOfRange { uid, shard, end, limit } => write!(
                f,
                "uid {uid}: entry ends at {end}, shard {shard} addressable range is {limit}"
            ),
            StoreError::Corrupt { path, detail } => {
                write!(f, "{}: {detail}", path.display())
            }
        }
    }
}

impl std::error::Error for StoreError {}

// ----------------------------------------------------------------------
// Blob encoding: one self-describing record per user (or eval shard)
// ----------------------------------------------------------------------

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_f32s(buf: &mut Vec<u8>, v: &[f32]) {
    for x in v {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

fn put_i32s(buf: &mut Vec<u8>, v: &[i32]) {
    for x in v {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

/// Byte cursor over an encoded blob.
struct Cur<'a> {
    b: &'a [u8],
    p: usize,
}

impl<'a> Cur<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self.p.checked_add(n).ok_or_else(|| anyhow!("blob offset overflow"))?;
        if end > self.b.len() {
            bail!("truncated blob: want {n} bytes at {}, have {}", self.p, self.b.len());
        }
        let s = &self.b[self.p..end];
        self.p = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        let s = self.take(4)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    fn u64(&mut self) -> Result<u64> {
        let s = self.take(8)?;
        Ok(u64::from_le_bytes([s[0], s[1], s[2], s[3], s[4], s[5], s[6], s[7]]))
    }

    fn f32s(&mut self, n: usize) -> Result<Vec<f32>> {
        let s = self.take(n.checked_mul(4).ok_or_else(|| anyhow!("blob length overflow"))?)?;
        Ok(s.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
    }

    fn i32s(&mut self, n: usize) -> Result<Vec<i32>> {
        let s = self.take(n.checked_mul(4).ok_or_else(|| anyhow!("blob length overflow"))?)?;
        Ok(s.chunks_exact(4).map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
    }
}

/// Encode one [`UserData`] record. f32/i32 payloads are stored as raw
/// little-endian bits, so a round trip is bit-exact (NaNs included).
fn encode_user_data(d: &UserData, buf: &mut Vec<u8>) {
    match d {
        UserData::Image { x, y, hwc } => {
            buf.push(0);
            put_u32(buf, *hwc as u32);
            put_u32(buf, y.len() as u32);
            put_u32(buf, x.len() as u32);
            put_i32s(buf, y);
            put_f32s(buf, x);
        }
        UserData::Features { x, y, feat, labels } => {
            buf.push(1);
            put_u32(buf, *feat as u32);
            put_u32(buf, *labels as u32);
            put_u32(buf, x.len() as u32);
            put_u32(buf, y.len() as u32);
            put_f32s(buf, x);
            put_f32s(buf, y);
        }
        UserData::Tokens { seqs, seq_len } => {
            buf.push(2);
            put_u32(buf, *seq_len as u32);
            put_u32(buf, seqs.len() as u32);
            put_i32s(buf, seqs);
        }
        UserData::Tabular { x, y, dim } => {
            buf.push(3);
            put_u32(buf, *dim as u32);
            put_u32(buf, x.len() as u32);
            put_u32(buf, y.len() as u32);
            put_f32s(buf, x);
            put_f32s(buf, y);
        }
        UserData::Points { x, dim } => {
            buf.push(4);
            put_u32(buf, *dim as u32);
            put_u32(buf, x.len() as u32);
            put_f32s(buf, x);
        }
    }
}

fn decode_user_data(b: &[u8]) -> Result<UserData> {
    let mut c = Cur { b, p: 0 };
    let d = match c.u8()? {
        0 => {
            let hwc = c.u32()? as usize;
            let ny = c.u32()? as usize;
            let nx = c.u32()? as usize;
            UserData::Image { y: c.i32s(ny)?, x: c.f32s(nx)?, hwc }
        }
        1 => {
            let feat = c.u32()? as usize;
            let labels = c.u32()? as usize;
            let nx = c.u32()? as usize;
            let ny = c.u32()? as usize;
            UserData::Features { x: c.f32s(nx)?, y: c.f32s(ny)?, feat, labels }
        }
        2 => {
            let seq_len = c.u32()? as usize;
            let n = c.u32()? as usize;
            UserData::Tokens { seqs: c.i32s(n)?, seq_len }
        }
        3 => {
            let dim = c.u32()? as usize;
            let nx = c.u32()? as usize;
            let ny = c.u32()? as usize;
            UserData::Tabular { x: c.f32s(nx)?, y: c.f32s(ny)?, dim }
        }
        4 => {
            let dim = c.u32()? as usize;
            let nx = c.u32()? as usize;
            UserData::Points { x: c.f32s(nx)?, dim }
        }
        t => bail!("unknown UserData tag {t}"),
    };
    if c.p != b.len() {
        bail!("trailing bytes in blob: consumed {}, have {}", c.p, b.len());
    }
    Ok(d)
}

// ----------------------------------------------------------------------
// Writer
// ----------------------------------------------------------------------

/// One user's location in the store. `offset` is the absolute file
/// offset of the blob for raw stores (V1-compatible), and the offset
/// into the shard's *uncompressed* stream (0-based, header excluded)
/// for compressed stores.
#[derive(Debug, Clone, Copy)]
struct IndexEntry {
    shard: u32,
    offset: u64,
    len: u32,
    examples: u32,
}

/// One compressed block's location: where its framed bytes live in the
/// shard file, and how many raw bytes it decodes to.
#[derive(Debug, Clone, Copy)]
struct BlockEntry {
    comp_off: u64,
    comp_len: u32,
    raw_len: u32,
}

/// Materialization summary returned by [`ShardWriter::finish`].
#[derive(Debug, Clone, Copy)]
pub struct StoreStats {
    pub num_users: usize,
    pub num_shards: usize,
    /// Total raw (uncompressed) user-payload bytes (headers excluded).
    pub data_bytes: u64,
    /// User-payload bytes actually on disk: equals `data_bytes` for raw
    /// stores, the framed compressed size for compressed ones.
    pub disk_bytes: u64,
    /// Compression scheme the store was written with.
    pub compression: Compression,
    /// Central-eval shards materialized alongside the users.
    pub eval_shards: usize,
}

impl StoreStats {
    /// Raw-to-disk payload ratio (≥ 1.0 when compression helps).
    pub fn compression_ratio(&self) -> f64 {
        if self.disk_bytes == 0 {
            1.0
        } else {
            self.data_bytes as f64 / self.disk_bytes as f64
        }
    }
}

struct CurShard {
    idx: u32,
    w: BufWriter<File>,
    /// Raw-stream cursor: absolute file offset for raw stores, bytes of
    /// uncompressed payload so far for compressed ones.
    off: u64,
    /// Compressed stores: raw bytes awaiting a full block.
    pending: Vec<u8>,
    /// Compressed stores: absolute file offset of the next block.
    comp_off: u64,
    blocks: Vec<BlockEntry>,
}

/// Sequential store writer: `append_user` in uid order (uid 0, 1, ...),
/// optionally `write_eval`, then `finish` to seal the index. Users land
/// in shard `uid / users_per_shard`, so a shard is one contiguous write
/// and one uid range. Any existing store in `dir` is overwritten.
///
/// With [`Compression::ShuffleLz`] the raw blob stream is cut into
/// fixed-size blocks, each framed by [`codec::compress_block`]; the
/// per-shard block tables land in `index.bin` so a reader can address
/// any byte range without scanning.
pub struct ShardWriter {
    dir: PathBuf,
    users_per_shard: usize,
    compression: Compression,
    block_size: u32,
    cur: Option<CurShard>,
    index: Vec<IndexEntry>,
    shard_blocks: Vec<Vec<BlockEntry>>,
    data_bytes: u64,
    disk_bytes: u64,
    eval_shards: usize,
    buf: Vec<u8>,
}

impl ShardWriter {
    /// A raw (uncompressed) writer — same on-disk payload layout as V1.
    pub fn create(dir: &Path, users_per_shard: usize) -> Result<Self> {
        Self::create_with(dir, users_per_shard, Compression::None, codec::DEFAULT_BLOCK_SIZE)
    }

    pub fn create_with(
        dir: &Path,
        users_per_shard: usize,
        compression: Compression,
        block_size: u32,
    ) -> Result<Self> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating store dir {}", dir.display()))?;
        Ok(ShardWriter {
            dir: dir.to_path_buf(),
            users_per_shard: users_per_shard.max(1),
            compression,
            block_size: block_size.max(1),
            cur: None,
            index: Vec::new(),
            shard_blocks: Vec::new(),
            data_bytes: 0,
            disk_bytes: 0,
            eval_shards: 0,
            buf: Vec::new(),
        })
    }

    /// Compress-and-write every full block sitting in `pending`; with
    /// `all`, also the final partial block.
    fn flush_blocks(c: &mut CurShard, block_size: u32, all: bool, disk_bytes: &mut u64) -> Result<()> {
        let bs = block_size as usize;
        let mut start = 0usize;
        while c.pending.len() - start >= bs || (all && c.pending.len() > start) {
            let end = (start + bs).min(c.pending.len());
            let framed = codec::compress_block(&c.pending[start..end]);
            c.w.write_all(&framed).context("writing compressed block")?;
            c.blocks.push(BlockEntry {
                comp_off: c.comp_off,
                comp_len: framed.len() as u32,
                raw_len: (end - start) as u32,
            });
            c.comp_off += framed.len() as u64;
            *disk_bytes += framed.len() as u64;
            start = end;
        }
        c.pending.drain(..start);
        Ok(())
    }

    fn close_shard(&mut self) -> Result<()> {
        if let Some(mut c) = self.cur.take() {
            if self.compression != Compression::None {
                Self::flush_blocks(&mut c, self.block_size, true, &mut self.disk_bytes)?;
            }
            c.w.flush().context("flushing shard")?;
            if self.compression != Compression::None {
                let idx = c.idx as usize;
                if self.shard_blocks.len() <= idx {
                    self.shard_blocks.resize_with(idx + 1, Vec::new);
                }
                self.shard_blocks[idx] = c.blocks;
            }
        }
        Ok(())
    }

    /// Append the next user (uid = number of users appended so far).
    pub fn append_user(&mut self, data: &UserData) -> Result<()> {
        let uid = self.index.len();
        let shard = (uid / self.users_per_shard) as u32;
        if self.cur.as_ref().map(|c| c.idx) != Some(shard) {
            self.close_shard()?;
            let path = self.dir.join(shard_file_name(shard));
            let f = File::create(&path)
                .with_context(|| format!("creating shard {}", path.display()))?;
            let mut w = BufWriter::new(f);
            w.write_all(SHARD_MAGIC)?;
            w.write_all(&VERSION.to_le_bytes())?;
            w.write_all(&shard.to_le_bytes())?;
            let off = if self.compression == Compression::None { SHARD_HEADER_LEN } else { 0 };
            self.cur = Some(CurShard {
                idx: shard,
                w,
                off,
                pending: Vec::new(),
                comp_off: SHARD_HEADER_LEN,
                blocks: Vec::new(),
            });
        }
        self.buf.clear();
        encode_user_data(data, &mut self.buf);
        if self.buf.len() > u32::MAX as usize {
            // the index stores blob lengths as u32; a wrapped length
            // would silently corrupt the store
            bail!("user {uid} encodes to {} bytes (> u32::MAX)", self.buf.len());
        }
        let c = self.cur.as_mut().unwrap();
        self.index.push(IndexEntry {
            shard,
            offset: c.off,
            len: self.buf.len() as u32,
            examples: data.len() as u32,
        });
        match self.compression {
            Compression::None => {
                c.w.write_all(&self.buf).with_context(|| format!("writing user {uid}"))?;
                self.disk_bytes += self.buf.len() as u64;
            }
            Compression::ShuffleLz => {
                c.pending.extend_from_slice(&self.buf);
                Self::flush_blocks(c, self.block_size, false, &mut self.disk_bytes)?;
            }
        }
        c.off += self.buf.len() as u64;
        self.data_bytes += self.buf.len() as u64;
        Ok(())
    }

    /// Materialize the central-eval shards (`eval.bin`). Always stored
    /// raw — eval shards are read once per run, so compressing them
    /// buys nothing. [`ShardedStore::central_eval`] returns these
    /// shards as stored.
    pub fn write_eval(&mut self, shards: &[UserData]) -> Result<()> {
        let path = self.dir.join("eval.bin");
        let f = File::create(&path).with_context(|| format!("creating {}", path.display()))?;
        let mut w = BufWriter::new(f);
        w.write_all(EVAL_MAGIC)?;
        w.write_all(&VERSION.to_le_bytes())?;
        w.write_all(&(shards.len() as u32).to_le_bytes())?;
        for (i, s) in shards.iter().enumerate() {
            self.buf.clear();
            encode_user_data(s, &mut self.buf);
            if self.buf.len() > u32::MAX as usize {
                bail!("eval shard {i} encodes to {} bytes (> u32::MAX)", self.buf.len());
            }
            w.write_all(&(self.buf.len() as u32).to_le_bytes())?;
            w.write_all(&self.buf)?;
        }
        w.flush().context("flushing eval.bin")?;
        self.eval_shards = shards.len();
        Ok(())
    }

    /// Seal the store: flush the open shard and write `index.bin`
    /// (format V2 — V1 plus `compression`, `block_size` and, for
    /// compressed stores, the per-shard block tables).
    pub fn finish(mut self, name: &str) -> Result<StoreStats> {
        self.close_shard()?;
        let num_shards = self.index.last().map(|e| e.shard as usize + 1).unwrap_or(0);
        if self.compression != Compression::None && self.shard_blocks.len() < num_shards {
            self.shard_blocks.resize_with(num_shards, Vec::new);
        }
        let path = self.dir.join("index.bin");
        let f = File::create(&path).with_context(|| format!("creating {}", path.display()))?;
        let mut w = BufWriter::new(f);
        w.write_all(INDEX_MAGIC)?;
        w.write_all(&VERSION.to_le_bytes())?;
        w.write_all(&(num_shards as u32).to_le_bytes())?;
        w.write_all(&(self.users_per_shard as u32).to_le_bytes())?;
        w.write_all(&(name.len() as u32).to_le_bytes())?;
        w.write_all(name.as_bytes())?;
        w.write_all(&[self.compression.to_u8()])?;
        w.write_all(&self.block_size.to_le_bytes())?;
        w.write_all(&(self.index.len() as u64).to_le_bytes())?;
        for e in &self.index {
            w.write_all(&e.shard.to_le_bytes())?;
            w.write_all(&e.offset.to_le_bytes())?;
            w.write_all(&e.len.to_le_bytes())?;
            w.write_all(&e.examples.to_le_bytes())?;
        }
        if self.compression != Compression::None {
            for blocks in &self.shard_blocks[..num_shards] {
                w.write_all(&(blocks.len() as u32).to_le_bytes())?;
                for b in blocks {
                    w.write_all(&b.comp_off.to_le_bytes())?;
                    w.write_all(&b.comp_len.to_le_bytes())?;
                    w.write_all(&b.raw_len.to_le_bytes())?;
                }
            }
        }
        w.flush().context("flushing index.bin")?;
        Ok(StoreStats {
            num_users: self.index.len(),
            num_shards,
            data_bytes: self.data_bytes,
            disk_bytes: self.disk_bytes,
            compression: self.compression,
            eval_shards: self.eval_shards,
        })
    }
}

/// Materialize a [`FederatedDataset`] to `dir` uncompressed: every user
/// in uid order plus (when `eval_shard_size > 0`) the central-eval
/// shards.
pub fn materialize(
    dataset: &dyn FederatedDataset,
    dir: &Path,
    users_per_shard: usize,
    eval_shard_size: usize,
) -> Result<StoreStats> {
    materialize_with(dataset, dir, users_per_shard, eval_shard_size, Compression::None)
}

/// [`materialize`] with an explicit compression scheme (CLI
/// `pfl materialize --compression shuffle-lz`).
pub fn materialize_with(
    dataset: &dyn FederatedDataset,
    dir: &Path,
    users_per_shard: usize,
    eval_shard_size: usize,
    compression: Compression,
) -> Result<StoreStats> {
    let mut w = ShardWriter::create_with(dir, users_per_shard, compression, codec::DEFAULT_BLOCK_SIZE)?;
    for uid in 0..dataset.num_users() {
        w.append_user(&dataset.user_data(uid))
            .with_context(|| format!("materializing user {uid}"))?;
    }
    if eval_shard_size > 0 {
        w.write_eval(&dataset.central_eval(eval_shard_size))?;
    }
    w.finish(dataset.name())
}

// ----------------------------------------------------------------------
// Reader
// ----------------------------------------------------------------------

/// How to open a store: mmap (default — zero-copy warm reads through
/// the page cache) or portable positioned reads. When mmap is requested
/// but unavailable (platform shim, or `mmap(2)` itself failing) the
/// store silently falls back to `pread` per shard.
#[derive(Debug, Clone, Copy)]
pub struct OpenOptions {
    pub mmap: bool,
}

impl Default for OpenOptions {
    fn default() -> Self {
        OpenOptions { mmap: true }
    }
}

/// Per-read accounting folded into [`Fetched`] and from there into
/// [`crate::simsys::Counters`].
#[derive(Debug, Clone, Copy, Default)]
pub struct ReadTrace {
    /// Bytes pulled from the shard file (compressed bytes for
    /// compressed stores; 0 when every needed block was cached).
    pub bytes_read: u64,
    /// Nanoseconds spent decompressing blocks on *this* thread.
    pub decode_nanos: u64,
    /// Whether the read went through a memory mapping (page-fault
    /// stalls) rather than explicit `pread` calls.
    pub via_mmap: bool,
}

/// A shard's backing: mapped or plain fd.
enum ShardBacking {
    Mapped(Mmap),
    Pread(File),
}

struct ShardFile {
    backing: ShardBacking,
}

/// Per-shard metadata derived from the index at open time.
#[derive(Default)]
struct ShardMeta {
    /// Minimum file length implied by the index; validated against
    /// `fs::metadata` before the file is mapped or read, so a truncated
    /// shard surfaces [`StoreError::Truncated`] instead of a SIGBUS
    /// (mmap) or short read (pread).
    required_len: u64,
    /// Compressed stores: the shard's block table.
    blocks: Vec<BlockEntry>,
    /// Compressed stores: total raw bytes across the blocks.
    raw_len: u64,
}

struct BlockCacheEntry {
    data: Arc<Vec<u8>>,
    last_used: u64,
}

/// LRU over decoded blocks, bounded by raw bytes (not entry count, so
/// one cache budget works for any block size). Shared by workers and
/// the prefetch thread; in the steady prefetching state only the
/// prefetch thread populates it.
struct BlockCache {
    cap_bytes: u64,
    bytes: u64,
    tick: u64,
    map: HashMap<(u32, u32), BlockCacheEntry>,
}

impl BlockCache {
    fn new(cap_bytes: u64) -> Self {
        BlockCache { cap_bytes: cap_bytes.max(1), bytes: 0, tick: 0, map: HashMap::new() }
    }

    fn get(&mut self, shard: u32, block: u32) -> Option<Arc<Vec<u8>>> {
        self.tick += 1;
        let tick = self.tick;
        let e = self.map.get_mut(&(shard, block))?;
        e.last_used = tick;
        Some(e.data.clone())
    }

    fn insert(&mut self, shard: u32, block: u32, data: Arc<Vec<u8>>) {
        if self.map.contains_key(&(shard, block)) {
            return;
        }
        while self.bytes + data.len() as u64 > self.cap_bytes && !self.map.is_empty() {
            let victim = self.map.iter().min_by_key(|(_, e)| e.last_used).map(|(&k, _)| k);
            if let Some(v) = victim {
                if let Some(e) = self.map.remove(&v) {
                    self.bytes -= e.data.len() as u64;
                }
            }
        }
        self.tick += 1;
        self.bytes += data.len() as u64;
        self.map.insert((shard, block), BlockCacheEntry { data, last_used: self.tick });
    }
}

/// An opened store directory. Thread-safe: shard backings are opened
/// (and mapped) lazily, kept for the store's lifetime, and read
/// position-independently — no shared seek cursor — so workers and the
/// prefetch thread read concurrently.
pub struct ShardedStore {
    dir: PathBuf,
    name: String,
    version: u32,
    compression: Compression,
    block_size: u32,
    index: Vec<IndexEntry>,
    shards: Vec<ShardMeta>,
    use_mmap: bool,
    /// Flips false the first time an mmap attempt fails (fallback to
    /// pread); read for the stall-split accounting.
    mmap_ok: AtomicBool,
    files: Mutex<HashMap<u32, Arc<ShardFile>>>,
    block_cache: Mutex<BlockCache>,
}

impl ShardedStore {
    /// Open with the default [`OpenOptions`] (mmap when available).
    pub fn open(dir: &Path) -> Result<Self> {
        Self::open_with(dir, OpenOptions::default())
    }

    pub fn open_with(dir: &Path, opts: OpenOptions) -> Result<Self> {
        let path = dir.join("index.bin");
        let mut raw = Vec::new();
        File::open(&path)
            .with_context(|| {
                format!("opening {} (is this a `pfl materialize` dir?)", path.display())
            })?
            .read_to_end(&mut raw)
            .with_context(|| format!("reading {}", path.display()))?;
        let cpath = path.clone();
        let corrupt = move |detail: String| StoreError::Corrupt { path: cpath.clone(), detail };
        let mut c = Cur { b: &raw, p: 0 };
        if c.take(8).map_err(|e| corrupt(e.to_string()))? != INDEX_MAGIC {
            bail!(StoreError::BadMagic { path, expected: "PFLSIDX1" });
        }
        let version = c.u32().map_err(|e| corrupt(e.to_string()))?;
        if version != V1 && version != VERSION {
            bail!(StoreError::UnsupportedVersion { path, version });
        }
        let num_shards = c.u32().map_err(|e| corrupt(e.to_string()))? as usize;
        let _users_per_shard = c.u32().map_err(|e| corrupt(e.to_string()))?;
        let name_len = c.u32().map_err(|e| corrupt(e.to_string()))? as usize;
        let name = String::from_utf8(c.take(name_len).map_err(|e| corrupt(e.to_string()))?.to_vec())
            .map_err(|_| corrupt("store name is not utf-8".into()))?;
        let (compression, block_size) = if version >= 2 {
            let comp = Compression::from_u8(c.u8().map_err(|e| corrupt(e.to_string()))?)
                .map_err(|e| corrupt(e.to_string()))?;
            let bs = c.u32().map_err(|e| corrupt(e.to_string()))?;
            if comp != Compression::None && bs == 0 {
                bail!(corrupt("compressed store with block_size 0".into()));
            }
            (comp, bs.max(1))
        } else {
            (Compression::None, codec::DEFAULT_BLOCK_SIZE)
        };
        let n = c.u64().map_err(|e| corrupt(e.to_string()))? as usize;
        let mut index = Vec::with_capacity(n);
        for _ in 0..n {
            let shard = c.u32().map_err(|e| corrupt(e.to_string()))?;
            let offset = c.u64().map_err(|e| corrupt(e.to_string()))?;
            let len = c.u32().map_err(|e| corrupt(e.to_string()))?;
            let examples = c.u32().map_err(|e| corrupt(e.to_string()))?;
            if (shard as usize) >= num_shards {
                bail!(corrupt(format!(
                    "index entry names shard {shard}, header declares {num_shards} shards"
                )));
            }
            index.push(IndexEntry { shard, offset, len, examples });
        }
        let mut shards: Vec<ShardMeta> = Vec::with_capacity(num_shards);
        shards.resize_with(num_shards, ShardMeta::default);
        for m in &mut shards {
            m.required_len = SHARD_HEADER_LEN;
        }
        if compression == Compression::None {
            // raw store: entry offsets are absolute file offsets, so
            // the index alone implies each shard's minimum length
            for (uid, e) in index.iter().enumerate() {
                if e.offset < SHARD_HEADER_LEN {
                    bail!(StoreError::OffsetOutOfRange {
                        uid,
                        shard: e.shard,
                        end: e.offset,
                        limit: SHARD_HEADER_LEN,
                    });
                }
                let m = &mut shards[e.shard as usize];
                m.required_len = m.required_len.max(e.offset + e.len as u64);
            }
        } else {
            // compressed store: parse the per-shard block tables and
            // validate every entry against the shard's raw extent
            for m in shards.iter_mut() {
                let nb = c.u32().map_err(|e| corrupt(e.to_string()))? as usize;
                m.blocks.reserve(nb);
                for _ in 0..nb {
                    let comp_off = c.u64().map_err(|e| corrupt(e.to_string()))?;
                    let comp_len = c.u32().map_err(|e| corrupt(e.to_string()))?;
                    let raw_len = c.u32().map_err(|e| corrupt(e.to_string()))?;
                    if comp_off < SHARD_HEADER_LEN {
                        bail!(corrupt(format!("block offset {comp_off} inside shard header")));
                    }
                    if raw_len == 0 || raw_len > block_size {
                        bail!(corrupt(format!(
                            "block raw length {raw_len} outside (0, {block_size}]"
                        )));
                    }
                    m.required_len = m.required_len.max(comp_off + comp_len as u64);
                    m.raw_len += raw_len as u64;
                    m.blocks.push(BlockEntry { comp_off, comp_len, raw_len });
                }
                // all blocks except the last must be exactly block_size
                // raw bytes, or raw-offset → block-index math breaks
                for b in m.blocks.iter().take(m.blocks.len().saturating_sub(1)) {
                    if b.raw_len != block_size {
                        bail!(corrupt(format!(
                            "interior block decodes to {} raw bytes, block size is {block_size}",
                            b.raw_len
                        )));
                    }
                }
            }
            for (uid, e) in index.iter().enumerate() {
                let limit = shards[e.shard as usize].raw_len;
                let end = e.offset + e.len as u64;
                if end > limit {
                    bail!(StoreError::OffsetOutOfRange { uid, shard: e.shard, end, limit });
                }
            }
        }
        if c.p != raw.len() {
            bail!(corrupt(format!("{} trailing bytes after index", raw.len() - c.p)));
        }
        Ok(ShardedStore {
            dir: dir.to_path_buf(),
            name,
            version,
            compression,
            block_size,
            index,
            shards,
            use_mmap: opts.mmap,
            mmap_ok: AtomicBool::new(opts.mmap),
            files: Mutex::new(HashMap::new()),
            block_cache: Mutex::new(BlockCache::new(BLOCK_CACHE_BYTES)),
        })
    }

    /// Format version this store was written with (1 or 2).
    pub fn version(&self) -> u32 {
        self.version
    }

    pub fn compression(&self) -> Compression {
        self.compression
    }

    /// Whether reads are currently going through mmap (false when
    /// opened with `mmap: false`, on unsupported platforms, or after an
    /// mmap failure fell back to pread).
    pub fn uses_mmap(&self) -> bool {
        self.mmap_ok.load(Ordering::Relaxed)
    }

    fn file(&self, shard: u32) -> Result<Arc<ShardFile>> {
        let mut files = self.files.lock().unwrap_or_else(PoisonError::into_inner);
        if let Some(f) = files.get(&shard) {
            return Ok(f.clone());
        }
        let path = self.dir.join(shard_file_name(shard));
        let f = File::open(&path).with_context(|| format!("opening {}", path.display()))?;
        let have = f.metadata().with_context(|| format!("stat {}", path.display()))?.len();
        let need = self
            .shards
            .get(shard as usize)
            .map(|m| m.required_len)
            .unwrap_or(SHARD_HEADER_LEN);
        if have < need {
            bail!(StoreError::Truncated { path, need, have });
        }
        let mut header = [0u8; SHARD_HEADER_LEN as usize];
        f.read_exact_at(&mut header, 0)
            .with_context(|| format!("reading {} header", path.display()))?;
        if &header[..8] != SHARD_MAGIC {
            bail!(StoreError::BadMagic { path, expected: "PFLSHRD1" });
        }
        let version = u32::from_le_bytes([header[8], header[9], header[10], header[11]]);
        if version != V1 && version != VERSION {
            bail!(StoreError::UnsupportedVersion { path, version });
        }
        let found = u32::from_le_bytes([header[12], header[13], header[14], header[15]]);
        if found != shard {
            bail!(StoreError::ShardMismatch { path, expected: shard, found });
        }
        let backing = if self.use_mmap {
            match Mmap::map_readonly(&f, need as usize) {
                Ok(m) => {
                    m.advise(Advice::WillNeed);
                    ShardBacking::Mapped(m)
                }
                Err(_) => {
                    self.mmap_ok.store(false, Ordering::Relaxed);
                    ShardBacking::Pread(f)
                }
            }
        } else {
            ShardBacking::Pread(f)
        };
        let sf = Arc::new(ShardFile { backing });
        files.insert(shard, sf.clone());
        Ok(sf)
    }

    /// Fetch one decoded block through the block LRU, decompressing on
    /// a miss (on whichever thread is calling — the prefetch thread in
    /// the steady state, so decode stays off the worker critical path).
    fn decoded_block(&self, shard: u32, block: u32, trace: &mut ReadTrace) -> Result<Arc<Vec<u8>>> {
        if let Some(b) = self
            .block_cache
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .get(shard, block)
        {
            return Ok(b);
        }
        let meta = &self.shards[shard as usize];
        let be = *meta.blocks.get(block as usize).ok_or_else(|| StoreError::Corrupt {
            path: self.dir.join(shard_file_name(shard)),
            detail: format!("block {block} out of table ({} blocks)", meta.blocks.len()),
        })?;
        let sf = self.file(shard)?;
        trace.bytes_read += be.comp_len as u64;
        let raw = match &sf.backing {
            ShardBacking::Mapped(m) => {
                trace.via_mmap = true;
                let lo = be.comp_off as usize;
                let framed = m
                    .as_slice()
                    .get(lo..lo + be.comp_len as usize)
                    .ok_or_else(|| StoreError::Truncated {
                        path: self.dir.join(shard_file_name(shard)),
                        need: be.comp_off + be.comp_len as u64,
                        have: m.len() as u64,
                    })?;
                let t0 = Instant::now();
                let raw = codec::decompress_block(framed, be.raw_len as usize)
                    .map_err(|e| StoreError::Corrupt {
                        path: self.dir.join(shard_file_name(shard)),
                        detail: format!("block {block}: {e}"),
                    })?;
                trace.decode_nanos += t0.elapsed().as_nanos() as u64;
                raw
            }
            ShardBacking::Pread(f) => {
                trace.via_mmap = false;
                let mut buf = vec![0u8; be.comp_len as usize];
                f.read_exact_at(&mut buf, be.comp_off).with_context(|| {
                    format!("reading shard {shard} block {block} at {}", be.comp_off)
                })?;
                let t0 = Instant::now();
                let raw = codec::decompress_block(&buf, be.raw_len as usize)
                    .map_err(|e| StoreError::Corrupt {
                        path: self.dir.join(shard_file_name(shard)),
                        detail: format!("block {block}: {e}"),
                    })?;
                trace.decode_nanos += t0.elapsed().as_nanos() as u64;
                raw
            }
        };
        let arc = Arc::new(raw);
        self.block_cache
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .insert(shard, block, arc.clone());
        Ok(arc)
    }

    fn read_compressed(&self, uid: usize, e: IndexEntry, trace: &mut ReadTrace) -> Result<UserData> {
        let bs = self.block_size as u64;
        let start = e.offset;
        let end = e.offset + e.len as u64;
        let b0 = (start / bs) as u32;
        let b1 = ((end.max(1) - 1) / bs) as u32;
        if b0 == b1 {
            // fast path: the blob lives in one block — decode straight
            // from the cached block, no assembly copy
            let block = self.decoded_block(e.shard, b0, trace)?;
            let lo = (start - b0 as u64 * bs) as usize;
            let hi = (end - b0 as u64 * bs) as usize;
            let bytes = block.get(lo..hi).ok_or_else(|| StoreError::OffsetOutOfRange {
                uid,
                shard: e.shard,
                end,
                limit: b0 as u64 * bs + block.len() as u64,
            })?;
            return decode_user_data(bytes).with_context(|| format!("decoding user {uid}"));
        }
        let mut buf = Vec::with_capacity(e.len as usize);
        for b in b0..=b1 {
            let block = self.decoded_block(e.shard, b, trace)?;
            let blk_start = b as u64 * bs;
            let lo = start.max(blk_start) - blk_start;
            let hi = end.min(blk_start + block.len() as u64) - blk_start;
            buf.extend_from_slice(&block[lo as usize..hi as usize]);
        }
        if buf.len() != e.len as usize {
            bail!(StoreError::OffsetOutOfRange {
                uid,
                shard: e.shard,
                end,
                limit: self.shards[e.shard as usize].raw_len,
            });
        }
        decode_user_data(&buf).with_context(|| format!("decoding user {uid}"))
    }

    /// Read one user straight from disk (no cache — [`StoreSource`]
    /// layers the user cache on top; compressed stores still go through
    /// the decoded-block LRU).
    pub fn read_user(&self, uid: usize) -> Result<UserData> {
        self.read_user_traced(uid).map(|(d, _)| d)
    }

    /// [`Self::read_user`] plus the [`ReadTrace`] accounting the
    /// calling thread incurred.
    pub fn read_user_traced(&self, uid: usize) -> Result<(UserData, ReadTrace)> {
        let e = self.index.get(uid).copied().ok_or(StoreError::UidOutOfRange {
            uid,
            num_users: self.index.len(),
        })?;
        let mut trace =
            ReadTrace { bytes_read: 0, decode_nanos: 0, via_mmap: self.uses_mmap() };
        if self.compression != Compression::None {
            let d = self.read_compressed(uid, e, &mut trace)?;
            return Ok((d, trace));
        }
        let sf = self.file(e.shard)?;
        trace.bytes_read = e.len as u64;
        let d = match &sf.backing {
            ShardBacking::Mapped(m) => {
                // zero-copy: decode straight out of the mapping (the
                // only allocations are the UserData vectors)
                trace.via_mmap = true;
                let lo = e.offset as usize;
                let bytes = m.as_slice().get(lo..lo + e.len as usize).ok_or_else(|| {
                    StoreError::Truncated {
                        path: self.dir.join(shard_file_name(e.shard)),
                        need: e.offset + e.len as u64,
                        have: m.len() as u64,
                    }
                })?;
                decode_user_data(bytes).with_context(|| format!("decoding user {uid}"))?
            }
            ShardBacking::Pread(f) => {
                trace.via_mmap = false;
                let mut buf = vec![0u8; e.len as usize];
                f.read_exact_at(&mut buf, e.offset).with_context(|| {
                    format!("reading user {uid} (shard {}, off {})", e.shard, e.offset)
                })?;
                decode_user_data(&buf).with_context(|| format!("decoding user {uid}"))?
            }
        };
        Ok((d, trace))
    }

    fn read_eval(&self) -> Result<Vec<UserData>> {
        let path = self.dir.join("eval.bin");
        if !path.exists() {
            return Ok(Vec::new());
        }
        let mut raw = Vec::new();
        File::open(&path)?.read_to_end(&mut raw)?;
        let mut c = Cur { b: &raw, p: 0 };
        if c.take(8)? != EVAL_MAGIC {
            bail!(StoreError::BadMagic { path, expected: "PFLSEVL1" });
        }
        let version = c.u32()?;
        if version != V1 && version != VERSION {
            bail!(StoreError::UnsupportedVersion { path, version });
        }
        let n = c.u32()? as usize;
        let mut shards = Vec::with_capacity(n);
        for i in 0..n {
            let len = c.u32()? as usize;
            shards.push(
                decode_user_data(c.take(len)?).with_context(|| format!("eval shard {i}"))?,
            );
        }
        Ok(shards)
    }
}

impl FederatedDataset for ShardedStore {
    /// The materialized generator's name, so runs over a store report
    /// the same dataset they would have reported over the generator.
    fn name(&self) -> &str {
        &self.name
    }

    fn num_users(&self) -> usize {
        self.index.len()
    }

    /// The trait is infallible (generators cannot fail), so an I/O or
    /// decode error here panics with the store path — a corrupt store
    /// is unrecoverable mid-simulation anyway. Fallible callers use
    /// [`ShardedStore::read_user`].
    fn user_data(&self, uid: usize) -> UserData {
        self.read_user(uid)
            .unwrap_or_else(|e| panic!("store {}: {e:#}", self.dir.display()))
    }

    /// Free: the example count comes from the in-memory index, never
    /// from disk — scheduling weights cost no I/O.
    fn user_len(&self, uid: usize) -> usize {
        self.index.get(uid).map(|e| e.examples as usize).unwrap_or(0)
    }

    /// Eval shards as materialized; the shard size was fixed by
    /// `pfl materialize --eval-shard`, so the requested size is ignored.
    fn central_eval(&self, _shard_size: usize) -> Vec<UserData> {
        self.read_eval()
            .unwrap_or_else(|e| panic!("store {}: {e:#}", self.dir.display()))
    }
}

// ----------------------------------------------------------------------
// stat: header/index-only store report
// ----------------------------------------------------------------------

/// `pfl store stat` report. Produced from `index.bin`, the shard
/// files' `fs::metadata` lengths, and the 16-byte `eval.bin` header —
/// never a full data scan, so it is O(population) time and O(1) I/O per
/// shard even on a ten-million-user store.
#[derive(Debug, Clone)]
pub struct StoreStat {
    pub name: String,
    pub version: u32,
    pub compression: Compression,
    pub block_size: u32,
    pub num_users: usize,
    pub num_shards: usize,
    /// Raw (uncompressed) user-payload bytes, from the index entries.
    pub raw_bytes: u64,
    /// Actual shard-file bytes on disk (headers included).
    pub disk_bytes: u64,
    pub eval_shards: usize,
}

impl StoreStat {
    /// Raw payload over on-disk shard bytes (> 1.0 when compression
    /// wins; slightly < 1.0 for raw stores because of shard headers).
    pub fn compression_ratio(&self) -> f64 {
        if self.disk_bytes == 0 {
            1.0
        } else {
            self.raw_bytes as f64 / self.disk_bytes as f64
        }
    }
}

/// Summarize a store from its headers and index only.
pub fn stat(dir: &Path) -> Result<StoreStat> {
    let store = ShardedStore::open_with(dir, OpenOptions { mmap: false })?;
    let raw_bytes: u64 = store.index.iter().map(|e| e.len as u64).sum();
    let mut disk_bytes = 0u64;
    for shard in 0..store.shards.len() {
        let path = dir.join(shard_file_name(shard as u32));
        disk_bytes += std::fs::metadata(&path)
            .with_context(|| format!("stat {}", path.display()))?
            .len();
    }
    let eval_path = dir.join("eval.bin");
    let eval_shards = if eval_path.exists() {
        let f = File::open(&eval_path)?;
        let mut header = [0u8; 16];
        f.read_exact_at(&mut header, 0)
            .with_context(|| format!("reading {} header", eval_path.display()))?;
        if &header[..8] != EVAL_MAGIC {
            bail!(StoreError::BadMagic { path: eval_path, expected: "PFLSEVL1" });
        }
        u32::from_le_bytes([header[12], header[13], header[14], header[15]]) as usize
    } else {
        0
    };
    Ok(StoreStat {
        name: store.name.clone(),
        version: store.version,
        compression: store.compression,
        block_size: store.block_size,
        num_users: store.index.len(),
        num_shards: store.shards.len(),
        raw_bytes,
        disk_bytes,
        eval_shards,
    })
}

// ----------------------------------------------------------------------
// UserDataSource: the worker-facing fetch interface
// ----------------------------------------------------------------------

/// One fetched user, with the bookkeeping the worker folds into its
/// round [`crate::simsys::Counters`].
pub struct Fetched {
    pub data: Arc<UserData>,
    /// `Some(hit)` for cache-backed sources; `None` when no cache is in
    /// play (generator-backed), so generator runs report no hit-rate.
    pub cache_hit: Option<bool>,
    /// Nanoseconds this call was blocked on I/O (0 on a hit).
    pub stall_nanos: u64,
    /// Bytes read from disk on behalf of this user — on a miss, the
    /// worker's own read; on the first hit of a prefetched user, the
    /// bytes the prefetch thread read (credited once, so the per-round
    /// sum is the true I/O volume).
    pub bytes_read: u64,
    /// Nanoseconds of block decompression on the *worker* thread (0 on
    /// hits: prefetch-thread decode is intentionally excluded — the
    /// whole point is keeping it off the critical path).
    pub decode_nanos: u64,
    /// Whether miss-path I/O went through mmap (splits the stall into
    /// page-fault vs pread wait).
    pub via_mmap: bool,
}

/// Where workers get user data: the lazy synthetic generators
/// ([`GeneratorSource`], the default — no behavior change) or the
/// out-of-core store ([`StoreSource`]). The backend feeds each round's
/// dispatch order to [`Self::hint_round`] so a prefetching source can
/// overlap loads with local training.
pub trait UserDataSource: Send + Sync {
    fn fetch(&self, uid: usize) -> Fetched;

    /// Whether [`Self::hint_round`] is worth calling (lets the backend
    /// skip building the order vector for generator runs).
    fn wants_hints(&self) -> bool {
        false
    }

    /// Announce one round's upcoming uids in dispatch order. Replaces
    /// any previous (possibly abandoned) round's hints.
    fn hint_round(&self, _uids: &[usize]) {}
}

/// The default source: generate lazily from (seed, uid), exactly the
/// pre-store behavior.
pub struct GeneratorSource {
    dataset: Arc<dyn FederatedDataset>,
}

impl GeneratorSource {
    pub fn new(dataset: Arc<dyn FederatedDataset>) -> Self {
        GeneratorSource { dataset }
    }
}

impl UserDataSource for GeneratorSource {
    fn fetch(&self, uid: usize) -> Fetched {
        Fetched {
            data: Arc::new(self.dataset.user_data(uid)),
            cache_hit: None,
            stall_nanos: 0,
            bytes_read: 0,
            decode_nanos: 0,
            via_mmap: false,
        }
    }
}

/// Tuning for a [`StoreSource`] (config `engine.cache_users` /
/// `engine.prefetch_depth`, CLI `--cache-users` / `--prefetch-depth`).
#[derive(Debug, Clone, Copy)]
pub struct SourceConfig {
    /// LRU user-cache capacity (entries).
    pub cache_users: usize,
    /// How many users the prefetch thread may run ahead of worker
    /// consumption (0 disables the thread; the cache remains).
    pub prefetch_depth: usize,
}

impl Default for SourceConfig {
    fn default() -> Self {
        SourceConfig { cache_users: 512, prefetch_depth: 8 }
    }
}

struct CacheEntry {
    data: Arc<UserData>,
    last_used: u64,
    /// Disk bytes read to produce this entry, not yet credited to any
    /// fetch; the first hit takes them (see [`Fetched::bytes_read`]).
    pending_bytes: u64,
}

/// Bounded LRU over `Arc<UserData>`: a hit bumps a tick in place and
/// clones the `Arc` — no allocation. Eviction scans for the least
/// recently used entry (O(capacity), fine for the few-thousand-entry
/// caches this is built for).
struct LruCache {
    cap: usize,
    tick: u64,
    map: HashMap<usize, CacheEntry>,
}

impl LruCache {
    fn new(cap: usize) -> Self {
        let cap = cap.max(1);
        LruCache { cap, tick: 0, map: HashMap::with_capacity(cap + 1) }
    }

    /// A hit: returns the data plus any uncredited prefetch bytes
    /// (taken exactly once).
    fn get(&mut self, uid: usize) -> Option<(Arc<UserData>, u64)> {
        self.tick += 1;
        let tick = self.tick;
        let e = self.map.get_mut(&uid)?;
        e.last_used = tick;
        let bytes = std::mem::take(&mut e.pending_bytes);
        Some((e.data.clone(), bytes))
    }

    fn contains(&self, uid: usize) -> bool {
        self.map.contains_key(&uid)
    }

    fn insert(&mut self, uid: usize, data: Arc<UserData>, pending_bytes: u64) {
        if let Some(e) = self.map.get_mut(&uid) {
            // fetch and prefetch raced: keep the resident copy, but
            // both reads really happened — account the extra bytes
            e.pending_bytes += pending_bytes;
            return;
        }
        if self.map.len() >= self.cap {
            let victim = self.map.iter().min_by_key(|(_, e)| e.last_used).map(|(&k, _)| k);
            if let Some(victim) = victim {
                self.map.remove(&victim);
            }
        }
        self.tick += 1;
        self.map.insert(uid, CacheEntry { data, last_used: self.tick, pending_bytes });
    }

    fn len(&self) -> usize {
        self.map.len()
    }
}

/// Round-scoped prefetch cursor. `issued - consumed` is how far the
/// prefetch thread has run ahead of the workers; it stalls at
/// `prefetch_depth` and wakes on every worker fetch. `hint_round`
/// resets the cursor, so hints from an abandoned round (async mode
/// moves on when its buffer fills) can never wedge the thread.
#[derive(Default)]
struct PrefetchState {
    upcoming: VecDeque<usize>,
    issued: u64,
    consumed: u64,
    stop: bool,
}

struct PrefetchShared {
    state: Mutex<PrefetchState>,
    cv: Condvar,
}

struct Prefetcher {
    shared: Arc<PrefetchShared>,
    handle: Option<JoinHandle<()>>,
}

/// The cached, prefetching [`UserDataSource`] over a [`ShardedStore`].
pub struct StoreSource {
    store: Arc<ShardedStore>,
    cache: Arc<Mutex<LruCache>>,
    prefetch: Option<Prefetcher>,
}

impl StoreSource {
    pub fn new(store: Arc<ShardedStore>, cfg: SourceConfig) -> Self {
        let cache = Arc::new(Mutex::new(LruCache::new(cfg.cache_users)));
        // a prefetch window wider than the cache would evict its own
        // loads before any worker consumed them — every fetch would
        // then re-read the shard, doubling I/O; clamp to the capacity
        let depth_cap = cfg.prefetch_depth.min(cfg.cache_users.max(1));
        let prefetch = if depth_cap > 0 {
            let shared = Arc::new(PrefetchShared {
                state: Mutex::new(PrefetchState::default()),
                cv: Condvar::new(),
            });
            let (s2, c2, st2) = (shared.clone(), cache.clone(), store.clone());
            let depth = depth_cap as u64;
            let handle = std::thread::Builder::new()
                .name("data-prefetch".into())
                .spawn(move || prefetch_loop(s2, c2, st2, depth))
                .expect("spawning data-prefetch thread");
            Some(Prefetcher { shared, handle: Some(handle) })
        } else {
            None
        };
        StoreSource { store, cache, prefetch }
    }

    /// Resident cache entries (diagnostics / tests).
    pub fn cached_users(&self) -> usize {
        self.cache.lock().unwrap_or_else(PoisonError::into_inner).len()
    }

    /// The underlying store (diagnostics / tests).
    pub fn store(&self) -> &Arc<ShardedStore> {
        &self.store
    }

    fn note_consumed(&self) {
        if let Some(p) = &self.prefetch {
            let mut st = p.shared.state.lock().unwrap_or_else(PoisonError::into_inner);
            st.consumed += 1;
            drop(st);
            p.shared.cv.notify_all();
        }
    }
}

impl UserDataSource for StoreSource {
    fn fetch(&self, uid: usize) -> Fetched {
        if let Some((data, bytes)) =
            self.cache.lock().unwrap_or_else(PoisonError::into_inner).get(uid)
        {
            self.note_consumed();
            return Fetched {
                data,
                cache_hit: Some(true),
                stall_nanos: 0,
                bytes_read: bytes,
                decode_nanos: 0,
                via_mmap: false,
            };
        }
        // Miss: the worker eats the read latency; that is exactly the
        // stall the prefetcher exists to hide.
        let t0 = Instant::now();
        let (data, trace) = self
            .store
            .read_user_traced(uid)
            .unwrap_or_else(|e| panic!("store {}: {e:#}", self.store.dir.display()));
        let data = Arc::new(data);
        let stall_nanos = t0.elapsed().as_nanos() as u64;
        // bytes are reported in this Fetched, so the cache entry holds
        // no pending credit (a later hit must not double-count them)
        self.cache
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .insert(uid, data.clone(), 0);
        self.note_consumed();
        Fetched {
            data,
            cache_hit: Some(false),
            stall_nanos,
            bytes_read: trace.bytes_read,
            decode_nanos: trace.decode_nanos,
            via_mmap: trace.via_mmap,
        }
    }

    fn wants_hints(&self) -> bool {
        self.prefetch.is_some()
    }

    fn hint_round(&self, uids: &[usize]) {
        if let Some(p) = &self.prefetch {
            let mut st = p.shared.state.lock().unwrap_or_else(PoisonError::into_inner);
            st.upcoming.clear();
            st.upcoming.extend(uids.iter().copied());
            st.issued = 0;
            st.consumed = 0;
            drop(st);
            p.shared.cv.notify_all();
        }
    }
}

impl Drop for StoreSource {
    fn drop(&mut self) {
        if let Some(p) = &mut self.prefetch {
            {
                let mut st = p.shared.state.lock().unwrap_or_else(PoisonError::into_inner);
                st.stop = true;
            }
            p.shared.cv.notify_all();
            if let Some(h) = p.handle.take() {
                let _ = h.join();
            }
        }
    }
}

fn prefetch_loop(
    shared: Arc<PrefetchShared>,
    cache: Arc<Mutex<LruCache>>,
    store: Arc<ShardedStore>,
    depth: u64,
) {
    loop {
        let uid = {
            let mut st = shared.state.lock().unwrap_or_else(PoisonError::into_inner);
            loop {
                if st.stop {
                    return;
                }
                if !st.upcoming.is_empty() && st.issued < st.consumed + depth {
                    st.issued += 1;
                    break st.upcoming.pop_front().unwrap();
                }
                st = shared.cv.wait(st).unwrap_or_else(PoisonError::into_inner);
            }
        };
        if cache.lock().unwrap_or_else(PoisonError::into_inner).contains(uid) {
            continue; // already resident: the cursor still advances
        }
        // I/O and block decode outside every lock, so workers hitting
        // the cache never wait on the disk or the codec. A failed read
        // is not fatal here: the worker's own fetch of this uid will
        // surface the error.
        //
        // Mid-fetch `hint_round` reset: this uid was popped under the
        // old hints, and the cache insert below lands *after* the
        // reset. That is safe by construction — the entry is keyed by
        // this uid and user data is a pure function of (store, uid), so
        // the worst case is one extra resident entry from the abandoned
        // round (evicted by LRU), never wrong bytes under another
        // user's key. Regression-tested by
        // `mid_round_resets_never_corrupt_reads`.
        if let Ok((d, trace)) = store.read_user_traced(uid) {
            cache
                .lock()
                .unwrap_or_else(PoisonError::into_inner)
                .insert(uid, Arc::new(d), trace.bytes_read);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{SynthGmmPoints, SynthTabular};

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("pfl_store_test_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn bits(d: &UserData) -> Vec<u64> {
        d.bit_fingerprint()
    }

    #[test]
    fn blob_roundtrip_every_variant() {
        let variants = vec![
            UserData::Image { x: vec![0.5, -1.25, f32::MIN_POSITIVE], y: vec![1, -2, 3], hwc: 1 },
            UserData::Features { x: vec![1.0, 2.0], y: vec![0.0, 1.0], feat: 1, labels: 1 },
            UserData::Tokens { seqs: vec![5, 0, -1, 7], seq_len: 2 },
            UserData::Tabular { x: vec![0.25; 6], y: vec![1.5, 2.5], dim: 3 },
            UserData::Points { x: vec![f32::NAN, 1.0], dim: 2 },
            UserData::Points { x: vec![], dim: 3 }, // empty payload
        ];
        for d in &variants {
            let mut buf = Vec::new();
            encode_user_data(d, &mut buf);
            let back = decode_user_data(&buf).unwrap();
            assert_eq!(bits(d), bits(&back));
        }
        // corrupt tag and truncation are errors, not panics
        assert!(decode_user_data(&[9]).is_err());
        let mut buf = Vec::new();
        encode_user_data(&variants[0], &mut buf);
        assert!(decode_user_data(&buf[..buf.len() - 1]).is_err());
    }

    #[test]
    fn materialize_then_read_matches_generator() {
        let dir = tmp_dir("roundtrip");
        let gen = SynthTabular::new(11, 8, 3, 42);
        // odd users_per_shard exercises the multi-shard path
        let stats = materialize(&gen, &dir, 4, 16).unwrap();
        assert_eq!(stats.num_users, 11);
        assert_eq!(stats.num_shards, 3);
        assert!(stats.eval_shards > 0);
        assert_eq!(stats.compression, Compression::None);
        assert_eq!(stats.disk_bytes, stats.data_bytes);
        let store = ShardedStore::open(&dir).unwrap();
        assert_eq!(store.version(), 2);
        assert_eq!(store.name(), gen.name());
        assert_eq!(store.num_users(), 11);
        for uid in 0..11 {
            let (a, b) = (gen.user_data(uid), store.user_data(uid));
            assert_eq!(bits(&a), bits(&b), "user {uid}");
            // user_len comes from the index, free of I/O, and reflects
            // the materialized data
            assert_eq!(store.user_len(uid), a.len());
        }
        let (ea, eb) = (gen.central_eval(16), store.central_eval(16));
        assert_eq!(ea.len(), eb.len());
        for (a, b) in ea.iter().zip(&eb) {
            assert_eq!(bits(a), bits(b));
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn compressed_store_roundtrips_on_both_read_paths() {
        let dir = tmp_dir("lz");
        let gen = SynthTabular::new(13, 16, 4, 7);
        let stats = materialize_with(&gen, &dir, 4, 8, Compression::ShuffleLz).unwrap();
        assert_eq!(stats.compression, Compression::ShuffleLz);
        assert!(
            stats.disk_bytes < stats.data_bytes,
            "shuffle-lz did not shrink: {} vs {}",
            stats.disk_bytes,
            stats.data_bytes
        );
        assert!(stats.compression_ratio() > 1.0);
        for mmap in [true, false] {
            let store =
                ShardedStore::open_with(&dir, OpenOptions { mmap }).unwrap();
            assert_eq!(store.compression(), Compression::ShuffleLz);
            for uid in 0..13 {
                assert_eq!(
                    bits(&gen.user_data(uid)),
                    bits(&store.user_data(uid)),
                    "user {uid} (mmap={mmap})"
                );
            }
            // eval shards stay uncompressed and still read back
            assert_eq!(store.central_eval(8).len(), gen.central_eval(8).len());
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn tiny_blocks_span_blob_boundaries() {
        // a 64-byte block is far smaller than one user's blob, so every
        // read exercises the multi-block assembly path
        let dir = tmp_dir("tinyblock");
        let gen = SynthTabular::new(6, 12, 5, 3);
        let mut w = ShardWriter::create_with(&dir, 4, Compression::ShuffleLz, 64).unwrap();
        for uid in 0..gen.num_users() {
            w.append_user(&gen.user_data(uid)).unwrap();
        }
        w.finish(gen.name()).unwrap();
        for mmap in [true, false] {
            let store = ShardedStore::open_with(&dir, OpenOptions { mmap }).unwrap();
            for uid in 0..6 {
                assert_eq!(bits(&gen.user_data(uid)), bits(&store.user_data(uid)));
            }
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn read_trace_accounts_bytes_and_decode() {
        let dir = tmp_dir("trace");
        let gen = SynthTabular::new(4, 16, 4, 9);
        materialize_with(&gen, &dir, 4, 0, Compression::ShuffleLz).unwrap();
        let store = ShardedStore::open(&dir).unwrap();
        let (_, t0) = store.read_user_traced(0).unwrap();
        assert!(t0.bytes_read > 0, "cold read must report compressed bytes");
        // warm: every block cached → no I/O, no decode
        let (_, t1) = store.read_user_traced(0).unwrap();
        assert_eq!(t1.bytes_read, 0);
        assert_eq!(t1.decode_nanos, 0);
        // raw store reports the blob length
        let dir2 = tmp_dir("trace_raw");
        materialize(&gen, &dir2, 4, 0).unwrap();
        let raw = ShardedStore::open(&dir2).unwrap();
        let (_, tr) = raw.read_user_traced(1).unwrap();
        assert!(tr.bytes_read > 0);
        assert_eq!(tr.decode_nanos, 0, "raw stores never touch the codec");
        let _ = std::fs::remove_dir_all(&dir);
        let _ = std::fs::remove_dir_all(&dir2);
    }

    /// Byte offset of index entry 0 in an `index.bin` written by this
    /// version with a 1-byte store name: magic(8) + version(4) +
    /// num_shards(4) + users_per_shard(4) + name_len(4) + name(1) +
    /// compression(1) + block_size(4) + num_users(8).
    const ENTRY0: usize = 38;

    fn small_store(dir: &Path, comp: Compression) {
        let mut w = ShardWriter::create_with(dir, 2, comp, 64).unwrap();
        for uid in 0..5u32 {
            w.append_user(&UserData::Points { x: vec![uid as f32; 8], dim: 2 }).unwrap();
        }
        w.finish("t").unwrap();
    }

    fn patch(path: &Path, at: usize, bytes: &[u8]) {
        let mut raw = std::fs::read(path).unwrap();
        raw[at..at + bytes.len()].copy_from_slice(bytes);
        std::fs::write(path, raw).unwrap();
    }

    fn store_err(err: &anyhow::Error) -> &StoreError {
        err.downcast_ref::<StoreError>()
            .unwrap_or_else(|| panic!("expected a typed StoreError, got: {err:#}"))
    }

    #[test]
    fn open_rejects_missing_and_garbage() {
        let dir = tmp_dir("garbage");
        assert!(ShardedStore::open(&dir).is_err()); // no index
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("index.bin"), b"not a store").unwrap();
        let err = ShardedStore::open(&dir).unwrap_err();
        assert!(matches!(store_err(&err), StoreError::BadMagic { .. }));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn typed_error_for_unsupported_version() {
        let dir = tmp_dir("badver");
        small_store(&dir, Compression::None);
        patch(&dir.join("index.bin"), 8, &99u32.to_le_bytes());
        let err = ShardedStore::open(&dir).unwrap_err();
        assert!(matches!(
            store_err(&err),
            StoreError::UnsupportedVersion { version: 99, .. }
        ));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn typed_error_for_out_of_range_offsets() {
        // raw store, offset inside the shard header: caught at open
        let dir = tmp_dir("badoff");
        small_store(&dir, Compression::None);
        patch(&dir.join("index.bin"), ENTRY0 + 4, &1u64.to_le_bytes());
        let err = ShardedStore::open(&dir).unwrap_err();
        assert!(matches!(store_err(&err), StoreError::OffsetOutOfRange { uid: 0, .. }));

        // compressed store, offset past the shard's raw extent: open
        let dir2 = tmp_dir("badoff_lz");
        small_store(&dir2, Compression::ShuffleLz);
        patch(&dir2.join("index.bin"), ENTRY0 + 4, &(1u64 << 40).to_le_bytes());
        let err = ShardedStore::open(&dir2).unwrap_err();
        assert!(matches!(store_err(&err), StoreError::OffsetOutOfRange { uid: 0, .. }));

        // raw store, offset far past EOF: the index alone cannot know
        // the file length, so the fetch surfaces Truncated instead
        let dir3 = tmp_dir("badoff_eof");
        small_store(&dir3, Compression::None);
        patch(&dir3.join("index.bin"), ENTRY0 + 4, &(1u64 << 40).to_le_bytes());
        let store = ShardedStore::open(&dir3).unwrap();
        let err = store.read_user(0).unwrap_err();
        assert!(matches!(store_err(&err), StoreError::Truncated { .. }));

        for d in [dir, dir2, dir3] {
            let _ = std::fs::remove_dir_all(&d);
        }
    }

    #[test]
    fn typed_error_for_truncated_shard() {
        for (tag, comp, mmap) in [
            ("trunc_raw_m", Compression::None, true),
            ("trunc_raw_p", Compression::None, false),
            ("trunc_lz_m", Compression::ShuffleLz, true),
            ("trunc_lz_p", Compression::ShuffleLz, false),
        ] {
            let dir = tmp_dir(tag);
            small_store(&dir, comp);
            let shard = dir.join(shard_file_name(0));
            let full = std::fs::read(&shard).unwrap();
            std::fs::write(&shard, &full[..full.len() / 2]).unwrap();
            // open succeeds (index is intact); the length check guards
            // the first read of that shard — before any mmap, so a
            // truncated file can never SIGBUS through the mapping
            let store = ShardedStore::open_with(&dir, OpenOptions { mmap }).unwrap();
            let err = store.read_user(0).unwrap_err();
            assert!(
                matches!(store_err(&err), StoreError::Truncated { .. }),
                "{tag}: {err:#}"
            );
            let _ = std::fs::remove_dir_all(&dir);
        }
    }

    #[test]
    fn typed_error_for_bad_shard_magic_and_mismatch() {
        let dir = tmp_dir("shardmagic");
        small_store(&dir, Compression::None);
        let shard = dir.join(shard_file_name(0));
        patch(&shard, 0, b"X");
        let store = ShardedStore::open(&dir).unwrap();
        let err = store.read_user(0).unwrap_err();
        assert!(matches!(store_err(&err), StoreError::BadMagic { .. }));

        // restore magic, corrupt the header's shard id
        patch(&shard, 0, b"P");
        patch(&shard, 12, &7u32.to_le_bytes());
        let store = ShardedStore::open(&dir).unwrap();
        let err = store.read_user(0).unwrap_err();
        assert!(matches!(
            store_err(&err),
            StoreError::ShardMismatch { expected: 0, found: 7, .. }
        ));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn typed_error_for_uid_out_of_range() {
        let dir = tmp_dir("uidrange");
        small_store(&dir, Compression::None);
        let store = ShardedStore::open(&dir).unwrap();
        let err = store.read_user(999).unwrap_err();
        assert!(matches!(
            store_err(&err),
            StoreError::UidOutOfRange { uid: 999, num_users: 5 }
        ));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn stat_reports_without_scanning() {
        let dir = tmp_dir("stat");
        let gen = SynthTabular::new(9, 16, 4, 21);
        let stats = materialize_with(&gen, &dir, 4, 8, Compression::ShuffleLz).unwrap();
        let st = stat(&dir).unwrap();
        assert_eq!(st.name, gen.name());
        assert_eq!(st.version, 2);
        assert_eq!(st.compression, Compression::ShuffleLz);
        assert_eq!(st.num_users, 9);
        assert_eq!(st.num_shards, 3);
        assert_eq!(st.raw_bytes, stats.data_bytes);
        // disk bytes = compressed payload + one 16-byte header per shard
        assert_eq!(st.disk_bytes, stats.disk_bytes + 3 * SHARD_HEADER_LEN);
        assert!(st.eval_shards > 0);
        assert!(st.compression_ratio() > 1.0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn lru_evicts_least_recently_used() {
        let d = Arc::new(UserData::Points { x: vec![1.0], dim: 1 });
        let mut c = LruCache::new(2);
        c.insert(1, d.clone(), 10);
        c.insert(2, d.clone(), 0);
        let (got, bytes) = c.get(1).unwrap(); // 1 is now most recent
        assert!(Arc::ptr_eq(&got, &d));
        assert_eq!(bytes, 10, "pending prefetch bytes credited on first hit");
        assert_eq!(c.get(1).unwrap().1, 0, "credited exactly once");
        c.insert(3, d.clone(), 0); // evicts 2
        assert!(c.contains(1));
        assert!(!c.contains(2));
        assert!(c.contains(3));
        assert_eq!(c.len(), 2);
        // double insert keeps one entry, accumulating uncredited bytes
        c.insert(3, d, 4);
        assert_eq!(c.len(), 2);
        assert_eq!(c.get(3).unwrap().1, 4);
    }

    #[test]
    fn block_cache_bounds_bytes_and_evicts_lru() {
        let mut c = BlockCache::new(100);
        c.insert(0, 0, Arc::new(vec![0u8; 40]));
        c.insert(0, 1, Arc::new(vec![0u8; 40]));
        assert!(c.get(0, 0).is_some()); // bump block 0
        c.insert(0, 2, Arc::new(vec![0u8; 40])); // evicts (0,1)
        assert!(c.get(0, 0).is_some());
        assert!(c.get(0, 1).is_none());
        assert!(c.get(0, 2).is_some());
        assert!(c.bytes <= 100);
    }

    #[test]
    fn source_counts_hits_misses_and_stalls() {
        let dir = tmp_dir("hitmiss");
        let gen = SynthGmmPoints::new(6, 5, 2, 2, 1);
        materialize(&gen, &dir, 8, 0).unwrap();
        let store = Arc::new(ShardedStore::open(&dir).unwrap());
        let src = StoreSource::new(store, SourceConfig { cache_users: 8, prefetch_depth: 0 });
        let first = src.fetch(3);
        assert_eq!(first.cache_hit, Some(false));
        assert!(first.bytes_read > 0, "miss reads from disk");
        let second = src.fetch(3);
        assert_eq!(second.cache_hit, Some(true));
        assert_eq!(second.stall_nanos, 0);
        assert_eq!(second.bytes_read, 0, "miss already credited its bytes");
        assert_eq!(second.decode_nanos, 0);
        assert_eq!(bits(&first.data), bits(&second.data));
        assert_eq!(bits(&first.data), bits(&gen.user_data(3)));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn prefetcher_runs_ahead_and_respects_depth() {
        let dir = tmp_dir("prefetch");
        let gen = SynthGmmPoints::new(16, 5, 2, 2, 2);
        materialize(&gen, &dir, 8, 0).unwrap();
        let store = Arc::new(ShardedStore::open(&dir).unwrap());
        let src =
            StoreSource::new(store, SourceConfig { cache_users: 16, prefetch_depth: 4 });
        assert!(src.wants_hints());
        let order: Vec<usize> = (0..16).collect();
        src.hint_round(&order);
        // the prefetcher loads at most `depth` users before any fetch
        let deadline = Instant::now() + std::time::Duration::from_secs(5);
        while src.cached_users() < 4 && Instant::now() < deadline {
            std::thread::yield_now();
        }
        assert_eq!(src.cached_users(), 4, "prefetcher should stop at depth");
        // consuming in dispatch order hits the cache and tops it back up
        let mut hits = 0;
        let mut prefetched_bytes = 0;
        for &uid in &order {
            let f = src.fetch(uid);
            if f.cache_hit == Some(true) {
                hits += 1;
                prefetched_bytes += f.bytes_read;
            }
        }
        assert!(hits >= 4, "prefetched users should be hits, got {hits}");
        assert!(
            prefetched_bytes > 0,
            "prefetch-thread reads must be credited through the hit path"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn stale_hints_are_replaced_not_wedged() {
        let dir = tmp_dir("stale");
        let gen = SynthGmmPoints::new(8, 5, 2, 2, 3);
        materialize(&gen, &dir, 8, 0).unwrap();
        let store = Arc::new(ShardedStore::open(&dir).unwrap());
        let src =
            StoreSource::new(store, SourceConfig { cache_users: 8, prefetch_depth: 2 });
        // an abandoned round's hints...
        src.hint_round(&[0, 1, 2, 3]);
        // ...are replaced wholesale by the next round's
        src.hint_round(&[4, 5, 6, 7]);
        for uid in [4usize, 5, 6, 7] {
            let f = src.fetch(uid);
            assert!(f.cache_hit.is_some());
        }
        // and the source still serves anything on demand
        assert_eq!(bits(&src.fetch(0).data), bits(&gen.user_data(0)));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn mid_round_resets_never_corrupt_reads() {
        // Regression for the prefetch-reset race: `hint_round` drops
        // the queue while a read may be in flight on the prefetch
        // thread. Hammer resets from one thread while fetching every
        // uid from another; every fetch must return bit-identical data
        // (an in-flight decoded block or user blob must never land
        // under the wrong key).
        let dir = tmp_dir("midreset");
        let gen = SynthTabular::new(24, 10, 3, 77);
        materialize_with(&gen, &dir, 5, 0, Compression::ShuffleLz).unwrap();
        let store = Arc::new(ShardedStore::open(&dir).unwrap());
        let src = Arc::new(StoreSource::new(
            store,
            SourceConfig { cache_users: 6, prefetch_depth: 3 },
        ));
        let stop = Arc::new(AtomicBool::new(false));
        let (src2, stop2) = (src.clone(), stop.clone());
        let resetter = std::thread::spawn(move || {
            let mut round = 0usize;
            while !stop2.load(Ordering::Relaxed) {
                let order: Vec<usize> = (0..24).map(|i| (i + round) % 24).collect();
                src2.hint_round(&order);
                round += 1;
                std::thread::yield_now();
            }
        });
        let expected: Vec<Vec<u64>> = (0..24).map(|u| bits(&gen.user_data(u))).collect();
        for pass in 0..50 {
            for uid in 0..24 {
                let f = src.fetch(uid);
                assert_eq!(
                    bits(&f.data),
                    expected[uid],
                    "pass {pass}: uid {uid} returned another user's data"
                );
            }
        }
        stop.store(true, Ordering::Relaxed);
        resetter.join().unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn store_backed_run_matches_generator_run() {
        // end-to-end: the same simulation over the generator and over
        // its materialized store produces bit-identical central models
        // (acceptance: with a store, reads are bit-identical, so the
        // whole run is).
        use crate::fl::algorithm::RunSpec;
        use crate::fl::backend::{BackendBuilder, RunParams};
        use crate::fl::central_opt::Sgd;
        use crate::fl::worker::tests::MeanModel;
        use crate::fl::FedAvg;

        let dir = tmp_dir("e2e");
        let gen: Arc<dyn FederatedDataset> = Arc::new(SynthGmmPoints::new(24, 10, 3, 2, 5));
        // compressed store: exercises prefetch-thread decode end to end
        materialize_with(&*gen, &dir, 7, 0, Compression::ShuffleLz).unwrap();
        let store = Arc::new(ShardedStore::open(&dir).unwrap());

        let run = |dataset: Arc<dyn FederatedDataset>,
                   source: Option<Arc<dyn UserDataSource>>| {
            let spec = RunSpec {
                iterations: 5,
                cohort_size: 8,
                population: 24,
                ..Default::default()
            };
            let alg = Arc::new(FedAvg::new(spec, Box::new(Sgd)));
            let mut builder = BackendBuilder::new(
                dataset,
                alg,
                Arc::new(|_| Ok(Box::new(MeanModel::new(3)) as Box<dyn crate::fl::Model>)),
            )
            .params(RunParams { num_workers: 2, ..Default::default() });
            if let Some(s) = source {
                builder = builder.data_source(s);
            }
            builder.build().unwrap().run(vec![1.0; 3], &mut []).unwrap()
        };

        let base = run(gen, None);
        let src: Arc<dyn UserDataSource> = Arc::new(StoreSource::new(
            store.clone(),
            SourceConfig { cache_users: 8, prefetch_depth: 2 },
        ));
        let stored = run(store as Arc<dyn FederatedDataset>, Some(src));
        assert_eq!(base.central, stored.central, "store-backed run diverged");
        assert_eq!(base.rounds, stored.rounds);
        // the store run observed its cache and its I/O volume
        let (h, m) = (stored.counters.cache_hits, stored.counters.cache_misses);
        assert!(h + m > 0, "cache counters never ticked");
        assert!(stored.counters.store_bytes_read > 0, "bytes-read never ticked");
        assert!(stored.final_metric("sys/cache-hit-frac").is_some());
        assert!(stored.final_metric("sys/store-bytes-read").is_some());
        assert!(stored.final_metric("sys/decode-nanos").is_some());
        // the generator run reports no cache metric at all
        assert!(base.final_metric("sys/cache-hit-frac").is_none());
        assert_eq!(base.counters.cache_hits + base.counters.cache_misses, 0);
        assert_eq!(base.counters.store_bytes_read, 0);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
