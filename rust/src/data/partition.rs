//! Partition processes used to federate a centralized pool of examples
//! (paper §4.3: "Datasets × {IID, non-IID}"; App. C.5/C.8).

use crate::util::rng::Rng;

/// IID fixed-size: `num_users` users, each with exactly `per_user`
/// datapoints (CIFAR10 benchmark: 50000/50 = 1000 users, App. C.5).
pub fn iid_fixed_size_partition(total: usize, per_user: usize) -> Vec<usize> {
    let num_users = total / per_user.max(1);
    vec![per_user; num_users]
}

/// Per-user class distributions from a symmetric Dirichlet(alpha) —
/// the standard label-skew non-IID process (App. C.5: alpha = 0.1).
/// Returns `num_users` rows of class probabilities.
pub fn dirichlet_label_partition(
    num_users: usize,
    num_classes: usize,
    alpha: f64,
    seed: u64,
) -> Vec<Vec<f64>> {
    let mut rng = Rng::seed_from_u64(seed ^ 0xD1A1);
    (0..num_users).map(|_| rng.dirichlet(alpha, num_classes)).collect()
}

/// Poisson-distributed user sizes (App. C.8: Stanford Alpaca partition —
/// "sample the length L of each user dataset using Poisson distribution
/// with expectation of 16"), stopping when `total` examples are assigned.
pub fn poisson_size_partition(total: usize, mean: f64, seed: u64) -> Vec<usize> {
    let mut rng = Rng::seed_from_u64(seed ^ 0x7015);
    let mut sizes = Vec::new();
    let mut assigned = 0usize;
    while assigned < total {
        let l = (rng.poisson(mean) as usize).max(1).min(total - assigned);
        sizes.push(l);
        assigned += l;
    }
    sizes
}

/// Log-normal user sizes clipped to [1, max] — FLAIR-like heavy tail
/// (the dispersion that makes load balancing matter, App. B.6 / Fig. 4).
pub fn lognormal_size_partition(
    num_users: usize,
    mu: f64,
    sigma: f64,
    max: usize,
    seed: u64,
) -> Vec<usize> {
    let mut rng = Rng::seed_from_u64(seed ^ 0x106A);
    (0..num_users)
        .map(|_| (rng.lognormal(mu, sigma).ceil() as usize).clamp(1, max))
        .collect()
}

/// Split users that exceed `max` into even chunks of <= max (App. C.8:
/// "if an annotator has more than 64 pairs, we evenly split").
pub fn split_oversized(sizes: &[usize], max: usize) -> Vec<usize> {
    let mut out = Vec::with_capacity(sizes.len());
    for &s in sizes {
        if s <= max {
            out.push(s);
        } else {
            let chunks = s.div_ceil(max);
            let base = s / chunks;
            let rem = s % chunks;
            for i in 0..chunks {
                out.push(base + usize::from(i < rem));
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn iid_matches_paper_cifar_setup() {
        let sizes = iid_fixed_size_partition(50_000, 50);
        assert_eq!(sizes.len(), 1000);
        assert!(sizes.iter().all(|&s| s == 50));
    }

    #[test]
    fn dirichlet_rows_are_distributions() {
        let rows = dirichlet_label_partition(20, 10, 0.1, 3);
        assert_eq!(rows.len(), 20);
        for r in &rows {
            let sum: f64 = r.iter().sum();
            assert!((sum - 1.0).abs() < 1e-9);
        }
        // alpha=0.1 should produce skewed rows: top class > 0.5 typically
        let skewed = rows
            .iter()
            .filter(|r| r.iter().cloned().fold(0.0, f64::max) > 0.5)
            .count();
        assert!(skewed > 10, "only {skewed}/20 rows skewed");
        // alpha=100 should be near-uniform
        let flat = dirichlet_label_partition(20, 10, 100.0, 3);
        let very_skewed = flat
            .iter()
            .filter(|r| r.iter().cloned().fold(0.0, f64::max) > 0.5)
            .count();
        assert_eq!(very_skewed, 0);
    }

    #[test]
    fn poisson_partition_conserves_total() {
        let sizes = poisson_size_partition(52_002, 16.0, 1);
        assert_eq!(sizes.iter().sum::<usize>(), 52_002);
        let mean = sizes.iter().sum::<usize>() as f64 / sizes.len() as f64;
        assert!((mean - 16.0).abs() < 1.5, "mean {mean}");
        assert!(sizes.iter().all(|&s| s >= 1));
    }

    #[test]
    fn lognormal_is_heavy_tailed_and_clipped() {
        let sizes = lognormal_size_partition(5000, 3.0, 1.2, 512, 9);
        assert!(sizes.iter().all(|&s| (1..=512).contains(&s)));
        let mean = sizes.iter().sum::<usize>() as f64 / sizes.len() as f64;
        let med = {
            let mut s = sizes.clone();
            s.sort_unstable();
            s[s.len() / 2] as f64
        };
        assert!(mean > med, "heavy tail: mean {mean} <= median {med}");
    }

    #[test]
    fn split_oversized_conserves_and_bounds() {
        let out = split_oversized(&[10, 64, 65, 200], 64);
        assert_eq!(out.iter().sum::<usize>(), 10 + 64 + 65 + 200);
        assert!(out.iter().all(|&s| s <= 64 && s >= 1));
        assert_eq!(out.len(), 1 + 1 + 2 + 4);
    }
}
