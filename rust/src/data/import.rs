//! Write-through corpus import: materialize an *external* tabular
//! corpus into the sharded store (`pfl import`), so real data — not
//! just the generator zoo — feeds the out-of-core pipeline.
//!
//! Two documented input layouts, both streamed row-by-row straight
//! through [`ShardWriter`] (the importer never holds more than one
//! user's rows in memory, so corpus size is bounded by disk, not RAM):
//!
//! **JSONL** — one object per line:
//! ```text
//! {"user": "alice", "x": [0.1, 2.0, -1.5], "y": 1.0}
//! {"user": "alice", "x": [0.0, 1.0, 3.25], "y": 0.0}
//! {"user": "bob",   "x": [9.5, 0.5, 0.75], "y": 1.0}
//! ```
//! `user` may be a string or a number; `y` is optional but must be
//! present on all rows or none.
//!
//! **CSV** — header row `user[,y],f0,f1,...` then one example per row:
//! ```text
//! user,y,f0,f1,f2
//! alice,1.0,0.1,2.0,-1.5
//! bob,1.0,9.5,0.5,0.75
//! ```
//!
//! Rows for one user must be contiguous (the store is written
//! sequentially); a user key reappearing after another user is an
//! error, not a silent merge. Labeled corpora become
//! [`UserData::Tabular`], unlabeled ones [`UserData::Points`]. uids are
//! assigned in order of first appearance.

use std::collections::HashSet;
use std::fs::File;
use std::io::{BufRead, BufReader};
use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use super::codec::Compression;
use super::store::{ShardWriter, StoreStats};
use super::UserData;
use crate::util::json::Value;

/// Input layout; [`ImportFormat::detect`] infers it from the file
/// extension when the CLI does not pass `--format`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ImportFormat {
    Jsonl,
    Csv,
}

impl ImportFormat {
    pub fn detect(path: &Path) -> Result<ImportFormat> {
        match path.extension().and_then(|e| e.to_str()) {
            Some("jsonl") | Some("ndjson") | Some("json") => Ok(ImportFormat::Jsonl),
            Some("csv") | Some("tsv") => Ok(ImportFormat::Csv),
            other => bail!(
                "cannot infer corpus format from extension {other:?} \
                 (use .jsonl/.ndjson or .csv, or pass --format)"
            ),
        }
    }
}

impl std::str::FromStr for ImportFormat {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<ImportFormat> {
        match s {
            "jsonl" => Ok(ImportFormat::Jsonl),
            "csv" => Ok(ImportFormat::Csv),
            other => bail!("unknown import format {other:?} (expected jsonl|csv)"),
        }
    }
}

/// Import tuning; the defaults mirror `pfl materialize`.
#[derive(Debug, Clone)]
pub struct ImportOptions {
    pub users_per_shard: usize,
    pub compression: Compression,
    /// Store name recorded in the index (shown by `pfl store stat` and
    /// used by `engine.data_store` validation).
    pub name: String,
    /// `None`: infer from the input file extension.
    pub format: Option<ImportFormat>,
}

impl Default for ImportOptions {
    fn default() -> Self {
        ImportOptions {
            users_per_shard: 256,
            compression: Compression::None,
            name: "imported".into(),
            format: None,
        }
    }
}

/// One parsed example row.
struct Row {
    user: String,
    x: Vec<f32>,
    y: Option<f32>,
}

fn parse_jsonl_row(line: &str, lineno: usize) -> Result<Row> {
    let v = Value::parse(line).with_context(|| format!("line {lineno}: invalid JSON"))?;
    let user_v = v.req("user").with_context(|| format!("line {lineno}"))?;
    let user = match user_v.as_str() {
        Ok(s) => s.to_string(),
        // numeric user ids are fine; canonicalize through f64
        Err(_) => {
            let n = user_v
                .as_f64()
                .with_context(|| format!("line {lineno}: user must be a string or number"))?;
            format!("{n}")
        }
    };
    let x: Vec<f32> = v
        .req("x")
        .and_then(|a| a.as_arr())
        .with_context(|| format!("line {lineno}: missing feature array \"x\""))?
        .iter()
        .map(|f| f.as_f64().map(|f| f as f32))
        .collect::<Result<_>>()
        .with_context(|| format!("line {lineno}: non-numeric feature"))?;
    let y = match v.get("y") {
        Some(f) => Some(
            f.as_f64()
                .with_context(|| format!("line {lineno}: label \"y\" must be a number"))?
                as f32,
        ),
        None => None,
    };
    Ok(Row { user, x, y })
}

/// CSV column layout from the header row.
struct CsvHeader {
    has_y: bool,
    features: usize,
}

fn parse_csv_header(line: &str) -> Result<CsvHeader> {
    let cols: Vec<&str> = line.split(',').map(str::trim).collect();
    ensure!(
        cols.first() == Some(&"user"),
        "CSV header must start with a \"user\" column, got {:?}",
        cols.first().unwrap_or(&"")
    );
    let has_y = cols.get(1) == Some(&"y");
    let features = cols.len() - 1 - usize::from(has_y);
    ensure!(features > 0, "CSV header declares no feature columns");
    Ok(CsvHeader { has_y, features })
}

fn parse_csv_row(line: &str, header: &CsvHeader, lineno: usize) -> Result<Row> {
    let cols: Vec<&str> = line.split(',').map(str::trim).collect();
    let expect = 1 + usize::from(header.has_y) + header.features;
    ensure!(
        cols.len() == expect,
        "line {lineno}: {} columns, header declares {expect}",
        cols.len()
    );
    let user = cols[0].to_string();
    ensure!(!user.is_empty(), "line {lineno}: empty user key");
    let mut idx = 1;
    let y = if header.has_y {
        let v: f32 = cols[idx]
            .parse()
            .with_context(|| format!("line {lineno}: bad label {:?}", cols[idx]))?;
        idx += 1;
        Some(v)
    } else {
        None
    };
    let x = cols[idx..]
        .iter()
        .map(|c| {
            c.parse::<f32>().with_context(|| format!("line {lineno}: bad feature {c:?}"))
        })
        .collect::<Result<Vec<f32>>>()?;
    Ok(Row { user, x, y })
}

/// Accumulates one user's contiguous rows, then writes through.
struct PendingUser {
    key: String,
    x: Vec<f32>,
    y: Vec<f32>,
}

struct Importer {
    writer: ShardWriter,
    pending: Option<PendingUser>,
    seen: HashSet<String>,
    /// Feature dimension and labeledness, fixed by the first row.
    dim: usize,
    has_y: bool,
    users: usize,
    rows: u64,
}

impl Importer {
    fn flush(&mut self) -> Result<()> {
        if let Some(p) = self.pending.take() {
            let data = if self.has_y {
                UserData::Tabular { x: p.x, y: p.y, dim: self.dim }
            } else {
                UserData::Points { x: p.x, dim: self.dim }
            };
            self.writer
                .append_user(&data)
                .with_context(|| format!("writing user {:?}", p.key))?;
            self.users += 1;
        }
        Ok(())
    }

    fn push(&mut self, row: Row, lineno: usize) -> Result<()> {
        if self.rows == 0 {
            self.dim = row.x.len();
            self.has_y = row.y.is_some();
            ensure!(self.dim > 0, "line {lineno}: first row has no features");
        }
        ensure!(
            row.x.len() == self.dim,
            "line {lineno}: {} features, corpus dimension is {}",
            row.x.len(),
            self.dim
        );
        ensure!(
            row.y.is_some() == self.has_y,
            "line {lineno}: label presence differs from the first row \
             (all rows must have \"y\", or none)"
        );
        let start_new = match &self.pending {
            Some(p) => p.key != row.user,
            None => true,
        };
        if start_new {
            self.flush()?;
            if !self.seen.insert(row.user.clone()) {
                bail!(
                    "line {lineno}: user {:?} reappears after other users — \
                     rows for one user must be contiguous",
                    row.user
                );
            }
            self.pending = Some(PendingUser { key: row.user, x: Vec::new(), y: Vec::new() });
        }
        let p = self.pending.as_mut().unwrap();
        p.x.extend_from_slice(&row.x);
        if let Some(y) = row.y {
            p.y.push(y);
        }
        self.rows += 1;
        Ok(())
    }
}

/// Stream `input` through [`ShardWriter`] into a store at `out`.
/// Returns the same [`StoreStats`] `materialize` would.
pub fn import_corpus(input: &Path, out: &Path, opts: &ImportOptions) -> Result<StoreStats> {
    let format = match opts.format {
        Some(f) => f,
        None => ImportFormat::detect(input)?,
    };
    let file =
        File::open(input).with_context(|| format!("opening corpus {}", input.display()))?;
    let reader = BufReader::new(file);
    let writer = ShardWriter::create_with(
        out,
        opts.users_per_shard,
        opts.compression,
        super::codec::DEFAULT_BLOCK_SIZE,
    )?;
    let mut imp = Importer {
        writer,
        pending: None,
        seen: HashSet::new(),
        dim: 0,
        has_y: false,
        users: 0,
        rows: 0,
    };
    let mut header: Option<CsvHeader> = None;
    for (i, line) in reader.lines().enumerate() {
        let lineno = i + 1;
        let line = line.with_context(|| format!("reading line {lineno}"))?;
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        let row = match format {
            ImportFormat::Jsonl => parse_jsonl_row(trimmed, lineno)?,
            ImportFormat::Csv => match &header {
                None => {
                    header = Some(parse_csv_header(trimmed)?);
                    continue;
                }
                Some(h) => parse_csv_row(trimmed, h, lineno)?,
            },
        };
        imp.push(row, lineno)?;
    }
    imp.flush()?;
    ensure!(imp.users > 0, "corpus {} contains no rows", input.display());
    imp.writer.finish(&opts.name)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::store::{OpenOptions, ShardedStore};
    use crate::data::FederatedDataset;
    use std::path::PathBuf;

    fn tmp(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("pfl_import_test_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        let _ = std::fs::remove_file(&d);
        d
    }

    #[test]
    fn jsonl_roundtrips_users_and_labels() {
        let corpus = tmp("jsonl").with_extension("jsonl");
        std::fs::write(
            &corpus,
            concat!(
                "{\"user\": \"alice\", \"x\": [0.5, -1.0], \"y\": 1.0}\n",
                "{\"user\": \"alice\", \"x\": [2.0, 3.0], \"y\": 0.0}\n",
                "\n",
                "{\"user\": \"bob\", \"x\": [9.0, 8.0], \"y\": 1.0}\n",
                "{\"user\": 3, \"x\": [7.5, 6.5], \"y\": 0.0}\n",
            ),
        )
        .unwrap();
        let out = tmp("jsonl_store");
        let stats = import_corpus(
            &corpus,
            &out,
            &ImportOptions {
                users_per_shard: 2,
                compression: Compression::ShuffleLz,
                name: "corpus-test".into(),
                format: None,
            },
        )
        .unwrap();
        assert_eq!(stats.num_users, 3);
        assert_eq!(stats.num_shards, 2);
        for mmap in [true, false] {
            let store = ShardedStore::open_with(&out, OpenOptions { mmap }).unwrap();
            assert_eq!(store.name(), "corpus-test");
            assert_eq!(store.num_users(), 3);
            // alice: 2 examples; bob and "3": 1 each
            assert_eq!(store.user_len(0), 2);
            match store.user_data(0) {
                UserData::Tabular { x, y, dim } => {
                    assert_eq!(dim, 2);
                    assert_eq!(x, vec![0.5, -1.0, 2.0, 3.0]);
                    assert_eq!(y, vec![1.0, 0.0]);
                }
                other => panic!("expected Tabular, got {other:?}"),
            }
            match store.user_data(2) {
                UserData::Tabular { x, y, .. } => {
                    assert_eq!(x, vec![7.5, 6.5]);
                    assert_eq!(y, vec![0.0]);
                }
                other => panic!("expected Tabular, got {other:?}"),
            }
        }
        let _ = std::fs::remove_file(&corpus);
        let _ = std::fs::remove_dir_all(&out);
    }

    #[test]
    fn csv_with_and_without_labels() {
        let corpus = tmp("csv").with_extension("csv");
        std::fs::write(&corpus, "user,y,f0,f1\nu1,1.0,0.5,0.25\nu1,0.0,1.5,2.5\nu2,1.0,3.0,4.0\n")
            .unwrap();
        let out = tmp("csv_store");
        let stats = import_corpus(&corpus, &out, &ImportOptions::default()).unwrap();
        assert_eq!(stats.num_users, 2);
        let store = ShardedStore::open(&out).unwrap();
        assert!(matches!(store.user_data(0), UserData::Tabular { .. }));

        // unlabeled variant becomes Points
        std::fs::write(&corpus, "user,f0,f1\nu1,0.5,0.25\nu2,3.0,4.0\n").unwrap();
        let out2 = tmp("csv_store2");
        import_corpus(&corpus, &out2, &ImportOptions::default()).unwrap();
        let store2 = ShardedStore::open(&out2).unwrap();
        match store2.user_data(1) {
            UserData::Points { x, dim } => {
                assert_eq!(dim, 2);
                assert_eq!(x, vec![3.0, 4.0]);
            }
            other => panic!("expected Points, got {other:?}"),
        }
        let _ = std::fs::remove_file(&corpus);
        let _ = std::fs::remove_dir_all(&out);
        let _ = std::fs::remove_dir_all(&out2);
    }

    #[test]
    fn malformed_corpora_error_cleanly() {
        let out = tmp("bad_store");
        let corpus = tmp("bad").with_extension("jsonl");

        // non-contiguous duplicate user
        std::fs::write(
            &corpus,
            "{\"user\":\"a\",\"x\":[1.0]}\n{\"user\":\"b\",\"x\":[2.0]}\n{\"user\":\"a\",\"x\":[3.0]}\n",
        )
        .unwrap();
        let err = import_corpus(&corpus, &out, &ImportOptions::default()).unwrap_err();
        assert!(format!("{err:#}").contains("contiguous"), "{err:#}");

        // feature dimension mismatch
        std::fs::write(&corpus, "{\"user\":\"a\",\"x\":[1.0]}\n{\"user\":\"a\",\"x\":[1.0,2.0]}\n")
            .unwrap();
        assert!(import_corpus(&corpus, &out, &ImportOptions::default()).is_err());

        // label on some rows only
        std::fs::write(
            &corpus,
            "{\"user\":\"a\",\"x\":[1.0],\"y\":1.0}\n{\"user\":\"a\",\"x\":[2.0]}\n",
        )
        .unwrap();
        assert!(import_corpus(&corpus, &out, &ImportOptions::default()).is_err());

        // empty corpus
        std::fs::write(&corpus, "\n\n").unwrap();
        assert!(import_corpus(&corpus, &out, &ImportOptions::default()).is_err());

        // unknown extension without explicit format
        let odd = tmp("odd").with_extension("parquet");
        std::fs::write(&odd, "x").unwrap();
        assert!(import_corpus(&odd, &out, &ImportOptions::default()).is_err());

        let _ = std::fs::remove_file(&corpus);
        let _ = std::fs::remove_file(&odd);
        let _ = std::fs::remove_dir_all(&out);
    }
}
