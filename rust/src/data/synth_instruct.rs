//! LLM fine-tuning corpora substitutes (paper App. C.8): Stanford Alpaca
//! (IID, Poisson-16 user sizes), Aya (natural user keys, max 64 per user,
//! oversized annotators split evenly) and OpenAssistant (natural user
//! keys, conversation pairs).
//!
//! All three reuse the topic-bigram generator of `SynthText` at the LoRA
//! model's shape (vocab 2000, seq 32); what differs — and what the paper's
//! LLM benchmarks actually probe — is the *user partition process*.

use super::synth_text::SynthText;
use super::{partition, FederatedDataset, UserData};

pub const VOCAB: usize = 2_000;
pub const SEQ: usize = 32;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InstructFlavor {
    /// Stanford Alpaca: no natural user keys; Poisson(16) partition.
    Alpaca,
    /// Aya: natural annotator keys, heavy-tailed, split at 64.
    Aya,
    /// OpenAssistant: natural conversation keys, lighter tail.
    OpenAssistant,
}

pub struct SynthInstruct {
    pub flavor: InstructFlavor,
    inner: SynthText,
    sizes: Vec<usize>,
}

impl SynthInstruct {
    pub fn new(flavor: InstructFlavor, target_examples: usize, seed: u64) -> Self {
        let sizes = match flavor {
            InstructFlavor::Alpaca => {
                // "sample the length L of each user dataset using Poisson
                // distribution with expectation of 16 data per user"
                partition::poisson_size_partition(target_examples, 16.0, seed)
            }
            InstructFlavor::Aya => {
                // heavy-tailed annotator productivity, split at 64
                let raw = partition::lognormal_size_partition(
                    target_examples / 12,
                    2.2,
                    1.3,
                    4096,
                    seed,
                );
                partition::split_oversized(&raw, 64)
            }
            InstructFlavor::OpenAssistant => {
                partition::lognormal_size_partition(target_examples / 8, 1.8, 0.9, 64, seed)
            }
        };
        let inner = SynthText::with_shape(sizes.len(), VOCAB, SEQ, seed ^ 0x11AA);
        SynthInstruct { flavor, inner, sizes }
    }

    /// Small presets sized for CPU simulation (paper used 52k/204k/85k
    /// examples; scale preserved in relative terms via `scale`).
    pub fn preset(flavor: InstructFlavor, scale: f64, seed: u64) -> Self {
        let base = match flavor {
            InstructFlavor::Alpaca => 52_002,
            InstructFlavor::Aya => 204_112,
            InstructFlavor::OpenAssistant => 85_318,
        };
        Self::new(flavor, ((base as f64 * scale) as usize).max(64), seed)
    }
}

impl FederatedDataset for SynthInstruct {
    fn name(&self) -> &str {
        match self.flavor {
            InstructFlavor::Alpaca => "synth-alpaca",
            InstructFlavor::Aya => "synth-aya",
            InstructFlavor::OpenAssistant => "synth-oasst",
        }
    }

    fn num_users(&self) -> usize {
        self.sizes.len()
    }

    fn user_data(&self, uid: usize) -> UserData {
        // reuse the topic-bigram generator but with this flavor's size
        let full = self.inner.user_data(uid);
        let want = self.user_len(uid);
        match full {
            UserData::Tokens { mut seqs, seq_len } => {
                let have = seqs.len() / seq_len;
                if have >= want {
                    seqs.truncate(want * seq_len);
                } else {
                    // tile to reach the partition size
                    let mut i = 0;
                    while seqs.len() < want * seq_len {
                        let row: Vec<i32> =
                            seqs[(i % have) * seq_len..(i % have + 1) * seq_len].to_vec();
                        seqs.extend_from_slice(&row);
                        i += 1;
                    }
                }
                UserData::Tokens { seqs, seq_len }
            }
            other => other,
        }
    }

    fn user_len(&self, uid: usize) -> usize {
        self.sizes[uid]
    }

    fn central_eval(&self, shard_size: usize) -> Vec<UserData> {
        self.inner.central_eval(shard_size)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alpaca_mean_size_is_poisson16() {
        let d = SynthInstruct::new(InstructFlavor::Alpaca, 16_000, 3);
        let mean = (0..d.num_users()).map(|u| d.user_len(u)).sum::<usize>() as f64
            / d.num_users() as f64;
        assert!((mean - 16.0).abs() < 2.0, "mean {mean}");
    }

    #[test]
    fn aya_sizes_capped_at_64() {
        let d = SynthInstruct::new(InstructFlavor::Aya, 20_000, 4);
        assert!((0..d.num_users()).all(|u| (1..=64).contains(&d.user_len(u))));
    }

    #[test]
    fn user_data_length_matches_partition() {
        for flavor in [
            InstructFlavor::Alpaca,
            InstructFlavor::Aya,
            InstructFlavor::OpenAssistant,
        ] {
            let d = SynthInstruct::new(flavor, 4000, 5);
            for uid in [0, d.num_users() / 2, d.num_users() - 1] {
                assert_eq!(d.user_data(uid).len(), d.user_len(uid), "{flavor:?} uid {uid}");
            }
        }
    }

    #[test]
    fn presets_scale() {
        let d = SynthInstruct::preset(InstructFlavor::Alpaca, 0.01, 0);
        let total: usize = (0..d.num_users()).map(|u| d.user_len(u)).sum();
        assert!((total as i64 - 520).abs() < 32, "total {total}");
    }
}
