//! Synthetic tabular / point-cloud federated datasets for the non-NN
//! models (paper §1 "Non-gradient-descent training": federated GBDT and
//! federated GMM).

use super::{FederatedDataset, UserData};
use crate::util::rng::Rng;

/// Regression dataset with piecewise structure a GBDT can exploit:
/// y = Σ_j step(x_j > θ_j) * w_j + noise. Users have heterogeneous
/// feature distributions (shifted means).
pub struct SynthTabular {
    pub num_users: usize,
    pub per_user: usize,
    pub dim: usize,
    pub noise: f64,
    pub eval_examples: usize,
    seed: u64,
    thresholds: Vec<f64>,
    weights: Vec<f64>,
}

impl SynthTabular {
    pub fn new(num_users: usize, per_user: usize, dim: usize, seed: u64) -> Self {
        let mut rng = Rng::seed_from_u64(seed ^ 0x7AB1_E000);
        let thresholds = (0..dim).map(|_| rng.range_f64(-0.5, 0.5)).collect();
        let weights = (0..dim).map(|_| rng.range_f64(-1.0, 1.0)).collect();
        SynthTabular {
            num_users,
            per_user,
            dim,
            noise: 0.1,
            eval_examples: 1000,
            seed,
            thresholds,
            weights,
        }
    }

    pub fn true_fn(&self, x: &[f64]) -> f64 {
        x.iter()
            .zip(&self.thresholds)
            .zip(&self.weights)
            .map(|((xi, t), w)| if xi > t { *w } else { 0.0 })
            .sum()
    }

    fn gen(&self, rng: &mut Rng, n: usize, shift: f64) -> UserData {
        let mut x = vec![0f32; n * self.dim];
        let mut y = vec![0f32; n];
        let mut row = vec![0f64; self.dim];
        for i in 0..n {
            for (j, r) in row.iter_mut().enumerate() {
                *r = rng.normal() + shift;
                x[i * self.dim + j] = *r as f32;
            }
            y[i] = (self.true_fn(&row) + self.noise * rng.normal()) as f32;
        }
        UserData::Tabular { x, y, dim: self.dim }
    }
}

impl FederatedDataset for SynthTabular {
    fn name(&self) -> &str {
        "synth-tabular"
    }

    fn num_users(&self) -> usize {
        self.num_users
    }

    fn user_data(&self, uid: usize) -> UserData {
        let mut rng = Rng::seed_from_u64(self.seed ^ (uid as u64).wrapping_mul(0xBF58_476D));
        let shift = 0.4 * rng.normal(); // heterogeneous covariate shift
        self.gen(&mut rng, self.user_len(uid), shift)
    }

    /// Heterogeneous user sizes in [per_user/2, 3·per_user/2] (realistic
    /// FL populations have dispersed dataset lengths; keeps the weighting
    /// and scheduling features observable on this dataset too).
    fn user_len(&self, uid: usize) -> usize {
        let mut rng = Rng::seed_from_u64(self.seed ^ (uid as u64).wrapping_mul(0x9E37_79B9));
        let half = (self.per_user / 2).max(1);
        half + rng.below(self.per_user.max(1))
    }

    fn central_eval(&self, shard_size: usize) -> Vec<UserData> {
        let mut rng = Rng::seed_from_u64(self.seed ^ 0xEEE4);
        let mut shards = Vec::new();
        let mut remaining = self.eval_examples;
        while remaining > 0 {
            let n = remaining.min(shard_size);
            shards.push(self.gen(&mut rng, n, 0.0));
            remaining -= n;
        }
        shards
    }
}

/// Mixture-of-Gaussians point clouds (for federated GMM): K true
/// components; users see a user-specific mixture of them.
pub struct SynthGmmPoints {
    pub num_users: usize,
    pub per_user: usize,
    pub dim: usize,
    pub components: usize,
    pub eval_examples: usize,
    seed: u64,
    pub means: Vec<f64>, // components x dim
}

impl SynthGmmPoints {
    pub fn new(num_users: usize, per_user: usize, dim: usize, components: usize, seed: u64) -> Self {
        let mut rng = Rng::seed_from_u64(seed ^ 0x6333_0000);
        // well-separated means
        let means = (0..components * dim).map(|_| 4.0 * rng.normal()).collect();
        SynthGmmPoints {
            num_users,
            per_user,
            dim,
            components,
            eval_examples: 1000,
            seed,
            means,
        }
    }

    fn gen(&self, rng: &mut Rng, n: usize, mixture: &[f64]) -> UserData {
        let mut x = vec![0f32; n * self.dim];
        for i in 0..n {
            let u = rng.f64();
            let mut k = self.components - 1;
            let mut acc = 0.0;
            for (c, p) in mixture.iter().enumerate() {
                acc += p;
                if u < acc {
                    k = c;
                    break;
                }
            }
            for j in 0..self.dim {
                x[i * self.dim + j] = (self.means[k * self.dim + j] + rng.normal()) as f32;
            }
        }
        UserData::Points { x, dim: self.dim }
    }
}

impl FederatedDataset for SynthGmmPoints {
    fn name(&self) -> &str {
        "synth-gmm-points"
    }

    fn num_users(&self) -> usize {
        self.num_users
    }

    fn user_data(&self, uid: usize) -> UserData {
        let mut rng = Rng::seed_from_u64(self.seed ^ (uid as u64).wrapping_mul(0x9403_91CB));
        let mixture = rng.dirichlet(0.5, self.components);
        self.gen(&mut rng, self.per_user, &mixture)
    }

    fn user_len(&self, _uid: usize) -> usize {
        self.per_user
    }

    fn central_eval(&self, shard_size: usize) -> Vec<UserData> {
        let mut rng = Rng::seed_from_u64(self.seed ^ 0xEEE5);
        let uniform = vec![1.0 / self.components as f64; self.components];
        let mut shards = Vec::new();
        let mut remaining = self.eval_examples;
        while remaining > 0 {
            let n = remaining.min(shard_size);
            shards.push(self.gen(&mut rng, n, &uniform));
            remaining -= n;
        }
        shards
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tabular_signal_dominates_noise() {
        let d = SynthTabular::new(10, 200, 5, 1);
        if let UserData::Tabular { y, .. } = d.user_data(0) {
            let var: f64 = {
                let m = y.iter().map(|v| *v as f64).sum::<f64>() / y.len() as f64;
                y.iter().map(|v| (*v as f64 - m).powi(2)).sum::<f64>() / y.len() as f64
            };
            assert!(var > 0.05, "var {var}"); // structure present
        } else {
            panic!()
        }
    }

    #[test]
    fn gmm_points_cluster_near_means() {
        let d = SynthGmmPoints::new(5, 500, 2, 3, 2);
        if let UserData::Points { x, dim } = d.user_data(1) {
            // every point within ~5 sigma of *some* mean
            for p in x.chunks(dim) {
                let mut best = f64::MAX;
                for k in 0..3 {
                    let dist: f64 = p
                        .iter()
                        .enumerate()
                        .map(|(j, v)| (*v as f64 - d.means[k * dim + j]).powi(2))
                        .sum();
                    best = best.min(dist.sqrt());
                }
                assert!(best < 6.0, "point {best} sigma away");
            }
        } else {
            panic!()
        }
    }

    #[test]
    fn deterministic_users() {
        let d = SynthTabular::new(4, 10, 3, 5);
        match (d.user_data(2), d.user_data(2)) {
            (UserData::Tabular { x: a, .. }, UserData::Tabular { x: b, .. }) => assert_eq!(a, b),
            _ => unreachable!(),
        }
    }
}
