//! Federated datasets (paper App. B.1 "Dataset" + §4.3 benchmarks).
//!
//! The paper's benchmark datasets (CIFAR10, StackOverflow, FLAIR, LLM
//! corpora) are substituted with deterministic synthetic generators that
//! preserve the properties each benchmark stresses — shapes, user
//! cardinalities, partition processes (IID / Dirichlet non-IID / natural
//! keys), and the heavy-tailed user-size dispersion that drives the
//! scheduling experiments. See DESIGN.md §2 for the substitution table.
//!
//! Data is generated lazily per user from (dataset_seed, user_id), so a
//! million-user population costs no memory — the analogue of
//! pfl-research's async user-dataset loading being off the critical path.
//! For the I/O-bound regime — materialized user data that cannot live in
//! RAM — [`store`] adds an out-of-core sharded store (`pfl materialize`
//! writes it, [`ShardedStore`] reads it back bit-identically) behind the
//! [`UserDataSource`] worker interface, with an LRU cache and a
//! dispatcher-fed prefetch thread (DESIGN.md §6).

pub mod codec;
pub mod import;
pub mod partition;
pub mod sampling;
pub mod store;
pub mod synth_cifar;
pub mod synth_flair;
pub mod synth_instruct;
pub mod synth_text;
pub mod tabular;

pub use partition::{dirichlet_label_partition, iid_fixed_size_partition, poisson_size_partition};
pub use sampling::{CohortSampler, CrossSiloSampler, MinibatchSampler, PoissonCohortSampler};
pub use codec::Compression;
pub use import::{import_corpus, ImportFormat, ImportOptions};
pub use store::{
    materialize, materialize_with, stat, Fetched, GeneratorSource, OpenOptions, ReadTrace,
    ShardWriter, ShardedStore, SourceConfig, StoreError, StoreSource, StoreStat, UserDataSource,
};
pub use synth_cifar::SynthCifar;
pub use synth_flair::SynthFlair;
pub use synth_instruct::{InstructFlavor, SynthInstruct};
pub use synth_text::SynthText;
pub use tabular::{SynthGmmPoints, SynthTabular};

/// One user's (or one central-eval shard's) data, shaped for the model
/// family that consumes it.
#[derive(Debug, Clone)]
pub enum UserData {
    /// Images NHWC-flattened + integer labels.
    Image { x: Vec<f32>, y: Vec<i32>, hwc: usize },
    /// Dense features + multi-hot labels.
    Features { x: Vec<f32>, y: Vec<f32>, feat: usize, labels: usize },
    /// Token sequences, row-major [n, seq_len], PAD=0.
    Tokens { seqs: Vec<i32>, seq_len: usize },
    /// Tabular regression/classification rows (GBDT).
    Tabular { x: Vec<f32>, y: Vec<f32>, dim: usize },
    /// Unlabeled points (GMM).
    Points { x: Vec<f32>, dim: usize },
}

impl UserData {
    /// Number of examples.
    pub fn len(&self) -> usize {
        match self {
            UserData::Image { y, .. } => y.len(),
            UserData::Features { y, labels, .. } => {
                if *labels == 0 {
                    0
                } else {
                    y.len() / labels
                }
            }
            UserData::Tokens { seqs, seq_len } => {
                if *seq_len == 0 {
                    0
                } else {
                    seqs.len() / seq_len
                }
            }
            UserData::Tabular { y, .. } => y.len(),
            UserData::Points { x, dim } => {
                if *dim == 0 {
                    0
                } else {
                    x.len() / dim
                }
            }
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Bit-level fingerprint: the variant tag, shape fields, and every
    /// payload element as raw bits (`f32::to_bits` — NaNs included, so
    /// "close" never passes for "identical"). Two records fingerprint
    /// equal iff they are byte-for-byte the same data; the store
    /// round-trip tests are built on this.
    pub fn bit_fingerprint(&self) -> Vec<u64> {
        let mut out = Vec::new();
        match self {
            UserData::Image { x, y, hwc } => {
                out.push(0);
                out.push(*hwc as u64);
                out.extend(y.iter().map(|v| *v as u64));
                out.extend(x.iter().map(|v| v.to_bits() as u64));
            }
            UserData::Features { x, y, feat, labels } => {
                out.push(1);
                out.push(*feat as u64);
                out.push(*labels as u64);
                out.extend(x.iter().map(|v| v.to_bits() as u64));
                out.extend(y.iter().map(|v| v.to_bits() as u64));
            }
            UserData::Tokens { seqs, seq_len } => {
                out.push(2);
                out.push(*seq_len as u64);
                out.extend(seqs.iter().map(|v| *v as u64));
            }
            UserData::Tabular { x, y, dim } => {
                out.push(3);
                out.push(*dim as u64);
                out.extend(x.iter().map(|v| v.to_bits() as u64));
                out.extend(y.iter().map(|v| v.to_bits() as u64));
            }
            UserData::Points { x, dim } => {
                out.push(4);
                out.push(*dim as u64);
                out.extend(x.iter().map(|v| v.to_bits() as u64));
            }
        }
        out
    }
}

/// A federated dataset: a population of users with lazily-generated data.
pub trait FederatedDataset: Send + Sync {
    fn name(&self) -> &str;

    /// Population size (number of user ids).
    fn num_users(&self) -> usize;

    /// Generate user `uid`'s training data.
    fn user_data(&self, uid: usize) -> UserData;

    /// Scheduling weight = number of datapoints, cheaply computable
    /// without generating the data (paper App. B.6 uses dataset length).
    fn user_len(&self, uid: usize) -> usize;

    /// Central validation set, pre-sharded into eval-batch-sized chunks
    /// ("evaluation is done on the validation partition without any
    /// federated splits", §4.3).
    fn central_eval(&self, shard_size: usize) -> Vec<UserData>;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn user_data_len_all_variants() {
        assert_eq!(
            UserData::Image { x: vec![0.0; 2 * 12], y: vec![1, 2], hwc: 12 }.len(),
            2
        );
        assert_eq!(
            UserData::Features { x: vec![0.0; 6], y: vec![0.0; 4], feat: 3, labels: 2 }.len(),
            2
        );
        assert_eq!(
            UserData::Tokens { seqs: vec![0; 40], seq_len: 20 }.len(),
            2
        );
        assert_eq!(
            UserData::Tabular { x: vec![0.0; 10], y: vec![0.0; 5], dim: 2 }.len(),
            5
        );
        assert_eq!(UserData::Points { x: vec![0.0; 9], dim: 3 }.len(), 3);
        assert!(!UserData::Points { x: vec![0.0; 9], dim: 3 }.is_empty());
    }
}
