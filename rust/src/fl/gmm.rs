//! Federated Gaussian mixture models (paper §1 "Non-gradient-descent
//! training"): federated EM over sufficient statistics.
//!
//! Each round, clients run the E-step locally — responsibilities of the
//! current mixture over their points — and contribute the sufficient
//! statistics (Σ r_k, Σ r_k·x, Σ r_k·x²). The server's M-step re-estimates
//! weights, means and (diagonal) variances from the aggregated sums.
//! Statistics are plain vectors, so aggregation and DP postprocessors
//! apply unchanged.
//!
//! Flat state layout (K components, D dims):
//! `[weights (K), means (K·D), vars (K·D)]`.

use anyhow::{bail, Result};

use super::algorithm::{FederatedAlgorithm, RunSpec};
use super::context::{CentralContext, Population};
use super::metrics::Metrics;
use super::model::{Model, ScoreSink, TrainOutput};
use super::stats::Statistics;
use crate::data::UserData;

#[derive(Debug, Clone, Copy)]
pub struct GmmParams {
    pub components: usize,
    pub dim: usize,
    /// Variance floor (numerical stability).
    pub var_floor: f64,
}

impl Default for GmmParams {
    fn default() -> Self {
        GmmParams { components: 4, dim: 2, var_floor: 1e-3 }
    }
}

impl GmmParams {
    pub fn state_len(&self) -> usize {
        self.components * (1 + 2 * self.dim)
    }

    /// Sufficient-statistics vector length: per component
    /// (count, Σx (D), Σx² (D)).
    pub fn stats_len(&self) -> usize {
        self.components * (1 + 2 * self.dim)
    }

    fn weights<'a>(&self, s: &'a [f32]) -> &'a [f32] {
        &s[..self.components]
    }

    fn means<'a>(&self, s: &'a [f32]) -> &'a [f32] {
        &s[self.components..self.components * (1 + self.dim)]
    }

    fn vars<'a>(&self, s: &'a [f32]) -> &'a [f32] {
        &s[self.components * (1 + self.dim)..]
    }
}

/// Deterministic initial mixture: uniform weights, means spread on a
/// seeded Gaussian, unit variances.
pub fn initial_state(p: &GmmParams, seed: u64) -> Vec<f32> {
    let mut rng = crate::util::rng::Rng::seed_from_u64(seed);
    let mut s = vec![0.0f32; p.state_len()];
    for k in 0..p.components {
        s[k] = 1.0 / p.components as f32;
    }
    for m in &mut s[p.components..p.components * (1 + p.dim)] {
        *m = (rng.normal() * 2.0) as f32;
    }
    for v in &mut s[p.components * (1 + p.dim)..] {
        *v = 1.0;
    }
    s
}

/// Per-point log-likelihood of the mixture (diagonal covariances).
pub fn log_likelihood(p: &GmmParams, state: &[f32], x: &[f32]) -> f64 {
    let w = p.weights(state);
    let means = p.means(state);
    let vars = p.vars(state);
    let mut ll = 0.0;
    for point in x.chunks(p.dim) {
        let mut best = f64::NEG_INFINITY;
        let mut terms = Vec::with_capacity(p.components);
        for k in 0..p.components {
            let mut logp = (w[k].max(1e-12) as f64).ln();
            for d in 0..p.dim {
                let var = vars[k * p.dim + d].max(p.var_floor as f32) as f64;
                let diff = (point[d] - means[k * p.dim + d]) as f64;
                logp += -0.5 * (diff * diff / var + var.ln() + (2.0 * std::f64::consts::PI).ln());
            }
            best = best.max(logp);
            terms.push(logp);
        }
        let sum: f64 = terms.iter().map(|t| (t - best).exp()).sum();
        ll += best + sum.ln();
    }
    ll
}

/// Client-side GMM: local E-step producing sufficient statistics.
pub struct GmmModel {
    pub p: GmmParams,
    state: Vec<f32>,
}

impl GmmModel {
    pub fn new(p: GmmParams, seed: u64) -> Self {
        let state = initial_state(&p, seed);
        GmmModel { p, state }
    }
}

impl Model for GmmModel {
    fn param_count(&self) -> usize {
        self.state.len()
    }

    fn set_central(&mut self, central: &[f32]) {
        self.state.copy_from_slice(central);
    }

    fn central(&self) -> &[f32] {
        &self.state
    }

    fn train_local(
        &mut self,
        data: &UserData,
        _lp: &super::context::LocalParams,
        _c_diff: Option<&[f32]>,
        _seed: u64,
    ) -> Result<TrainOutput> {
        let x = match data {
            UserData::Points { x, dim } if *dim == self.p.dim => x,
            UserData::Points { dim, .. } => bail!("GMM dim mismatch: {} vs {}", dim, self.p.dim),
            _ => bail!("GmmModel wants Points data"),
        };
        let p = &self.p;
        let w = p.weights(&self.state).to_vec();
        let means = p.means(&self.state).to_vec();
        let vars = p.vars(&self.state).to_vec();

        let mut suff = vec![0.0f32; p.stats_len()];
        let mut ll = 0.0f64;
        let mut logps = vec![0f64; p.components];
        for point in x.chunks(p.dim) {
            let mut best = f64::NEG_INFINITY;
            for k in 0..p.components {
                let mut logp = (w[k].max(1e-12) as f64).ln();
                for d in 0..p.dim {
                    let var = vars[k * p.dim + d].max(p.var_floor as f32) as f64;
                    let diff = (point[d] - means[k * p.dim + d]) as f64;
                    logp +=
                        -0.5 * (diff * diff / var + var.ln() + (2.0 * std::f64::consts::PI).ln());
                }
                logps[k] = logp;
                best = best.max(logp);
            }
            let norm: f64 = logps.iter().map(|l| (l - best).exp()).sum();
            ll += best + norm.ln();
            for k in 0..p.components {
                let r = ((logps[k] - best).exp() / norm) as f32;
                // layout per component: [count, Σx, Σx²]
                let off = k * (1 + 2 * p.dim);
                suff[off] += r;
                for d in 0..p.dim {
                    suff[off + 1 + d] += r * point[d];
                    suff[off + 1 + p.dim + d] += r * point[d] * point[d];
                }
            }
        }
        let n = (x.len() / p.dim) as f64;
        Ok(TrainOutput {
            update: suff,
            loss_sum: -ll, // negative log-likelihood as the "loss"
            stat_sum: 0.0,
            wsum: n,
            steps: 1,
        })
    }

    fn evaluate(&mut self, data: &UserData, _sink: Option<&mut ScoreSink>) -> Result<Metrics> {
        let x = match data {
            UserData::Points { x, dim } if *dim == self.p.dim => x,
            _ => bail!("GmmModel wants Points data of dim {}", self.p.dim),
        };
        let ll = log_likelihood(&self.p, &self.state, x);
        let mut m = Metrics::new();
        m.add_central("loss", -ll, (x.len() / self.p.dim) as f64);
        Ok(m)
    }

    fn name(&self) -> &str {
        "gmm"
    }
}

/// Federated EM: the server M-step over aggregated sufficient statistics.
pub struct FedGmm {
    pub spec: RunSpec,
    pub p: GmmParams,
}

impl FedGmm {
    pub fn new(spec: RunSpec, p: GmmParams) -> Self {
        FedGmm { spec, p }
    }
}

impl FederatedAlgorithm for FedGmm {
    fn name(&self) -> &'static str {
        "fed-gmm"
    }

    fn next_contexts(&self, t: u64) -> Vec<CentralContext> {
        if t >= self.spec.iterations {
            return Vec::new();
        }
        let mut ctxs = vec![CentralContext::train(
            t,
            self.spec.cohort_size,
            self.spec.local.clone(),
            self.spec.seed.wrapping_add(t),
        )];
        if self.spec.val_cohort_size > 0 && t % self.spec.eval_every.max(1) == 0 {
            ctxs.push(CentralContext::eval(t, self.spec.val_cohort_size, self.spec.seed ^ t));
        }
        ctxs
    }

    fn simulate_one_user(
        &self,
        model: &mut dyn Model,
        _uid: usize,
        data: &UserData,
        ctx: &CentralContext,
    ) -> Result<(Option<Statistics>, Metrics)> {
        if ctx.population == Population::Val {
            let m = model.evaluate(data, None)?;
            return Ok((None, m));
        }
        let out = model.train_local(data, &ctx.local, None, 0)?;
        let mut m = Metrics::new();
        m.add_central("train/nll", out.loss_sum, out.wsum);
        Ok((Some(Statistics::new_update(out.update, 1.0)), m))
    }

    /// M-step: weights = counts/N, means = Σx/count,
    /// vars = Σx²/count − mean² (floored).
    fn process_aggregated(
        &self,
        central: &mut [f32],
        _ctx: &CentralContext,
        aggregate: Statistics,
        metrics: &mut Metrics,
    ) -> Result<()> {
        let p = &self.p;
        let suff = aggregate.update();
        anyhow::ensure!(suff.len() == p.stats_len(), "sufficient stats length mismatch");
        let total: f64 = (0..p.components)
            .map(|k| suff[k * (1 + 2 * p.dim)] as f64)
            .sum();
        if total <= 0.0 {
            return Ok(()); // empty round; keep the current mixture
        }
        for k in 0..p.components {
            let off = k * (1 + 2 * p.dim);
            let count = suff[off] as f64;
            central[k] = (count / total).max(1e-6) as f32;
            if count < 1e-6 {
                continue; // dead component: keep previous parameters
            }
            for d in 0..p.dim {
                let mean = suff[off + 1 + d] as f64 / count;
                let ex2 = suff[off + 1 + p.dim + d] as f64 / count;
                let var = (ex2 - mean * mean).max(p.var_floor);
                central[p.components + k * p.dim + d] = mean as f32;
                central[p.components * (1 + p.dim) + k * p.dim + d] = var as f32;
            }
        }
        metrics.add_central("gmm/total-resp", total, 1.0);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fl::context::LocalParams;
    use crate::fl::aggregator::Aggregator as _;

    fn two_cluster_user(n: usize, seed: u64) -> UserData {
        // clusters at (-2,-2) and (2,2)
        let mut rng = crate::util::rng::Rng::seed_from_u64(seed);
        let mut x = Vec::with_capacity(n * 2);
        for i in 0..n {
            let c = if i % 2 == 0 { -2.0 } else { 2.0 };
            x.push((c + rng.normal() * 0.3) as f32);
            x.push((c + rng.normal() * 0.3) as f32);
        }
        UserData::Points { x, dim: 2 }
    }

    #[test]
    fn state_layout_sizes() {
        let p = GmmParams { components: 3, dim: 4, var_floor: 1e-3 };
        assert_eq!(p.state_len(), 3 * (1 + 8));
        let s = initial_state(&p, 0);
        assert_eq!(s.len(), p.state_len());
        let wsum: f32 = p.weights(&s).iter().sum();
        assert!((wsum - 1.0).abs() < 1e-6);
        assert!(p.vars(&s).iter().all(|&v| v == 1.0));
    }

    #[test]
    fn estep_responsibilities_sum_to_n() {
        let p = GmmParams { components: 2, dim: 2, var_floor: 1e-3 };
        let mut model = GmmModel::new(p, 1);
        let data = two_cluster_user(40, 0);
        let out = model.train_local(&data, &LocalParams::default(), None, 0).unwrap();
        let counts: f64 = (0..2).map(|k| out.update[k * 5] as f64).sum();
        assert!((counts - 40.0).abs() < 1e-3, "{counts}");
    }

    #[test]
    fn federated_em_improves_likelihood_and_finds_clusters() {
        let p = GmmParams { components: 2, dim: 2, var_floor: 1e-3 };
        let spec = RunSpec { iterations: 20, cohort_size: 4, ..Default::default() };
        let alg = FedGmm::new(spec, p);
        let mut central = initial_state(&p, 3);
        let users: Vec<UserData> = (0..4).map(|i| two_cluster_user(50, i)).collect();
        let mut model = GmmModel::new(p, 3);

        let mut nll = Vec::new();
        for t in 0..15u64 {
            let ctx = alg.next_contexts(t).remove(0);
            model.set_central(&central);
            let mut acc: Option<Statistics> = None;
            let mut round_nll = 0.0;
            for (i, u) in users.iter().enumerate() {
                let (s, m) = alg.simulate_one_user(&mut model, i, u, &ctx).unwrap();
                round_nll += m.get("train/nll").unwrap();
                crate::fl::SumAggregator.accumulate(&mut acc, s.unwrap());
            }
            nll.push(round_nll);
            let mut metrics = Metrics::new();
            alg.process_aggregated(&mut central, &ctx, acc.unwrap(), &mut metrics).unwrap();
        }
        assert!(nll.last().unwrap() < &nll[0], "EM failed: {nll:?}");
        // the two means should be near (±2, ±2) with opposite signs
        let m0 = (central[2], central[3]);
        let m1 = (central[4], central[5]);
        assert!(
            (m0.0 * m1.0) < 0.0,
            "means did not separate: {m0:?} vs {m1:?}"
        );
        for &m in &[m0.0, m0.1, m1.0, m1.1] {
            assert!((m.abs() - 2.0).abs() < 0.5, "mean {m}");
        }
    }

    #[test]
    fn empty_aggregate_keeps_mixture() {
        let p = GmmParams::default();
        let alg = FedGmm::new(RunSpec::default(), p);
        let mut central = initial_state(&p, 0);
        let before = central.clone();
        let agg = Statistics::new_update(vec![0.0; p.stats_len()], 0.0);
        let ctx = CentralContext::train(0, 1, LocalParams::default(), 0);
        let mut m = Metrics::new();
        alg.process_aggregated(&mut central, &ctx, agg, &mut m).unwrap();
        assert_eq!(central, before);
    }

    #[test]
    fn dim_mismatch_is_error() {
        let p = GmmParams { components: 2, dim: 3, var_floor: 1e-3 };
        let mut model = GmmModel::new(p, 0);
        let data = UserData::Points { x: vec![0.0; 8], dim: 2 };
        assert!(model.train_local(&data, &LocalParams::default(), None, 0).is_err());
    }
}
