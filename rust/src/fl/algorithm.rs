//! Federated algorithms (paper App. B.1 "Algorithm" + Alg. 2).
//!
//! An algorithm's three responsibilities, verbatim from the paper:
//! construct the per-iteration [`CentralContext`]s, define the local
//! optimization (`simulate_one_user`, executed concurrently on worker
//! replicas), and consume the aggregated statistics to update the central
//! model. Everything orthogonal to learning (aggregation, DP,
//! compression) lives in other components that mix and match with these.
//!
//! The unified local-step artifact (L2) lowers FedAvg / FedProx / SCAFFOLD
//! into one executable per model: g = ∇L + µ·(θ′−θ) + c_diff, so switching
//! algorithms changes only the Rust-side bookkeeping, never the HLO.

use std::collections::HashMap;
use std::sync::Mutex;

use anyhow::Result;

use super::central_opt::CentralOptimizer;
use super::context::{CentralContext, DispatchSpec, LocalParams, Population};
use super::metrics::Metrics;
use super::model::Model;
use super::stats::{Statistics, C_DELTA};
use crate::data::UserData;

/// Shared run schedule: how long to train, how big the cohorts are, and
/// the resolved-per-iteration local parameters. Constructed from the
/// config presets (paper Tables 8–11).
#[derive(Debug, Clone)]
pub struct RunSpec {
    /// Total central iterations T.
    pub iterations: u64,
    /// Training cohort size C.
    pub cohort_size: usize,
    /// Federated-eval cohort size (0 disables Val contexts).
    pub val_cohort_size: usize,
    /// Evaluate every τ iterations.
    pub eval_every: u64,
    /// Base local parameters (lr may be overridden by a schedule).
    pub local: LocalParams,
    /// Central learning rate (resolved per iteration via warmup).
    pub central_lr: f64,
    /// Central lr linear-warmup iterations (paper Table 9).
    pub central_lr_warmup: u64,
    /// Population size (for SCAFFOLD's c-update scaling).
    pub population: usize,
    /// Seed stream.
    pub seed: u64,
    /// Cohort dispatch policy stamped onto train contexts. The default
    /// spec means "inherit `RunParams::dispatch`" (see
    /// [`DispatchSpec`]); a non-default Static/WorkStealing spec pins
    /// the mode per context, while Async must be selected engine-wide
    /// through `RunParams::dispatch` (the synchronous engine errors on
    /// async-requesting contexts rather than silently degrading).
    pub dispatch: DispatchSpec,
}

impl Default for RunSpec {
    fn default() -> Self {
        RunSpec {
            iterations: 100,
            cohort_size: 50,
            val_cohort_size: 0,
            eval_every: 10,
            local: LocalParams::default(),
            central_lr: 1.0,
            central_lr_warmup: 0,
            population: 1000,
            seed: 0,
            dispatch: DispatchSpec::default(),
        }
    }
}

impl RunSpec {
    pub fn central_lr_at(&self, t: u64) -> f64 {
        if self.central_lr_warmup == 0 || t >= self.central_lr_warmup {
            self.central_lr
        } else {
            self.central_lr * (t + 1) as f64 / self.central_lr_warmup as f64
        }
    }

    fn base_contexts(&self, t: u64, local: LocalParams) -> Vec<CentralContext> {
        if t >= self.iterations {
            return Vec::new(); // signal: training complete
        }
        let mut train =
            CentralContext::train(t, self.cohort_size, local, self.seed.wrapping_add(t));
        train.dispatch = self.dispatch;
        let mut ctxs = vec![train];
        if self.val_cohort_size > 0 && self.eval_every > 0 && t % self.eval_every == 0 {
            ctxs.push(CentralContext::eval(
                t,
                self.val_cohort_size,
                self.seed.wrapping_add(t) ^ EVAL_SEED,
            ));
        }
        ctxs
    }
}

const EVAL_SEED: u64 = 0x45564131;

/// The FederatedAlgorithm interface (paper App. B.1). Methods take
/// `&self`; algorithm state that evolves across iterations (optimizer
/// moments, adaptive µ, SCAFFOLD control variates) lives behind mutexes
/// so `simulate_one_user` can run concurrently on worker replicas.
pub trait FederatedAlgorithm: Send + Sync {
    fn name(&self) -> &'static str;

    /// Contexts for iteration t; empty signals that training should end
    /// (paper Alg. 1 line 4).
    fn next_contexts(&self, t: u64) -> Vec<CentralContext>;

    /// Local optimization (or evaluation) for one user. Runs on a worker
    /// replica with that worker's model, already loaded with the current
    /// central state.
    fn simulate_one_user(
        &self,
        model: &mut dyn Model,
        uid: usize,
        data: &UserData,
        ctx: &CentralContext,
    ) -> Result<(Option<Statistics>, Metrics)>;

    /// Consume the aggregated statistics (one per train context) and
    /// update the central state in place.
    fn process_aggregated(
        &self,
        central: &mut [f32],
        ctx: &CentralContext,
        aggregate: Statistics,
        metrics: &mut Metrics,
    ) -> Result<()>;
}

/// Evaluation-only handling shared by all algorithms: Val-population
/// contexts run local evaluation and return metrics, no statistics.
fn eval_user(model: &mut dyn Model, data: &UserData) -> Result<(Option<Statistics>, Metrics)> {
    let mut m = model.evaluate(data, None)?;
    // per-user view of the same quantity (paper App. B.4)
    let loss = m.get("loss").unwrap_or(0.0);
    m.add_per_user("loss/per-user", loss);
    Ok((None, m))
}

/// Train-side shared path: run the unified local step and wrap the delta.
fn train_user(
    model: &mut dyn Model,
    uid: usize,
    data: &UserData,
    ctx: &CentralContext,
    mu: f32,
    c_diff: Option<&[f32]>,
) -> Result<(super::model::TrainOutput, Metrics)> {
    let mut local = ctx.local.clone();
    local.mu = mu;
    let seed = ctx.seed ^ (uid as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    let out = model.train_local(data, &local, c_diff, seed)?;
    let mut m = Metrics::new();
    m.add_central("train/loss", out.loss_sum, out.wsum);
    m.add_central("train/stat", out.stat_sum, out.wsum);
    m.add_central("train/steps", out.steps as f64, 1.0);
    Ok((out, m))
}

// ---------------------------------------------------------------------
// FedAvg
// ---------------------------------------------------------------------

/// Federated averaging (McMahan et al. [60]; paper Alg. 2), with a
/// pluggable central optimizer (FedAdam etc.).
pub struct FedAvg {
    pub spec: RunSpec,
    opt: Mutex<Box<dyn CentralOptimizer>>,
}

impl FedAvg {
    pub fn new(spec: RunSpec, opt: Box<dyn CentralOptimizer>) -> Self {
        FedAvg { spec, opt: Mutex::new(opt) }
    }
}

impl FederatedAlgorithm for FedAvg {
    fn name(&self) -> &'static str {
        "fedavg"
    }

    fn next_contexts(&self, t: u64) -> Vec<CentralContext> {
        self.spec.base_contexts(t, self.spec.local.clone())
    }

    fn simulate_one_user(
        &self,
        model: &mut dyn Model,
        uid: usize,
        data: &UserData,
        ctx: &CentralContext,
    ) -> Result<(Option<Statistics>, Metrics)> {
        if ctx.population == Population::Val {
            return eval_user(model, data);
        }
        let (out, m) = train_user(model, uid, data, ctx, 0.0, None)?;
        Ok((Some(Statistics::new_update(out.update, 1.0)), m))
    }

    fn process_aggregated(
        &self,
        central: &mut [f32],
        ctx: &CentralContext,
        mut aggregate: Statistics,
        metrics: &mut Metrics,
    ) -> Result<()> {
        // the backend densifies the aggregate before this call
        aggregate.average_in_place();
        let lr = self.spec.central_lr_at(ctx.iteration);
        self.opt.lock().unwrap().apply(central, aggregate.update(), lr);
        metrics.add_central("central/update-norm", crate::util::l2_norm(aggregate.update()), 1.0);
        Ok(())
    }
}

// ---------------------------------------------------------------------
// FedProx / AdaFedProx
// ---------------------------------------------------------------------

/// FedProx (Li et al. [52]): FedAvg plus a proximal term µ‖θ′−θ‖²/2 in
/// the local objective — already lowered into the unified artifact, so
/// this is FedAvg with µ ≠ 0.
pub struct FedProx {
    pub spec: RunSpec,
    pub mu: f32,
    opt: Mutex<Box<dyn CentralOptimizer>>,
}

impl FedProx {
    pub fn new(spec: RunSpec, mu: f32, opt: Box<dyn CentralOptimizer>) -> Self {
        FedProx { spec, mu, opt: Mutex::new(opt) }
    }
}

impl FederatedAlgorithm for FedProx {
    fn name(&self) -> &'static str {
        "fedprox"
    }

    fn next_contexts(&self, t: u64) -> Vec<CentralContext> {
        let mut local = self.spec.local.clone();
        local.mu = self.mu;
        self.spec.base_contexts(t, local)
    }

    fn simulate_one_user(
        &self,
        model: &mut dyn Model,
        uid: usize,
        data: &UserData,
        ctx: &CentralContext,
    ) -> Result<(Option<Statistics>, Metrics)> {
        if ctx.population == Population::Val {
            return eval_user(model, data);
        }
        let (out, m) = train_user(model, uid, data, ctx, ctx.local.mu, None)?;
        Ok((Some(Statistics::new_update(out.update, 1.0)), m))
    }

    fn process_aggregated(
        &self,
        central: &mut [f32],
        ctx: &CentralContext,
        mut aggregate: Statistics,
        metrics: &mut Metrics,
    ) -> Result<()> {
        aggregate.average_in_place();
        let lr = self.spec.central_lr_at(ctx.iteration);
        self.opt.lock().unwrap().apply(central, aggregate.update(), lr);
        metrics.add_central("central/update-norm", crate::util::l2_norm(aggregate.update()), 1.0);
        metrics.add_central("fedprox/mu", ctx.local.mu as f64, 1.0);
        Ok(())
    }
}

/// FedProx with adaptive µ (paper Table 3 "AdaFedProx", rule from [52]
/// App. C.3.3): increase µ when the aggregated training loss goes up,
/// decrease it after `patience` consecutive decreases.
pub struct AdaFedProx {
    pub spec: RunSpec,
    pub step: f32,
    pub max_mu: f32,
    pub patience: u32,
    opt: Mutex<Box<dyn CentralOptimizer>>,
    state: Mutex<AdaState>,
}

#[derive(Debug, Default)]
struct AdaState {
    mu: f32,
    prev_loss: Option<f64>,
    decreases: u32,
}

impl AdaFedProx {
    pub fn new(spec: RunSpec, opt: Box<dyn CentralOptimizer>) -> Self {
        AdaFedProx {
            spec,
            step: 0.1,
            max_mu: 1.0,
            patience: 5,
            opt: Mutex::new(opt),
            state: Mutex::new(AdaState::default()),
        }
    }

    pub fn current_mu(&self) -> f32 {
        self.state.lock().unwrap().mu
    }
}

impl FederatedAlgorithm for AdaFedProx {
    fn name(&self) -> &'static str {
        "adafedprox"
    }

    fn next_contexts(&self, t: u64) -> Vec<CentralContext> {
        let mut local = self.spec.local.clone();
        local.mu = self.current_mu();
        self.spec.base_contexts(t, local)
    }

    fn simulate_one_user(
        &self,
        model: &mut dyn Model,
        uid: usize,
        data: &UserData,
        ctx: &CentralContext,
    ) -> Result<(Option<Statistics>, Metrics)> {
        if ctx.population == Population::Val {
            return eval_user(model, data);
        }
        let (out, m) = train_user(model, uid, data, ctx, ctx.local.mu, None)?;
        Ok((Some(Statistics::new_update(out.update, 1.0)), m))
    }

    fn process_aggregated(
        &self,
        central: &mut [f32],
        ctx: &CentralContext,
        mut aggregate: Statistics,
        metrics: &mut Metrics,
    ) -> Result<()> {
        aggregate.average_in_place();
        let lr = self.spec.central_lr_at(ctx.iteration);
        self.opt.lock().unwrap().apply(central, aggregate.update(), lr);

        // Adapt µ on the aggregated train loss trend.
        let loss = metrics.get("train/loss").unwrap_or(0.0);
        let mut st = self.state.lock().unwrap();
        if let Some(prev) = st.prev_loss {
            if loss > prev {
                st.mu = (st.mu + self.step).min(self.max_mu);
                st.decreases = 0;
            } else {
                st.decreases += 1;
                if st.decreases >= self.patience {
                    st.mu = (st.mu - self.step).max(0.0);
                    st.decreases = 0;
                }
            }
        }
        st.prev_loss = Some(loss);
        metrics.add_central("fedprox/mu", st.mu as f64, 1.0);
        Ok(())
    }
}

// ---------------------------------------------------------------------
// SCAFFOLD
// ---------------------------------------------------------------------

/// SCAFFOLD (Karimireddy et al. [42]) with option-II control variates:
///
/// * local step uses c_diff = c − c_u (lowered into the unified artifact),
/// * after K local steps, c_u′ = c_u − c + Δ/(K·η_l),
/// * the aggregated c-deltas update c: c ← c + (|S|/N)·avg(c_delta).
///
/// Per-user control variates are model-sized; memory is O(participating
/// users × params), the known cost of stateful SCAFFOLD in cross-device
/// settings (one reason the paper finds it underperforms there).
pub struct Scaffold {
    pub spec: RunSpec,
    opt: Mutex<Box<dyn CentralOptimizer>>,
    c_global: Mutex<Vec<f32>>,
    c_users: Mutex<HashMap<usize, Vec<f32>>>,
}

impl Scaffold {
    pub fn new(spec: RunSpec, opt: Box<dyn CentralOptimizer>) -> Self {
        Scaffold {
            spec,
            opt: Mutex::new(opt),
            c_global: Mutex::new(Vec::new()),
            c_users: Mutex::new(HashMap::new()),
        }
    }

    /// Number of users with stored control variates (diagnostics).
    pub fn tracked_users(&self) -> usize {
        self.c_users.lock().unwrap().len()
    }
}

impl FederatedAlgorithm for Scaffold {
    fn name(&self) -> &'static str {
        "scaffold"
    }

    fn next_contexts(&self, t: u64) -> Vec<CentralContext> {
        self.spec.base_contexts(t, self.spec.local.clone())
    }

    fn simulate_one_user(
        &self,
        model: &mut dyn Model,
        uid: usize,
        data: &UserData,
        ctx: &CentralContext,
    ) -> Result<(Option<Statistics>, Metrics)> {
        if ctx.population == Population::Val {
            return eval_user(model, data);
        }
        let n = model.param_count();
        // c_diff = c − c_u (both default to zeros before first touch)
        let mut c_diff = vec![0.0f32; n];
        {
            let cg = self.c_global.lock().unwrap();
            if !cg.is_empty() {
                c_diff.copy_from_slice(&cg);
            }
        }
        let c_u_old: Option<Vec<f32>> = self.c_users.lock().unwrap().get(&uid).cloned();
        if let Some(cu) = &c_u_old {
            crate::tensor::ops::sub_assign(&mut c_diff, cu);
        }

        let (out, m) = train_user(model, uid, data, ctx, 0.0, Some(&c_diff))?;
        let k = out.steps.max(1) as f32;
        let inv = 1.0 / (k * ctx.local.lr);

        // c_u' = c_u − c + Δ/(K·lr); c_delta = c_u' − c_u = Δ/(K·lr) − c
        // Reuse c_diff's buffer for c_delta = Δ·inv − c
        let mut c_delta = c_diff;
        c_delta.copy_from_slice(&out.update);
        crate::util::scale(&mut c_delta, inv);
        {
            let cg = self.c_global.lock().unwrap();
            if !cg.is_empty() {
                crate::tensor::ops::sub_assign(&mut c_delta, &cg);
            }
        }
        // store c_u' = c_u + c_delta
        {
            let mut users = self.c_users.lock().unwrap();
            let cu = users.entry(uid).or_insert_with(|| vec![0.0; n]);
            crate::util::add_assign(cu, &c_delta);
        }

        let mut stats = Statistics::new_update(out.update, 1.0);
        stats.insert(C_DELTA, c_delta);
        Ok((Some(stats), m))
    }

    fn process_aggregated(
        &self,
        central: &mut [f32],
        ctx: &CentralContext,
        mut aggregate: Statistics,
        metrics: &mut Metrics,
    ) -> Result<()> {
        let cohort = aggregate.weight.max(1.0);
        aggregate.average_in_place();
        let lr = self.spec.central_lr_at(ctx.iteration);
        self.opt.lock().unwrap().apply(central, aggregate.update(), lr);

        if let Some(c_delta_avg) = aggregate.get(C_DELTA) {
            let scale = (cohort / self.spec.population.max(1) as f64) as f32;
            let mut cg = self.c_global.lock().unwrap();
            if cg.is_empty() {
                *cg = vec![0.0; c_delta_avg.len()];
            }
            crate::util::axpy(&mut cg, scale, c_delta_avg);
            metrics.add_central("scaffold/c-norm", crate::util::l2_norm(&cg), 1.0);
        }
        metrics.add_central("central/update-norm", crate::util::l2_norm(aggregate.update()), 1.0);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fl::central_opt::Sgd;
    use crate::fl::aggregator::Aggregator as _;

    fn spec(iters: u64) -> RunSpec {
        RunSpec { iterations: iters, cohort_size: 4, val_cohort_size: 2, eval_every: 2, ..Default::default() }
    }

    #[test]
    fn contexts_end_training() {
        let alg = FedAvg::new(spec(3), Box::new(Sgd));
        assert!(!alg.next_contexts(2).is_empty());
        assert!(alg.next_contexts(3).is_empty());
    }

    #[test]
    fn eval_context_every_tau() {
        let alg = FedAvg::new(spec(10), Box::new(Sgd));
        assert_eq!(alg.next_contexts(0).len(), 2); // train + eval
        assert_eq!(alg.next_contexts(1).len(), 1);
        assert_eq!(alg.next_contexts(2).len(), 2);
    }

    #[test]
    fn fedavg_average_and_apply() {
        let alg = FedAvg::new(spec(10), Box::new(Sgd));
        let mut central = vec![1.0f32, 1.0];
        let ctx = alg.next_contexts(0).remove(0);
        // two users contributed deltas [1,0] and [0,1]
        let agg = Statistics::new_update(vec![1.0, 0.0], 1.0);
        crate::fl::SumAggregator.accumulate(
            &mut Some(agg.clone()),
            Statistics::new_update(vec![0.0, 1.0], 1.0),
        );
        // do it properly through the aggregator:
        let mut acc = None;
        crate::fl::SumAggregator.accumulate(&mut acc, agg);
        crate::fl::SumAggregator.accumulate(&mut acc, Statistics::new_update(vec![0.0, 1.0], 1.0));
        let mut metrics = Metrics::new();
        alg.process_aggregated(&mut central, &ctx, acc.unwrap(), &mut metrics).unwrap();
        // avg delta = [0.5, 0.5]; sgd lr=1 -> central = [0.5, 0.5]
        assert_eq!(central, vec![0.5, 0.5]);
        assert!(metrics.get("central/update-norm").is_some());
    }

    #[test]
    fn fedprox_contexts_carry_mu() {
        let alg = FedProx::new(spec(5), 0.25, Box::new(Sgd));
        let c = alg.next_contexts(0);
        assert_eq!(c[0].local.mu, 0.25);
    }

    #[test]
    fn adafedprox_mu_adapts_upward_on_loss_increase() {
        let alg = AdaFedProx::new(spec(100), Box::new(Sgd));
        let mut central = vec![0.0f32; 2];
        for (t, loss) in [(0u64, 1.0f64), (1, 2.0), (2, 3.0)] {
            let ctx = alg.next_contexts(t).remove(0);
            let mut m = Metrics::new();
            m.add_central("train/loss", loss, 1.0);
            alg.process_aggregated(
                &mut central,
                &ctx,
                Statistics::new_update(vec![0.0, 0.0], 1.0),
                &mut m,
            )
            .unwrap();
        }
        assert!(alg.current_mu() >= 0.2 - 1e-6, "mu = {}", alg.current_mu());
    }

    #[test]
    fn adafedprox_mu_decays_after_patience() {
        let alg = AdaFedProx::new(spec(100), Box::new(Sgd));
        // force mu up once
        {
            let mut st = alg.state.lock().unwrap();
            st.mu = 0.5;
            st.prev_loss = Some(10.0);
        }
        let mut central = vec![0.0f32; 1];
        for t in 0..(alg.patience as u64 + 1) {
            let ctx = alg.next_contexts(t).remove(0);
            let mut m = Metrics::new();
            m.add_central("train/loss", 1.0 - t as f64 * 0.01, 1.0);
            alg.process_aggregated(
                &mut central,
                &ctx,
                Statistics::new_update(vec![0.0], 1.0),
                &mut m,
            )
            .unwrap();
        }
        assert!(alg.current_mu() < 0.5);
    }

    #[test]
    fn scaffold_c_update_scales_by_participation() {
        let spec = RunSpec { population: 10, ..spec(5) };
        let alg = Scaffold::new(spec, Box::new(Sgd));
        let ctx = alg.next_contexts(0).remove(0);
        let mut central = vec![0.0f32; 2];
        let mut agg = Statistics::new_update(vec![0.0, 0.0], 2.0);
        agg.insert(C_DELTA, vec![10.0, 0.0]);
        let mut m = Metrics::new();
        alg.process_aggregated(&mut central, &ctx, agg, &mut m).unwrap();
        // avg c_delta = [5, 0]; scale = 2/10 -> c = [1, 0]
        let cg = alg.c_global.lock().unwrap();
        assert_eq!(&*cg, &[1.0, 0.0]);
    }
}
