//! The simulation framework (L3): the paper's system contribution.
//!
//! Modules map one-to-one onto the extension points of pfl-research's API
//! (paper App. B.1): [`algorithm`] (FederatedAlgorithm), [`aggregator`]
//! (Aggregator), [`backend`] (SimulatedBackend, paper Alg. 1),
//! [`postprocess`] (Postprocessor — DP, weighting, compression),
//! [`callbacks`] (TrainingProcessCallback), [`hyperparam`] (HyperParam),
//! [`metrics`] (central vs per-user), [`model`] (Model adapters),
//! [`scheduler`] (cohort ordering policy, App. B.6), [`dispatch`]
//! (static / work-stealing / async cohort distribution), [`device`]
//! (per-user device realism: speed tiers, diurnal availability and
//! dropout hazard, DESIGN.md §8) and [`worker`] (replica worker pool,
//! §3.1 / Fig. 1).

pub mod aggregator;
pub mod algorithm;
pub mod backend;
pub mod callbacks;
pub mod central_opt;
pub mod context;
pub mod device;
pub mod dispatch;
pub mod gbdt;
pub mod gmm;
pub mod hyperparam;
pub mod linear;
pub mod metrics;
pub mod model;
pub mod postprocess;
pub mod scheduler;
pub mod stats;
pub mod worker;

pub use aggregator::{tree_reduce, Aggregator, CollectAggregator, SumAggregator};
pub use algorithm::{AdaFedProx, FedAvg, FedProx, FederatedAlgorithm, Scaffold};
pub use backend::{RunOutcome, RunParams, SimulatedBackend};
pub use callbacks::{
    Callback, CentralEvalCallback, CsvReporter, EarlyStopping, EmaCallback, JsonlReporter,
    StragglerRecorder, TimeBudget,
};
pub use central_opt::{Adam, CentralOptimizer, Sgd};
pub use context::{CentralContext, DispatchMode, DispatchSpec, LocalParams, Population};
pub use device::{DeviceProfile, ScenarioSpec};
pub use dispatch::{
    dispatcher_for, staleness_weight, CohortQueue, DispatchPlan, Dispatcher, StaticDispatcher,
    WorkSource, WorkStealingDispatcher,
};
pub use linear::LinearModel;
pub use metrics::{MetricError, MetricValue, Metrics};
#[cfg(feature = "hlo")]
pub use model::HloModel;
pub use model::{ClipKernel, Model, TrainOutput};
pub use scheduler::{median, order, schedule, Schedule, SchedulerKind};
pub use stats::{StatValue, Statistics, C_DELTA, UPDATE};
pub use worker::{run_socket_worker, Cmd, RoundResult, WorkerPanic, WorkerPool};
