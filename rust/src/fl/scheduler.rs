//! Cohort **ordering policy** for worker dispatch (paper §3.1 + App. B.6).
//!
//! The paper's distributed deployment pre-calculates per-cohort
//! assignments because its worker *processes* cannot cheaply pull user
//! ids from a central queue. Our in-process replica threads don't share
//! that constraint, so this module is now the policy layer consumed by
//! [`crate::fl::dispatch`]: [`order`] yields the dispatch order (LPT —
//! largest effective weight first — for the greedy kinds, arrival order
//! for `Uniform`), and [`schedule`] packs that order into static
//! per-worker assignments (classic greedy LPT bin packing) for the
//! paper-faithful `Static` mode and the virtual-cluster replay.
//!
//! The weight is a proxy for per-user wall-clock (the number of
//! datapoints: Fig. 4a shows the correlation), and adding a small **base
//! value** (≈ the median user size) to every weight models the fixed
//! per-user overhead, which App. B.6 shows buys an extra ~3% (19% total
//! vs no scheduling on FLAIR).

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SchedulerKind {
    /// Round-robin in arrival order — the "no scheduling" baseline
    /// (uniform user split) in Table 5.
    Uniform,
    /// Greedy LPT on user weights.
    Greedy,
    /// Greedy LPT on (weight + base); base ≈ median weight is the paper's
    /// recommendation ("+median" row of Table 5).
    GreedyBase { base: f64 },
    /// GreedyBase with base = the cohort's median weight, computed per
    /// cohort (what `pfl-research` 0.2.0 does by default).
    GreedyMedianBase,
}

/// Assignment of cohort members to workers. `assignments[w]` lists
/// indices into the cohort slice handed to `schedule`.
#[derive(Debug, Clone)]
pub struct Schedule {
    pub assignments: Vec<Vec<usize>>,
    /// Σ weight per worker (diagnostics; Fig. 5 histograms).
    pub totals: Vec<f64>,
}

impl Schedule {
    /// Max − min of per-worker totals: the *predicted* straggler gap.
    pub fn predicted_straggler_gap(&self) -> f64 {
        let max = self.totals.iter().cloned().fold(f64::MIN, f64::max);
        let min = self.totals.iter().cloned().fold(f64::MAX, f64::min);
        if self.totals.is_empty() {
            0.0
        } else {
            max - min
        }
    }
}

/// Resolve per-cohort kinds (`GreedyMedianBase` computes its base from
/// the cohort at hand) into a concrete kind.
fn resolve(kind: SchedulerKind, weights: &[f64]) -> SchedulerKind {
    match kind {
        SchedulerKind::GreedyMedianBase => SchedulerKind::GreedyBase { base: median(weights) },
        k => k,
    }
}

/// The ordering policy consumed by dispatchers: indices of cohort
/// members in dispatch order — largest effective weight first (LPT) for
/// the greedy kinds, arrival order for `Uniform`. Pull-based dispatchers
/// enqueue users in this order so the heaviest users start earliest and
/// the straggler tail is at most one (small) user long.
pub fn order(kind: SchedulerKind, weights: &[f64]) -> Vec<usize> {
    let kind = resolve(kind, weights);
    let mut order: Vec<usize> = (0..weights.len()).collect();
    if kind != SchedulerKind::Uniform {
        // stable sort by effective weight, largest first (LPT)
        order.sort_by(|&a, &b| {
            effective(kind, weights[b])
                .partial_cmp(&effective(kind, weights[a]))
                .unwrap()
        });
    }
    order
}

/// Compute the per-cohort static assignment. `weights[i]` is the
/// scheduling weight of cohort member i (user dataset length).
pub fn schedule(kind: SchedulerKind, weights: &[f64], num_workers: usize) -> Schedule {
    let kind = resolve(kind, weights);
    let n = num_workers.max(1);
    let mut assignments = vec![Vec::new(); n];
    let mut totals = vec![0f64; n];

    match kind {
        SchedulerKind::Uniform => {
            for (i, w) in weights.iter().enumerate() {
                let worker = i % n;
                assignments[worker].push(i);
                totals[worker] += effective(kind, *w);
            }
        }
        SchedulerKind::Greedy | SchedulerKind::GreedyBase { .. } | SchedulerKind::GreedyMedianBase => {
            // binary heap of (total, worker) would be O(n log w); with the
            // worker counts used in simulations a linear argmin is fine and
            // branch-predictable. Perf pass: see benches/scheduler.rs.
            for i in order(kind, weights) {
                let w = effective(kind, weights[i]);
                let (worker, _) = totals
                    .iter()
                    .enumerate()
                    .min_by(|(_, a), (_, b)| a.partial_cmp(b).unwrap())
                    .map(|(j, t)| (j, *t))
                    .unwrap();
                assignments[worker].push(i);
                totals[worker] += w;
            }
        }
    }

    Schedule { assignments, totals }
}

fn effective(kind: SchedulerKind, w: f64) -> f64 {
    match kind {
        SchedulerKind::GreedyBase { base } => w + base,
        _ => w,
    }
}

/// Median helper for picking the base value (paper: "median number of
/// datapoints per user").
pub fn median(weights: &[f64]) -> f64 {
    if weights.is_empty() {
        return 0.0;
    }
    let mut sorted = weights.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mid = sorted.len() / 2;
    if sorted.len() % 2 == 0 {
        (sorted[mid - 1] + sorted[mid]) / 2.0
    } else {
        sorted[mid]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn heavy_tailed_weights(n: usize, seed: u64) -> Vec<f64> {
        // log-normal sizes like FLAIR (high dispersion)
        let mut rng = Rng::seed_from_u64(seed);
        (0..n).map(|_| rng.lognormal(3.0, 1.2).ceil().max(1.0)).collect()
    }

    fn covers_all(s: &Schedule, n: usize) {
        let mut seen = vec![false; n];
        for a in &s.assignments {
            for &i in a {
                assert!(!seen[i], "user {i} assigned twice");
                seen[i] = true;
            }
        }
        assert!(seen.into_iter().all(|x| x), "some user unassigned");
    }

    #[test]
    fn all_kinds_partition_the_cohort() {
        let w = heavy_tailed_weights(97, 0);
        for kind in [
            SchedulerKind::Uniform,
            SchedulerKind::Greedy,
            SchedulerKind::GreedyBase { base: median(&w) },
        ] {
            let s = schedule(kind, &w, 8);
            assert_eq!(s.assignments.len(), 8);
            covers_all(&s, w.len());
        }
    }

    #[test]
    fn greedy_beats_uniform_on_heavy_tail() {
        // Table 5's qualitative claim, on the predicted gap.
        let mut total_uniform = 0.0;
        let mut total_greedy = 0.0;
        let mut total_base = 0.0;
        for seed in 0..20 {
            let w = heavy_tailed_weights(200, seed);
            total_uniform += schedule(SchedulerKind::Uniform, &w, 5).predicted_straggler_gap();
            total_greedy += schedule(SchedulerKind::Greedy, &w, 5).predicted_straggler_gap();
            total_base += schedule(
                SchedulerKind::GreedyBase { base: median(&w) },
                &w,
                5,
            )
            .predicted_straggler_gap();
        }
        assert!(
            total_greedy < total_uniform * 0.5,
            "greedy {total_greedy} vs uniform {total_uniform}"
        );
        // base value does not hurt balance
        assert!(total_base < total_uniform * 0.5);
    }

    #[test]
    fn single_worker_gets_everything() {
        let w = vec![1.0, 2.0, 3.0];
        let s = schedule(SchedulerKind::Greedy, &w, 1);
        assert_eq!(s.assignments[0].len(), 3);
        assert_eq!(s.totals[0], 6.0);
    }

    #[test]
    fn more_workers_than_users() {
        let w = vec![5.0, 1.0];
        let s = schedule(SchedulerKind::Greedy, &w, 4);
        covers_all(&s, 2);
        let nonempty = s.assignments.iter().filter(|a| !a.is_empty()).count();
        assert_eq!(nonempty, 2);
    }

    #[test]
    fn empty_cohort() {
        let s = schedule(SchedulerKind::Greedy, &[], 3);
        assert!(s.assignments.iter().all(|a| a.is_empty()));
        assert_eq!(s.predicted_straggler_gap(), 0.0);
    }

    #[test]
    fn greedy_is_deterministic() {
        let w = heavy_tailed_weights(50, 7);
        let a = schedule(SchedulerKind::Greedy, &w, 4);
        let b = schedule(SchedulerKind::Greedy, &w, 4);
        assert_eq!(a.assignments, b.assignments);
    }

    #[test]
    fn order_is_lpt_for_greedy_and_fifo_for_uniform() {
        let w = vec![2.0, 9.0, 4.0];
        assert_eq!(order(SchedulerKind::Greedy, &w), vec![1, 2, 0]);
        assert_eq!(order(SchedulerKind::Uniform, &w), vec![0, 1, 2]);
        // a constant base shifts every weight equally: same order
        assert_eq!(order(SchedulerKind::GreedyBase { base: 100.0 }, &w), vec![1, 2, 0]);
        assert_eq!(order(SchedulerKind::GreedyMedianBase, &w), vec![1, 2, 0]);
        assert!(order(SchedulerKind::Greedy, &[]).is_empty());
    }

    #[test]
    fn median_odd_even_empty() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
        assert_eq!(median(&[]), 0.0);
    }

    #[test]
    fn base_value_changes_assignment_shape() {
        // With a large base, counts per worker even out (the base
        // dominates), even if raw weights are skewed.
        let w = vec![100.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0];
        let s = schedule(SchedulerKind::GreedyBase { base: 1000.0 }, &w, 4);
        let counts: Vec<usize> = s.assignments.iter().map(|a| a.len()).collect();
        assert!(counts.iter().all(|&c| c == 2), "{counts:?}");
    }
}
