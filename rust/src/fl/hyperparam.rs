//! Hyperparameters that can vary across central iterations (App. B.1
//! "Hyperparameters"): at the start of each iteration the algorithm
//! requests the current value, which is then static for that iteration.

/// A scalar hyperparameter schedule.
pub trait HyperParam: Send + Sync {
    /// Value at central iteration `t`.
    fn at(&self, t: u64) -> f64;
    fn describe(&self) -> String;
}

/// Constant for the whole experiment.
pub struct Constant(pub f64);

impl HyperParam for Constant {
    fn at(&self, _t: u64) -> f64 {
        self.0
    }
    fn describe(&self) -> String {
        format!("const({})", self.0)
    }
}

/// Linear warmup to `base` over `warmup` iterations (paper Table 9:
/// "Central lr warmup 50"), constant afterwards.
pub struct Warmup {
    pub base: f64,
    pub warmup: u64,
}

impl HyperParam for Warmup {
    fn at(&self, t: u64) -> f64 {
        if self.warmup == 0 || t >= self.warmup {
            self.base
        } else {
            self.base * (t + 1) as f64 / self.warmup as f64
        }
    }
    fn describe(&self) -> String {
        format!("warmup({}, {})", self.base, self.warmup)
    }
}

/// Step decay: value = base * gamma^(t / every).
pub struct StepDecay {
    pub base: f64,
    pub gamma: f64,
    pub every: u64,
}

impl HyperParam for StepDecay {
    fn at(&self, t: u64) -> f64 {
        self.base * self.gamma.powi((t / self.every.max(1)) as i32)
    }
    fn describe(&self) -> String {
        format!("step({}, x{}, every {})", self.base, self.gamma, self.every)
    }
}

/// Exponential decay: value = base * exp(-rate * t).
pub struct ExpDecay {
    pub base: f64,
    pub rate: f64,
}

impl HyperParam for ExpDecay {
    fn at(&self, t: u64) -> f64 {
        self.base * (-self.rate * t as f64).exp()
    }
    fn describe(&self) -> String {
        format!("exp({}, {})", self.base, self.rate)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_is_constant() {
        let h = Constant(0.3);
        assert_eq!(h.at(0), 0.3);
        assert_eq!(h.at(1_000_000), 0.3);
    }

    #[test]
    fn warmup_ramps_then_holds() {
        let h = Warmup { base: 1.0, warmup: 10 };
        assert!(h.at(0) > 0.0 && h.at(0) < 0.2);
        assert!(h.at(4) < h.at(8));
        assert_eq!(h.at(10), 1.0);
        assert_eq!(h.at(100), 1.0);
        // degenerate warmup
        let h0 = Warmup { base: 2.0, warmup: 0 };
        assert_eq!(h0.at(0), 2.0);
    }

    #[test]
    fn decays_are_monotone() {
        let s = StepDecay { base: 1.0, gamma: 0.5, every: 5 };
        assert_eq!(s.at(0), 1.0);
        assert_eq!(s.at(5), 0.5);
        assert_eq!(s.at(10), 0.25);
        let e = ExpDecay { base: 1.0, rate: 0.1 };
        assert!(e.at(1) < e.at(0));
        assert!((e.at(10) - (-1.0f64).exp()).abs() < 1e-12);
    }
}
