//! Central (server) optimizers: consume the aggregated pseudo-gradient Δ
//! and update the central model (paper App. A; FedAdam from Reddi et al.
//! [70] is "a tunable component of these algorithms", §4.3).

/// A server optimizer over the flat central parameter vector.
pub trait CentralOptimizer: Send {
    /// θ ← Opt(θ, Δ) with the pseudo-gradient Δ (the *averaged* model
    /// update; note Δ = θ − θ′ so descent is θ ← θ − lr·Δ̂).
    fn apply(&mut self, params: &mut [f32], delta: &[f32], lr: f64);
    fn name(&self) -> &'static str;
    /// Reset optimizer state (new run with the same instance).
    fn reset(&mut self);
}

/// Plain SGD: θ ← θ − lr·Δ. With lr = 1 this is exactly FedAvg's
/// "replace by the average" (paper Table 8 uses central SGD, lr 1.0).
#[derive(Debug, Default)]
pub struct Sgd;

impl CentralOptimizer for Sgd {
    fn apply(&mut self, params: &mut [f32], delta: &[f32], lr: f64) {
        crate::util::axpy(params, -(lr as f32), delta);
    }
    fn name(&self) -> &'static str {
        "sgd"
    }
    fn reset(&mut self) {}
}

/// FedAdam (Reddi et al. [70]) with the *adaptivity degree* τ added to
/// √v̂ (paper Tables 9–11 set τ = 0.1 or 1e-4). Moments are allocated
/// lazily at first apply and reused (no per-round allocation).
#[derive(Debug)]
pub struct Adam {
    pub beta1: f64,
    pub beta2: f64,
    /// Adaptivity degree τ (plays epsilon's role but is a first-class
    /// hyperparameter in federated Adam).
    pub adaptivity: f64,
    m: Vec<f32>,
    v: Vec<f32>,
    t: u64,
}

impl Adam {
    pub fn new(beta1: f64, beta2: f64, adaptivity: f64) -> Self {
        Adam { beta1, beta2, adaptivity, m: Vec::new(), v: Vec::new(), t: 0 }
    }

    /// The paper's benchmark configuration (Tables 9–10).
    pub fn paper(adaptivity: f64) -> Self {
        Self::new(0.9, 0.99, adaptivity)
    }
}

impl CentralOptimizer for Adam {
    fn apply(&mut self, params: &mut [f32], delta: &[f32], lr: f64) {
        if self.m.len() != params.len() {
            self.m = vec![0.0; params.len()];
            self.v = vec![0.0; params.len()];
            self.t = 0;
        }
        self.t += 1;
        crate::tensor::ops::adam_step(
            params,
            delta,
            &mut self.m,
            &mut self.v,
            self.beta1 as f32,
            self.beta2 as f32,
            (1.0 - self.beta1.powi(self.t as i32)) as f32,
            (1.0 - self.beta2.powi(self.t as i32)) as f32,
            self.adaptivity as f32,
            lr as f32,
        );
    }

    fn name(&self) -> &'static str {
        "adam"
    }

    fn reset(&mut self) {
        self.m.clear();
        self.v.clear();
        self.t = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sgd_descends() {
        let mut p = vec![1.0f32, 1.0];
        Sgd.apply(&mut p, &[0.5, -0.5], 1.0);
        assert_eq!(p, vec![0.5, 1.5]);
    }

    #[test]
    fn adam_moves_against_gradient_sign() {
        let mut opt = Adam::paper(0.1);
        let mut p = vec![0.0f32, 0.0];
        for _ in 0..10 {
            opt.apply(&mut p, &[1.0, -1.0], 0.1);
        }
        assert!(p[0] < 0.0 && p[1] > 0.0);
        // roughly symmetric
        assert!((p[0] + p[1]).abs() < 1e-5);
    }

    #[test]
    fn adam_adaptivity_bounds_step() {
        // With constant unit gradient the per-step move approaches
        // lr·1/(1+τ); τ large → smaller steps.
        let mut small_tau = Adam::paper(0.01);
        let mut big_tau = Adam::paper(10.0);
        let mut p1 = vec![0.0f32];
        let mut p2 = vec![0.0f32];
        for _ in 0..50 {
            small_tau.apply(&mut p1, &[1.0], 0.1);
            big_tau.apply(&mut p2, &[1.0], 0.1);
        }
        assert!(p1[0].abs() > p2[0].abs() * 5.0);
    }

    #[test]
    fn adam_reset_clears_state() {
        let mut opt = Adam::paper(0.1);
        let mut p = vec![0.0f32];
        opt.apply(&mut p, &[1.0], 0.1);
        let after_one = p[0];
        opt.reset();
        let mut q = vec![0.0f32];
        opt.apply(&mut q, &[1.0], 0.1);
        assert_eq!(after_one, q[0]);
    }
}
