//! Model adapters (paper App. B.1 "Model").
//!
//! A [`Model`] connects a trainable object to the simulator. The NN
//! benchmark models are [`HloModel`]s: thin wrappers over the AOT-lowered
//! artifacts (L2 JAX step functions + L1 Pallas kernels) executed through
//! the per-worker PJRT runtime. Non-neural models (federated GBDT / GMM,
//! paper §1 "Non-gradient-descent training") implement the same trait in
//! pure Rust — see [`super::gbdt`] and [`super::gmm`].
//!
//! The efficiency contract (paper §3, items 1–2): one model per worker,
//! buffers allocated once, the central state cloned *into* preallocated
//! tensors before each user, parameters updated in place. `HloModel`
//! mirrors that: `central`, `work` and the batch staging buffers are
//! allocated at construction and reused for every user of every round.

#[cfg(feature = "hlo")]
use std::rc::Rc;

#[cfg(feature = "hlo")]
use anyhow::{bail, Context};
use anyhow::Result;

use super::context::LocalParams;
use super::metrics::Metrics;
use crate::data::UserData;
#[cfg(feature = "hlo")]
use crate::runtime::{Arg, Compiled, ModelEntry, Out, Runtime};
#[cfg(feature = "hlo")]
use crate::util::rng::Rng;

/// Output of one user's local optimization.
#[derive(Debug, Clone, Default)]
pub struct TrainOutput {
    /// The user's contribution for aggregation. For gradient-descent
    /// models this is the model delta Δ = θ − θ′ (paper Alg. 2); for
    /// GBDT it is gradient histograms, for GMM sufficient statistics.
    pub update: Vec<f32>,
    /// Σ per-example loss (sufficient statistic for the central metric).
    pub loss_sum: f64,
    /// Model-family "stat" sum (correct count / true positives).
    pub stat_sum: f64,
    /// Σ example weights (the denominator).
    pub wsum: f64,
    /// Local optimization steps executed.
    pub steps: usize,
}

/// Collects per-example scores + targets during evaluation, for metrics
/// that are not decomposable into sums (mAP on the FLAIR benchmark).
#[derive(Debug, Default, Clone)]
pub struct ScoreSink {
    pub labels: usize,
    pub scores: Vec<f32>,
    pub targets: Vec<f32>,
}

/// The L1 Pallas `clip_scale` kernel as a callable: clips `v` to L2 norm
/// `bound` in place and returns the pre-clip norm. The DP postprocessors
/// call this through the worker's model so clipping runs in the same
/// stack as training (paper §3: "DP mechanisms are implemented with GPU
/// acceleration without data transferring between CPU and GPU").
pub trait ClipKernel {
    fn clip(&self, v: &mut Vec<f32>, bound: f32) -> Result<f64>;
}

/// A trainable model bound to one worker.
pub trait Model {
    /// Length of the central state vector.
    fn param_count(&self) -> usize;

    /// Clone the broadcast central state into the preallocated local
    /// buffer (paper §3 item 2: "always cloned to already allocated
    /// tensors").
    fn set_central(&mut self, central: &[f32]);

    /// The current central state.
    fn central(&self) -> &[f32];

    /// Run local optimization for one user and return its contribution.
    /// `c_diff` is SCAFFOLD's control-variate correction (c − c_u),
    /// lowered into the unified train artifact; `None` means zeros.
    fn train_local(
        &mut self,
        data: &UserData,
        p: &LocalParams,
        c_diff: Option<&[f32]>,
        seed: u64,
    ) -> Result<TrainOutput>;

    /// Evaluate the current central state on `data`. When `sink` is given
    /// and the model emits per-example scores, they are appended for
    /// non-decomposable metrics (mAP).
    fn evaluate(&mut self, data: &UserData, sink: Option<&mut ScoreSink>) -> Result<Metrics>;

    /// The model's L1 clip kernel, when it has one.
    fn clip_kernel(&self) -> Option<&dyn ClipKernel> {
        None
    }

    /// Device busy-time consumed so far (for the simulated-device
    /// accounting; 0 for pure-Rust models, which cost host time only).
    fn busy_nanos(&self) -> u64 {
        0
    }

    /// Model family tag for diagnostics.
    fn name(&self) -> &str;
}

/// A NN benchmark model: AOT-lowered train/eval/clip artifacts plus the
/// flat-parameter buffers, executed through the worker's PJRT runtime.
/// Requires the `hlo` cargo feature (the `xla` crate).
#[cfg(feature = "hlo")]
pub struct HloModel {
    model_name: String,
    entry: ModelEntry,
    train_exe: Rc<Compiled>,
    eval_exe: Rc<Compiled>,
    clip_exe: Rc<Compiled>,
    /// Frozen base weights (LoRA models only) — a runtime *input*, never
    /// trained or aggregated.
    base: Option<Vec<f32>>,
    /// Central (global) parameters θ for the current iteration.
    central: Vec<f32>,
    /// Local parameters θ′, trained in place.
    work: Vec<f32>,
    /// Zero vector reused as c_diff when the algorithm passes none.
    zeros: Vec<f32>,
    /// Batch staging buffers (train shape).
    stage: BatchStage,
    /// Batch staging buffers (eval shape).
    eval_stage: BatchStage,
    eval_emits_scores: bool,
    /// Keeps the PJRT client alive for the executables' lifetime when the
    /// model owns its runtime (worker-factory construction).
    _runtime: Option<std::rc::Rc<Runtime>>,
}

/// Preallocated padded-batch staging buffers.
#[cfg(feature = "hlo")]
struct BatchStage {
    batch: usize,
    xf: Vec<f32>,
    xi: Vec<i32>,
    yf: Vec<f32>,
    yi: Vec<i32>,
    w: Vec<f32>,
}

#[cfg(feature = "hlo")]
impl BatchStage {
    fn new(batch: usize, x_elems: usize, y_elems: usize) -> Self {
        BatchStage {
            batch,
            xf: vec![0.0; batch * x_elems],
            xi: vec![0; batch * x_elems],
            yf: vec![0.0; batch * y_elems],
            yi: vec![0; batch * y_elems],
            w: vec![0.0; batch],
        }
    }
}

#[cfg(feature = "hlo")]
impl HloModel {
    /// Build a model from the manifest entry `name`, compiling (or reusing
    /// the worker's cached) train/eval/clip executables.
    pub fn new(rt: &Runtime, name: &str, init_seed: u64) -> Result<Self> {
        let entry = rt.manifest.model(name)?.clone();
        let train_key = entry
            .artifacts
            .get("train")
            .with_context(|| format!("model {name} has no train artifact"))?;
        let eval_key = entry.artifacts.get("eval").context("no eval artifact")?;
        let clip_key = entry.artifacts.get("clip").context("no clip artifact")?;
        let train_exe = rt.get(train_key)?;
        let eval_exe = rt.get(eval_key)?;
        let clip_exe = rt.get(clip_key)?;

        let central = entry.init_params(init_seed);
        let n = central.len();
        let base = entry.init_base_params(init_seed ^ 0xBA5E);

        // Staging sizes come from the artifact input specs: the batch
        // input follows (params, [base,] global, c_diff) for train.
        let skip = if base.is_some() { 4 } else { 3 };
        let x_spec = &train_exe.spec.inputs[skip];
        let x_per = x_spec.element_count() / entry.train_batch;
        let y_per = if train_exe.spec.inputs.len() == skip + 5 {
            // (x, y, w, lr, mu)
            train_exe.spec.inputs[skip + 1].element_count() / entry.train_batch
        } else {
            0 // (tokens, w, lr, mu): loss is self-supervised
        };
        let eval_skip = if base.is_some() { 2 } else { 1 };
        let ex_spec = &eval_exe.spec.inputs[eval_skip];
        let ex_per = ex_spec.element_count() / entry.eval_batch;
        let ey_per = if eval_exe.spec.inputs.len() == eval_skip + 3 {
            eval_exe.spec.inputs[eval_skip + 1].element_count() / entry.eval_batch
        } else {
            0
        };
        let eval_emits_scores = eval_exe.spec.outputs.len() > 3;

        Ok(HloModel {
            model_name: name.to_string(),
            train_exe,
            eval_exe,
            clip_exe,
            base,
            work: central.clone(),
            zeros: vec![0.0; n],
            stage: BatchStage::new(entry.train_batch, x_per, y_per),
            eval_stage: BatchStage::new(entry.eval_batch, ex_per, ey_per),
            central,
            entry,
            eval_emits_scores,
            _runtime: None,
        })
    }

    /// Build a model that owns its runtime (keeps the PJRT client alive;
    /// the per-worker construction path).
    pub fn new_owned(rt: std::rc::Rc<Runtime>, name: &str, init_seed: u64) -> Result<Self> {
        let mut m = Self::new(&rt, name, init_seed)?;
        m._runtime = Some(rt);
        Ok(m)
    }

    pub fn entry(&self) -> &ModelEntry {
        &self.entry
    }

    /// Re-initialize the central parameters from the manifest init spec.
    pub fn reinit(&mut self, seed: u64) {
        self.central = self.entry.init_params(seed);
    }

    /// Stage examples `idx` of `data` into a padded batch; returns the
    /// number of real (weight-1) examples staged.
    fn stage_batch(stage: &mut BatchStage, data: &UserData, idx: &[usize]) -> Result<usize> {
        let b = stage.batch;
        let n = idx.len().min(b);
        stage.w[..n].fill(1.0);
        stage.w[n..].fill(0.0);
        match data {
            UserData::Image { x, y, hwc } => {
                for (row, &i) in idx.iter().take(n).enumerate() {
                    stage.xf[row * hwc..(row + 1) * hwc]
                        .copy_from_slice(&x[i * hwc..(i + 1) * hwc]);
                    stage.yi[row] = y[i];
                }
                for row in n..b {
                    stage.xf[row * hwc..(row + 1) * hwc].fill(0.0);
                    stage.yi[row] = 0;
                }
            }
            UserData::Features { x, y, feat, labels } => {
                for (row, &i) in idx.iter().take(n).enumerate() {
                    stage.xf[row * feat..(row + 1) * feat]
                        .copy_from_slice(&x[i * feat..(i + 1) * feat]);
                    stage.yf[row * labels..(row + 1) * labels]
                        .copy_from_slice(&y[i * labels..(i + 1) * labels]);
                }
                for row in n..b {
                    stage.xf[row * feat..(row + 1) * feat].fill(0.0);
                    stage.yf[row * labels..(row + 1) * labels].fill(0.0);
                }
            }
            UserData::Tokens { seqs, seq_len } => {
                for (row, &i) in idx.iter().take(n).enumerate() {
                    stage.xi[row * seq_len..(row + 1) * seq_len]
                        .copy_from_slice(&seqs[i * seq_len..(i + 1) * seq_len]);
                }
                for row in n..b {
                    stage.xi[row * seq_len..(row + 1) * seq_len].fill(0);
                }
            }
            other => bail!("HloModel cannot train on {other:?}"),
        }
        Ok(n)
    }

    /// Build the batch `Arg`s matching the artifact's input layout.
    fn batch_args<'a>(stage: &'a BatchStage, data: &UserData) -> Vec<Arg<'a>> {
        match data {
            UserData::Image { .. } => vec![
                Arg::F32(&stage.xf),
                Arg::I32(&stage.yi),
                Arg::F32(&stage.w),
            ],
            UserData::Features { .. } => vec![
                Arg::F32(&stage.xf),
                Arg::F32(&stage.yf),
                Arg::F32(&stage.w),
            ],
            UserData::Tokens { .. } => vec![Arg::I32(&stage.xi), Arg::F32(&stage.w)],
            _ => unreachable!("checked in stage_batch"),
        }
    }
}

#[cfg(feature = "hlo")]
impl Model for HloModel {
    fn param_count(&self) -> usize {
        self.central.len()
    }

    fn set_central(&mut self, central: &[f32]) {
        self.central.copy_from_slice(central);
    }

    fn central(&self) -> &[f32] {
        &self.central
    }

    fn train_local(
        &mut self,
        data: &UserData,
        p: &LocalParams,
        c_diff: Option<&[f32]>,
        seed: u64,
    ) -> Result<TrainOutput> {
        let n_examples = data.len();
        if n_examples == 0 {
            return Ok(TrainOutput::default());
        }
        // θ′ ← θ (clone into the work buffer; the buffer was moved out as
        // the previous user's Δ, so restore capacity first — the only
        // model-sized allocation per user besides PJRT's own output
        // literal, which the xla-crate API forces).
        self.work.resize(self.central.len(), 0.0);
        self.work.copy_from_slice(&self.central);
        let c_diff = c_diff.unwrap_or(&self.zeros);
        let mut rng = Rng::seed_from_u64(seed);
        let mut order: Vec<usize> = (0..n_examples).collect();
        let mut out = TrainOutput { update: Vec::new(), ..Default::default() };

        'epochs: for _epoch in 0..p.epochs.max(1) {
            rng.shuffle(&mut order);
            for chunk in order.chunks(self.stage.batch) {
                if p.max_steps > 0 && out.steps >= p.max_steps {
                    break 'epochs;
                }
                Self::stage_batch(&mut self.stage, data, chunk)?;
                let mut args: Vec<Arg> = Vec::with_capacity(8);
                args.push(Arg::F32(&self.work));
                if let Some(base) = &self.base {
                    args.push(Arg::F32(base));
                }
                args.push(Arg::F32(&self.central));
                args.push(Arg::F32(c_diff));
                args.extend(Self::batch_args(&self.stage, data));
                args.push(Arg::ScalarF32(p.lr));
                args.push(Arg::ScalarF32(p.mu));
                let mut outs = self.train_exe.execute(&args)?;
                // outputs: (new_flat, loss_sum, stat_sum, wsum)
                out.wsum += outs[3].scalar_f32() as f64;
                out.stat_sum += outs[2].scalar_f32() as f64;
                out.loss_sum += outs[1].scalar_f32() as f64;
                let new_flat = std::mem::replace(&mut outs[0], Out::F32(Vec::new())).into_f32();
                debug_assert_eq!(new_flat.len(), self.work.len());
                self.work = new_flat;
                out.steps += 1;
            }
        }

        // Δ = θ − θ′ (paper Alg. 2). Reuse the final work buffer as Δ to
        // avoid a second model-sized allocation.
        let mut delta = std::mem::take(&mut self.work);
        crate::tensor::ops::sub_rev_assign(&mut delta, &self.central);
        out.update = delta;
        Ok(out)
    }

    fn evaluate(&mut self, data: &UserData, mut sink: Option<&mut ScoreSink>) -> Result<Metrics> {
        let n_examples = data.len();
        let mut metrics = Metrics::new();
        if n_examples == 0 {
            return Ok(metrics);
        }
        let idx: Vec<usize> = (0..n_examples).collect();
        let mut loss_sum = 0f64;
        let mut stat_sum = 0f64;
        let mut wsum = 0f64;
        for chunk in idx.chunks(self.eval_stage.batch) {
            let real = Self::stage_batch(&mut self.eval_stage, data, chunk)?;
            let mut args: Vec<Arg> = Vec::with_capacity(5);
            args.push(Arg::F32(&self.central));
            if let Some(base) = &self.base {
                args.push(Arg::F32(base));
            }
            args.extend(Self::batch_args(&self.eval_stage, data));
            let outs = self.eval_exe.execute(&args)?;
            loss_sum += outs[0].scalar_f32() as f64;
            stat_sum += outs[1].scalar_f32() as f64;
            wsum += outs[2].scalar_f32() as f64;
            if self.eval_emits_scores {
                if let Some(sink) = sink.as_deref_mut() {
                    if let UserData::Features { y, labels, .. } = data {
                        sink.labels = *labels;
                        let scores = outs[3].as_f32();
                        for (row, &i) in chunk.iter().take(real).enumerate() {
                            sink.scores
                                .extend_from_slice(&scores[row * labels..(row + 1) * labels]);
                            sink.targets
                                .extend_from_slice(&y[i * labels..(i + 1) * labels]);
                        }
                    }
                }
            }
        }
        metrics.add_central("loss", loss_sum, wsum);
        metrics.add_central("stat", stat_sum, wsum);
        Ok(metrics)
    }

    fn clip_kernel(&self) -> Option<&dyn ClipKernel> {
        Some(self)
    }

    fn busy_nanos(&self) -> u64 {
        self.train_exe.stats().exec_nanos
            + self.eval_exe.stats().exec_nanos
            + self.clip_exe.stats().exec_nanos
    }

    fn name(&self) -> &str {
        &self.model_name
    }
}

#[cfg(feature = "hlo")]
impl ClipKernel for HloModel {
    /// Run the L1 Pallas `clip_scale` artifact: v ← v·min(1, bound/‖v‖₂),
    /// returning the pre-clip norm.
    fn clip(&self, v: &mut Vec<f32>, bound: f32) -> Result<f64> {
        let args = [Arg::F32(v), Arg::ScalarF32(bound)];
        let mut outs = self.clip_exe.execute(&args)?;
        let norm = outs[1].scalar_f32() as f64;
        *v = std::mem::replace(&mut outs[0], Out::F32(Vec::new())).into_f32();
        Ok(norm)
    }
}

/// Pure-Rust clip with identical semantics, used server-side and by
/// non-NN models (and as the oracle in tests against the L1 kernel).
pub struct RustClip;

impl ClipKernel for RustClip {
    fn clip(&self, v: &mut Vec<f32>, bound: f32) -> Result<f64> {
        Ok(crate::tensor::ops::l2_clip(v, bound))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rust_clip_caps_norm() {
        let mut v = vec![3.0f32, 4.0];
        let norm = RustClip.clip(&mut v, 1.0).unwrap();
        assert!((norm - 5.0).abs() < 1e-6);
        assert!((crate::util::l2_norm(&v) - 1.0).abs() < 1e-6);
        // below the bound: untouched
        let mut u = vec![0.3f32, 0.4];
        RustClip.clip(&mut u, 1.0).unwrap();
        assert_eq!(u, vec![0.3, 0.4]);
    }

    #[test]
    fn train_output_default_is_empty() {
        let t = TrainOutput::default();
        assert!(t.update.is_empty());
        assert_eq!(t.steps, 0);
    }
}
