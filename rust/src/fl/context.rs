//! Central contexts: the per-iteration "recipe" an algorithm constructs
//! (paper App. B.2). A context targets one population, carries the local
//! optimization hyperparameters for that iteration (already resolved from
//! any `HyperParam` schedules), and tells the backend how big a cohort to
//! sample.

/// Which federated population a context targets.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Population {
    /// Training users; local optimization returns statistics.
    Train,
    /// Held-out users; federated evaluation only (no statistics).
    Val,
}

/// How a context's cohort is distributed across worker replicas (see
/// [`crate::fl::dispatch`] for the execution engines behind each mode).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DispatchMode {
    /// Pre-computed per-worker assignments (greedy LPT schedule), barrier
    /// on all workers — the paper's design (App. B.6), kept for baseline
    /// comparisons and virtual-cluster replay.
    Static,
    /// Workers pull user ids one at a time from a shared LPT-ordered
    /// queue; no per-cohort assignment allocation, and the straggler gap
    /// collapses to at most one user's tail.
    WorkStealing,
    /// Staleness-bounded buffered aggregation (FedBuff-style extension):
    /// workers stream per-user statistics as they finish; the server
    /// folds the first K arrivals weighted by staleness and launches the
    /// next context without waiting for stragglers.
    Async,
    /// Multi-process distribution: seq-stamped per-user commands go to
    /// worker *processes* over Unix-domain/TCP sockets
    /// ([`crate::comms`]), folded through the same deterministic
    /// reorder-window as async replay — so a distributed run is
    /// bit-identical to the threaded replay run at the same seed,
    /// whatever the worker-process count (DESIGN.md §7).
    Socket,
}

/// Dispatch policy carried by a [`CentralContext`]: the mode plus the
/// async-mode knobs.
///
/// The **default spec is the "inherit the engine policy" sentinel**:
/// the backend stamps `RunParams::dispatch` over contexts that carry
/// it, so a context cannot distinguish "unset" from a deliberate
/// default-Static override — set a non-default spec (e.g. a different
/// `max_staleness`) to pin Static or WorkStealing per context. Async
/// can only be selected engine-wide via `RunParams::dispatch` (the
/// synchronous engine rejects async-requesting contexts with an
/// error), and the async engine stamps its own spec over every
/// context.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DispatchSpec {
    pub mode: DispatchMode,
    /// Async: drop an in-flight update once it lags the current round by
    /// more than this many iterations.
    pub max_staleness: u64,
    /// Async: fraction of the cohort whose arrival closes the round's
    /// buffer (K = ⌈frac·cohort⌉).
    pub buffer_frac: f64,
    /// Async: deterministic-replay window. `0` (default) folds arrivals
    /// in physical arrival order — fastest, but the result depends on
    /// worker count and timing. `> 0` keeps at most this many commands
    /// logically outstanding and folds their results strictly in
    /// dispatch (round, uid) order through a bounded arrival-reorder
    /// buffer, making async runs bit-identical across worker counts
    /// (the window also caps the parallelism the engine can exploit,
    /// so pick `>= num_workers`).
    pub reorder_window: usize,
}

impl Default for DispatchSpec {
    fn default() -> Self {
        DispatchSpec {
            mode: DispatchMode::Static,
            max_staleness: 2,
            buffer_frac: 0.5,
            reorder_window: 0,
        }
    }
}

impl DispatchSpec {
    pub fn work_stealing() -> Self {
        DispatchSpec { mode: DispatchMode::WorkStealing, ..Default::default() }
    }

    pub fn async_mode(max_staleness: u64, buffer_frac: f64) -> Self {
        DispatchSpec {
            mode: DispatchMode::Async,
            max_staleness,
            buffer_frac,
            reorder_window: 0,
        }
    }

    /// Async with deterministic replay: arrivals release in dispatch
    /// (round, uid) order through a reorder buffer bounded by `window`
    /// (clamped to ≥ 1), so runs are bit-identical across worker counts.
    pub fn async_replay(max_staleness: u64, buffer_frac: f64, window: usize) -> Self {
        DispatchSpec {
            mode: DispatchMode::Async,
            max_staleness,
            buffer_frac,
            reorder_window: window.max(1),
        }
    }

    /// Socket (multi-process) dispatch: async-replay semantics over a
    /// process transport; the window is clamped to ≥ 1 for the same
    /// reason as [`DispatchSpec::async_replay`].
    pub fn socket(max_staleness: u64, buffer_frac: f64, window: usize) -> Self {
        DispatchSpec {
            mode: DispatchMode::Socket,
            max_staleness,
            buffer_frac,
            reorder_window: window.max(1),
        }
    }

    /// Async buffer size K for a cohort of `cohort` users: ⌈frac·n⌉,
    /// clamped into [1, n].
    pub fn buffer_k(&self, cohort: usize) -> usize {
        if cohort == 0 {
            return 0;
        }
        ((self.buffer_frac * cohort as f64).ceil() as usize).clamp(1, cohort)
    }
}

/// Local optimization hyperparameters, resolved to static values for one
/// central iteration (paper App. B.1 "Hyperparameters").
#[derive(Debug, Clone)]
pub struct LocalParams {
    /// Number of passes over the user's data.
    pub epochs: usize,
    /// Local minibatch size.
    pub batch_size: usize,
    /// Local (client) learning rate.
    pub lr: f32,
    /// FedProx proximal coefficient µ (0 recovers FedAvg). Lowered into
    /// the unified train artifact, so switching algorithms does not
    /// require a different executable.
    pub mu: f32,
    /// Cap on the number of local steps (0 = unlimited); some setups
    /// bound local work per round.
    pub max_steps: usize,
}

impl Default for LocalParams {
    fn default() -> Self {
        LocalParams { epochs: 1, batch_size: 10, lr: 0.1, mu: 0.0, max_steps: 0 }
    }
}

/// The recipe for gathering one aggregated result (paper Alg. 1, `c_i`).
#[derive(Debug, Clone)]
pub struct CentralContext {
    /// Central iteration index t.
    pub iteration: u64,
    pub population: Population,
    /// Number of users to sample for this context.
    pub cohort_size: usize,
    /// Local training (or evaluation) parameters for this iteration.
    pub local: LocalParams,
    /// Seed stream for this iteration (cohort sampling, DP noise).
    pub seed: u64,
    /// How the cohort is distributed across workers (stamped from
    /// `RunParams::dispatch` when left at the default).
    pub dispatch: DispatchSpec,
    /// Algorithm tag for diagnostics.
    pub algorithm: &'static str,
}

impl CentralContext {
    pub fn train(iteration: u64, cohort_size: usize, local: LocalParams, seed: u64) -> Self {
        CentralContext {
            iteration,
            population: Population::Train,
            cohort_size,
            local,
            seed,
            dispatch: DispatchSpec::default(),
            algorithm: "",
        }
    }

    pub fn eval(iteration: u64, cohort_size: usize, seed: u64) -> Self {
        CentralContext {
            iteration,
            population: Population::Val,
            cohort_size,
            local: LocalParams::default(),
            seed,
            dispatch: DispatchSpec::default(),
            algorithm: "",
        }
    }

    pub fn is_train(&self) -> bool {
        self.population == Population::Train
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors_set_population() {
        let c = CentralContext::train(3, 50, LocalParams::default(), 7);
        assert!(c.is_train());
        assert_eq!(c.iteration, 3);
        let e = CentralContext::eval(3, 20, 7);
        assert_eq!(e.population, Population::Val);
        assert!(!e.is_train());
    }

    #[test]
    fn default_local_params_are_fedavg() {
        let p = LocalParams::default();
        assert_eq!(p.mu, 0.0);
        assert_eq!(p.epochs, 1);
    }

    #[test]
    fn default_dispatch_is_static() {
        let c = CentralContext::train(0, 10, LocalParams::default(), 0);
        assert_eq!(c.dispatch.mode, DispatchMode::Static);
        assert_eq!(DispatchSpec::work_stealing().mode, DispatchMode::WorkStealing);
    }

    #[test]
    fn buffer_k_clamps() {
        let spec = DispatchSpec::async_mode(2, 0.5);
        assert_eq!(spec.buffer_k(0), 0);
        assert_eq!(spec.buffer_k(1), 1);
        assert_eq!(spec.buffer_k(10), 5);
        assert_eq!(spec.buffer_k(11), 6);
        // frac > 1 clamps to the full cohort; frac <= 0 to one arrival
        assert_eq!(DispatchSpec::async_mode(2, 5.0).buffer_k(8), 8);
        assert_eq!(DispatchSpec::async_mode(2, 0.0).buffer_k(8), 1);
    }

    #[test]
    fn replay_spec_sets_window() {
        assert_eq!(DispatchSpec::async_mode(2, 0.5).reorder_window, 0);
        let r = DispatchSpec::async_replay(2, 0.5, 4);
        assert_eq!(r.mode, DispatchMode::Async);
        assert_eq!(r.reorder_window, 4);
        // a zero window would deadlock the fold loop: clamped to 1
        assert_eq!(DispatchSpec::async_replay(2, 0.5, 0).reorder_window, 1);
    }

    #[test]
    fn socket_spec_mirrors_replay() {
        let s = DispatchSpec::socket(3, 0.25, 6);
        assert_eq!(s.mode, DispatchMode::Socket);
        assert_eq!(s.max_staleness, 3);
        assert_eq!(s.buffer_frac, 0.25);
        assert_eq!(s.reorder_window, 6);
        assert_eq!(DispatchSpec::socket(3, 0.25, 0).reorder_window, 1);
    }
}
