//! Central contexts: the per-iteration "recipe" an algorithm constructs
//! (paper App. B.2). A context targets one population, carries the local
//! optimization hyperparameters for that iteration (already resolved from
//! any `HyperParam` schedules), and tells the backend how big a cohort to
//! sample.

/// Which federated population a context targets.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Population {
    /// Training users; local optimization returns statistics.
    Train,
    /// Held-out users; federated evaluation only (no statistics).
    Val,
}

/// Local optimization hyperparameters, resolved to static values for one
/// central iteration (paper App. B.1 "Hyperparameters").
#[derive(Debug, Clone)]
pub struct LocalParams {
    /// Number of passes over the user's data.
    pub epochs: usize,
    /// Local minibatch size.
    pub batch_size: usize,
    /// Local (client) learning rate.
    pub lr: f32,
    /// FedProx proximal coefficient µ (0 recovers FedAvg). Lowered into
    /// the unified train artifact, so switching algorithms does not
    /// require a different executable.
    pub mu: f32,
    /// Cap on the number of local steps (0 = unlimited); some setups
    /// bound local work per round.
    pub max_steps: usize,
}

impl Default for LocalParams {
    fn default() -> Self {
        LocalParams { epochs: 1, batch_size: 10, lr: 0.1, mu: 0.0, max_steps: 0 }
    }
}

/// The recipe for gathering one aggregated result (paper Alg. 1, `c_i`).
#[derive(Debug, Clone)]
pub struct CentralContext {
    /// Central iteration index t.
    pub iteration: u64,
    pub population: Population,
    /// Number of users to sample for this context.
    pub cohort_size: usize,
    /// Local training (or evaluation) parameters for this iteration.
    pub local: LocalParams,
    /// Seed stream for this iteration (cohort sampling, DP noise).
    pub seed: u64,
    /// Algorithm tag for diagnostics.
    pub algorithm: &'static str,
}

impl CentralContext {
    pub fn train(iteration: u64, cohort_size: usize, local: LocalParams, seed: u64) -> Self {
        CentralContext {
            iteration,
            population: Population::Train,
            cohort_size,
            local,
            seed,
            algorithm: "",
        }
    }

    pub fn eval(iteration: u64, cohort_size: usize, seed: u64) -> Self {
        CentralContext {
            iteration,
            population: Population::Val,
            cohort_size,
            local: LocalParams::default(),
            seed,
            algorithm: "",
        }
    }

    pub fn is_train(&self) -> bool {
        self.population == Population::Train
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors_set_population() {
        let c = CentralContext::train(3, 50, LocalParams::default(), 7);
        assert!(c.is_train());
        assert_eq!(c.iteration, 3);
        let e = CentralContext::eval(3, 20, 7);
        assert_eq!(e.population, Population::Val);
        assert!(!e.is_train());
    }

    #[test]
    fn default_local_params_are_fedavg() {
        let p = LocalParams::default();
        assert_eq!(p.mu, 0.0);
        assert_eq!(p.epochs, 1);
    }
}
