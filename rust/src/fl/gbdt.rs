//! Federated gradient-boosted decision trees (paper §1 "Non-gradient-
//! descent training"; [27]).
//!
//! The histogram-based federated GBDT: each round, clients compute
//! gradient/hessian histograms of the current ensemble's residuals over a
//! shared feature binning; the server aggregates the histograms and grows
//! one regression tree, appended to the ensemble. The *model state* is
//! the flat-encoded ensemble, so the generic broadcast/aggregate
//! machinery (and DP postprocessors — histograms are just vectors)
//! applies unchanged.
//!
//! With one communication round per tree the clients histogram at the
//! root only, which makes multi-feature deep trees inexact. We therefore
//! grow *single-feature* trees: the split feature is chosen greedily at
//! the root and refined recursively on its bin ranges — exact with root
//! histograms — and boosting across rounds composes different features
//! (GAM-style additive boosting). Documented in DESIGN.md §2.
//!
//! Flat encoding: `[num_trees, lr, tree_0 ..., tree_1 ...]`; each tree is
//! `max_nodes × 4` floats `(feature|leaf flag, threshold|value, left,
//! right)`. Capacity is fixed at construction, so the state length never
//! changes during training.

use anyhow::{bail, Result};

use super::algorithm::{FederatedAlgorithm, RunSpec};
use super::context::{CentralContext, Population};
use super::metrics::Metrics;
use super::model::{Model, ScoreSink, TrainOutput};
use super::stats::{StatValue, Statistics};
use crate::data::UserData;

/// Histogram bins per feature (uniform binning over a fixed range shared
/// by all clients).
pub const BINS: usize = 16;
/// Floats per tree node in the flat encoding.
const NODE_F: usize = 4;

/// GBDT hyperparameters shared by model and algorithm.
#[derive(Debug, Clone)]
pub struct GbdtParams {
    pub max_trees: usize,
    pub max_depth: usize,
    pub learning_rate: f32,
    /// L2 regularization on leaf values (xgboost λ).
    pub lambda: f64,
    /// Feature value range for the shared binning.
    pub feat_min: f32,
    pub feat_max: f32,
    pub num_features: usize,
}

impl Default for GbdtParams {
    fn default() -> Self {
        GbdtParams {
            max_trees: 20,
            max_depth: 3,
            learning_rate: 0.3,
            lambda: 1.0,
            feat_min: -3.0,
            feat_max: 3.0,
            num_features: 8,
        }
    }
}

impl GbdtParams {
    pub fn max_nodes(&self) -> usize {
        (1 << (self.max_depth + 1)) - 1
    }

    /// Flat state length: header (2) + trees.
    pub fn state_len(&self) -> usize {
        2 + self.max_trees * self.max_nodes() * NODE_F
    }

    /// Histogram vector length: (grad, hess) per (feature, bin).
    pub fn hist_len(&self) -> usize {
        self.num_features * BINS * 2
    }

    pub fn bin_of(&self, x: f32) -> usize {
        let t = ((x - self.feat_min) / (self.feat_max - self.feat_min)).clamp(0.0, 1.0);
        ((t * BINS as f32) as usize).min(BINS - 1)
    }

    /// Upper edge of a bin = the split threshold "x ≤ edge".
    pub fn bin_upper_edge(&self, bin: usize) -> f32 {
        self.feat_min + (bin + 1) as f32 * (self.feat_max - self.feat_min) / BINS as f32
    }
}

/// Evaluate the flat-encoded ensemble on one feature row.
pub fn predict(state: &[f32], x: &[f32], p: &GbdtParams) -> f32 {
    let num_trees = state[0] as usize;
    let lr = state[1];
    let mut out = 0.0;
    for t in 0..num_trees.min(p.max_trees) {
        let mut node = 0usize;
        loop {
            let off = 2 + (t * p.max_nodes() + node) * NODE_F;
            let feature = state[off];
            if feature < 0.0 {
                out += lr * state[off + 1];
                break;
            }
            let f = (feature as usize).min(x.len() - 1);
            node = if x[f] <= state[off + 1] {
                state[off + 2] as usize
            } else {
                state[off + 3] as usize
            };
        }
    }
    out
}

/// The client-side GBDT "model": computes residual gradient histograms.
/// Regression with squared loss: g = pred − y, h = 1.
pub struct GbdtModel {
    pub p: GbdtParams,
    state: Vec<f32>,
}

impl GbdtModel {
    pub fn new(p: GbdtParams) -> Self {
        let state = initial_state(&p);
        GbdtModel { p, state }
    }
}

/// Fresh flat state (no trees yet).
pub fn initial_state(p: &GbdtParams) -> Vec<f32> {
    let mut s = vec![0.0; p.state_len()];
    s[1] = p.learning_rate;
    s
}

impl Model for GbdtModel {
    fn param_count(&self) -> usize {
        self.state.len()
    }

    fn set_central(&mut self, central: &[f32]) {
        self.state.copy_from_slice(central);
    }

    fn central(&self) -> &[f32] {
        &self.state
    }

    /// "Local training" for GBDT = gradient/hessian histograms of the
    /// current ensemble's residuals over this user's rows.
    fn train_local(
        &mut self,
        data: &UserData,
        _p: &super::context::LocalParams,
        _c_diff: Option<&[f32]>,
        _seed: u64,
    ) -> Result<TrainOutput> {
        let (x, y, dim) = match data {
            UserData::Tabular { x, y, dim } => (x, y, *dim),
            _ => bail!("GbdtModel wants Tabular data"),
        };
        let p = &self.p;
        let mut hist = vec![0.0f32; p.hist_len()];
        let mut loss_sum = 0f64;
        for (row, &target) in x.chunks(dim).zip(y) {
            let pred = predict(&self.state, row, p);
            let g = pred - target;
            loss_sum += 0.5 * ((pred - target) as f64).powi(2);
            for (f, &v) in row.iter().enumerate().take(p.num_features) {
                let off = ((f * BINS) + p.bin_of(v)) * 2;
                hist[off] += g;
                hist[off + 1] += 1.0;
            }
        }
        Ok(TrainOutput {
            update: hist,
            loss_sum,
            stat_sum: 0.0,
            wsum: y.len() as f64,
            steps: 1,
        })
    }

    fn evaluate(&mut self, data: &UserData, _sink: Option<&mut ScoreSink>) -> Result<Metrics> {
        let (x, y, dim) = match data {
            UserData::Tabular { x, y, dim } => (x, y, *dim),
            _ => bail!("GbdtModel wants Tabular data"),
        };
        let mut loss = 0f64;
        for (row, &target) in x.chunks(dim).zip(y) {
            let pred = predict(&self.state, row, &self.p);
            loss += 0.5 * ((pred - target) as f64).powi(2);
        }
        let mut m = Metrics::new();
        m.add_central("loss", loss, y.len() as f64);
        Ok(m)
    }

    fn name(&self) -> &str {
        "gbdt"
    }
}

/// The federated GBDT algorithm: one tree per central iteration, grown
/// from the aggregated histograms.
pub struct FedGbdt {
    pub spec: RunSpec,
    pub p: GbdtParams,
}

impl FedGbdt {
    pub fn new(spec: RunSpec, p: GbdtParams) -> Self {
        FedGbdt { spec, p }
    }

    /// Grow one single-feature tree from the aggregated histograms:
    /// choose the feature with the best root gain, then recursively
    /// partition its bin range (exact with root histograms).
    fn grow_tree(&self, hist: &[f32]) -> Vec<f32> {
        let p = &self.p;
        let mut nodes = vec![[-1.0f32, 0.0, 0.0, 0.0]; p.max_nodes()];
        let gh = |f: usize, b: usize| {
            let off = ((f * BINS) + b) * 2;
            (hist[off] as f64, hist[off + 1] as f64)
        };
        let range_gh = |f: usize, lo: usize, hi: usize| {
            let (mut g, mut h) = (0f64, 0f64);
            for b in lo..=hi {
                let (gb, hb) = gh(f, b);
                g += gb;
                h += hb;
            }
            (g, h)
        };
        // best split of feature f over bins [lo, hi): (gain, bin)
        let best_split = |f: usize, lo: usize, hi: usize| -> Option<(f64, usize)> {
            let (gf, hf) = range_gh(f, lo, hi);
            let mut best: Option<(f64, usize)> = None;
            let (mut gl, mut hl) = (0f64, 0f64);
            for b in lo..hi {
                let (gb, hb) = gh(f, b);
                gl += gb;
                hl += hb;
                let (gr, hr) = (gf - gl, hf - hl);
                if hl < 1.0 || hr < 1.0 {
                    continue;
                }
                let gain = gl * gl / (hl + p.lambda) + gr * gr / (hr + p.lambda)
                    - gf * gf / (hf + p.lambda);
                if gain > best.map(|(g, _)| g).unwrap_or(1e-9) {
                    best = Some((gain, b));
                }
            }
            best
        };

        // pick the tree's feature by root gain
        let feature = (0..p.num_features)
            .filter_map(|f| best_split(f, 0, BINS - 1).map(|(g, _)| (g, f)))
            .max_by(|a, b| a.0.partial_cmp(&b.0).unwrap())
            .map(|(_, f)| f);

        // recursively split that feature's bin ranges
        let mut stack: Vec<(usize, usize, usize, usize)> = vec![(0, 0, 0, BINS - 1)];
        while let Some((node, depth, lo, hi)) = stack.pop() {
            let (g, h) = match feature {
                Some(f) => range_gh(f, lo, hi),
                None => range_gh(0, lo, hi),
            };
            let can_split = depth < p.max_depth && 2 * node + 2 < p.max_nodes();
            let split = match (feature, can_split) {
                (Some(f), true) => best_split(f, lo, hi),
                _ => None,
            };
            match split {
                Some((_gain, bin)) => {
                    let f = feature.unwrap();
                    let (l, r) = (2 * node + 1, 2 * node + 2);
                    nodes[node] = [f as f32, p.bin_upper_edge(bin), l as f32, r as f32];
                    stack.push((l, depth + 1, lo, bin));
                    stack.push((r, depth + 1, bin + 1, hi));
                }
                None => {
                    nodes[node] = [-1.0, (-g / (h + p.lambda)) as f32, 0.0, 0.0];
                }
            }
        }

        nodes.into_iter().flatten().collect()
    }
}

impl FederatedAlgorithm for FedGbdt {
    fn name(&self) -> &'static str {
        "fed-gbdt"
    }

    fn next_contexts(&self, t: u64) -> Vec<CentralContext> {
        if t >= self.spec.iterations.min(self.p.max_trees as u64) {
            return Vec::new();
        }
        let mut ctxs = vec![CentralContext::train(
            t,
            self.spec.cohort_size,
            self.spec.local.clone(),
            self.spec.seed.wrapping_add(t),
        )];
        if self.spec.val_cohort_size > 0 && t % self.spec.eval_every.max(1) == 0 {
            ctxs.push(CentralContext::eval(t, self.spec.val_cohort_size, self.spec.seed ^ t));
        }
        ctxs
    }

    fn simulate_one_user(
        &self,
        model: &mut dyn Model,
        _uid: usize,
        data: &UserData,
        ctx: &CentralContext,
    ) -> Result<(Option<Statistics>, Metrics)> {
        if ctx.population == Population::Val {
            let m = model.evaluate(data, None)?;
            return Ok((None, m));
        }
        let out = model.train_local(data, &ctx.local, None, 0)?;
        let mut m = Metrics::new();
        m.add_central("train/loss", out.loss_sum, out.wsum);
        // Users with few datapoints touch few (feature, bin) cells, so
        // the histogram is mostly zeros — ship it sparse when that is
        // smaller; aggregation handles the mix transparently.
        let hist = StatValue::Dense(out.update).compact();
        Ok((Some(Statistics::new_update_value(hist, 1.0)), m))
    }

    fn process_aggregated(
        &self,
        central: &mut [f32],
        _ctx: &CentralContext,
        mut aggregate: Statistics,
        metrics: &mut Metrics,
    ) -> Result<()> {
        // unlike the gradient algorithms, GBDT aggregates are consumed
        // sparse in direct-call paths (tests, library users) too — the
        // backend chokepoint densifies, but this must stay self-reliant
        aggregate.densify_all();
        let hist = aggregate.update();
        anyhow::ensure!(hist.len() == self.p.hist_len(), "histogram length mismatch");
        let tree = self.grow_tree(hist);
        let num_trees = central[0] as usize;
        anyhow::ensure!(num_trees < self.p.max_trees, "ensemble is full");
        let off = 2 + num_trees * self.p.max_nodes() * NODE_F;
        central[off..off + tree.len()].copy_from_slice(&tree);
        central[0] = (num_trees + 1) as f32;
        metrics.add_central("gbdt/trees", central[0] as f64, 1.0);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fl::context::LocalParams;
    use crate::fl::aggregator::Aggregator as _;

    fn params() -> GbdtParams {
        GbdtParams { num_features: 4, max_depth: 2, max_trees: 8, ..Default::default() }
    }

    fn user(n: usize, seed: u64, p: &GbdtParams) -> UserData {
        // y = 2·1[x0 > 0] − 1 (deterministic given the features)
        let mut rng = crate::util::rng::Rng::seed_from_u64(seed);
        let mut x = Vec::with_capacity(n * p.num_features);
        let mut y = Vec::with_capacity(n);
        for _ in 0..n {
            let mut row = Vec::with_capacity(p.num_features);
            for _ in 0..p.num_features {
                row.push(rng.normal() as f32);
            }
            y.push(if row[0] > 0.0 { 1.0 } else { -1.0 });
            x.extend(row);
        }
        UserData::Tabular { x, y, dim: p.num_features }
    }

    #[test]
    fn state_layout_roundtrip() {
        let p = params();
        let s = initial_state(&p);
        assert_eq!(s.len(), p.state_len());
        assert_eq!(s[0], 0.0);
        assert_eq!(s[1], p.learning_rate);
        // empty ensemble predicts 0
        assert_eq!(predict(&s, &[1.0, 0.0, 0.0, 0.0], &p), 0.0);
    }

    #[test]
    fn bins_cover_range() {
        let p = params();
        assert_eq!(p.bin_of(p.feat_min - 10.0), 0);
        assert_eq!(p.bin_of(p.feat_max + 10.0), BINS - 1);
        assert!(p.bin_of(0.0) > 0 && p.bin_of(0.0) < BINS - 1);
    }

    #[test]
    fn histograms_sum_to_cohort_size() {
        let p = params();
        let mut model = GbdtModel::new(p.clone());
        let data = user(32, 0, &p);
        let out = model.train_local(&data, &LocalParams::default(), None, 0).unwrap();
        assert_eq!(out.update.len(), p.hist_len());
        // hessians per feature must sum to n
        for f in 0..p.num_features {
            let h: f32 = (0..BINS).map(|b| out.update[((f * BINS) + b) * 2 + 1]).sum();
            assert!((h - 32.0).abs() < 1e-4, "feature {f}: {h}");
        }
    }

    #[test]
    fn boosting_reduces_loss_end_to_end() {
        let p = params();
        let spec = RunSpec { iterations: 8, cohort_size: 4, ..Default::default() };
        let alg = FedGbdt::new(spec, p.clone());
        let mut central = initial_state(&p);
        let users: Vec<UserData> = (0..4).map(|i| user(64, i, &p)).collect();

        let mut model = GbdtModel::new(p.clone());
        let mut losses = Vec::new();
        for t in 0..6u64 {
            let ctx = alg.next_contexts(t).remove(0);
            model.set_central(&central);
            let mut acc: Option<Statistics> = None;
            let mut loss = 0.0;
            for (i, u) in users.iter().enumerate() {
                let (s, m) = alg.simulate_one_user(&mut model, i, u, &ctx).unwrap();
                loss += m.get("train/loss").unwrap();
                crate::fl::SumAggregator.accumulate(&mut acc, s.unwrap());
            }
            losses.push(loss / users.len() as f64);
            let mut metrics = Metrics::new();
            alg.process_aggregated(&mut central, &ctx, acc.unwrap(), &mut metrics).unwrap();
            assert_eq!(metrics.get("gbdt/trees"), Some((t + 1) as f64));
        }
        assert!(
            losses.last().unwrap() < &(losses[0] * 0.6),
            "boosting failed to reduce loss: {losses:?}"
        );
    }

    #[test]
    fn grown_tree_splits_informative_feature() {
        let p = params();
        let spec = RunSpec::default();
        let alg = FedGbdt::new(spec, p.clone());
        let mut model = GbdtModel::new(p.clone());
        model.set_central(&initial_state(&p));
        let out = model
            .train_local(&user(256, 9, &p), &LocalParams::default(), None, 0)
            .unwrap();
        let tree = alg.grow_tree(&out.update);
        // root must split feature 0 (the label-defining feature)
        assert_eq!(tree[0], 0.0, "root split feature: {}", tree[0]);
    }

    #[test]
    fn ensemble_capacity_enforced() {
        let p = GbdtParams { max_trees: 1, ..params() };
        let spec = RunSpec { iterations: 10, cohort_size: 1, ..Default::default() };
        let alg = FedGbdt::new(spec, p.clone());
        let mut central = initial_state(&p);
        central[0] = 1.0; // full
        let agg = Statistics::new_update(vec![0.0; p.hist_len()], 1.0);
        let mut m = Metrics::new();
        let ctx = CentralContext::train(0, 1, LocalParams::default(), 0);
        assert!(alg.process_aggregated(&mut central, &ctx, agg, &mut m).is_err());
        // and next_contexts stops at the tree budget
        assert!(alg.next_contexts(1).is_empty());
    }
}
