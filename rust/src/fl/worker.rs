//! Worker replicas (paper §3.1, Fig. 1a).
//!
//! Every worker process is a *replica* with a different distributed
//! context — there is no dedicated coordinator or aggregator process.
//! Here a "process" is a thread that owns its own PJRT runtime + model
//! (the `Runtime` type is deliberately `!Send`, so each worker constructs
//! its own — the exact replica model of the paper). Workers receive a
//! per-round command (context + central state + a [`WorkSource`]: an
//! owned queue from the static LPT schedule, or a shared pull queue they
//! drain user-by-user — unlike the paper's distributed processes, our
//! in-process replicas *can* pull from a central queue, see
//! [`super::dispatch`]), train the users they claim, locally accumulate
//! statistics, and return one partial per command; the backend then
//! performs the all-reduce-equivalent `worker_reduce`.
//!
//! The optional topology emulation (a dedicated coordinator thread that
//! every per-user update is serialized through) exists only for the
//! baseline comparisons (paper Tables 1–2); pfl-style runs never touch
//! it.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use super::aggregator::Aggregator;
use super::algorithm::FederatedAlgorithm;
use super::context::CentralContext;
use super::dispatch::WorkSource;
use super::metrics::Metrics;
use super::model::{Model, RustClip};
use super::postprocess::{Postprocessor, PpEnv};
use super::stats::Statistics;
use crate::baselines::OverheadProfile;
use crate::data::UserDataSource;
use crate::simsys::{Counters, UserCost};
use crate::tensor::StatsArena;
use crate::util::rng::Rng;

/// Builds one worker's model inside the worker thread (so `!Send` models
/// like `HloModel` are constructed where they live).
pub type ModelFactory = Arc<dyn Fn(usize) -> Result<Box<dyn Model>> + Send + Sync>;

/// One round command to a worker. Public because the socket transport
/// ([`crate::comms::codec`]) encodes/decodes the same command type the
/// in-process channel driver sends — one command vocabulary, two
/// transports (DESIGN.md §7).
pub enum Cmd {
    Round {
        ctx: CentralContext,
        central: Arc<Vec<f32>>,
        /// This worker's work: an owned queue (static schedule) or a
        /// shared pull queue it drains user-by-user.
        work: WorkSource,
        /// Dispatch sequence number, echoed in [`RoundResult::seq`]. The
        /// async replay engine orders arrivals by it; barrier rounds
        /// send 0.
        seq: u64,
    },
    Stop,
}

/// One worker's per-round result.
pub struct RoundResult {
    pub worker: usize,
    /// Central iteration the command was issued for (async mode computes
    /// staleness from this when the result arrives rounds later).
    pub round: u64,
    /// Echo of the command's dispatch sequence number (async replay
    /// matches out-of-order arrivals against the expected fold order
    /// with it; 0 for barrier rounds).
    pub seq: u64,
    pub partial: Option<Statistics>,
    pub metrics: Metrics,
    pub counters: Counters,
    /// Measured per-user costs (Fig. 4a; virtual-cluster replay input).
    pub costs: Vec<UserCost>,
    pub error: Option<String>,
}

/// Shared immutable pieces each worker needs.
pub struct WorkerShared {
    /// Where user data comes from: the lazy synthetic generators
    /// ([`crate::data::GeneratorSource`], the default) or an
    /// out-of-core [`crate::data::StoreSource`] whose cache/prefetch
    /// bookkeeping lands in this worker's round counters.
    pub source: Arc<dyn UserDataSource>,
    pub algorithm: Arc<dyn FederatedAlgorithm>,
    pub postprocessors: Arc<Vec<Box<dyn Postprocessor>>>,
    pub aggregator: Arc<dyn Aggregator>,
    pub factory: ModelFactory,
    pub profile: OverheadProfile,
    pub seed: u64,
    /// Use the model's L1 HLO clip kernel (paper-faithful on-device path)
    /// instead of the native Rust clip. See `RunParams::clip_backend`.
    pub use_hlo_clip: bool,
    /// Accumulation-arena tuning (sparse spill threshold); each worker
    /// builds its resident [`StatsArena`] from this.
    pub arena: crate::tensor::ArenaConfig,
    /// Counter noise engine setting (`RunParams::noise_threads`). On the
    /// worker path N ≥ 1 selects the counter engine but runs it on the
    /// worker's own thread (no nested parallelism; the counter output is
    /// bit-identical for any thread count anyway).
    pub noise_threads: usize,
    /// Device-realism scenario (speed tiers, diurnal availability,
    /// mid-round dropout hazard — DESIGN.md §8). Disabled by default;
    /// every predicate is a pure function of `(seed, uid, round)`, so
    /// thread and socket workers behave bit-identically.
    pub scenario: crate::fl::device::ScenarioSpec,
}

/// The replica pool: w worker threads plus (baselines only) a coordinator
/// thread emulating explicit client→server topology.
pub struct WorkerPool {
    cmd_txs: Vec<Sender<Cmd>>,
    res_rx: Receiver<RoundResult>,
    handles: Vec<JoinHandle<()>>,
    coordinator: Option<CoordinatorHandle>,
    pub num_workers: usize,
}

struct CoordinatorHandle {
    tx: Sender<CoordMsg>,
    handle: JoinHandle<()>,
    msgs: Arc<AtomicU64>,
    bytes: Arc<AtomicU64>,
}

enum CoordMsg {
    /// A serialized per-user update routed through the "server".
    Update(Vec<u8>),
    Stop,
}

impl WorkerPool {
    pub fn new(num_workers: usize, shared: WorkerShared) -> Result<Self> {
        let num_workers = num_workers.max(1);
        let (res_tx, res_rx) = channel::<RoundResult>();
        let shared = Arc::new(shared);

        // Topology-emulating coordinator (baselines only): deserializes
        // every message like the frameworks that simulate FL topology do.
        let coordinator = if shared.profile.coordinator {
            let (ctx, crx) = channel::<CoordMsg>();
            let msgs = Arc::new(AtomicU64::new(0));
            let bytes = Arc::new(AtomicU64::new(0));
            let (m2, b2) = (msgs.clone(), bytes.clone());
            let handle = std::thread::Builder::new()
                .name("coordinator".into())
                .spawn(move || coordinator_loop(crx, m2, b2))
                .context("spawning coordinator")?;
            Some(CoordinatorHandle { tx: ctx, handle, msgs, bytes })
        } else {
            None
        };

        let mut cmd_txs = Vec::with_capacity(num_workers);
        let mut handles = Vec::with_capacity(num_workers);
        for w in 0..num_workers {
            let (tx, rx) = channel::<Cmd>();
            cmd_txs.push(tx);
            let res_tx = res_tx.clone();
            let shared = shared.clone();
            let coord_tx = coordinator.as_ref().map(|c| c.tx.clone());
            let handle = std::thread::Builder::new()
                .name(format!("worker-{w}"))
                .spawn(move || {
                    // A panic in algorithm/model code must not wedge the
                    // backend waiting on a result that will never come:
                    // surface it as an error result (failing the round
                    // with a diagnostic), then re-raise so join_all can
                    // report the typed [`WorkerPanic`].
                    let guard_tx = res_tx.clone();
                    let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        worker_loop(w, rx, res_tx, shared, coord_tx)
                    }));
                    if let Err(payload) = caught {
                        let _ = guard_tx.send(RoundResult {
                            worker: w,
                            round: 0,
                            seq: 0,
                            partial: None,
                            metrics: Metrics::new(),
                            counters: Counters::default(),
                            costs: Vec::new(),
                            error: Some(format!(
                                "worker panicked: {}",
                                panic_message(payload.as_ref())
                            )),
                        });
                        std::panic::resume_unwind(payload);
                    }
                })
                .with_context(|| format!("spawning worker {w}"))?;
            handles.push(handle);
        }

        Ok(WorkerPool { cmd_txs, res_rx, handles, coordinator, num_workers })
    }

    /// Run one (context, cohort) round: hand each worker its
    /// [`WorkSource`] (a [`crate::fl::dispatch::DispatchPlan`]'s
    /// sources), wait for every worker, return the per-worker results in
    /// worker order — the barrier used by Static and WorkStealing modes.
    pub fn run_round(
        &self,
        ctx: &CentralContext,
        central: Arc<Vec<f32>>,
        sources: Vec<WorkSource>,
    ) -> Result<Vec<RoundResult>> {
        assert_eq!(sources.len(), self.num_workers);
        for (tx, work) in self.cmd_txs.iter().zip(sources) {
            tx.send(Cmd::Round { ctx: ctx.clone(), central: central.clone(), work, seq: 0 })
                .map_err(|_| anyhow!("worker channel closed"))?;
        }
        let mut results: Vec<Option<RoundResult>> = (0..self.num_workers).map(|_| None).collect();
        for _ in 0..self.num_workers {
            let r = self.res_rx.recv().context("worker result channel closed")?;
            let w = r.worker;
            results[w] = Some(r);
        }
        let out: Vec<RoundResult> = results.into_iter().map(|r| r.unwrap()).collect();
        if let Some(r) = out.iter().find(|r| r.error.is_some()) {
            return Err(anyhow!("worker {} failed: {}", r.worker, r.error.clone().unwrap()));
        }
        Ok(out)
    }

    /// Dispatch a single user to one worker without waiting (async mode).
    /// Exactly one [`RoundResult`] will later arrive via
    /// [`Self::recv_result`] for every dispatched command, echoing
    /// `seq` (the replay engine's fold-order key; pass 0 when arrival
    /// order is allowed to be physical). Commands queue on the worker's
    /// channel and execute FIFO, so more commands than workers is fine.
    pub fn send_user(
        &self,
        worker: usize,
        ctx: &CentralContext,
        central: Arc<Vec<f32>>,
        uid: usize,
        seq: u64,
    ) -> Result<()> {
        self.cmd_txs[worker]
            .send(Cmd::Round {
                ctx: ctx.clone(),
                central,
                work: WorkSource::Owned(vec![uid]),
                seq,
            })
            .map_err(|_| anyhow!("worker channel closed"))
    }

    /// Block until the next worker result arrives (async mode).
    pub fn recv_result(&self) -> Result<RoundResult> {
        self.res_rx.recv().context("worker result channel closed")
    }

    /// Coordinator message/byte counters (baselines diagnostics).
    pub fn coordinator_traffic(&self) -> (u64, u64) {
        match &self.coordinator {
            Some(c) => (c.msgs.load(Ordering::Relaxed), c.bytes.load(Ordering::Relaxed)),
            None => (0, 0),
        }
    }

    /// Stop every worker (and the coordinator) and join their threads.
    /// Idempotent: the explicit [`Self::shutdown`] and the `Drop` both
    /// funnel here. A worker thread that died by panic surfaces as a
    /// typed [`WorkerPanic`] error (the first one, if several).
    fn join_all(&mut self) -> Result<()> {
        for tx in &self.cmd_txs {
            let _ = tx.send(Cmd::Stop);
        }
        let mut first: Option<WorkerPanic> = None;
        for (w, h) in self.handles.drain(..).enumerate() {
            if let Err(payload) = h.join() {
                let p = WorkerPanic { worker: w, message: panic_message(payload.as_ref()) };
                if first.is_none() {
                    first = Some(p);
                }
            }
        }
        if let Some(c) = self.coordinator.take() {
            let _ = c.tx.send(CoordMsg::Stop);
            let _ = c.handle.join();
        }
        match first {
            Some(p) => Err(p.into()),
            None => Ok(()),
        }
    }

    /// Join the pool, surfacing worker panics as a typed error instead
    /// of swallowing them (a run that looked clean but lost a worker is
    /// not clean).
    pub fn shutdown(mut self) -> Result<()> {
        self.join_all()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // shutdown() already drained the handles; a panic surfaced there.
        // On the plain-drop path there is no caller to hand the error to.
        let _ = self.join_all();
    }
}

/// A worker thread died by panic. [`WorkerPool::shutdown`] returns this
/// (via `anyhow`) so the run fails with a diagnostic naming the worker
/// instead of hanging on a result that will never arrive or silently
/// losing the replica.
#[derive(Debug)]
pub struct WorkerPanic {
    pub worker: usize,
    pub message: String,
}

impl std::fmt::Display for WorkerPanic {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "worker {} panicked: {}", self.worker, self.message)
    }
}

impl std::error::Error for WorkerPanic {}

/// Best-effort extraction of a panic payload's message (`panic!` with a
/// literal yields `&str`, with a format string `String`).
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

fn coordinator_loop(rx: Receiver<CoordMsg>, msgs: Arc<AtomicU64>, bytes: Arc<AtomicU64>) {
    // The coordinator deserializes every update (the cost the paper's
    // design deliberately avoids) and drops it — aggregation correctness
    // still comes from the worker partials, so the emulation adds the
    // topology's *cost* without forking its numerics.
    while let Ok(msg) = rx.recv() {
        match msg {
            CoordMsg::Update(buf) => {
                let mut checksum = 0f32;
                for chunk in buf.chunks_exact(4) {
                    checksum += f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
                }
                std::hint::black_box(checksum);
                msgs.fetch_add(1, Ordering::Relaxed);
                bytes.fetch_add(buf.len() as u64, Ordering::Relaxed);
            }
            CoordMsg::Stop => break,
        }
    }
}

fn worker_loop(
    id: usize,
    rx: Receiver<Cmd>,
    res_tx: Sender<RoundResult>,
    shared: Arc<WorkerShared>,
    coord_tx: Option<Sender<CoordMsg>>,
) {
    // Build this replica's model here: one model per worker, alive for
    // the whole simulation (paper §3 item 1).
    let mut model: Option<Box<dyn Model>> = None;
    // Worker-local accumulation arena, resident for the whole simulation
    // so steady-state rounds fold user statistics with zero allocation.
    let mut arena = StatsArena::with_config(shared.arena);

    while let Ok(cmd) = rx.recv() {
        match cmd {
            Cmd::Stop => break,
            Cmd::Round { ctx, central, work, seq } => {
                if model.is_none() {
                    match (shared.factory)(id) {
                        Ok(m) => model = Some(m),
                        Err(e) => {
                            let _ = res_tx.send(RoundResult {
                                worker: id,
                                round: ctx.iteration,
                                seq,
                                partial: None,
                                metrics: Metrics::new(),
                                counters: Counters::default(),
                                costs: Vec::new(),
                                error: Some(format!("model factory: {e:#}")),
                            });
                            continue;
                        }
                    }
                }
                let result = run_worker_round(
                    id,
                    model.as_deref_mut().unwrap(),
                    &shared,
                    &ctx,
                    &central,
                    work,
                    seq,
                    &mut arena,
                    coord_tx.as_ref(),
                );
                let result = match result {
                    Ok(r) => r,
                    Err(e) => RoundResult {
                        worker: id,
                        round: ctx.iteration,
                        seq,
                        partial: None,
                        metrics: Metrics::new(),
                        counters: Counters::default(),
                        costs: Vec::new(),
                        error: Some(format!("{e:#}")),
                    },
                };
                if res_tx.send(result).is_err() {
                    break;
                }
            }
        }
    }
}

/// Busy-wait for `ns` nanoseconds (emulates interpreter/dispatch tax in
/// the baseline profiles; sleeping would under-represent CPU contention).
fn spin_ns(ns: u64) {
    if ns == 0 {
        return;
    }
    let start = Instant::now();
    while (start.elapsed().as_nanos() as u64) < ns {
        std::hint::spin_loop();
    }
}

#[allow(clippy::too_many_arguments)]
fn run_worker_round(
    id: usize,
    model: &mut dyn Model,
    shared: &WorkerShared,
    ctx: &CentralContext,
    central: &[f32],
    work: WorkSource,
    seq: u64,
    arena: &mut StatsArena,
    coord_tx: Option<&Sender<CoordMsg>>,
) -> Result<RoundResult> {
    let mut counters = Counters::default();
    let mut metrics = Metrics::new();
    let mut costs = Vec::with_capacity(work.len_hint());
    let mut partial: Option<Statistics> = None;
    // Plain-sum aggregators fold into the resident arena buffers by
    // reference (no per-user move/insert); others keep the generic path.
    let use_arena = shared.aggregator.arena_compatible();
    // Re-arm defensively: a previous round that erred out mid-loop may
    // have left folded state — and undrained spill/sparse counts —
    // behind (normal rounds reset on take_partial and drain at round
    // end, so these discards are no-ops in normal flow).
    arena.reset();
    arena.drain_spill_count();
    arena.drain_sparse_rounds();
    let profile = &shared.profile;

    let busy0 = model.busy_nanos();
    model.set_central(central);

    // Owned sources iterate the precomputed queue; shared sources claim
    // the next user from the cohort-wide pull queue on every step.
    for uid in work.into_pull() {
        // Mid-round hazard dropout (DESIGN.md §8): the device dies after
        // being dispatched, so its partial is discarded and never folded
        // — unlike transport death, which requeues the uid at its
        // original seq. The draw is a pure function of (seed, uid,
        // round), never of which worker ran it or when, so thread and
        // socket transports drop the exact same users.
        if shared.scenario.enabled()
            && ctx.is_train()
            && shared.scenario.drops_out(shared.seed, uid, ctx.iteration)
        {
            counters.dropout_users += 1;
            continue;
        }
        let t0 = Instant::now();
        let dev0 = model.busy_nanos();

        if profile.realloc_per_user {
            // Flower/FedML-style: re-materialize model-sized tensors for
            // every client instead of reusing the resident model.
            let fresh: Vec<f32> = central.to_vec();
            counters.loop_alloc_bytes += (fresh.len() * 4) as u64;
            std::hint::black_box(&fresh);
            model.set_central(&fresh);
            drop(fresh);
        }
        spin_ns(profile.per_user_overhead_ns);

        // User data arrives through the source: generated on the spot
        // (lazy synth), or pulled from the store cache — where a miss
        // means the prefetcher lost the race and the worker pays the
        // read, recorded as prefetch stall.
        let fetched = shared.source.fetch(uid);
        match fetched.cache_hit {
            Some(true) => counters.cache_hits += 1,
            Some(false) => counters.cache_misses += 1,
            None => {}
        }
        counters.prefetch_stall_nanos += fetched.stall_nanos;
        counters.store_bytes_read += fetched.bytes_read;
        counters.decode_nanos += fetched.decode_nanos;
        if fetched.via_mmap {
            counters.mmap_stall_nanos += fetched.stall_nanos;
        } else {
            counters.pread_stall_nanos += fetched.stall_nanos;
        }
        let data = fetched.data;
        let user_len = data.len();
        let (stats, m) = shared
            .algorithm
            .simulate_one_user(model, uid, &data, ctx)
            .with_context(|| format!("user {uid}"))?;
        metrics.merge(&m);
        counters.users_trained += 1;
        counters.steps += m.get("train/steps").map(|s| s as u64).unwrap_or(0);
        if profile.per_step_overhead_ns > 0 {
            spin_ns(profile.per_step_overhead_ns * m.get("train/steps").unwrap_or(0.0) as u64);
        }

        if let Some(mut stats) = stats {
            // per-user postprocessors (DP clipping through the model's L1
            // kernel when it has one)
            let rust_clip = RustClip;
            {
                let clip = if shared.use_hlo_clip {
                    model.clip_kernel().unwrap_or(&rust_clip)
                } else {
                    &rust_clip as &dyn crate::fl::model::ClipKernel
                };
                // The postprocessor RNG (local-DP noise) is derived from
                // (run seed, context seed, uid) — NOT from a worker-thread
                // stream — so which worker claims a user (pull-based
                // dispatch is a thread race) never changes the statistics
                // and runs stay seed-reproducible under every dispatcher.
                let mut user_rng = Rng::seed_from_u64(
                    shared
                        .seed
                        .wrapping_mul(0x9E37_79B9_7F4A_7C15)
                        ^ ctx.seed.rotate_left(17)
                        ^ (uid as u64).wrapping_mul(0xD1B5_4A32_D192_ED03),
                );
                let mut env = PpEnv {
                    clip,
                    rng: &mut user_rng,
                    user_len,
                    uid,
                    // counter streams on the worker path key off the run
                    // seed too (mechanisms salt them per uid); cap the
                    // engine to one thread here — each user already runs
                    // on its own worker, and counter output is
                    // bit-identical for any thread count
                    noise_key: shared.seed,
                    noise_threads: shared.noise_threads.min(1),
                    noise_nanos: 0,
                };
                for pp in shared.postprocessors.iter() {
                    let pm = pp.postprocess_one_user(&mut stats, ctx, &mut env)?;
                    metrics.merge(&pm);
                }
                counters.noise_nanos += env.noise_nanos;
            }

            if profile.cpu_roundtrip {
                // NumPy-outer-loop emulation: bounce the update through a
                // host staging buffer (device→host→device copies).
                for v in stats.vecs.values_mut() {
                    let vals = v.values_mut();
                    let staged = vals.clone();
                    counters.copy_bytes += (staged.len() * 4) as u64 * 2;
                    vals.copy_from_slice(&staged);
                }
            }
            if let Some(tx) = coord_tx {
                // explicit topology: serialize and route via coordinator,
                // using the comms wire codec — the exact payload a socket
                // worker would ship (one serialization path for both the
                // emulated and the real transport, DESIGN.md §7)
                for v in stats.vecs.values() {
                    let mut buf = Vec::with_capacity(v.wire_bytes());
                    crate::comms::codec::encode_stat_value(&mut buf, v);
                    counters.wire_bytes += buf.len() as u64;
                    counters.coordinator_msgs += 1;
                    let _ = tx.send(CoordMsg::Update(buf));
                }
            }

            // user→server communication volume, after all local
            // postprocessing (so sparsification and wire quantization
            // are reflected); sparse values count idx + val, matching
            // the wire serialization; bytes account for the stored width
            counters.stat_elements += stats.wire_elements() as u64;
            counters.stat_bytes += stats.wire_bytes() as u64;

            if use_arena {
                arena.fold(&stats);
            } else {
                shared.aggregator.accumulate(&mut partial, stats);
            }
        }

        let mut nanos = t0.elapsed().as_nanos() as u64;
        let mut device_nanos = model.busy_nanos() - dev0;
        if shared.scenario.enabled() {
            // Speed tiers stretch the measured wall-clock before it
            // feeds the LPT/work-steal/replay cost models; the disabled
            // path leaves the measurement untouched.
            let speed = shared.scenario.speed_multiplier(shared.seed, uid);
            nanos = (nanos as f64 * speed) as u64;
            device_nanos = (device_nanos as f64 * speed) as u64;
        }
        costs.push(UserCost {
            datapoints: user_len,
            nanos,
            device_nanos,
        });
    }

    counters.arena_grow_bytes = arena.drain_grown_bytes();
    if use_arena {
        partial = arena.take_partial();
    }
    // drained after take_partial: the sparse-round classification happens
    // when the partial is emitted
    counters.arena_spill_count = arena.drain_spill_count();
    counters.arena_sparse_rounds = arena.drain_sparse_rounds();
    counters.busy_nanos = model.busy_nanos() - busy0;
    Ok(RoundResult {
        worker: id,
        round: ctx.iteration,
        seq,
        partial,
        metrics,
        counters,
        costs,
        error: None,
    })
}

/// The socket-fed worker driver (`pfl worker --connect ADDR`): the same
/// transport-independent round execution as [`worker_loop`], but driven
/// by wire frames from a [`crate::comms::WorkerConn`] instead of an
/// in-process channel (DESIGN.md §7). Runs until the server sends STOP
/// or closes the connection; transport errors propagate so the process
/// exits non-zero and the server's dead-worker detection requeues its
/// in-flight users.
pub fn run_socket_worker(
    mut conn: crate::comms::WorkerConn,
    shared: Arc<WorkerShared>,
) -> Result<()> {
    let id = conn.setup.worker;
    // One model + one resident arena per worker process, alive for the
    // whole simulation — identical to the thread replica.
    let mut model: Option<Box<dyn Model>> = None;
    let mut arena = StatsArena::with_config(shared.arena);
    while let Some(msg) = conn.recv()? {
        let crate::comms::codec::RoundMsg { seq, ctx, central, uids } = msg;
        if model.is_none() {
            match (shared.factory)(id) {
                Ok(m) => model = Some(m),
                Err(e) => {
                    conn.send_result(&RoundResult {
                        worker: id,
                        round: ctx.iteration,
                        seq,
                        partial: None,
                        metrics: Metrics::new(),
                        counters: Counters::default(),
                        costs: Vec::new(),
                        error: Some(format!("model factory: {e:#}")),
                    })?;
                    continue;
                }
            }
        }
        let result = run_worker_round(
            id,
            model.as_deref_mut().unwrap(),
            &shared,
            &ctx,
            &central,
            WorkSource::Owned(uids),
            seq,
            &mut arena,
            None,
        );
        let result = match result {
            Ok(r) => r,
            Err(e) => RoundResult {
                worker: id,
                round: ctx.iteration,
                seq,
                partial: None,
                metrics: Metrics::new(),
                counters: Counters::default(),
                costs: Vec::new(),
                error: Some(format!("{e:#}")),
            },
        };
        conn.send_result(&result)?;
    }
    Ok(())
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::data::{FederatedDataset, UserData};
    use crate::fl::algorithm::RunSpec;
    use crate::fl::central_opt::Sgd;
    use crate::fl::FedAvg;

    /// A trivial linear model trained in pure Rust: params = mean of user
    /// targets (delta = central − mean). Lets worker/backend tests run
    /// without PJRT.
    pub struct MeanModel {
        central: Vec<f32>,
    }

    impl MeanModel {
        pub fn new(dim: usize) -> Self {
            MeanModel { central: vec![0.0; dim] }
        }
    }

    impl Model for MeanModel {
        fn param_count(&self) -> usize {
            self.central.len()
        }
        fn set_central(&mut self, central: &[f32]) {
            self.central.copy_from_slice(central);
        }
        fn central(&self) -> &[f32] {
            &self.central
        }
        fn train_local(
            &mut self,
            data: &UserData,
            p: &crate::fl::context::LocalParams,
            _c_diff: Option<&[f32]>,
            _seed: u64,
        ) -> Result<super::super::model::TrainOutput> {
            let (x, dim) = match data {
                UserData::Points { x, dim } => (x, *dim),
                _ => anyhow::bail!("MeanModel wants Points"),
            };
            let n = x.len() / dim;
            let mut mean = vec![0.0f32; dim];
            for row in x.chunks(dim) {
                crate::util::add_assign(&mut mean, row);
            }
            crate::util::scale(&mut mean, 1.0 / n.max(1) as f32);
            // gradient step toward the mean: delta = lr * (central − mean)
            let mut delta = vec![0.0f32; dim];
            crate::util::sub_into(&mut delta, &self.central, &mean);
            crate::util::scale(&mut delta, p.lr);
            let loss: f64 = (0..dim).map(|i| ((self.central[i] - mean[i]) as f64).powi(2)).sum();
            Ok(super::super::model::TrainOutput {
                update: delta,
                loss_sum: loss * n as f64,
                stat_sum: 0.0,
                wsum: n as f64,
                steps: 1,
            })
        }
        fn evaluate(
            &mut self,
            data: &UserData,
            _sink: Option<&mut super::super::model::ScoreSink>,
        ) -> Result<Metrics> {
            let mut m = Metrics::new();
            let (x, dim) = match data {
                UserData::Points { x, dim } => (x, *dim),
                _ => anyhow::bail!("MeanModel wants Points"),
            };
            let n = x.len() / dim;
            let mut loss = 0f64;
            for row in x.chunks(dim) {
                for (c, v) in self.central.iter().zip(row) {
                    loss += ((c - v) as f64).powi(2);
                }
            }
            m.add_central("loss", loss, n as f64);
            Ok(m)
        }
        fn name(&self) -> &str {
            "mean"
        }
    }

    /// Wrap precomputed per-worker queues as owned work sources.
    pub fn owned(assignments: Vec<Vec<usize>>) -> Vec<WorkSource> {
        assignments.into_iter().map(WorkSource::Owned).collect()
    }

    pub fn mean_pool(workers: usize, dim: usize, dataset: Arc<dyn FederatedDataset>) -> WorkerPool {
        let spec = RunSpec { iterations: 10, cohort_size: 8, ..Default::default() };
        let shared = WorkerShared {
            source: Arc::new(crate::data::GeneratorSource::new(dataset)),
            algorithm: Arc::new(FedAvg::new(spec, Box::new(Sgd))),
            postprocessors: Arc::new(Vec::new()),
            aggregator: Arc::new(crate::fl::SumAggregator),
            factory: Arc::new(move |_| Ok(Box::new(MeanModel::new(dim)) as Box<dyn Model>)),
            profile: OverheadProfile::default(),
            seed: 0,
            use_hlo_clip: false,
            arena: crate::tensor::ArenaConfig::default(),
            noise_threads: 0,
            scenario: Default::default(),
        };
        WorkerPool::new(workers, shared).unwrap()
    }

    #[test]
    fn pool_round_trains_all_users_once() {
        let data = Arc::new(crate::data::SynthGmmPoints::new(16, 10, 3, 2, 0));
        let pool = mean_pool(3, 3, data);
        let ctx = CentralContext::train(0, 9, Default::default(), 1);
        let central = Arc::new(vec![0.0f32; 3]);
        let assignments = owned(vec![vec![0, 1, 2], vec![3, 4, 5], vec![6, 7, 8]]);
        let results = pool.run_round(&ctx, central, assignments).unwrap();
        assert_eq!(results.len(), 3);
        let total: u64 = results.iter().map(|r| r.counters.users_trained).sum();
        assert_eq!(total, 9);
        for r in &results {
            assert!(r.partial.is_some());
            assert_eq!(r.costs.len(), 3);
            assert_eq!(r.round, 0);
        }
        pool.shutdown().unwrap();
    }

    #[test]
    fn pool_empty_assignment_is_ok() {
        let data = Arc::new(crate::data::SynthGmmPoints::new(4, 10, 2, 2, 0));
        let pool = mean_pool(2, 2, data);
        let ctx = CentralContext::train(0, 2, Default::default(), 1);
        let results = pool
            .run_round(&ctx, Arc::new(vec![0.0; 2]), owned(vec![vec![0, 1], vec![]]))
            .unwrap();
        assert!(results[1].partial.is_none());
        assert_eq!(results[1].counters.users_trained, 0);
    }

    #[test]
    fn pool_shared_queue_trains_all_users_once() {
        use crate::fl::dispatch::CohortQueue;
        let data = Arc::new(crate::data::SynthGmmPoints::new(9, 10, 3, 2, 0));
        let pool = mean_pool(3, 3, data);
        let ctx = CentralContext::train(0, 9, Default::default(), 1);
        let q = Arc::new(CohortQueue::new((0..9).collect()));
        let sources = (0..3).map(|_| WorkSource::Shared(q.clone())).collect();
        let results = pool.run_round(&ctx, Arc::new(vec![0.0; 3]), sources).unwrap();
        let total: u64 = results.iter().map(|r| r.counters.users_trained).sum();
        assert_eq!(total, 9, "shared queue must hand out each user exactly once");
        assert_eq!(q.pop(), None);
        pool.shutdown().unwrap();
    }

    #[test]
    fn pool_single_user_dispatch_streams_results() {
        let data = Arc::new(crate::data::SynthGmmPoints::new(4, 10, 2, 2, 0));
        let pool = mean_pool(2, 2, data);
        let ctx = CentralContext::train(3, 4, Default::default(), 1);
        let central = Arc::new(vec![0.0f32; 2]);
        pool.send_user(0, &ctx, central.clone(), 0, 7).unwrap();
        pool.send_user(1, &ctx, central, 1, 8).unwrap();
        let (a, b) = (pool.recv_result().unwrap(), pool.recv_result().unwrap());
        for r in [&a, &b] {
            assert_eq!(r.round, 3);
            assert_eq!(r.counters.users_trained, 1);
            assert!(r.partial.is_some());
        }
        assert_ne!(a.worker, b.worker);
        // the dispatch sequence number is echoed for replay ordering
        let mut seqs = [a.seq, b.seq];
        seqs.sort();
        assert_eq!(seqs, [7, 8]);
        pool.shutdown().unwrap();
    }

    #[test]
    fn pool_result_independent_of_worker_count() {
        // replica workers + exchange-law aggregation => scheduling must
        // not change the reduced statistics (the paper's correctness
        // argument for ignoring topology).
        let data: Arc<dyn FederatedDataset> =
            Arc::new(crate::data::SynthGmmPoints::new(12, 10, 2, 2, 3));
        let ctx = CentralContext::train(0, 12, Default::default(), 1);
        let agg = crate::fl::SumAggregator;

        let mut reduced = Vec::new();
        for (w, chunks) in [
            (1usize, vec![(0..12).collect::<Vec<_>>()]),
            (3, vec![vec![0, 3, 6, 9], vec![1, 4, 7, 10], vec![2, 5, 8, 11]]),
        ] {
            let pool = mean_pool(w, 2, data.clone());
            let results = pool
                .run_round(&ctx, Arc::new(vec![0.0; 2]), owned(chunks))
                .unwrap();
            let partials: Vec<Statistics> =
                results.into_iter().filter_map(|r| r.partial).collect();
            reduced.push(agg.worker_reduce(partials).unwrap());
            pool.shutdown().unwrap();
        }
        let a = &reduced[0];
        let b = &reduced[1];
        assert_eq!(a.weight, b.weight);
        for (x, y) in a.update().iter().zip(b.update()) {
            assert!((x - y).abs() < 1e-5, "{x} vs {y}");
        }
    }

    #[test]
    fn overhead_profile_counters_tick() {
        let data = Arc::new(crate::data::SynthGmmPoints::new(4, 10, 2, 2, 0));
        let spec = RunSpec { iterations: 10, cohort_size: 4, ..Default::default() };
        let shared = WorkerShared {
            source: Arc::new(crate::data::GeneratorSource::new(data)),
            algorithm: Arc::new(FedAvg::new(spec, Box::new(Sgd))),
            postprocessors: Arc::new(Vec::new()),
            aggregator: Arc::new(crate::fl::SumAggregator),
            factory: Arc::new(|_| Ok(Box::new(MeanModel::new(2)) as Box<dyn Model>)),
            profile: OverheadProfile {
                realloc_per_user: true,
                cpu_roundtrip: true,
                coordinator: true,
                ..Default::default()
            },
            seed: 0,
            use_hlo_clip: false,
            arena: crate::tensor::ArenaConfig::default(),
            noise_threads: 0,
            scenario: Default::default(),
        };
        let pool = WorkerPool::new(2, shared).unwrap();
        let ctx = CentralContext::train(0, 4, Default::default(), 1);
        let results = pool
            .run_round(&ctx, Arc::new(vec![0.0; 2]), owned(vec![vec![0, 1], vec![2, 3]]))
            .unwrap();
        let mut c = Counters::default();
        for r in &results {
            c.merge(&r.counters);
        }
        assert!(c.loop_alloc_bytes > 0);
        assert!(c.copy_bytes > 0);
        assert!(c.wire_bytes > 0);
        assert_eq!(c.coordinator_msgs, 4);
        // 4 users × 2-dim dense update
        assert_eq!(c.stat_elements, 8);
        // same update in bytes: 8 f32 elements × 4 bytes
        assert_eq!(c.stat_bytes, 32);
        pool.shutdown().unwrap();
    }

    /// A model whose local training panics — stands in for a bug in
    /// algorithm/model code (as opposed to an `Err`, which the worker
    /// already converts into an error result).
    struct PanicModel {
        central: Vec<f32>,
    }

    impl Model for PanicModel {
        fn param_count(&self) -> usize {
            self.central.len()
        }
        fn set_central(&mut self, central: &[f32]) {
            self.central.copy_from_slice(central);
        }
        fn central(&self) -> &[f32] {
            &self.central
        }
        fn train_local(
            &mut self,
            _data: &UserData,
            _p: &crate::fl::context::LocalParams,
            _c_diff: Option<&[f32]>,
            _seed: u64,
        ) -> Result<super::super::model::TrainOutput> {
            panic!("injected local-training bug");
        }
        fn evaluate(
            &mut self,
            _data: &UserData,
            _sink: Option<&mut super::super::model::ScoreSink>,
        ) -> Result<Metrics> {
            panic!("injected local-training bug");
        }
        fn name(&self) -> &str {
            "panic"
        }
    }

    #[test]
    fn panicking_worker_fails_the_run_with_a_diagnostic() {
        let data: Arc<dyn FederatedDataset> =
            Arc::new(crate::data::SynthGmmPoints::new(4, 10, 2, 2, 0));
        let spec = RunSpec { iterations: 10, cohort_size: 4, ..Default::default() };
        let shared = WorkerShared {
            source: Arc::new(crate::data::GeneratorSource::new(data)),
            algorithm: Arc::new(FedAvg::new(spec, Box::new(Sgd))),
            postprocessors: Arc::new(Vec::new()),
            aggregator: Arc::new(crate::fl::SumAggregator),
            // worker 0 is healthy; worker 1 panics on its first user
            factory: Arc::new(|w| {
                Ok(if w == 0 {
                    Box::new(MeanModel::new(2)) as Box<dyn Model>
                } else {
                    Box::new(PanicModel { central: vec![0.0; 2] }) as Box<dyn Model>
                })
            }),
            profile: OverheadProfile::default(),
            seed: 0,
            use_hlo_clip: false,
            arena: crate::tensor::ArenaConfig::default(),
            noise_threads: 0,
            scenario: Default::default(),
        };
        let pool = WorkerPool::new(2, shared).unwrap();
        let ctx = CentralContext::train(0, 4, Default::default(), 1);
        // the round fails with a diagnostic instead of hanging on a
        // result that will never arrive (or aborting the process)
        let err = pool
            .run_round(&ctx, Arc::new(vec![0.0; 2]), owned(vec![vec![0, 1], vec![2, 3]]))
            .unwrap_err();
        assert!(err.to_string().contains("panicked"), "unexpected error: {err:#}");
        // the join surfaces the typed panic error too
        let err = pool.shutdown().unwrap_err();
        let panic = err.downcast_ref::<WorkerPanic>().expect("typed WorkerPanic");
        assert_eq!(panic.worker, 1);
        assert!(panic.message.contains("injected local-training bug"));
    }
}
