//! Training-process callbacks (paper App. B.1 "Callback"): hooks into the
//! central loop, invoked after the central model has been updated. A
//! callback never alters learning; it evaluates, reports, checkpoints or
//! stops.

use std::io::Write;
use std::path::PathBuf;

use anyhow::{Context, Result};

use super::metrics::{mean_average_precision, Metrics};
use super::model::{Model, ScoreSink};
use crate::data::UserData;

pub trait Callback {
    /// Called after every central iteration; return `true` to stop
    /// training (early stopping, time budget...).
    fn after_central_iteration(
        &mut self,
        central: &[f32],
        t: u64,
        metrics: &mut Metrics,
    ) -> Result<bool>;

    fn on_train_end(&mut self, _central: &[f32]) -> Result<()> {
        Ok(())
    }
}

/// Central evaluation on held-out shards (paper §4.3: "evaluation is done
/// on the validation data partitions ... without any federated splits").
/// Owns its own model instance — the analogue of the evaluation happening
/// on the worker's resident model without re-allocation.
pub struct CentralEvalCallback {
    model: Box<dyn Model>,
    shards: Vec<UserData>,
    pub every: u64,
    /// "accuracy" | "perplexity" | "map": how `stat`/`loss` become the
    /// headline benchmark metric.
    pub headline: &'static str,
}

impl CentralEvalCallback {
    pub fn new(
        model: Box<dyn Model>,
        shards: Vec<UserData>,
        every: u64,
        headline: &'static str,
    ) -> Self {
        CentralEvalCallback { model, shards, every: every.max(1), headline }
    }

    /// Evaluate `central` over all shards; returns the metric bag.
    pub fn evaluate(&mut self, central: &[f32]) -> Result<Metrics> {
        self.model.set_central(central);
        let mut agg = Metrics::new();
        let mut sink = ScoreSink::default();
        let want_scores = self.headline == "map";
        for shard in &self.shards {
            let m = self
                .model
                .evaluate(shard, if want_scores { Some(&mut sink) } else { None })?;
            agg.merge(&m);
        }
        let mut out = Metrics::new();
        let loss = agg.get("loss").unwrap_or(f64::NAN);
        out.add_central("centraleval/loss", loss, 1.0);
        match self.headline {
            "accuracy" => {
                out.add_central("centraleval/accuracy", agg.get("stat").unwrap_or(0.0), 1.0)
            }
            "perplexity" => out.add_central("centraleval/perplexity", loss.exp(), 1.0),
            "map" => {
                let map = mean_average_precision(&sink.scores, &sink.targets, sink.labels);
                out.add_central("centraleval/map", map, 1.0);
            }
            _ => {}
        }
        Ok(out)
    }
}

impl Callback for CentralEvalCallback {
    fn after_central_iteration(
        &mut self,
        central: &[f32],
        t: u64,
        metrics: &mut Metrics,
    ) -> Result<bool> {
        if t % self.every == 0 {
            let m = self.evaluate(central)?;
            metrics.merge(&m);
        }
        Ok(false)
    }

    fn on_train_end(&mut self, _central: &[f32]) -> Result<()> {
        Ok(())
    }
}

/// Stop when a metric stops improving (paper's "stopping criterion"
/// callback).
pub struct EarlyStopping {
    pub metric: String,
    /// `true` if larger is better.
    pub maximize: bool,
    pub patience: u64,
    pub min_delta: f64,
    best: Option<f64>,
    since_best: u64,
}

impl EarlyStopping {
    pub fn new(metric: impl Into<String>, maximize: bool, patience: u64) -> Self {
        EarlyStopping {
            metric: metric.into(),
            maximize,
            patience,
            min_delta: 0.0,
            best: None,
            since_best: 0,
        }
    }
}

impl Callback for EarlyStopping {
    fn after_central_iteration(
        &mut self,
        _central: &[f32],
        _t: u64,
        metrics: &mut Metrics,
    ) -> Result<bool> {
        let Some(v) = metrics.get(&self.metric) else {
            return Ok(false); // metric not reported this round
        };
        let improved = match self.best {
            None => true,
            Some(b) => {
                if self.maximize {
                    v > b + self.min_delta
                } else {
                    v < b - self.min_delta
                }
            }
        };
        if improved {
            self.best = Some(v);
            self.since_best = 0;
        } else {
            self.since_best += 1;
        }
        Ok(self.since_best > self.patience)
    }
}

/// Exponential moving average of the central model (paper's "exponential
/// moving average of model" callback). `ema()` exposes the shadow weights
/// for evaluation.
pub struct EmaCallback {
    pub decay: f32,
    ema: Vec<f32>,
}

impl EmaCallback {
    pub fn new(decay: f32) -> Self {
        EmaCallback { decay, ema: Vec::new() }
    }

    pub fn ema(&self) -> &[f32] {
        &self.ema
    }
}

impl Callback for EmaCallback {
    fn after_central_iteration(
        &mut self,
        central: &[f32],
        _t: u64,
        _metrics: &mut Metrics,
    ) -> Result<bool> {
        if self.ema.len() != central.len() {
            self.ema = central.to_vec();
        } else {
            let d = self.decay;
            for (e, c) in self.ema.iter_mut().zip(central) {
                *e = d * *e + (1.0 - d) * c;
            }
        }
        Ok(false)
    }
}

/// Fault-tolerant training (paper's "fault-tolerant training procedure"):
/// checkpoint the central model + iteration every `every` rounds; a new
/// run resumes via [`load_checkpoint`].
pub struct CheckpointCallback {
    pub path: PathBuf,
    pub every: u64,
    last_t: u64,
}

impl CheckpointCallback {
    pub fn new(path: impl Into<PathBuf>, every: u64) -> Self {
        CheckpointCallback { path: path.into(), every: every.max(1), last_t: 0 }
    }

    fn save(&self, central: &[f32], t: u64) -> Result<()> {
        let mut buf = Vec::with_capacity(16 + central.len() * 4);
        buf.extend_from_slice(b"PFLCKPT1");
        buf.extend_from_slice(&t.to_le_bytes());
        buf.extend_from_slice(&(central.len() as u64).to_le_bytes());
        for x in central {
            buf.extend_from_slice(&x.to_le_bytes());
        }
        let tmp = self.path.with_extension("tmp");
        std::fs::write(&tmp, &buf).with_context(|| format!("writing {tmp:?}"))?;
        std::fs::rename(&tmp, &self.path)?;
        Ok(())
    }
}

/// Load a checkpoint written by [`CheckpointCallback`]: (params, next_t).
pub fn load_checkpoint(path: impl Into<PathBuf>) -> Result<(Vec<f32>, u64)> {
    let path = path.into();
    let buf = std::fs::read(&path).with_context(|| format!("reading {path:?}"))?;
    anyhow::ensure!(buf.len() >= 24 && &buf[..8] == b"PFLCKPT1", "bad checkpoint header");
    let t = u64::from_le_bytes(buf[8..16].try_into().unwrap());
    let n = u64::from_le_bytes(buf[16..24].try_into().unwrap()) as usize;
    anyhow::ensure!(buf.len() == 24 + n * 4, "truncated checkpoint");
    let mut params = Vec::with_capacity(n);
    for chunk in buf[24..].chunks_exact(4) {
        params.push(f32::from_le_bytes(chunk.try_into().unwrap()));
    }
    Ok((params, t + 1))
}

impl Callback for CheckpointCallback {
    fn after_central_iteration(
        &mut self,
        central: &[f32],
        t: u64,
        _metrics: &mut Metrics,
    ) -> Result<bool> {
        self.last_t = t;
        if t % self.every == 0 {
            self.save(central, t)?;
        }
        Ok(false)
    }

    fn on_train_end(&mut self, central: &[f32]) -> Result<()> {
        self.save(central, self.last_t)
    }
}

/// CSV metric reporter (paper: "reporting intermediate results (csv
/// files, TensorBoard and Weights & Biases)"). Columns are fixed by the
/// first reported round; later metrics missing a column print empty.
pub struct CsvReporter {
    path: PathBuf,
    columns: Vec<String>,
    rows: Vec<(u64, Vec<Option<f64>>)>,
}

impl CsvReporter {
    pub fn new(path: impl Into<PathBuf>) -> Self {
        CsvReporter { path: path.into(), columns: Vec::new(), rows: Vec::new() }
    }
}

impl Callback for CsvReporter {
    fn after_central_iteration(
        &mut self,
        _central: &[f32],
        t: u64,
        metrics: &mut Metrics,
    ) -> Result<bool> {
        if self.columns.is_empty() {
            self.columns = metrics.names().map(|s| s.to_string()).collect();
        }
        let row = self.columns.iter().map(|c| metrics.get(c)).collect();
        self.rows.push((t, row));
        Ok(false)
    }

    fn on_train_end(&mut self, _central: &[f32]) -> Result<()> {
        let mut f = std::fs::File::create(&self.path)
            .with_context(|| format!("creating {:?}", self.path))?;
        write!(f, "round")?;
        for c in &self.columns {
            write!(f, ",{c}")?;
        }
        writeln!(f)?;
        for (t, row) in &self.rows {
            write!(f, "{t}")?;
            for v in row {
                match v {
                    Some(x) => write!(f, ",{x}")?,
                    None => write!(f, ",")?,
                }
            }
            writeln!(f)?;
        }
        Ok(())
    }
}

/// JSONL metric reporter: one JSON object per central iteration, written
/// incrementally (survives crashes, greppable).
pub struct JsonlReporter {
    file: std::fs::File,
}

impl JsonlReporter {
    pub fn new(path: impl Into<PathBuf>) -> Result<Self> {
        let path = path.into();
        let file = std::fs::File::create(&path).with_context(|| format!("creating {path:?}"))?;
        Ok(JsonlReporter { file })
    }
}

impl Callback for JsonlReporter {
    fn after_central_iteration(
        &mut self,
        _central: &[f32],
        t: u64,
        metrics: &mut Metrics,
    ) -> Result<bool> {
        use crate::util::json::{num, obj, Value};
        let mut pairs: Vec<(&str, Value)> = vec![("round", num(t as f64))];
        let names: Vec<String> = metrics.names().map(|s| s.to_string()).collect();
        for n in &names {
            pairs.push((n.as_str(), num(metrics.get(n).unwrap())));
        }
        writeln!(self.file, "{}", obj(pairs).to_json())?;
        Ok(false)
    }
}

/// Stop after a wall-clock budget (keeps benchmark sweeps bounded).
pub struct TimeBudget {
    deadline: std::time::Instant,
}

impl TimeBudget {
    pub fn new(budget: std::time::Duration) -> Self {
        TimeBudget { deadline: std::time::Instant::now() + budget }
    }
}

impl Callback for TimeBudget {
    fn after_central_iteration(
        &mut self,
        _central: &[f32],
        _t: u64,
        _metrics: &mut Metrics,
    ) -> Result<bool> {
        Ok(std::time::Instant::now() >= self.deadline)
    }
}

/// Collects the per-round straggler series the backend reports (Table 5 /
/// Fig. 5 harness).
#[derive(Default)]
pub struct StragglerRecorder {
    pub gaps_secs: Vec<f64>,
}

impl StragglerRecorder {
    pub fn mean(&self) -> f64 {
        if self.gaps_secs.is_empty() {
            0.0
        } else {
            self.gaps_secs.iter().sum::<f64>() / self.gaps_secs.len() as f64
        }
    }
}

impl Callback for StragglerRecorder {
    fn after_central_iteration(
        &mut self,
        _central: &[f32],
        _t: u64,
        metrics: &mut Metrics,
    ) -> Result<bool> {
        if let Some(g) = metrics.get("sys/straggler-secs") {
            self.gaps_secs.push(g);
        }
        Ok(false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn early_stopping_patience() {
        let mut es = EarlyStopping::new("loss", false, 2);
        let mut m = Metrics::new();
        m.add_central("loss", 1.0, 1.0);
        assert!(!es.after_central_iteration(&[], 0, &mut m).unwrap());
        // three non-improving rounds -> stop on the third
        for t in 1..=2 {
            let mut m = Metrics::new();
            m.add_central("loss", 1.5, 1.0);
            assert!(!es.after_central_iteration(&[], t, &mut m).unwrap());
        }
        let mut m = Metrics::new();
        m.add_central("loss", 1.5, 1.0);
        assert!(es.after_central_iteration(&[], 3, &mut m).unwrap());
    }

    #[test]
    fn early_stopping_ignores_missing_metric() {
        let mut es = EarlyStopping::new("loss", false, 0);
        let mut m = Metrics::new();
        assert!(!es.after_central_iteration(&[], 0, &mut m).unwrap());
    }

    #[test]
    fn ema_tracks_params() {
        let mut ema = EmaCallback::new(0.5);
        let mut m = Metrics::new();
        ema.after_central_iteration(&[2.0, 4.0], 0, &mut m).unwrap();
        assert_eq!(ema.ema(), &[2.0, 4.0]);
        ema.after_central_iteration(&[0.0, 0.0], 1, &mut m).unwrap();
        assert_eq!(ema.ema(), &[1.0, 2.0]);
    }

    #[test]
    fn checkpoint_roundtrip() {
        let dir = std::env::temp_dir().join(format!("pfl_test_ckpt_{}", std::process::id()));
        let mut cb = CheckpointCallback::new(&dir, 1);
        let mut m = Metrics::new();
        cb.after_central_iteration(&[1.0, -2.5, 3.0], 7, &mut m).unwrap();
        let (params, next_t) = load_checkpoint(&dir).unwrap();
        assert_eq!(params, vec![1.0, -2.5, 3.0]);
        assert_eq!(next_t, 8);
        std::fs::remove_file(&dir).ok();
    }

    #[test]
    fn checkpoint_rejects_garbage() {
        let dir = std::env::temp_dir().join(format!("pfl_test_bad_{}", std::process::id()));
        std::fs::write(&dir, b"not a checkpoint").unwrap();
        assert!(load_checkpoint(&dir).is_err());
        std::fs::remove_file(&dir).ok();
    }

    #[test]
    fn csv_reporter_writes_rows() {
        let path = std::env::temp_dir().join(format!("pfl_test_csv_{}", std::process::id()));
        let mut cb = CsvReporter::new(&path);
        for t in 0..3 {
            let mut m = Metrics::new();
            m.add_central("loss", t as f64, 1.0);
            cb.after_central_iteration(&[], t, &mut m).unwrap();
        }
        cb.on_train_end(&[]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("round,loss"));
        assert_eq!(text.lines().count(), 4);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn jsonl_reporter_emits_valid_json() {
        let path = std::env::temp_dir().join(format!("pfl_test_jsonl_{}", std::process::id()));
        {
            let mut cb = JsonlReporter::new(&path).unwrap();
            let mut m = Metrics::new();
            m.add_central("x", 0.5, 1.0);
            cb.after_central_iteration(&[], 0, &mut m).unwrap();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let v = crate::util::json::Value::parse(text.trim()).unwrap();
        assert_eq!(v.req("x").unwrap().as_f64().unwrap(), 0.5);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn time_budget_stops() {
        let mut tb = TimeBudget::new(std::time::Duration::from_millis(0));
        let mut m = Metrics::new();
        assert!(tb.after_central_iteration(&[], 0, &mut m).unwrap());
    }

    #[test]
    fn straggler_recorder_collects() {
        let mut sr = StragglerRecorder::default();
        let mut m = Metrics::new();
        m.add_central("sys/straggler-secs", 0.25, 1.0);
        sr.after_central_iteration(&[], 0, &mut m).unwrap();
        assert_eq!(sr.gaps_secs, vec![0.25]);
        assert_eq!(sr.mean(), 0.25);
    }
}
