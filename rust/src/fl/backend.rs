//! `SimulatedBackend` — the generalized PFL simulation loop, a faithful
//! implementation of paper Algorithm 1:
//!
//! ```text
//! repeat
//!   (C, θ') ← alg.get_next_central_contexts(θ, t)      // next_contexts
//!   for each context c_i ∈ C:
//!     sample cohort, distribute across workers          // scheduler
//!     workers: simulate_one_user → postprocess_one_user → accumulate
//!     Δ ← worker_reduce(partials)                        // all-reduce
//!     for p in reversed(P): Δ ← p.postprocess_server(Δ) // DP noise etc.
//!   θ ← alg.process_aggregated_statistics_all_contexts
//!   for b in callbacks: stop |= b.after_central_iteration(θ, t)
//! until stop
//! ```
//!
//! The backend simulates only the *computation* of FL: the only
//! synchronization is the per-round reduce over worker partials (§3.1).

use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};

use super::aggregator::Aggregator;
use super::algorithm::FederatedAlgorithm;
use super::callbacks::Callback;
use super::context::{CentralContext, Population};
use super::metrics::Metrics;
use super::model::RustClip;
use super::postprocess::{Postprocessor, PpEnv};
use super::scheduler::{schedule, SchedulerKind};
use super::worker::{ModelFactory, WorkerPool, WorkerShared};
use crate::baselines::OverheadProfile;
use crate::data::{CohortSampler, FederatedDataset, MinibatchSampler};
use crate::simsys::{current_rss_bytes, Counters, Timeline, TimelineRow, UserCost};
use crate::util::rng::Rng;

/// Everything a simulation run needs besides the algorithm + model.
pub struct RunParams {
    /// Worker replica count (the paper's g·p worker processes).
    pub num_workers: usize,
    pub scheduler: SchedulerKind,
    pub profile: OverheadProfile,
    pub seed: u64,
    /// Print a metrics line every k rounds (0 = silent).
    pub log_every: u64,
    /// Which clip kernel the per-user DP path uses. `Hlo` is the paper's
    /// on-device design (no host transfer on a real accelerator); on CPU
    /// PJRT the buffers are host-side anyway and the interpret-mode
    /// Pallas kernel is ~24x slower than the native path (§Perf), so the
    /// CPU default is `Rust`. Both are bit-compatible (tested).
    pub clip_backend: ClipBackend,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClipBackend {
    Hlo,
    Rust,
}

impl Default for RunParams {
    fn default() -> Self {
        RunParams {
            num_workers: 1,
            scheduler: SchedulerKind::GreedyMedianBase,
            profile: OverheadProfile::default(),
            seed: 0,
            log_every: 0,
            clip_backend: ClipBackend::Rust,
        }
    }
}

/// The result of a full simulation run.
pub struct RunOutcome {
    /// Final central model parameters.
    pub central: Vec<f32>,
    /// Central iterations completed.
    pub rounds: u64,
    pub wall_secs: f64,
    /// Per-round metrics (train + namespaced val + sys).
    pub history: Vec<(u64, Metrics)>,
    /// Merged system counters across all workers and rounds.
    pub counters: Counters,
    /// Per-round timeline (Figs. 7–8 output format).
    pub timeline: Timeline,
    /// Per-round wall-clock nanos.
    pub round_nanos: Vec<u64>,
    /// Per-round measured straggler gap (Table 5 / Fig. 5).
    pub straggler_nanos: Vec<u64>,
    /// Per-user (datapoints, nanos) records sampled across the run
    /// (Fig. 4a; virtual-cluster replay input).
    pub user_costs: Vec<UserCost>,
    /// Per-worker busy nanos summed over rounds (GPU-hours analogue).
    pub worker_busy_nanos: Vec<u64>,
}

impl RunOutcome {
    /// Last value of a metric across the history.
    pub fn final_metric(&self, name: &str) -> Option<f64> {
        self.history.iter().rev().find_map(|(_, m)| m.get(name))
    }

    /// Full series of a metric: (round, value).
    pub fn series(&self, name: &str) -> Vec<(u64, f64)> {
        self.history
            .iter()
            .filter_map(|(t, m)| m.get(name).map(|v| (*t, v)))
            .collect()
    }
}

/// The simulation backend (paper App. B.1 "Backend"; the only concrete
/// backend, as in pfl-research's initial release).
pub struct SimulatedBackend {
    dataset: Arc<dyn FederatedDataset>,
    val_dataset: Arc<dyn FederatedDataset>,
    algorithm: Arc<dyn FederatedAlgorithm>,
    aggregator: Arc<dyn Aggregator>,
    postprocessors: Arc<Vec<Box<dyn Postprocessor>>>,
    sampler: Box<dyn CohortSampler>,
    pool: WorkerPool,
    params: RunParams,
}

pub struct BackendBuilder {
    pub dataset: Arc<dyn FederatedDataset>,
    pub val_dataset: Option<Arc<dyn FederatedDataset>>,
    pub algorithm: Arc<dyn FederatedAlgorithm>,
    pub aggregator: Option<Arc<dyn Aggregator>>,
    pub postprocessors: Vec<Box<dyn Postprocessor>>,
    pub sampler: Option<Box<dyn CohortSampler>>,
    pub factory: ModelFactory,
    pub params: RunParams,
}

impl BackendBuilder {
    pub fn new(
        dataset: Arc<dyn FederatedDataset>,
        algorithm: Arc<dyn FederatedAlgorithm>,
        factory: ModelFactory,
    ) -> Self {
        BackendBuilder {
            dataset,
            val_dataset: None,
            algorithm,
            aggregator: None,
            postprocessors: Vec::new(),
            sampler: None,
            factory,
            params: RunParams::default(),
        }
    }

    pub fn postprocessor(mut self, pp: Box<dyn Postprocessor>) -> Self {
        self.postprocessors.push(pp);
        self
    }

    pub fn params(mut self, params: RunParams) -> Self {
        self.params = params;
        self
    }

    pub fn val_dataset(mut self, ds: Arc<dyn FederatedDataset>) -> Self {
        self.val_dataset = Some(ds);
        self
    }

    pub fn sampler(mut self, s: Box<dyn CohortSampler>) -> Self {
        self.sampler = Some(s);
        self
    }

    pub fn build(self) -> Result<SimulatedBackend> {
        let postprocessors = Arc::new(self.postprocessors);
        let shared = WorkerShared {
            dataset: self.dataset.clone(),
            algorithm: self.algorithm.clone(),
            postprocessors: postprocessors.clone(),
            aggregator: self
                .aggregator
                .clone()
                .unwrap_or_else(|| Arc::new(super::aggregator::SumAggregator)),
            factory: self.factory,
            profile: self.params.profile.clone(),
            seed: self.params.seed,
            use_hlo_clip: self.params.clip_backend == ClipBackend::Hlo,
        };
        let pool = WorkerPool::new(self.params.num_workers, shared)?;
        Ok(SimulatedBackend {
            val_dataset: self.val_dataset.unwrap_or_else(|| self.dataset.clone()),
            dataset: self.dataset,
            algorithm: self.algorithm,
            aggregator: self
                .aggregator
                .unwrap_or_else(|| Arc::new(super::aggregator::SumAggregator)),
            postprocessors,
            sampler: self.sampler.unwrap_or_else(|| Box::new(MinibatchSampler { cohort_size: 0 })),
            pool,
            params: self.params,
        })
    }
}

impl SimulatedBackend {
    /// Run the full simulation from `central` (paper Alg. 1). Callbacks
    /// run on this thread after every central iteration and may stop
    /// training early.
    pub fn run(
        &mut self,
        mut central: Vec<f32>,
        callbacks: &mut [Box<dyn Callback>],
    ) -> Result<RunOutcome> {
        let start = Instant::now();
        let mut server_rng = Rng::seed_from_u64(self.params.seed ^ 0x5E12_4E4D);
        let mut outcome = RunOutcome {
            central: Vec::new(),
            rounds: 0,
            wall_secs: 0.0,
            history: Vec::new(),
            counters: Counters::default(),
            timeline: Timeline::default(),
            round_nanos: Vec::new(),
            straggler_nanos: Vec::new(),
            user_costs: Vec::new(),
            worker_busy_nanos: vec![0; self.pool.num_workers],
        };

        let mut t: u64 = 0;
        'outer: loop {
            let contexts = self.algorithm.next_contexts(t);
            if contexts.is_empty() {
                break; // the algorithm signaled training should end
            }
            let round_start = Instant::now();
            let mut round_metrics = Metrics::new();

            for ctx in &contexts {
                let (agg, metrics) = self
                    .run_context(ctx, &central, &mut server_rng, &mut outcome)
                    .with_context(|| format!("iteration {t} ({:?})", ctx.population))?;
                match ctx.population {
                    Population::Train => {
                        round_metrics.merge(&metrics);
                        if let Some(mut agg) = agg {
                            // densify once at the chokepoint: algorithms
                            // consume the aggregate through dense slices,
                            // and a sparse aggregate reaching one that
                            // forgot densify_all() would silently no-op
                            agg.densify_all();
                            self.algorithm
                                .process_aggregated(&mut central, ctx, agg, &mut round_metrics)?;
                        }
                    }
                    Population::Val => round_metrics.merge(&metrics.prefixed("val/")),
                }
            }

            let round_nanos = round_start.elapsed().as_nanos() as u64;
            outcome.round_nanos.push(round_nanos);
            round_metrics.add_central("sys/round-secs", round_nanos as f64 / 1e9, 1.0);

            // full-participation bookkeeping tax (FedScale-like engines):
            // O(population) work per round.
            if self.params.profile.full_participation_bookkeeping {
                let mut acc = 0u64;
                for uid in 0..self.dataset.num_users() {
                    acc = acc.wrapping_add(self.dataset.user_len(uid) as u64);
                }
                std::hint::black_box(acc);
            }
            if self.params.profile.checkpoint_every_round {
                // hard-coded per-round checkpointing (FedScale): serialize
                // the model to a scratch file.
                let path = std::env::temp_dir().join("pfl_baseline_ckpt.bin");
                let mut buf = Vec::with_capacity(central.len() * 4);
                for x in &central {
                    buf.extend_from_slice(&x.to_le_bytes());
                }
                let _ = std::fs::write(path, &buf);
            }

            let mut stop = false;
            for cb in callbacks.iter_mut() {
                stop |= cb.after_central_iteration(&central, t, &mut round_metrics)?;
            }

            if self.params.log_every > 0 && t % self.params.log_every == 0 {
                println!("[round {t}] {round_metrics}");
            }
            outcome.timeline.push(TimelineRow {
                round: t,
                wall_secs: start.elapsed().as_secs_f64(),
                rss_bytes: current_rss_bytes(),
                busy_frac: 0.0, // filled by callers that track device busy
                loop_alloc_bytes: outcome.counters.loop_alloc_bytes,
                copy_bytes: outcome.counters.copy_bytes,
            });
            outcome.history.push((t, round_metrics));
            outcome.rounds = t + 1;
            t += 1;
            if stop {
                break 'outer;
            }
        }

        for cb in callbacks.iter_mut() {
            cb.on_train_end(&central)?;
        }
        outcome.wall_secs = start.elapsed().as_secs_f64();
        outcome.central = central;
        Ok(outcome)
    }

    /// Sample + schedule + train one context's cohort, reduce the worker
    /// partials and apply the server-side postprocessors (reversed).
    fn run_context(
        &self,
        ctx: &CentralContext,
        central: &[f32],
        server_rng: &mut Rng,
        outcome: &mut RunOutcome,
    ) -> Result<(Option<super::stats::Statistics>, Metrics)> {
        let dataset = match ctx.population {
            Population::Train => &self.dataset,
            Population::Val => &self.val_dataset,
        };
        // --- sample the cohort (with the postprocessors' participation
        // filters, e.g. banded-MF min-separation) -----------------------
        let mut cohort = if ctx.cohort_size > 0 {
            MinibatchSampler { cohort_size: ctx.cohort_size }.sample(
                dataset.num_users(),
                ctx.iteration,
                ctx.seed,
            )
        } else {
            self.sampler.sample(dataset.num_users(), ctx.iteration, ctx.seed)
        };
        if ctx.population == Population::Train {
            cohort.retain(|&uid| {
                self.postprocessors.iter().all(|p| p.may_participate(uid, ctx.iteration))
            });
            for &uid in &cohort {
                for p in self.postprocessors.iter() {
                    p.record_participation(uid, ctx.iteration);
                }
            }
        }

        // --- greedy load balancing (App. B.6) --------------------------
        let weights: Vec<f64> = cohort.iter().map(|&u| dataset.user_len(u) as f64).collect();
        let sched = schedule(self.params.scheduler, &weights, self.pool.num_workers);
        let assignments: Vec<Vec<usize>> = sched
            .assignments
            .iter()
            .map(|idxs| idxs.iter().map(|&i| cohort[i]).collect())
            .collect();

        // --- distribute + train ----------------------------------------
        let central_arc = Arc::new(central.to_vec());
        let results = self.pool.run_round(ctx, central_arc, assignments)?;

        let mut metrics = Metrics::new();
        let mut partials = Vec::with_capacity(results.len());
        let mut worker_busy: Vec<u64> = Vec::with_capacity(results.len());
        let mut round_stat_elements = 0u64;
        for r in results {
            metrics.merge(&r.metrics);
            round_stat_elements += r.counters.stat_elements;
            outcome.counters.merge(&r.counters);
            let busy: u64 = r.costs.iter().map(|c| c.nanos).sum();
            worker_busy.push(busy);
            outcome.worker_busy_nanos[r.worker] += busy;
            // keep a bounded sample of user costs for Fig. 4a
            if outcome.user_costs.len() < 100_000 {
                outcome.user_costs.extend(&r.costs);
            }
            if let Some(p) = r.partial {
                partials.push(p);
            }
        }
        if ctx.population == Population::Train {
            let gap = crate::simsys::straggler_gap_nanos(&worker_busy);
            outcome.straggler_nanos.push(gap);
            metrics.add_central("sys/straggler-secs", gap as f64 / 1e9, 1.0);
            metrics.add_central("sys/cohort", cohort.len() as f64, 1.0);
            // user→server wire volume this round, in f32-equivalents
            // (sparse updates count idx + val per nonzero)
            metrics.add_central("sys/user-update-elems", round_stat_elements as f64, 1.0);
        }

        // --- worker_reduce (all-reduce equivalent) ----------------------
        let mut agg = self.aggregator.worker_reduce(partials);
        if ctx.population == Population::Train {
            if let Some(a) = agg.as_ref() {
                // stored f32s in the reduced aggregate (dense after an
                // arena round by design; the per-user communication
                // saving shows up in sys/user-update-elems instead)
                metrics.add_central("sys/agg-elements", a.element_count() as f64, 1.0);
            }
        }

        // --- server postprocessors, reversed (paper Alg. 1 l.18) --------
        if let Some(agg) = agg.as_mut() {
            let mut env = PpEnv { clip: &RustClip, rng: server_rng, user_len: 0 };
            for pp in self.postprocessors.iter().rev() {
                let pm = pp.postprocess_server(agg, ctx, &mut env)?;
                metrics.merge(&pm);
            }
        }
        Ok((agg, metrics))
    }

    pub fn num_workers(&self) -> usize {
        self.pool.num_workers
    }

    /// Coordinator traffic counters (baseline diagnostics).
    pub fn coordinator_traffic(&self) -> (u64, u64) {
        self.pool.coordinator_traffic()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fl::algorithm::{FedAvg, RunSpec};
    use crate::fl::central_opt::Sgd;
    use crate::fl::worker::tests::MeanModel;

    fn build_backend(workers: usize, iters: u64) -> SimulatedBackend {
        let dataset: Arc<dyn FederatedDataset> =
            Arc::new(crate::data::SynthGmmPoints::new(32, 12, 3, 2, 1));
        let spec = RunSpec {
            iterations: iters,
            cohort_size: 8,
            val_cohort_size: 4,
            eval_every: 2,
            population: 32,
            ..Default::default()
        };
        let alg = Arc::new(FedAvg::new(spec, Box::new(Sgd)));
        BackendBuilder::new(
            dataset,
            alg,
            Arc::new(|_| Ok(Box::new(MeanModel::new(3)) as Box<dyn crate::fl::Model>)),
        )
        .params(RunParams { num_workers: workers, ..Default::default() })
        .build()
        .unwrap()
    }

    #[test]
    fn run_completes_all_iterations() {
        let mut b = build_backend(2, 5);
        let out = b.run(vec![0.0; 3], &mut []).unwrap();
        assert_eq!(out.rounds, 5);
        assert_eq!(out.history.len(), 5);
        assert_eq!(out.round_nanos.len(), 5);
        assert!(out.counters.users_trained >= 5 * 8);
        assert!(out.final_metric("train/loss").is_some());
        // val rounds every 2 iterations
        assert!(out.final_metric("val/loss").is_some());
    }

    #[test]
    fn loss_decreases_on_mean_problem() {
        let mut b = build_backend(2, 30);
        let out = b.run(vec![5.0; 3], &mut []).unwrap();
        let series = out.series("train/loss");
        let first = series.first().unwrap().1;
        let last = series.last().unwrap().1;
        assert!(last < first * 0.9, "loss {first} -> {last}");
    }

    #[test]
    fn worker_count_does_not_change_learning() {
        // replica-worker invariance: final model identical across worker
        // counts (the sum aggregation is exchange-law compliant; MeanModel
        // arithmetic is deterministic).
        let out1 = build_backend(1, 6).run(vec![1.0; 3], &mut []).unwrap();
        let out4 = build_backend(4, 6).run(vec![1.0; 3], &mut []).unwrap();
        for (a, b) in out1.central.iter().zip(&out4.central) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn outcome_series_and_final_metric() {
        let mut b = build_backend(1, 4);
        let out = b.run(vec![0.0; 3], &mut []).unwrap();
        let series = out.series("sys/cohort");
        assert_eq!(series.len(), 4);
        assert_eq!(out.final_metric("sys/cohort"), Some(8.0));
        assert!(out.final_metric("does-not-exist").is_none());
    }
}
